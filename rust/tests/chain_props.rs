//! Property-based tests on coordinator invariants — no PJRT needed, so
//! these run fast and exercise the accounting / ordering / data machinery
//! over randomized inputs (in-repo prop harness; proptest is unavailable
//! offline).

use std::collections::BTreeMap;
use std::sync::Arc;

use coc::chain::Technique;
use coc::data::{Batcher, Dataset, DatasetKind};
use coc::exits;
use coc::models::{Accountant, ArchManifest, LayerDesc, LayerKind, MaskSlot, ModelState, QBits};
use coc::order::{Preference, PreferenceGraph, SortOutcome};
use coc::tensor::Tensor;
use coc::util::prop::{check, Shrink};
use coc::util::stats;

fn rand_arch(rng: &mut coc::util::rng::Rng) -> Arc<ArchManifest> {
    let nconv = 1 + rng.below(4);
    let mut layers = Vec::new();
    let mut mask_slots = Vec::new();
    let mut param_shapes = Vec::new();
    let mut cin = 3usize;
    let mut in_mask = -1i64;
    let mut hw = 16usize;
    for i in 0..nconv {
        let cout = 4 + rng.below(28);
        mask_slots.push(MaskSlot { name: format!("m{i}"), channels: cout });
        layers.push(LayerDesc {
            name: format!("c{i}"),
            kind: LayerKind::Conv,
            k: 3,
            cin,
            cout,
            stride: 1,
            hout: hw,
            wout: hw,
            in_mask,
            out_mask: i as i64,
            segment: if i < nconv / 2 { "seg1" } else { "seg2" }.into(),
            input: String::new(),
            act: true,
        });
        param_shapes.push(vec![3, 3, cin, cout]);
        param_shapes.push(vec![cout]);
        in_mask = i as i64;
        cin = cout;
        if i % 2 == 1 && hw > 4 {
            hw /= 2;
        }
    }
    layers.push(LayerDesc {
        name: "fc".into(),
        kind: LayerKind::Dense,
        k: 1,
        cin,
        cout: 20,
        stride: 1,
        hout: 1,
        wout: 1,
        in_mask,
        out_mask: -1,
        segment: "seg3".into(),
        input: String::new(),
        act: true,
    });
    param_shapes.push(vec![cin, 20]);
    param_shapes.push(vec![20]);
    Arc::new(ArchManifest {
        name: "rand".into(),
        num_classes: 20,
        layers,
        mask_slots,
        param_shapes,
        graphs: BTreeMap::new(),
        train_batch: 8,
        eval_batch: 8,
        stage_batch: 1,
        stage_batches: vec![1],
        stage_h1_shape: vec![1],
        stage_h2_shape: vec![1],
        joins: Vec::new(),
    })
}

#[derive(Clone, Debug)]
struct ArchCase {
    seed: u64,
    prune: Vec<usize>, // channels to kill in slot 0
    bits: (u8, u8),
}

impl Shrink for ArchCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if !self.prune.is_empty() {
            out.push(ArchCase { prune: self.prune[..self.prune.len() / 2].to_vec(), ..self.clone() });
        }
        out
    }
}

/// BitOps must decrease monotonically under more pruning and under fewer
/// bits, and never go negative.
#[test]
fn prop_accounting_monotone() {
    check(
        "accounting monotone",
        120,
        |rng| ArchCase {
            seed: rng.next_u64(),
            prune: {
                let n = rng.below(4);
                (0..n).map(|_| rng.below(32)).collect()
            },
            bits: ([0u8, 1, 2, 4, 8][rng.below(5)], [0u8, 2, 8][rng.below(3)]),
        },
        |case| {
            let mut rng = coc::util::rng::Rng::new(case.seed);
            let arch = rand_arch(&mut rng);
            let mut st = ModelState::init_host(arch.clone(), case.seed);
            let full = Accountant::new(&st).expected_bitops();
            if full <= 0.0 {
                return Err("baseline bitops not positive".into());
            }
            // prune some channels of slot 0
            let c0 = arch.mask_slots[0].channels;
            for &p in &case.prune {
                st.masks[0].data[p % c0] = 0.0;
            }
            let pruned = Accountant::new(&st).expected_bitops();
            if pruned > full + 1e-6 {
                return Err(format!("pruning increased bitops {full} -> {pruned}"));
            }
            st.qbits = QBits { weight: case.bits.0 as f32, act: case.bits.1 as f32 };
            let quant = Accountant::new(&st).expected_bitops();
            if quant > pruned + 1e-6 {
                return Err(format!("quantizing increased bitops {pruned} -> {quant}"));
            }
            let cr = Accountant::new(&st).bitops_cr();
            if !(cr >= 1.0 - 1e-9 && cr.is_finite()) {
                return Err(format!("CR {cr} out of range"));
            }
            Ok(())
        },
    );
}

/// Storage accounting: pruning + quantization never increase storage, and
/// the fp32 unpruned state matches the baseline exactly.
#[test]
fn prop_storage_consistent() {
    check(
        "storage consistent",
        100,
        |rng| rng.next_u64(),
        |&seed| {
            let mut rng = coc::util::rng::Rng::new(seed);
            let arch = rand_arch(&mut rng);
            let st = ModelState::init_host(arch.clone(), seed);
            let base = Accountant::baseline_storage(&arch);
            let now = Accountant::new(&st).storage_bits();
            if (base - now).abs() > 1e-6 {
                return Err(format!("fp32 storage {now} != baseline {base}"));
            }
            let mut q = st.clone();
            q.qbits = QBits { weight: 2.0, act: 8.0 };
            if Accountant::new(&q).storage_bits() >= now {
                return Err("quantized storage not smaller".into());
            }
            Ok(())
        },
    );
}

/// Any complete preference set (random margins, random directions) either
/// toposorts or reports a cycle — never panics, never loses techniques.
#[test]
fn prop_toposort_total() {
    check(
        "toposort total",
        300,
        |rng| {
            (0..6).map(|_| (rng.f32() - 0.5) * 2.0).collect::<Vec<f32>>()
        },
        |margins| {
            use Technique::*;
            let pairs =
                [(Distill, Prune), (Distill, Quantize), (Distill, EarlyExit), (Prune, Quantize), (Prune, EarlyExit), (Quantize, EarlyExit)];
            let mut g = PreferenceGraph::default();
            for (i, &(a, b)) in pairs.iter().enumerate() {
                g.add(Preference { first: a, second: b, margin: margins[i] as f64 });
            }
            match g.toposort() {
                SortOutcome::Unique(o) | SortOutcome::Ambiguous(o) => {
                    if o.len() != 4 {
                        return Err(format!("lost techniques: {o:?}"));
                    }
                    // Every edge must be respected.
                    let pos: BTreeMap<Technique, usize> =
                        o.iter().enumerate().map(|(i, &t)| (t, i)).collect();
                    for (&(a, b), _) in &g.edges {
                        if pos[&a] > pos[&b] {
                            return Err(format!("order {o:?} violates edge {a:?}->{b:?}"));
                        }
                    }
                    Ok(())
                }
                SortOutcome::Cycle(_) => Ok(()),
            }
        },
    );
}

/// Batcher: over any epoch, no index repeats; all batches full-size.
#[test]
fn prop_batcher_epoch_partition() {
    check(
        "batcher epoch partition",
        100,
        |rng| (8 + rng.below(200), 1 + rng.below(16)),
        |&(n, b)| {
            if b > n {
                return Ok(());
            }
            let mut batcher = Batcher::new(n, b, 99);
            let per_epoch = n / b;
            let mut seen = vec![0u8; n];
            for _ in 0..per_epoch {
                for &i in batcher.next_indices() {
                    if i >= n {
                        return Err(format!("index {i} out of range {n}"));
                    }
                    seen[i] += 1;
                    if seen[i] > 1 {
                        return Err(format!("index {i} repeated within epoch"));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Exit-policy accounting: exit probabilities sum to <= 1, accuracy in
/// [0,1], and raising thresholds never increases exit rates.
#[test]
fn prop_exit_policy_monotone() {
    check(
        "exit policy monotone",
        60,
        |rng| rng.next_u64(),
        |&seed| {
            let mut r = coc::util::rng::Rng::new(seed);
            let n = 40;
            let nc = 10;
            let mk = |r: &mut coc::util::rng::Rng| {
                Tensor::new(
                    vec![n, nc],
                    (0..n * nc).map(|_| r.normal() * 2.0).collect(),
                )
            };
            let (main, e1, e2) = (mk(&mut r), mk(&mut r), mk(&mut r));
            let labels: Vec<usize> = (0..n).map(|_| r.below(nc)).collect();
            let lo = exits::evaluate_from_logits(&main, &e1, &e2, &labels, 0.3, 0.3);
            let hi = exits::evaluate_from_logits(&main, &e1, &e2, &labels, 0.9, 0.9);
            for ev in [&lo, &hi] {
                if ev.p_exit1 + ev.p_exit2 > 1.0 + 1e-9 {
                    return Err("exit probs exceed 1".into());
                }
                if !(0.0..=1.0).contains(&ev.accuracy) {
                    return Err("accuracy out of range".into());
                }
            }
            if hi.p_exit1 > lo.p_exit1 + 1e-9 {
                return Err(format!(
                    "raising threshold increased exit1 rate {} -> {}",
                    lo.p_exit1, hi.p_exit1
                ));
            }
            Ok(())
        },
    );
}

/// Pareto frontier: every input point is dominated-by-or-equal-to some
/// frontier point, and the frontier is strictly increasing in x with
/// decreasing-or-equal y ordering violations.
#[test]
fn prop_pareto_frontier_sound() {
    check(
        "pareto frontier sound",
        200,
        |rng| {
            let n = 1 + rng.below(30);
            (0..n)
                .map(|_| (1.0 + rng.f32() as f64 * 100.0, rng.f32() as f64))
                .collect::<Vec<(f64, f64)>>()
        },
        |pts| {
            let f = stats::pareto_frontier(
                &pts.iter().map(|&(a, b)| (a, b)).collect::<Vec<_>>(),
            );
            if f.is_empty() {
                return Err("empty frontier from non-empty points".into());
            }
            for &(x, y) in pts {
                let covered = f.iter().any(|&(fx, fy)| fx >= x && fy >= y);
                if !covered {
                    return Err(format!("point ({x},{y}) not dominated by frontier"));
                }
            }
            for w in f.windows(2) {
                if w[1].0 <= w[0].0 {
                    return Err("frontier x not increasing".into());
                }
            }
            Ok(())
        },
    );
}

/// Dataset determinism + label/shape invariants across all four analogs.
#[test]
fn prop_dataset_invariants() {
    check(
        "dataset invariants",
        40,
        |rng| (rng.next_u64(), rng.below(4)),
        |&(seed, kid)| {
            let kind = [
                DatasetKind::SynthC10,
                DatasetKind::SynthC100,
                DatasetKind::SynthSVHN,
                DatasetKind::SynthCINIC,
            ][kid];
            let a = Dataset::generate(kind, 24, seed, 0);
            let b = Dataset::generate(kind, 24, seed, 0);
            if a.images.data != b.images.data || a.labels != b.labels {
                return Err("generation not deterministic".into());
            }
            if a.labels.iter().any(|&l| l >= kind.num_classes()) {
                return Err("label out of range".into());
            }
            if a.images.data.iter().any(|v| !v.is_finite()) {
                return Err("non-finite pixel".into());
            }
            Ok(())
        },
    );
}
