//! Shared helpers for the hermetic ref-backend suites: the builtin
//! architecture matrix and the canonical golden-digest flow.  Each test
//! binary pulls this in with `mod common;` and uses its own subset.
#![allow(dead_code)]

use std::sync::Arc;

use coc::data::{Dataset, DatasetKind};
use coc::models::{builtin_ref_manifest, ArchManifest, BUILTIN_REF_ARCHS};
use coc::runtime::Engine;
use coc::train::{self, TrainOpts};

/// The architecture matrix every hermetic suite runs over: the legacy
/// feed-forward chain plus the two DAG topologies (residual joins and
/// depthwise towers with a skip join).
pub const REF_ARCHS: [&str; 3] = BUILTIN_REF_ARCHS;

/// Builtin arch by name (panics on unknown names — test-only).
pub fn builtin_arch(name: &str) -> Arc<ArchManifest> {
    builtin_ref_manifest().arch(name).unwrap()
}

/// One canonical train -> eval flow on the ref backend, hashed to a
/// single value (FNV-1a over the exact f32 bit patterns of params,
/// momenta, losses, and all three logit heads).  Shared by the
/// thread-count and SIMD-ISA digest tests; CI additionally diffs the
/// per-arch digest lines across `COC_REF_THREADS` / `COC_REF_SIMD`
/// settings, pinning the invariance across processes too.
pub fn golden_digest(arch_name: &str, threads: Option<usize>) -> u64 {
    let engine = match threads {
        Some(t) => Engine::new_ref_with_threads(t).unwrap(),
        None => Engine::new_ref().unwrap(), // COC_REF_THREADS / parallelism
    };
    let arch = builtin_arch(arch_name);
    // mini_vgg keeps the original real-sized flow (big enough that the
    // kernel thread pool actually engages); the deeper DAG archs use a
    // shorter schedule so the matrixed suite stays bounded.
    let (steps, ntrain, ntest) =
        if arch_name == "mini_vgg" { (6usize, 96usize, 48usize) } else { (3, 48, 24) };
    let train_ds = Dataset::generate(DatasetKind::SynthC10, ntrain, 21, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, ntest, 21, 1);
    let mut st = train::init_state(&engine, arch, 21).unwrap();
    let opts = TrainOpts { steps, seed: 21, exit_w: [0.3, 0.3], ..Default::default() };
    let log = train::train(&engine, &mut st, &train_ds, None, &opts).unwrap();
    let (logits, e1, e2) = train::eval_logits(&engine, &st, &test_ds).unwrap();

    let mut h = 0xcbf29ce484222325u64;
    let mut eat = |data: &[f32]| {
        for v in data {
            for byte in v.to_bits().to_le_bytes() {
                h ^= byte as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
    };
    for t in st.params.iter().chain(st.momenta.iter()) {
        eat(&t.data);
    }
    eat(&log.losses);
    eat(&logits.data);
    eat(&e1.data);
    eat(&e2.data);
    h
}
