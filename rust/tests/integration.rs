//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! Requires `make artifacts` to have run (the repo checks artifacts in CI
//! via the Makefile `test` target).  One Engine per test function; the
//! heavyweight end-to-end scenario shares a single compiled graph set to
//! keep XLA compile time bounded.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use coc::chain::{stages, Chain, StageCtx};
use coc::data::{Dataset, DatasetKind};
use coc::metrics::Measurement;
use coc::models::{
    builtin_ref_manifest, Accountant, ArchManifest, LayerDesc, LayerKind, Manifest, MaskSlot,
    QBits,
};
use coc::runtime::Engine;
use coc::serve::Server;
use coc::train::{self, TrainOpts};

mod common;

fn artifacts_ok() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

/// Small feed-forward arch for the hermetic ref-backend suite: two convs
/// (one pooled), a classifier, and both exit heads; batched stage graphs
/// declared at batch 4.
fn ref_arch() -> Arc<ArchManifest> {
    let conv = |name: &str, cin: usize, cout: usize, hout: usize, im: i64, om: i64, seg: &str| {
        LayerDesc {
            name: name.into(),
            kind: LayerKind::Conv,
            k: 3,
            cin,
            cout,
            stride: 1,
            hout,
            wout: hout,
            in_mask: im,
            out_mask: om,
            segment: seg.into(),
            input: String::new(),
            act: true,
        }
    };
    let dense = |name: &str, cin: usize, im: i64, seg: &str| LayerDesc {
        name: name.into(),
        kind: LayerKind::Dense,
        k: 1,
        cin,
        cout: 10,
        stride: 1,
        hout: 1,
        wout: 1,
        in_mask: im,
        out_mask: -1,
        segment: seg.into(),
        input: String::new(),
        act: true,
    };
    let layers = vec![
        conv("c1", 3, 8, 16, -1, 0, "seg1"),
        conv("c2", 8, 16, 8, 0, 1, "seg2"),
        dense("fc", 16, 1, "seg3"),
        dense("x1", 8, 0, "exit1"),
        dense("x2", 16, 1, "exit2"),
    ];
    let param_shapes = vec![
        vec![3, 3, 3, 8],
        vec![8],
        vec![3, 3, 8, 16],
        vec![16],
        vec![16, 10],
        vec![10],
        vec![8, 10],
        vec![10],
        vec![16, 10],
        vec![10],
    ];
    let mut graphs = BTreeMap::new();
    for tag in [
        "init", "train", "eval", "stage1", "stage2", "stage3", "stage1_b4", "stage2_b4",
        "stage3_b4",
    ] {
        graphs.insert(tag.to_string(), format!("ref://itest/{tag}"));
    }
    Arc::new(ArchManifest {
        name: "ref_itest".into(),
        num_classes: 10,
        layers,
        mask_slots: vec![
            MaskSlot { name: "m0".into(), channels: 8 },
            MaskSlot { name: "m1".into(), channels: 16 },
        ],
        param_shapes,
        graphs,
        train_batch: 16,
        eval_batch: 32,
        stage_batch: 1,
        stage_batches: vec![1, 4],
        stage_h1_shape: vec![1, 16, 16, 8],
        stage_h2_shape: vec![1, 8, 8, 16],
        joins: Vec::new(),
    })
}

#[test]
fn manifest_parses_and_matches_graphs() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let m = Manifest::load("artifacts").unwrap();
    assert_eq!(m.num_classes, 20);
    assert_eq!(m.archs.len(), 3);
    for (name, arch) in &m.archs {
        assert_eq!(&arch.name, name);
        for tag in ["init", "train", "eval", "stage1", "stage2", "stage3"] {
            let file = arch.graph(tag).unwrap();
            assert!(
                Path::new("artifacts").join(file).exists(),
                "missing artifact {file}"
            );
        }
        // (w, b) per layer.
        assert_eq!(arch.param_shapes.len(), 2 * arch.layers.len());
        // masks cover declared channels.
        for l in &arch.layers {
            if l.out_mask >= 0 {
                assert_eq!(arch.mask_slots[l.out_mask as usize].channels, l.cout);
            }
        }
    }
}

/// The big end-to-end scenario on mini_vgg (smallest compile): init ->
/// train -> eval -> mask equivalence -> staged-vs-full -> save/load ->
/// chain stages -> serving.
#[test]
fn end_to_end_vgg() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();

    let train_ds = Dataset::generate(DatasetKind::SynthC10, 256, 5, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 96, 5, 1);

    // ---- init + a few train steps reduce the loss ----
    let mut state = train::init_state(&engine, arch.clone(), 5).unwrap();
    let opts = TrainOpts { steps: 40, ..Default::default() };
    let log = train::train(&engine, &mut state, &train_ds, None, &opts).unwrap();
    assert!(log.losses[0].is_finite());
    let first = log.losses[..5].iter().sum::<f32>() / 5.0;
    let last = log.losses[log.losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(last < first, "loss did not decrease: {first} -> {last}");

    // ---- eval produces sane logits & above-chance accuracy ----
    let (logits, e1, e2) = train::eval_logits(&engine, &state, &test_ds).unwrap();
    assert_eq!(logits.shape, vec![96, 20]);
    assert_eq!(e1.shape, vec![96, 20]);
    assert_eq!(e2.shape, vec![96, 20]);
    let acc = train::eval_accuracy(&engine, &state, &test_ds).unwrap();
    assert!(acc > 0.15, "accuracy {acc} not above chance");

    // ---- mask equivalence through the real graph ----
    let mut masked = state.clone();
    for c in 0..8 {
        masked.masks[0].data[c] = 0.0;
    }
    let (ml, _, _) = train::eval_logits(&engine, &masked, &test_ds).unwrap();
    let mut perturbed = masked.clone();
    // Perturb the dead channels' weights of the conv writing slot 0.
    let li = arch.layers.iter().position(|l| l.out_mask == 0).unwrap();
    let w = &mut perturbed.params[arch.weight_index(li)];
    let c_out = *w.shape.last().unwrap();
    for (i, v) in w.data.iter_mut().enumerate() {
        if i % c_out < 8 {
            *v += 5.0;
        }
    }
    let (pl, _, _) = train::eval_logits(&engine, &perturbed, &test_ds).unwrap();
    let max_diff = ml
        .data
        .iter()
        .zip(&pl.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "masked channels leak: max diff {max_diff}");

    // ---- staged graphs reproduce the full eval on a sample ----
    let server = Server::new(&engine, state.clone()).unwrap();
    let (x, _) = test_ds.batch(&[0]);
    // threshold 1.01: unreachable, so serving must use the main head.
    let (pred, stage) = server.infer(&x, 1.01, 1.01).unwrap();
    assert_eq!(stage, 3);
    assert_eq!(pred, logits.argmax_rows()[0], "staged main prediction differs from full eval");
    // threshold 0.0: always exit at stage 1 with exit1's prediction.
    let (pred1, stage1) = server.infer(&x, 0.0, 0.0).unwrap();
    assert_eq!(stage1, 1);
    assert_eq!(pred1, e1.argmax_rows()[0]);

    // ---- save / load round-trip preserves behaviour ----
    let tmp = std::env::temp_dir().join(format!("coc_it_{}.state", std::process::id()));
    state.save(&tmp).unwrap();
    let loaded = coc::models::ModelState::load(&tmp, arch.clone()).unwrap();
    std::fs::remove_file(&tmp).ok();
    let (ll, _, _) = train::eval_logits(&engine, &loaded, &test_ds).unwrap();
    assert_eq!(ll.data, logits.data);

    // ---- chain stages: P then Q strictly increase BitOpsCR ----
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 24,
        seed: 5,
        verbose: false,
    };
    let m0 = Measurement::take(&engine, &state, &test_ds).unwrap();
    let chain = Chain::new()
        .push(Box::new(stages::Prune { ratio: 0.3, ..Default::default() }))
        .push(Box::new(stages::Quantize { bits_w: 4.0, bits_a: 8.0, ..Default::default() }));
    let reports = chain.run(&mut state, &ctx).unwrap();
    assert_eq!(reports.len(), 2);
    assert!(reports[0].measurement.bitops_cr > m0.bitops_cr);
    assert!(reports[1].measurement.bitops_cr > reports[0].measurement.bitops_cr * 10.0);
    assert_eq!(state.qbits, QBits { weight: 4.0, act: 8.0 });
    assert!(state.keep_fraction() < 0.75);

    // accounting sanity: quantized+pruned CR in plausible band
    let acct = Accountant::new(&state);
    assert!(acct.bitops_cr() > 10.0 && acct.bitops_cr() < 5000.0);
    assert!(acct.storage_cr() > 4.0);

    // ---- early exit stage + serving with real skipping ----
    let chain = Chain::new().push(Box::new(stages::EarlyExit {
        threshold: 0.5,
        ..Default::default()
    }));
    chain.run(&mut state, &ctx).unwrap();
    assert!(state.exits.trained);
    let server = Server::new(&engine, state).unwrap();
    let rep = server.serve_dataset(&test_ds, 32, 0.5, 0.5).unwrap();
    assert_eq!(rep.requests, 32);
    assert!(rep.p_exit1 + rep.p_exit2 <= 1.0 + 1e-9);
    assert!(rep.latency_us.len() == 32);
    assert!(rep.throughput_rps > 0.0);

    // runtime stats accumulated
    let st = engine.stats();
    assert!(st.executions > 100);
    assert!(st.execute_ns > 0);
}

/// Distillation through the real graphs: a width-scaled student distilled
/// from a trained teacher must train stably and compress.  Uses
/// MiniResNet: the MiniVGG student at narrow widths is a documented
/// known-limitation (EXPERIMENTS.md) — its thin stem collapses under KD at
/// tiny budgets.
#[test]
fn distillation_produces_smaller_model() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_resnet").unwrap();
    let train_ds = Dataset::generate(DatasetKind::SynthSVHN, 256, 9, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthSVHN, 96, 9, 1);

    let mut teacher = train::init_state(&engine, arch.clone(), 9).unwrap();
    train::train(
        &engine,
        &mut teacher,
        &train_ds,
        None,
        &TrainOpts { steps: 60, ..Default::default() },
    )
    .unwrap();
    let t_bitops = Accountant::new(&teacher).expected_bitops();

    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 110,
        seed: 9,
        verbose: false,
    };
    let mut state = teacher.clone();
    // Gentler KD mix than the experiment default: at this tiny test budget
    // a hard-KD (alpha 0.7) student can stay at chance (see EXPERIMENTS.md
    // known limitations on narrow-width students under tight budgets).
    Chain::new()
        .push(Box::new(stages::Distill { width: 0.6, alpha: 0.3, ..Default::default() }))
        .run(&mut state, &ctx)
        .unwrap();
    let s_bitops = Accountant::new(&state).expected_bitops();
    // 0.6 width => ~0.36x MACs on interior convs; at least 1.5x overall.
    assert!(
        s_bitops < t_bitops / 1.5,
        "student BitOps {s_bitops:.2e} not < 2/3 of teacher {t_bitops:.2e}"
    );
    let acc = train::eval_accuracy(&engine, &state, &test_ds).unwrap();
    assert!(acc > 0.2, "student failed to learn: acc {acc}");
}

// ---------------------------------------------------------------------------
// Hermetic reference-backend suite: the same end-to-end guarantees as the
// PJRT tests above, running unconditionally (no artifacts, no self-skip).
// ---------------------------------------------------------------------------

/// init -> train -> eval -> mask equivalence -> staged-vs-full ->
/// save/load -> chain stages -> serving, all on the ref backend.
#[test]
fn ref_end_to_end() {
    let engine = Engine::new_ref().unwrap();
    let arch = ref_arch();

    let train_ds = Dataset::generate(DatasetKind::SynthC10, 256, 5, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 96, 5, 1);

    // ---- init + train steps reduce the loss ----
    let mut state = train::init_state(&engine, arch.clone(), 5).unwrap();
    let opts = TrainOpts { steps: 120, ..Default::default() };
    let log = train::train(&engine, &mut state, &train_ds, None, &opts).unwrap();
    assert!(log.losses[0].is_finite());
    let first = log.losses[..10].iter().sum::<f32>() / 10.0;
    let last = log.losses[log.losses.len() - 10..].iter().sum::<f32>() / 10.0;
    assert!(last < first, "loss did not decrease: {first} -> {last}");

    // ---- eval produces sane logits & above-chance accuracy ----
    let (logits, e1, e2) = train::eval_logits(&engine, &state, &test_ds).unwrap();
    assert_eq!(logits.shape, vec![96, 10]);
    assert_eq!(e1.shape, vec![96, 10]);
    assert_eq!(e2.shape, vec![96, 10]);
    let acc = train::eval_accuracy(&engine, &state, &test_ds).unwrap();
    assert!(acc > 0.15, "accuracy {acc} not above chance");

    // ---- mask equivalence: dead channels are *exactly* invisible ----
    let mut masked = state.clone();
    for c in 0..4 {
        masked.masks[0].data[c] = 0.0;
    }
    let (ml, _, _) = train::eval_logits(&engine, &masked, &test_ds).unwrap();
    let mut perturbed = masked.clone();
    let li = arch.layers.iter().position(|l| l.out_mask == 0).unwrap();
    let w = &mut perturbed.params[arch.weight_index(li)];
    let c_out = *w.shape.last().unwrap();
    for (i, v) in w.data.iter_mut().enumerate() {
        if i % c_out < 4 {
            *v += 5.0;
        }
    }
    let (pl, _, _) = train::eval_logits(&engine, &perturbed, &test_ds).unwrap();
    assert_eq!(ml.data, pl.data, "masked channels leak on the ref backend");

    // ---- staged graphs reproduce the full eval bit-identically ----
    let server = Server::new(&engine, state.clone()).unwrap();
    let (x, _) = test_ds.batch(&[0]);
    let (pred, stage) = server.infer(&x, 1.01, 1.01).unwrap();
    assert_eq!(stage, 3);
    assert_eq!(pred, logits.argmax_rows()[0], "staged main prediction differs from full eval");
    let (pred1, stage1) = server.infer(&x, 0.0, 0.0).unwrap();
    assert_eq!(stage1, 1);
    assert_eq!(pred1, e1.argmax_rows()[0]);

    // ---- save / load round-trip preserves behaviour exactly ----
    let tmp = std::env::temp_dir().join(format!("coc_ref_it_{}.state", std::process::id()));
    state.save(&tmp).unwrap();
    let loaded = coc::models::ModelState::load(&tmp, arch.clone()).unwrap();
    std::fs::remove_file(&tmp).ok();
    let (ll, _, _) = train::eval_logits(&engine, &loaded, &test_ds).unwrap();
    assert_eq!(ll.data, logits.data);

    // ---- chain stages: P then Q strictly increase BitOpsCR ----
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 16,
        seed: 5,
        verbose: false,
    };
    let m0 = Measurement::take(&engine, &state, &test_ds).unwrap();
    let chain = Chain::new()
        .push(Box::new(stages::Prune { ratio: 0.3, ..Default::default() }))
        .push(Box::new(stages::Quantize { bits_w: 4.0, bits_a: 8.0, ..Default::default() }));
    let reports = chain.run(&mut state, &ctx).unwrap();
    assert_eq!(reports.len(), 2);
    assert!(reports[0].measurement.bitops_cr > m0.bitops_cr);
    assert!(reports[1].measurement.bitops_cr > reports[0].measurement.bitops_cr * 5.0);
    assert_eq!(state.qbits, QBits { weight: 4.0, act: 8.0 });
    assert!(state.keep_fraction() < 0.75);

    let acct = Accountant::new(&state);
    assert!(acct.bitops_cr() > 10.0 && acct.bitops_cr() < 5000.0);
    assert!(acct.storage_cr() > 4.0);

    // ---- early exit stage + serving with real skipping ----
    let chain = Chain::new().push(Box::new(stages::EarlyExit {
        threshold: 0.5,
        ..Default::default()
    }));
    chain.run(&mut state, &ctx).unwrap();
    assert!(state.exits.trained);
    let server = Server::new(&engine, state).unwrap();
    let rep = server.serve_dataset(&test_ds, 32, 0.5, 0.5).unwrap();
    assert_eq!(rep.requests, 32);
    assert!(rep.p_exit1 + rep.p_exit2 <= 1.0 + 1e-9);
    assert!(rep.latency_us.len() == 32);
    assert!(rep.throughput_rps > 0.0);

    // runtime stats accumulated (executions; no transfer bytes — the ref
    // backend has no device boundary to cross).
    let st = engine.stats();
    assert!(st.executions > 100);
    assert!(st.execute_ns > 0);
    assert_eq!(st.bytes_uploaded, 0);
    assert_eq!(st.bytes_downloaded, 0);
}

/// Two identical runs — init, train (plain + KD), eval — must be
/// bit-identical: the determinism contract the plan cache and the CI
/// suites ride on.
#[test]
fn ref_training_is_bit_deterministic() {
    let arch = ref_arch();
    let ds = Dataset::generate(DatasetKind::SynthC10, 64, 9, 0);
    let run = || {
        let engine = Engine::new_ref().unwrap();
        let mut st = train::init_state(&engine, arch.clone(), 9).unwrap();
        let opts = TrainOpts { steps: 10, seed: 9, ..Default::default() };
        let log = train::train(&engine, &mut st, &ds, None, &opts).unwrap();
        let teacher = train::teacher_logits(&engine, &st, &ds).unwrap();
        let kd_opts = TrainOpts { steps: 4, seed: 10, kd_alpha: 0.5, ..Default::default() };
        train::train(&engine, &mut st, &ds, Some(&teacher), &kd_opts).unwrap();
        let (logits, e1, e2) = train::eval_logits(&engine, &st, &ds).unwrap();
        (st.params, st.momenta, log.losses, logits, e1, e2)
    };
    let a = run();
    let b = run();
    assert_eq!(a.0, b.0, "params diverged across identical runs");
    assert_eq!(a.1, b.1, "momenta diverged across identical runs");
    assert_eq!(a.2, b.2, "losses diverged across identical runs");
    assert_eq!(a.3, b.3, "logits diverged across identical runs");
    assert_eq!(a.4, b.4);
    assert_eq!(a.5, b.5);
}

/// A built-in manifest drives the ref backend end to end (the
/// `--backend ref` CLI path with no artifacts directory at all): eval on
/// a ragged dataset, then staged serving agreeing with the full eval.
fn builtin_manifest_serves(arch_name: &str) {
    let m = builtin_ref_manifest();
    assert_eq!(m.num_classes, 20);
    let arch = m.arch(arch_name).unwrap();
    let engine = Engine::new_ref().unwrap();
    let state = train::init_state(&engine, arch.clone(), 3).unwrap();
    assert_eq!(state.params.len(), arch.num_params());

    // Eval on a ragged dataset (eval batch 64, 70 samples).
    let ds = Dataset::generate(DatasetKind::SynthC10, 70, 3, 1);
    let (logits, e1, e2) = train::eval_logits(&engine, &state, &ds).unwrap();
    assert_eq!(logits.shape, vec![70, 20]);
    assert_eq!(e1.shape, vec![70, 20]);
    assert_eq!(e2.shape, vec![70, 20]);

    // Staged serving agrees with the full eval (micro-batched at b8).
    let server = Server::with_batching(&engine, state, 8).unwrap();
    assert_eq!(server.runner().stage_batch(), 8);
    let xs: Vec<_> = (0..6).map(|i| ds.batch(&[i]).0).collect();
    let x_refs: Vec<_> = xs.iter().collect();
    let preds = server.infer_batch(&x_refs, 1.01, 1.01).unwrap();
    for (i, (pred, stage)) in preds.iter().enumerate() {
        assert_eq!(*stage, 3);
        assert_eq!(*pred, logits.argmax_rows()[i], "request {i} diverged from eval");
    }
}

#[test]
fn ref_builtin_manifest_serves_mini_vgg() {
    builtin_manifest_serves("mini_vgg");
}

#[test]
fn ref_builtin_manifest_serves_mini_resnet() {
    builtin_manifest_serves("mini_resnet");
}

#[test]
fn ref_builtin_manifest_serves_mini_mobilenet() {
    builtin_manifest_serves("mini_mobilenet");
}

/// The DAG archs train for real on the ref backend: the loss moves and
/// stays finite through residual / depthwise-tower topologies (the
/// mini_vgg variant of this guarantee lives in `ref_end_to_end`).
#[test]
fn ref_builtin_dag_archs_train() {
    for arch_name in ["mini_resnet", "mini_mobilenet"] {
        let engine = Engine::new_ref().unwrap();
        let arch = common::builtin_arch(arch_name);
        let ds = Dataset::generate(DatasetKind::SynthC10, 64, 7, 0);
        let mut state = train::init_state(&engine, arch, 7).unwrap();
        let opts = TrainOpts { steps: 6, seed: 7, ..Default::default() };
        let log = train::train(&engine, &mut state, &ds, None, &opts).unwrap();
        assert!(
            log.losses.iter().all(|l| l.is_finite()),
            "{arch_name}: non-finite loss {:?}",
            log.losses
        );
        for p in &state.params {
            assert!(p.data.iter().all(|v| v.is_finite()), "{arch_name}: non-finite params");
        }
    }
}

/// Golden determinism digest: a canonical train -> eval flow on the
/// ref backend over every built-in arch (the real-sized mini_vgg chain
/// plus the mini_resnet / mini_mobilenet DAG topologies), hashed to one
/// value per arch.
///
/// Asserts in-process that 1, 2 and 3 kernel threads produce the same
/// bits, and — when `COC_REF_DIGEST_OUT` is set — writes one digest line
/// per arch so CI can diff the file across `COC_REF_THREADS` settings:
/// if threading ever changes a result, the two CI runs disagree and the
/// diff fails.
#[test]
fn ref_golden_digest_is_thread_count_invariant() {
    let mut lines = String::new();
    for arch in common::REF_ARCHS {
        let d1 = common::golden_digest(arch, Some(1));
        for t in [2usize, 3] {
            assert_eq!(
                d1,
                common::golden_digest(arch, Some(t)),
                "{arch}: {t} kernel threads changed the golden digest"
            );
        }
        let denv = common::golden_digest(arch, None);
        assert_eq!(d1, denv, "{arch}: default thread count changed the golden digest");
        lines.push_str(&format!("{arch} {denv:016x}\n"));
    }

    // The observability overhead contract: tracing records timings, never
    // numerics.  The same flow run with tracing enabled (spans recording
    // and exporting a real Chrome trace) must produce bit-identical
    // results.
    let want = common::golden_digest("mini_vgg", Some(2));
    coc::obs::trace::enable();
    let dtraced = common::golden_digest("mini_vgg", Some(2));
    coc::obs::trace::disable();
    let trace_path =
        std::env::temp_dir().join(format!("coc_golden_trace_{}.json", std::process::id()));
    coc::obs::trace::export(&trace_path).unwrap();
    assert_eq!(want, dtraced, "tracing changed the golden digest");
    let text = std::fs::read_to_string(&trace_path).unwrap();
    assert!(text.contains("refback.conv2d"), "trace should contain kernel spans");
    std::fs::remove_file(&trace_path).ok();

    if let Ok(path) = std::env::var("COC_REF_DIGEST_OUT") {
        std::fs::write(&path, &lines).unwrap();
        eprintln!("golden digests -> {path}\n{lines}");
    }
}

/// The SIMD twin of the thread-count digest: the same canonical flows,
/// forced onto every ISA path this host supports, must match the scalar
/// path bit for bit (DESIGN.md §Backends) on every built-in arch.  CI
/// additionally diffs `$COC_REF_DIGEST_OUT` across `COC_REF_SIMD=scalar`
/// and the default run, pinning the equivalence across processes too.
#[test]
fn ref_golden_digest_is_simd_isa_invariant() {
    use coc::runtime::refback::simd;
    for arch in common::REF_ARCHS {
        let want = simd::with_forced(simd::Isa::Scalar, || common::golden_digest(arch, Some(2)));
        for isa in simd::available() {
            let got = simd::with_forced(isa, || common::golden_digest(arch, Some(2)));
            assert_eq!(got, want, "{arch}: isa {} changed the golden digest", isa.name());
        }
    }
}
