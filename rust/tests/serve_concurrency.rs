//! Concurrent-serving integration tests: the multi-worker pool over real
//! PJRT engines (skipped without artifacts, like tests/integration.rs) plus
//! host-only checks of the queue/batcher pipeline under real threads.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use coc::chain::{stages, Chain, StageCtx};
use coc::data::{Dataset, DatasetKind};
use coc::models::{Manifest, ModelState};
use coc::runtime::Engine;
use coc::serve::batcher::BatchPolicy;
use coc::serve::loadgen::{self, LoadMode, LoadOpts};
use coc::serve::queue::Queue;
use coc::serve::worker::{PoolOpts, ServeJob, WorkerPool};
use coc::serve::Server;

mod common;

fn artifacts_ok() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

/// Compile-enforced Send bounds: everything the pool moves across worker
/// threads.  (`Engine` itself is intentionally per-thread — see runtime.)
#[test]
fn serving_types_are_send() {
    fn assert_send<T: Send>() {}
    assert_send::<ModelState>();
    assert_send::<ServeJob>();
    assert_send::<Arc<Queue<ServeJob>>>();
    assert_send::<PoolOpts>();
}

/// Host-only: a 2-producer/2-consumer pipeline through the bounded queue
/// under admission control keeps every accepted item exactly once.
#[test]
fn queue_pipeline_two_workers_host_only() {
    let jobs: Arc<Queue<u64>> = Arc::new(Queue::bounded(32));
    let done: Arc<Queue<u64>> = Arc::new(Queue::unbounded());
    let mut workers = Vec::new();
    for _ in 0..2 {
        let jobs = jobs.clone();
        let done = done.clone();
        workers.push(std::thread::spawn(move || {
            let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
            loop {
                let batch = coc::serve::batcher::drain_batch(&jobs, &policy);
                if batch.is_empty() {
                    break;
                }
                for v in batch {
                    done.push(v).unwrap();
                }
            }
        }));
    }
    let mut accepted = 0u64;
    for i in 0..1000u64 {
        if jobs.push(i).is_ok() {
            accepted += 1;
        }
    }
    jobs.close();
    for w in workers {
        w.join().unwrap();
    }
    done.close();
    let mut seen = Vec::new();
    while let Some(v) = done.pop() {
        seen.push(v);
    }
    assert_eq!(seen.len() as u64, accepted);
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len() as u64, accepted, "duplicated or lost items");
}

/// The headline acceptance test: >= 2 concurrent workers, each with its
/// own PJRT engine, must reproduce the sequential server's per-request
/// results exactly (same predictions, same exit stages) and complete every
/// request.
#[test]
fn two_workers_match_sequential_serving() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();

    let train_ds = Dataset::generate(DatasetKind::SynthC10, 192, 11, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 64, 11, 1);

    let mut state = coc::train::init_state(&engine, arch, 11).unwrap();
    coc::train::train(
        &engine,
        &mut state,
        &train_ds,
        None,
        &coc::train::TrainOpts { steps: 30, ..Default::default() },
    )
    .unwrap();
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 16,
        seed: 11,
        verbose: false,
    };
    Chain::new()
        .push(Box::new(stages::EarlyExit { threshold: 0.6, ..Default::default() }))
        .run(&mut state, &ctx)
        .unwrap();

    let t = 0.6f32;
    // Sequential ground truth, per test index.
    let server = Server::new(&engine, state.clone()).unwrap();
    let mut want = Vec::new();
    for i in 0..test_ds.len() {
        let (x, _) = test_ds.batch(&[i]);
        want.push(server.infer(&x, t, t).unwrap());
    }

    // Pool with 2 workers, micro-batching enabled.
    let mut opts = PoolOpts::new("artifacts", 2, (t, t));
    opts.batch = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let pool = WorkerPool::start(Arc::new(state), opts);
    let up = pool.wait_ready(Duration::from_secs(600)).unwrap();
    assert_eq!(up.ready, 2, "both workers must come up");

    for i in 0..test_ds.len() {
        let (x, _) = test_ds.batch(&[i]);
        pool.submit(ServeJob::new(i as u64, x, Some(test_ds.labels[i]))).unwrap();
    }
    let mut got: Vec<Option<(usize, u8)>> = vec![None; test_ds.len()];
    let mut workers_seen = std::collections::BTreeSet::new();
    for _ in 0..test_ds.len() {
        let o = pool.outcomes().pop().expect("pool dropped a request");
        workers_seen.insert(o.worker);
        got[o.id as usize] = Some((o.pred, o.stage));
    }
    let outcome = pool.shutdown();
    assert!(outcome.errors.is_empty(), "worker errors: {:?}", outcome.errors);
    assert_eq!(outcome.stats.len(), 2);
    let processed: u64 = outcome.stats.iter().map(|w| w.processed).sum();
    assert_eq!(processed, test_ds.len() as u64);

    // Micro-batched stage graphs are row-independent, so per-request
    // results must match the sequential server.  Tolerate <= 2/64 flips
    // from f32 vectorization differences between the batch-1 and batch-8
    // lowerings; aggregate accuracy and exit distribution must agree well
    // within the ±1% serving contract.
    let mut diverged = 0usize;
    for (i, w) in want.iter().enumerate() {
        let g = got[i].expect("request never completed");
        if &g != w {
            eprintln!("request {i}: sequential {w:?} vs pool {g:?}");
            diverged += 1;
        }
    }
    assert!(diverged <= 2, "{diverged}/64 requests diverged under concurrency");
    let acc = |rs: &[(usize, u8)]| {
        rs.iter()
            .zip(&test_ds.labels)
            .filter(|((p, _), &l)| *p == l)
            .count() as f64
            / rs.len() as f64
    };
    let got_flat: Vec<(usize, u8)> = got.iter().map(|o| o.unwrap()).collect();
    assert!((acc(&want) - acc(&got_flat)).abs() <= 0.01 + 1e-9);
    let exit_frac = |rs: &[(usize, u8)], s: u8| {
        rs.iter().filter(|(_, st)| *st == s).count() as f64 / rs.len() as f64
    };
    for s in [1u8, 2, 3] {
        assert!(
            (exit_frac(&want, s) - exit_frac(&got_flat, s)).abs() <= 0.04,
            "exit-{s} distribution shifted under concurrency"
        );
    }
    assert!(!workers_seen.is_empty());
}

/// Closed-loop load generation through the pool reports consistent
/// accounting (completed + lost == accepted; exit fractions in [0,1]).
#[test]
fn loadgen_accounting_consistent() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 128, 13, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 48, 13, 1);
    let mut state = coc::train::init_state(&engine, arch, 13).unwrap();
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 10,
        seed: 13,
        verbose: false,
    };
    Chain::new()
        .push(Box::new(stages::EarlyExit { threshold: 0.7, ..Default::default() }))
        .run(&mut state, &ctx)
        .unwrap();

    let pool = WorkerPool::start(Arc::new(state), PoolOpts::new("artifacts", 2, (0.7, 0.7)));
    pool.wait_ready(Duration::from_secs(600)).unwrap();
    let rep = loadgen::run(
        &pool,
        &test_ds,
        &LoadOpts {
            mode: LoadMode::Closed { concurrency: 6 },
            requests: 96,
            seed: 13,
            ..Default::default()
        },
    )
    .unwrap();
    pool.shutdown();

    assert_eq!(rep.offered, 96);
    assert_eq!(rep.completed + rep.lost, rep.accepted);
    assert_eq!(rep.lost, 0);
    assert_eq!(rep.latency_us.len(), rep.completed);
    assert!(rep.p_exit1 >= 0.0 && rep.p_exit1 <= 1.0);
    assert!(rep.p_exit1 + rep.p_exit2 <= 1.0 + 1e-9);
    assert!(rep.throughput_rps > 0.0);
    assert!(rep.queue.accepted >= 96);
    // JSON report round-trips.
    let j = rep.to_json();
    let parsed = coc::util::json::Json::parse(&j.to_string()).unwrap();
    assert_eq!(parsed.req("completed").unwrap().as_usize(), Some(rep.completed));
}

// ---------------------------------------------------------------------------
// Hermetic reference-backend suite: the worker pool, micro-batcher, and
// load generator over ref engines.  Runs unconditionally (no artifacts).
// ---------------------------------------------------------------------------

use std::collections::BTreeMap;

use coc::models::{ArchManifest, LayerDesc, LayerKind, MaskSlot};
use coc::runtime::BackendChoice;
use coc::serve::loadgen::LoadOpts as RefLoadOpts;
use coc::tensor::Tensor;
use coc::train::TrainOpts;

/// Feed-forward arch with both exit heads and batched stage graphs at
/// batch 4.  `with_b4` controls whether the *full* batch-4 ladder is
/// declared (dropping stage2_b4 exercises the partial-artifact fallback).
fn ref_arch(with_full_b4: bool) -> Arc<ArchManifest> {
    let conv = |name: &str, cin: usize, cout: usize, hout: usize, im: i64, om: i64, seg: &str| {
        LayerDesc {
            name: name.into(),
            kind: LayerKind::Conv,
            k: 3,
            cin,
            cout,
            stride: 1,
            hout,
            wout: hout,
            in_mask: im,
            out_mask: om,
            segment: seg.into(),
            input: String::new(),
            act: true,
        }
    };
    let dense = |name: &str, cin: usize, seg: &str| LayerDesc {
        name: name.into(),
        kind: LayerKind::Dense,
        k: 1,
        cin,
        cout: 10,
        stride: 1,
        hout: 1,
        wout: 1,
        in_mask: -1,
        out_mask: -1,
        segment: seg.into(),
        input: String::new(),
        act: true,
    };
    let layers = vec![
        conv("c1", 3, 8, 8, -1, 0, "seg1"),
        conv("c2", 8, 12, 8, 0, 1, "seg2"),
        dense("fc", 12, "seg3"),
        dense("x1", 8, "exit1"),
        dense("x2", 12, "exit2"),
    ];
    let mut graphs = BTreeMap::new();
    let mut tags = vec![
        "init", "train", "eval", "stage1", "stage2", "stage3", "stage1_b4", "stage3_b4",
    ];
    if with_full_b4 {
        tags.push("stage2_b4");
    }
    for tag in tags {
        graphs.insert(tag.to_string(), format!("ref://stest/{tag}"));
    }
    Arc::new(ArchManifest {
        name: "ref_stest".into(),
        num_classes: 10,
        layers,
        mask_slots: vec![
            MaskSlot { name: "m0".into(), channels: 8 },
            MaskSlot { name: "m1".into(), channels: 12 },
        ],
        param_shapes: vec![
            vec![3, 3, 3, 8],
            vec![8],
            vec![3, 3, 8, 12],
            vec![12],
            vec![12, 10],
            vec![10],
            vec![8, 10],
            vec![10],
            vec![12, 10],
            vec![10],
        ],
        graphs,
        train_batch: 8,
        eval_batch: 16,
        stage_batch: 1,
        stage_batches: vec![1, 4],
        stage_h1_shape: vec![1, 8, 8, 8],
        stage_h2_shape: vec![1, 8, 8, 12],
        joins: Vec::new(),
    })
}

/// A lightly trained fp32 state (fp32 keeps per-row results independent
/// of batch grouping, so pooled and sequential serving match exactly).
fn ref_state(engine: &Engine, arch: Arc<ArchManifest>, ds: &Dataset, seed: u64) -> ModelState {
    let mut state = coc::train::init_state(engine, arch, seed).unwrap();
    coc::train::train(
        engine,
        &mut state,
        ds,
        None,
        &TrainOpts { steps: 6, seed, ..Default::default() },
    )
    .unwrap();
    state.exits.trained = true;
    state.exits.thresholds = Some((0.5, 0.5));
    state
}

/// The headline pool test, hermetic: >= 2 concurrent ref workers must
/// reproduce the sequential server's per-request results **exactly** —
/// the ref backend is deterministic and batch-independent at fp32, so
/// unlike the PJRT variant above no vectorization flips are tolerated.
#[test]
fn ref_two_workers_match_sequential() {
    let arch = ref_arch(true);
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 48, 31, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 40, 31, 1);
    let engine = Engine::new_ref().unwrap();
    let state = ref_state(&engine, arch, &train_ds, 31);

    let t = 0.5f32;
    let server = Server::new(&engine, state.clone()).unwrap();
    let mut want = Vec::new();
    for i in 0..test_ds.len() {
        let (x, _) = test_ds.batch(&[i]);
        want.push(server.infer(&x, t, t).unwrap());
    }

    let mut opts = PoolOpts::new("unused-by-ref-backend", 2, (t, t));
    opts.backend = BackendChoice::Ref;
    opts.batch = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    let pool = WorkerPool::start(Arc::new(state), opts);
    let up = pool.wait_ready(Duration::from_secs(60)).unwrap();
    assert_eq!(up.ready, 2, "both ref workers must come up");

    for i in 0..test_ds.len() {
        let (x, _) = test_ds.batch(&[i]);
        pool.submit(ServeJob::new(i as u64, x, Some(test_ds.labels[i]))).unwrap();
    }
    let mut got: Vec<Option<(usize, u8)>> = vec![None; test_ds.len()];
    for _ in 0..test_ds.len() {
        let o = pool.outcomes().pop().expect("pool dropped a request");
        got[o.id as usize] = Some((o.pred, o.stage));
    }
    let outcome = pool.shutdown();
    assert!(outcome.errors.is_empty(), "worker errors: {:?}", outcome.errors);
    assert_eq!(outcome.stats.len(), 2);
    let processed: u64 = outcome.stats.iter().map(|w| w.processed).sum();
    assert_eq!(processed, test_ds.len() as u64);
    for w in &outcome.stats {
        assert_eq!(w.stage_batch, 4, "batched ref stage graphs must be used");
        assert_eq!(w.bytes_uploaded, 0, "ref backend crosses no host/device boundary");
    }
    for (i, w) in want.iter().enumerate() {
        assert_eq!(
            got[i].expect("request never completed"),
            *w,
            "request {i} diverged under concurrency"
        );
    }
}

/// Property: for any request-group size and thresholds, micro-batched
/// serving equals per-request serving exactly — padding rows are
/// discarded and survivors regrouped correctly at every stage.
#[test]
fn ref_batched_serving_matches_single_requests_prop() {
    let arch = ref_arch(true);
    let ds = Dataset::generate(DatasetKind::SynthC10, 32, 37, 0);
    let engine = Engine::new_ref().unwrap();
    let state = ref_state(&engine, arch, &ds, 37);
    let server = Server::with_batching(&engine, state, 4).unwrap();
    assert_eq!(server.runner().stage_batch(), 4);
    let xs: Vec<Tensor> = (0..ds.len()).map(|i| ds.batch(&[i]).0).collect();

    coc::util::prop::check(
        "micro-batched == sequential serving",
        40,
        |r| (r.below(11), r.below(4), r.below(4)),
        |&(n, t1i, t2i)| {
            let grid = [0.0f32, 0.3, 0.6, 1.01];
            let (t1, t2) = (grid[t1i.min(3)], grid[t2i.min(3)]);
            let group: Vec<&Tensor> = xs.iter().take(n).collect();
            let batched = server.infer_batch(&group, t1, t2).map_err(|e| format!("{e:#}"))?;
            for (i, x) in group.iter().enumerate() {
                let single = server.infer(x, t1, t2).map_err(|e| format!("{e:#}"))?;
                if batched[i] != single {
                    return Err(format!(
                        "request {i}/{n} at ({t1}, {t2}): batched {:?} != single {:?}",
                        batched[i], single
                    ));
                }
            }
            Ok(())
        },
    );
}

/// A partially declared batch ladder (stage2_b4 missing) must fall back
/// to batch-1 serving rather than fail — on the ref backend exactly as on
/// partially regenerated artifacts.
#[test]
fn ref_partial_batch_ladder_falls_back_to_batch1() {
    let arch = ref_arch(false);
    let ds = Dataset::generate(DatasetKind::SynthC10, 16, 41, 0);
    let engine = Engine::new_ref().unwrap();
    let state = ref_state(&engine, arch, &ds, 41);
    let server = Server::with_batching(&engine, state, 4).unwrap();
    assert_eq!(server.runner().stage_batch(), 1, "partial ladder must degrade to batch 1");
    let xs: Vec<Tensor> = (0..6).map(|i| ds.batch(&[i]).0).collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    let batch = server.infer_batch(&refs, 0.5, 0.5).unwrap();
    for (i, x) in xs.iter().enumerate() {
        assert_eq!(batch[i], server.infer(x, 0.5, 0.5).unwrap());
    }
}

/// Same seed ⇒ identical arrival schedule, and on the deterministic ref
/// backend the deterministic half of the closed-loop report (accuracy,
/// exit distribution, completion accounting) is identical across runs;
/// wall-clock percentiles are checked for shape, not value.
#[test]
fn ref_loadgen_same_seed_same_schedule_and_report() {
    let arch = ref_arch(true);
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 48, 43, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 32, 43, 1);
    let engine = Engine::new_ref().unwrap();
    let state = ref_state(&engine, arch, &train_ds, 43);

    let mut opts = PoolOpts::new("unused-by-ref-backend", 2, (0.5, 0.5));
    opts.backend = BackendChoice::Ref;
    let pool = WorkerPool::start(Arc::new(state), opts);
    pool.wait_ready(Duration::from_secs(60)).unwrap();

    let load = RefLoadOpts {
        mode: LoadMode::Closed { concurrency: 6 },
        requests: 64,
        seed: 7,
        ..Default::default()
    };
    let a = loadgen::run(&pool, &test_ds, &load).unwrap();
    let b = loadgen::run(&pool, &test_ds, &load).unwrap();
    pool.shutdown();

    for rep in [&a, &b] {
        assert_eq!(rep.offered, 64);
        assert_eq!(rep.completed + rep.lost, rep.accepted);
        assert_eq!(rep.lost, 0);
        assert!(rep.latency_us.p50() <= rep.latency_us.p95());
        assert!(rep.latency_us.p95() <= rep.latency_us.p99());
    }
    assert_eq!(a.accepted, b.accepted);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.accuracy, b.accuracy, "same seed + deterministic backend => same accuracy");
    assert_eq!(a.p_exit1, b.p_exit1, "exit-1 distribution diverged across same-seed runs");
    assert_eq!(a.p_exit2, b.p_exit2, "exit-2 distribution diverged across same-seed runs");
}

/// The concurrent pool over the full builtin arch matrix: two ref
/// workers reproduce the sequential server's per-request results exactly
/// on mini_vgg, mini_resnet and mini_mobilenet — the DAG stage graphs
/// micro-batch and split across workers like the legacy chain.
#[test]
fn ref_pool_serves_builtin_arch_matrix() {
    for arch_name in common::REF_ARCHS {
        let arch = common::builtin_arch(arch_name);
        let test_ds = Dataset::generate(DatasetKind::SynthC10, 12, 47, 1);
        let engine = Engine::new_ref().unwrap();
        let mut state = coc::train::init_state(&engine, arch, 47).unwrap();
        state.exits.trained = true;
        state.exits.thresholds = Some((0.5, 0.5));

        let t = 0.5f32;
        let server = Server::new(&engine, state.clone()).unwrap();
        let mut want = Vec::new();
        for i in 0..test_ds.len() {
            let (x, _) = test_ds.batch(&[i]);
            want.push(server.infer(&x, t, t).unwrap());
        }

        let mut opts = PoolOpts::new("unused-by-ref-backend", 2, (t, t));
        opts.backend = BackendChoice::Ref;
        opts.batch = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
        let pool = WorkerPool::start(Arc::new(state), opts);
        let up = pool.wait_ready(Duration::from_secs(60)).unwrap();
        assert_eq!(up.ready, 2, "{arch_name}: both ref workers must come up");

        for i in 0..test_ds.len() {
            let (x, _) = test_ds.batch(&[i]);
            pool.submit(ServeJob::new(i as u64, x, Some(test_ds.labels[i]))).unwrap();
        }
        let mut got: Vec<Option<(usize, u8)>> = vec![None; test_ds.len()];
        for _ in 0..test_ds.len() {
            let o = pool.outcomes().pop().expect("pool dropped a request");
            got[o.id as usize] = Some((o.pred, o.stage));
        }
        let outcome = pool.shutdown();
        assert!(outcome.errors.is_empty(), "{arch_name}: worker errors: {:?}", outcome.errors);
        let processed: u64 = outcome.stats.iter().map(|w| w.processed).sum();
        assert_eq!(processed, test_ds.len() as u64);
        for (i, w) in want.iter().enumerate() {
            assert_eq!(
                got[i].expect("request never completed"),
                *w,
                "{arch_name}: request {i} diverged under concurrency"
            );
        }
    }
}
