//! Concurrent-serving integration tests: the multi-worker pool over real
//! PJRT engines (skipped without artifacts, like tests/integration.rs) plus
//! host-only checks of the queue/batcher pipeline under real threads.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use coc::chain::{stages, Chain, StageCtx};
use coc::data::{Dataset, DatasetKind};
use coc::models::{Manifest, ModelState};
use coc::runtime::Engine;
use coc::serve::batcher::BatchPolicy;
use coc::serve::loadgen::{self, LoadMode, LoadOpts};
use coc::serve::queue::Queue;
use coc::serve::worker::{PoolOpts, ServeJob, WorkerPool};
use coc::serve::Server;

fn artifacts_ok() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

/// Compile-enforced Send bounds: everything the pool moves across worker
/// threads.  (`Engine` itself is intentionally per-thread — see runtime.)
#[test]
fn serving_types_are_send() {
    fn assert_send<T: Send>() {}
    assert_send::<ModelState>();
    assert_send::<ServeJob>();
    assert_send::<Arc<Queue<ServeJob>>>();
    assert_send::<PoolOpts>();
}

/// Host-only: a 2-producer/2-consumer pipeline through the bounded queue
/// under admission control keeps every accepted item exactly once.
#[test]
fn queue_pipeline_two_workers_host_only() {
    let jobs: Arc<Queue<u64>> = Arc::new(Queue::bounded(32));
    let done: Arc<Queue<u64>> = Arc::new(Queue::unbounded());
    let mut workers = Vec::new();
    for _ in 0..2 {
        let jobs = jobs.clone();
        let done = done.clone();
        workers.push(std::thread::spawn(move || {
            let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
            loop {
                let batch = coc::serve::batcher::drain_batch(&jobs, &policy);
                if batch.is_empty() {
                    break;
                }
                for v in batch {
                    done.push(v).unwrap();
                }
            }
        }));
    }
    let mut accepted = 0u64;
    for i in 0..1000u64 {
        if jobs.push(i).is_ok() {
            accepted += 1;
        }
    }
    jobs.close();
    for w in workers {
        w.join().unwrap();
    }
    done.close();
    let mut seen = Vec::new();
    while let Some(v) = done.pop() {
        seen.push(v);
    }
    assert_eq!(seen.len() as u64, accepted);
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len() as u64, accepted, "duplicated or lost items");
}

/// The headline acceptance test: >= 2 concurrent workers, each with its
/// own PJRT engine, must reproduce the sequential server's per-request
/// results exactly (same predictions, same exit stages) and complete every
/// request.
#[test]
fn two_workers_match_sequential_serving() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();

    let train_ds = Dataset::generate(DatasetKind::SynthC10, 192, 11, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 64, 11, 1);

    let mut state = coc::train::init_state(&engine, arch, 11).unwrap();
    coc::train::train(
        &engine,
        &mut state,
        &train_ds,
        None,
        &coc::train::TrainOpts { steps: 30, ..Default::default() },
    )
    .unwrap();
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 16,
        seed: 11,
        verbose: false,
    };
    Chain::new()
        .push(Box::new(stages::EarlyExit { threshold: 0.6, ..Default::default() }))
        .run(&mut state, &ctx)
        .unwrap();

    let t = 0.6f32;
    // Sequential ground truth, per test index.
    let server = Server::new(&engine, state.clone()).unwrap();
    let mut want = Vec::new();
    for i in 0..test_ds.len() {
        let (x, _) = test_ds.batch(&[i]);
        want.push(server.infer(&x, t, t).unwrap());
    }

    // Pool with 2 workers, micro-batching enabled.
    let mut opts = PoolOpts::new("artifacts", 2, (t, t));
    opts.batch = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let pool = WorkerPool::start(Arc::new(state), opts);
    let up = pool.wait_ready(Duration::from_secs(600)).unwrap();
    assert_eq!(up, 2, "both workers must come up");

    for i in 0..test_ds.len() {
        let (x, _) = test_ds.batch(&[i]);
        pool.submit(ServeJob::new(i as u64, x, Some(test_ds.labels[i]))).unwrap();
    }
    let mut got: Vec<Option<(usize, u8)>> = vec![None; test_ds.len()];
    let mut workers_seen = std::collections::BTreeSet::new();
    for _ in 0..test_ds.len() {
        let o = pool.outcomes().pop().expect("pool dropped a request");
        workers_seen.insert(o.worker);
        got[o.id as usize] = Some((o.pred, o.stage));
    }
    let outcome = pool.shutdown();
    assert!(outcome.errors.is_empty(), "worker errors: {:?}", outcome.errors);
    assert_eq!(outcome.stats.len(), 2);
    let processed: u64 = outcome.stats.iter().map(|w| w.processed).sum();
    assert_eq!(processed, test_ds.len() as u64);

    // Micro-batched stage graphs are row-independent, so per-request
    // results must match the sequential server.  Tolerate <= 2/64 flips
    // from f32 vectorization differences between the batch-1 and batch-8
    // lowerings; aggregate accuracy and exit distribution must agree well
    // within the ±1% serving contract.
    let mut diverged = 0usize;
    for (i, w) in want.iter().enumerate() {
        let g = got[i].expect("request never completed");
        if &g != w {
            eprintln!("request {i}: sequential {w:?} vs pool {g:?}");
            diverged += 1;
        }
    }
    assert!(diverged <= 2, "{diverged}/64 requests diverged under concurrency");
    let acc = |rs: &[(usize, u8)]| {
        rs.iter()
            .zip(&test_ds.labels)
            .filter(|((p, _), &l)| *p == l)
            .count() as f64
            / rs.len() as f64
    };
    let got_flat: Vec<(usize, u8)> = got.iter().map(|o| o.unwrap()).collect();
    assert!((acc(&want) - acc(&got_flat)).abs() <= 0.01 + 1e-9);
    let exit_frac = |rs: &[(usize, u8)], s: u8| {
        rs.iter().filter(|(_, st)| *st == s).count() as f64 / rs.len() as f64
    };
    for s in [1u8, 2, 3] {
        assert!(
            (exit_frac(&want, s) - exit_frac(&got_flat, s)).abs() <= 0.04,
            "exit-{s} distribution shifted under concurrency"
        );
    }
    assert!(!workers_seen.is_empty());
}

/// Closed-loop load generation through the pool reports consistent
/// accounting (completed + lost == accepted; exit fractions in [0,1]).
#[test]
fn loadgen_accounting_consistent() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 128, 13, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 48, 13, 1);
    let mut state = coc::train::init_state(&engine, arch, 13).unwrap();
    let ctx = StageCtx {
        engine: &engine,
        train: &train_ds,
        test: &test_ds,
        base_steps: 10,
        seed: 13,
        verbose: false,
    };
    Chain::new()
        .push(Box::new(stages::EarlyExit { threshold: 0.7, ..Default::default() }))
        .run(&mut state, &ctx)
        .unwrap();

    let pool = WorkerPool::start(Arc::new(state), PoolOpts::new("artifacts", 2, (0.7, 0.7)));
    pool.wait_ready(Duration::from_secs(600)).unwrap();
    let rep = loadgen::run(
        &pool,
        &test_ds,
        &LoadOpts {
            mode: LoadMode::Closed { concurrency: 6 },
            requests: 96,
            seed: 13,
            ..Default::default()
        },
    )
    .unwrap();
    pool.shutdown();

    assert_eq!(rep.offered, 96);
    assert_eq!(rep.completed + rep.lost, rep.accepted);
    assert_eq!(rep.lost, 0);
    assert_eq!(rep.latency_us.len(), rep.completed);
    assert!(rep.p_exit1 >= 0.0 && rep.p_exit1 <= 1.0);
    assert!(rep.p_exit1 + rep.p_exit2 <= 1.0 + 1e-9);
    assert!(rep.throughput_rps > 0.0);
    assert!(rep.queue.accepted >= 96);
    // JSON report round-trips.
    let j = rep.to_json();
    let parsed = coc::util::json::Json::parse(&j.to_string()).unwrap();
    assert_eq!(parsed.req("completed").unwrap().as_usize(), Some(rep.completed));
}
