//! Device-residency equivalence over the real PJRT runtime: the buffer
//! transport (resident params/momenta, hoisted eval/serve operand
//! prefixes) must be *invisible* — bit-identical states, logits and
//! predictions vs the legacy literal marshalling.  Same graphs, same
//! operand values, different transport.
//!
//! Every test self-skips without `make artifacts` (the plan-cache test
//! pattern), so the suite stays green in artifact-free environments.

use std::path::Path;

use coc::data::{Dataset, DatasetKind};
use coc::models::Manifest;
use coc::runtime::Engine;
use coc::serve::Server;
use coc::train::{self, TrainOpts};

fn artifacts_ok() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

#[test]
fn resident_and_marshalled_training_are_bit_identical() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let ds = Dataset::generate(DatasetKind::SynthC10, 96, 13, 0);
    let opts = TrainOpts { steps: 8, seed: 13, ..Default::default() };

    let base = train::init_state(&engine, arch, 13).unwrap();
    let mut resident = base.clone();
    let log_r = train::train(&engine, &mut resident, &ds, None, &opts).unwrap();
    let mut legacy = base.clone();
    let log_l = train::train_marshalled(&engine, &mut legacy, &ds, None, &opts).unwrap();

    // Exact f32 equality throughout: same graph, same batch schedule,
    // same operand values — the transport must not perturb a single bit.
    assert_eq!(log_r.losses, log_l.losses, "per-step losses diverged");
    assert_eq!(log_r.accs, log_l.accs, "per-step accuracies diverged");
    assert_eq!(resident.params, legacy.params, "trained params diverged");
    assert_eq!(resident.momenta, legacy.momenta, "trained momenta diverged");
}

#[test]
fn resident_and_marshalled_training_match_with_teacher() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // The KD path exercises the per-step teacher-row stream (the third
    // per-step upload next to x and y).
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let ds = Dataset::generate(DatasetKind::SynthC10, 96, 17, 0);

    let mut teacher_model = train::init_state(&engine, arch, 17).unwrap();
    train::train(
        &engine,
        &mut teacher_model,
        &ds,
        None,
        &TrainOpts { steps: 6, seed: 17, ..Default::default() },
    )
    .unwrap();
    let teacher = train::teacher_logits(&engine, &teacher_model, &ds).unwrap();

    let opts = TrainOpts { steps: 6, seed: 18, kd_alpha: 0.5, ..Default::default() };
    let mut resident = teacher_model.clone();
    train::train(&engine, &mut resident, &ds, Some(&teacher), &opts).unwrap();
    let mut legacy = teacher_model.clone();
    train::train_marshalled(&engine, &mut legacy, &ds, Some(&teacher), &opts).unwrap();
    assert_eq!(resident.params, legacy.params);
    assert_eq!(resident.momenta, legacy.momenta);
}

#[test]
fn resident_and_marshalled_eval_are_bit_identical() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    // A ragged size so the padded final batch goes through both paths.
    let eval_batch = arch.eval_batch;
    let ds = Dataset::generate(DatasetKind::SynthC10, eval_batch + eval_batch / 2 + 1, 19, 1);
    let state = train::init_state(&engine, arch, 19).unwrap();

    let (m_r, e1_r, e2_r) = train::eval_logits(&engine, &state, &ds).unwrap();
    let (m_l, e1_l, e2_l) = train::eval_logits_marshalled(&engine, &state, &ds).unwrap();
    assert_eq!(m_r, m_l, "main logits diverged");
    assert_eq!(e1_r, e1_l, "exit1 logits diverged");
    assert_eq!(e2_r, e2_l, "exit2 logits diverged");
}

#[test]
fn ragged_final_batch_padding_is_dropped() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let bs = arch.eval_batch;
    let nc = arch.num_classes;
    // Generators are pure per (kind, seed, index) and sequential, so the
    // ragged dataset is an exact prefix of the batch-aligned one.
    let n = bs + bs / 2 + 3;
    let ds_ragged = Dataset::generate(DatasetKind::SynthC10, n, 21, 1);
    let ds_aligned = Dataset::generate(DatasetKind::SynthC10, 2 * bs, 21, 1);
    let spl = ds_ragged.images.len() / n;
    assert_eq!(
        ds_ragged.images.data[..],
        ds_aligned.images.data[..n * spl],
        "generator prefix property violated — padding comparison would be meaningless"
    );
    assert_eq!(&ds_ragged.labels[..], &ds_aligned.labels[..n]);

    let state = train::init_state(&engine, arch, 21).unwrap();
    let (m_ragged, e1_ragged, _) = train::eval_logits(&engine, &state, &ds_ragged).unwrap();
    let (m_aligned, e1_aligned, _) = train::eval_logits(&engine, &state, &ds_aligned).unwrap();

    // Padded rows (the repeated last index) must be dropped: the ragged
    // eval returns exactly n rows, equal to the aligned eval's first n.
    assert_eq!(m_ragged.shape, vec![n, nc]);
    assert_eq!(m_ragged.data[..], m_aligned.data[..n * nc], "padding leaked into main logits");
    assert_eq!(e1_ragged.data[..], e1_aligned.data[..n * nc], "padding leaked into exit1 logits");

    // And the accuracy over the ragged set matches the unpadded reference
    // computed from the aligned run's first n rows.
    let acc_ragged = train::accuracy_of(&m_ragged, &ds_ragged.labels);
    let first_n = coc::tensor::Tensor::new(vec![n, nc], m_aligned.data[..n * nc].to_vec());
    let acc_ref = train::accuracy_of(&first_n, &ds_aligned.labels[..n]);
    assert_eq!(acc_ragged, acc_ref, "ragged-batch accuracy diverged from unpadded reference");
}

#[test]
fn serve_resident_prefix_matches_literal_transport() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let ds = Dataset::generate(DatasetKind::SynthC10, 24, 23, 1);
    let mut state = train::init_state(&engine, arch, 23).unwrap();
    train::train(
        &engine,
        &mut state,
        &ds,
        None,
        &TrainOpts { steps: 6, seed: 23, ..Default::default() },
    )
    .unwrap();

    // Two runners over the SAME engine and state: one on the resident
    // prefix, one forced onto the literal transport.
    let resident = Server::with_batching(&engine, state.clone(), 8).unwrap();
    let literal = Server::with_batching(&engine, state, 8).unwrap();
    literal.runner().disable_residency();
    assert!(!literal.runner().residency_active());

    let xs: Vec<_> = (0..ds.len()).map(|i| ds.batch(&[i]).0).collect();
    let x_refs: Vec<_> = xs.iter().collect();
    // Thresholds spanning exit-at-1, mixed, and full-path routing.
    for (t1, t2) in [(0.0, 0.0), (0.6, 0.6), (1.01, 1.01)] {
        let a = resident.infer_batch(&x_refs, t1, t2).unwrap();
        let b = literal.infer_batch(&x_refs, t1, t2).unwrap();
        assert_eq!(a, b, "predictions diverged at thresholds ({t1}, {t2})");
        for x in &xs {
            assert_eq!(
                resident.infer(x, t1, t2).unwrap(),
                literal.infer(x, t1, t2).unwrap(),
                "batch-1 prediction diverged at thresholds ({t1}, {t2})"
            );
        }
    }
}
