//! Device-residency equivalence over the real PJRT runtime: the buffer
//! transport (resident params/momenta, hoisted eval/serve operand
//! prefixes) must be *invisible* — bit-identical states, logits and
//! predictions vs the legacy literal marshalling.  Same graphs, same
//! operand values, different transport.
//!
//! Every test self-skips without `make artifacts` (the plan-cache test
//! pattern), so the suite stays green in artifact-free environments.

use std::path::Path;

use coc::data::{Dataset, DatasetKind};
use coc::models::Manifest;
use coc::runtime::Engine;
use coc::serve::Server;
use coc::train::{self, TrainOpts};

mod common;

fn artifacts_ok() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

#[test]
fn resident_and_marshalled_training_are_bit_identical() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let ds = Dataset::generate(DatasetKind::SynthC10, 96, 13, 0);
    let opts = TrainOpts { steps: 8, seed: 13, ..Default::default() };

    let base = train::init_state(&engine, arch, 13).unwrap();
    let mut resident = base.clone();
    let log_r = train::train(&engine, &mut resident, &ds, None, &opts).unwrap();
    let mut legacy = base.clone();
    let log_l = train::train_marshalled(&engine, &mut legacy, &ds, None, &opts).unwrap();

    // Exact f32 equality throughout: same graph, same batch schedule,
    // same operand values — the transport must not perturb a single bit.
    assert_eq!(log_r.losses, log_l.losses, "per-step losses diverged");
    assert_eq!(log_r.accs, log_l.accs, "per-step accuracies diverged");
    assert_eq!(resident.params, legacy.params, "trained params diverged");
    assert_eq!(resident.momenta, legacy.momenta, "trained momenta diverged");
}

#[test]
fn resident_and_marshalled_training_match_with_teacher() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // The KD path exercises the per-step teacher-row stream (the third
    // per-step upload next to x and y).
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let ds = Dataset::generate(DatasetKind::SynthC10, 96, 17, 0);

    let mut teacher_model = train::init_state(&engine, arch, 17).unwrap();
    train::train(
        &engine,
        &mut teacher_model,
        &ds,
        None,
        &TrainOpts { steps: 6, seed: 17, ..Default::default() },
    )
    .unwrap();
    let teacher = train::teacher_logits(&engine, &teacher_model, &ds).unwrap();

    let opts = TrainOpts { steps: 6, seed: 18, kd_alpha: 0.5, ..Default::default() };
    let mut resident = teacher_model.clone();
    train::train(&engine, &mut resident, &ds, Some(&teacher), &opts).unwrap();
    let mut legacy = teacher_model.clone();
    train::train_marshalled(&engine, &mut legacy, &ds, Some(&teacher), &opts).unwrap();
    assert_eq!(resident.params, legacy.params);
    assert_eq!(resident.momenta, legacy.momenta);
}

#[test]
fn resident_and_marshalled_eval_are_bit_identical() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    // A ragged size so the padded final batch goes through both paths.
    let eval_batch = arch.eval_batch;
    let ds = Dataset::generate(DatasetKind::SynthC10, eval_batch + eval_batch / 2 + 1, 19, 1);
    let state = train::init_state(&engine, arch, 19).unwrap();

    let (m_r, e1_r, e2_r) = train::eval_logits(&engine, &state, &ds).unwrap();
    let (m_l, e1_l, e2_l) = train::eval_logits_marshalled(&engine, &state, &ds).unwrap();
    assert_eq!(m_r, m_l, "main logits diverged");
    assert_eq!(e1_r, e1_l, "exit1 logits diverged");
    assert_eq!(e2_r, e2_l, "exit2 logits diverged");
}

#[test]
fn ragged_final_batch_padding_is_dropped() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let bs = arch.eval_batch;
    let nc = arch.num_classes;
    // Generators are pure per (kind, seed, index) and sequential, so the
    // ragged dataset is an exact prefix of the batch-aligned one.
    let n = bs + bs / 2 + 3;
    let ds_ragged = Dataset::generate(DatasetKind::SynthC10, n, 21, 1);
    let ds_aligned = Dataset::generate(DatasetKind::SynthC10, 2 * bs, 21, 1);
    let spl = ds_ragged.images.len() / n;
    assert_eq!(
        ds_ragged.images.data[..],
        ds_aligned.images.data[..n * spl],
        "generator prefix property violated — padding comparison would be meaningless"
    );
    assert_eq!(&ds_ragged.labels[..], &ds_aligned.labels[..n]);

    let state = train::init_state(&engine, arch, 21).unwrap();
    let (m_ragged, e1_ragged, _) = train::eval_logits(&engine, &state, &ds_ragged).unwrap();
    let (m_aligned, e1_aligned, _) = train::eval_logits(&engine, &state, &ds_aligned).unwrap();

    // Padded rows (the repeated last index) must be dropped: the ragged
    // eval returns exactly n rows, equal to the aligned eval's first n.
    assert_eq!(m_ragged.shape, vec![n, nc]);
    assert_eq!(m_ragged.data[..], m_aligned.data[..n * nc], "padding leaked into main logits");
    assert_eq!(e1_ragged.data[..], e1_aligned.data[..n * nc], "padding leaked into exit1 logits");

    // And the accuracy over the ragged set matches the unpadded reference
    // computed from the aligned run's first n rows.
    let acc_ragged = train::accuracy_of(&m_ragged, &ds_ragged.labels);
    let first_n = coc::tensor::Tensor::new(vec![n, nc], m_aligned.data[..n * nc].to_vec());
    let acc_ref = train::accuracy_of(&first_n, &ds_aligned.labels[..n]);
    assert_eq!(acc_ragged, acc_ref, "ragged-batch accuracy diverged from unpadded reference");
}

#[test]
fn serve_resident_prefix_matches_literal_transport() {
    if !artifacts_ok() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let ds = Dataset::generate(DatasetKind::SynthC10, 24, 23, 1);
    let mut state = train::init_state(&engine, arch, 23).unwrap();
    train::train(
        &engine,
        &mut state,
        &ds,
        None,
        &TrainOpts { steps: 6, seed: 23, ..Default::default() },
    )
    .unwrap();

    // Two runners over the SAME engine and state: one on the resident
    // prefix, one forced onto the literal transport.
    let resident = Server::with_batching(&engine, state.clone(), 8).unwrap();
    let literal = Server::with_batching(&engine, state, 8).unwrap();
    literal.runner().disable_residency();
    assert!(!literal.runner().residency_active());

    let xs: Vec<_> = (0..ds.len()).map(|i| ds.batch(&[i]).0).collect();
    let x_refs: Vec<_> = xs.iter().collect();
    // Thresholds spanning exit-at-1, mixed, and full-path routing.
    for (t1, t2) in [(0.0, 0.0), (0.6, 0.6), (1.01, 1.01)] {
        let a = resident.infer_batch(&x_refs, t1, t2).unwrap();
        let b = literal.infer_batch(&x_refs, t1, t2).unwrap();
        assert_eq!(a, b, "predictions diverged at thresholds ({t1}, {t2})");
        for x in &xs {
            assert_eq!(
                resident.infer(x, t1, t2).unwrap(),
                literal.infer(x, t1, t2).unwrap(),
                "batch-1 prediction diverged at thresholds ({t1}, {t2})"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Hermetic reference-backend suite: the ref backend has no device, so the
// resident entry points must degrade to the literal transport and match
// it bit-for-bit.  These run unconditionally (no artifacts, no self-skip).
// ---------------------------------------------------------------------------

use std::collections::BTreeMap;
use std::sync::Arc;

use coc::models::{ArchManifest, LayerDesc, LayerKind, MaskSlot};
use coc::train::TrainOpts as RefTrainOpts;

/// Tiny feed-forward arch for the hermetic transport tests.
fn ref_arch() -> Arc<ArchManifest> {
    let layers = vec![
        LayerDesc {
            name: "c1".into(),
            kind: LayerKind::Conv,
            k: 3,
            cin: 3,
            cout: 8,
            stride: 1,
            hout: 8,
            wout: 8,
            in_mask: -1,
            out_mask: 0,
            segment: "seg1".into(),
            input: String::new(),
            act: true,
        },
        LayerDesc {
            name: "fc".into(),
            kind: LayerKind::Dense,
            k: 1,
            cin: 8,
            cout: 10,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: 0,
            out_mask: -1,
            segment: "seg3".into(),
            input: String::new(),
            act: true,
        },
        LayerDesc {
            name: "x1".into(),
            kind: LayerKind::Dense,
            k: 1,
            cin: 8,
            cout: 10,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: 0,
            out_mask: -1,
            segment: "exit1".into(),
            input: String::new(),
            act: true,
        },
    ];
    let mut graphs = BTreeMap::new();
    for tag in ["init", "train", "eval", "stage1", "stage2", "stage3"] {
        graphs.insert(tag.to_string(), format!("ref://rtest/{tag}"));
    }
    Arc::new(ArchManifest {
        name: "ref_rtest".into(),
        num_classes: 10,
        layers,
        mask_slots: vec![MaskSlot { name: "m0".into(), channels: 8 }],
        param_shapes: vec![
            vec![3, 3, 3, 8],
            vec![8],
            vec![8, 10],
            vec![10],
            vec![8, 10],
            vec![10],
        ],
        graphs,
        train_batch: 8,
        eval_batch: 16,
        stage_batch: 1,
        stage_batches: vec![1],
        stage_h1_shape: vec![1, 8, 8, 8],
        stage_h2_shape: vec![1, 8, 8, 8],
        joins: Vec::new(),
    })
}

#[test]
fn ref_train_entrypoints_bit_identical() {
    // `train` (which attempts the resident transport, sees
    // ResidencyUnsupported, and falls back) must equal a direct
    // `train_marshalled` call exactly.
    let engine = Engine::new_ref().unwrap();
    let arch = ref_arch();
    let ds = Dataset::generate(DatasetKind::SynthC10, 64, 13, 0);
    let opts = RefTrainOpts { steps: 8, seed: 13, ..Default::default() };

    let base = train::init_state(&engine, arch.clone(), 13).unwrap();
    let mut via_fallback = base.clone();
    let log_f = train::train(&engine, &mut via_fallback, &ds, None, &opts).unwrap();
    let mut direct = base.clone();
    let log_d = train::train_marshalled(&engine, &mut direct, &ds, None, &opts).unwrap();

    assert_eq!(log_f.losses, log_d.losses, "per-step losses diverged");
    assert_eq!(log_f.accs, log_d.accs, "per-step accuracies diverged");
    assert_eq!(via_fallback.params, direct.params, "trained params diverged");
    assert_eq!(via_fallback.momenta, direct.momenta, "trained momenta diverged");

    // And the KD path (per-step teacher-row stream).
    let teacher = train::teacher_logits(&engine, &direct, &ds).unwrap();
    let kd = RefTrainOpts { steps: 4, seed: 14, kd_alpha: 0.5, ..Default::default() };
    let mut a = direct.clone();
    train::train(&engine, &mut a, &ds, Some(&teacher), &kd).unwrap();
    let mut b = direct.clone();
    train::train_marshalled(&engine, &mut b, &ds, Some(&teacher), &kd).unwrap();
    assert_eq!(a.params, b.params);
    assert_eq!(a.momenta, b.momenta);
}

#[test]
fn ref_eval_entrypoints_bit_identical() {
    let engine = Engine::new_ref().unwrap();
    let arch = ref_arch();
    // A ragged size so the padded final batch goes through both paths.
    let eval_batch = arch.eval_batch;
    let ds = Dataset::generate(DatasetKind::SynthC10, eval_batch + eval_batch / 2 + 1, 19, 1);
    let state = train::init_state(&engine, arch, 19).unwrap();

    let (m_f, e1_f, e2_f) = train::eval_logits(&engine, &state, &ds).unwrap();
    let (m_d, e1_d, e2_d) = train::eval_logits_marshalled(&engine, &state, &ds).unwrap();
    assert_eq!(m_f, m_d, "main logits diverged");
    assert_eq!(e1_f, e1_d, "exit1 logits diverged");
    assert_eq!(e2_f, e2_d, "exit2 logits diverged");
}

#[test]
fn ref_ragged_final_batch_padding_is_dropped() {
    let engine = Engine::new_ref().unwrap();
    let arch = ref_arch();
    let bs = arch.eval_batch;
    let nc = arch.num_classes;
    // Generators are pure per (kind, seed, index) and sequential, so the
    // ragged dataset is an exact prefix of the batch-aligned one.
    let n = bs + bs / 2 + 3;
    let ds_ragged = Dataset::generate(DatasetKind::SynthC10, n, 21, 1);
    let ds_aligned = Dataset::generate(DatasetKind::SynthC10, 2 * bs, 21, 1);
    let spl = ds_ragged.images.len() / n;
    assert_eq!(
        ds_ragged.images.data[..],
        ds_aligned.images.data[..n * spl],
        "generator prefix property violated — padding comparison would be meaningless"
    );
    assert_eq!(&ds_ragged.labels[..], &ds_aligned.labels[..n]);

    let state = train::init_state(&engine, arch, 21).unwrap();
    let (m_ragged, e1_ragged, _) = train::eval_logits(&engine, &state, &ds_ragged).unwrap();
    let (m_aligned, e1_aligned, _) = train::eval_logits(&engine, &state, &ds_aligned).unwrap();

    assert_eq!(m_ragged.shape, vec![n, nc]);
    assert_eq!(m_ragged.data[..], m_aligned.data[..n * nc], "padding leaked into main logits");
    assert_eq!(e1_ragged.data[..], e1_aligned.data[..n * nc], "padding leaked into exit1 logits");

    let acc_ragged = train::accuracy_of(&m_ragged, &ds_ragged.labels);
    let first_n = coc::tensor::Tensor::new(vec![n, nc], m_aligned.data[..n * nc].to_vec());
    let acc_ref = train::accuracy_of(&first_n, &ds_aligned.labels[..n]);
    assert_eq!(acc_ragged, acc_ref, "ragged-batch accuracy diverged from unpadded reference");
}

#[test]
fn ref_serve_has_no_residency_and_transports_agree() {
    let engine = Engine::new_ref().unwrap();
    let arch = ref_arch();
    let ds = Dataset::generate(DatasetKind::SynthC10, 12, 23, 1);
    let mut state = train::init_state(&engine, arch, 23).unwrap();
    train::train(
        &engine,
        &mut state,
        &ds,
        None,
        &RefTrainOpts { steps: 4, seed: 23, ..Default::default() },
    )
    .unwrap();

    let a = Server::new(&engine, state.clone()).unwrap();
    // The ref backend reports ResidencyUnsupported at upload, so the
    // runner must come up on the literal transport from the start.
    assert!(!a.runner().residency_active(), "ref backend must have no resident prefix");
    let b = Server::new(&engine, state).unwrap();
    b.runner().disable_residency();
    for (t1, t2) in [(0.0f32, 0.0f32), (0.6, 0.6), (1.01, 1.01)] {
        for i in 0..ds.len() {
            let (x, _) = ds.batch(&[i]);
            assert_eq!(
                a.infer(&x, t1, t2).unwrap(),
                b.infer(&x, t1, t2).unwrap(),
                "prediction diverged at thresholds ({t1}, {t2})"
            );
        }
    }
}

/// The transport-equivalence guarantee over the full builtin arch matrix:
/// resident-attempting and marshalled eval entry points agree bit-for-bit
/// on a ragged dataset, and padded rows never leak — including through
/// the mini_resnet / mini_mobilenet DAG topologies.
#[test]
fn ref_eval_transports_agree_on_builtin_archs() {
    for arch_name in common::REF_ARCHS {
        let engine = Engine::new_ref().unwrap();
        let arch = common::builtin_arch(arch_name);
        let nc = arch.num_classes;
        // Ragged: one full eval batch plus a padded remainder.
        let n = arch.eval_batch + arch.eval_batch / 2 + 1;
        let ds = Dataset::generate(DatasetKind::SynthC10, n, 29, 1);
        let state = train::init_state(&engine, arch, 29).unwrap();

        let (m_f, e1_f, e2_f) = train::eval_logits(&engine, &state, &ds).unwrap();
        let (m_d, e1_d, e2_d) = train::eval_logits_marshalled(&engine, &state, &ds).unwrap();
        assert_eq!(m_f, m_d, "{arch_name}: main logits diverged across transports");
        assert_eq!(e1_f, e1_d, "{arch_name}: exit1 logits diverged across transports");
        assert_eq!(e2_f, e2_d, "{arch_name}: exit2 logits diverged across transports");
        assert_eq!(m_f.shape, vec![n, nc], "{arch_name}: padding leaked into the row count");
        assert!(m_f.data.iter().all(|v| v.is_finite()), "{arch_name}: non-finite logits");
    }
}

/// Serving the builtin matrix on the ref backend: no resident prefix ever
/// comes up, and the literal-vs-disabled transports agree per request.
#[test]
fn ref_serve_transports_agree_on_builtin_archs() {
    for arch_name in common::REF_ARCHS {
        let engine = Engine::new_ref().unwrap();
        let arch = common::builtin_arch(arch_name);
        let ds = Dataset::generate(DatasetKind::SynthC10, 6, 31, 1);
        let state = train::init_state(&engine, arch, 31).unwrap();

        let a = Server::new(&engine, state.clone()).unwrap();
        assert!(
            !a.runner().residency_active(),
            "{arch_name}: ref backend must have no resident prefix"
        );
        let b = Server::new(&engine, state).unwrap();
        b.runner().disable_residency();
        for (t1, t2) in [(0.0f32, 0.0f32), (1.01, 1.01)] {
            for i in 0..ds.len() {
                let (x, _) = ds.batch(&[i]);
                assert_eq!(
                    a.infer(&x, t1, t2).unwrap(),
                    b.infer(&x, t1, t2).unwrap(),
                    "{arch_name}: prediction diverged at thresholds ({t1}, {t2})"
                );
            }
        }
    }
}
