//! Hermetic integration tests for compressed execution: the lowered
//! sparse/int8 graphs (`Engine::load_compressed_graph`, serve's
//! `--compressed` path) against the dense reference graphs on the
//! built-in mini_vgg.
//!
//! Parity contract under test (see runtime/refback/compressed.rs):
//! - pruned fp32 leaves execute *bit-identically* to the dense masked
//!   graph, at every thread count and batch decomposition;
//! - int8 leaves track the dense fake-quant output to tolerance and are
//!   exactly deterministic across `--ref-threads`;
//! - a save/load roundtrip of the packed artifact changes nothing.

use std::collections::BTreeMap;
use std::sync::Arc;

use coc::data::{Dataset, DatasetKind};
use coc::models::compressed::CompressedModel;
use coc::models::{
    builtin_ref_manifest, ArchManifest, JoinDesc, LayerDesc, LayerKind, MaskSlot, ModelState,
    QBits,
};
use coc::runtime::Engine;
use coc::serve::StageRunner;
use coc::tensor::Tensor;
use coc::train;

/// Built-in state with every mask slot half-zeroed (a pruned leaf
/// without the training budget) and the given qbits.
fn leaf_state_for(arch_name: &str, seed: u64, qbits: QBits) -> ModelState {
    let engine = Engine::new_ref_with_threads(1).unwrap();
    let arch = builtin_ref_manifest().arch(arch_name).unwrap();
    let mut st = train::init_state(&engine, arch, seed).unwrap();
    for (mi, m) in st.masks.iter_mut().enumerate() {
        for (i, v) in m.data.iter_mut().enumerate() {
            if (i + mi) % 2 == 1 {
                *v = 0.0;
            }
        }
    }
    st.qbits = qbits;
    st
}

fn leaf_state(seed: u64, qbits: QBits) -> ModelState {
    leaf_state_for("mini_vgg", seed, qbits)
}

fn eval_input(st: &ModelState, seed: u64) -> (Dataset, Tensor) {
    let ds = Dataset::generate(DatasetKind::SynthC10, 128, seed, 0);
    let idx: Vec<usize> = (0..st.arch.eval_batch).collect();
    let (x, _) = ds.batch(&idx);
    (ds, x)
}

fn dense_eval(threads: usize, st: &ModelState, x: &Tensor) -> Vec<Tensor> {
    let engine = Engine::new_ref_with_threads(threads).unwrap();
    let exe = engine.load_graph(&st.arch, "eval").unwrap();
    let qbw = Tensor::scalar(st.qbits.weight);
    let qba = Tensor::scalar(st.qbits.act);
    let mut inputs: Vec<&Tensor> = Vec::with_capacity(st.params.len() + st.masks.len() + 3);
    inputs.extend(st.params.iter());
    inputs.extend(st.masks.iter());
    inputs.push(&qbw);
    inputs.push(&qba);
    inputs.push(x);
    exe.run(&inputs).unwrap()
}

fn compressed_eval(threads: usize, cm: &Arc<CompressedModel>, x: &Tensor) -> Vec<Tensor> {
    let engine = Engine::new_ref_with_threads(threads).unwrap();
    engine.load_compressed_graph(cm, "eval").unwrap().run(&[x]).unwrap()
}

#[test]
fn ref_pruned_fp32_compressed_eval_is_bitwise_dense() {
    let st = leaf_state(7, QBits::FP32);
    let (_ds, x) = eval_input(&st, 3);
    let cm = Arc::new(CompressedModel::lower(&st).unwrap());
    assert!(cm.packed_bytes() < CompressedModel::dense_bytes(&st.arch));
    let want = dense_eval(2, &st, &x);
    let got = compressed_eval(2, &cm, &x);
    assert_eq!(want.len(), 3);
    assert_eq!(got.len(), 3);
    for (name, (w, g)) in ["logits", "exit1", "exit2"].iter().zip(want.iter().zip(&got)) {
        assert_eq!(w.shape, g.shape, "{name} shape");
        assert_eq!(w.data, g.data, "{name}: pruned-fp32 compressed eval must be bit-identical");
    }
}

#[test]
fn ref_int8_compressed_eval_tracks_dense_and_is_thread_invariant() {
    let st = leaf_state(11, QBits { weight: 2.0, act: 8.0 });
    let (_ds, x) = eval_input(&st, 5);
    let cm = Arc::new(CompressedModel::lower(&st).unwrap());
    // Every conv/dense layer of mini_vgg qualifies for int8 at {2, 8}.
    assert!(
        cm.layers.iter().any(|l| l.form.tag() == "int8"),
        "expected int8-packed layers, got {:?}",
        cm.layers.iter().map(|l| l.form.tag()).collect::<Vec<_>>()
    );

    let want = compressed_eval(1, &cm, &x);
    for threads in [2usize, 4] {
        let got = compressed_eval(threads, &cm, &x);
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.data, g.data, "int8 eval changed bits at {threads} threads");
        }
    }

    // Tolerance-level agreement with the dense fake-quant graph: the
    // integer path only differs by f32 accumulation rounding (and the
    // act-quant code flips it can induce downstream).  Random logits sit
    // at O(1) relative distance, so 10% cleanly separates broken from ok.
    let dense = dense_eval(2, &st, &x);
    for (name, (w, g)) in ["logits", "exit1", "exit2"].iter().zip(dense.iter().zip(&want)) {
        let scale = w.data.iter().fold(0.0f32, |a, v| a.max(v.abs())).max(1e-6);
        let diff = w
            .data
            .iter()
            .zip(&g.data)
            .fold(0.0f32, |a, (x, y)| a.max((x - y).abs()));
        assert!(
            diff / scale < 0.1,
            "{name}: int8 drifted {diff} (scale {scale}) from the dense fake-quant output"
        );
    }
}

#[test]
fn ref_compressed_stage_composition_matches_eval() {
    let st = leaf_state(13, QBits::FP32);
    let (ds, x) = eval_input(&st, 9);
    let cm = Arc::new(CompressedModel::lower(&st).unwrap());
    let engine = Engine::new_ref_with_threads(2).unwrap();
    let s1 = engine.load_compressed_graph(&cm, "stage1").unwrap();
    let s2 = engine.load_compressed_graph(&cm, "stage2").unwrap();
    let s3 = engine.load_compressed_graph(&cm, "stage3").unwrap();
    let eval = engine.load_compressed_graph(&cm, "eval").unwrap().run(&[&x]).unwrap();
    let nc = st.arch.num_classes;
    for i in 0..3usize {
        let (xi, _) = ds.batch(&[i]);
        let o1 = s1.run(&[&xi]).unwrap();
        assert_eq!(o1.len(), 2, "stage1 returns [e1, h1]");
        let o2 = s2.run(&[&o1[1]]).unwrap();
        assert_eq!(o2.len(), 2, "stage2 returns [e2, h2]");
        let o3 = s3.run(&[&o2[1]]).unwrap();
        assert_eq!(o3.len(), 1, "stage3 returns [logits]");
        // Row i of the batched eval vs the single-row staged pipeline:
        // kernels are batch-decomposition invariant, so bits must match.
        assert_eq!(o3[0].data[..], eval[0].data[i * nc..(i + 1) * nc], "logits row {i}");
        assert_eq!(o1[0].data[..], eval[1].data[i * nc..(i + 1) * nc], "exit1 row {i}");
        assert_eq!(o2[0].data[..], eval[2].data[i * nc..(i + 1) * nc], "exit2 row {i}");
    }
}

#[test]
fn ref_compressed_roundtrip_serves_identically() {
    let st = leaf_state(17, QBits { weight: 2.0, act: 8.0 });
    let (_ds, x) = eval_input(&st, 21);
    let cm = Arc::new(CompressedModel::lower(&st).unwrap());
    let path = std::env::temp_dir().join(format!("coc_cmp_roundtrip_{}.cmp", std::process::id()));
    cm.save(&path).unwrap();
    let back = Arc::new(CompressedModel::load(&path, st.arch.clone()).unwrap());
    std::fs::remove_file(&path).ok();
    let want = compressed_eval(2, &cm, &x);
    let got = compressed_eval(2, &back, &x);
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.data, g.data, "save/load roundtrip changed the packed execution");
    }
}

#[test]
fn ref_serve_runner_compressed_matches_dense_pruned_fp32() {
    let st = Arc::new(leaf_state(19, QBits::FP32));
    let ds = Dataset::generate(DatasetKind::SynthC10, 64, 23, 0);
    let engine = Engine::new_ref_with_threads(2).unwrap();
    // max_batch 8 exercises the batched stage ladder (stage*_b8 graphs)
    // on both runners; 19 requests leave a ragged tail for the batch-1
    // fallback path.
    let dense = StageRunner::new(&engine, st.clone(), 8).unwrap();
    let packed = StageRunner::new_compressed(&engine, st.clone(), 8).unwrap();
    assert!(packed.compressed_model().is_some());
    let xs: Vec<Tensor> = (0..19).map(|i| ds.batch(&[i]).0).collect();
    let refs: Vec<&Tensor> = xs.iter().collect();
    let want = dense.infer_many(&refs, 0.6, 0.6).unwrap();
    let got = packed.infer_many(&refs, 0.6, 0.6).unwrap();
    assert_eq!(want, got, "compressed serving diverged from dense on a pruned fp32 leaf");
}

/// The DAG archs under the compressed umbrella: pruned fp32 lowering of
/// mini_resnet (skip joins over a shared live set) and mini_mobilenet
/// (depthwise towers + unary joins) executes bit-identically to the
/// dense masked graph.  Dead channels contribute exactly +0.0 at every
/// join, so compaction must not move a single bit.
#[test]
fn ref_dag_archs_pruned_fp32_compressed_eval_is_bitwise_dense() {
    for arch_name in ["mini_resnet", "mini_mobilenet"] {
        let st = leaf_state_for(arch_name, 7, QBits::FP32);
        let (_ds, x) = eval_input(&st, 3);
        let cm = Arc::new(CompressedModel::lower(&st).unwrap());
        assert!(
            cm.packed_bytes() < CompressedModel::dense_bytes(&st.arch),
            "{arch_name}: packed form did not shrink"
        );
        let want = dense_eval(2, &st, &x);
        let got = compressed_eval(2, &cm, &x);
        assert_eq!(want.len(), 3);
        for (name, (w, g)) in ["logits", "exit1", "exit2"].iter().zip(want.iter().zip(&got)) {
            assert_eq!(w.shape, g.shape, "{arch_name}: {name} shape");
            assert_eq!(
                w.data, g.data,
                "{arch_name}: {name}: pruned-fp32 compressed eval must be bit-identical"
            );
        }
    }
}

/// int8 lowering of mini_resnet: exactly deterministic across thread
/// counts, and tracking the dense fake-quant graph to tolerance through
/// the skip joins (the integer path only differs by accumulation
/// rounding and the act-quant code flips it induces downstream).
#[test]
fn ref_resnet_int8_compressed_eval_is_thread_invariant() {
    let st = leaf_state_for("mini_resnet", 11, QBits { weight: 2.0, act: 8.0 });
    let (_ds, x) = eval_input(&st, 5);
    let cm = Arc::new(CompressedModel::lower(&st).unwrap());
    assert!(
        cm.layers.iter().any(|l| l.form.tag() == "int8"),
        "expected int8-packed layers on mini_resnet, got {:?}",
        cm.layers.iter().map(|l| l.form.tag()).collect::<Vec<_>>()
    );

    let want = compressed_eval(1, &cm, &x);
    for threads in [2usize, 4] {
        let got = compressed_eval(threads, &cm, &x);
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.data, g.data, "int8 resnet eval changed bits at {threads} threads");
        }
    }

    let dense = dense_eval(2, &st, &x);
    for (name, (w, g)) in ["logits", "exit1", "exit2"].iter().zip(dense.iter().zip(&want)) {
        let scale = w.data.iter().fold(0.0f32, |a, v| a.max(v.abs())).max(1e-6);
        let diff =
            w.data.iter().zip(&g.data).fold(0.0f32, |a, (x, y)| a.max((x - y).abs()));
        assert!(
            diff / scale < 0.1,
            "{name}: int8 resnet drifted {diff} (scale {scale}) from dense fake-quant"
        );
    }
}

/// Negative: a manifest whose projection writes a different mask slot
/// than its skip join must be rejected at `lower` with a diagnostic
/// naming the join — compaction over disagreeing live sets would
/// silently misalign the add.
#[test]
fn lower_rejects_disagreeing_masks_at_skip_join() {
    let conv = |name: &str, k: usize, cin: usize, im: i64, om: i64, input: &str| LayerDesc {
        name: name.into(),
        kind: LayerKind::Conv,
        k,
        cin,
        cout: 8,
        stride: 1,
        hout: 8,
        wout: 8,
        in_mask: im,
        out_mask: om,
        segment: "seg1".into(),
        input: input.into(),
        act: false,
    };
    let mut stem = conv("stem", 3, 3, -1, 0, "@input");
    stem.act = true;
    let layers = vec![
        stem,
        conv("a1", 3, 8, 0, 2, "stem"),
        // Wrong slot: the projection writes m1 while the join owns m2.
        conv("proj", 1, 8, 0, 1, "stem"),
        LayerDesc {
            name: "fc".into(),
            kind: LayerKind::Dense,
            k: 1,
            cin: 8,
            cout: 4,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: 2,
            out_mask: -1,
            segment: "seg3".into(),
            input: "j".into(),
            act: true,
        },
    ];
    let arch = Arc::new(ArchManifest {
        name: "bad_join".into(),
        num_classes: 4,
        layers,
        mask_slots: (0..3)
            .map(|i| MaskSlot { name: format!("m{i}"), channels: 8 })
            .collect(),
        param_shapes: vec![
            vec![3, 3, 3, 8],
            vec![8],
            vec![3, 3, 8, 8],
            vec![8],
            vec![1, 1, 8, 8],
            vec![8],
            vec![8, 4],
            vec![4],
        ],
        graphs: BTreeMap::new(),
        train_batch: 2,
        eval_batch: 2,
        stage_batch: 1,
        stage_batches: vec![1],
        stage_h1_shape: vec![1, 8, 8, 8],
        stage_h2_shape: vec![1, 8, 8, 8],
        joins: vec![JoinDesc {
            name: "j".into(),
            a: "a1".into(),
            b: Some("proj".into()),
            out_mask: 2,
            segment: "seg1".into(),
        }],
    });
    let st = ModelState::init_host(arch, 3);
    let err = CompressedModel::lower(&st).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("disagree at the skip join") && msg.contains("`j`"),
        "diagnostic must name the offending join: {msg}"
    );
}
