//! Chaos soak: the serving pool and the plan executor under injected
//! faults — hermetic (ref backend + engine-free plan runners), driven by
//! the deterministic `coc::faults` layer.
//!
//! What these tests pin down:
//!
//! * **No lost request**: every submitted request reaches exactly one
//!   terminal outcome (done / timeout / failed) under panics, slowness,
//!   and deadlines — the pool never hangs and never double-answers.
//! * **Failure isolation**: a panicking micro-batch fails only its own
//!   requests; the worker respawns a replacement engine and keeps
//!   serving.  A failing plan node is quarantined with its subtree while
//!   sibling branches complete, and the run reports partial results.
//! * **Determinism**: the same workload + fault seed reproduces the
//!   identical fault schedule and identical surviving-request results,
//!   across every builtin architecture.
//!
//! The fault registry is process-global, so every test serializes on one
//! gate and disarms it before returning.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use anyhow::Result;

use coc::chain::plan::{ExecOpts, NodeRunner, PlanKey, Planner};
use coc::chain::{stages, Chain, CompressionStage};
use coc::data::{Dataset, DatasetKind};
use coc::faults;
use coc::metrics::Measurement;
use coc::models::{ArchManifest, LayerDesc, LayerKind, MaskSlot, ModelState};
use coc::runtime::{BackendChoice, Engine};
use coc::serve::batcher::BatchPolicy;
use coc::serve::worker::{OutcomeStatus, PoolOpts, ServeJob, WorkerPool};
use coc::serve::Server;

mod common;

/// Faults are process-global; tests that arm them must not overlap.
fn serial() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("coc_chaos_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

// ---------------------------------------------------------------------------
// Serve-side chaos
// ---------------------------------------------------------------------------

/// One chaos run through a ref-backend pool: fault schedule + per-request
/// terminal outcomes + pool accounting, for cross-run comparison.
struct ChaosRun {
    digest: u64,
    fired: Vec<faults::FireEvent>,
    /// id -> terminal (status, pred, stage); insertion asserts uniqueness.
    outcomes: BTreeMap<u64, (OutcomeStatus, usize, u8)>,
    restarts: u32,
    errors: Vec<String>,
}

/// Single worker + batch-1 so the micro-batch composition (and therefore
/// the per-site evaluation sequence) is identical across same-seed runs.
fn run_pool_chaos(arch_name: &str, spec: &str, seed: u64, requests: usize) -> ChaosRun {
    faults::configure(spec, seed).unwrap();
    let arch = common::builtin_arch(arch_name);
    let ds = Dataset::generate(DatasetKind::SynthC10, requests, 53, 1);
    let engine = Engine::new_ref().unwrap();
    let mut state = coc::train::init_state(&engine, arch, 53).unwrap();
    state.exits.trained = true;
    state.exits.thresholds = Some((0.5, 0.5));

    let mut opts = PoolOpts::new("unused-by-ref-backend", 1, (0.5, 0.5));
    opts.backend = BackendChoice::Ref;
    opts.batch = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    opts.max_restarts = 1000;
    opts.restart_backoff = Duration::from_millis(1);
    let pool = WorkerPool::start(Arc::new(state), opts);
    pool.wait_ready(Duration::from_secs(60)).unwrap();
    for i in 0..requests {
        let (x, _) = ds.batch(&[i]);
        pool.submit(ServeJob::new(i as u64, x, Some(ds.labels[i]))).unwrap();
    }
    let mut outcomes = BTreeMap::new();
    for _ in 0..requests {
        let o = pool.outcomes().pop().expect("pool dropped a request");
        let prev = outcomes.insert(o.id, (o.status, o.pred, o.stage));
        assert!(prev.is_none(), "request {} got two terminal outcomes", o.id);
    }
    let out = pool.shutdown();
    let fired = faults::fired_sorted();
    let digest = faults::schedule_digest();
    faults::clear();
    ChaosRun {
        digest,
        fired,
        outcomes,
        restarts: out.stats.iter().map(|w| w.restarts).sum(),
        errors: out.errors,
    }
}

/// The headline soak: every builtin architecture, panics + slowness
/// injected, two same-seed runs — identical fault schedule, identical
/// terminal outcome for every request, no lost request, no hang.
#[test]
fn ref_chaos_soak_matrix_same_seed_identical() {
    let _g = serial();
    // every=6 over 20 single-request batches -> panics at evals 5, 11, 17
    // (deterministic by construction); slow_batch exercises the
    // hash-scheduled probabilistic path on top.
    let spec = "worker_panic@every=6,slow_batch@p=0.2:arg=2";
    for arch_name in common::REF_ARCHS {
        let a = run_pool_chaos(arch_name, spec, 1234, 20);
        let b = run_pool_chaos(arch_name, spec, 1234, 20);
        assert!(a.errors.is_empty(), "{arch_name}: pool should absorb panics: {:?}", a.errors);
        assert_eq!(a.restarts, 3, "{arch_name}: one respawn per injected panic");
        assert_eq!(a.outcomes.len(), 20, "{arch_name}: every request terminal");

        let panics: Vec<u64> =
            a.fired.iter().filter(|e| e.site == "worker_panic").map(|e| e.index).collect();
        assert_eq!(panics, vec![5, 11, 17], "{arch_name}: panic schedule moved");
        let failed = a.outcomes.values().filter(|(s, _, _)| *s == OutcomeStatus::Failed).count();
        assert_eq!(failed, 3, "{arch_name}: one failed request per panicked batch-of-1");

        assert_eq!(a.fired, b.fired, "{arch_name}: fault schedule diverged across reruns");
        assert_eq!(a.digest, b.digest, "{arch_name}: schedule digest diverged");
        assert_eq!(
            a.outcomes, b.outcomes,
            "{arch_name}: surviving-request results diverged across same-seed reruns"
        );
    }
}

/// Two workers, micro-batches up to 4, two injected panics: each panic
/// fails only its own batch, both workers respawn within budget, and the
/// surviving requests still match the sequential server bit-for-bit.
#[test]
fn ref_panic_storm_isolates_batches_and_respawns() {
    let _g = serial();
    let arch = common::builtin_arch("mini_vgg");
    let ds = Dataset::generate(DatasetKind::SynthC10, 40, 59, 1);
    let engine = Engine::new_ref().unwrap();
    let mut state = coc::train::init_state(&engine, arch, 59).unwrap();
    state.exits.trained = true;
    state.exits.thresholds = Some((0.5, 0.5));
    let server = Server::new(&engine, state.clone()).unwrap();
    let mut want = Vec::new();
    for i in 0..ds.len() {
        let (x, _) = ds.batch(&[i]);
        want.push(server.infer(&x, 0.5, 0.5).unwrap());
    }

    faults::configure("worker_panic@n=2", 77).unwrap();
    let mut opts = PoolOpts::new("unused-by-ref-backend", 2, (0.5, 0.5));
    opts.backend = BackendChoice::Ref;
    opts.batch = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    opts.restart_backoff = Duration::from_millis(1);
    let pool = WorkerPool::start(Arc::new(state), opts);
    assert!(pool.wait_ready(Duration::from_secs(60)).unwrap().all_up());
    for i in 0..ds.len() {
        let (x, _) = ds.batch(&[i]);
        pool.submit(ServeJob::new(i as u64, x, Some(ds.labels[i]))).unwrap();
    }
    let mut got: Vec<Option<(OutcomeStatus, usize, u8)>> = vec![None; ds.len()];
    for _ in 0..ds.len() {
        let o = pool.outcomes().pop().expect("pool dropped a request");
        assert!(got[o.id as usize].is_none(), "duplicate outcome for {}", o.id);
        got[o.id as usize] = Some((o.status, o.pred, o.stage));
    }
    let out = pool.shutdown();
    faults::clear();

    assert!(out.errors.is_empty(), "respawn should absorb both panics: {:?}", out.errors);
    let restarts: u32 = out.stats.iter().map(|w| w.restarts).sum();
    assert_eq!(restarts, 2, "each injected panic costs exactly one respawn");
    let (mut done, mut failed) = (0usize, 0usize);
    for (i, g) in got.iter().enumerate() {
        match g.expect("request never completed") {
            (OutcomeStatus::Done, pred, stage) => {
                done += 1;
                assert_eq!((pred, stage), want[i], "surviving request {i} diverged under chaos");
            }
            (OutcomeStatus::Failed, _, _) => failed += 1,
            (OutcomeStatus::Timeout, _, _) => panic!("no deadline configured"),
        }
    }
    assert!((2..=8).contains(&failed), "two panicked micro-batches of <=4 requests: {failed}");
    assert_eq!(done + failed, ds.len());
}

/// Injected slowness + a tight deadline: expired requests are shed with a
/// terminal `Timeout` at dequeue or mid-ladder — answered, never lost,
/// never executed to completion past their budget.
#[test]
fn ref_deadline_sheds_expired_requests_terminally() {
    let _g = serial();
    faults::configure("slow_batch@p=1.0:arg=50", 0).unwrap();
    let arch = common::builtin_arch("mini_vgg");
    let ds = Dataset::generate(DatasetKind::SynthC10, 6, 61, 1);
    let engine = Engine::new_ref().unwrap();
    let mut state = coc::train::init_state(&engine, arch, 61).unwrap();
    state.exits.trained = true;
    state.exits.thresholds = Some((0.5, 0.5));

    let mut opts = PoolOpts::new("unused-by-ref-backend", 1, (0.5, 0.5));
    opts.backend = BackendChoice::Ref;
    opts.batch = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    opts.deadline = Some(Duration::from_millis(5));
    let pool = WorkerPool::start(Arc::new(state), opts);
    pool.wait_ready(Duration::from_secs(60)).unwrap();
    for i in 0..ds.len() {
        let (x, _) = ds.batch(&[i]);
        pool.submit(ServeJob::new(i as u64, x, Some(ds.labels[i]))).unwrap();
    }
    let mut statuses = Vec::new();
    for _ in 0..ds.len() {
        let o = pool.outcomes().pop().expect("pool dropped a request");
        statuses.push(o.status);
    }
    let out = pool.shutdown();
    faults::clear();

    assert!(out.errors.is_empty(), "slowness is not a crash: {:?}", out.errors);
    assert_eq!(statuses.len(), ds.len());
    // Every batch sleeps 50ms against a 5ms budget: nothing can finish.
    assert!(
        statuses.iter().all(|s| *s == OutcomeStatus::Timeout),
        "expected all-timeout under 50ms slowness vs 5ms deadline: {statuses:?}"
    );
}

// ---------------------------------------------------------------------------
// Plan-side chaos (engine-free runner, same pattern as tests/plan_cache.rs)
// ---------------------------------------------------------------------------

fn toy_arch() -> Arc<ArchManifest> {
    Arc::new(ArchManifest {
        name: "toy".into(),
        num_classes: 4,
        layers: vec![
            LayerDesc {
                name: "c1".into(),
                kind: LayerKind::Conv,
                k: 3,
                cin: 3,
                cout: 8,
                stride: 1,
                hout: 8,
                wout: 8,
                in_mask: -1,
                out_mask: 0,
                segment: "seg1".into(),
                input: String::new(),
                act: true,
            },
            LayerDesc {
                name: "fc".into(),
                kind: LayerKind::Dense,
                k: 1,
                cin: 8,
                cout: 4,
                stride: 1,
                hout: 1,
                wout: 1,
                in_mask: 0,
                out_mask: -1,
                segment: "seg3".into(),
                input: String::new(),
                act: true,
            },
        ],
        mask_slots: vec![MaskSlot { name: "m0".into(), channels: 8 }],
        param_shapes: vec![vec![3, 3, 3, 8], vec![8], vec![8, 4], vec![4]],
        graphs: BTreeMap::new(),
        train_batch: 2,
        eval_batch: 2,
        stage_batch: 1,
        stage_batches: vec![1],
        stage_h1_shape: vec![1, 8, 8, 8],
        stage_h2_shape: vec![1, 8, 8, 8],
        joins: Vec::new(),
    })
}

/// Deterministic pure-function stage application, no engine.
struct HostRunner;

fn fp_hash(s: &str) -> u64 {
    s.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64))
}

impl NodeRunner for HostRunner {
    fn apply(&self, stage: &dyn CompressionStage, state: &mut ModelState) -> Result<()> {
        state.params[0].data[0] += (fp_hash(&stage.fingerprint()) % 97) as f32;
        Ok(())
    }

    fn measure(&self, state: &ModelState) -> Result<Measurement> {
        Ok(Measurement {
            accuracy: state.params[0].data[0] as f64 / 1e3,
            bitops_cr: 1.0,
            storage_cr: 1.0,
            bitops: 0.0,
            storage_bits: 0.0,
            exit_probs: (0.0, 0.0),
        })
    }

    fn extra_measurements(&self, _state: &ModelState) -> Result<Vec<(String, Measurement)>> {
        Ok(Vec::new())
    }
}

/// Two independent root branches: `A` alone, `B`/`C` sharing a prefix —
/// 4 unique nodes, so quarantining one root leaves a whole sibling
/// subtree that must still complete.
fn chaos_plan() -> Planner {
    let mut plan = Planner::new(PlanKey {
        arch: "toy".into(),
        dataset: "c10".into(),
        scale: "chaos".into(),
        base_steps: 4,
        seed: 5,
    });
    let p3 = || Box::new(stages::Prune { ratio: 0.3, ..Default::default() });
    let p6 = || Box::new(stages::Prune { ratio: 0.6, ..Default::default() });
    let q = || Box::new(stages::Quantize { bits_w: 2.0, bits_a: 8.0, ..Default::default() });
    plan.submit(Chain::new().push(p3()), "A", "x");
    plan.submit(Chain::new().push(p6()).push(q()), "B", "x");
    plan.submit(
        Chain::new().push(p6()).push(q()).push(Box::new(stages::Prune {
            ratio: 0.7,
            ..Default::default()
        })),
        "C",
        "x",
    );
    assert_eq!(plan.unique_nodes(), 4);
    plan
}

fn fast_opts(cache: Option<PathBuf>, jobs: usize) -> ExecOpts {
    ExecOpts {
        jobs,
        cache_dir: cache,
        retry_backoff: Duration::from_millis(1),
        ..Default::default()
    }
}

/// A node that fails every attempt is quarantined with its chain while
/// sibling branches complete; the run returns partial results plus a
/// resumable failure report, and a fault-free rerun over the same cache
/// finishes the job.
#[test]
fn ref_plan_quarantines_failing_node_and_resumes() {
    let _g = serial();
    faults::clear();
    let base = ModelState::init_host(toy_arch(), 1);
    let plan = chaos_plan();
    let cache = tmp_dir("quarantine");

    // Fault-free reference for the final equivalence check.
    let want = plan.execute(&base, &HostRunner, &fast_opts(None, 1), || Ok(HostRunner)).unwrap();
    assert_eq!(want.points.len(), 3);

    // node_fail on the first 3 attempts: node A burns 1 try + 2 retries
    // and is quarantined; B and C (executed after) never see a fault.
    faults::configure("node_fail@n=3", 0).unwrap();
    let opts = fast_opts(Some(cache.clone()), 1);
    let partial = plan.execute(&base, &HostRunner, &opts, || Ok(HostRunner)).unwrap();
    let fs = faults::stats();
    faults::clear();

    assert_eq!(partial.failures.len(), 1, "exactly one quarantined node");
    assert_eq!(partial.failures[0].chains, vec!["A".to_string()]);
    assert!(partial.failures[0].error.contains("node_fail"), "{}", partial.failures[0].error);
    assert_eq!(partial.stats.quarantined, 1);
    assert_eq!(partial.stats.skipped, 0, "A is a leaf; nothing below it");
    let labels: Vec<&str> = partial.outcomes.iter().map(|o| o.label.as_str()).collect();
    assert_eq!(labels, ["B", "C"], "sibling branches must complete");
    assert_eq!(partial.points[..], want.points[1..], "partial results are the real results");
    let nf = fs.iter().find(|s| s.site == "node_fail").unwrap();
    assert_eq!((nf.evals, nf.fires), (6, 3), "3 attempts on A + 1 clean attempt each on B/C");

    // Resume over the same cache with faults gone: only A re-executes.
    let resumed = plan.execute(&base, &HostRunner, &opts, || Ok(HostRunner)).unwrap();
    assert!(resumed.failures.is_empty());
    assert_eq!(resumed.stats.cache_hits, 3);
    assert_eq!(resumed.stats.executed, 1);
    assert_eq!(resumed.points, want.points);
    std::fs::remove_dir_all(&cache).ok();
}

/// A single transient failure is absorbed by the bounded-backoff retry:
/// no quarantine, identical results to the fault-free run.
#[test]
fn ref_plan_transient_fault_retries_to_success() {
    let _g = serial();
    faults::clear();
    let base = ModelState::init_host(toy_arch(), 1);
    let plan = chaos_plan();
    let opts = fast_opts(None, 1);
    let want = plan.execute(&base, &HostRunner, &opts, || Ok(HostRunner)).unwrap();

    faults::configure("node_fail@n=1", 0).unwrap();
    let run = plan.execute(&base, &HostRunner, &opts, || Ok(HostRunner)).unwrap();
    let fs = faults::stats();
    faults::clear();

    assert!(run.failures.is_empty(), "one transient failure must be retried away");
    assert_eq!(run.stats.quarantined, 0);
    assert_eq!(run.points, want.points);
    let nf = fs.iter().find(|s| s.site == "node_fail").unwrap();
    assert_eq!((nf.evals, nf.fires), (5, 1), "4 nodes + exactly one retry");
}

/// The cache_corrupt fault flips a published snapshot on disk; the rerun
/// detects it through the state-header checksum, rotates it to
/// `.corrupt`, recomputes the node, and replays the rest.
#[test]
fn ref_cache_corrupt_fault_is_detected_and_rotated() {
    let _g = serial();
    let base = ModelState::init_host(toy_arch(), 1);
    let plan = chaos_plan();
    let cache = tmp_dir("corrupt");
    faults::configure("cache_corrupt@n=1", 0).unwrap();
    let opts = fast_opts(Some(cache.clone()), 1);
    let cold = plan.execute(&base, &HostRunner, &opts, || Ok(HostRunner)).unwrap();
    faults::clear();
    assert!(cold.failures.is_empty(), "on-disk corruption must not touch the in-memory run");
    assert_eq!(cold.stats.executed, 4);

    let resumed = plan.execute(&base, &HostRunner, &opts, || Ok(HostRunner)).unwrap();
    assert!(resumed.failures.is_empty());
    assert_eq!(resumed.stats.cache_hits, 3, "three snapshots replay clean");
    assert_eq!(resumed.stats.executed, 1, "the corrupted node recomputes");
    assert_eq!(resumed.points, cold.points);
    let corrupt_files = std::fs::read_dir(&cache)
        .unwrap()
        .filter(|e| e.as_ref().unwrap().path().extension().is_some_and(|x| x == "corrupt"))
        .count();
    assert_eq!(corrupt_files, 1, "corrupt snapshot rotated aside for forensics");
    std::fs::remove_dir_all(&cache).ok();
}

/// Worst case on the parallel executor: every node fails every attempt.
/// The scheduler must still terminate (no stall, no hang), quarantine
/// both roots, skip their subtrees, and report every chain cut off.
#[test]
fn ref_parallel_executor_survives_total_node_failure() {
    let _g = serial();
    let base = ModelState::init_host(toy_arch(), 1);
    let plan = chaos_plan();
    faults::configure("node_fail", 0).unwrap();
    let opts = ExecOpts {
        jobs: 2,
        retries: 1,
        retry_backoff: Duration::from_millis(1),
        ..Default::default()
    };
    let run = plan.execute(&base, &HostRunner, &opts, || Ok(HostRunner)).unwrap();
    faults::clear();

    assert!(run.outcomes.is_empty());
    assert!(run.points.is_empty());
    assert_eq!(run.stats.quarantined, 2, "both root nodes quarantined");
    assert_eq!(run.stats.skipped, 2, "downstream nodes cut off without execution");
    let mut cut: Vec<String> = run.failures.iter().flat_map(|f| f.chains.clone()).collect();
    cut.sort();
    assert_eq!(cut, ["A", "B", "C"], "every chain is accounted for in the failure report");
}
