//! Plan-executor equivalence tests: cached, uncached, serial, and
//! parallel executions of the same plan must produce **bit-identical**
//! outcomes.
//!
//! The host-runner tests always run: they drive the real trie walk,
//! scheduler, snapshot/replay, and point synthesis through an engine-free
//! `NodeRunner` whose stage semantics are a deterministic function of the
//! stage fingerprint — the same purity contract real stages satisfy.  The
//! final test repeats the guarantee through real stages on the PJRT
//! runtime and self-skips when `make artifacts` has not run.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::Result;

use coc::chain::plan::{EngineRunner, ExecOpts, NodeRunner, PlanKey, Planner};
use coc::chain::{stages, Chain, CompressionStage};
use coc::data::{Dataset, DatasetKind};
use coc::metrics::Measurement;
use coc::models::{
    Accountant, ArchManifest, LayerDesc, LayerKind, MaskSlot, ModelState, QBits,
};
use coc::runtime::Engine;
use coc::train::{self, TrainOpts};

mod common;

// ---------------------------------------------------------------------------
// Engine-free substrate
// ---------------------------------------------------------------------------

fn toy_arch() -> Arc<ArchManifest> {
    Arc::new(ArchManifest {
        name: "toy".into(),
        num_classes: 4,
        layers: vec![
            LayerDesc {
                name: "c1".into(),
                kind: LayerKind::Conv,
                k: 3,
                cin: 3,
                cout: 8,
                stride: 1,
                hout: 8,
                wout: 8,
                in_mask: -1,
                out_mask: 0,
                segment: "seg1".into(),
                input: String::new(),
                act: true,
            },
            LayerDesc {
                name: "fc".into(),
                kind: LayerKind::Dense,
                k: 1,
                cin: 8,
                cout: 4,
                stride: 1,
                hout: 1,
                wout: 1,
                in_mask: 0,
                out_mask: -1,
                segment: "seg3".into(),
                input: String::new(),
                act: true,
            },
        ],
        mask_slots: vec![MaskSlot { name: "m0".into(), channels: 8 }],
        param_shapes: vec![vec![3, 3, 3, 8], vec![8], vec![8, 4], vec![4]],
        graphs: BTreeMap::new(),
        train_batch: 2,
        eval_batch: 2,
        stage_batch: 1,
        stage_batches: vec![1],
        stage_h1_shape: vec![1, 8, 8, 8],
        stage_h2_shape: vec![1, 8, 8, 8],
        joins: Vec::new(),
    })
}

/// Applies stages as a deterministic pure function of the fingerprint —
/// no engine, no training — so the executor machinery is exercised alone.
struct HostRunner;

fn fp_hash(s: &str) -> u64 {
    s.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64))
}

impl NodeRunner for HostRunner {
    fn apply(&self, stage: &dyn CompressionStage, state: &mut ModelState) -> Result<()> {
        let h = fp_hash(&stage.fingerprint());
        state.params[0].data[0] += (h % 97) as f32;
        state.qbits = QBits { weight: ((h % 7) + 1) as f32, act: 8.0 };
        Ok(())
    }

    fn measure(&self, state: &ModelState) -> Result<Measurement> {
        let acct = Accountant::new(state);
        Ok(Measurement {
            accuracy: state.params[0].data[0] as f64 / 1e3,
            bitops_cr: acct.bitops_cr(),
            storage_cr: acct.storage_cr(),
            bitops: acct.expected_bitops(),
            storage_bits: acct.storage_bits(),
            exit_probs: state.exits.exit_probs,
        })
    }

    fn extra_measurements(&self, _state: &ModelState) -> Result<Vec<(String, Measurement)>> {
        Ok(Vec::new())
    }
}

fn key() -> PlanKey {
    PlanKey {
        arch: "toy".into(),
        dataset: "c10".into(),
        scale: "smoke".into(),
        base_steps: 6,
        seed: 3,
    }
}

/// Three overlapping chains: P | P->Q | P->Q->E-ish (all fake), sharing
/// the P prefix and the PQ prefix.
fn overlapping_plan() -> Planner {
    let mut plan = Planner::new(key());
    let p = || Box::new(stages::Prune { ratio: 0.4, ..Default::default() });
    let q = || Box::new(stages::Quantize { bits_w: 2.0, bits_a: 8.0, ..Default::default() });
    plan.submit(Chain::new().push(p()), "P", "rung0");
    plan.submit(Chain::new().push(p()).push(q()), "PQ", "rung0");
    plan.submit(
        Chain::new().push(p()).push(q()).push(Box::new(stages::Prune {
            ratio: 0.7,
            ..Default::default()
        })),
        "PQP",
        "rung0",
    );
    assert_eq!(plan.total_stages(), 6);
    assert_eq!(plan.unique_nodes(), 3, "prefixes must dedupe");
    plan
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("coc_plan_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn exec(
    plan: &Planner,
    base: &ModelState,
    jobs: usize,
    cache_dir: Option<&Path>,
) -> coc::chain::plan::PlanRun {
    let opts = ExecOpts { jobs, cache_dir: cache_dir.map(|p| p.to_path_buf()), ..Default::default() };
    plan.execute(base, &HostRunner, &opts, || Ok(HostRunner)).unwrap()
}

#[test]
fn cached_and_uncached_runs_are_bit_identical() {
    let base = ModelState::init_host(toy_arch(), 3);
    let plan = overlapping_plan();
    let cache = tmp_dir("cache_equiv");

    let fresh = exec(&plan, &base, 1, None);
    assert_eq!(fresh.stats.cache_hits, 0);
    assert_eq!(fresh.stats.executed, 3);
    assert_eq!(fresh.points.len(), 3);

    // Cold cache: executes everything, snapshots every node.
    let cold = exec(&plan, &base, 1, Some(&cache));
    assert_eq!(cold.stats.executed, 3);
    assert_eq!(cold.points, fresh.points, "caching must not change outputs");

    // Warm cache: replays everything; outputs stay bit-identical.
    let warm = exec(&plan, &base, 1, Some(&cache));
    assert_eq!(warm.stats.cache_hits, 3);
    assert_eq!(warm.stats.executed, 0);
    assert_eq!(warm.points, fresh.points);
    for (a, b) in fresh.outcomes.iter().zip(&warm.outcomes) {
        assert_eq!(a.reports, b.reports);
        assert_eq!(a.final_state.params, b.final_state.params);
        assert_eq!(a.final_state.masks, b.final_state.masks);
        assert_eq!(a.final_state.qbits, b.final_state.qbits);
        assert_eq!(a.final_state.history, b.final_state.history);
    }
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn interrupted_cache_resumes_from_deepest_prefix() {
    let base = ModelState::init_host(toy_arch(), 3);
    let plan = overlapping_plan();
    let cache = tmp_dir("cache_resume");

    let full = exec(&plan, &base, 1, Some(&cache));

    // Simulate an interrupted run: drop one node's snapshot pair.  The
    // re-run replays the surviving prefix and re-executes only the rest.
    let mut removed = 0;
    for entry in std::fs::read_dir(&cache).unwrap() {
        let p = entry.unwrap().path();
        let name = p.file_name().unwrap().to_string_lossy().into_owned();
        // The PQP leaf is the only 0.7-ratio node; find it by its state
        // differing from every chain's shared prefix is overkill — just
        // drop one .state file and its sidecar.
        if removed == 0 && name.ends_with(".state") {
            std::fs::remove_file(&p).unwrap();
            std::fs::remove_file(cache.join(name.replace(".state", ".meas.json"))).ok();
            removed = 1;
        }
    }
    assert_eq!(removed, 1);

    let resumed = exec(&plan, &base, 1, Some(&cache));
    assert_eq!(resumed.stats.cache_hits + resumed.stats.executed, 3);
    assert!(resumed.stats.executed >= 1, "the dropped node re-executes");
    assert!(resumed.stats.cache_hits >= 1, "surviving snapshots replay");
    assert_eq!(resumed.points, full.points);
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn parallel_execution_matches_serial() {
    let base = ModelState::init_host(toy_arch(), 3);
    // A wider plan so the pool actually has independent branches.
    let mut plan = Planner::new(key());
    for (i, ratio) in [0.25f32, 0.4, 0.55, 0.7].iter().enumerate() {
        let first = Box::new(stages::Prune { ratio: *ratio, ..Default::default() });
        plan.submit(Chain::new().push(first), &format!("P{i}"), "x");
        let first = Box::new(stages::Prune { ratio: *ratio, ..Default::default() });
        let second = Box::new(stages::Quantize { bits_w: 2.0, bits_a: 8.0, ..Default::default() });
        plan.submit(Chain::new().push(first).push(second), &format!("P{i}Q"), "x");
    }
    assert_eq!(plan.unique_nodes(), 8);

    let serial = exec(&plan, &base, 1, None);
    let parallel = exec(&plan, &base, 3, None);
    assert_eq!(serial.points, parallel.points);

    // And a parallel run over a warm cache replays everything.
    let cache = tmp_dir("cache_par");
    exec(&plan, &base, 3, Some(&cache));
    let warm = exec(&plan, &base, 3, Some(&cache));
    assert_eq!(warm.stats.cache_hits, 8);
    assert_eq!(warm.points, serial.points);
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn corrupt_snapshot_is_rotated_and_recomputed() {
    let base = ModelState::init_host(toy_arch(), 3);
    let plan = overlapping_plan();
    let cache = tmp_dir("cache_corrupt");
    let cold = exec(&plan, &base, 1, Some(&cache));
    assert_eq!(cold.stats.executed, 3);

    // Flip one payload bit of the shared P node's snapshot — valid file
    // length, valid header, silently different weights without the
    // header checksum.
    let id = plan.chain_node_ids(0)[0];
    let sp = cache.join(format!("{id}.state"));
    let mut bytes = std::fs::read(&sp).unwrap();
    let off = bytes.iter().position(|&b| b == b'\n').unwrap() + 1;
    bytes[off] ^= 0xff;
    std::fs::write(&sp, &bytes).unwrap();

    // The corrupt entry is detected, rotated aside to `.corrupt`, and
    // recomputed; the two downstream nodes still replay from cache and
    // every output matches the cold run bit-for-bit.
    let resumed = exec(&plan, &base, 1, Some(&cache));
    assert!(resumed.failures.is_empty());
    assert_eq!(resumed.stats.cache_hits, 2);
    assert_eq!(resumed.stats.executed, 1);
    assert_eq!(resumed.points, cold.points);
    assert!(
        cache.join(format!("{id}.state.corrupt")).exists(),
        "corrupt snapshot rotated aside for forensics"
    );

    // The republished snapshot is a clean hit on the next run.
    let warm = exec(&plan, &base, 1, Some(&cache));
    assert_eq!(warm.stats.cache_hits, 3);
    assert_eq!(warm.points, cold.points);
    std::fs::remove_dir_all(&cache).ok();
}

#[test]
fn stale_tag_is_a_miss_not_a_wrong_answer() {
    let base = ModelState::init_host(toy_arch(), 3);
    let plan = overlapping_plan();
    let cache = tmp_dir("cache_stale");
    let first = exec(&plan, &base, 1, Some(&cache));

    // Corrupt one snapshot by retagging it: the header tag no longer
    // matches the content address, so the loader must refuse it and the
    // executor must recompute (not trust) the node.
    let victim = std::fs::read_dir(&cache)
        .unwrap()
        .filter_map(|e| Some(e.ok()?.path()))
        .find(|p| p.extension().map(|x| x == "state").unwrap_or(false))
        .unwrap();
    let retagged = ModelState::load(&victim, toy_arch()).unwrap();
    retagged.save_tagged(&victim, Some("0000deadbeef")).unwrap();

    let rerun = exec(&plan, &base, 1, Some(&cache));
    assert!(rerun.stats.executed >= 1, "retagged snapshot must not count as a hit");
    assert_eq!(rerun.points, first.points);
    std::fs::remove_dir_all(&cache).ok();
}

// ---------------------------------------------------------------------------
// The same guarantee through real stages + PJRT (self-skips without
// artifacts, like tests/integration.rs).
// ---------------------------------------------------------------------------

#[test]
fn pjrt_cached_equivalence_smoke() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let engine = Engine::new("artifacts").unwrap();
    let manifest = coc::models::Manifest::load("artifacts").unwrap();
    let arch = manifest.arch("mini_vgg").unwrap();
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 128, 9, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 64, 9, 1);
    let mut base = train::init_state(&engine, arch, 9).unwrap();
    train::train(&engine, &mut base, &train_ds, None, &TrainOpts { steps: 12, ..Default::default() })
        .unwrap();

    let build = || {
        let mut plan = Planner::new(PlanKey {
            arch: "mini_vgg".into(),
            dataset: "c10".into(),
            scale: "test".into(),
            base_steps: 6,
            seed: 9,
        });
        let p = || Box::new(stages::Prune { ratio: 0.4, ..Default::default() });
        plan.submit(Chain::new().push(p()), "P", "rung0");
        plan.submit(
            Chain::new().push(p()).push(Box::new(stages::Quantize {
                bits_w: 2.0,
                bits_a: 8.0,
                ..Default::default()
            })),
            "PQ",
            "rung0",
        );
        plan
    };
    let runner = EngineRunner::new(&engine, &train_ds, &test_ds, 6, 9, false);
    // Match instead of `?` so the closure's error type is inferable
    // before it meets `execute`'s generic bound.
    let factory = || match Engine::new("artifacts") {
        Ok(e) => Ok(EngineRunner::new(e, &train_ds, &test_ds, 6, 9, false)),
        Err(e) => Err(e),
    };
    let cache = tmp_dir("cache_pjrt");

    let plan = build();
    assert_eq!(plan.unique_nodes(), 2, "PQ rides on the P node");
    let cold_opts =
        ExecOpts { jobs: 1, cache_dir: Some(cache.clone()), ..Default::default() };
    let cold = plan.execute(&base, &runner, &cold_opts, &factory).unwrap();
    assert_eq!(cold.stats.executed, 2);

    let warm = plan.execute(&base, &runner, &cold_opts, &factory).unwrap();
    assert_eq!(warm.stats.cache_hits, 2);
    assert_eq!(warm.stats.executed, 0);
    // The headline guarantee: replayed measurements are bit-identical to
    // the freshly computed ones, through real training + PJRT eval.
    assert_eq!(cold.points, warm.points);
    std::fs::remove_dir_all(&cache).ok();
}

// ---------------------------------------------------------------------------
// Hermetic reference-backend suite: the same cached/uncached/parallel
// equivalence guarantee through REAL stages (train + eval) on the ref
// backend — runs unconditionally, no artifacts, no self-skip.
// ---------------------------------------------------------------------------

/// Tiny feed-forward arch the ref plan tests train for real.
fn ref_plan_arch() -> Arc<ArchManifest> {
    let layers = vec![
        LayerDesc {
            name: "c1".into(),
            kind: LayerKind::Conv,
            k: 3,
            cin: 3,
            cout: 6,
            stride: 1,
            hout: 8,
            wout: 8,
            in_mask: -1,
            out_mask: 0,
            segment: "seg1".into(),
            input: String::new(),
            act: true,
        },
        LayerDesc {
            name: "fc".into(),
            kind: LayerKind::Dense,
            k: 1,
            cin: 6,
            cout: 10,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: 0,
            out_mask: -1,
            segment: "seg3".into(),
            input: String::new(),
            act: true,
        },
    ];
    let mut graphs = BTreeMap::new();
    for tag in ["init", "train", "eval", "stage1", "stage2", "stage3"] {
        graphs.insert(tag.to_string(), format!("ref://ptest/{tag}"));
    }
    Arc::new(ArchManifest {
        name: "ref_ptest".into(),
        num_classes: 10,
        layers,
        mask_slots: vec![MaskSlot { name: "m0".into(), channels: 6 }],
        param_shapes: vec![vec![3, 3, 3, 6], vec![6], vec![6, 10], vec![10]],
        graphs,
        train_batch: 8,
        eval_batch: 16,
        stage_batch: 1,
        stage_batches: vec![1],
        stage_h1_shape: vec![1, 8, 8, 6],
        stage_h2_shape: vec![1, 8, 8, 6],
        joins: Vec::new(),
    })
}

fn ref_plan_key() -> PlanKey {
    PlanKey {
        arch: "ref_ptest".into(),
        dataset: "c10".into(),
        scale: "test".into(),
        base_steps: 6,
        seed: 9,
    }
}

fn ref_plan() -> Planner {
    let mut plan = Planner::new(ref_plan_key());
    let p = || Box::new(stages::Prune { ratio: 0.4, ..Default::default() });
    plan.submit(Chain::new().push(p()), "P", "rung0");
    plan.submit(
        Chain::new().push(p()).push(Box::new(stages::Quantize {
            bits_w: 2.0,
            bits_a: 8.0,
            ..Default::default()
        })),
        "PQ",
        "rung0",
    );
    plan
}

/// Cold-vs-warm bit-identity through real train/eval on the ref backend,
/// plus the acceptance-criterion determinism pin: two independent cold
/// runs publish byte-identical cache files (states AND measurements).
#[test]
fn ref_cached_equivalence_end_to_end() {
    let engine = Engine::new_ref().unwrap();
    let arch = ref_plan_arch();
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 64, 9, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 32, 9, 1);
    let mut base = train::init_state(&engine, arch, 9).unwrap();
    train::train(
        &engine,
        &mut base,
        &train_ds,
        None,
        &TrainOpts { steps: 8, seed: 9, ..Default::default() },
    )
    .unwrap();

    let runner = EngineRunner::new(&engine, &train_ds, &test_ds, 6, 9, false);
    let factory = || match Engine::new_ref() {
        Ok(e) => Ok(EngineRunner::new(e, &train_ds, &test_ds, 6, 9, false)),
        Err(e) => Err(e),
    };
    let plan = ref_plan();
    assert_eq!(plan.unique_nodes(), 2, "PQ rides on the P node");

    let cache_a = tmp_dir("ref_cold_a");
    let opts_a = ExecOpts { jobs: 1, cache_dir: Some(cache_a.clone()), ..Default::default() };
    let cold = plan.execute(&base, &runner, &opts_a, &factory).unwrap();
    assert_eq!(cold.stats.executed, 2);
    assert_eq!(cold.stats.cache_hits, 0);

    // Warm replay: zero executions, bit-identical points and states.
    let warm = plan.execute(&base, &runner, &opts_a, &factory).unwrap();
    assert_eq!(warm.stats.cache_hits, 2);
    assert_eq!(warm.stats.executed, 0);
    assert_eq!(cold.points, warm.points);
    for (a, b) in cold.outcomes.iter().zip(&warm.outcomes) {
        assert_eq!(a.reports, b.reports);
        assert_eq!(a.final_state.params, b.final_state.params);
        assert_eq!(a.final_state.masks, b.final_state.masks);
        assert_eq!(a.final_state.qbits, b.final_state.qbits);
    }

    // A second cold run into a fresh cache dir must publish byte-identical
    // files: training, eval, snapshot serialization — all deterministic.
    let cache_b = tmp_dir("ref_cold_b");
    let opts_b = ExecOpts { jobs: 1, cache_dir: Some(cache_b.clone()), ..Default::default() };
    let cold2 = plan.execute(&base, &runner, &opts_b, &factory).unwrap();
    assert_eq!(cold2.points, cold.points);
    let mut files_a: Vec<_> = std::fs::read_dir(&cache_a)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    files_a.sort();
    let mut files_b: Vec<_> = std::fs::read_dir(&cache_b)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    files_b.sort();
    assert_eq!(files_a, files_b, "cache file sets differ between cold runs");
    assert!(files_a.iter().any(|f| f.ends_with(".state")));
    assert!(files_a.iter().any(|f| f.ends_with(".meas.json")));
    for f in &files_a {
        let a = std::fs::read(cache_a.join(f)).unwrap();
        let b = std::fs::read(cache_b.join(f)).unwrap();
        assert_eq!(a, b, "cache file `{f}` differs between two cold runs");
    }
    std::fs::remove_dir_all(&cache_a).ok();
    std::fs::remove_dir_all(&cache_b).ok();
}

/// Parallel execution over per-worker ref engines equals the serial run
/// bit-for-bit — real stages, real training, independent branches.
#[test]
fn ref_parallel_plan_matches_serial() {
    let engine = Engine::new_ref().unwrap();
    let arch = ref_plan_arch();
    let train_ds = Dataset::generate(DatasetKind::SynthC10, 64, 11, 0);
    let test_ds = Dataset::generate(DatasetKind::SynthC10, 32, 11, 1);
    let base = train::init_state(&engine, arch, 11).unwrap();

    let mut plan = Planner::new(ref_plan_key());
    for (i, ratio) in [0.3f32, 0.5].iter().enumerate() {
        let first = Box::new(stages::Prune { ratio: *ratio, ..Default::default() });
        plan.submit(Chain::new().push(first), &format!("P{i}"), "x");
        let first = Box::new(stages::Prune { ratio: *ratio, ..Default::default() });
        let second =
            Box::new(stages::Quantize { bits_w: 2.0, bits_a: 8.0, ..Default::default() });
        plan.submit(Chain::new().push(first).push(second), &format!("P{i}Q"), "x");
    }
    assert_eq!(plan.unique_nodes(), 4);

    let runner = EngineRunner::new(&engine, &train_ds, &test_ds, 6, 11, false);
    let factory = || match Engine::new_ref() {
        Ok(e) => Ok(EngineRunner::new(e, &train_ds, &test_ds, 6, 11, false)),
        Err(e) => Err(e),
    };
    let serial_opts = ExecOpts { jobs: 1, ..Default::default() };
    let serial = plan.execute(&base, &runner, &serial_opts, &factory).unwrap();
    let par_opts = ExecOpts { jobs: 2, ..Default::default() };
    let parallel = plan.execute(&base, &runner, &par_opts, &factory).unwrap();
    assert_eq!(serial.points, parallel.points, "parallel ref execution diverged from serial");
    for (a, b) in serial.outcomes.iter().zip(&parallel.outcomes) {
        assert_eq!(a.final_state.params, b.final_state.params);
        assert_eq!(a.final_state.qbits, b.final_state.qbits);
    }
}

/// Snapshot/replay over the full builtin arch matrix: plan-cache
/// serialization round-trips the DAG archs' states (including their
/// join-declaring manifests) bit-identically — warm replays equal the
/// cold run on mini_vgg, mini_resnet and mini_mobilenet alike.
#[test]
fn ref_plan_cache_round_trips_builtin_archs() {
    for arch_name in common::REF_ARCHS {
        let arch = common::builtin_arch(arch_name);
        let base = ModelState::init_host(arch, 5);
        let mut plan = Planner::new(PlanKey {
            arch: arch_name.into(),
            dataset: "c10".into(),
            scale: "smoke".into(),
            base_steps: 6,
            seed: 5,
        });
        let p = || Box::new(stages::Prune { ratio: 0.4, ..Default::default() });
        let q = || Box::new(stages::Quantize { bits_w: 2.0, bits_a: 8.0, ..Default::default() });
        plan.submit(Chain::new().push(p()), "P", "rung0");
        plan.submit(Chain::new().push(p()).push(q()), "PQ", "rung0");
        assert_eq!(plan.unique_nodes(), 2, "{arch_name}: PQ must ride on the P node");

        let cache = tmp_dir(&format!("cache_matrix_{arch_name}"));
        let cold = exec(&plan, &base, 1, Some(&cache));
        assert_eq!(cold.stats.executed, 2);
        let warm = exec(&plan, &base, 1, Some(&cache));
        assert_eq!(warm.stats.cache_hits, 2, "{arch_name}: warm run must replay every node");
        assert_eq!(warm.points, cold.points, "{arch_name}: replayed points diverged");
        for (a, b) in cold.outcomes.iter().zip(&warm.outcomes) {
            assert_eq!(a.final_state.params, b.final_state.params, "{arch_name}: params diverged");
            assert_eq!(a.final_state.masks, b.final_state.masks, "{arch_name}: masks diverged");
            assert_eq!(a.final_state.qbits, b.final_state.qbits, "{arch_name}: qbits diverged");
        }
        std::fs::remove_dir_all(&cache).ok();
    }
}
