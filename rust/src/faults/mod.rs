//! Deterministic fault injection — failure as a first-class, testable input.
//!
//! The determinism contract (PR 5) makes thread count a non-observable; this
//! module does the same for *failure*.  A fault spec names sites and an
//! activation rule per site:
//!
//! ```text
//! COC_FAULTS="worker_panic@p=0.01,cache_corrupt@n=3,slow_batch@p=0.05:arg=20"
//! coc serve-bench --faults "worker_panic@n=2" --fault-seed 7
//! ```
//!
//! Forms: `site@p=F` (fire with probability F per evaluation), `site@n=N`
//! (fire on the first N evaluations), `site@every=K` (fire on every K-th
//! evaluation), bare `site` (fire always).  An optional `:arg=F` rides along
//! as a payload (e.g. slow-batch milliseconds).
//!
//! **Determinism.**  Each evaluation of a site atomically takes the next
//! per-site index; the fire/no-fire decision is a pure hash of
//! `(fault_seed, site, index)` — no shared RNG stream, so the schedule (the
//! set of `(site, index)` pairs that fire) is bit-identical across reruns of
//! the same workload and seed even when sites are evaluated from many
//! threads.  `fired_sorted()` / `schedule_digest()` expose the schedule for
//! the chaos soak to compare across runs.
//!
//! Sites are plain `&str` names with an `area_event` taxonomy (see
//! DESIGN.md): `worker_panic`, `worker_start_fail`, `slow_batch`,
//! `node_fail`, `cache_corrupt`.  Production code asks `faults::fire(SITE)`
//! at the site; when no spec is installed the check is one relaxed atomic
//! load.  Every injected fault emits a `fault.<site>` trace span, a
//! `fault.<site>` counter tick, and a Warn log line through the PR 6
//! observability layer.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::obs;
use crate::obs::Level;
use crate::util::sync::lock;

/// Serve: panic mid-batch inside a worker's inference call.
pub const WORKER_PANIC: &str = "worker_panic";
/// Serve: a worker's engine fails to construct at pool start.
pub const WORKER_START_FAIL: &str = "worker_start_fail";
/// Serve: a batch takes `arg` extra milliseconds (deadline pressure).
pub const SLOW_BATCH: &str = "slow_batch";
/// Plan: a node's apply step returns a (transient) error.
pub const NODE_FAIL: &str = "node_fail";
/// Plan: a just-published cache snapshot is corrupted on disk.
pub const CACHE_CORRUPT: &str = "cache_corrupt";

/// How an active site decides whether evaluation `idx` (0-based) fires.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Mode {
    /// Fire with probability `p` per evaluation (hash-thresholded).
    Prob(f64),
    /// Fire on evaluations 0..n.
    FirstN(u64),
    /// Fire on every k-th evaluation (idx % k == k-1).
    Every(u64),
    /// Fire on every evaluation.
    Always,
}

struct SiteState {
    name: String,
    name_hash: u64,
    mode: Mode,
    arg: Option<f64>,
    evals: AtomicU64,
    fires: AtomicU64,
}

struct Config {
    seed: u64,
    sites: Vec<SiteState>,
}

/// One injected fault: which site fired, at which per-site evaluation index.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct FireEvent {
    pub site: String,
    pub index: u64,
}

/// Per-site counters, for reports and tests.
#[derive(Clone, Debug)]
pub struct SiteStats {
    pub site: String,
    pub evals: u64,
    pub fires: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<Option<Arc<Config>>> {
    static REG: Mutex<Option<Arc<Config>>> = Mutex::new(None);
    &REG
}

fn fired_log() -> &'static Mutex<Vec<FireEvent>> {
    static LOG: Mutex<Vec<FireEvent>> = Mutex::new(Vec::new());
    &LOG
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// splitmix64 finalizer — decision hash for (seed, site, index).
fn mix(seed: u64, site_hash: u64, idx: u64) -> u64 {
    let mut z = seed
        .wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add(site_hash)
        .wrapping_mul(0xbf58476d1ce4e5b9)
        .wrapping_add(idx.wrapping_mul(0x94d049bb133111eb));
    z ^= z >> 30;
    z = z.wrapping_mul(0xbf58476d1ce4e5b9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

fn parse_site(part: &str) -> Result<SiteState> {
    let (name, rules) = match part.split_once('@') {
        Some((n, r)) => (n.trim(), Some(r.trim())),
        None => (part.trim(), None),
    };
    if name.is_empty() {
        bail!("fault spec: empty site name in `{part}`");
    }
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.')
    {
        bail!("fault spec: bad site name `{name}` (want [a-z0-9_.])");
    }
    let mut mode: Option<Mode> = None;
    let mut arg: Option<f64> = None;
    if let Some(rules) = rules {
        for kv in rules.split(':') {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| anyhow!("fault spec: `{kv}` is not key=value (site `{name}`)"))?;
            match k.trim() {
                "p" => {
                    let p: f64 = v.parse().map_err(|_| anyhow!("fault spec: bad p `{v}`"))?;
                    if !(0.0..=1.0).contains(&p) {
                        bail!("fault spec: p={p} out of [0,1] (site `{name}`)");
                    }
                    mode = Some(Mode::Prob(p));
                }
                "n" => {
                    let n: u64 = v.parse().map_err(|_| anyhow!("fault spec: bad n `{v}`"))?;
                    mode = Some(Mode::FirstN(n));
                }
                "every" => {
                    let k: u64 = v
                        .parse()
                        .map_err(|_| anyhow!("fault spec: bad every `{v}`"))?;
                    if k == 0 {
                        bail!("fault spec: every=0 (site `{name}`)");
                    }
                    mode = Some(Mode::Every(k));
                }
                "arg" => {
                    arg = Some(v.parse().map_err(|_| anyhow!("fault spec: bad arg `{v}`"))?);
                }
                other => bail!("fault spec: unknown key `{other}` (site `{name}`)"),
            }
        }
    }
    Ok(SiteState {
        name_hash: fnv1a64(name.as_bytes()),
        name: name.to_string(),
        mode: mode.unwrap_or(Mode::Always),
        arg,
        evals: AtomicU64::new(0),
        fires: AtomicU64::new(0),
    })
}

/// Parse and install a fault spec.  Replaces any previous spec and resets
/// all per-site counters and the fired log.
pub fn configure(spec: &str, seed: u64) -> Result<()> {
    let parsed = (|| -> Result<Vec<SiteState>> {
        let mut sites = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let site = parse_site(part)?;
            if sites.iter().any(|s: &SiteState| s.name == site.name) {
                bail!("fault spec: duplicate site `{}`", site.name);
            }
            sites.push(site);
        }
        Ok(sites)
    })();
    let sites = match parsed {
        Ok(s) => s,
        Err(e) => {
            // A bad spec must leave the layer disarmed, not half-armed.
            clear();
            return Err(e);
        }
    };
    lock(fired_log()).clear();
    let enabled = !sites.is_empty();
    *lock(registry()) = if enabled {
        Some(Arc::new(Config { seed, sites }))
    } else {
        None
    };
    ENABLED.store(enabled, Ordering::Release);
    if enabled {
        obs::log!(Level::Info, "faults: armed `{spec}` (seed {seed})");
    }
    Ok(())
}

/// Install from `COC_FAULTS` / `COC_FAULT_SEED` if set (no-op otherwise).
pub fn configure_from_env() -> Result<()> {
    if let Ok(spec) = std::env::var("COC_FAULTS") {
        let seed = std::env::var("COC_FAULT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        configure(&spec, seed)?;
    }
    Ok(())
}

/// Disarm all fault sites and clear the fired log.
pub fn clear() {
    ENABLED.store(false, Ordering::Release);
    *lock(registry()) = None;
    lock(fired_log()).clear();
}

/// True if any fault site is armed.
pub fn active() -> bool {
    ENABLED.load(Ordering::Acquire)
}

fn config() -> Option<Arc<Config>> {
    if !active() {
        return None;
    }
    lock(registry()).clone()
}

/// Evaluate a fault site: takes the next per-site index and returns whether
/// this evaluation fires.  One atomic load when no faults are armed.
pub fn fire(site: &str) -> bool {
    let Some(cfg) = config() else { return false };
    let Some(s) = cfg.sites.iter().find(|s| s.name == site) else {
        return false;
    };
    let idx = s.evals.fetch_add(1, Ordering::Relaxed);
    let hit = match s.mode {
        Mode::Always => true,
        Mode::FirstN(n) => idx < n,
        Mode::Every(k) => idx % k == k - 1,
        Mode::Prob(p) => {
            // 53 uniform bits -> [0,1); pure in (seed, site, idx).
            let u = (mix(cfg.seed, s.name_hash, idx) >> 11) as f64 / (1u64 << 53) as f64;
            u < p
        }
    };
    if hit {
        s.fires.fetch_add(1, Ordering::Relaxed);
        let _sp = obs::trace::span_with(|| format!("fault.{site}"));
        obs::metrics::counter(&format!("fault.{site}")).incr();
        obs::log!(Level::Warn, "fault injected: {site} (eval #{idx})");
        let mut log = lock(fired_log());
        if log.len() < 65_536 {
            log.push(FireEvent {
                site: site.to_string(),
                index: idx,
            });
        }
    }
    hit
}

/// The payload argument configured for a site (`:arg=F`), if armed.
pub fn arg(site: &str) -> Option<f64> {
    let cfg = config()?;
    cfg.sites.iter().find(|s| s.name == site).and_then(|s| s.arg)
}

/// All injected faults so far, sorted by (site, index) so the schedule
/// compares equal across runs regardless of thread interleaving.
pub fn fired_sorted() -> Vec<FireEvent> {
    let mut v = lock(fired_log()).clone();
    v.sort();
    v
}

/// Order-insensitive digest of the fault schedule (FNV over sorted events).
pub fn schedule_digest() -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for e in fired_sorted() {
        h = fnv1a64(format!("{}#{}|{h:016x}", e.site, e.index).as_bytes());
    }
    h
}

/// Per-site evaluation/fire counters.
pub fn stats() -> Vec<SiteStats> {
    match config() {
        None => Vec::new(),
        Some(cfg) => cfg
            .sites
            .iter()
            .map(|s| SiteStats {
                site: s.name.clone(),
                evals: s.evals.load(Ordering::Relaxed),
                fires: s.fires.load(Ordering::Relaxed),
            })
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; tests that arm it must not run
    // concurrently with each other.  A local mutex serializes them.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        GATE.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn inactive_by_default_and_after_clear() {
        let _g = serial();
        clear();
        assert!(!active());
        assert!(!fire("worker_panic"));
        configure("worker_panic@n=1", 0).unwrap();
        assert!(active());
        clear();
        assert!(!active());
        assert!(!fire("worker_panic"));
    }

    #[test]
    fn first_n_fires_exactly_n() {
        let _g = serial();
        configure("cache_corrupt@n=3", 9).unwrap();
        let hits: Vec<bool> = (0..6).map(|_| fire("cache_corrupt")).collect();
        assert_eq!(hits, vec![true, true, true, false, false, false]);
        let st = &stats()[0];
        assert_eq!((st.evals, st.fires), (6, 3));
        clear();
    }

    #[test]
    fn every_k_fires_periodically() {
        let _g = serial();
        configure("node_fail@every=3", 0).unwrap();
        let hits: Vec<bool> = (0..9).map(|_| fire("node_fail")).collect();
        assert_eq!(
            hits,
            vec![false, false, true, false, false, true, false, false, true]
        );
        clear();
    }

    #[test]
    fn prob_schedule_is_seed_deterministic() {
        let _g = serial();
        configure("worker_panic@p=0.3", 42).unwrap();
        for _ in 0..200 {
            fire("worker_panic");
        }
        let a = fired_sorted();
        let da = schedule_digest();
        assert!(!a.is_empty() && a.len() < 200, "p=0.3 over 200: {}", a.len());

        configure("worker_panic@p=0.3", 42).unwrap();
        for _ in 0..200 {
            fire("worker_panic");
        }
        assert_eq!(a, fired_sorted());
        assert_eq!(da, schedule_digest());

        configure("worker_panic@p=0.3", 43).unwrap();
        for _ in 0..200 {
            fire("worker_panic");
        }
        assert_ne!(a, fired_sorted(), "different seed, same schedule");
        clear();
    }

    #[test]
    fn arg_payload_and_bare_site() {
        let _g = serial();
        configure("slow_batch@p=1.0:arg=25,worker_panic", 0).unwrap();
        assert_eq!(arg("slow_batch"), Some(25.0));
        assert_eq!(arg("worker_panic"), None);
        assert!(fire("worker_panic"), "bare site means always");
        assert!(fire("slow_batch"));
        clear();
    }

    #[test]
    fn unarmed_site_never_fires() {
        let _g = serial();
        configure("worker_panic@n=100", 0).unwrap();
        assert!(!fire("cache_corrupt"));
        clear();
    }

    #[test]
    fn spec_errors_are_rejected() {
        let _g = serial();
        for bad in [
            "worker_panic@p=1.5",
            "x@q=3",
            "x@p",
            "x@every=0",
            "a@n=1,a@n=2",
            "bad name@n=1",
            "@n=1",
        ] {
            assert!(configure(bad, 0).is_err(), "accepted `{bad}`");
        }
        // A failed configure must leave faults disarmed.
        assert!(!active());
        clear();
    }

    #[test]
    fn empty_spec_disarms() {
        let _g = serial();
        configure("worker_panic@n=1", 0).unwrap();
        configure("", 0).unwrap();
        assert!(!active());
        clear();
    }
}
