//! Early-exit policy evaluation and threshold calibration.
//!
//! The exit rule is the classic confidence gate: a sample exits at head k
//! if its max-softmax confidence is >= the head's threshold.  Everything
//! here runs on the *full* eval graph (all heads computed) — perfect for
//! measurement because we see every head's prediction for every sample.
//! The serving loop (`serve`) uses the staged graphs instead to actually
//! skip the computation.

use anyhow::Result;

use crate::data::Dataset;
use crate::models::ModelState;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::train;

/// Outcome of evaluating an exit policy on a dataset.
#[derive(Debug, Clone)]
pub struct ExitEval {
    pub accuracy: f64,
    pub p_exit1: f64,
    pub p_exit2: f64,
    /// Accuracy of each head over the samples that used it.
    pub acc_exit1: f64,
    pub acc_exit2: f64,
    pub acc_main: f64,
}

fn max_conf(row: &[f32]) -> f32 {
    // max softmax == softmax of max logit; compute stably.
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = row.iter().map(|x| (x - m).exp()).sum();
    1.0 / denom
}

/// Evaluate the (t1, t2) confidence-threshold policy.
pub fn evaluate(
    engine: &Engine,
    state: &ModelState,
    ds: &Dataset,
    t1: f32,
    t2: f32,
) -> Result<ExitEval> {
    let (main, e1, e2) = train::eval_logits(engine, state, ds)?;
    Ok(evaluate_from_logits(&main, &e1, &e2, &ds.labels, t1, t2))
}

/// Policy evaluation from precomputed logits (lets sweeps vary thresholds
/// without re-running the network — the paper's "several samples per
/// trained case").
pub fn evaluate_from_logits(
    main: &Tensor,
    e1: &Tensor,
    e2: &Tensor,
    labels: &[usize],
    t1: f32,
    t2: f32,
) -> ExitEval {
    let nc = main.shape[1];
    let n = labels.len();
    let (mut n1, mut n2, mut nm) = (0usize, 0usize, 0usize);
    let (mut c1, mut c2, mut cm) = (0usize, 0usize, 0usize);
    for i in 0..n {
        let r1 = &e1.data[i * nc..(i + 1) * nc];
        let r2 = &e2.data[i * nc..(i + 1) * nc];
        let rm = &main.data[i * nc..(i + 1) * nc];
        let (row, bucket) = if max_conf(r1) >= t1 {
            (r1, 0)
        } else if max_conf(r2) >= t2 {
            (r2, 1)
        } else {
            (rm, 2)
        };
        // The shared tie/NaN-total argmax rule (tensor::argmax_slice):
        // the inline partial_cmp it replaces aborted on a NaN logit.
        let pred = crate::tensor::argmax_slice(row);
        let ok = pred == labels[i];
        match bucket {
            0 => {
                n1 += 1;
                c1 += ok as usize;
            }
            1 => {
                n2 += 1;
                c2 += ok as usize;
            }
            _ => {
                nm += 1;
                cm += ok as usize;
            }
        }
    }
    let frac = |c: usize, n: usize| if n == 0 { 0.0 } else { c as f64 / n as f64 };
    ExitEval {
        accuracy: (c1 + c2 + cm) as f64 / n.max(1) as f64,
        p_exit1: n1 as f64 / n.max(1) as f64,
        p_exit2: n2 as f64 / n.max(1) as f64,
        acc_exit1: frac(c1, n1),
        acc_exit2: frac(c2, n2),
        acc_main: frac(cm, nm),
    }
}

/// Sweep thresholds on fixed logits: the runtime knob of a trained
/// early-exit model.  Returns (t, ExitEval) pairs.
pub fn threshold_sweep(
    main: &Tensor,
    e1: &Tensor,
    e2: &Tensor,
    labels: &[usize],
    thresholds: &[f32],
) -> Vec<(f32, ExitEval)> {
    thresholds
        .iter()
        .map(|&t| (t, evaluate_from_logits(main, e1, e2, labels, t, t)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(rows: Vec<Vec<f32>>) -> Tensor {
        let n = rows.len();
        let c = rows[0].len();
        Tensor::new(vec![n, c], rows.into_iter().flatten().collect())
    }

    #[test]
    fn confident_exit1_takes_all() {
        // exit1 very confident and correct on both samples.
        let e1 = t(vec![vec![10.0, -10.0], vec![-10.0, 10.0]]);
        let e2 = t(vec![vec![0.0, 0.0], vec![0.0, 0.0]]);
        let main = t(vec![vec![0.0, 1.0], vec![1.0, 0.0]]); // wrong!
        let ev = evaluate_from_logits(&main, &e1, &e2, &[0, 1], 0.9, 0.9);
        assert_eq!(ev.p_exit1, 1.0);
        assert_eq!(ev.accuracy, 1.0);
    }

    #[test]
    fn threshold_one_routes_to_main() {
        let e1 = t(vec![vec![10.0, -10.0]]);
        let e2 = t(vec![vec![10.0, -10.0]]);
        let main = t(vec![vec![-5.0, 5.0]]);
        // thresholds above max confidence 1.0 are unreachable.
        let ev = evaluate_from_logits(&main, &e1, &e2, &[1], 1.01, 1.01);
        assert_eq!(ev.p_exit1 + ev.p_exit2, 0.0);
        assert_eq!(ev.accuracy, 1.0);
    }

    #[test]
    fn lower_threshold_exits_more(){
        let mk = |conf: f32| {
            // logit gap controls confidence
            t(vec![vec![conf, 0.0]; 8])
        };
        let e1 = mk(1.0);
        let e2 = mk(3.0);
        let main = mk(9.0);
        let labels = [0usize; 8];
        let lo = evaluate_from_logits(&main, &e1, &e2, &labels, 0.55, 0.55);
        let hi = evaluate_from_logits(&main, &e1, &e2, &labels, 0.99, 0.99);
        assert!(lo.p_exit1 > hi.p_exit1);
    }

    #[test]
    fn max_conf_is_softmax_max() {
        let c = max_conf(&[2.0, 0.0, 0.0]);
        let want = (2.0f32).exp() / ((2.0f32).exp() + 2.0);
        assert!((c - want).abs() < 1e-6);
    }
}
