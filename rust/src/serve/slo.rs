//! Service-level-objective accounting: latency targets, attainment, and
//! goodput (the throughput that *counts* — requests completed within SLO).
//!
//! End-to-end serving cost for an early-exit model only materializes under
//! a realistic request stream; the SLO view is how the serve bench turns a
//! latency distribution into the single number capacity planning uses.

use crate::util::stats::Summary;

/// A per-request latency objective.
#[derive(Debug, Clone, Copy)]
pub struct Slo {
    pub latency_ms: f64,
}

impl Slo {
    pub fn latency_us(&self) -> f64 {
        self.latency_ms * 1e3
    }
}

impl Default for Slo {
    fn default() -> Self {
        Slo { latency_ms: 50.0 }
    }
}

/// SLO outcome over one load run.
#[derive(Debug, Clone, Default)]
pub struct SloReport {
    pub slo_ms: f64,
    /// Requests completed within the SLO.
    pub attained: usize,
    /// All completed requests.
    pub completed: usize,
    /// attained / (completed + shed-or-lost): a request that was rejected
    /// at admission or lost to a dead worker violates the SLO by
    /// definition — hiding either would overstate attainment.
    pub attainment: f64,
    /// Attained requests per wall-clock second.
    pub goodput_rps: f64,
}

/// Compute the SLO report from completed-request latencies (µs), the
/// number of requests that never completed (shed at admission or lost to
/// a dead worker — both violate the SLO), and the run wall time.
pub fn report(latency_us: &Summary, shed_or_lost: usize, wall_secs: f64, slo: Slo) -> SloReport {
    let target = slo.latency_us();
    // count_le works for both exact and bounded (fixed-memory) summaries;
    // the open-loop load generator records into the bounded form.
    let attained = latency_us.count_le(target);
    let offered = latency_us.len() + shed_or_lost;
    SloReport {
        slo_ms: slo.latency_ms,
        attained,
        completed: latency_us.len(),
        attainment: if offered == 0 { 0.0 } else { attained as f64 / offered as f64 },
        goodput_rps: attained as f64 / wall_secs.max(1e-9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary_of(xs: &[f64]) -> Summary {
        let mut s = Summary::default();
        for &x in xs {
            s.push(x);
        }
        s
    }

    #[test]
    fn all_within_slo() {
        let lat = summary_of(&[1000.0, 2000.0, 3000.0]); // µs
        let r = report(&lat, 0, 1.0, Slo { latency_ms: 50.0 });
        assert_eq!(r.attained, 3);
        assert_eq!(r.attainment, 1.0);
        assert!((r.goodput_rps - 3.0).abs() < 1e-9);
    }

    #[test]
    fn slow_and_shed_requests_violate() {
        // 2 fast, 1 slow, 1 rejected: attainment = 2/4.
        let lat = summary_of(&[1000.0, 2000.0, 80_000.0]);
        let r = report(&lat, 1, 2.0, Slo { latency_ms: 50.0 });
        assert_eq!(r.attained, 2);
        assert_eq!(r.completed, 3);
        assert!((r.attainment - 0.5).abs() < 1e-9);
        assert!((r.goodput_rps - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_run_is_zero_not_nan() {
        let r = report(&Summary::default(), 0, 1.0, Slo::default());
        assert_eq!(r.attained, 0);
        assert_eq!(r.attainment, 0.0);
        assert_eq!(r.goodput_rps, 0.0);
    }
}
