//! Early-exit serving loop: the *dynamic* half of the chain, running on
//! the staged AOT graphs so an exiting request genuinely skips the rest of
//! the network (batch-1 stage graphs; see aot.py).
//!
//! This is the runtime component the paper's early-exit technique implies:
//! compression decisions happen per-request at inference time, in the
//! coordinator, with the confidence thresholds as the knob.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::data::Dataset;
use crate::models::ModelState;
use crate::runtime::Engine;
use crate::tensor::Tensor;
use crate::util::stats::Summary;

#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub accuracy: f64,
    pub p_exit1: f64,
    pub p_exit2: f64,
    /// Per-request wall latency (µs).
    pub latency_us: Summary,
    pub throughput_rps: f64,
}

fn max_conf(row: &[f32]) -> f32 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = row.iter().map(|x| (x - m).exp()).sum();
    1.0 / denom
}

pub struct Server<'e> {
    engine: &'e Engine,
    state: ModelState,
    stage1: std::rc::Rc<crate::runtime::Executable>,
    stage2: std::rc::Rc<crate::runtime::Executable>,
    stage3: std::rc::Rc<crate::runtime::Executable>,
    qbw: Tensor,
    qba: Tensor,
}

impl<'e> Server<'e> {
    pub fn new(engine: &'e Engine, state: ModelState) -> Result<Server<'e>> {
        let arch = state.arch.clone();
        Ok(Server {
            stage1: engine.load(arch.graph("stage1")?)?,
            stage2: engine.load(arch.graph("stage2")?)?,
            stage3: engine.load(arch.graph("stage3")?)?,
            qbw: Tensor::scalar(state.qbits.weight),
            qba: Tensor::scalar(state.qbits.act),
            engine,
            state,
        })
    }

    fn stage_inputs<'a>(&'a self, x: &'a Tensor) -> Vec<&'a Tensor> {
        let mut v: Vec<&Tensor> = Vec::with_capacity(self.state.params.len() + 8);
        v.extend(self.state.params.iter());
        v.extend(self.state.masks.iter());
        v.push(&self.qbw);
        v.push(&self.qba);
        v.push(x);
        v
    }

    /// Serve one request; returns (prediction, exit_stage 1|2|3).
    pub fn infer(&self, x: &Tensor, t1: f32, t2: f32) -> Result<(usize, u8)> {
        let outs = self.stage1.run(&self.stage_inputs(x))?;
        ensure!(outs.len() == 2, "stage1 returned {} outputs", outs.len());
        let (e1, h1) = (&outs[0], &outs[1]);
        if max_conf(&e1.data) >= t1 {
            return Ok((e1.argmax(), 1));
        }
        let outs = self.stage2.run(&self.stage_inputs(h1))?;
        ensure!(outs.len() == 2, "stage2 returned {} outputs", outs.len());
        let (e2, h2) = (&outs[0], &outs[1]);
        if max_conf(&e2.data) >= t2 {
            return Ok((e2.argmax(), 2));
        }
        let outs = self.stage3.run(&self.stage_inputs(h2))?;
        Ok((outs[0].argmax(), 3))
    }

    /// Run a synchronous request stream drawn from `ds`.
    pub fn serve_dataset(&self, ds: &Dataset, n_requests: usize, t1: f32, t2: f32) -> Result<ServeReport> {
        let _ = self.engine; // engine lifetime anchors executables
        let mut lat = Summary::default();
        let (mut c, mut n1, mut n2) = (0usize, 0usize, 0usize);
        let start = Instant::now();
        for r in 0..n_requests {
            let i = r % ds.len();
            let (x, _) = ds.batch(&[i]);
            let t = Instant::now();
            let (pred, stage) = self.infer(&x, t1, t2)?;
            lat.push(t.elapsed().as_micros() as f64);
            c += (pred == ds.labels[i]) as usize;
            match stage {
                1 => n1 += 1,
                2 => n2 += 1,
                _ => {}
            }
        }
        let wall = start.elapsed().as_secs_f64();
        Ok(ServeReport {
            requests: n_requests,
            accuracy: c as f64 / n_requests.max(1) as f64,
            p_exit1: n1 as f64 / n_requests.max(1) as f64,
            p_exit2: n2 as f64 / n_requests.max(1) as f64,
            latency_us: lat,
            throughput_rps: n_requests as f64 / wall.max(1e-9),
        })
    }
}
