//! Serving subsystem: the *dynamic* half of the chain, running on the
//! staged AOT graphs so an exiting request genuinely skips the rest of the
//! network.
//!
//! The paper's early-exit technique is a serving-time compression — the
//! per-request exit decision is the one knob applied at inference — so
//! this is a full third pillar next to `chain` and `exp`:
//!
//! * [`queue`]   — bounded MPMC request queue with admission control,
//! * [`batcher`] — dynamic micro-batching (pad to the lowered stage batch,
//!   batch-1 fallback when batched artifacts are absent),
//! * [`worker`]  — a pool of N threads, each owning its own PJRT engine
//!   (see `runtime` for why engines are per-thread),
//! * [`loadgen`] — open-/closed-loop load generation with p50/p95/p99
//!   latency, exit-distribution, goodput-under-SLO and queue-depth stats,
//! * [`slo`]     — the latency-objective accounting.
//!
//! [`StageRunner`] is the shared execution core: it owns the staged
//! executables plus the *invariant* operand prefix (params ++ masks ++
//! qbits — only `x` changes per request).  The prefix is **device
//! resident**: uploaded once at runner construction, so the per-request
//! host->device traffic is just the input rows (`x`, then the surviving
//! `h1`/`h2` features).  When buffer execution is unavailable the runner
//! degrades permanently to the legacy literal transport (same graphs,
//! same operand values, identical predictions).  [`Server`] keeps the
//! simple synchronous single-stream API on top of it.

pub mod batcher;
pub mod loadgen;
pub mod queue;
pub mod slo;
pub mod worker;

use std::cell::Cell;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::data::Dataset;
use crate::models::compressed::CompressedModel;
use crate::models::{ArchManifest, ModelState};
use crate::runtime::{self, DeviceBuffer, Engine, Executable};
use crate::tensor::{argmax_slice, Tensor};
use crate::util::stats::Summary;

/// Per-row terminal result of a deadline-aware batch: the row either
/// completed the exit ladder, or its deadline passed at a stage boundary
/// and it was shed instead of executed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RowOutcome {
    /// (prediction, exit stage 1|2|3).
    Done(usize, u8),
    /// Deadline expired before completion; no prediction was computed.
    Expired,
}

#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub accuracy: f64,
    pub p_exit1: f64,
    pub p_exit2: f64,
    /// Per-request wall latency (µs).
    pub latency_us: Summary,
    pub throughput_rps: f64,
}

/// Max-softmax confidence of one logits row (softmax of the max logit,
/// computed stably).
pub(crate) fn max_conf(row: &[f32]) -> f32 {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = row.iter().map(|x| (x - m).exp()).sum();
    1.0 / denom
}

// ----- row plumbing for padded micro-batches --------------------------------

/// Stack `n` single-sample tensors `[1, rest..]` into `[n, rest..]`.
fn concat_rows(xs: &[&Tensor]) -> Tensor {
    let first = xs[0];
    debug_assert_eq!(first.shape.first(), Some(&1));
    let mut shape = first.shape.clone();
    shape[0] = xs.len();
    let mut data = Vec::with_capacity(first.len() * xs.len());
    for x in xs {
        debug_assert_eq!(x.shape, first.shape);
        data.extend_from_slice(&x.data);
    }
    Tensor::new(shape, data)
}

/// Gather `rows` of `t` (`[b, rest..]`) into `[rows.len(), rest..]`.
fn gather_rows(t: &Tensor, rows: &[usize]) -> Tensor {
    let b = t.shape[0];
    let row = t.len() / b;
    let mut shape = t.shape.clone();
    shape[0] = rows.len();
    let mut data = Vec::with_capacity(row * rows.len());
    for &r in rows {
        data.extend_from_slice(&t.data[r * row..(r + 1) * row]);
    }
    Tensor::new(shape, data)
}

/// Pad `[m, rest..]` to `[b, rest..]` by repeating the last row (padding
/// rows are computed by the graph and discarded).
fn pad_rows(t: &Tensor, b: usize) -> Tensor {
    let m = t.shape[0];
    debug_assert!(m >= 1 && m <= b);
    let row = t.len() / m;
    let mut shape = t.shape.clone();
    shape[0] = b;
    let mut data = Vec::with_capacity(row * b);
    data.extend_from_slice(&t.data);
    for _ in m..b {
        data.extend_from_slice(&t.data[(m - 1) * row..m * row]);
    }
    Tensor::new(shape, data)
}

/// First `m` rows of `[b, rest..]`.
fn take_rows(t: &Tensor, m: usize) -> Tensor {
    let b = t.shape[0];
    debug_assert!(m <= b);
    let row = t.len() / b;
    let mut shape = t.shape.clone();
    shape[0] = m;
    Tensor::new(shape, t.data[..m * row].to_vec())
}

// ----- stage executables ----------------------------------------------------

struct BatchedStages {
    batch: usize,
    exes: [Arc<Executable>; 3],
}

struct StageSet {
    /// Batch-1 graphs: always present (the seed contract).
    b1: [Arc<Executable>; 3],
    /// Micro-batched graphs, when the manifest declares them AND the
    /// artifacts compile; absent -> batch-1 fallback.
    batched: Option<BatchedStages>,
}

/// The serving execution core: staged executables + the shared model
/// state.  One `StageRunner` per thread (its executables and resident
/// buffers belong to that thread's engine, which the runner now borrows —
/// the "engine outlives the runner" rule is compile-enforced); the model
/// state is shared via `Arc`, so an N-worker pool holds ONE copy of the
/// weights, not N.
pub struct StageRunner<'e> {
    engine: &'e Engine,
    stages: StageSet,
    /// Shared source of the invariant operands (params ++ masks); these
    /// host-side copies also back the literal-transport fallback.
    state: Arc<ModelState>,
    qbw: Tensor,
    qba: Tensor,
    /// Device-resident invariant prefix (params ++ masks ++ qbw ++ qba),
    /// uploaded once at construction; `None` when buffer upload is
    /// unavailable.  Buffers belong to the engine that built this runner,
    /// which the runner's owner keeps alive (same rule as executables).
    resident: Option<Vec<DeviceBuffer>>,
    /// Sticky transport switch: flips to `false` on the first buffer-mode
    /// execution failure so a broken transport costs one failed attempt,
    /// not one per request.  `Cell` because a `StageRunner` is a
    /// per-thread object (its executables already pin it to one engine).
    resident_ok: Cell<bool>,
    /// Lowered packed form when the runner executes compressed graphs.
    /// Compressed graphs bake params/masks/qbits, take `x` as their only
    /// operand, and never use the resident-prefix transport.
    compressed: Option<Arc<CompressedModel>>,
}

impl<'e> StageRunner<'e> {
    /// Load the staged graphs for `state` on `engine`.  `max_batch` caps
    /// which lowered stage batch is used (1 disables micro-batching).
    pub fn new(
        engine: &'e Engine,
        state: Arc<ModelState>,
        max_batch: usize,
    ) -> Result<StageRunner<'e>> {
        Self::build(engine, state, max_batch, None)
    }

    /// Lower `state` to its packed compressed form and load the staged
    /// graphs over it.  Same exit semantics and batch ladder as the dense
    /// runner; only the per-stage kernels differ.
    pub fn new_compressed(
        engine: &'e Engine,
        state: Arc<ModelState>,
        max_batch: usize,
    ) -> Result<StageRunner<'e>> {
        let cm = Arc::new(
            CompressedModel::lower(&state).context("lowering model for compressed serving")?,
        );
        Self::build(engine, state, max_batch, Some(cm))
    }

    fn build(
        engine: &'e Engine,
        state: Arc<ModelState>,
        max_batch: usize,
        cm: Option<Arc<CompressedModel>>,
    ) -> Result<StageRunner<'e>> {
        let arch = &state.arch;
        let load = |tag: &str| -> Result<Arc<Executable>> {
            match &cm {
                Some(cm) => engine.load_compressed_graph(cm, tag),
                None => engine.load_graph(arch, tag),
            }
        };
        let b1 = [load("stage1")?, load("stage2")?, load("stage3")?];
        // Walk the declared batch ladder downward: a half-lowered batch
        // (e.g. stage1_b8 present but stage2_b8 missing from partially
        // regenerated artifacts) must fall back to the next smaller fully
        // lowered batch, not all the way to batch-1.
        let mut batched = None;
        let mut cap = max_batch.max(1);
        loop {
            let best = arch.best_stage_batch(cap);
            if best <= 1 {
                break;
            }
            let loaded = (|| -> Result<[Arc<Executable>; 3]> {
                let mut exes = Vec::with_capacity(3);
                for s in 1..=3u8 {
                    let tag = ArchManifest::stage_graph_tag(s, best);
                    exes.push(
                        load(&tag)
                            .with_context(|| format!("loading batched stage graph `{tag}`"))?,
                    );
                }
                Ok([exes[0].clone(), exes[1].clone(), exes[2].clone()])
            })();
            match loaded {
                Ok(exes) => {
                    batched = Some(BatchedStages { batch: best, exes });
                    break;
                }
                Err(e) => {
                    crate::obs::log!(
                        crate::obs::Level::Warn,
                        "[serve] batched stage graphs (b{best}) unavailable: {e:#}"
                    );
                    cap = best - 1;
                }
            }
        }
        let qbw = Tensor::scalar(state.qbits.weight);
        let qba = Tensor::scalar(state.qbits.act);
        // Hoist the invariant prefix onto the device once; per request only
        // the input rows are uploaded.  Unavailable -> literal fallback.
        // Compressed graphs have no prefix at all: everything invariant is
        // baked into the packed layers.
        let resident = if cm.is_some() {
            None
        } else {
            match runtime::upload_eval_prefix(engine, &state) {
                Ok(prefix) => Some(prefix),
                Err(e) => {
                    runtime::note_residency_fallback("serve", &e);
                    None
                }
            }
        };
        let resident_ok = Cell::new(resident.is_some());
        Ok(StageRunner {
            engine,
            stages: StageSet { b1, batched },
            state,
            qbw,
            qba,
            resident,
            resident_ok,
            compressed: cm,
        })
    }

    /// The packed form this runner executes, when lowered.
    pub fn compressed_model(&self) -> Option<&Arc<CompressedModel>> {
        self.compressed.as_ref()
    }

    /// Force the legacy literal transport (equivalence tests and the
    /// residency benches compare the two paths through this).
    pub fn disable_residency(&self) {
        self.resident_ok.set(false);
    }

    /// Whether stage executions currently run over the resident prefix.
    pub fn residency_active(&self) -> bool {
        self.resident_ok.get() && self.resident.is_some()
    }

    /// The stage batch the runner actually executes at (1 = unbatched).
    pub fn stage_batch(&self) -> usize {
        self.stages.batched.as_ref().map(|b| b.batch).unwrap_or(1)
    }

    /// Calibrated thresholds recorded on the model state, if any.
    pub fn thresholds_hint(&self) -> Option<(f32, f32)> {
        self.state.exits.thresholds
    }

    /// Operand list for one literal-transport stage call: invariant
    /// operands (params ++ masks ++ qbits, referenced out of the shared
    /// state — never copied) + `x` last.
    fn input_refs<'a>(&'a self, x: &'a Tensor) -> Vec<&'a Tensor> {
        let mut v: Vec<&Tensor> =
            Vec::with_capacity(self.state.params.len() + self.state.masks.len() + 3);
        v.extend(self.state.params.iter());
        v.extend(self.state.masks.iter());
        v.push(&self.qbw);
        v.push(&self.qba);
        v.push(x);
        v
    }

    /// Run one staged executable on input rows `x`: resident prefix +
    /// row upload when the buffer transport is live, full literal
    /// marshalling otherwise.  `min_outputs` is the stage's contractual
    /// leaf count (2 for stages 1/2: exit logits + features; 1 for stage
    /// 3) — a short result means the runtime packed the tuple, which must
    /// flip the transport, not fail the request.  A buffer-mode failure
    /// flips the sticky switch and re-runs the same call on the literal
    /// path, so one bad transport costs one retry ever.
    fn run_stage(&self, exe: &Executable, x: &Tensor, min_outputs: usize) -> Result<Vec<Tensor>> {
        if self.compressed.is_some() {
            // Packed graphs take the batch input alone; params/masks/qbits
            // no longer exist as operands.
            return exe.run(&[x]);
        }
        if self.resident_ok.get() {
            if let Some(prefix) = &self.resident {
                match self.run_stage_resident(exe, prefix, x, min_outputs) {
                    Ok(outs) => return Ok(outs),
                    Err(e) => {
                        runtime::note_residency_fallback("serve stage", &e);
                        self.resident_ok.set(false);
                    }
                }
            }
        }
        exe.run(&self.input_refs(x))
    }

    fn run_stage_resident(
        &self,
        exe: &Executable,
        prefix: &[DeviceBuffer],
        x: &Tensor,
        min_outputs: usize,
    ) -> Result<Vec<Tensor>> {
        let xb = self.engine.upload(x)?;
        let mut inputs: Vec<&DeviceBuffer> = Vec::with_capacity(prefix.len() + 1);
        inputs.extend(prefix.iter());
        inputs.push(&xb);
        let outs = exe.run_buffers(&inputs)?;
        ensure!(
            outs.len() >= min_outputs,
            "`{}` returned {} device results, want >= {min_outputs} untupled leaves",
            exe.name,
            outs.len()
        );
        // Stage outputs (exit logits + forwarded features) come back to
        // the host: the exit decision and survivor regrouping are
        // host-side, exactly as on the literal path.
        outs.iter().map(|b| b.to_tensor()).collect()
    }

    /// Execute stage `s` (0-based) on `hm` = `[m, rest..]` real rows.
    /// `m == 1` uses the batch-1 graph; `m > 1` pads to the batched graph
    /// (caller guarantees `m <=` the lowered stage batch).
    /// Contractual output-leaf count per 0-based stage index: stages 1/2
    /// emit (exit logits, forwarded features); stage 3 only main logits.
    fn stage_min_outputs(s: usize) -> usize {
        if s < 2 {
            2
        } else {
            1
        }
    }

    fn exec_stage(&self, s: usize, hm: &Tensor) -> Result<Vec<Tensor>> {
        let m = hm.shape[0];
        if m == 1 {
            return self.run_stage(&self.stages.b1[s], hm, Self::stage_min_outputs(s));
        }
        let batched = self
            .stages
            .batched
            .as_ref()
            .expect("multi-row exec_stage requires batched graphs");
        ensure!(m <= batched.batch, "chunk of {m} exceeds stage batch {}", batched.batch);
        let padded;
        let href = if m == batched.batch {
            hm
        } else {
            padded = pad_rows(hm, batched.batch);
            &padded
        };
        let outs = self.run_stage(&batched.exes[s], href, Self::stage_min_outputs(s))?;
        Ok(outs.iter().map(|t| take_rows(t, m)).collect())
    }

    /// Serve one request at batch 1; returns (prediction, exit_stage 1|2|3).
    pub fn infer_one(&self, x: &Tensor, t1: f32, t2: f32) -> Result<(usize, u8)> {
        // Per stage, only the final operand (x, then h1, then h2) crosses
        // the host boundary; the invariant prefix stays device-resident.
        let outs = self.run_stage(&self.stages.b1[0], x, 2)?;
        ensure!(outs.len() == 2, "stage1 returned {} outputs", outs.len());
        let (e1, h1) = (&outs[0], &outs[1]);
        if max_conf(&e1.data) >= t1 {
            return Ok((e1.argmax(), 1));
        }
        let outs2 = self.run_stage(&self.stages.b1[1], h1, 2)?;
        ensure!(outs2.len() == 2, "stage2 returned {} outputs", outs2.len());
        let (e2, h2) = (&outs2[0], &outs2[1]);
        if max_conf(&e2.data) >= t2 {
            return Ok((e2.argmax(), 2));
        }
        let outs3 = self.run_stage(&self.stages.b1[2], h2, 1)?;
        ensure!(!outs3.is_empty(), "stage3 returned no outputs");
        Ok((outs3[0].argmax(), 3))
    }

    /// Serve one micro-batch (`xs.len() <=` stage batch when batched
    /// graphs exist).  Requests that exit early genuinely skip the later
    /// stages: survivors are regrouped (and re-padded) per stage, and a
    /// single survivor drops to the cheaper batch-1 graph.
    pub fn infer_chunk(&self, xs: &[&Tensor], t1: f32, t2: f32) -> Result<Vec<(usize, u8)>> {
        let n = xs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        if n == 1 || self.stages.batched.is_none() {
            return xs.iter().map(|x| self.infer_one(x, t1, t2)).collect();
        }

        let xb = concat_rows(xs);
        let outs1 = self.exec_stage(0, &xb)?;
        ensure!(outs1.len() == 2, "stage1 returned {} outputs", outs1.len());
        let mut results = vec![(0usize, 0u8); n];
        let mut live: Vec<usize> = Vec::new();
        for i in 0..n {
            let row = outs1[0].row(i);
            if max_conf(row) >= t1 {
                results[i] = (argmax_slice(row), 1);
            } else {
                live.push(i);
            }
        }
        if !live.is_empty() {
            let h1 = gather_rows(&outs1[1], &live);
            let outs2 = self.exec_stage(1, &h1)?;
            ensure!(outs2.len() == 2, "stage2 returned {} outputs", outs2.len());
            let mut live2: Vec<(usize, usize)> = Vec::new(); // (row in outs2, request idx)
            for (pos, &i) in live.iter().enumerate() {
                let row = outs2[0].row(pos);
                if max_conf(row) >= t2 {
                    results[i] = (argmax_slice(row), 2);
                } else {
                    live2.push((pos, i));
                }
            }
            if !live2.is_empty() {
                let rows: Vec<usize> = live2.iter().map(|&(p, _)| p).collect();
                let h2 = gather_rows(&outs2[1], &rows);
                let outs3 = self.exec_stage(2, &h2)?;
                for (pos2, &(_, i)) in live2.iter().enumerate() {
                    let row = outs3[0].row(pos2);
                    results[i] = (argmax_slice(row), 3);
                }
            }
        }
        Ok(results)
    }

    /// Serve an arbitrary number of requests, chunked to the stage batch.
    pub fn infer_many(&self, xs: &[&Tensor], t1: f32, t2: f32) -> Result<Vec<(usize, u8)>> {
        let b = self.stage_batch();
        let mut out = Vec::with_capacity(xs.len());
        let mut off = 0;
        for c in batcher::plan_chunks(xs.len(), b) {
            out.extend(self.infer_chunk(&xs[off..off + c], t1, t2)?);
            off += c;
        }
        Ok(out)
    }

    /// Deadline-aware [`StageRunner::infer_many`]: a row whose deadline
    /// has passed is shed — before stage 1 and again at each stage-ladder
    /// boundary — instead of executed.  `deadlines[i] == None` means row
    /// `i` never expires; when no row carries a deadline this is exactly
    /// `infer_many` (so the deadline-free path stays bit-identical).
    pub fn infer_many_deadline(
        &self,
        xs: &[&Tensor],
        t1: f32,
        t2: f32,
        deadlines: &[Option<Instant>],
    ) -> Result<Vec<RowOutcome>> {
        debug_assert_eq!(xs.len(), deadlines.len());
        if deadlines.iter().all(|d| d.is_none()) {
            return Ok(self
                .infer_many(xs, t1, t2)?
                .into_iter()
                .map(|(p, s)| RowOutcome::Done(p, s))
                .collect());
        }
        let b = self.stage_batch();
        let mut out = Vec::with_capacity(xs.len());
        let mut off = 0;
        for c in batcher::plan_chunks(xs.len(), b) {
            out.extend(self.infer_chunk_deadline(
                &xs[off..off + c],
                t1,
                t2,
                &deadlines[off..off + c],
            )?);
            off += c;
        }
        Ok(out)
    }

    /// One chunk of the deadline-aware ladder (see `infer_many_deadline`).
    fn infer_chunk_deadline(
        &self,
        xs: &[&Tensor],
        t1: f32,
        t2: f32,
        deadlines: &[Option<Instant>],
    ) -> Result<Vec<RowOutcome>> {
        let n = xs.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let expired = |i: usize, now: Instant| deadlines[i].is_some_and(|d| now >= d);
        // Rows start Expired; every row that reaches a verdict overwrites.
        let mut results = vec![RowOutcome::Expired; n];
        let now = Instant::now();
        let live: Vec<usize> = (0..n).filter(|&i| !expired(i, now)).collect();
        if live.is_empty() {
            return Ok(results);
        }
        if live.len() == 1 || self.stages.batched.is_none() {
            // Batch-1 ladder: re-check each row at its own start (the
            // preceding rows' execution time counts against its budget).
            for &i in &live {
                if expired(i, Instant::now()) {
                    continue;
                }
                let (p, s) = self.infer_one(xs[i], t1, t2)?;
                results[i] = RowOutcome::Done(p, s);
            }
            return Ok(results);
        }

        // Batched ladder with mid-ladder shedding at each stage boundary.
        let xsel: Vec<&Tensor> = live.iter().map(|&i| xs[i]).collect();
        let xb = concat_rows(&xsel);
        let outs1 = self.exec_stage(0, &xb)?;
        ensure!(outs1.len() == 2, "stage1 returned {} outputs", outs1.len());
        let mut undecided: Vec<(usize, usize)> = Vec::new(); // (row in outs1, request idx)
        for (pos, &i) in live.iter().enumerate() {
            let row = outs1[0].row(pos);
            if max_conf(row) >= t1 {
                results[i] = RowOutcome::Done(argmax_slice(row), 1);
            } else {
                undecided.push((pos, i));
            }
        }
        let now = Instant::now();
        undecided.retain(|&(_, i)| !expired(i, now)); // shed stays Expired
        if !undecided.is_empty() {
            let rows: Vec<usize> = undecided.iter().map(|&(p, _)| p).collect();
            let h1 = gather_rows(&outs1[1], &rows);
            let outs2 = self.exec_stage(1, &h1)?;
            ensure!(outs2.len() == 2, "stage2 returned {} outputs", outs2.len());
            let mut undecided2: Vec<(usize, usize)> = Vec::new(); // (row in outs2, request idx)
            for (pos, &(_, i)) in undecided.iter().enumerate() {
                let row = outs2[0].row(pos);
                if max_conf(row) >= t2 {
                    results[i] = RowOutcome::Done(argmax_slice(row), 2);
                } else {
                    undecided2.push((pos, i));
                }
            }
            let now = Instant::now();
            undecided2.retain(|&(_, i)| !expired(i, now));
            if !undecided2.is_empty() {
                let rows2: Vec<usize> = undecided2.iter().map(|&(p, _)| p).collect();
                let h2 = gather_rows(&outs2[1], &rows2);
                let outs3 = self.exec_stage(2, &h2)?;
                for (pos3, &(_, i)) in undecided2.iter().enumerate() {
                    results[i] = RowOutcome::Done(argmax_slice(outs3[0].row(pos3)), 3);
                }
            }
        }
        Ok(results)
    }
}

// ----- synchronous single-stream server (the demo/baseline path) ------------

pub struct Server<'e> {
    engine: &'e Engine,
    runner: StageRunner<'e>,
}

impl<'e> Server<'e> {
    /// Batch-1 server (the `coc serve` baseline).
    pub fn new(engine: &'e Engine, state: ModelState) -> Result<Server<'e>> {
        Self::with_batching(engine, state, 1)
    }

    /// Server that micro-batches `infer_batch` calls up to `max_batch`
    /// (uses the lowered batched stage graphs when available).
    pub fn with_batching(engine: &'e Engine, state: ModelState, max_batch: usize) -> Result<Server<'e>> {
        let runner = StageRunner::new(engine, Arc::new(state), max_batch)?;
        Ok(Server { engine, runner })
    }

    pub fn state(&self) -> &ModelState {
        &self.runner.state
    }

    pub fn runner(&self) -> &StageRunner<'e> {
        &self.runner
    }

    /// Serve one request; returns (prediction, exit_stage 1|2|3).
    pub fn infer(&self, x: &Tensor, t1: f32, t2: f32) -> Result<(usize, u8)> {
        self.runner.infer_one(x, t1, t2)
    }

    /// Serve a group of requests through the micro-batched staged graphs.
    pub fn infer_batch(&self, xs: &[&Tensor], t1: f32, t2: f32) -> Result<Vec<(usize, u8)>> {
        self.runner.infer_many(xs, t1, t2)
    }

    /// Run a synchronous request stream drawn from `ds`.
    pub fn serve_dataset(&self, ds: &Dataset, n_requests: usize, t1: f32, t2: f32) -> Result<ServeReport> {
        let _ = self.engine; // engine lifetime anchors executables
        let mut lat = Summary::default();
        let (mut c, mut n1, mut n2) = (0usize, 0usize, 0usize);
        let start = Instant::now();
        for r in 0..n_requests {
            let i = r % ds.len();
            let (x, _) = ds.batch(&[i]);
            let t = Instant::now();
            let (pred, stage) = self.infer(&x, t1, t2)?;
            lat.push(t.elapsed().as_micros() as f64);
            c += (pred == ds.labels[i]) as usize;
            match stage {
                1 => n1 += 1,
                2 => n2 += 1,
                _ => {}
            }
        }
        let wall = start.elapsed().as_secs_f64();
        Ok(ServeReport {
            requests: n_requests,
            accuracy: c as f64 / n_requests.max(1) as f64,
            p_exit1: n1 as f64 / n_requests.max(1) as f64,
            p_exit2: n2 as f64 / n_requests.max(1) as f64,
            latency_us: lat,
            throughput_rps: n_requests as f64 / wall.max(1e-9),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_helpers_roundtrip() {
        // [3, 2] rows: (1,2), (3,4), (5,6)
        let t = Tensor::new(vec![3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.row(1), &[3.0, 4.0]);
        let g = gather_rows(&t, &[2, 0]);
        assert_eq!(g.shape, vec![2, 2]);
        assert_eq!(g.data, vec![5.0, 6.0, 1.0, 2.0]);
        let p = pad_rows(&g, 4);
        assert_eq!(p.shape, vec![4, 2]);
        assert_eq!(&p.data[4..], &[1.0, 2.0, 1.0, 2.0]);
        let back = take_rows(&p, 2);
        assert_eq!(back.data, g.data);
    }

    #[test]
    fn concat_unit_rows() {
        let a = Tensor::new(vec![1, 2], vec![1.0, 2.0]);
        let b = Tensor::new(vec![1, 2], vec![3.0, 4.0]);
        let c = concat_rows(&[&a, &b]);
        assert_eq!(c.shape, vec![2, 2]);
        assert_eq!(c.data, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn prop_padding_rows_are_always_discarded() {
        // pad-to-stage-batch then take-back-real-rows is the identity on
        // the real rows, for any occupancy 1..=b — the padded tail never
        // leaks into results.
        crate::util::prop::check(
            "pad/take roundtrip",
            200,
            |r| (r.below(8) + 1, r.below(8) + 1, r.below(5) + 1),
            |&(m, extra, cols)| {
                if m == 0 || cols == 0 {
                    return Ok(()); // vacuous shrink candidates
                }
                let b = m + extra; // b >= m >= 1
                let data: Vec<f32> = (0..m * cols).map(|i| i as f32).collect();
                let t = Tensor::new(vec![m, cols], data.clone());
                let padded = pad_rows(&t, b);
                if padded.shape != vec![b, cols] {
                    return Err(format!("pad_rows shape {:?}", padded.shape));
                }
                // Padding repeats the final real row.
                for row in m..b {
                    if padded.row(row) != t.row(m - 1) {
                        return Err(format!("padding row {row} is not the last real row"));
                    }
                }
                let back = take_rows(&padded, m);
                if back.data != data {
                    return Err("take_rows did not recover the real rows".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_survivor_regrouping_preserves_rows() {
        // gather_rows over an arbitrary survivor subset reproduces each
        // survivor's row exactly and in order — the stage-2/3 regrouping
        // contract.
        crate::util::prop::check(
            "survivor gather",
            200,
            |r| {
                let n = r.below(10) + 1;
                let keep: Vec<usize> = (0..n).filter(|_| r.below(2) == 1).collect();
                (n, keep)
            },
            |&(n, ref keep)| {
                let cols = 3usize;
                let data: Vec<f32> = (0..n * cols).map(|i| (i * 7 % 23) as f32).collect();
                let t = Tensor::new(vec![n, cols], data);
                let g = gather_rows(&t, keep);
                if g.shape != vec![keep.len(), cols] {
                    return Err(format!("gather shape {:?}", g.shape));
                }
                for (pos, &r0) in keep.iter().enumerate() {
                    if g.row(pos) != t.row(r0) {
                        return Err(format!("survivor {r0} row mangled at position {pos}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn argmax_and_conf_on_rows() {
        assert_eq!(argmax_slice(&[0.1, 0.9, 0.3]), 1);
        let c = max_conf(&[2.0, 0.0, 0.0]);
        let want = (2.0f32).exp() / ((2.0f32).exp() + 2.0);
        assert!((c - want).abs() < 1e-6);
    }
}
