//! Dynamic micro-batching: group requests that are waiting on the same
//! stage into one padded PJRT execute.
//!
//! The policy is the classic serving trade-off: wait up to `max_wait` for
//! up to `max_batch` requests, then run with whatever arrived.  At low
//! load a request goes straight through at batch 1 (no added latency
//! beyond `max_wait`); at high load batches fill instantly and throughput
//! scales with the batched graphs' efficiency.
//!
//! Stage graphs are AOT-lowered at *fixed* batch sizes (batch shape is
//! baked into the HLO), so a drained group is chunked to the lowered stage
//! batch and the last partial chunk is padded by repeating its final row;
//! padded rows are computed and discarded.  When no batched artifacts
//! exist the planner degrades to batch-1 chunks — the scheduler never
//! requires re-lowering to run.

use std::time::{Duration, Instant};

use super::queue::{Pop, Queue};

/// Micro-batching knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Most requests grouped into one drain (>= 1).
    pub max_batch: usize,
    /// How long the drain waits for stragglers after the first request.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Block for the next request, then accumulate up to `policy.max_batch`
/// items or until `policy.max_wait` elapses.  Empty result means the queue
/// closed and drained.
pub fn drain_batch<T>(q: &Queue<T>, policy: &BatchPolicy) -> Vec<T> {
    let mut out = Vec::with_capacity(policy.max_batch.min(64));
    match q.pop() {
        Some(t) => out.push(t),
        None => return out,
    }
    let deadline = Instant::now() + policy.max_wait;
    while out.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match q.pop_timeout(deadline - now) {
            Pop::Item(t) => out.push(t),
            Pop::TimedOut | Pop::Closed => break,
        }
    }
    out
}

/// Split `n` same-stage requests into executable chunks given the lowered
/// stage batch `b`: full chunks of `b`, then one padded partial chunk
/// (its true occupancy is returned; padding = b - occupancy), except a
/// trailing single request which runs on the cheaper batch-1 graph.
///
/// With `b == 1` (no batched artifacts) every chunk is a singleton.
pub fn plan_chunks(n: usize, b: usize) -> Vec<usize> {
    assert!(b >= 1, "stage batch must be >= 1");
    if b == 1 {
        return vec![1; n];
    }
    let mut chunks = Vec::new();
    let mut left = n;
    while left > 0 {
        let take = left.min(b);
        chunks.push(take);
        left -= take;
    }
    chunks
}

/// (useful, executed) row counts of a plan at stage batch `b`: useful rows
/// carry real requests; executed rows include padding (a chunk of 1 runs
/// on the batch-1 graph, everything else pads to `b`).  Workers accumulate
/// these into `WorkerStats` so batching overhead is visible, not hidden.
pub fn plan_rows(chunks: &[usize], b: usize) -> (usize, usize) {
    let useful: usize = chunks.iter().sum();
    let executed: usize = if b <= 1 {
        useful
    } else {
        chunks.iter().map(|&c| if c == 1 { 1 } else { b }).sum()
    };
    (useful, executed)
}

/// Padding waste of a plan: rows computed then discarded, as a fraction of
/// all rows executed.
pub fn padding_waste(chunks: &[usize], b: usize) -> f64 {
    let (useful, executed) = plan_rows(chunks, b);
    if executed == 0 {
        0.0
    } else {
        (executed - useful) as f64 / executed as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn plan_batch1_is_all_singletons() {
        assert_eq!(plan_chunks(3, 1), vec![1, 1, 1]);
        assert_eq!(plan_chunks(0, 1), Vec::<usize>::new());
    }

    #[test]
    fn plan_chunks_full_and_partial() {
        assert_eq!(plan_chunks(8, 8), vec![8]);
        assert_eq!(plan_chunks(10, 8), vec![8, 2]);
        assert_eq!(plan_chunks(17, 8), vec![8, 8, 1]);
        assert_eq!(plan_chunks(5, 8), vec![5]);
    }

    #[test]
    fn padding_waste_accounts_batch1_fallback() {
        // [8, 2]: executes 8 + 8 rows for 10 useful -> 6/16 waste.
        assert_eq!(plan_rows(&[8, 2], 8), (10, 16));
        assert!((padding_waste(&[8, 2], 8) - 6.0 / 16.0).abs() < 1e-12);
        // Trailing singleton runs on the batch-1 graph: zero waste.
        assert_eq!(plan_rows(&[8, 1], 8), (9, 9));
        assert!((padding_waste(&[8, 1], 8) - 0.0).abs() < 1e-12);
        assert_eq!(plan_rows(&[4], 1), (4, 4));
        assert_eq!(padding_waste(&[4], 1), 0.0);
        assert_eq!(padding_waste(&[], 8), 0.0);
    }

    #[test]
    fn prop_chunks_cover_requests_and_respect_stage_batch() {
        // The micro-batching invariants, over random (n, b): chunks
        // partition the request group exactly, no chunk exceeds the
        // lowered stage batch, and b == 1 (absent batched artifacts)
        // degrades to singletons.
        crate::util::prop::check(
            "batcher chunk plan",
            300,
            |r| (r.below(200), r.below(16) + 1),
            |&(n, b)| {
                if b == 0 {
                    return Ok(()); // vacuous shrink candidate
                }
                let chunks = plan_chunks(n, b);
                if chunks.iter().sum::<usize>() != n {
                    return Err(format!("chunks {chunks:?} do not sum to {n}"));
                }
                if chunks.iter().any(|&c| c == 0 || c > b) {
                    return Err(format!("chunk outside 1..={b}: {chunks:?}"));
                }
                if b == 1 && !chunks.iter().all(|&c| c == 1) {
                    return Err("batch-1 fallback must produce singletons".into());
                }
                let (useful, executed) = plan_rows(&chunks, b);
                if useful != n {
                    return Err(format!("useful rows {useful} != {n}"));
                }
                if executed < useful {
                    return Err(format!("executed {executed} < useful {useful}"));
                }
                // Padding is bounded: at most (b - 1) rows per partial
                // chunk, and a trailing singleton never pads.
                if b == 1 && executed != useful {
                    return Err("batch-1 plans must execute no padding".into());
                }
                let waste = padding_waste(&chunks, b);
                if !(0.0..1.0).contains(&waste) {
                    return Err(format!("padding waste {waste} out of range"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn drain_collects_up_to_max_batch() {
        let q = Queue::bounded(64);
        for i in 0..10 {
            q.try_push(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(50) };
        let got = drain_batch(&q, &policy);
        assert_eq!(got, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn drain_returns_partial_after_wait() {
        let q = Queue::bounded(64);
        q.try_push(1u32).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        let got = drain_batch(&q, &policy);
        assert_eq!(got, vec![1]);
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn drain_empty_closed_queue_is_empty() {
        let q: Queue<u32> = Queue::bounded(4);
        q.close();
        let got = drain_batch(&q, &BatchPolicy::default());
        assert!(got.is_empty());
    }

    #[test]
    fn drain_sees_items_from_other_threads() {
        let q: Arc<Queue<u32>> = Arc::new(Queue::bounded(64));
        let qc = q.clone();
        let h = std::thread::spawn(move || {
            for i in 0..3 {
                std::thread::sleep(Duration::from_millis(2));
                qc.try_push(i).unwrap();
            }
        });
        let policy = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(200) };
        let got = drain_batch(&q, &policy);
        h.join().unwrap();
        assert_eq!(got.len(), 3);
    }
}
