//! Multi-worker serving: N threads, each owning its **own** PJRT engine.
//!
//! PJRT client/executable handles are not `Send` (see `runtime`), so
//! instead of sharing one engine behind a lock — which would serialize
//! every execute and defeat the pool — each worker thread constructs an
//! `Engine` over the shared artifacts directory and compiles its own
//! staged executables.  Compilation is seconds per worker, paid once at
//! startup ([`WorkerPool::wait_ready`] gates load generation on it); what
//! crosses threads is only `Send` data: jobs, tensors, and the shared
//! `Arc<ModelState>`.
//!
//! Workers drain dynamic micro-batches from the shared bounded queue
//! (`batcher::drain_batch`) and run them through `StageRunner::infer_many`,
//! so requests grouped in one drain share padded stage executes and
//! early-exiting requests genuinely skip later stages.
//!
//! ## Failure domains
//!
//! The failure domain is one micro-batch on one engine generation, never
//! the pool:
//!
//! * batch execution runs under `catch_unwind`, so a panicking batch fails
//!   *its* requests with a terminal [`OutcomeStatus::Failed`] outcome
//!   instead of hanging their waiters;
//! * after a crash the worker respawns a replacement engine in place
//!   (engines are not `Send`, so supervision is in-thread) with capped
//!   exponential backoff, up to [`PoolOpts::max_restarts`] — counted by
//!   the `serve.worker.restarts` metric;
//! * an optional per-request deadline ([`PoolOpts::deadline`]) is enforced
//!   at dequeue and mid-ladder: expired work is shed with a terminal
//!   [`OutcomeStatus::Timeout`] outcome (`serve.req.timeout`), not
//!   executed;
//! * every submitted request reaches **exactly one** terminal outcome —
//!   done, rejected at admission, timeout, or failed; [`WorkerPool::
//!   shutdown`] fails any requests stranded in the queue by dead workers.

use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::{drain_batch, plan_chunks, plan_rows, BatchPolicy};
use super::queue::{Queue, QueueStats};
use super::{RowOutcome, StageRunner};
use crate::faults;
use crate::models::ModelState;
use crate::obs::metrics::{self, Counter, Gauge};
use crate::obs::trace;
use crate::runtime::{BackendChoice, Engine};
use crate::tensor::Tensor;
use crate::util::sync;

/// One enqueued inference request.
#[derive(Debug)]
pub struct ServeJob {
    pub id: u64,
    /// `[1, H, W, C]` input sample.
    pub x: Tensor,
    /// Ground-truth label when known (load generation from a dataset), so
    /// the report can check accuracy is unchanged under concurrency.
    pub label: Option<usize>,
    pub submitted: Instant,
}

impl ServeJob {
    pub fn new(id: u64, x: Tensor, label: Option<usize>) -> ServeJob {
        ServeJob { id, x, label, submitted: Instant::now() }
    }
}

/// How a request terminated.  Together with admission rejection these are
/// the only ends a submitted request can meet, and it meets exactly one.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutcomeStatus {
    /// Served: `pred`/`stage` are meaningful.
    Done,
    /// Deadline expired before or mid-ladder; shed, never fully executed.
    Timeout,
    /// The batch executing this request died (panic or execute error), or
    /// the request was stranded in the queue when the pool shut down.
    Failed,
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub id: u64,
    /// Meaningful only when `status == Done` (0 otherwise).
    pub pred: usize,
    /// Exit stage 1|2|3 when `status == Done` (0 otherwise).
    pub stage: u8,
    pub label: Option<usize>,
    /// Queue wait + execution, measured from submission.
    pub latency_us: f64,
    /// Serving worker; `usize::MAX` for requests failed at shutdown.
    pub worker: usize,
    pub status: OutcomeStatus,
}

impl ServeOutcome {
    fn terminal(
        job: &ServeJob,
        worker: usize,
        status: OutcomeStatus,
        pred: usize,
        stage: u8,
    ) -> ServeOutcome {
        ServeOutcome {
            id: job.id,
            pred,
            stage,
            label: job.label,
            latency_us: job.submitted.elapsed().as_micros() as f64,
            worker,
            status,
        }
    }

    pub fn done(job: &ServeJob, pred: usize, stage: u8, worker: usize) -> ServeOutcome {
        Self::terminal(job, worker, OutcomeStatus::Done, pred, stage)
    }

    pub fn timeout(job: &ServeJob, worker: usize) -> ServeOutcome {
        Self::terminal(job, worker, OutcomeStatus::Timeout, 0, 0)
    }

    pub fn failed(job: &ServeJob, worker: usize) -> ServeOutcome {
        Self::terminal(job, worker, OutcomeStatus::Failed, 0, 0)
    }
}

#[derive(Debug, Clone)]
pub struct PoolOpts {
    pub workers: usize,
    pub artifacts_dir: PathBuf,
    /// Request-queue bound (admission control beyond it).
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Confidence thresholds (t1, t2) applied to every request.
    pub thresholds: (f32, f32),
    /// Execution backend each worker engine is built on.  `Ref` ignores
    /// `artifacts_dir` — the hermetic pool the concurrency tests run on.
    pub backend: BackendChoice,
    /// Total ref-backend kernel thread budget for the whole pool
    /// (`--ref-threads`; default: available parallelism).  Each worker
    /// engine gets a `runtime::threads_per_worker` share, so worker
    /// threads and kernel threads compose without oversubscription.
    /// Thread counts never change results — the ref backend is
    /// thread-count invariant by contract.
    pub ref_threads: usize,
    /// Lower the model to its packed compressed form at worker startup
    /// and execute the compressed stage graphs (`--compressed`).  Workers
    /// fail ready if the state cannot be lowered or the backend cannot
    /// execute packed forms.
    pub compressed: bool,
    /// Per-request latency budget from submission.  Expired requests are
    /// shed at dequeue and at stage-ladder boundaries with a terminal
    /// `Timeout` outcome.  `None` (the default) disables shedding.
    pub deadline: Option<Duration>,
    /// How many times a worker may respawn a replacement engine after a
    /// mid-run crash before giving up and going to `failed`.
    pub max_restarts: u32,
    /// Base respawn backoff; doubles per consecutive restart (capped).
    pub restart_backoff: Duration,
}

impl PoolOpts {
    pub fn new<P: Into<PathBuf>>(artifacts_dir: P, workers: usize, thresholds: (f32, f32)) -> PoolOpts {
        PoolOpts {
            workers: workers.max(1),
            artifacts_dir: artifacts_dir.into(),
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            thresholds,
            backend: BackendChoice::Pjrt,
            ref_threads: crate::runtime::default_ref_threads(),
            compressed: false,
            deadline: None,
            max_restarts: 3,
            restart_backoff: Duration::from_millis(50),
        }
    }
}

/// Per-worker counters, returned at shutdown.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub worker: usize,
    pub processed: u64,
    pub drains: u64,
    pub max_chunk: usize,
    /// Stage batch this worker's runner executed at (1 = unbatched).
    pub stage_batch: usize,
    /// Stage-1 rows that carried real requests vs rows executed including
    /// padding — the micro-batching overhead, surfaced not hidden.
    pub rows_useful: u64,
    pub rows_executed: u64,
    /// Host<->device transfer volume over this worker's engine lifetime
    /// (includes the one-time resident-prefix upload; summed over engine
    /// generations when the worker respawned).  With the device-resident
    /// operand prefix, the per-request upload share is just the input
    /// rows — `serve_bench.json` surfaces these so BENCH trajectories
    /// capture transfer volume alongside latency.
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
    /// Engine respawns this worker performed after mid-run crashes.
    pub restarts: u32,
}

impl WorkerStats {
    /// Fraction of executed stage-1 rows that were padding.
    pub fn padding_waste(&self) -> f64 {
        if self.rows_executed == 0 {
            0.0
        } else {
            (self.rows_executed - self.rows_useful) as f64 / self.rows_executed as f64
        }
    }
}

/// Pool result: per-worker stats plus any worker failures.  A failed
/// worker's in-flight batch gets terminal `Failed` outcomes; requests
/// stranded in the queue are failed by [`WorkerPool::shutdown`].
#[derive(Debug, Default)]
pub struct PoolOutcome {
    pub stats: Vec<WorkerStats>,
    pub errors: Vec<String>,
}

#[derive(Default)]
struct Ready {
    ready: usize,
    failed: usize,
    /// Startup/death errors of the workers that failed, in arrival order.
    errors: Vec<String>,
}

/// How the pool start settled, from [`WorkerPool::wait_ready`].
#[derive(Debug, Clone, Default)]
pub struct ReadyReport {
    /// Configured pool size.
    pub workers: usize,
    /// Workers that came up.
    pub ready: usize,
    /// Workers that failed to start.
    pub failed: usize,
    /// The failed workers' startup errors, in arrival order.
    pub errors: Vec<String>,
}

impl ReadyReport {
    pub fn all_up(&self) -> bool {
        self.failed == 0 && self.ready == self.workers
    }

    /// Human summary: "N of M up" or "N of M up, K failed: <first error>".
    pub fn describe(&self) -> String {
        if self.failed == 0 {
            format!("{} of {} up", self.ready, self.workers)
        } else {
            format!(
                "{} of {} up, {} failed: {}",
                self.ready,
                self.workers,
                self.failed,
                self.errors.first().map(String::as_str).unwrap_or("unknown error")
            )
        }
    }
}

pub struct WorkerPool {
    jobs: Arc<Queue<ServeJob>>,
    outcomes: Arc<Queue<ServeOutcome>>,
    handles: Vec<JoinHandle<Result<WorkerStats>>>,
    ready: Arc<(Mutex<Ready>, Condvar)>,
    workers: usize,
    // Registry handles resolved once at construction — submit paths touch
    // only the cached Arcs, never the name lookup.
    m_accepted: Arc<Counter>,
    m_rejected: Arc<Counter>,
    m_depth: Arc<Gauge>,
}

impl WorkerPool {
    /// Spawn the pool; workers compile in the background.  Call
    /// [`WorkerPool::wait_ready`] before timing anything.
    pub fn start(state: Arc<ModelState>, opts: PoolOpts) -> WorkerPool {
        let jobs: Arc<Queue<ServeJob>> = Arc::new(Queue::bounded(opts.queue_capacity));
        let outcomes: Arc<Queue<ServeOutcome>> = Arc::new(Queue::unbounded());
        let ready = Arc::new((Mutex::new(Ready::default()), Condvar::new()));
        let mut handles = Vec::with_capacity(opts.workers);
        for w in 0..opts.workers {
            let state = state.clone();
            let opts = opts.clone();
            let jobs = jobs.clone();
            let outcomes = outcomes.clone();
            let ready = ready.clone();
            handles.push(std::thread::spawn(move || {
                worker_main(w, state, opts, jobs, outcomes, ready)
            }));
        }
        WorkerPool {
            jobs,
            outcomes,
            handles,
            ready,
            workers: opts.workers,
            m_accepted: metrics::counter("serve.queue.accepted"),
            m_rejected: metrics::counter("serve.queue.rejected"),
            m_depth: metrics::gauge("serve.queue.depth"),
        }
    }

    /// Configured pool size.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Workers currently alive (came up and have not died mid-run).
    /// Reports must use this, not the configured size — throughput
    /// achieved by 2 survivors of a 4-worker pool is 2-worker throughput.
    pub fn live_workers(&self) -> usize {
        sync::lock(&self.ready.0).ready
    }

    /// Block until every worker has either compiled its engine or failed.
    /// Partial starts succeed: the report carries how many workers came
    /// up, how many failed, and the failed workers' startup errors.
    /// Errors only when *no* worker survived or the timeout lapsed (both
    /// messages name the partial state and the first startup error).
    pub fn wait_ready(&self, timeout: Duration) -> Result<ReadyReport> {
        let (lock, cv) = &*self.ready;
        let deadline = Instant::now() + timeout;
        let mut st = sync::lock(lock);
        while st.ready + st.failed < self.workers {
            let now = Instant::now();
            if now >= deadline {
                return Err(anyhow!(
                    "worker pool not ready after {timeout:?}: {} of {} up, {} failed{}",
                    st.ready,
                    self.workers,
                    st.failed,
                    st.errors.first().map(|e| format!(": {e}")).unwrap_or_default()
                ));
            }
            let (guard, _) = sync::wait_timeout(cv, st, deadline - now);
            st = guard;
        }
        let report = ReadyReport {
            workers: self.workers,
            ready: st.ready,
            failed: st.failed,
            errors: st.errors.clone(),
        };
        if report.ready == 0 {
            return Err(anyhow!(
                "all {} workers failed to start{}",
                self.workers,
                report.errors.first().map(|e| format!(": {e}")).unwrap_or_default()
            ));
        }
        Ok(report)
    }

    /// Admission-controlled submit (load shedding when the queue is full).
    pub fn try_submit(&self, job: ServeJob) -> std::result::Result<(), ServeJob> {
        match self.jobs.try_push(job) {
            Ok(()) => {
                self.m_accepted.incr();
                self.m_depth.set(self.jobs.len() as f64);
                Ok(())
            }
            Err(j) => {
                self.m_rejected.incr();
                Err(j)
            }
        }
    }

    /// Blocking submit (closed-loop clients).
    pub fn submit(&self, job: ServeJob) -> std::result::Result<(), ServeJob> {
        match self.jobs.push(job) {
            Ok(()) => {
                self.m_accepted.incr();
                self.m_depth.set(self.jobs.len() as f64);
                Ok(())
            }
            Err(j) => Err(j), // closed, not shed — no rejection count
        }
    }

    pub fn outcomes(&self) -> &Queue<ServeOutcome> {
        &self.outcomes
    }

    pub fn queue_depth(&self) -> usize {
        self.jobs.len()
    }

    pub fn queue_stats(&self) -> QueueStats {
        self.jobs.stats()
    }

    /// Close the request queue, join every worker, and return the pool
    /// outcome.  Pending queued jobs are still drained before workers
    /// exit; if every worker died, the stranded jobs are accounted with
    /// terminal `Failed` outcomes so no accepted request simply vanishes.
    pub fn shutdown(self) -> PoolOutcome {
        self.jobs.close();
        let mut out = PoolOutcome::default();
        for h in self.handles {
            match h.join() {
                Ok(Ok(stats)) => out.stats.push(stats),
                Ok(Err(e)) => out.errors.push(format!("{e:#}")),
                Err(_) => out.errors.push("worker panicked".to_string()),
            }
        }
        // Workers are gone; anything still queued would otherwise be lost
        // without a terminal outcome.
        let m_failed = metrics::counter("serve.req.failed");
        while let Some(job) = self.jobs.pop() {
            m_failed.incr();
            if self.outcomes.push(ServeOutcome::failed(&job, usize::MAX)).is_err() {
                break;
            }
        }
        self.outcomes.close();
        out
    }
}

/// Why one engine generation's serve loop ended.
enum ServeExit {
    /// Queue closed and drained: clean shutdown.
    Drained,
    /// Outcome side closed: the consumer is gone, stop serving.
    OutcomesClosed,
    /// The in-flight batch died (panic or execute error).  Its requests
    /// already got terminal `Failed` outcomes; the engine generation must
    /// be replaced before serving again.
    Crashed(String),
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_main(
    w: usize,
    state: Arc<ModelState>,
    opts: PoolOpts,
    jobs: Arc<Queue<ServeJob>>,
    outcomes: Arc<Queue<ServeOutcome>>,
    ready: Arc<(Mutex<Ready>, Condvar)>,
) -> Result<WorkerStats> {
    let (lock, cv) = &*ready;
    // Startup failure: this worker never counted ready.
    let start_fail = |e: anyhow::Error| -> anyhow::Error {
        let mut st = sync::lock(lock);
        st.failed += 1;
        st.errors.push(format!("worker {w}: {e:#}"));
        drop(st);
        cv.notify_all();
        e
    };
    // Death after being ready: move ready -> failed so reports attribute
    // throughput to the survivors and the `ready + failed == workers`
    // settlement invariant that wait_ready blocks on stays intact.
    let die = |e: &anyhow::Error| {
        let mut st = sync::lock(lock);
        st.ready -= 1;
        st.failed += 1;
        st.errors.push(format!("worker {w}: {e:#}"));
        drop(st);
        cv.notify_all();
    };

    // Each worker engine gets its share of the pool's kernel-thread
    // budget (ref backend only; PJRT ignores it).
    let kernel_threads = crate::runtime::threads_per_worker(opts.ref_threads, opts.workers);
    let mut stats = WorkerStats { worker: w, ..Default::default() };
    let m_restarts = metrics::counter("serve.worker.restarts");
    // Supervision loop: one engine generation per iteration.  Engines are
    // not `Send`, so the replacement for a crashed engine is built right
    // here in the worker's own thread.
    let mut generation: u32 = 0;
    loop {
        // Per-worker engine: compile once, then serve (see module docs).
        // The runner borrows the engine (its executables and resident
        // prefix buffers), so "engine outlives the runner" is
        // compile-enforced and the two are constructed as separate locals
        // rather than returned together.
        let made = (|| -> Result<Engine> {
            if faults::fire(faults::WORKER_START_FAIL) {
                anyhow::bail!("injected fault: worker_start_fail");
            }
            Engine::with_backend_threads(opts.backend, &opts.artifacts_dir, kernel_threads)
                .with_context(|| format!("worker {w}: creating {} engine", opts.backend.name()))
        })();
        let engine = match made {
            Ok(e) => e,
            Err(e) if generation == 0 => return Err(start_fail(e)),
            Err(e) => {
                let e = e.context(format!("worker {w}: engine respawn {generation} failed"));
                die(&e);
                return Err(e);
            }
        };
        // Arc clone: all workers share one copy of the weights.
        let made_runner = if opts.compressed {
            StageRunner::new_compressed(&engine, state.clone(), opts.batch.max_batch)
        } else {
            StageRunner::new(&engine, state.clone(), opts.batch.max_batch)
        };
        let made_runner =
            made_runner.with_context(|| format!("worker {w}: loading staged graphs"));
        let runner = match made_runner {
            Ok(r) => r,
            Err(e) if generation == 0 => return Err(start_fail(e)),
            Err(e) => {
                die(&e);
                return Err(e);
            }
        };
        if generation == 0 {
            sync::lock(lock).ready += 1;
            cv.notify_all();
        }
        stats.stage_batch = runner.stage_batch();

        let exit = serve_generation(w, &runner, &opts, &jobs, &outcomes, &mut stats);

        // Fold this generation's transfer volume into the lifetime stats
        // before the engine is dropped.
        let rs = engine.stats();
        stats.bytes_uploaded += rs.bytes_uploaded;
        stats.bytes_downloaded += rs.bytes_downloaded;

        match exit {
            ServeExit::Drained | ServeExit::OutcomesClosed => return Ok(stats),
            ServeExit::Crashed(desc) => {
                generation += 1;
                if generation > opts.max_restarts {
                    let e = anyhow!(
                        "worker {w}: {desc} (restart budget {} exhausted)",
                        opts.max_restarts
                    );
                    die(&e);
                    return Err(e);
                }
                stats.restarts += 1;
                m_restarts.incr();
                let _sp = trace::span("serve.worker.respawn");
                // Capped exponential backoff before the replacement engine.
                let backoff = opts.restart_backoff.saturating_mul(1u32 << (generation - 1).min(6));
                crate::obs::log!(
                    crate::obs::Level::Warn,
                    "worker {w}: {desc}; respawning engine (restart {generation}/{}, backoff {backoff:?})",
                    opts.max_restarts
                );
                std::thread::sleep(backoff);
            }
        }
    }
}

/// Serve micro-batches on one engine generation until the queue drains,
/// the outcome side closes, or the batch in flight dies.
fn serve_generation(
    w: usize,
    runner: &StageRunner<'_>,
    opts: &PoolOpts,
    jobs: &Queue<ServeJob>,
    outcomes: &Queue<ServeOutcome>,
    stats: &mut WorkerStats,
) -> ServeExit {
    let (t1, t2) = opts.thresholds;
    // Resolve registry handles once per generation; the loop touches only
    // Arcs.
    let m_drains = metrics::counter("serve.batch.drains");
    let m_rows_useful = metrics::counter("serve.batch.rows_useful");
    let m_rows_executed = metrics::counter("serve.batch.rows_executed");
    let m_timeout = metrics::counter("serve.req.timeout");
    let m_failed = metrics::counter("serve.req.failed");
    loop {
        let mut batch = {
            // Span covers the micro-batch assembly wait (arrival gaps +
            // linger), distinct from the execute below.
            let _s = trace::span("serve.drain_batch");
            drain_batch(jobs, &opts.batch)
        };
        if batch.is_empty() {
            return ServeExit::Drained; // queue closed and drained
        }
        stats.drains += 1;
        m_drains.incr();
        // Deadline check at dequeue: expired work is answered, not run.
        if let Some(budget) = opts.deadline {
            let now = Instant::now();
            let mut kept = Vec::with_capacity(batch.len());
            for job in batch {
                if now.duration_since(job.submitted) >= budget {
                    m_timeout.incr();
                    if outcomes.push(ServeOutcome::timeout(&job, w)).is_err() {
                        return ServeExit::OutcomesClosed;
                    }
                } else {
                    kept.push(job);
                }
            }
            batch = kept;
            if batch.is_empty() {
                continue;
            }
        }
        stats.max_chunk = stats.max_chunk.max(batch.len());
        let (useful, executed) =
            plan_rows(&plan_chunks(batch.len(), stats.stage_batch), stats.stage_batch);
        stats.rows_useful += useful as u64;
        stats.rows_executed += executed as u64;
        m_rows_useful.add(useful as u64);
        m_rows_executed.add(executed as u64);
        // Injected slowness: builds deadline pressure for the chaos soak.
        if faults::fire(faults::SLOW_BATCH) {
            let ms = faults::arg(faults::SLOW_BATCH).unwrap_or(10.0);
            std::thread::sleep(Duration::from_micros((ms * 1000.0) as u64));
        }
        let deadlines: Vec<Option<Instant>> =
            batch.iter().map(|j| opts.deadline.map(|d| j.submitted + d)).collect();
        let xs: Vec<&Tensor> = batch.iter().map(|j| &j.x).collect();
        // The batch is the failure domain: a panic (injected or real) in
        // the stage ladder fails these requests terminally and ends the
        // engine generation; it never propagates past this frame, so no
        // waiter hangs and no lock stays poisoned on this path.
        let ran = {
            let _s = trace::span("serve.infer_batch");
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if faults::fire(faults::WORKER_PANIC) {
                    panic!("injected fault: worker_panic");
                }
                runner.infer_many_deadline(&xs, t1, t2, &deadlines)
            }))
        };
        let rows = match ran {
            Err(p) => {
                for job in &batch {
                    m_failed.incr();
                    if outcomes.push(ServeOutcome::failed(job, w)).is_err() {
                        return ServeExit::OutcomesClosed;
                    }
                }
                return ServeExit::Crashed(format!(
                    "panicked during micro-batch of {}: {}",
                    batch.len(),
                    panic_msg(&*p)
                ));
            }
            Ok(Err(e)) => {
                for job in &batch {
                    m_failed.incr();
                    if outcomes.push(ServeOutcome::failed(job, w)).is_err() {
                        return ServeExit::OutcomesClosed;
                    }
                }
                return ServeExit::Crashed(format!(
                    "micro-batch of {} failed: {e:#}",
                    batch.len()
                ));
            }
            Ok(Ok(rows)) => rows,
        };
        for (job, row) in batch.iter().zip(rows) {
            let outcome = match row {
                RowOutcome::Done(pred, stage) => {
                    stats.processed += 1;
                    ServeOutcome::done(job, pred, stage, w)
                }
                RowOutcome::Expired => {
                    m_timeout.incr();
                    ServeOutcome::timeout(job, w)
                }
            };
            if outcomes.push(outcome).is_err() {
                return ServeExit::OutcomesClosed; // result side closed
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_types_are_send() {
        // Compile-enforced: these cross worker-thread boundaries.
        fn assert_send<T: Send>() {}
        assert_send::<ServeJob>();
        assert_send::<ServeOutcome>();
        assert_send::<Arc<Queue<ServeJob>>>();
        assert_send::<Arc<ModelState>>();
        assert_send::<PoolOpts>();
    }

    #[test]
    fn ready_report_describes_partial_starts() {
        let rep = ReadyReport {
            workers: 4,
            ready: 3,
            failed: 1,
            errors: vec!["worker 2: engine exploded".into()],
        };
        assert!(!rep.all_up());
        assert_eq!(rep.describe(), "3 of 4 up, 1 failed: worker 2: engine exploded");
        let ok = ReadyReport { workers: 2, ready: 2, failed: 0, errors: vec![] };
        assert!(ok.all_up());
        assert_eq!(ok.describe(), "2 of 2 up");
    }

    #[test]
    fn pool_with_bad_artifacts_fails_ready_cleanly() {
        // A host-initialized state over a toy arch with no graph files:
        // every worker must fail setup and wait_ready must report that
        // instead of hanging.
        let layers = vec![crate::models::LayerDesc {
            name: "fc".into(),
            kind: crate::models::LayerKind::Dense,
            k: 1,
            cin: 4,
            cout: 2,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: -1,
            out_mask: -1,
            segment: "seg3".into(),
            input: String::new(),
            act: true,
        }];
        let arch = Arc::new(crate::models::ArchManifest {
            name: "toy".into(),
            num_classes: 2,
            layers,
            mask_slots: vec![],
            param_shapes: vec![vec![4, 2], vec![2]],
            graphs: std::collections::BTreeMap::new(),
            train_batch: 1,
            eval_batch: 1,
            stage_batch: 1,
            stage_batches: vec![1],
            stage_h1_shape: vec![1, 4],
            stage_h2_shape: vec![1, 4],
            joins: Vec::new(),
        });
        let state = Arc::new(ModelState::init_host(arch, 0));
        let pool = WorkerPool::start(
            state,
            PoolOpts::new("/nonexistent/artifacts", 2, (0.8, 0.8)),
        );
        let res = pool.wait_ready(Duration::from_secs(30));
        let err = format!("{:#}", res.expect_err("expected startup failure"));
        assert!(err.contains("all 2 workers failed to start"), "{err}");
        assert!(err.contains("worker"), "error should carry a startup cause: {err}");
        let outcome = pool.shutdown();
        assert_eq!(outcome.stats.len(), 0);
        assert_eq!(outcome.errors.len(), 2);
    }
}
