//! Multi-worker serving: N threads, each owning its **own** PJRT engine.
//!
//! PJRT client/executable handles are not `Send` (see `runtime`), so
//! instead of sharing one engine behind a lock — which would serialize
//! every execute and defeat the pool — each worker thread constructs an
//! `Engine` over the shared artifacts directory and compiles its own
//! staged executables.  Compilation is seconds per worker, paid once at
//! startup ([`WorkerPool::wait_ready`] gates load generation on it); what
//! crosses threads is only `Send` data: jobs, tensors, and the shared
//! `Arc<ModelState>`.
//!
//! Workers drain dynamic micro-batches from the shared bounded queue
//! (`batcher::drain_batch`) and run them through `StageRunner::infer_many`,
//! so requests grouped in one drain share padded stage executes and
//! early-exiting requests genuinely skip later stages.

use std::path::PathBuf;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::batcher::{drain_batch, plan_chunks, plan_rows, BatchPolicy};
use super::queue::{Queue, QueueStats};
use super::StageRunner;
use crate::models::ModelState;
use crate::obs::metrics::{self, Counter, Gauge};
use crate::obs::trace;
use crate::runtime::{BackendChoice, Engine};
use crate::tensor::Tensor;

/// One enqueued inference request.
#[derive(Debug)]
pub struct ServeJob {
    pub id: u64,
    /// `[1, H, W, C]` input sample.
    pub x: Tensor,
    /// Ground-truth label when known (load generation from a dataset), so
    /// the report can check accuracy is unchanged under concurrency.
    pub label: Option<usize>,
    pub submitted: Instant,
}

impl ServeJob {
    pub fn new(id: u64, x: Tensor, label: Option<usize>) -> ServeJob {
        ServeJob { id, x, label, submitted: Instant::now() }
    }
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub id: u64,
    pub pred: usize,
    pub stage: u8,
    pub label: Option<usize>,
    /// Queue wait + execution, measured from submission.
    pub latency_us: f64,
    pub worker: usize,
}

#[derive(Debug, Clone)]
pub struct PoolOpts {
    pub workers: usize,
    pub artifacts_dir: PathBuf,
    /// Request-queue bound (admission control beyond it).
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    /// Confidence thresholds (t1, t2) applied to every request.
    pub thresholds: (f32, f32),
    /// Execution backend each worker engine is built on.  `Ref` ignores
    /// `artifacts_dir` — the hermetic pool the concurrency tests run on.
    pub backend: BackendChoice,
    /// Total ref-backend kernel thread budget for the whole pool
    /// (`--ref-threads`; default: available parallelism).  Each worker
    /// engine gets a `runtime::threads_per_worker` share, so worker
    /// threads and kernel threads compose without oversubscription.
    /// Thread counts never change results — the ref backend is
    /// thread-count invariant by contract.
    pub ref_threads: usize,
    /// Lower the model to its packed compressed form at worker startup
    /// and execute the compressed stage graphs (`--compressed`).  Workers
    /// fail ready if the state cannot be lowered or the backend cannot
    /// execute packed forms.
    pub compressed: bool,
}

impl PoolOpts {
    pub fn new<P: Into<PathBuf>>(artifacts_dir: P, workers: usize, thresholds: (f32, f32)) -> PoolOpts {
        PoolOpts {
            workers: workers.max(1),
            artifacts_dir: artifacts_dir.into(),
            queue_capacity: 256,
            batch: BatchPolicy::default(),
            thresholds,
            backend: BackendChoice::Pjrt,
            ref_threads: crate::runtime::default_ref_threads(),
            compressed: false,
        }
    }
}

/// Per-worker counters, returned at shutdown.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub worker: usize,
    pub processed: u64,
    pub drains: u64,
    pub max_chunk: usize,
    /// Stage batch this worker's runner executed at (1 = unbatched).
    pub stage_batch: usize,
    /// Stage-1 rows that carried real requests vs rows executed including
    /// padding — the micro-batching overhead, surfaced not hidden.
    pub rows_useful: u64,
    pub rows_executed: u64,
    /// Host<->device transfer volume over this worker's engine lifetime
    /// (includes the one-time resident-prefix upload).  With the
    /// device-resident operand prefix, the per-request upload share is
    /// just the input rows — `serve_bench.json` surfaces these so BENCH
    /// trajectories capture transfer volume alongside latency.
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
}

impl WorkerStats {
    /// Fraction of executed stage-1 rows that were padding.
    pub fn padding_waste(&self) -> f64 {
        if self.rows_executed == 0 {
            0.0
        } else {
            (self.rows_executed - self.rows_useful) as f64 / self.rows_executed as f64
        }
    }
}

/// Pool result: per-worker stats plus any worker failures (a failed
/// worker's in-flight jobs are lost; loadgen reports the shortfall).
#[derive(Debug, Default)]
pub struct PoolOutcome {
    pub stats: Vec<WorkerStats>,
    pub errors: Vec<String>,
}

#[derive(Default)]
struct Ready {
    ready: usize,
    failed: usize,
}

pub struct WorkerPool {
    jobs: Arc<Queue<ServeJob>>,
    outcomes: Arc<Queue<ServeOutcome>>,
    handles: Vec<JoinHandle<Result<WorkerStats>>>,
    ready: Arc<(Mutex<Ready>, Condvar)>,
    workers: usize,
    // Registry handles resolved once at construction — submit paths touch
    // only the cached Arcs, never the name lookup.
    m_accepted: Arc<Counter>,
    m_rejected: Arc<Counter>,
    m_depth: Arc<Gauge>,
}

impl WorkerPool {
    /// Spawn the pool; workers compile in the background.  Call
    /// [`WorkerPool::wait_ready`] before timing anything.
    pub fn start(state: Arc<ModelState>, opts: PoolOpts) -> WorkerPool {
        let jobs: Arc<Queue<ServeJob>> = Arc::new(Queue::bounded(opts.queue_capacity));
        let outcomes: Arc<Queue<ServeOutcome>> = Arc::new(Queue::unbounded());
        let ready = Arc::new((Mutex::new(Ready::default()), Condvar::new()));
        let mut handles = Vec::with_capacity(opts.workers);
        for w in 0..opts.workers {
            let state = state.clone();
            let opts = opts.clone();
            let jobs = jobs.clone();
            let outcomes = outcomes.clone();
            let ready = ready.clone();
            handles.push(std::thread::spawn(move || {
                worker_main(w, state, opts, jobs, outcomes, ready)
            }));
        }
        WorkerPool {
            jobs,
            outcomes,
            handles,
            ready,
            workers: opts.workers,
            m_accepted: metrics::counter("serve.queue.accepted"),
            m_rejected: metrics::counter("serve.queue.rejected"),
            m_depth: metrics::gauge("serve.queue.depth"),
        }
    }

    /// Configured pool size.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Workers currently alive (came up and have not died mid-run).
    /// Reports must use this, not the configured size — throughput
    /// achieved by 2 survivors of a 4-worker pool is 2-worker throughput.
    pub fn live_workers(&self) -> usize {
        self.ready.0.lock().unwrap().ready
    }

    /// Block until every worker has either compiled its engine or failed.
    /// Returns the number of live workers; errors if none survived or the
    /// timeout lapsed.
    pub fn wait_ready(&self, timeout: Duration) -> Result<usize> {
        let (lock, cv) = &*self.ready;
        let deadline = Instant::now() + timeout;
        let mut st = lock.lock().unwrap();
        while st.ready + st.failed < self.workers {
            let now = Instant::now();
            if now >= deadline {
                return Err(anyhow!(
                    "worker pool not ready after {timeout:?} ({}/{} up)",
                    st.ready,
                    self.workers
                ));
            }
            let (guard, _) = cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
        if st.ready == 0 {
            return Err(anyhow!("all {} workers failed to start", self.workers));
        }
        Ok(st.ready)
    }

    /// Admission-controlled submit (load shedding when the queue is full).
    pub fn try_submit(&self, job: ServeJob) -> std::result::Result<(), ServeJob> {
        match self.jobs.try_push(job) {
            Ok(()) => {
                self.m_accepted.incr();
                self.m_depth.set(self.jobs.len() as f64);
                Ok(())
            }
            Err(j) => {
                self.m_rejected.incr();
                Err(j)
            }
        }
    }

    /// Blocking submit (closed-loop clients).
    pub fn submit(&self, job: ServeJob) -> std::result::Result<(), ServeJob> {
        match self.jobs.push(job) {
            Ok(()) => {
                self.m_accepted.incr();
                self.m_depth.set(self.jobs.len() as f64);
                Ok(())
            }
            Err(j) => Err(j), // closed, not shed — no rejection count
        }
    }

    pub fn outcomes(&self) -> &Queue<ServeOutcome> {
        &self.outcomes
    }

    pub fn queue_depth(&self) -> usize {
        self.jobs.len()
    }

    pub fn queue_stats(&self) -> QueueStats {
        self.jobs.stats()
    }

    /// Close the request queue, join every worker, and return the pool
    /// outcome.  Pending queued jobs are still drained before workers exit.
    pub fn shutdown(self) -> PoolOutcome {
        self.jobs.close();
        let mut out = PoolOutcome::default();
        for h in self.handles {
            match h.join() {
                Ok(Ok(stats)) => out.stats.push(stats),
                Ok(Err(e)) => out.errors.push(format!("{e:#}")),
                Err(_) => out.errors.push("worker panicked".to_string()),
            }
        }
        self.outcomes.close();
        out
    }
}

fn worker_main(
    w: usize,
    state: Arc<ModelState>,
    opts: PoolOpts,
    jobs: Arc<Queue<ServeJob>>,
    outcomes: Arc<Queue<ServeOutcome>>,
    ready: Arc<(Mutex<Ready>, Condvar)>,
) -> Result<WorkerStats> {
    // Per-worker engine: compile once, then serve (see module docs).  The
    // runner borrows the engine (its executables and resident prefix
    // buffers), so "engine outlives the runner" is compile-enforced and
    // the two are constructed as separate locals rather than returned
    // together.
    let (lock, cv) = &*ready;
    let fail = |e: anyhow::Error| -> anyhow::Error {
        lock.lock().unwrap().failed += 1;
        cv.notify_all();
        e
    };
    // Each worker engine gets its share of the pool's kernel-thread
    // budget (ref backend only; PJRT ignores it).
    let kernel_threads = crate::runtime::threads_per_worker(opts.ref_threads, opts.workers);
    let made = Engine::with_backend_threads(opts.backend, &opts.artifacts_dir, kernel_threads)
        .with_context(|| format!("worker {w}: creating {} engine", opts.backend.name()));
    let engine = match made {
        Ok(e) => e,
        Err(e) => return Err(fail(e)),
    };
    // Arc clone: all workers share one copy of the weights.
    let made_runner = if opts.compressed {
        StageRunner::new_compressed(&engine, state.clone(), opts.batch.max_batch)
    } else {
        StageRunner::new(&engine, state.clone(), opts.batch.max_batch)
    };
    let runner = match made_runner.with_context(|| format!("worker {w}: loading staged graphs")) {
        Ok(r) => {
            lock.lock().unwrap().ready += 1;
            cv.notify_all();
            r
        }
        Err(e) => return Err(fail(e)),
    };

    let (t1, t2) = opts.thresholds;
    let mut stats = WorkerStats { worker: w, stage_batch: runner.stage_batch(), ..Default::default() };
    // Transfer-volume snapshot on every successful exit path.
    let finish = |mut stats: WorkerStats| -> WorkerStats {
        let rs = engine.stats();
        stats.bytes_uploaded = rs.bytes_uploaded;
        stats.bytes_downloaded = rs.bytes_downloaded;
        stats
    };
    // Resolve registry handles once per worker; the loop touches only Arcs.
    let m_drains = metrics::counter("serve.batch.drains");
    let m_rows_useful = metrics::counter("serve.batch.rows_useful");
    let m_rows_executed = metrics::counter("serve.batch.rows_executed");
    loop {
        let batch = {
            // Span covers the micro-batch assembly wait (arrival gaps +
            // linger), distinct from the execute below.
            let _s = trace::span("serve.drain_batch");
            drain_batch(&jobs, &opts.batch)
        };
        if batch.is_empty() {
            break; // queue closed and drained
        }
        stats.drains += 1;
        m_drains.incr();
        stats.max_chunk = stats.max_chunk.max(batch.len());
        let (useful, executed) =
            plan_rows(&plan_chunks(batch.len(), stats.stage_batch), stats.stage_batch);
        stats.rows_useful += useful as u64;
        stats.rows_executed += executed as u64;
        m_rows_useful.add(useful as u64);
        m_rows_executed.add(executed as u64);
        let xs: Vec<&Tensor> = batch.iter().map(|j| &j.x).collect();
        let results = {
            let _s = trace::span("serve.infer_batch");
            runner.infer_many(&xs, t1, t2)
        };
        let results = match results {
            Ok(r) => r,
            Err(e) => {
                // Dying mid-run: move ourselves from `ready` to `failed`
                // so reports attribute throughput to the survivors and the
                // `ready + failed == workers` settlement invariant that
                // wait_ready blocks on stays intact.
                {
                    let mut st = lock.lock().unwrap();
                    st.ready -= 1;
                    st.failed += 1;
                }
                cv.notify_all();
                return Err(e)
                    .with_context(|| format!("worker {w}: micro-batch of {}", batch.len()));
            }
        };
        for (job, (pred, stage)) in batch.into_iter().zip(results) {
            stats.processed += 1;
            let outcome = ServeOutcome {
                id: job.id,
                pred,
                stage,
                label: job.label,
                latency_us: job.submitted.elapsed().as_micros() as f64,
                worker: w,
            };
            if outcomes.push(outcome).is_err() {
                return Ok(finish(stats)); // result side closed: shutting down
            }
        }
    }
    Ok(finish(stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_types_are_send() {
        // Compile-enforced: these cross worker-thread boundaries.
        fn assert_send<T: Send>() {}
        assert_send::<ServeJob>();
        assert_send::<ServeOutcome>();
        assert_send::<Arc<Queue<ServeJob>>>();
        assert_send::<Arc<ModelState>>();
        assert_send::<PoolOpts>();
    }

    #[test]
    fn pool_with_bad_artifacts_fails_ready_cleanly() {
        // A host-initialized state over a toy arch with no graph files:
        // every worker must fail setup and wait_ready must report that
        // instead of hanging.
        let layers = vec![crate::models::LayerDesc {
            name: "fc".into(),
            kind: crate::models::LayerKind::Dense,
            k: 1,
            cin: 4,
            cout: 2,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: -1,
            out_mask: -1,
            segment: "seg3".into(),
            input: String::new(),
            act: true,
        }];
        let arch = Arc::new(crate::models::ArchManifest {
            name: "toy".into(),
            num_classes: 2,
            layers,
            mask_slots: vec![],
            param_shapes: vec![vec![4, 2], vec![2]],
            graphs: std::collections::BTreeMap::new(),
            train_batch: 1,
            eval_batch: 1,
            stage_batch: 1,
            stage_batches: vec![1],
            stage_h1_shape: vec![1, 4],
            stage_h2_shape: vec![1, 4],
            joins: Vec::new(),
        });
        let state = Arc::new(ModelState::init_host(arch, 0));
        let pool = WorkerPool::start(
            state,
            PoolOpts::new("/nonexistent/artifacts", 2, (0.8, 0.8)),
        );
        let res = pool.wait_ready(Duration::from_secs(30));
        assert!(res.is_err(), "expected startup failure, got {res:?}");
        let outcome = pool.shutdown();
        assert_eq!(outcome.stats.len(), 0);
        assert_eq!(outcome.errors.len(), 2);
    }
}
