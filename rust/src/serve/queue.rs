//! Bounded MPMC queue with admission control — the front door of the
//! serving subsystem.
//!
//! Built on `Mutex<VecDeque>` + two `Condvar`s (the offline crate set has
//! no crossbeam); the contended section is a push/pop of one element, so a
//! mutex is fine at the request rates the micro-batched workers sustain.
//!
//! Admission control: [`Queue::try_push`] fails fast when the queue is at
//! capacity instead of letting latency grow without bound — rejected
//! requests are counted and reported by `serve::loadgen` (load shedding,
//! the standard open-loop serving discipline).  Queue depth is sampled at
//! every accepted push so the serve report can show the depth distribution
//! the worker pool actually ran at.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::{lock, wait, wait_timeout};

/// Outcome of a timed pop.
#[derive(Debug)]
pub enum Pop<T> {
    Item(T),
    /// Queue open but empty for the whole wait.
    TimedOut,
    /// Queue closed and drained — no more items will ever arrive.
    Closed,
}

/// Aggregate queue statistics for the serving report.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueueStats {
    pub accepted: u64,
    /// Failed `try_push` attempts: open-loop load shedding, plus
    /// backpressure retries when a closed-loop generator meets a full
    /// queue (the generator's own `rejected` counter excludes retries).
    pub rejected: u64,
    /// Depth observed *after* each accepted push.
    pub mean_depth: f64,
    pub max_depth: usize,
    /// Running sum behind `mean_depth` (exposed so callers can compute
    /// per-window deltas from two snapshots).
    pub depth_sum: u64,
}

impl QueueStats {
    /// Stats for the window between `start` (an earlier snapshot of the
    /// same queue) and `self`.  `max_depth` cannot be windowed from
    /// snapshots and stays the lifetime maximum.
    pub fn since(&self, start: &QueueStats) -> QueueStats {
        let accepted = self.accepted.saturating_sub(start.accepted);
        let depth_sum = self.depth_sum.saturating_sub(start.depth_sum);
        QueueStats {
            accepted,
            rejected: self.rejected.saturating_sub(start.rejected),
            mean_depth: if accepted == 0 { 0.0 } else { depth_sum as f64 / accepted as f64 },
            max_depth: self.max_depth,
            depth_sum,
        }
    }
}

struct Inner<T> {
    q: VecDeque<T>,
    closed: bool,
    accepted: u64,
    rejected: u64,
    depth_sum: u64,
    max_depth: usize,
}

pub struct Queue<T> {
    capacity: usize,
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Queue<T> {
    pub fn bounded(capacity: usize) -> Queue<T> {
        assert!(capacity > 0, "queue capacity must be positive");
        Queue {
            capacity,
            inner: Mutex::new(Inner {
                q: VecDeque::new(),
                closed: false,
                accepted: 0,
                rejected: 0,
                depth_sum: 0,
                max_depth: 0,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Effectively-unbounded variant for result fan-in (consumers drain it
    /// continuously; admission control lives on the request side).
    pub fn unbounded() -> Queue<T> {
        Queue::bounded(usize::MAX)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn record_push(inner: &mut Inner<T>) {
        inner.accepted += 1;
        let depth = inner.q.len();
        inner.depth_sum += depth as u64;
        inner.max_depth = inner.max_depth.max(depth);
    }

    /// Admission-controlled push: `Err(t)` immediately when the queue is
    /// full or closed (the item is handed back so the caller can count or
    /// retry it).
    pub fn try_push(&self, t: T) -> Result<(), T> {
        let mut inner = lock(&self.inner);
        if inner.closed {
            return Err(t);
        }
        if inner.q.len() >= self.capacity {
            inner.rejected += 1;
            return Err(t);
        }
        inner.q.push_back(t);
        Self::record_push(&mut inner);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking push: waits for space; `Err(t)` only if the queue closes
    /// while waiting.
    pub fn push(&self, t: T) -> Result<(), T> {
        let mut inner = lock(&self.inner);
        while inner.q.len() >= self.capacity && !inner.closed {
            inner = wait(&self.not_full, inner);
        }
        if inner.closed {
            return Err(t);
        }
        inner.q.push_back(t);
        Self::record_push(&mut inner);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = lock(&self.inner);
        loop {
            if let Some(t) = inner.q.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Some(t);
            }
            if inner.closed {
                return None;
            }
            inner = wait(&self.not_empty, inner);
        }
    }

    /// Pop with a deadline, for micro-batch accumulation.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let deadline = Instant::now() + timeout;
        let mut inner = lock(&self.inner);
        loop {
            if let Some(t) = inner.q.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Pop::Item(t);
            }
            if inner.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, timed_out) = wait_timeout(&self.not_empty, inner, deadline - now);
            inner = guard;
            if timed_out && inner.q.is_empty() {
                return if inner.closed { Pop::Closed } else { Pop::TimedOut };
            }
        }
    }

    /// Close the queue: pending items stay poppable, new pushes fail, and
    /// blocked poppers wake up.
    pub fn close(&self) {
        lock(&self.inner).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        lock(&self.inner).closed
    }

    pub fn len(&self) -> usize {
        lock(&self.inner).q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> QueueStats {
        let inner = lock(&self.inner);
        QueueStats {
            accepted: inner.accepted,
            rejected: inner.rejected,
            mean_depth: if inner.accepted == 0 {
                0.0
            } else {
                inner.depth_sum as f64 / inner.accepted as f64
            },
            max_depth: inner.max_depth,
            depth_sum: inner.depth_sum,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_order_single_thread() {
        let q = Queue::bounded(4);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn admission_control_rejects_when_full() {
        let q = Queue::bounded(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.stats().rejected, 1);
        assert_eq!(q.stats().accepted, 2);
        q.pop().unwrap();
        q.try_push(3).unwrap();
        assert_eq!(q.stats().accepted, 3);
    }

    #[test]
    fn close_wakes_poppers_and_drains() {
        let q: Arc<Queue<u32>> = Arc::new(Queue::bounded(8));
        q.try_push(7).unwrap();
        let qc = q.clone();
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while let Some(v) = qc.pop() {
                got.push(v);
            }
            got
        });
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), vec![7]);
        assert_eq!(q.try_push(9), Err(9));
    }

    #[test]
    fn pop_timeout_times_out_then_delivers() {
        let q: Arc<Queue<u32>> = Arc::new(Queue::bounded(8));
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Pop::TimedOut));
        let qc = q.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            qc.try_push(42).unwrap();
        });
        match q.pop_timeout(Duration::from_secs(5)) {
            Pop::Item(v) => assert_eq!(v, 42),
            other => panic!("expected item, got {other:?}"),
        }
        h.join().unwrap();
    }

    #[test]
    fn mpmc_preserves_every_item() {
        let q: Arc<Queue<u64>> = Arc::new(Queue::bounded(16));
        let producers = 4;
        let per_producer = 500u64;
        let consumers = 3;
        let mut handles = Vec::new();
        for p in 0..producers {
            let qc = q.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    // Blocking push: every item must eventually land.
                    qc.push(p * per_producer + i).unwrap();
                }
            }));
        }
        let mut sums = Vec::new();
        for _ in 0..consumers {
            let qc = q.clone();
            sums.push(std::thread::spawn(move || {
                let mut s = 0u64;
                while let Some(v) = qc.pop() {
                    s += v;
                }
                s
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let total: u64 = sums.into_iter().map(|h| h.join().unwrap()).sum();
        let n = producers * per_producer;
        assert_eq!(total, n * (n - 1) / 2);
        let st = q.stats();
        assert_eq!(st.accepted, n);
        assert!(st.max_depth <= 16);
    }

    #[test]
    fn depth_stats_tracked() {
        let q = Queue::bounded(8);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        let st = q.stats();
        assert_eq!(st.max_depth, 4);
        // Depth after pushes 1..=4 is 1,2,3,4 -> mean 2.5.
        assert!((st.mean_depth - 2.5).abs() < 1e-9);
    }

    #[test]
    fn stats_since_windows_a_second_run() {
        let q = Queue::bounded(8);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        let first = q.stats();
        for _ in 0..4 {
            q.pop().unwrap();
        }
        // Second "run": 2 pushes at depths 1, 2.
        q.try_push(9).unwrap();
        q.try_push(9).unwrap();
        let windowed = q.stats().since(&first);
        assert_eq!(windowed.accepted, 2);
        assert_eq!(windowed.rejected, 0);
        assert!((windowed.mean_depth - 1.5).abs() < 1e-9);
    }
}
