//! Load generation against a [`WorkerPool`]: open-loop (Poisson arrivals
//! at a target rate, with admission-control shedding) and closed-loop (a
//! fixed concurrency window, the classic saturation probe).
//!
//! Open loop measures what users experience at a given offered rate —
//! queueing delay shows up in the latency tail and overload shows up as
//! shed requests, not as a silently slowed generator.  Closed loop
//! measures capacity: the sustained requests/sec at a given concurrency.
//! Both report per-request latency (p50/p95/p99), the exit distribution,
//! accuracy against ground-truth labels, goodput under the SLO, and the
//! request-queue depth distribution.
//!
//! The whole request stream is precomputed by [`arrival_schedule`] as a
//! pure function of (mode, requests, seed, dataset size): same seed ⇒
//! identical request indices and inter-arrival gaps, so two runs differ
//! only in wall-clock measurements.  On a deterministic backend the
//! deterministic half of the report (accuracy, exit distribution,
//! completion accounting) is bit-identical across same-seed runs —
//! `rust/tests/serve_concurrency.rs` pins this on the ref backend.

use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use super::queue::{Pop, QueueStats};
use super::slo::{self, Slo, SloReport};
use super::worker::{OutcomeStatus, ServeJob, ServeOutcome, WorkerPool};
use crate::data::Dataset;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;
use crate::util::stats::Summary;

#[derive(Debug, Clone, Copy)]
pub enum LoadMode {
    /// Arrivals from a Poisson process at `rate_rps`, shed when the queue
    /// is full.
    Open { rate_rps: f64 },
    /// `concurrency` requests kept in flight at all times.
    Closed { concurrency: usize },
}

impl LoadMode {
    pub fn name(&self) -> &'static str {
        match self {
            LoadMode::Open { .. } => "open",
            LoadMode::Closed { .. } => "closed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct LoadOpts {
    pub mode: LoadMode,
    pub requests: usize,
    pub seed: u64,
    pub slo: Slo,
    /// Give up waiting for stragglers after this much silence (covers
    /// worker death without hanging the bench).
    pub drain_timeout: Duration,
}

impl Default for LoadOpts {
    fn default() -> Self {
        LoadOpts {
            mode: LoadMode::Closed { concurrency: 16 },
            requests: 1000,
            seed: 42,
            slo: Slo::default(),
            drain_timeout: Duration::from_secs(30),
        }
    }
}

/// One planned request: which dataset sample, and how long after the
/// previous arrival it enters the system (0 in closed loop, where the
/// concurrency window — not time — paces admissions).
#[derive(Debug, Clone, PartialEq)]
pub struct Arrival {
    pub index: usize,
    pub gap_secs: f64,
}

/// The full request stream as a pure function of (mode, requests, seed,
/// dataset size).  Open-loop gaps are Exp(rate) draws (Poisson process);
/// closed-loop schedules carry indices only.
pub fn arrival_schedule(
    mode: &LoadMode,
    requests: usize,
    seed: u64,
    ds_len: usize,
) -> Vec<Arrival> {
    let mut rng = Rng::new(seed ^ 0x10adc0de);
    (0..requests)
        .map(|_| {
            let index = rng.below(ds_len.max(1));
            let gap_secs = match mode {
                LoadMode::Open { rate_rps } => {
                    let u = (rng.f32() as f64).max(1e-7);
                    -u.ln() / rate_rps.max(1e-3)
                }
                LoadMode::Closed { .. } => 0.0,
            };
            Arrival { index, gap_secs }
        })
        .collect()
}

/// Everything one load run measured.
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub mode: String,
    /// Workers that were actually alive for the run (not the configured
    /// pool size — see `WorkerPool::live_workers`).
    pub workers: usize,
    pub offered: usize,
    pub accepted: usize,
    pub rejected: usize,
    pub completed: usize,
    /// Deadline-expired requests shed with a terminal `Timeout` outcome.
    pub timed_out: usize,
    /// Requests whose batch died (panic/error) — terminal `Failed`.
    pub failed: usize,
    /// Accepted but never reached *any* terminal outcome (should be 0:
    /// the terminal-outcome accounting invariant).
    pub lost: usize,
    pub accuracy: f64,
    pub p_exit1: f64,
    pub p_exit2: f64,
    pub latency_us: Summary,
    pub wall_secs: f64,
    /// Completed requests per second of wall time.
    pub throughput_rps: f64,
    pub queue: QueueStats,
    pub slo: SloReport,
}

impl BenchReport {
    pub fn to_json(&self) -> Json {
        let lat = obj(vec![
            ("count", num(self.latency_us.len() as f64)),
            ("mean_us", num(self.latency_us.mean())),
            ("p50_us", num(self.latency_us.p50())),
            ("p95_us", num(self.latency_us.p95())),
            ("p99_us", num(self.latency_us.p99())),
            ("min_us", num(self.latency_us.min())),
            ("max_us", num(self.latency_us.max())),
        ]);
        let queue = obj(vec![
            ("accepted", num(self.queue.accepted as f64)),
            ("rejected", num(self.queue.rejected as f64)),
            ("mean_depth", num(self.queue.mean_depth)),
            ("max_depth", num(self.queue.max_depth as f64)),
        ]);
        let slo = obj(vec![
            ("latency_ms", num(self.slo.slo_ms)),
            ("attained", num(self.slo.attained as f64)),
            ("attainment", num(self.slo.attainment)),
            ("goodput_rps", num(self.slo.goodput_rps)),
        ]);
        obj(vec![
            ("mode", s(&self.mode)),
            ("workers", num(self.workers as f64)),
            ("offered", num(self.offered as f64)),
            ("accepted", num(self.accepted as f64)),
            ("rejected", num(self.rejected as f64)),
            ("completed", num(self.completed as f64)),
            ("timed_out", num(self.timed_out as f64)),
            ("failed", num(self.failed as f64)),
            ("lost", num(self.lost as f64)),
            ("accuracy", num(self.accuracy)),
            ("p_exit1", num(self.p_exit1)),
            ("p_exit2", num(self.p_exit2)),
            ("wall_secs", num(self.wall_secs)),
            ("throughput_rps", num(self.throughput_rps)),
            ("latency", lat),
            ("queue", queue),
            ("slo", slo),
        ])
    }

    pub fn summary_line(&self) -> String {
        format!(
            "{} load, {} workers: {}/{} ok ({} shed, {} timed out, {} failed, {} lost)  \
             acc {:.2}%  exit1 {:.0}% exit2 {:.0}%  \
             p50 {:.0}µs p95 {:.0}µs p99 {:.0}µs  {:.0} rps  goodput {:.0} rps @ {:.0}ms SLO  \
             queue depth mean {:.1} max {}",
            self.mode,
            self.workers,
            self.completed,
            self.offered,
            self.rejected,
            self.timed_out,
            self.failed,
            self.lost,
            self.accuracy * 100.0,
            self.p_exit1 * 100.0,
            self.p_exit2 * 100.0,
            self.latency_us.p50(),
            self.latency_us.p95(),
            self.latency_us.p99(),
            self.throughput_rps,
            self.slo.goodput_rps,
            self.slo.slo_ms,
            self.queue.mean_depth,
            self.queue.max_depth,
        )
    }
}

struct Recorder {
    latency_us: Summary,
    completed: usize,
    timed_out: usize,
    failed: usize,
    correct: usize,
    labelled: usize,
    n1: usize,
    n2: usize,
}

impl Recorder {
    fn new() -> Recorder {
        // Bounded summary: open-loop soaks record one latency per request
        // for the whole run — the exact representation grows without bound
        // at high rates, the histogram-backed one is O(1).
        Recorder {
            latency_us: Summary::bounded(),
            completed: 0,
            timed_out: 0,
            failed: 0,
            correct: 0,
            labelled: 0,
            n1: 0,
            n2: 0,
        }
    }

    fn record(&mut self, o: &ServeOutcome) {
        match o.status {
            OutcomeStatus::Done => {
                self.completed += 1;
                self.latency_us.push(o.latency_us);
                if let Some(label) = o.label {
                    self.labelled += 1;
                    self.correct += (o.pred == label) as usize;
                }
                match o.stage {
                    1 => self.n1 += 1,
                    2 => self.n2 += 1,
                    _ => {}
                }
            }
            OutcomeStatus::Timeout => self.timed_out += 1,
            OutcomeStatus::Failed => self.failed += 1,
        }
    }

    /// Requests that reached any terminal outcome.
    fn terminal(&self) -> usize {
        self.completed + self.timed_out + self.failed
    }
}

/// Drive `opts.requests` requests drawn from `ds` through the pool.
/// Call after `pool.wait_ready(..)` so compile time doesn't pollute the
/// measurement.
pub fn run(pool: &WorkerPool, ds: &Dataset, opts: &LoadOpts) -> Result<BenchReport> {
    if ds.is_empty() {
        return Err(anyhow!("load generation needs a non-empty dataset"));
    }
    let schedule = arrival_schedule(&opts.mode, opts.requests, opts.seed, ds.len());
    let mut rec = Recorder::new();
    let mut accepted = 0usize;
    let mut rejected = 0usize;
    // Reports must be per-run even on a reused pool (benches warm up on
    // the same pool): window the queue stats between two snapshots, and
    // discard stale outcomes a previous run gave up waiting for — counting
    // them here would underflow this run's in-flight accounting.
    let queue_start = pool.queue_stats();
    while let Pop::Item(_) = pool.outcomes().pop_timeout(Duration::ZERO) {}
    let mut gave_up = false;
    let start = Instant::now();

    match opts.mode {
        LoadMode::Open { .. } => {
            let mut next = Instant::now();
            for (r, a) in schedule.iter().enumerate() {
                let (x, _) = ds.batch(&[a.index]);
                let now = Instant::now();
                if next > now {
                    std::thread::sleep(next - now);
                }
                let job = ServeJob::new(r as u64, x, Some(ds.labels[a.index]));
                if pool.try_submit(job).is_ok() {
                    accepted += 1;
                } else {
                    rejected += 1;
                }
                next += Duration::from_secs_f64(a.gap_secs);
                // Drain completed results opportunistically so the outcome
                // queue stays small at high rates.
                while let Pop::Item(o) = pool.outcomes().pop_timeout(Duration::ZERO) {
                    rec.record(&o);
                }
            }
        }
        LoadMode::Closed { concurrency } => {
            let window = concurrency.max(1);
            let mut submitted = 0usize;
            let mut in_flight = 0usize;
            'run: while submitted < opts.requests || in_flight > 0 {
                while in_flight < window && submitted < opts.requests {
                    let i = schedule[submitted].index;
                    let (x, _) = ds.batch(&[i]);
                    let mut job = ServeJob::new(submitted as u64, x, Some(ds.labels[i]));
                    // Never block on a full queue without a timeout: if the
                    // queue is full (window > capacity, or workers dead),
                    // make room by consuming an outcome first — a silent
                    // pool here means the workers are gone.
                    loop {
                        match pool.try_submit(job) {
                            Ok(()) => {
                                submitted += 1;
                                accepted += 1;
                                in_flight += 1;
                                break;
                            }
                            Err(j) => {
                                job = j;
                                match pool.outcomes().pop_timeout(opts.drain_timeout) {
                                    Pop::Item(o) => {
                                        rec.record(&o);
                                        in_flight = in_flight.saturating_sub(1);
                                    }
                                    Pop::TimedOut => {
                                        crate::obs::log!(
                                            crate::obs::Level::Warn,
                                            "[loadgen] queue full and pool silent for {:?} — workers dead?",
                                            opts.drain_timeout
                                        );
                                        gave_up = true;
                                        break 'run;
                                    }
                                    Pop::Closed => break 'run,
                                }
                            }
                        }
                    }
                }
                if in_flight == 0 {
                    continue;
                }
                match pool.outcomes().pop_timeout(opts.drain_timeout) {
                    Pop::Item(o) => {
                        rec.record(&o);
                        in_flight = in_flight.saturating_sub(1);
                    }
                    Pop::TimedOut => {
                        crate::obs::log!(
                            crate::obs::Level::Warn,
                            "[loadgen] {in_flight} requests silent for {:?} — workers dead?",
                            opts.drain_timeout
                        );
                        gave_up = true;
                        break;
                    }
                    Pop::Closed => break,
                }
            }
        }
    }

    // Drain stragglers (open loop; closed loop exits drained, and after a
    // timeout there is no point waiting the full window a second time).
    while !gave_up && rec.terminal() < accepted {
        match pool.outcomes().pop_timeout(opts.drain_timeout) {
            Pop::Item(o) => rec.record(&o),
            Pop::TimedOut => {
                crate::obs::log!(
                    crate::obs::Level::Warn,
                    "[loadgen] gave up on {} in-flight requests after {:?}",
                    accepted - rec.terminal(),
                    opts.drain_timeout
                );
                break;
            }
            Pop::Closed => break,
        }
    }

    let wall_secs = start.elapsed().as_secs_f64();
    let lost = accepted.saturating_sub(rec.terminal());
    // Requests that never produced a served answer — shed, timed out,
    // failed, or lost — all violate the SLO alike (see slo::report).
    let unserved = rejected + lost + rec.timed_out + rec.failed;
    let slo_report = slo::report(&rec.latency_us, unserved, wall_secs, opts.slo);
    Ok(BenchReport {
        mode: opts.mode.name().to_string(),
        workers: pool.live_workers(),
        offered: opts.requests,
        accepted,
        rejected,
        completed: rec.completed,
        timed_out: rec.timed_out,
        failed: rec.failed,
        lost,
        accuracy: if rec.labelled == 0 { 0.0 } else { rec.correct as f64 / rec.labelled as f64 },
        p_exit1: if rec.completed == 0 { 0.0 } else { rec.n1 as f64 / rec.completed as f64 },
        p_exit2: if rec.completed == 0 { 0.0 } else { rec.n2 as f64 / rec.completed as f64 },
        latency_us: rec.latency_us,
        wall_secs,
        throughput_rps: rec.completed as f64 / wall_secs.max(1e-9),
        queue: pool.queue_stats().since(&queue_start),
        slo: slo_report,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ref_arrival_schedule_is_a_pure_function_of_the_seed() {
        for mode in [LoadMode::Closed { concurrency: 8 }, LoadMode::Open { rate_rps: 250.0 }] {
            let a = arrival_schedule(&mode, 200, 42, 48);
            let b = arrival_schedule(&mode, 200, 42, 48);
            assert_eq!(a, b, "same seed must yield an identical schedule");
            let c = arrival_schedule(&mode, 200, 43, 48);
            assert_ne!(a, c, "different seeds must decorrelate the stream");
            assert!(a.iter().all(|x| x.index < 48));
            match mode {
                LoadMode::Closed { .. } => assert!(a.iter().all(|x| x.gap_secs == 0.0)),
                LoadMode::Open { .. } => {
                    assert!(a.iter().all(|x| x.gap_secs > 0.0));
                    // Mean inter-arrival ~ 1/rate (loose 3x band).
                    let mean = a.iter().map(|x| x.gap_secs).sum::<f64>() / a.len() as f64;
                    assert!(mean > 1.0 / 750.0 && mean < 3.0 / 250.0, "mean gap {mean}");
                }
            }
        }
    }

    #[test]
    fn report_json_has_the_headline_fields() {
        let mut lat = Summary::default();
        for i in 0..100 {
            lat.push(1000.0 + i as f64);
        }
        let slo_rep = slo::report(&lat, 5, 2.0, Slo { latency_ms: 50.0 });
        let rep = BenchReport {
            mode: "open".into(),
            workers: 4,
            offered: 105,
            accepted: 100,
            rejected: 5,
            completed: 100,
            timed_out: 0,
            failed: 0,
            lost: 0,
            accuracy: 0.9,
            p_exit1: 0.5,
            p_exit2: 0.2,
            latency_us: lat,
            wall_secs: 2.0,
            throughput_rps: 50.0,
            queue: QueueStats {
                accepted: 100,
                rejected: 5,
                mean_depth: 1.5,
                max_depth: 7,
                depth_sum: 150,
            },
            slo: slo_rep,
        };
        let j = rep.to_json();
        let txt = j.to_string();
        for key in [
            "\"mode\"", "\"workers\"", "\"p50_us\"", "\"p95_us\"", "\"p99_us\"",
            "\"goodput_rps\"", "\"mean_depth\"", "\"max_depth\"", "\"rejected\"", "\"accuracy\"",
        ] {
            assert!(txt.contains(key), "missing {key} in {txt}");
        }
        // Round-trip through the parser.
        let parsed = Json::parse(&txt).unwrap();
        assert_eq!(parsed.req("workers").unwrap().as_usize(), Some(4));
        assert_eq!(
            parsed.req("queue").unwrap().req("max_depth").unwrap().as_usize(),
            Some(7)
        );
        assert!(rep.summary_line().contains("4 workers"));
    }
}
