//! Measurement bookkeeping: the (accuracy, BitOpsCR, CR) triples every
//! experiment reports, in the paper's units.

use anyhow::{anyhow, Result};

use crate::data::Dataset;
use crate::exits;
use crate::models::{Accountant, ModelState};
use crate::runtime::Engine;
use crate::util::json::{num, obj, Json};

/// One measured point: what every scatter plot / table row is made of.
///
/// `PartialEq` is exact f64 equality on purpose: the plan cache's replay
/// guarantee is *bit-identical*, not approximate, and the equivalence
/// tests assert it.
#[derive(Debug, Clone, PartialEq)]
pub struct Measurement {
    pub accuracy: f64,
    pub bitops_cr: f64,
    pub storage_cr: f64,
    pub bitops: f64,
    pub storage_bits: f64,
    /// Exit distribution at measurement time (0, 0 if exits unused).
    pub exit_probs: (f64, f64),
}

impl Measurement {
    /// Measure the state on the given dataset.  If exits are trained and
    /// thresholds set, accuracy and BitOps use the early-exit policy;
    /// otherwise the main head.
    pub fn take(engine: &Engine, state: &ModelState, test: &Dataset) -> Result<Measurement> {
        let state = &mut state.clone();
        let accuracy = if state.exits.trained && state.exits.thresholds.is_some() {
            let (t1, t2) = state.exits.thresholds.unwrap();
            let ev = exits::evaluate(engine, state, test, t1, t2)?;
            state.exits.exit_probs = (ev.p_exit1, ev.p_exit2);
            ev.accuracy
        } else {
            crate::train::eval_accuracy(engine, state, test)?
        };
        let acct = Accountant::new(state);
        Ok(Measurement {
            accuracy,
            bitops_cr: acct.bitops_cr(),
            storage_cr: acct.storage_cr(),
            bitops: acct.expected_bitops(),
            storage_bits: acct.storage_bits(),
            exit_probs: state.exits.exit_probs,
        })
    }

    pub fn as_point(&self) -> (f64, f64) {
        (self.bitops_cr, self.accuracy)
    }

    /// Sidecar form for the plan cache.  The JSON writer emits the
    /// shortest round-trippable decimal for every f64, so
    /// `from_json(parse(to_json())) == self` bit-for-bit.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("accuracy", num(self.accuracy)),
            ("bitops_cr", num(self.bitops_cr)),
            ("storage_cr", num(self.storage_cr)),
            ("bitops", num(self.bitops)),
            ("storage_bits", num(self.storage_bits)),
            ("p_exit1", num(self.exit_probs.0)),
            ("p_exit2", num(self.exit_probs.1)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Measurement> {
        let f = |key: &str| -> Result<f64> {
            j.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow!("measurement field `{key}` is not a number"))
        };
        Ok(Measurement {
            accuracy: f("accuracy")?,
            bitops_cr: f("bitops_cr")?,
            storage_cr: f("storage_cr")?,
            bitops: f("bitops")?,
            storage_bits: f("storage_bits")?,
            exit_probs: (f("p_exit1")?, f("p_exit2")?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_is_bit_identical() {
        // Awkward values: non-terminating binary fractions, integers, a
        // subnormal, and an exactly-representable large count.
        let m = Measurement {
            accuracy: 1.0 / 3.0,
            bitops_cr: 317.2894561230001,
            storage_cr: 64.0,
            bitops: 9.87654321e12,
            storage_bits: f64::MIN_POSITIVE,
            exit_probs: (0.1 + 0.2, 0.0),
        };
        let text = m.to_json().to_string();
        let back = Measurement::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(m, back);

        // And through a second generation, to catch any canonicalization.
        let text2 = back.to_json().to_string();
        assert_eq!(text, text2);
    }

    #[test]
    fn from_json_rejects_missing_fields() {
        let j = Json::parse(r#"{"accuracy": 0.5}"#).unwrap();
        assert!(Measurement::from_json(&j).is_err());
    }
}
