//! Measurement bookkeeping: the (accuracy, BitOpsCR, CR) triples every
//! experiment reports, in the paper's units.

use anyhow::Result;

use crate::data::Dataset;
use crate::exits;
use crate::models::{Accountant, ModelState};
use crate::runtime::Engine;

/// One measured point: what every scatter plot / table row is made of.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub accuracy: f64,
    pub bitops_cr: f64,
    pub storage_cr: f64,
    pub bitops: f64,
    pub storage_bits: f64,
    /// Exit distribution at measurement time (0, 0 if exits unused).
    pub exit_probs: (f64, f64),
}

impl Measurement {
    /// Measure the state on the given dataset.  If exits are trained and
    /// thresholds set, accuracy and BitOps use the early-exit policy;
    /// otherwise the main head.
    pub fn take(engine: &Engine, state: &ModelState, test: &Dataset) -> Result<Measurement> {
        let state = &mut state.clone();
        let accuracy = if state.exits.trained && state.exits.thresholds.is_some() {
            let (t1, t2) = state.exits.thresholds.unwrap();
            let ev = exits::evaluate(engine, state, test, t1, t2)?;
            state.exits.exit_probs = (ev.p_exit1, ev.p_exit2);
            ev.accuracy
        } else {
            crate::train::eval_accuracy(engine, state, test)?
        };
        let acct = Accountant::new(state);
        Ok(Measurement {
            accuracy,
            bitops_cr: acct.bitops_cr(),
            storage_cr: acct.storage_cr(),
            bitops: acct.expected_bitops(),
            storage_bits: acct.storage_bits(),
            exit_probs: state.exits.exit_probs,
        })
    }

    pub fn as_point(&self) -> (f64, f64) {
        (self.bitops_cr, self.accuracy)
    }
}
