//! Experiment drivers: one per paper table/figure (see DESIGN.md
//! experiment index).  Every driver writes `results/<id>.csv` plus a
//! console summary in the paper's own terms, and returns the written rows
//! for composition (fig13/fig15 reuse table runs).
//!
//! Drivers never run chains imperatively: they *submit* chains to a
//! [`Planner`] (`chain::plan`) and call [`ExpCtx::run_plan`], which
//! dedupes shared stage prefixes, replays cached nodes from
//! `results/cache/`, fans independent branches out over `--jobs` worker
//! engines, and appends per-run accounting to `results/plan_stats.csv`.

use anyhow::{anyhow, Result};

use crate::chain::plan::{EngineRunner, ExecOpts, PlanKey, PlanRun, Planner};
use crate::chain::{Chain, StageCtx, Technique};
use crate::data::{Dataset, DatasetKind};
use crate::metrics::Measurement;
use crate::models::{Manifest, ModelState};
use crate::order::{self, Preference, PreferenceGraph, SortOutcome};
use crate::report::Reporter;
use crate::runtime::{BackendChoice, Engine};
use crate::sweep::{self, Scale, SweepPoint};
use crate::train::{self, TrainOpts};
use crate::util::stats;

pub struct ExpCtx {
    pub engine: Engine,
    pub manifest: Manifest,
    pub scale: Scale,
    pub seed: u64,
    pub reporter: Reporter,
    pub verbose: bool,
    /// Plan-executor worker threads (1 = serial on the main engine).
    pub jobs: usize,
    /// Snapshot/replay plan nodes under `results/cache/` (`--no-cache`
    /// turns this off).
    pub cache: bool,
    /// Execution backend (`--backend pjrt|ref`); worker engines (plan
    /// `--jobs`, serve pools) are built on the same backend.
    pub backend: BackendChoice,
    /// Total ref-backend kernel thread budget (`--ref-threads`; default:
    /// available parallelism).  The main engine uses the full budget;
    /// plan `--jobs` worker engines and serve pools split it
    /// (`runtime::threads_per_worker`) so worker threads and kernel
    /// threads compose without oversubscription.  Never changes results:
    /// the ref backend is thread-count invariant by contract.
    pub ref_threads: usize,
    /// Lower every distinct plan leaf to its packed `CompressedModel`
    /// after execution (`--lower`): logs packed-vs-dense bytes and, when
    /// caching, publishes `<node_id>.cmp` next to the state snapshots.
    pub lower: bool,
}

impl ExpCtx {
    pub fn new(artifacts: &str, out: &str, scale: Scale, seed: u64, verbose: bool) -> Result<ExpCtx> {
        Self::with_backend(BackendChoice::Pjrt, artifacts, out, scale, seed, verbose)
    }

    /// Like [`ExpCtx::new`] with an explicit backend.  On the reference
    /// backend a missing `artifacts/manifest.json` falls back to the
    /// built-in mini_vgg manifest (`models::builtin_ref_manifest`) so the
    /// whole CLI works hermetically.
    pub fn with_backend(
        backend: BackendChoice,
        artifacts: &str,
        out: &str,
        scale: Scale,
        seed: u64,
        verbose: bool,
    ) -> Result<ExpCtx> {
        Self::with_backend_threads(
            backend,
            artifacts,
            out,
            scale,
            seed,
            verbose,
            crate::runtime::default_ref_threads(),
        )
    }

    /// Like [`ExpCtx::with_backend`] with an explicit ref-backend kernel
    /// thread budget (the `--ref-threads` CLI path).
    pub fn with_backend_threads(
        backend: BackendChoice,
        artifacts: &str,
        out: &str,
        scale: Scale,
        seed: u64,
        verbose: bool,
        ref_threads: usize,
    ) -> Result<ExpCtx> {
        // The built-in manifest substitutes only for a genuinely *absent*
        // manifest (and only on the ref backend), and says so: a present
        // but corrupt manifest.json must fail loudly, never silently run
        // the wrong model.
        let manifest_path = std::path::Path::new(artifacts).join("manifest.json");
        let manifest = if backend == BackendChoice::Ref && !manifest_path.exists() {
            crate::obs::log!(
                crate::obs::Level::Warn,
                "[exp] no {} — using the built-in ref manifest (mini_vgg)",
                manifest_path.display()
            );
            crate::models::builtin_ref_manifest()
        } else {
            Manifest::load(artifacts)?
        };
        let ref_threads = ref_threads.max(1);
        Ok(ExpCtx {
            engine: Engine::with_backend_threads(backend, artifacts, ref_threads)?,
            manifest,
            scale,
            seed,
            reporter: Reporter::new(out)?,
            verbose,
            jobs: 1,
            cache: true,
            backend,
            lower: false,
            ref_threads,
        })
    }

    pub fn datasets(&self, kind: DatasetKind) -> (Dataset, Dataset) {
        let (ntr, nte) = self.scale.dataset_sizes();
        (
            Dataset::generate(kind, ntr, self.seed, 0),
            Dataset::generate(kind, nte, self.seed, 1),
        )
    }

    /// Pretrained fp32 base model for (arch, dataset) — cached on disk so
    /// every experiment shares the same teacher (~the paper's "original
    /// model").
    pub fn base_model(
        &self,
        arch_name: &str,
        kind: DatasetKind,
        train_ds: &Dataset,
    ) -> Result<ModelState> {
        let arch = self.manifest.arch(arch_name)?;
        let cache = self.reporter.path(&format!(
            "cache/{arch_name}_{}_{}_s{}.state",
            kind.name(),
            self.scale.name(),
            self.seed
        ));
        if cache.exists() {
            if let Ok(st) = ModelState::load(&cache, arch.clone()) {
                return Ok(st);
            }
        }
        let mut st = train::init_state(&self.engine, arch, self.seed)?;
        let opts = TrainOpts {
            steps: self.scale.base_steps() * 3 / 2,
            seed: self.seed,
            log_every: if self.verbose { 50 } else { 0 },
            ..Default::default()
        };
        train::train(&self.engine, &mut st, train_ds, None, &opts)?;
        st.save(&cache)?;
        Ok(st)
    }

    pub fn stage_ctx<'a>(&'a self, train_ds: &'a Dataset, test_ds: &'a Dataset) -> StageCtx<'a> {
        StageCtx {
            engine: &self.engine,
            train: train_ds,
            test: test_ds,
            base_steps: self.scale.base_steps(),
            seed: self.seed,
            verbose: self.verbose,
        }
    }

    /// Fresh planner rooted at this context's (arch, dataset, scale,
    /// training budget, seed).
    pub fn planner(&self, arch_name: &str, kind: DatasetKind) -> Planner {
        Planner::new(PlanKey {
            arch: arch_name.to_string(),
            dataset: kind.name().to_string(),
            scale: self.scale.name().to_string(),
            base_steps: self.scale.base_steps(),
            seed: self.seed,
        })
    }

    /// Execute a plan under this context's `--jobs` / `--no-cache`
    /// settings and append the run's cache accounting to
    /// `results/plan_stats.csv`.  Includes runtime-threshold extras in
    /// `run.points` for trained-exit chains.
    pub fn run_plan(
        &self,
        exp_id: &str,
        plan: &Planner,
        base: &ModelState,
        train_ds: &Dataset,
        test_ds: &Dataset,
    ) -> Result<PlanRun> {
        self.run_plan_impl(exp_id, plan, base, train_ds, test_ds, true)
    }

    /// Like [`ExpCtx::run_plan`] but skips the per-leaf threshold-sweep
    /// eval — for drivers that only read `run.outcomes`.
    pub fn run_plan_reports(
        &self,
        exp_id: &str,
        plan: &Planner,
        base: &ModelState,
        train_ds: &Dataset,
        test_ds: &Dataset,
    ) -> Result<PlanRun> {
        self.run_plan_impl(exp_id, plan, base, train_ds, test_ds, false)
    }

    fn run_plan_impl(
        &self,
        exp_id: &str,
        plan: &Planner,
        base: &ModelState,
        train_ds: &Dataset,
        test_ds: &Dataset,
        extras: bool,
    ) -> Result<PlanRun> {
        let runner = EngineRunner::new(
            &self.engine,
            train_ds,
            test_ds,
            self.scale.base_steps(),
            self.seed,
            self.verbose,
        );
        let opts = ExecOpts {
            jobs: self.jobs,
            cache_dir: self.cache.then(|| self.reporter.path("cache")),
            extras,
            verbose: self.verbose,
            lower: self.lower,
            ..Default::default()
        };
        let artifacts = self.engine.artifacts_dir().to_path_buf();
        let backend = self.backend;
        let (base_steps, seed, verbose) = (self.scale.base_steps(), self.seed, self.verbose);
        // One engine per plan worker thread (engines are per-thread on
        // every backend), same pattern as serve::worker; each worker
        // engine gets its share of the kernel-thread budget so `--jobs`
        // and `--ref-threads` compose without oversubscription.
        let worker_threads = crate::runtime::threads_per_worker(self.ref_threads, self.jobs);
        let run = plan.execute(base, &runner, &opts, || {
            match Engine::with_backend_threads(backend, &artifacts, worker_threads) {
                Ok(engine) => {
                    Ok(EngineRunner::new(engine, train_ds, test_ds, base_steps, seed, verbose))
                }
                Err(e) => Err(e),
            }
        })?;
        if !run.failures.is_empty() {
            // Experiment drivers need every submitted chain: surface the
            // quarantine report as the run error.  Everything that did
            // complete is cached, so a rerun resumes from here.
            let f = &run.failures[0];
            return Err(anyhow::anyhow!(
                "plan quarantined {} node(s); first: {} ({}) cutting chains [{}]: {} \
                 — completed nodes are cached, rerun to resume",
                run.failures.len(),
                f.node,
                f.stage,
                f.chains.join(","),
                f.error
            ));
        }
        let st = &run.stats;
        self.reporter.append_row(
            "plan_stats.csv",
            &[
                "experiment",
                "chains",
                "stage_applications",
                "unique_nodes",
                "cache_hits",
                "executed",
                "jobs",
                "wall_ms",
                "bytes_uploaded",
                "bytes_downloaded",
            ],
            &[
                exp_id.to_string(),
                st.chains.to_string(),
                st.total_stages.to_string(),
                st.unique_nodes.to_string(),
                st.cache_hits.to_string(),
                st.executed.to_string(),
                self.jobs.to_string(),
                format!("{:.1}", st.wall_ms),
                st.bytes_uploaded.to_string(),
                st.bytes_downloaded.to_string(),
            ],
        )?;
        Ok(run)
    }
}

/// The six pairwise figures.  fig6=(D,P) ... fig11=(Q,E); `first` is the
/// paper's winning order for the pair.
pub fn pair_for_fig(fig: usize) -> Option<(Technique, Technique)> {
    use Technique::*;
    match fig {
        6 => Some((Distill, Prune)),
        7 => Some((Distill, Quantize)),
        8 => Some((Distill, EarlyExit)),
        9 => Some((Prune, Quantize)),
        10 => Some((Prune, EarlyExit)),
        11 => Some((Quantize, EarlyExit)),
        _ => None,
    }
}

/// figs 6-11: singles + both orders of the pair, on MiniResNet / SynthC10
/// (the paper's §3 testbed: ResNet34 / CIFAR10).
pub fn run_pair_fig(ctx: &ExpCtx, fig: usize) -> Result<Vec<SweepPoint>> {
    let (a, b) = pair_for_fig(fig).ok_or_else(|| anyhow!("fig{fig} is not a pairwise figure"))?;
    let (train_ds, test_ds) = ctx.datasets(DatasetKind::SynthC10);
    let base = ctx.base_model("mini_resnet", DatasetKind::SynthC10, &train_ds)?;
    let ladder = ctx.scale.ladder();

    let mut plan = ctx.planner("mini_resnet", DatasetKind::SynthC10);
    sweep::submit_single(&mut plan, a, ladder);
    sweep::submit_single(&mut plan, b, ladder);
    sweep::submit_pairwise(&mut plan, a, b, ladder);
    sweep::submit_pairwise(&mut plan, b, a, ladder);
    let mut points =
        ctx.run_plan(&format!("fig{fig}"), &plan, &base, &train_ds, &test_ds)?.points;

    // Baseline reference row.
    let m = Measurement::take(&ctx.engine, &base, &test_ds)?;
    points.push(SweepPoint { label: "base".into(), config: "fp32".into(), measurement: m });

    ctx.reporter.write_points(&format!("fig{fig}.csv"), &points)?;
    let (margin, win) = pair_margin(&points, a, b);
    println!(
        "fig{fig}: {}{} vs {}{} -> winner {} (margin {:+.4})",
        a.letter(),
        b.letter(),
        b.letter(),
        a.letter(),
        win,
        margin
    );
    Ok(points)
}

/// frontier-score margin of order (a,b) over (b,a) from labelled points.
pub fn pair_margin(points: &[SweepPoint], a: Technique, b: Technique) -> (f64, String) {
    let lab_ab = format!("{}{}", a.letter(), b.letter());
    let lab_ba = format!("{}{}", b.letter(), a.letter());
    let pts = |lab: &str| -> Vec<(f64, f64)> {
        points.iter().filter(|p| p.label == lab).map(|p| p.xy()).collect()
    };
    let margin = stats::frontier_score(&pts(&lab_ab)) - stats::frontier_score(&pts(&lab_ba));
    let win = if margin >= 0.0 { lab_ab } else { lab_ba };
    (margin, win)
}

/// §5: measure all six pairwise preferences, build the DAG, toposort.
pub fn run_toposort(ctx: &ExpCtx) -> Result<SortOutcome> {
    let mut graph = PreferenceGraph::default();
    let mut rows = Vec::new();
    for fig in 6..=11 {
        let (a, b) = pair_for_fig(fig).unwrap();
        let points = run_pair_fig(ctx, fig)?;
        let (margin, win) = pair_margin(&points, a, b);
        graph.add(Preference { first: a, second: b, margin });
        rows.push(vec![
            format!("fig{fig}"),
            format!("{}{}", a.letter(), b.letter()),
            win.clone(),
            format!("{margin:+.4}"),
        ]);
    }
    let outcome = graph.toposort();
    let law = match &outcome {
        SortOutcome::Unique(o) => format!("UNIQUE: {}", order::sequence_string(o)),
        SortOutcome::Ambiguous(o) => format!("ambiguous: {}", order::sequence_string(o)),
        SortOutcome::Cycle(_) => "CYCLE — no consistent order".to_string(),
    };
    rows.push(vec!["toposort".into(), "-".into(), law.clone(), "-".into()]);
    ctx.reporter.write_table("toposort.csv", &["experiment", "pair", "winner", "margin"], &rows)?;
    println!("combinational sequence law: {law}");
    Ok(outcome)
}

/// Fig 12: inserting a third technique between an established pair does
/// not flip the pair's order.  For each static pair (a,b) of {P,Q,E} and
/// the remaining technique t: compare a->t->b against b->t->a.
pub fn run_fig12(ctx: &ExpCtx) -> Result<()> {
    use Technique::*;
    let (train_ds, test_ds) = ctx.datasets(DatasetKind::SynthC10);
    let base = ctx.base_model("mini_resnet", DatasetKind::SynthC10, &train_ds)?;
    let ladder = ctx.scale.ladder().min(3);

    let combos: [(Technique, Technique, Technique); 3] =
        [(Prune, Quantize, EarlyExit), (Prune, EarlyExit, Quantize), (Quantize, EarlyExit, Prune)];
    let mut plan = ctx.planner("mini_resnet", DatasetKind::SynthC10);
    for (a, b, t) in combos {
        for (x, y, lab) in [(a, b, "kept"), (b, a, "flipped")] {
            let label = format!("{}{}{}", x.letter(), t.letter(), y.letter());
            for i in 0..ladder {
                let chain = Chain::new()
                    .push(sweep::stage_at(x, i, ladder))
                    .push(sweep::stage_at(t, i, ladder))
                    .push(sweep::stage_at(y, i, ladder));
                plan.submit(chain, &label, &format!("rung{i},{lab}"));
            }
        }
    }
    let points = ctx.run_plan("fig12", &plan, &base, &train_ds, &test_ds)?.points;

    let mut rows = Vec::new();
    for (a, b, t) in combos {
        let la = format!("{}{}{}", a.letter(), t.letter(), b.letter());
        let lb = format!("{}{}{}", b.letter(), t.letter(), a.letter());
        let fa: Vec<(f64, f64)> =
            points.iter().filter(|p| p.label == la).map(|p| p.xy()).collect();
        let fb: Vec<(f64, f64)> =
            points.iter().filter(|p| p.label == lb).map(|p| p.xy()).collect();
        let margin = stats::frontier_score(&fa) - stats::frontier_score(&fb);
        rows.push(vec![
            format!("{}>{} insert {}", a.letter(), b.letter(), t.letter()),
            la,
            lb,
            format!("{margin:+.4}"),
            (if margin >= 0.0 { "order preserved" } else { "ORDER FLIPPED" }).into(),
        ]);
    }
    ctx.reporter.write_points("fig12.csv", &points)?;
    ctx.reporter.write_table(
        "fig12_summary.csv",
        &["pair", "kept_order", "flipped_order", "margin", "verdict"],
        &rows,
    )?;
    for r in &rows {
        println!("fig12: {} {} vs {} margin {} -> {}", r[0], r[1], r[2], r[3], r[4]);
    }
    Ok(())
}

/// Build a chain for a technique sequence at given ladder rung.
pub fn chain_for_sequence(seq: &[Technique], rung: usize, ladder: usize) -> Chain {
    let mut c = Chain::new();
    for &t in seq {
        c = c.push(sweep::stage_at(t, rung, ladder));
    }
    c
}

/// Fig 13: full DPQE vs the established two-technique combinations.
pub fn run_fig13(ctx: &ExpCtx) -> Result<()> {
    use Technique::*;
    let (train_ds, test_ds) = ctx.datasets(DatasetKind::SynthC10);
    let base = ctx.base_model("mini_resnet", DatasetKind::SynthC10, &train_ds)?;
    let ladder = ctx.scale.ladder();

    let mut plan = ctx.planner("mini_resnet", DatasetKind::SynthC10);
    for rung in 0..ladder {
        plan.submit(chain_for_sequence(&order::paper_law(), rung, ladder), "DPQE", &format!("rung{rung}"));
    }
    for (a, b) in [(Distill, Prune), (Distill, Quantize), (Prune, Quantize), (Quantize, EarlyExit)] {
        sweep::submit_pairwise(&mut plan, a, b, ladder);
    }
    let points = ctx.run_plan("fig13", &plan, &base, &train_ds, &test_ds)?.points;
    ctx.reporter.write_points("fig13.csv", &points)?;
    let dpqe: Vec<(f64, f64)> = points.iter().filter(|p| p.label == "DPQE").map(|p| p.xy()).collect();
    let best_cr = dpqe.iter().map(|p| p.0).fold(0.0, f64::max);
    println!("fig13: DPQE reaches BitOpsCR {best_cr:.0}x; see results/fig13.csv");
    Ok(())
}

/// Table 1: all six distillation-started orders, max BitOpsCR under
/// accuracy-loss budgets.  The planner makes this the paper's headline
/// reuse case: all six orders share one `D` node per rung, and `DPQE` /
/// `DPEQ` share their whole `DP` prefix.
pub fn run_table1(ctx: &ExpCtx) -> Result<()> {
    let (train_ds, test_ds) = ctx.datasets(DatasetKind::SynthC10);
    let base = ctx.base_model("mini_resnet", DatasetKind::SynthC10, &train_ds)?;
    let base_acc = train::eval_accuracy(&ctx.engine, &base, &test_ds)?;
    let ladder = ctx.scale.ladder();

    let mut plan = ctx.planner("mini_resnet", DatasetKind::SynthC10);
    let labels: Vec<String> = order::distill_started_orders()
        .into_iter()
        .map(|seq| {
            let label = order::sequence_string(&seq);
            for rung in 0..ladder {
                plan.submit(chain_for_sequence(&seq, rung, ladder), &label, &format!("rung{rung}"));
            }
            label
        })
        .collect();
    let all_points = ctx.run_plan("table1", &plan, &base, &train_ds, &test_ds)?.points;
    let per_order: Vec<(String, Vec<(f64, f64)>)> = labels
        .into_iter()
        .map(|label| {
            let pts = all_points.iter().filter(|p| p.label == label).map(|p| p.xy()).collect();
            (label, pts)
        })
        .collect();

    let budgets = [0.01, 0.02, 0.04, 0.08];
    let mut rows = Vec::new();
    for &bud in &budgets {
        let mut row = vec![format!("<= {:.1}%", bud * 100.0)];
        for (_, pts) in &per_order {
            let best = pts
                .iter()
                .filter(|&&(_, acc)| acc >= base_acc - bud)
                .map(|&(cr, _)| cr)
                .fold(0.0, f64::max);
            row.push(if best > 0.0 { format!("{best:.0}x") } else { "-".into() });
        }
        rows.push(row);
    }
    let mut header = vec!["acc_loss".to_string()];
    header.extend(per_order.iter().map(|(l, _)| l.clone()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    ctx.reporter.write_table("table1.csv", &header_refs, &rows)?;
    ctx.reporter.write_points("table1_points.csv", &all_points)?;
    println!("table1 (base acc {:.2}%):", base_acc * 100.0);
    println!("{}", Reporter::markdown_table(&header_refs, &rows));
    Ok(())
}

/// Fig 14: repeating a single compression, alone and after DPQE.  The
/// `DPQE+X` chains extend the shared `DPQE` prefix — one extra node each.
pub fn run_fig14(ctx: &ExpCtx) -> Result<()> {
    use Technique::*;
    let (train_ds, test_ds) = ctx.datasets(DatasetKind::SynthC10);
    let base = ctx.base_model("mini_resnet", DatasetKind::SynthC10, &train_ds)?;
    let ladder = ctx.scale.ladder();

    let mut plan = ctx.planner("mini_resnet", DatasetKind::SynthC10);
    // Repeating one method twice (mild rung) vs once-aggressive.
    for t in [Distill, Prune, Quantize] {
        let mild = 1.min(ladder - 1);
        let aggressive = (ladder - 1).max(mild + 1).min(ladder.max(2) - 1);
        let twice = Chain::new()
            .push(sweep::stage_at(t, mild, ladder))
            .push(sweep::stage_at(t, mild, ladder));
        plan.submit(twice, &format!("{0}{0}", t.letter()), "mild x2");
        let once = Chain::new().push(sweep::stage_at(t, aggressive, ladder));
        plan.submit(once, &format!("{}_aggr", t.letter()), "aggressive x1");
    }
    // DPQE then repeat a stage.
    let rung = 1.min(ladder - 1);
    plan.submit(chain_for_sequence(&order::paper_law(), rung, ladder), "DPQE", &format!("rung{rung}"));
    for t in [Distill, Prune, Quantize] {
        let chain = chain_for_sequence(&order::paper_law(), rung, ladder)
            .push(sweep::stage_at(t, rung, ladder));
        plan.submit(chain, &format!("DPQE+{}", t.letter()), &format!("rung{rung}"));
    }
    let run = ctx.run_plan_reports("fig14", &plan, &base, &train_ds, &test_ds)?;

    // Final measurement per chain only (no runtime-threshold extras), the
    // shape this figure has always had.
    let points: Vec<SweepPoint> = run
        .outcomes
        .iter()
        .map(|o| SweepPoint {
            label: o.label.clone(),
            config: o.config.clone(),
            measurement: o.reports.last().expect("non-empty chain").measurement.clone(),
        })
        .collect();
    ctx.reporter.write_points("fig14.csv", &points)?;
    println!("fig14: wrote {} points", points.len());
    Ok(())
}

/// Tables 2-4 + Fig 15: the end-to-end DPQE evaluation over arch x dataset.
pub fn run_table_e2e(ctx: &ExpCtx, arch_name: &str, table_id: &str) -> Result<()> {
    let kinds = [
        DatasetKind::SynthC10,
        DatasetKind::SynthC100,
        DatasetKind::SynthSVHN,
        DatasetKind::SynthCINIC,
    ];
    let ladder = ctx.scale.ladder();
    let rung = 1.min(ladder - 1);
    let mut rows = Vec::new();
    let mut stage_points = Vec::new();
    for kind in kinds {
        let (train_ds, test_ds) = ctx.datasets(kind);
        let base = ctx.base_model(arch_name, kind, &train_ds)?;
        let orig_acc = train::eval_accuracy(&ctx.engine, &base, &test_ds)?;
        let mut plan = ctx.planner(arch_name, kind);
        plan.submit(
            chain_for_sequence(&order::paper_law(), rung, ladder),
            "DPQE",
            &format!("rung{rung}"),
        );
        let run = ctx.run_plan_reports(table_id, &plan, &base, &train_ds, &test_ds)?;
        let reports = &run.outcomes[0].reports;
        let m = &reports.last().expect("non-empty chain").measurement;
        rows.push(vec![
            kind.name().to_string(),
            format!("{:.2}", orig_acc * 100.0),
            format!("{:.2}({:+.2})", m.accuracy * 100.0, (m.accuracy - orig_acc) * 100.0),
            format!("{:.0}x", m.bitops_cr),
            format!("{:.0}x", m.storage_cr),
        ]);
        // fig15 waterfall: per-stage accuracy + CR.
        for (si, r) in reports.iter().enumerate() {
            stage_points.push(SweepPoint {
                label: format!("{arch_name}/{}", kind.name()),
                config: format!("stage{}:{}", si + 1, r.stage),
                measurement: r.measurement.clone(),
            });
        }
        if ctx.verbose {
            println!(
                "{table_id} {} {}: acc {:.2}% -> {:.2}%  BitOpsCR {:.0}x CR {:.0}x",
                arch_name,
                kind.name(),
                orig_acc * 100.0,
                m.accuracy * 100.0,
                m.bitops_cr,
                m.storage_cr
            );
        }
    }
    let header = ["dataset", "original_acc", "compressed_acc", "bitops_cr", "cr"];
    ctx.reporter.write_table(&format!("{table_id}.csv"), &header, &rows)?;
    ctx.reporter.write_points(&format!("fig15_{arch_name}.csv"), &stage_points)?;
    println!("{table_id} ({arch_name}):");
    println!("{}", Reporter::markdown_table(&header, &rows));
    Ok(())
}

/// Table 5: DPQE vs re-implementable combination baselines (the rows of
/// Table 5 built from our own building blocks; externally-reported rows
/// are quoted in EXPERIMENTS.md, not re-run — see DESIGN.md).
pub fn run_table5(ctx: &ExpCtx) -> Result<()> {
    use Technique::*;
    let (train_ds, test_ds) = ctx.datasets(DatasetKind::SynthC10);
    let base = ctx.base_model("mini_resnet", DatasetKind::SynthC10, &train_ds)?;
    let orig_acc = train::eval_accuracy(&ctx.engine, &base, &test_ds)?;
    let ladder = ctx.scale.ladder();
    let rung = 1.min(ladder - 1);

    let baselines: Vec<(&str, Vec<Technique>)> = vec![
        ("PD (Aghli21-style: prune then distill)", vec![Prune, Distill]),
        ("Quantized Distillation (D+Q)", vec![Distill, Quantize]),
        ("predictive E+Q (Q then E)", vec![Quantize, EarlyExit]),
        ("P+Q (OICSR-style)", vec![Prune, Quantize]),
        ("Ours DPQE", order::paper_law()),
    ];
    let mut plan = ctx.planner("mini_resnet", DatasetKind::SynthC10);
    for (name, seq) in &baselines {
        plan.submit(chain_for_sequence(seq, rung, ladder), name, &format!("rung{rung}"));
    }
    let run = ctx.run_plan_reports("table5", &plan, &base, &train_ds, &test_ds)?;

    let mut rows = Vec::new();
    for outcome in &run.outcomes {
        let m = &outcome.reports.last().expect("non-empty chain").measurement;
        rows.push(vec![
            outcome.label.clone(),
            format!("{:.2}({:+.2})", m.accuracy * 100.0, (m.accuracy - orig_acc) * 100.0),
            format!("{:.1}", m.bitops_cr),
            format!("{:.1}", m.storage_cr),
        ]);
    }
    let header = ["method", "acc(%)", "bitops_cr", "cr"];
    ctx.reporter.write_table("table5.csv", &header, &rows)?;
    println!("table5 (orig acc {:.2}%):", orig_acc * 100.0);
    println!("{}", Reporter::markdown_table(&header, &rows));
    Ok(())
}

/// Ablation: L2 channel-importance vs random pruning at matched ratios —
/// the design-choice bench DESIGN.md calls out for the Prune stage.
pub fn run_ablation_prune(ctx: &ExpCtx) -> Result<()> {
    use crate::chain::stages::{Importance, Prune};
    let (train_ds, test_ds) = ctx.datasets(DatasetKind::SynthC10);
    let base = ctx.base_model("mini_resnet", DatasetKind::SynthC10, &train_ds)?;
    let mut plan = ctx.planner("mini_resnet", DatasetKind::SynthC10);
    for &ratio in &[0.3f32, 0.5, 0.7] {
        for (imp, label) in [(Importance::L2, "prune_l2"), (Importance::Random, "prune_random")] {
            let chain = Chain::new().push(Box::new(Prune {
                ratio,
                importance: imp,
                ..Default::default()
            }));
            plan.submit(chain, label, &format!("ratio={ratio}"));
        }
    }
    let points = ctx.run_plan("ablation_prune", &plan, &base, &train_ds, &test_ds)?.points;
    ctx.reporter.write_points("ablation_prune.csv", &points)?;
    let score = |lab: &str| {
        stats::frontier_score(
            &points.iter().filter(|p| p.label == lab).map(|p| p.xy()).collect::<Vec<_>>(),
        )
    };
    println!(
        "ablation_prune: L2 frontier {:.4} vs random {:.4} ({})",
        score("prune_l2"),
        score("prune_random"),
        if score("prune_l2") >= score("prune_random") { "L2 wins" } else { "random wins?!" }
    );
    Ok(())
}

/// Deep Compression baseline (Han et al. 2015): P -> weight clustering ->
/// Huffman coding, reported against our DPQE on the same base model.
pub fn run_deepcompression(ctx: &ExpCtx) -> Result<()> {
    use crate::chain::stages::{HuffmanCoding, Prune, WeightCluster};
    let (train_ds, test_ds) = ctx.datasets(DatasetKind::SynthC10);
    let base = ctx.base_model("mini_resnet", DatasetKind::SynthC10, &train_ds)?;
    let orig_acc = train::eval_accuracy(&ctx.engine, &base, &test_ds)?;
    let ladder = ctx.scale.ladder();
    let rung = 1.min(ladder - 1);

    let mut plan = ctx.planner("mini_resnet", DatasetKind::SynthC10);
    let dc = Chain::new()
        .push(Box::new(Prune { ratio: 0.5, ..Default::default() }))
        .push(Box::new(WeightCluster { index_bits: 4, ..Default::default() }))
        .push(Box::new(HuffmanCoding));
    plan.submit(dc, "Deep Compression (P+cluster+huffman)", "p0.5,k16");
    plan.submit(
        chain_for_sequence(&order::paper_law(), rung, ladder),
        "Ours DPQE",
        &format!("rung{rung}"),
    );
    let run = ctx.run_plan_reports("deepcompression", &plan, &base, &train_ds, &test_ds)?;

    let mut rows = Vec::new();
    for outcome in &run.outcomes {
        let m = &outcome.reports.last().expect("non-empty chain").measurement;
        rows.push(vec![
            outcome.label.clone(),
            format!("{:.2}({:+.2})", m.accuracy * 100.0, (m.accuracy - orig_acc) * 100.0),
            format!("{:.1}", m.bitops_cr),
            format!("{:.1}", m.storage_cr),
        ]);
    }
    let header = ["method", "acc(%)", "bitops_cr", "cr"];
    ctx.reporter.write_table("deepcompression.csv", &header, &rows)?;
    println!("deepcompression (orig acc {:.2}%):", orig_acc * 100.0);
    println!("{}", Reporter::markdown_table(&header, &rows));
    Ok(())
}

/// Dispatch by experiment id.
pub fn run(ctx: &ExpCtx, id: &str) -> Result<()> {
    match id {
        "fig6" | "fig7" | "fig8" | "fig9" | "fig10" | "fig11" => {
            let fig: usize = id[3..].parse().unwrap();
            run_pair_fig(ctx, fig)?;
        }
        "toposort" => {
            run_toposort(ctx)?;
        }
        "fig12" => run_fig12(ctx)?,
        "fig13" => run_fig13(ctx)?,
        "table1" => run_table1(ctx)?,
        "fig14" => run_fig14(ctx)?,
        "table2" => run_table_e2e(ctx, "mini_vgg", "table2")?,
        "table3" => run_table_e2e(ctx, "mini_resnet", "table3")?,
        "table4" => run_table_e2e(ctx, "mini_mobilenet", "table4")?,
        "fig15" => {
            // Waterfalls are emitted alongside tables 2-4.
            run_table_e2e(ctx, "mini_vgg", "table2")?;
            run_table_e2e(ctx, "mini_resnet", "table3")?;
            run_table_e2e(ctx, "mini_mobilenet", "table4")?;
        }
        "table5" => run_table5(ctx)?,
        "ablation_prune" => run_ablation_prune(ctx)?,
        "deepcompression" => run_deepcompression(ctx)?,
        "all" => {
            run_toposort(ctx)?;
            run_fig12(ctx)?;
            run_fig13(ctx)?;
            run_table1(ctx)?;
            run_fig14(ctx)?;
            run_table_e2e(ctx, "mini_vgg", "table2")?;
            run_table_e2e(ctx, "mini_resnet", "table3")?;
            run_table_e2e(ctx, "mini_mobilenet", "table4")?;
            run_table5(ctx)?;
        }
        other => return Err(anyhow!("unknown experiment `{other}` (see DESIGN.md index)")),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig_pairs_cover_all_six() {
        let mut seen = std::collections::BTreeSet::new();
        for fig in 6..=11 {
            let (a, b) = pair_for_fig(fig).unwrap();
            assert_ne!(a, b);
            seen.insert((a.min(b), a.max(b)));
        }
        assert_eq!(seen.len(), 6);
        assert!(pair_for_fig(5).is_none());
    }

    #[test]
    fn chain_for_sequence_letters() {
        let c = chain_for_sequence(&order::paper_law(), 0, 4);
        assert_eq!(c.sequence_letters(), "DPQE");
    }

    #[test]
    fn table1_plan_dedupes_to_unique_prefixes() {
        // The acceptance-criterion invariant, checked without an engine:
        // table1's submission set at smoke scale executes each unique
        // stage prefix exactly once.
        let ladder = Scale::Smoke.ladder();
        let mut plan = Planner::new(PlanKey {
            arch: "mini_resnet".into(),
            dataset: "c10".into(),
            scale: Scale::Smoke.name().into(),
            base_steps: Scale::Smoke.base_steps(),
            seed: 42,
        });
        for seq in order::distill_started_orders() {
            let label = order::sequence_string(&seq);
            for rung in 0..ladder {
                plan.submit(chain_for_sequence(&seq, rung, ladder), &label, &format!("rung{rung}"));
            }
        }
        // Per rung: 1 D + 3 second + 6 third + 6 leaves = 16 unique nodes
        // vs 24 requested stage applications.
        assert_eq!(plan.total_stages(), 24 * ladder);
        assert_eq!(plan.unique_nodes(), 16 * ladder);
        assert_eq!(plan.root_children(), ladder);
    }
}
