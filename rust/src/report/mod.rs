//! Result emitters: CSV files (one per paper table/figure) + markdown
//! summaries, written under `results/`.

use std::fmt::Write as _;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::sweep::SweepPoint;

pub struct Reporter {
    dir: PathBuf,
}

impl Reporter {
    pub fn new<P: AsRef<Path>>(dir: P) -> Result<Reporter> {
        fs::create_dir_all(dir.as_ref()).context("creating results dir")?;
        Ok(Reporter { dir: dir.as_ref().to_path_buf() })
    }

    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    pub fn write(&self, name: &str, contents: &str) -> Result<PathBuf> {
        let p = self.path(name);
        fs::write(&p, contents).with_context(|| format!("writing {}", p.display()))?;
        crate::obs::log!(crate::obs::Level::Info, "wrote {}", p.display());
        Ok(p)
    }

    /// Scatter-point CSV shared by all figure experiments.
    pub fn write_points(&self, name: &str, points: &[SweepPoint]) -> Result<PathBuf> {
        let mut s = String::from(
            "series,config,accuracy,bitops_cr,storage_cr,bitops,storage_bits,p_exit1,p_exit2\n",
        );
        for p in points {
            let m = &p.measurement;
            writeln!(
                s,
                "{},{},{:.5},{:.4},{:.4},{:.4e},{:.4e},{:.4},{:.4}",
                csv_escape(&p.label),
                csv_escape(&p.config),
                m.accuracy,
                m.bitops_cr,
                m.storage_cr,
                m.bitops,
                m.storage_bits,
                m.exit_probs.0,
                m.exit_probs.1
            )
            .unwrap();
        }
        self.write(name, &s)
    }

    /// Append one row to a long-lived accounting CSV (creating it with
    /// `header` on first use) — e.g. `plan_stats.csv`, which accumulates
    /// the plan executor's cache-hit accounting across invocations.
    ///
    /// Schema evolution: if the file's existing header differs from
    /// `header` (a release added columns), the old file is rotated to
    /// `<name>.bak` and a fresh one starts — rows are never appended
    /// misaligned under a stale header.
    pub fn append_row(&self, name: &str, header: &[&str], row: &[String]) -> Result<PathBuf> {
        use std::io::{BufRead as _, BufReader, Write as _};
        let p = self.path(name);
        let want = header.join(",");
        if let Ok(f) = fs::File::open(&p) {
            let mut first = String::new();
            if BufReader::new(f).read_line(&mut first).is_ok() {
                let first = first.trim_end();
                if !first.is_empty() && first != want {
                    let bak = p.with_extension("csv.bak");
                    // Atomic rename; a concurrent loser's failed rename is
                    // harmless (the winner already moved the stale file).
                    if fs::rename(&p, &bak).is_ok() {
                        crate::obs::log!(
                            crate::obs::Level::Warn,
                            "[report] {} header changed; rotated old rows to {}",
                            p.display(),
                            bak.display()
                        );
                    }
                }
            }
        }
        // create+append (no exists-then-write TOCTOU): concurrent writers
        // can at worst duplicate the header line, never truncate rows.
        let mut f = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&p)
            .with_context(|| format!("opening {}", p.display()))?;
        let line = row.iter().map(|c| csv_escape(c)).collect::<Vec<_>>().join(",");
        if f.metadata().map(|m| m.len() == 0).unwrap_or(false) {
            writeln!(f, "{want}").with_context(|| format!("writing header to {}", p.display()))?;
        }
        writeln!(f, "{line}").with_context(|| format!("appending to {}", p.display()))?;
        Ok(p)
    }

    /// Generic table CSV.
    pub fn write_table(&self, name: &str, header: &[&str], rows: &[Vec<String>]) -> Result<PathBuf> {
        let mut s = header.join(",");
        s.push('\n');
        for row in rows {
            s.push_str(
                &row.iter().map(|c| csv_escape(c)).collect::<Vec<_>>().join(","),
            );
            s.push('\n');
        }
        self.write(name, &s)
    }

    /// Markdown table for EXPERIMENTS.md-style summaries.
    pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
        let mut s = format!("| {} |\n", header.join(" | "));
        s.push_str(&format!("|{}\n", "---|".repeat(header.len())));
        for row in rows {
            s.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        s
    }
}

fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_escaping() {
        assert_eq!(csv_escape("plain"), "plain");
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("q\"q"), "\"q\"\"q\"");
    }

    #[test]
    fn markdown_shape() {
        let md = Reporter::markdown_table(&["a", "b"], &[vec!["1".into(), "2".into()]]);
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join(format!("coc_report_test_{}", std::process::id()));
        let r = Reporter::new(&dir).unwrap();
        let p = r.write("x.csv", "a,b\n1,2\n").unwrap();
        assert!(p.exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_row_creates_then_extends() {
        let dir = std::env::temp_dir().join(format!("coc_report_append_{}", std::process::id()));
        let r = Reporter::new(&dir).unwrap();
        let header = ["experiment", "hits"];
        r.append_row("stats.csv", &header, &["fig6".into(), "3".into()]).unwrap();
        let p = r.append_row("stats.csv", &header, &["fig7,x".into(), "4".into()]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "experiment,hits\nfig6,3\n\"fig7,x\",4\n");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn append_row_rotates_on_header_change() {
        // A schema change (e.g. plan_stats.csv gaining byte columns) must
        // not append wider rows under the stale header.
        let dir = std::env::temp_dir().join(format!("coc_report_rotate_{}", std::process::id()));
        let r = Reporter::new(&dir).unwrap();
        r.append_row("stats.csv", &["a", "b"], &["1".into(), "2".into()]).unwrap();
        let p = r
            .append_row("stats.csv", &["a", "b", "c"], &["3".into(), "4".into(), "5".into()])
            .unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "a,b,c\n3,4,5\n");
        let bak = std::fs::read_to_string(p.with_extension("csv.bak")).unwrap();
        assert_eq!(bak, "a,b\n1,2\n", "old rows preserved under the old header");
        std::fs::remove_dir_all(&dir).ok();
    }
}
