//! Training-loop driver: drives the AOT train graph over device-resident
//! model state, applies the paper's fine-tuning protocol (fresh training
//! vs fine-tune at 1/10 LR), and evaluates via the eval graph.
//!
//! Graph operand orders are fixed by python/compile/aot.py:
//!   train : params*, momenta*, x, y, masks*, qbw, qba, tlogits,
//!           kd_alpha, kd_tau, exit_w[2], hp[3]      -> params*, momenta*, loss, acc
//!   eval  : params*, masks*, qbw, qba, x            -> logits, e1, e2
//!   init  : seed                                    -> params*, momenta*
//!
//! # Transport
//!
//! Every compression stage is dominated by these two loops, so both run on
//! the buffer transport (`runtime::DeviceState` / `Executable::run_buffers`):
//! [`train`] uploads params/momenta/masks/scalars once per stage, streams
//! only `(x, y, teacher_rows)` per step, downloads only the `loss`/`acc`
//! scalars, and materializes host tensors once at the stage boundary;
//! [`eval_logits`] hoists the invariant `params*, masks*, qbw, qba` prefix
//! out of the per-batch loop.  When buffer execution is unavailable
//! ([`runtime::ResidencyUnsupported`]) both degrade to the legacy per-call
//! literal marshalling ([`train_marshalled`] / [`eval_logits_marshalled`],
//! also the baselines of the `train_residency` bench) — same graphs, same
//! operand values, bit-identical results either way
//! (`rust/tests/residency.rs`).

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::data::{Batcher, Dataset};
use crate::models::{ArchManifest, ModelState};
use crate::runtime::{self, DeviceBuffer, DeviceState, Engine, ResidencyUnsupported};
use crate::tensor::Tensor;

/// Hyper-parameters for one training run (one chain stage).
#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// KD mixing weight (0 = plain CE) and temperature.
    pub kd_alpha: f32,
    pub kd_tau: f32,
    /// Per-exit loss weights (0 = exits untrained).
    pub exit_w: [f32; 2],
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 200,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            kd_alpha: 0.0,
            kd_tau: 4.0,
            exit_w: [0.0, 0.0],
            seed: 0,
            log_every: 0,
        }
    }
}

impl TrainOpts {
    /// The paper's fine-tune rule: same budget discipline, 1/10 LR.
    pub fn fine_tune_of(base: &TrainOpts, steps: usize) -> TrainOpts {
        TrainOpts { steps, lr: base.lr / 10.0, ..base.clone() }
    }
}

#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
}

impl TrainLog {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    /// Mean training accuracy over the last quarter of the run.
    pub fn settled_acc(&self) -> f32 {
        let n = self.accs.len();
        if n == 0 {
            return f32::NAN;
        }
        let tail = &self.accs[n - (n / 4).max(1)..];
        tail.iter().sum::<f32>() / tail.len() as f32
    }
}

/// Precomputed teacher logits over a dataset, row-gatherable per batch.
pub struct TeacherLogits {
    pub rows: Tensor, // [n, num_classes]
}

impl TeacherLogits {
    pub fn gather(&self, idx: &[usize]) -> Tensor {
        let c = self.rows.shape[1];
        let mut out = Vec::with_capacity(idx.len() * c);
        for &i in idx {
            out.extend_from_slice(self.rows.row(i));
        }
        Tensor::new(vec![idx.len(), c], out)
    }
}

/// Initialize a fresh ModelState by running the AOT init graph (keeps rust
/// and jax initialization identical by construction).
pub fn init_state(engine: &Engine, arch: Arc<ArchManifest>, seed: u64) -> Result<ModelState> {
    let exe = engine.load_graph(&arch, "init")?;
    let seed_t = Tensor::scalar(seed as f32);
    let outs = exe.run(&[&seed_t]).context("running init graph")?;
    let np = arch.num_params();
    ensure!(outs.len() == 2 * np, "init graph returned {} outputs, want {}", outs.len(), 2 * np);
    let params = outs[..np].to_vec();
    let momenta = outs[np..].to_vec();
    let masks = arch.mask_slots.iter().map(|m| Tensor::ones(&[m.channels])).collect();
    Ok(ModelState {
        arch,
        params,
        momenta,
        masks,
        qbits: crate::models::QBits::FP32,
        exits: Default::default(),
        extras: Default::default(),
        history: Vec::new(),
    })
}

/// Run `opts.steps` SGD steps on `state` in place.
///
/// Device-resident: params and momenta stay on the PJRT device across all
/// steps (step N+1 consumes step N's output buffers), so the per-step
/// host->device traffic is the batch only and the per-step device->host
/// traffic is the two loss/acc scalars.  Falls back to
/// [`train_marshalled`] when buffer execution is unavailable; both paths
/// produce bit-identical `ModelState`s.
pub fn train(
    engine: &Engine,
    state: &mut ModelState,
    ds: &Dataset,
    teacher: Option<&TeacherLogits>,
    opts: &TrainOpts,
) -> Result<TrainLog> {
    let _span = crate::obs::trace::span("train.run");
    match train_resident(engine, state, ds, teacher, opts) {
        Ok(log) => Ok(log),
        Err(e) if e.downcast_ref::<ResidencyUnsupported>().is_some() => {
            runtime::note_residency_fallback("train", &e);
            train_marshalled(engine, state, ds, teacher, opts)
        }
        Err(e) => Err(e),
    }
}

/// The buffer-transport training loop.  Mutates `state` only at the very
/// end ([`DeviceState::to_host`]), so any error leaves the host state
/// untouched and the caller is free to re-run the stage on the literal
/// transport.
fn train_resident(
    engine: &Engine,
    state: &mut ModelState,
    ds: &Dataset,
    teacher: Option<&TeacherLogits>,
    opts: &TrainOpts,
) -> Result<TrainLog> {
    let mut log = TrainLog::default();
    if opts.steps == 0 {
        return Ok(log);
    }
    let arch = state.arch.clone();
    let exe = engine.load_graph(&arch, "train")?;
    let bs = arch.train_batch;
    let np = arch.num_params();
    let mut batcher = Batcher::new(ds.len(), bs, opts.seed ^ 0xbadc0de);

    // Stage-entry uploads: the entire invariant operand set goes
    // device-side once, not once per step.  (`Engine::upload` wraps its
    // failures in `ResidencyUnsupported` already.)
    let mut dev = DeviceState::from_model(engine, state)?;
    let kd_alpha =
        engine.upload(&Tensor::scalar(if teacher.is_some() { opts.kd_alpha } else { 0.0 }))?;
    let kd_tau = engine.upload(&Tensor::scalar(opts.kd_tau))?;
    let exit_w = engine.upload(&Tensor::from_vec(opts.exit_w.to_vec()))?;
    let hp = engine.upload(&Tensor::from_vec(vec![opts.lr, opts.momentum, opts.weight_decay]))?;
    // Hoisted too: the marshalled path re-marshals this zero block every
    // teacherless step.
    let zero_teacher = engine.upload(&Tensor::zeros(&[bs, arch.num_classes]))?;

    for step in 0..opts.steps {
        let idx = batcher.next_indices().to_vec();
        let (x, y) = ds.batch(&idx);
        let xb = engine.upload(&x)?;
        let yb = engine.upload(&y)?;
        let tlb = match teacher {
            Some(t) => Some(engine.upload(&t.gather(&idx))?),
            None => None,
        };

        let mut inputs: Vec<&DeviceBuffer> = Vec::with_capacity(2 * np + 10);
        inputs.extend(dev.params.iter());
        inputs.extend(dev.momenta.iter());
        inputs.push(&xb);
        inputs.push(&yb);
        inputs.extend(dev.masks.iter());
        inputs.push(&dev.qbw);
        inputs.push(&dev.qba);
        inputs.push(tlb.as_ref().unwrap_or(&zero_teacher));
        inputs.push(&kd_alpha);
        inputs.push(&kd_tau);
        inputs.push(&exit_w);
        inputs.push(&hp);

        let ran = exe.run_buffers(&inputs).with_context(|| format!("train step {step}"));
        let mut outs = if step == 0 {
            // Nothing has been consumed device-side yet: a failure (or a
            // packed-tuple result, visible as the wrong leaf count) on the
            // FIRST step means buffer-mode execution is unavailable, not
            // that training failed.  Later steps report errors as errors.
            let outs = ran.map_err(|e| ResidencyUnsupported(format!("{e:#}")))?;
            if outs.len() != 2 * np + 2 {
                return Err(ResidencyUnsupported(format!(
                    "train graph returned {} device results, want {} untupled leaves",
                    outs.len(),
                    2 * np + 2
                ))
                .into());
            }
            outs
        } else {
            let outs = ran?;
            ensure!(
                outs.len() == 2 * np + 2,
                "train graph returned {} outputs, want {}",
                outs.len(),
                2 * np + 2
            );
            outs
        };

        // The only per-step downloads: the two scalars.
        let acc = outs.pop().unwrap().to_tensor().context("downloading acc scalar")?.data[0];
        let loss = outs.pop().unwrap().to_tensor().context("downloading loss scalar")?.data[0];
        // Step N's outputs become step N+1's resident inputs; the consumed
        // buffers drop (and free) here.
        dev.momenta = outs.split_off(np);
        dev.params = outs;
        log.losses.push(loss);
        log.accs.push(acc);
        if opts.log_every > 0 && step % opts.log_every == 0 {
            crate::obs::log!(
                crate::obs::Level::Info,
                "  step {step:>4}  loss {loss:.4}  acc {acc:.3}"
            );
        }
        ensure!(loss.is_finite(), "training diverged at step {step} (loss={loss})");
    }
    // The stage boundary: the single host-materialization point, where the
    // plan cache snapshots the state.
    dev.to_host(state)?;
    Ok(log)
}

/// Legacy transport: re-marshal the full `params ++ momenta` set through
/// host literals on every step and download them all back.  Kept as the
/// measured baseline of the `train_residency` bench and the reference side
/// of the bit-identical equivalence tests — not used on any hot path
/// unless buffer execution is unavailable.
pub fn train_marshalled(
    engine: &Engine,
    state: &mut ModelState,
    ds: &Dataset,
    teacher: Option<&TeacherLogits>,
    opts: &TrainOpts,
) -> Result<TrainLog> {
    let arch = state.arch.clone();
    let exe = engine.load_graph(&arch, "train")?;
    let bs = arch.train_batch;
    let np = arch.num_params();
    let mut batcher = Batcher::new(ds.len(), bs, opts.seed ^ 0xbadc0de);
    let mut log = TrainLog::default();

    let qbw = Tensor::scalar(state.qbits.weight);
    let qba = Tensor::scalar(state.qbits.act);
    let kd_alpha = Tensor::scalar(if teacher.is_some() { opts.kd_alpha } else { 0.0 });
    let kd_tau = Tensor::scalar(opts.kd_tau);
    let exit_w = Tensor::from_vec(opts.exit_w.to_vec());
    let hp = Tensor::from_vec(vec![opts.lr, opts.momentum, opts.weight_decay]);
    let zero_teacher = Tensor::zeros(&[bs, arch.num_classes]);

    for step in 0..opts.steps {
        let idx = batcher.next_indices().to_vec();
        let (x, y) = ds.batch(&idx);
        let tl = teacher.map(|t| t.gather(&idx));

        let mut inputs: Vec<&Tensor> = Vec::with_capacity(2 * np + 10);
        inputs.extend(state.params.iter());
        inputs.extend(state.momenta.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(state.masks.iter());
        inputs.push(&qbw);
        inputs.push(&qba);
        inputs.push(tl.as_ref().unwrap_or(&zero_teacher));
        inputs.push(&kd_alpha);
        inputs.push(&kd_tau);
        inputs.push(&exit_w);
        inputs.push(&hp);

        let mut outs = exe.run(&inputs).with_context(|| format!("train step {step}"))?;
        ensure!(
            outs.len() == 2 * np + 2,
            "train graph returned {} outputs, want {}",
            outs.len(),
            2 * np + 2
        );
        let acc = outs.pop().unwrap().data[0];
        let loss = outs.pop().unwrap().data[0];
        state.momenta = outs.split_off(np);
        state.params = outs;
        log.losses.push(loss);
        log.accs.push(acc);
        if opts.log_every > 0 && step % opts.log_every == 0 {
            crate::obs::log!(
                crate::obs::Level::Info,
                "  step {step:>4}  loss {loss:.4}  acc {acc:.3}"
            );
        }
        ensure!(loss.is_finite(), "training diverged at step {step} (loss={loss})");
    }
    Ok(log)
}

/// Index list for one eval batch: `take` real rows starting at `start`,
/// padded to the lowered batch `bs` by repeating the final dataset row
/// (index `n - 1`).  Padded rows are computed by the graph and dropped
/// from the returned logits — `rust/tests/residency.rs` pins that the
/// ragged tail changes nothing.
fn padded_eval_indices(start: usize, take: usize, bs: usize, n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (start..start + take).collect();
    while idx.len() < bs {
        idx.push(n - 1);
    }
    idx
}

/// Full-dataset forward: returns (main logits, exit1 logits, exit2 logits)
/// stacked over the dataset (padding batches internally).
///
/// Device-resident: the invariant `params*, masks*, qbw, qba` operand
/// prefix is uploaded once and only `x` crosses the host boundary per
/// batch.  Falls back to [`eval_logits_marshalled`] when buffer execution
/// is unavailable; the logits are bit-identical either way.
pub fn eval_logits(
    engine: &Engine,
    state: &ModelState,
    ds: &Dataset,
) -> Result<(Tensor, Tensor, Tensor)> {
    let _span = crate::obs::trace::span("train.eval");
    match eval_logits_resident(engine, state, ds) {
        Ok(r) => Ok(r),
        Err(e) if e.downcast_ref::<ResidencyUnsupported>().is_some() => {
            runtime::note_residency_fallback("eval", &e);
            eval_logits_marshalled(engine, state, ds)
        }
        Err(e) => Err(e),
    }
}

fn eval_logits_resident(
    engine: &Engine,
    state: &ModelState,
    ds: &Dataset,
) -> Result<(Tensor, Tensor, Tensor)> {
    let arch = &state.arch;
    let exe = engine.load_graph(arch, "eval")?;
    let bs = arch.eval_batch;
    let nc = arch.num_classes;
    let n = ds.len();

    // The invariant prefix, hoisted out of the per-batch loop.
    let prefix = runtime::upload_eval_prefix(engine, state)?;

    let mut main = Vec::with_capacity(n * nc);
    let mut e1 = Vec::with_capacity(n * nc);
    let mut e2 = Vec::with_capacity(n * nc);
    let mut i = 0;
    let mut first = true;
    while i < n {
        let take = bs.min(n - i);
        let (x, _) = ds.batch(&padded_eval_indices(i, take, bs, n));
        let xb = engine.upload(&x)?;
        let mut inputs: Vec<&DeviceBuffer> = Vec::with_capacity(prefix.len() + 1);
        inputs.extend(prefix.iter());
        inputs.push(&xb);
        let ran = exe.run_buffers(&inputs).context("eval batch");
        let outs = if first {
            // See train_resident: a first-execute failure or a packed
            // tuple means the transport is unavailable, not that eval
            // failed.
            let outs = ran.map_err(|e| ResidencyUnsupported(format!("{e:#}")))?;
            if outs.len() != 3 {
                return Err(ResidencyUnsupported(format!(
                    "eval graph returned {} device results, want 3 untupled leaves",
                    outs.len()
                ))
                .into());
            }
            first = false;
            outs
        } else {
            let outs = ran?;
            ensure!(outs.len() == 3, "eval graph returned {} outputs", outs.len());
            outs
        };
        // Padded rows are dropped here: only `take * nc` values survive.
        main.extend_from_slice(&outs[0].to_tensor()?.data[..take * nc]);
        e1.extend_from_slice(&outs[1].to_tensor()?.data[..take * nc]);
        e2.extend_from_slice(&outs[2].to_tensor()?.data[..take * nc]);
        i += take;
    }
    Ok((
        Tensor::new(vec![n, nc], main),
        Tensor::new(vec![n, nc], e1),
        Tensor::new(vec![n, nc], e2),
    ))
}

/// Legacy transport for [`eval_logits`]: re-marshal the full operand list
/// per batch.  Kept for the `train_residency` bench and the equivalence
/// tests.
pub fn eval_logits_marshalled(
    engine: &Engine,
    state: &ModelState,
    ds: &Dataset,
) -> Result<(Tensor, Tensor, Tensor)> {
    let arch = &state.arch;
    let exe = engine.load_graph(arch, "eval")?;
    let bs = arch.eval_batch;
    let nc = arch.num_classes;
    let n = ds.len();
    let qbw = Tensor::scalar(state.qbits.weight);
    let qba = Tensor::scalar(state.qbits.act);

    let mut main = Vec::with_capacity(n * nc);
    let mut e1 = Vec::with_capacity(n * nc);
    let mut e2 = Vec::with_capacity(n * nc);
    let mut i = 0;
    while i < n {
        let take = bs.min(n - i);
        let (x, _) = ds.batch(&padded_eval_indices(i, take, bs, n));
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(arch.num_params() + 8);
        inputs.extend(state.params.iter());
        inputs.extend(state.masks.iter());
        inputs.push(&qbw);
        inputs.push(&qba);
        inputs.push(&x);
        let outs = exe.run(&inputs).context("eval batch")?;
        ensure!(outs.len() == 3, "eval graph returned {} outputs", outs.len());
        main.extend_from_slice(&outs[0].data[..take * nc]);
        e1.extend_from_slice(&outs[1].data[..take * nc]);
        e2.extend_from_slice(&outs[2].data[..take * nc]);
        i += take;
    }
    Ok((
        Tensor::new(vec![n, nc], main),
        Tensor::new(vec![n, nc], e1),
        Tensor::new(vec![n, nc], e2),
    ))
}

/// Top-1 accuracy of the main head.
pub fn eval_accuracy(engine: &Engine, state: &ModelState, ds: &Dataset) -> Result<f64> {
    let (logits, _, _) = eval_logits(engine, state, ds)?;
    Ok(accuracy_of(&logits, &ds.labels))
}

pub fn accuracy_of(logits: &Tensor, labels: &[usize]) -> f64 {
    let preds = logits.argmax_rows();
    let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    correct as f64 / labels.len().max(1) as f64
}

/// Teacher logits over a dataset (for distillation): the teacher is run
/// once; students gather rows per batch.
pub fn teacher_logits(engine: &Engine, state: &ModelState, ds: &Dataset) -> Result<TeacherLogits> {
    let (logits, _, _) = eval_logits(engine, state, ds)?;
    Ok(TeacherLogits { rows: logits })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_of_counts() {
        let logits = Tensor::new(vec![3, 2], vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]);
        assert!((accuracy_of(&logits, &[0, 1, 1]) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn fine_tune_tenth_lr() {
        let base = TrainOpts { lr: 0.05, ..Default::default() };
        let ft = TrainOpts::fine_tune_of(&base, 10);
        assert!((ft.lr - 0.005).abs() < 1e-9);
        assert_eq!(ft.steps, 10);
    }

    #[test]
    fn teacher_gather() {
        let t = TeacherLogits { rows: Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]) };
        let g = t.gather(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }

    #[test]
    fn padded_eval_indices_fill_with_last_row() {
        // Final ragged batch of a 10-sample dataset at batch 4: 2 real
        // rows, then index 9 repeated.
        assert_eq!(padded_eval_indices(8, 2, 4, 10), vec![8, 9, 9, 9]);
        // Full batches carry no padding.
        assert_eq!(padded_eval_indices(4, 4, 4, 10), vec![4, 5, 6, 7]);
        // Degenerate single-sample dataset at batch 3.
        assert_eq!(padded_eval_indices(0, 1, 3, 1), vec![0, 0, 0]);
    }

    #[test]
    fn padded_eval_batches_cover_dataset_exactly_once() {
        // Walking the same (start, take) schedule as eval_logits must
        // enumerate 0..n exactly once in order, whatever the raggedness.
        for (n, bs) in [(10usize, 4usize), (8, 4), (1, 64), (7, 7), (13, 5)] {
            let mut seen = Vec::new();
            let mut i = 0;
            while i < n {
                let take = bs.min(n - i);
                let idx = padded_eval_indices(i, take, bs, n);
                assert_eq!(idx.len(), bs, "every executed batch is the lowered size");
                assert!(idx[take..].iter().all(|&p| p == n - 1), "padding repeats the last row");
                seen.extend_from_slice(&idx[..take]);
                i += take;
            }
            assert_eq!(seen, (0..n).collect::<Vec<_>>());
        }
    }
}
