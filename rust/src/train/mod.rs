//! Training-loop driver: marshals ModelState + batches into the AOT train
//! graph, applies the paper's fine-tuning protocol (fresh training vs
//! fine-tune at 1/10 LR), and evaluates via the eval graph.
//!
//! Graph operand orders are fixed by python/compile/aot.py:
//!   train : params*, momenta*, x, y, masks*, qbw, qba, tlogits,
//!           kd_alpha, kd_tau, exit_w[2], hp[3]      -> params*, momenta*, loss, acc
//!   eval  : params*, masks*, qbw, qba, x            -> logits, e1, e2
//!   init  : seed                                    -> params*, momenta*

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::data::{Batcher, Dataset};
use crate::models::{ArchManifest, ModelState};
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Hyper-parameters for one training run (one chain stage).
#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f32,
    pub momentum: f32,
    pub weight_decay: f32,
    /// KD mixing weight (0 = plain CE) and temperature.
    pub kd_alpha: f32,
    pub kd_tau: f32,
    /// Per-exit loss weights (0 = exits untrained).
    pub exit_w: [f32; 2],
    pub seed: u64,
    pub log_every: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            steps: 200,
            lr: 0.05,
            momentum: 0.9,
            weight_decay: 1e-4,
            kd_alpha: 0.0,
            kd_tau: 4.0,
            exit_w: [0.0, 0.0],
            seed: 0,
            log_every: 0,
        }
    }
}

impl TrainOpts {
    /// The paper's fine-tune rule: same budget discipline, 1/10 LR.
    pub fn fine_tune_of(base: &TrainOpts, steps: usize) -> TrainOpts {
        TrainOpts { steps, lr: base.lr / 10.0, ..base.clone() }
    }
}

#[derive(Debug, Clone, Default)]
pub struct TrainLog {
    pub losses: Vec<f32>,
    pub accs: Vec<f32>,
}

impl TrainLog {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    /// Mean training accuracy over the last quarter of the run.
    pub fn settled_acc(&self) -> f32 {
        let n = self.accs.len();
        if n == 0 {
            return f32::NAN;
        }
        let tail = &self.accs[n - (n / 4).max(1)..];
        tail.iter().sum::<f32>() / tail.len() as f32
    }
}

/// Precomputed teacher logits over a dataset, row-gatherable per batch.
pub struct TeacherLogits {
    pub rows: Tensor, // [n, num_classes]
}

impl TeacherLogits {
    pub fn gather(&self, idx: &[usize]) -> Tensor {
        let c = self.rows.shape[1];
        let mut out = Vec::with_capacity(idx.len() * c);
        for &i in idx {
            out.extend_from_slice(self.rows.row(i));
        }
        Tensor::new(vec![idx.len(), c], out)
    }
}

/// Initialize a fresh ModelState by running the AOT init graph (keeps rust
/// and jax initialization identical by construction).
pub fn init_state(engine: &Engine, arch: Arc<ArchManifest>, seed: u64) -> Result<ModelState> {
    let exe = engine.load(arch.graph("init")?)?;
    let seed_t = Tensor::scalar(seed as f32);
    let outs = exe.run(&[&seed_t]).context("running init graph")?;
    let np = arch.num_params();
    ensure!(outs.len() == 2 * np, "init graph returned {} outputs, want {}", outs.len(), 2 * np);
    let params = outs[..np].to_vec();
    let momenta = outs[np..].to_vec();
    let masks = arch.mask_slots.iter().map(|m| Tensor::ones(&[m.channels])).collect();
    Ok(ModelState {
        arch,
        params,
        momenta,
        masks,
        qbits: crate::models::QBits::FP32,
        exits: Default::default(),
        extras: Default::default(),
        history: Vec::new(),
    })
}

/// Run `opts.steps` SGD steps on `state` in place.
pub fn train(
    engine: &Engine,
    state: &mut ModelState,
    ds: &Dataset,
    teacher: Option<&TeacherLogits>,
    opts: &TrainOpts,
) -> Result<TrainLog> {
    let arch = state.arch.clone();
    let exe = engine.load(arch.graph("train")?)?;
    let bs = arch.train_batch;
    let np = arch.num_params();
    let mut batcher = Batcher::new(ds.len(), bs, opts.seed ^ 0xbadc0de);
    let mut log = TrainLog::default();

    let qbw = Tensor::scalar(state.qbits.weight);
    let qba = Tensor::scalar(state.qbits.act);
    let kd_alpha = Tensor::scalar(if teacher.is_some() { opts.kd_alpha } else { 0.0 });
    let kd_tau = Tensor::scalar(opts.kd_tau);
    let exit_w = Tensor::from_vec(opts.exit_w.to_vec());
    let hp = Tensor::from_vec(vec![opts.lr, opts.momentum, opts.weight_decay]);
    let zero_teacher = Tensor::zeros(&[bs, arch.num_classes]);

    for step in 0..opts.steps {
        let idx = batcher.next_indices().to_vec();
        let (x, y) = ds.batch(&idx);
        let tl = teacher.map(|t| t.gather(&idx));

        let mut inputs: Vec<&Tensor> = Vec::with_capacity(2 * np + 10);
        inputs.extend(state.params.iter());
        inputs.extend(state.momenta.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(state.masks.iter());
        inputs.push(&qbw);
        inputs.push(&qba);
        inputs.push(tl.as_ref().unwrap_or(&zero_teacher));
        inputs.push(&kd_alpha);
        inputs.push(&kd_tau);
        inputs.push(&exit_w);
        inputs.push(&hp);

        let mut outs = exe.run(&inputs).with_context(|| format!("train step {step}"))?;
        ensure!(
            outs.len() == 2 * np + 2,
            "train graph returned {} outputs, want {}",
            outs.len(),
            2 * np + 2
        );
        let acc = outs.pop().unwrap().data[0];
        let loss = outs.pop().unwrap().data[0];
        state.momenta = outs.split_off(np);
        state.params = outs;
        log.losses.push(loss);
        log.accs.push(acc);
        if opts.log_every > 0 && step % opts.log_every == 0 {
            eprintln!("  step {step:>4}  loss {loss:.4}  acc {acc:.3}");
        }
        ensure!(loss.is_finite(), "training diverged at step {step} (loss={loss})");
    }
    Ok(log)
}

/// Full-dataset forward: returns (main logits, exit1 logits, exit2 logits)
/// stacked over the dataset (padding batches internally).
pub fn eval_logits(
    engine: &Engine,
    state: &ModelState,
    ds: &Dataset,
) -> Result<(Tensor, Tensor, Tensor)> {
    let arch = &state.arch;
    let exe = engine.load(arch.graph("eval")?)?;
    let bs = arch.eval_batch;
    let nc = arch.num_classes;
    let n = ds.len();
    let qbw = Tensor::scalar(state.qbits.weight);
    let qba = Tensor::scalar(state.qbits.act);

    let mut main = Vec::with_capacity(n * nc);
    let mut e1 = Vec::with_capacity(n * nc);
    let mut e2 = Vec::with_capacity(n * nc);
    let mut i = 0;
    while i < n {
        let take = bs.min(n - i);
        // Pad the final ragged batch by repeating the last index.
        let mut idx: Vec<usize> = (i..i + take).collect();
        while idx.len() < bs {
            idx.push(n - 1);
        }
        let (x, _) = ds.batch(&idx);
        let mut inputs: Vec<&Tensor> = Vec::with_capacity(arch.num_params() + 8);
        inputs.extend(state.params.iter());
        inputs.extend(state.masks.iter());
        inputs.push(&qbw);
        inputs.push(&qba);
        inputs.push(&x);
        let outs = exe.run(&inputs).context("eval batch")?;
        ensure!(outs.len() == 3, "eval graph returned {} outputs", outs.len());
        main.extend_from_slice(&outs[0].data[..take * nc]);
        e1.extend_from_slice(&outs[1].data[..take * nc]);
        e2.extend_from_slice(&outs[2].data[..take * nc]);
        i += take;
    }
    Ok((
        Tensor::new(vec![n, nc], main),
        Tensor::new(vec![n, nc], e1),
        Tensor::new(vec![n, nc], e2),
    ))
}

/// Top-1 accuracy of the main head.
pub fn eval_accuracy(engine: &Engine, state: &ModelState, ds: &Dataset) -> Result<f64> {
    let (logits, _, _) = eval_logits(engine, state, ds)?;
    Ok(accuracy_of(&logits, &ds.labels))
}

pub fn accuracy_of(logits: &Tensor, labels: &[usize]) -> f64 {
    let preds = logits.argmax_rows();
    let correct = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
    correct as f64 / labels.len().max(1) as f64
}

/// Teacher logits over a dataset (for distillation): the teacher is run
/// once; students gather rows per batch.
pub fn teacher_logits(engine: &Engine, state: &ModelState, ds: &Dataset) -> Result<TeacherLogits> {
    let (logits, _, _) = eval_logits(engine, state, ds)?;
    Ok(TeacherLogits { rows: logits })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_of_counts() {
        let logits = Tensor::new(vec![3, 2], vec![0.9, 0.1, 0.2, 0.8, 0.6, 0.4]);
        assert!((accuracy_of(&logits, &[0, 1, 1]) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn fine_tune_tenth_lr() {
        let base = TrainOpts { lr: 0.05, ..Default::default() };
        let ft = TrainOpts::fine_tune_of(&base, 10);
        assert!((ft.lr - 0.005).abs() < 1e-9);
        assert_eq!(ft.steps, 10);
    }

    #[test]
    fn teacher_gather() {
        let t = TeacherLogits { rows: Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]) };
        let g = t.gather(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
    }
}
