//! Hyper-parameter sweep driver: the §3.1 protocol — per technique, a
//! ladder of aggressiveness settings; per combination, the cross product
//! (or a diagonal of it at smoke scale); early-exit models additionally
//! yield one sample per runtime threshold.

use anyhow::Result;

use crate::chain::{stages, Chain, CompressionStage, StageCtx, Technique};
use crate::exits;
use crate::metrics::Measurement;
use crate::models::{Accountant, ModelState};
use crate::train;

/// Experiment scale profiles (single-core testbed; see DESIGN.md
/// §Substitutions on budget parity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-speed: tiny budgets, 2-point ladders.
    Smoke,
    /// The scale EXPERIMENTS.md numbers are recorded at.
    Default,
    /// Closer to the paper's budgets (hours on this box).
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "default" => Some(Scale::Default),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    /// Steps for one full training stage.
    pub fn base_steps(&self) -> usize {
        match self {
            Scale::Smoke => 40,
            Scale::Default => 220,
            Scale::Paper => 1200,
        }
    }

    /// Train / test set sizes.
    pub fn dataset_sizes(&self) -> (usize, usize) {
        match self {
            Scale::Smoke => (256, 128),
            Scale::Default => (1024, 256),
            Scale::Paper => (4096, 512),
        }
    }

    /// Ladder length per technique in pairwise sweeps.
    pub fn ladder(&self) -> usize {
        match self {
            Scale::Smoke => 2,
            Scale::Default => 4,
            Scale::Paper => 6,
        }
    }
}

/// Aggressiveness ladders (index 0 = mildest).  These are the tunable
/// hyper-parameters behind every scatter point.
pub fn distill_ladder(n: usize) -> Vec<stages::Distill> {
    let widths = [0.75f32, 0.5, 0.35, 0.25, 0.18, 0.12];
    widths.iter().take(n).map(|&width| stages::Distill { width, ..Default::default() }).collect()
}

pub fn prune_ladder(n: usize) -> Vec<stages::Prune> {
    let ratios = [0.25f32, 0.4, 0.55, 0.7, 0.8, 0.88];
    ratios.iter().take(n).map(|&ratio| stages::Prune { ratio, ..Default::default() }).collect()
}

pub fn quantize_ladder(n: usize) -> Vec<stages::Quantize> {
    let bits = [(8.0f32, 8.0f32), (4.0, 8.0), (2.0, 8.0), (1.0, 8.0), (2.0, 4.0), (1.0, 4.0)];
    bits.iter()
        .take(n)
        .map(|&(bits_w, bits_a)| stages::Quantize { bits_w, bits_a, ..Default::default() })
        .collect()
}

pub fn exit_ladder(n: usize) -> Vec<stages::EarlyExit> {
    let ts = [0.95f32, 0.85, 0.7, 0.55, 0.45, 0.35];
    ts.iter().take(n).map(|&threshold| stages::EarlyExit { threshold, ..Default::default() }).collect()
}

/// One boxed stage at ladder position i for a technique.
pub fn stage_at(t: Technique, i: usize, n: usize) -> Box<dyn CompressionStage> {
    let i = i.min(n - 1);
    match t {
        Technique::Distill => Box::new(distill_ladder(n)[i].clone()),
        Technique::Prune => Box::new(prune_ladder(n)[i].clone()),
        Technique::Quantize => Box::new(quantize_ladder(n)[i].clone()),
        Technique::EarlyExit => Box::new(exit_ladder(n)[i].clone()),
    }
}

/// A labelled measured point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub label: String,
    pub config: String,
    pub measurement: Measurement,
}

impl SweepPoint {
    pub fn xy(&self) -> (f64, f64) {
        self.measurement.as_point()
    }
}

/// Run one chain from a shared pretrained base model, returning the final
/// measurement.  If the chain ends in a trained early-exit model, the
/// runtime threshold sweep adds extra points (paper §3.1 rule 3).
pub fn run_chain_points(
    base: &ModelState,
    chain: &Chain,
    ctx: &StageCtx,
    label: &str,
    config: &str,
) -> Result<Vec<SweepPoint>> {
    let mut state = base.clone();
    let reports = chain.run(&mut state, ctx)?;
    let last = reports
        .last()
        .map(|r| r.measurement.clone())
        .unwrap_or(Measurement::take(ctx.engine, &state, ctx.test)?);
    let mut points = vec![SweepPoint {
        label: label.to_string(),
        config: config.to_string(),
        measurement: last,
    }];

    if state.exits.trained {
        // Extra samples from runtime thresholds, no retraining.
        let (main, e1, e2) = train::eval_logits(ctx.engine, &state, ctx.test)?;
        for (t, ev) in
            exits::threshold_sweep(&main, &e1, &e2, &ctx.test.labels, &[0.35, 0.5, 0.65, 0.8, 0.9, 0.97])
        {
            let mut st = state.clone();
            st.exits.thresholds = Some((t, t));
            st.exits.exit_probs = (ev.p_exit1, ev.p_exit2);
            let acct = Accountant::new(&st);
            points.push(SweepPoint {
                label: label.to_string(),
                config: format!("{config},t={t:.2}"),
                measurement: Measurement {
                    accuracy: ev.accuracy,
                    bitops_cr: acct.bitops_cr(),
                    storage_cr: acct.storage_cr(),
                    bitops: acct.expected_bitops(),
                    storage_bits: acct.storage_bits(),
                    exit_probs: (ev.p_exit1, ev.p_exit2),
                },
            });
        }
    }
    Ok(points)
}

/// Pairwise sweep for techniques (a, b) in that order: a diagonal ladder
/// (matched aggressiveness) — the protocol that maximizes coverage per
/// training run on a single-core budget.
pub fn pairwise_points(
    base: &ModelState,
    a: Technique,
    b: Technique,
    ctx: &StageCtx,
    ladder: usize,
) -> Result<Vec<SweepPoint>> {
    let label = format!("{}{}", a.letter(), b.letter());
    let mut out = Vec::new();
    for i in 0..ladder {
        let chain = Chain::new().push(stage_at(a, i, ladder)).push(stage_at(b, i, ladder));
        let cfg = format!("rung{i}");
        out.extend(run_chain_points(base, &chain, ctx, &label, &cfg)?);
    }
    Ok(out)
}

/// Single-technique sweep (the "D alone" / "P alone" curves).
pub fn single_points(
    base: &ModelState,
    t: Technique,
    ctx: &StageCtx,
    ladder: usize,
) -> Result<Vec<SweepPoint>> {
    let label = t.letter().to_string();
    let mut out = Vec::new();
    for i in 0..ladder {
        let chain = Chain::new().push(stage_at(t, i, ladder));
        out.extend(run_chain_points(base, &chain, ctx, &label, &format!("rung{i}"))?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladders_monotone_aggressiveness() {
        let d = distill_ladder(6);
        assert!(d.windows(2).all(|w| w[0].width > w[1].width));
        let p = prune_ladder(6);
        assert!(p.windows(2).all(|w| w[0].ratio < w[1].ratio));
        let q = quantize_ladder(6);
        // Effective bits product must not increase along the ladder.
        assert!(q
            .windows(2)
            .all(|w| w[0].bits_w * w[0].bits_a >= w[1].bits_w * w[1].bits_a));
        let e = exit_ladder(6);
        assert!(e.windows(2).all(|w| w[0].threshold > w[1].threshold));
    }

    #[test]
    fn scales_parse() {
        assert_eq!(Scale::parse("smoke"), Some(Scale::Smoke));
        assert_eq!(Scale::parse("default"), Some(Scale::Default));
        assert_eq!(Scale::parse("paper"), Some(Scale::Paper));
        assert_eq!(Scale::parse("x"), None);
        assert!(Scale::Smoke.base_steps() < Scale::Default.base_steps());
    }

    #[test]
    fn stage_at_covers_all() {
        for t in [Technique::Distill, Technique::Prune, Technique::Quantize, Technique::EarlyExit]
        {
            let s = stage_at(t, 1, 4);
            assert_eq!(s.technique(), t);
        }
    }
}
