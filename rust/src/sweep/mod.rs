//! Hyper-parameter sweep vocabulary: the §3.1 protocol — per technique, a
//! ladder of aggressiveness settings; per combination, the cross product
//! (or a diagonal of it at smoke scale); early-exit models additionally
//! yield one sample per runtime threshold.  Sweeps are *submitted* to the
//! plan layer (`chain::plan`), which dedupes shared stage prefixes and
//! executes each unique prefix once.

use crate::chain::plan::Planner;
use crate::chain::{stages, Chain, CompressionStage, Technique};
use crate::metrics::Measurement;

/// Experiment scale profiles (single-core testbed; see DESIGN.md
/// §Substitutions on budget parity).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-speed: tiny budgets, 2-point ladders.
    Smoke,
    /// The scale EXPERIMENTS.md numbers are recorded at.
    Default,
    /// Closer to the paper's budgets (hours on this box).
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "smoke" => Some(Scale::Smoke),
            "default" => Some(Scale::Default),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }

    /// Stable explicit name, inverse of [`Scale::parse`].  Cache paths and
    /// plan keys use this — never the `Debug` form, which changes when the
    /// enum is refactored.
    pub fn name(&self) -> &'static str {
        match self {
            Scale::Smoke => "smoke",
            Scale::Default => "default",
            Scale::Paper => "paper",
        }
    }

    /// Steps for one full training stage.
    pub fn base_steps(&self) -> usize {
        match self {
            Scale::Smoke => 40,
            Scale::Default => 220,
            Scale::Paper => 1200,
        }
    }

    /// Train / test set sizes.
    pub fn dataset_sizes(&self) -> (usize, usize) {
        match self {
            Scale::Smoke => (256, 128),
            Scale::Default => (1024, 256),
            Scale::Paper => (4096, 512),
        }
    }

    /// Ladder length per technique in pairwise sweeps.
    pub fn ladder(&self) -> usize {
        match self {
            Scale::Smoke => 2,
            Scale::Default => 4,
            Scale::Paper => 6,
        }
    }
}

/// Aggressiveness ladders (index 0 = mildest).  These are the tunable
/// hyper-parameters behind every scatter point.
pub fn distill_ladder(n: usize) -> Vec<stages::Distill> {
    let widths = [0.75f32, 0.5, 0.35, 0.25, 0.18, 0.12];
    widths.iter().take(n).map(|&width| stages::Distill { width, ..Default::default() }).collect()
}

pub fn prune_ladder(n: usize) -> Vec<stages::Prune> {
    let ratios = [0.25f32, 0.4, 0.55, 0.7, 0.8, 0.88];
    ratios.iter().take(n).map(|&ratio| stages::Prune { ratio, ..Default::default() }).collect()
}

pub fn quantize_ladder(n: usize) -> Vec<stages::Quantize> {
    let bits = [(8.0f32, 8.0f32), (4.0, 8.0), (2.0, 8.0), (1.0, 8.0), (2.0, 4.0), (1.0, 4.0)];
    bits.iter()
        .take(n)
        .map(|&(bits_w, bits_a)| stages::Quantize { bits_w, bits_a, ..Default::default() })
        .collect()
}

pub fn exit_ladder(n: usize) -> Vec<stages::EarlyExit> {
    let ts = [0.95f32, 0.85, 0.7, 0.55, 0.45, 0.35];
    ts.iter().take(n).map(|&threshold| stages::EarlyExit { threshold, ..Default::default() }).collect()
}

/// One boxed stage at ladder position i for a technique.
pub fn stage_at(t: Technique, i: usize, n: usize) -> Box<dyn CompressionStage> {
    let i = i.min(n - 1);
    match t {
        Technique::Distill => Box::new(distill_ladder(n)[i].clone()),
        Technique::Prune => Box::new(prune_ladder(n)[i].clone()),
        Technique::Quantize => Box::new(quantize_ladder(n)[i].clone()),
        Technique::EarlyExit => Box::new(exit_ladder(n)[i].clone()),
    }
}

/// A labelled measured point.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub label: String,
    pub config: String,
    pub measurement: Measurement,
}

impl SweepPoint {
    pub fn xy(&self) -> (f64, f64) {
        self.measurement.as_point()
    }
}

/// Submit the pairwise sweep for techniques (a, b) in that order: a
/// diagonal ladder (matched aggressiveness) — the protocol that maximizes
/// coverage per training run on a single-core budget.  The planner dedupes
/// the first-stage rungs against every other chain sharing them; the
/// executor emits one final point per rung plus runtime-threshold extras
/// for trained-exit chains (paper §3.1 rule 3).
pub fn submit_pairwise(plan: &mut Planner, a: Technique, b: Technique, ladder: usize) {
    let label = format!("{}{}", a.letter(), b.letter());
    for i in 0..ladder {
        let chain = Chain::new().push(stage_at(a, i, ladder)).push(stage_at(b, i, ladder));
        plan.submit(chain, &label, &format!("rung{i}"));
    }
}

/// Submit the single-technique sweep (the "D alone" / "P alone" curves).
pub fn submit_single(plan: &mut Planner, t: Technique, ladder: usize) {
    let label = t.letter().to_string();
    for i in 0..ladder {
        plan.submit(Chain::new().push(stage_at(t, i, ladder)), &label, &format!("rung{i}"));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladders_monotone_aggressiveness() {
        let d = distill_ladder(6);
        assert!(d.windows(2).all(|w| w[0].width > w[1].width));
        let p = prune_ladder(6);
        assert!(p.windows(2).all(|w| w[0].ratio < w[1].ratio));
        let q = quantize_ladder(6);
        // Effective bits product must not increase along the ladder.
        assert!(q
            .windows(2)
            .all(|w| w[0].bits_w * w[0].bits_a >= w[1].bits_w * w[1].bits_a));
        let e = exit_ladder(6);
        assert!(e.windows(2).all(|w| w[0].threshold > w[1].threshold));
    }

    #[test]
    fn scales_parse() {
        assert_eq!(Scale::parse("smoke"), Some(Scale::Smoke));
        assert_eq!(Scale::parse("default"), Some(Scale::Default));
        assert_eq!(Scale::parse("paper"), Some(Scale::Paper));
        assert_eq!(Scale::parse("x"), None);
        assert!(Scale::Smoke.base_steps() < Scale::Default.base_steps());
    }

    #[test]
    fn scale_name_roundtrips_through_parse() {
        for sc in [Scale::Smoke, Scale::Default, Scale::Paper] {
            assert_eq!(Scale::parse(sc.name()), Some(sc));
        }
    }

    #[test]
    fn submit_helpers_share_prefixes() {
        use crate::chain::plan::{PlanKey, Planner};
        let mut plan = Planner::new(PlanKey {
            arch: "mini_resnet".into(),
            dataset: "c10".into(),
            scale: "smoke".into(),
            base_steps: 40,
            seed: 42,
        });
        submit_pairwise(&mut plan, Technique::Prune, Technique::Quantize, 2);
        // Two rungs x two stages, no shared prefixes yet.
        assert_eq!(plan.unique_nodes(), 4);
        // The single-P ladder rides entirely on the pairwise P prefixes.
        submit_single(&mut plan, Technique::Prune, 2);
        assert_eq!(plan.unique_nodes(), 4);
        assert_eq!(plan.total_stages(), 6);
    }

    #[test]
    fn stage_at_covers_all() {
        for t in [Technique::Distill, Technique::Prune, Technique::Quantize, Technique::EarlyExit]
        {
            let s = stage_at(t, 1, 4);
            assert_eq!(s.technique(), t);
        }
    }
}
