//! # Chain of Compression
//!
//! A rust + JAX/Pallas reproduction of *"Chain of Compression: A Systematic
//! Approach to Combinationally Compress Convolutional Neural Networks"*
//! (a.k.a. "Order of Compression", Shen et al., 2024).
//!
//! Three layers (see DESIGN.md):
//!
//! * **L1** — Pallas fake-quant / qmatmul kernels (`python/compile/kernels/`),
//! * **L2** — JAX CNN train/eval graphs with all four compression knobs as
//!   runtime operands (`python/compile/`), AOT-lowered to HLO text once,
//! * **L3** — this crate: the coordinator that owns datasets, training
//!   loops, the four compression stages, the plan/executor layer that
//!   dedupes and caches shared chain prefixes (`chain::plan`: prefix
//!   trie, content-addressed state snapshots, `--jobs` worker engines),
//!   order search, metrics, experiment drivers and the concurrent
//!   early-exit serving subsystem (request queue, dynamic micro-batching,
//!   multi-worker PJRT engines — see `serve`), executing the AOT graphs
//!   via PJRT (`xla` crate).  Python never runs at experiment time.
//!
//! Quickstart: see `examples/quickstart.rs`; experiments: `coc exp <id>`;
//! serving benchmark: `coc serve-bench --workers 4`.

pub mod chain;
pub mod data;
pub mod exits;
pub mod exp;
pub mod faults;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod order;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sweep;
pub mod tensor;
pub mod train;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

/// Default artifacts directory (relative to the repo root).
pub const DEFAULT_ARTIFACTS: &str = "artifacts";
/// Default results directory.
pub const DEFAULT_RESULTS: &str = "results";
