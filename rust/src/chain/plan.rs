//! Plan/executor split for the compression chain.
//!
//! The paper's experiments are *combinatorial*: Table 1 runs all six
//! distill-started orders, the pairwise figures run every pair twice, and
//! `DPQE` / `DPEQ` share the whole `DP` prefix.  Running each `Chain`
//! imperatively from the pretrained base re-trains every shared prefix
//! once per chain.  This module splits that into:
//!
//! 1. **Plan** — experiments *submit* whole chains to a [`Planner`], which
//!    merges them into a prefix trie.  Every trie node is content-addressed
//!    by a [`NodeId`]: the FNV-1a-128 hash chain of the [`PlanKey`]
//!    (arch, dataset, scale, seed) and the [`CompressionStage::fingerprint`]
//!    of every stage on the path, so a node *is* the exact recipe that
//!    produced its state.
//! 2. **Execute** — the executor walks the trie once per unique node.
//!    With a cache directory, each node's `ModelState` is snapshotted to
//!    `<node_id>.state` (via `ModelState::save_tagged`, header-verified on
//!    load) and its `Measurement` to `<node_id>.meas.json`; re-runs replay
//!    both and interrupted runs resume from the deepest cached prefix.
//!    Independent branches can run on a worker pool (`--jobs N`), one
//!    engine per thread — the same pattern as `serve::worker`, because
//!    PJRT handles are not `Send`.
//!
//! Cached and uncached runs are equal by construction: stages are pure
//! functions of (state, fixed seeds), state files round-trip exact f32
//! bytes, and measurement JSON round-trips exact f64s (shortest
//! round-trippable formatting).  `rust/tests/plan_cache.rs` proves it.
//!
//! Known trade-off: replay deserializes each node's snapshot eagerly even
//! when no child misses; at this testbed's model sizes (sub-MB states)
//! that warm-run I/O is negligible, and lazy interior loads are the first
//! optimization to reach for if states grow by orders of magnitude.

use std::borrow::Borrow;
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::{Chain, CompressionStage, StageCtx, StageReport};
use crate::data::Dataset;
use crate::exits;
use crate::metrics::Measurement;
use crate::models::{Accountant, ModelState};
use crate::runtime::{Engine, RuntimeStats};
use crate::sweep::SweepPoint;
use crate::train;
use crate::util::json::Json;

/// Bump to invalidate every existing plan cache entry (the version is
/// hashed into the root id).  v2: the ref backend's canonical
/// accumulation order changed (blocked kernels, lane-striped reductions,
/// zero-skips removed), so states trained by the v1 kernels must never
/// be replayed as prefixes of runs on the new ones.
pub const PLAN_FORMAT_VERSION: u32 = 2;

// ---------------------------------------------------------------------------
// Content addressing
// ---------------------------------------------------------------------------

const FNV128_OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
const FNV128_PRIME: u128 = 0x0000000001000000000000000000013b;

fn fnv1a128(seed: u128, bytes: &[u8]) -> u128 {
    let mut h = seed;
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(FNV128_PRIME);
    }
    h
}

/// Content address of a (possibly intermediate) compressed model state:
/// the hash chain of the plan key and every stage fingerprint applied so
/// far.  Display form (32 hex chars) names the cache files.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(u128);

impl NodeId {
    fn root(key: &PlanKey) -> NodeId {
        NodeId(fnv1a128(FNV128_OFFSET, key.canonical().as_bytes()))
    }

    fn child(self, fingerprint: &str) -> NodeId {
        // Length-prefix each link so the byte stream is unambiguous: a
        // stage fingerprinted "a/b" must never alias the path "a" -> "b".
        let h = fnv1a128(self.0, &(fingerprint.len() as u64).to_le_bytes());
        NodeId(fnv1a128(h, fingerprint.as_bytes()))
    }
}

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:032x}", self.0)
    }
}

/// Everything *outside* the stage sequence that determines a trained
/// state: architecture, dataset kind, scale profile (dataset sizes), the
/// per-stage training budget, and the seed.  All strings are stable
/// explicit names — never `{:?}` of an enum — so cache addresses survive
/// refactors.  `base_steps` is hashed explicitly (not implied by the
/// scale name) so a caller that changes its training budget without
/// renaming the scale can never replay stale states.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanKey {
    pub arch: String,
    pub dataset: String,
    pub scale: String,
    pub base_steps: usize,
    pub seed: u64,
}

impl PlanKey {
    fn canonical(&self) -> String {
        format!(
            "coc-plan-v{}|arch={}|data={}|scale={}|steps={}|seed={}",
            PLAN_FORMAT_VERSION, self.arch, self.dataset, self.scale, self.base_steps, self.seed
        )
    }
}

// ---------------------------------------------------------------------------
// The plan: a prefix trie of stages
// ---------------------------------------------------------------------------

struct Node {
    stage: Arc<dyn CompressionStage>,
    id: NodeId,
    parent: Option<usize>,
    children: Vec<usize>,
}

struct SubmittedChain {
    label: String,
    config: String,
    /// Trie node indices along this chain, in stage order.
    path: Vec<usize>,
}

/// Merges submitted chains into a prefix trie and executes each unique
/// node exactly once.
pub struct Planner {
    key: PlanKey,
    root_id: NodeId,
    nodes: Vec<Node>,
    /// (parent index or -1, stage fingerprint) -> node index.
    index: BTreeMap<(i64, String), usize>,
    chains: Vec<SubmittedChain>,
}

impl Planner {
    pub fn new(key: PlanKey) -> Planner {
        let root_id = NodeId::root(&key);
        Planner { key, root_id, nodes: Vec::new(), index: BTreeMap::new(), chains: Vec::new() }
    }

    pub fn key(&self) -> &PlanKey {
        &self.key
    }

    /// Merge a chain into the trie; returns the chain's index (outcome
    /// order matches submission order).
    pub fn submit(&mut self, chain: Chain, label: &str, config: &str) -> usize {
        let mut parent: Option<usize> = None;
        let mut path = Vec::with_capacity(chain.stages.len());
        for stage in chain.stages {
            let stage: Arc<dyn CompressionStage> = Arc::from(stage);
            let fp = stage.fingerprint();
            let key = (parent.map(|p| p as i64).unwrap_or(-1), fp.clone());
            let idx = match self.index.get(&key) {
                Some(&i) => i,
                None => {
                    let id = match parent {
                        Some(p) => self.nodes[p].id.child(&fp),
                        None => self.root_id.child(&fp),
                    };
                    let i = self.nodes.len();
                    self.nodes.push(Node { stage, id, parent, children: Vec::new() });
                    if let Some(p) = parent {
                        self.nodes[p].children.push(i);
                    }
                    self.index.insert(key, i);
                    i
                }
            };
            path.push(idx);
            parent = Some(idx);
        }
        self.chains.push(SubmittedChain {
            label: label.to_string(),
            config: config.to_string(),
            path,
        });
        self.chains.len() - 1
    }

    /// Unique trie nodes — the number of stage executions a cold run pays.
    pub fn unique_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Total stage applications requested across all submitted chains —
    /// what the pre-planner implementation paid.
    pub fn total_stages(&self) -> usize {
        self.chains.iter().map(|c| c.path.len()).sum()
    }

    pub fn num_chains(&self) -> usize {
        self.chains.len()
    }

    /// Distinct first stages across all chains (e.g. the six
    /// distill-started orders share exactly one `D` root child).
    pub fn root_children(&self) -> usize {
        self.nodes.iter().filter(|n| n.parent.is_none()).count()
    }

    /// Content addresses along a submitted chain (tests + diagnostics).
    pub fn chain_node_ids(&self, chain: usize) -> Vec<NodeId> {
        self.chains[chain].path.iter().map(|&i| self.nodes[i].id).collect()
    }

    /// All nodes strictly below `root` in the trie — the subtree that is
    /// skipped when `root` is quarantined.
    fn descendants(&self, root: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut stack = self.nodes[root].children.clone();
        while let Some(i) = stack.pop() {
            out.push(i);
            stack.extend_from_slice(&self.nodes[i].children);
        }
        out
    }
}

// ---------------------------------------------------------------------------
// The executor
// ---------------------------------------------------------------------------

/// How one trie node is applied and measured.  The production
/// implementation is [`EngineRunner`] (over a PJRT or reference-backend
/// engine); tests substitute an engine-free runner to exercise the
/// executor and cache without artifacts.
pub trait NodeRunner {
    fn apply(&self, stage: &dyn CompressionStage, state: &mut ModelState) -> Result<()>;
    fn measure(&self, state: &ModelState) -> Result<Measurement>;
    /// Extra measurements derived from a chain's final state without
    /// retraining (the runtime threshold sweep of trained-exit models),
    /// as (config-suffix, measurement) pairs — the executor applies the
    /// chain's label/config and caches them per leaf node.
    fn extra_measurements(&self, state: &ModelState) -> Result<Vec<(String, Measurement)>>;
    /// Identity of the extra-measurement semantics (e.g. the runtime
    /// threshold grid).  Node ids don't cover it, so cached extras record
    /// this signature and a mismatch is a miss — editing the grid can
    /// never silently replay stale sweeps.
    fn extras_signature(&self) -> String {
        String::new()
    }
    /// Cumulative runtime counters of this runner's engine, if it has one
    /// — the executor diffs them around a run so `PlanStats` (and
    /// `results/plan_stats.csv`) report host<->device transfer volume.
    /// Engine-free test runners return `None` and account zero.
    fn runtime_stats(&self) -> Option<RuntimeStats> {
        None
    }
}

/// Executes stages through a [`runtime::Engine`](crate::runtime::Engine)
/// of either backend: `apply` builds a [`StageCtx`] over the engine +
/// datasets, `measure` is `Measurement::take`, and `extra_measurements`
/// is the paper's §3.1 runtime-threshold sweep.  Generic over engine
/// ownership: the main thread borrows the experiment engine, worker
/// threads own one engine each (engines are per-thread on every backend
/// — PJRT handles are not `Send`).
pub struct EngineRunner<'d, E: Borrow<Engine>> {
    engine: E,
    train: &'d Dataset,
    test: &'d Dataset,
    base_steps: usize,
    seed: u64,
    verbose: bool,
}

impl<'d, E: Borrow<Engine>> EngineRunner<'d, E> {
    pub fn new(
        engine: E,
        train: &'d Dataset,
        test: &'d Dataset,
        base_steps: usize,
        seed: u64,
        verbose: bool,
    ) -> Self {
        EngineRunner { engine, train, test, base_steps, seed, verbose }
    }

    fn ctx(&self) -> StageCtx<'_> {
        StageCtx {
            engine: self.engine.borrow(),
            train: self.train,
            test: self.test,
            base_steps: self.base_steps,
            seed: self.seed,
            verbose: self.verbose,
        }
    }
}

impl<'d, E: Borrow<Engine>> NodeRunner for EngineRunner<'d, E> {
    fn apply(&self, stage: &dyn CompressionStage, state: &mut ModelState) -> Result<()> {
        stage.apply(state, &self.ctx())
    }

    fn measure(&self, state: &ModelState) -> Result<Measurement> {
        Measurement::take(self.engine.borrow(), state, self.test)
    }

    fn extra_measurements(&self, state: &ModelState) -> Result<Vec<(String, Measurement)>> {
        if !state.exits.trained {
            return Ok(Vec::new());
        }
        // Extra samples from runtime thresholds, no retraining.
        let (main, e1, e2) = train::eval_logits(self.engine.borrow(), state, self.test)?;
        let mut out = Vec::new();
        for (t, ev) in exits::threshold_sweep(
            &main,
            &e1,
            &e2,
            &self.test.labels,
            &EXIT_SWEEP_THRESHOLDS,
        ) {
            let mut st = state.clone();
            st.exits.thresholds = Some((t, t));
            st.exits.exit_probs = (ev.p_exit1, ev.p_exit2);
            let acct = Accountant::new(&st);
            out.push((
                format!("t={t:.2}"),
                Measurement {
                    accuracy: ev.accuracy,
                    bitops_cr: acct.bitops_cr(),
                    storage_cr: acct.storage_cr(),
                    bitops: acct.expected_bitops(),
                    storage_bits: acct.storage_bits(),
                    exit_probs: (ev.p_exit1, ev.p_exit2),
                },
            ));
        }
        Ok(out)
    }

    fn extras_signature(&self) -> String {
        let grid: Vec<String> = EXIT_SWEEP_THRESHOLDS.iter().map(|t| t.to_string()).collect();
        format!("tsweep|{}", grid.join(","))
    }

    fn runtime_stats(&self) -> Option<RuntimeStats> {
        Some(self.engine.borrow().stats())
    }
}

/// Runtime threshold grid for the paper's §3.1 exit sweep.  Part of
/// [`NodeRunner::extras_signature`]: changing it invalidates cached
/// extras automatically.
const EXIT_SWEEP_THRESHOLDS: [f32; 6] = [0.35, 0.5, 0.65, 0.8, 0.9, 0.97];

/// Execution knobs, surfaced on the CLI as `--jobs N` / `--no-cache`.
#[derive(Debug, Clone)]
pub struct ExecOpts {
    /// Worker threads; `<= 1` runs serially on the caller's runner.
    pub jobs: usize,
    /// Snapshot/replay directory; `None` disables caching.
    pub cache_dir: Option<PathBuf>,
    /// Compute runtime-threshold extras for trained-exit leaves.  Drivers
    /// that only read per-stage reports turn this off and skip the
    /// per-leaf eval entirely.
    pub extras: bool,
    pub verbose: bool,
    /// Lower every distinct leaf state to its packed `CompressedModel`
    /// after the run (`--lower`): logs packed-vs-dense bytes and, with a
    /// cache dir, publishes the artifact as `<node_id>.cmp`.
    pub lower: bool,
    /// Extra attempts a failing node gets (doubling backoff) before it is
    /// quarantined and its subtree skipped.
    pub retries: u32,
    /// Base sleep between node retry attempts.
    pub retry_backoff: Duration,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts {
            jobs: 1,
            cache_dir: None,
            extras: true,
            verbose: false,
            lower: false,
            retries: 2,
            retry_backoff: Duration::from_millis(10),
        }
    }
}

/// Per-execute accounting, logged and written to `results/plan_stats.csv`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlanStats {
    pub chains: usize,
    pub total_stages: usize,
    pub unique_nodes: usize,
    pub cache_hits: usize,
    pub executed: usize,
    pub wall_ms: f64,
    /// Host<->device transfer volume across the run: the main runner's
    /// engine delta plus every parallel worker engine's lifetime totals.
    /// Zero under engine-free runners (tests).  Tracked so the
    /// device-residency win shows up in BENCH trajectories as bytes, not
    /// just wall time.
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
    /// Nodes that exhausted their retries and were quarantined.
    pub quarantined: usize,
    /// Nodes never attempted because a quarantined ancestor cut them off.
    pub skipped: usize,
}

/// One quarantined node in a partial run: the content address (which is
/// also the resume key — a rerun over the same cache re-attempts exactly
/// this node), the stage, the error that exhausted its retries, and the
/// submitted chains it cut off.
#[derive(Debug, Clone)]
pub struct NodeFailure {
    pub node: String,
    pub stage: String,
    pub error: String,
    pub chains: Vec<String>,
}

/// One submitted chain after execution: the per-stage reports (same shape
/// `Chain::run` produced) plus the final state for runtime sweeps.
/// `final_state` is shared, not cloned — chains ending on the same trie
/// node hand out the same `Arc`.
pub struct ChainOutcome {
    pub label: String,
    pub config: String,
    pub reports: Vec<StageReport>,
    pub final_state: Arc<ModelState>,
}

/// Everything an experiment driver needs back from one plan execution.
pub struct PlanRun {
    /// Completed chains only — a chain cut off by a quarantined node is
    /// reported in `failures` instead.
    pub outcomes: Vec<ChainOutcome>,
    /// `SweepPoint`s in submission order (completed chains): final
    /// measurement per chain plus runtime-threshold extras for
    /// trained-exit final states — exactly what the pre-planner
    /// `run_chain_points` emitted per chain.
    pub points: Vec<SweepPoint>,
    pub stats: PlanStats,
    /// Quarantined nodes, if any: empty means every chain completed.
    /// Non-empty runs are resumable — completed nodes are cached, so a
    /// rerun over the same cache dir re-attempts only the failures.
    pub failures: Vec<NodeFailure>,
}

/// `state` is `Arc`ed so worker threads can take a cheap handle under the
/// scheduler lock and clone the tensors outside it, and `Option` so
/// interior states can be dropped as soon as every child has consumed
/// them — peak memory is O(frontier + chain leaves), not O(unique nodes).
struct NodeResult {
    state: Option<Arc<ModelState>>,
    meas: Measurement,
    hit: bool,
}

/// Scheduler state shared by the worker pool.
struct Sched {
    ready: Vec<usize>,
    results: Vec<Option<NodeResult>>,
    /// Children not yet executed, per node; at zero a non-leaf state drops.
    pending: Vec<usize>,
    done: usize,
    /// Per-node quarantine record: the error that exhausted its retries.
    failed: Vec<Option<String>>,
    /// Nodes never attempted because a quarantined ancestor cut them off.
    skipped: Vec<bool>,
    /// Fatal only (worker panic, runner setup failure) — node failures
    /// quarantine instead so sibling branches finish.
    error: Option<String>,
    /// (bytes_uploaded, bytes_downloaded) credited by each retiring
    /// worker from its per-thread engine.
    transfer: (u64, u64),
}

/// Armed for the whole life of a worker thread: if the worker unwinds
/// (a stage panic, an `expect` firing) instead of returning, the guard
/// records the failure and wakes every peer so `thread::scope` can join
/// and propagate the panic — without it, waiters sleep on the condvar
/// forever and the process hangs.
struct PanicGuard<'a> {
    sched: &'a Mutex<Sched>,
    cv: &'a Condvar,
    armed: bool,
}

impl Drop for PanicGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            if let Ok(mut g) = self.sched.lock() {
                if g.error.is_none() {
                    g.error = Some("plan worker panicked".to_string());
                }
            }
            self.cv.notify_all();
        }
    }
}

/// Drop a finished node's parent state once its last child has consumed
/// it, unless some chain still needs it as a final state.
fn release_parent(
    parent: Option<usize>,
    results: &mut [Option<NodeResult>],
    pending: &mut [usize],
    leaf: &[bool],
) {
    if let Some(p) = parent {
        pending[p] -= 1;
        if pending[p] == 0 && !leaf[p] {
            if let Some(r) = &mut results[p] {
                r.state = None;
            }
        }
    }
}

impl Planner {
    /// Execute every unique node once and synthesize per-chain outcomes.
    ///
    /// `main` is the caller-thread runner (used for serial execution and
    /// for point synthesis); `factory` builds one runner per worker thread
    /// when `opts.jobs > 1` and is never called otherwise.
    pub fn execute<R, R2, F>(
        &self,
        base: &ModelState,
        main: &R,
        opts: &ExecOpts,
        factory: F,
    ) -> Result<PlanRun>
    where
        R: NodeRunner,
        R2: NodeRunner,
        F: Fn() -> Result<R2> + Sync,
    {
        let t0 = Instant::now();
        // Transfer accounting: diff the main runner's engine counters
        // around the whole run (node execution on the serial path plus
        // measurement synthesis below); parallel worker engines are
        // per-thread and credited as they retire.
        let transfer_before = main.runtime_stats();
        if let Some(dir) = &opts.cache_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating plan cache dir {}", dir.display()))?;
        }
        let cache_dir = opts.cache_dir.as_deref();
        // Retention policy: which nodes end some chain (their states are
        // needed at synthesis) and how many children each node still owes.
        let mut leaf = vec![false; self.nodes.len()];
        for ch in &self.chains {
            if let Some(&i) = ch.path.last() {
                leaf[i] = true;
            }
        }
        let pending: Vec<usize> = self.nodes.iter().map(|n| n.children.len()).collect();

        let (results, failed, worker_transfer) = if opts.jobs > 1 && self.nodes.len() > 1 {
            self.execute_parallel(base, opts, cache_dir, &leaf, pending, &factory)?
        } else {
            let (r, f) = self.execute_serial(base, main, cache_dir, &leaf, pending, opts)?;
            (r, f, (0, 0))
        };

        let cache_hits = results.iter().filter(|r| r.as_ref().is_some_and(|r| r.hit)).count();
        let quarantined = failed.iter().filter(|f| f.is_some()).count();
        let unavailable = results.iter().filter(|r| r.is_none()).count();
        let mut stats = PlanStats {
            chains: self.chains.len(),
            total_stages: self.total_stages(),
            unique_nodes: self.nodes.len(),
            cache_hits,
            executed: self.nodes.len() - cache_hits - unavailable,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            bytes_uploaded: worker_transfer.0,
            bytes_downloaded: worker_transfer.1,
            quarantined,
            skipped: unavailable - quarantined,
        };
        // Resumable failure report: every quarantined node with the
        // chains it cut off.  The node id doubles as the resume key —
        // rerunning over the same cache re-attempts exactly these nodes.
        let failures: Vec<NodeFailure> = failed
            .iter()
            .enumerate()
            .filter_map(|(i, e)| {
                e.as_ref().map(|err| NodeFailure {
                    node: self.nodes[i].id.to_string(),
                    stage: self.nodes[i].stage.name(),
                    error: err.clone(),
                    chains: self
                        .chains
                        .iter()
                        .filter(|c| c.path.contains(&i))
                        .map(|c| c.label.clone())
                        .collect(),
                })
            })
            .collect();
        crate::obs::log!(
            crate::obs::Level::Info,
            "[plan] {} chains / {} stage applications -> {} unique nodes ({} cache hits, {} executed, {} quarantined, {} skipped) in {:.1}s",
            stats.chains,
            stats.total_stages,
            stats.unique_nodes,
            stats.cache_hits,
            stats.executed,
            stats.quarantined,
            stats.skipped,
            stats.wall_ms / 1e3
        );
        for f in &failures {
            crate::obs::log!(
                crate::obs::Level::Warn,
                "[plan] quarantined node {} ({}) cut chains [{}]: {}",
                f.node,
                f.stage,
                f.chains.join(","),
                f.error
            );
        }

        // Synthesize per-chain outcomes and sweep points.  Leaf extras
        // (the runtime threshold sweep) are content-addressed too:
        // replayed from `<node_id>.extras.json` on warm runs, computed
        // once per distinct leaf otherwise.
        let mut extras_memo: BTreeMap<NodeId, Vec<(String, Measurement)>> = BTreeMap::new();
        let mut outcomes = Vec::with_capacity(self.chains.len());
        let mut outcome_leaves: Vec<Option<NodeId>> = Vec::with_capacity(self.chains.len());
        let mut points = Vec::new();
        for ch in &self.chains {
            // A chain through a quarantined (or skipped-descendant) node
            // has no complete result — it is reported via `failures`.
            if ch.path.iter().any(|&i| results[i].is_none()) {
                continue;
            }
            let reports: Vec<StageReport> = ch
                .path
                .iter()
                .map(|&i| StageReport {
                    stage: self.nodes[i].stage.name(),
                    technique: self.nodes[i].stage.technique(),
                    measurement: results[i].as_ref().expect("complete chain").meas.clone(),
                })
                .collect();
            let final_state: Arc<ModelState> = match ch.path.last() {
                Some(&i) => results[i]
                    .as_ref()
                    .expect("complete chain")
                    .state
                    .clone()
                    .expect("leaf state retained"),
                None => Arc::new(base.clone()),
            };
            let last = match reports.last() {
                Some(r) => r.measurement.clone(),
                None => main.measure(&final_state)?,
            };
            points.push(SweepPoint {
                label: ch.label.clone(),
                config: ch.config.clone(),
                measurement: last,
            });
            if opts.extras && final_state.exits.trained {
                let extras = match ch.path.last() {
                    Some(&i) => leaf_extras(
                        self.nodes[i].id,
                        &final_state,
                        main,
                        cache_dir,
                        &mut extras_memo,
                    )?,
                    None => main.extra_measurements(&final_state)?,
                };
                points.extend(extras.into_iter().map(|(suffix, m)| SweepPoint {
                    label: ch.label.clone(),
                    config: format!("{},{suffix}", ch.config),
                    measurement: m,
                }));
            }
            outcomes.push(ChainOutcome {
                label: ch.label.clone(),
                config: ch.config.clone(),
                reports,
                final_state,
            });
            outcome_leaves.push(ch.path.last().map(|&i| self.nodes[i].id));
        }
        if opts.lower {
            // Lower-at-leaf hook (`--lower`): pack every distinct leaf
            // state into its `CompressedModel` — what compressed serving
            // would actually ship — log packed-vs-dense bytes, and with a
            // cache dir publish the packed artifact as `<node_id>.cmp`.
            // A leaf the packed kernels cannot represent is a real error.
            let mut lowered: std::collections::BTreeSet<NodeId> = std::collections::BTreeSet::new();
            for (out, leaf_id) in outcomes.iter().zip(&outcome_leaves) {
                let Some(id) = *leaf_id else { continue };
                if !lowered.insert(id) {
                    continue;
                }
                let cm = crate::models::compressed::CompressedModel::lower(&out.final_state)
                    .with_context(|| format!("lowering leaf {id} ({})", out.label))?;
                let packed = cm.packed_bytes();
                let dense =
                    crate::models::compressed::CompressedModel::dense_bytes(&out.final_state.arch);
                crate::obs::log!(
                    crate::obs::Level::Info,
                    "[plan] leaf {id} ({}) lowered: {dense} -> {packed} bytes ({:.2}x)",
                    out.label,
                    dense as f64 / packed.max(1) as f64
                );
                if let Some(dir) = cache_dir {
                    let path = dir.join(format!("{id}.cmp"));
                    cm.save(&path).with_context(|| {
                        format!("saving lowered leaf {}", path.display())
                    })?;
                }
            }
        }
        if let (Some(b), Some(a)) = (transfer_before, main.runtime_stats()) {
            stats.bytes_uploaded += a.bytes_uploaded.saturating_sub(b.bytes_uploaded);
            stats.bytes_downloaded += a.bytes_downloaded.saturating_sub(b.bytes_downloaded);
        }
        Ok(PlanRun { outcomes, points, stats, failures })
    }

    fn execute_serial<R: NodeRunner>(
        &self,
        base: &ModelState,
        runner: &R,
        cache_dir: Option<&Path>,
        leaf: &[bool],
        mut pending: Vec<usize>,
        opts: &ExecOpts,
    ) -> Result<(Vec<Option<NodeResult>>, Vec<Option<String>>)> {
        // Submission order is topological: parents are interned before
        // their children.
        let n = self.nodes.len();
        let mut results: Vec<Option<NodeResult>> = (0..n).map(|_| None).collect();
        let mut failed: Vec<Option<String>> = vec![None; n];
        let mut skip = vec![false; n];
        for (i, node) in self.nodes.iter().enumerate() {
            if skip[i] {
                continue;
            }
            let parent_state = match node.parent {
                Some(p) => results[p]
                    .as_ref()
                    .and_then(|r| r.state.as_deref())
                    .expect("parent state retained"),
                None => base,
            };
            match run_node(runner, node, parent_state, cache_dir, opts) {
                Ok(res) => results[i] = Some(res),
                Err(e) => {
                    // Quarantine: this node's whole subtree is cut off,
                    // sibling branches keep executing.
                    crate::obs::metrics::counter("plan.node.quarantined").incr();
                    failed[i] = Some(format!("{e:#}"));
                    for d in self.descendants(i) {
                        skip[d] = true;
                    }
                }
            }
            release_parent(node.parent, &mut results, &mut pending, leaf);
        }
        Ok((results, failed))
    }

    fn execute_parallel<R2, F>(
        &self,
        base: &ModelState,
        opts: &ExecOpts,
        cache_dir: Option<&Path>,
        leaf: &[bool],
        pending: Vec<usize>,
        factory: &F,
    ) -> Result<(Vec<Option<NodeResult>>, Vec<Option<String>>, (u64, u64))>
    where
        R2: NodeRunner,
        F: Fn() -> Result<R2> + Sync,
    {
        let n = self.nodes.len();
        let init = Sched {
            ready: (0..n).filter(|&i| self.nodes[i].parent.is_none()).collect(),
            results: (0..n).map(|_| None).collect(),
            pending,
            done: 0,
            failed: vec![None; n],
            skipped: vec![false; n],
            error: None,
            transfer: (0, 0),
        };
        let sched = Mutex::new(init);
        let cv = Condvar::new();
        // The ready frontier is an antichain, and every frontier node
        // extends to a distinct leaf — so leaf count bounds useful
        // parallelism.  A linear chain gets exactly one worker no matter
        // how large --jobs is.
        let width = self.nodes.iter().filter(|nd| nd.children.is_empty()).count().max(1);
        let jobs = opts.jobs.min(n).min(width);

        std::thread::scope(|s| {
            for _ in 0..jobs {
                s.spawn(|| {
                    let mut guard = PanicGuard { sched: &sched, cv: &cv, armed: true };
                    // One runner (one engine) per worker thread, built
                    // lazily on the first node this worker actually pops —
                    // a narrow trie (e.g. one linear chain) never pays for
                    // engines that would only block on the condvar.
                    let mut runner: Option<R2> = None;
                    // Credit this worker's engine transfer counters into
                    // the shared accounting on the way out (the engine —
                    // and its stats — drop with the runner).
                    let credit = |runner: &Option<R2>| {
                        if let Some(st) = runner.as_ref().and_then(|r| r.runtime_stats()) {
                            let mut g = sched.lock().unwrap();
                            g.transfer.0 += st.bytes_uploaded;
                            g.transfer.1 += st.bytes_downloaded;
                        }
                    };
                    loop {
                        // Under the lock, only pop a node and take a cheap
                        // Arc handle on its parent; tensor clones happen
                        // outside so workers never serialize on a memcpy.
                        let (idx, parent_arc) = {
                            let mut g = sched.lock().unwrap();
                            loop {
                                if g.error.is_some() || g.done == n {
                                    drop(g);
                                    credit(&runner);
                                    guard.armed = false;
                                    return;
                                }
                                if let Some(i) = g.ready.pop() {
                                    let ps = match self.nodes[i].parent {
                                        Some(p) => Some(
                                            g.results[p]
                                                .as_ref()
                                                .and_then(|r| r.state.clone())
                                                .expect("parent state retained"),
                                        ),
                                        None => None,
                                    };
                                    break (i, ps);
                                }
                                g = cv.wait(g).unwrap();
                            }
                        };
                        if runner.is_none() {
                            match factory() {
                                Ok(r) => runner = Some(r),
                                Err(e) => {
                                    sched.lock().unwrap().error =
                                        Some(format!("plan worker setup: {e:#}"));
                                    cv.notify_all();
                                    guard.armed = false;
                                    return;
                                }
                            }
                        }
                        let parent_state = parent_arc.as_deref().unwrap_or(base);
                        match run_node(
                            runner.as_ref().expect("runner built above"),
                            &self.nodes[idx],
                            parent_state,
                            cache_dir,
                            opts,
                        ) {
                            Ok(res) => {
                                let mut g = sched.lock().unwrap();
                                g.results[idx] = Some(res);
                                g.done += 1;
                                g.ready.extend_from_slice(&self.nodes[idx].children);
                                let Sched { results, pending, .. } = &mut *g;
                                release_parent(self.nodes[idx].parent, results, pending, leaf);
                                cv.notify_all();
                            }
                            Err(e) => {
                                // Quarantine the node and account its
                                // whole subtree as done-without-result;
                                // descendants were never enqueued (only a
                                // successful parent pushes children), so
                                // sibling branches keep running and the
                                // done==n termination still holds.
                                crate::obs::metrics::counter("plan.node.quarantined").incr();
                                let mut g = sched.lock().unwrap();
                                g.failed[idx] = Some(format!("{e:#}"));
                                g.done += 1;
                                for d in self.descendants(idx) {
                                    if !g.skipped[d] {
                                        g.skipped[d] = true;
                                        g.done += 1;
                                    }
                                }
                                let Sched { results, pending, .. } = &mut *g;
                                release_parent(self.nodes[idx].parent, results, pending, leaf);
                                cv.notify_all();
                            }
                        }
                    }
                });
            }
        });

        let g = sched.into_inner().unwrap_or_else(|e| e.into_inner());
        if let Some(e) = g.error {
            return Err(anyhow!("plan execution failed: {e}"));
        }
        if g.done != n {
            return Err(anyhow!("plan execution stalled at {}/{n} nodes", g.done));
        }
        Ok((g.results, g.failed, g.transfer))
    }
}

/// Run one trie node: replay from the content-addressed cache when both
/// the tagged state snapshot and the measurement sidecar are valid, else
/// apply the stage to a clone of the parent state and snapshot the result.
///
/// Failure domains: a corrupt snapshot (checksum mismatch, truncation) is
/// rotated aside to `.corrupt` and treated as a miss; a failing stage is
/// retried `opts.retries` times with doubling backoff before the error
/// propagates (and the caller quarantines the node).
fn run_node<R: NodeRunner>(
    runner: &R,
    node: &Node,
    parent: &ModelState,
    cache_dir: Option<&Path>,
    opts: &ExecOpts,
) -> Result<NodeResult> {
    // One span per node lifecycle: covers the cache probe and, on a miss,
    // the apply + measure + snapshot.  Hits/misses also land in the
    // metrics registry so plan reuse is visible without a trace file.
    let _span = crate::obs::trace::span_with(|| format!("plan.node.{}", node.stage.name()));
    let verbose = opts.verbose;
    let tag = node.id.to_string();
    let paths = cache_dir.map(|d| (d.join(format!("{tag}.state")), d.join(format!("{tag}.meas.json"))));
    if let Some((sp, mp)) = &paths {
        if sp.exists() && mp.exists() {
            let loaded = ModelState::load_tagged(sp, parent.arch.clone(), Some(&tag)).and_then(|st| {
                let j = Json::parse(&std::fs::read_to_string(mp)?)?;
                Ok((st, Measurement::from_json(&j)?))
            });
            match loaded {
                Ok((state, meas)) => {
                    crate::obs::metrics::counter("plan.cache.hit").incr();
                    if verbose {
                        crate::obs::log!(
                            crate::obs::Level::Info,
                            "[plan] hit  {} {}",
                            node.id,
                            node.stage.name()
                        );
                    }
                    return Ok(NodeResult { state: Some(Arc::new(state)), meas, hit: true });
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    if msg.contains("corrupt") || msg.contains("checksum") {
                        // Keep the bad bytes for forensics but get them
                        // out of the probe path: rotate to `.corrupt` so
                        // the recompute below can republish cleanly.
                        crate::obs::metrics::counter("plan.cache.corrupt").incr();
                        let rotated = std::fs::rename(sp, sp.with_extension("state.corrupt"));
                        crate::obs::log!(
                            crate::obs::Level::Warn,
                            "[plan] corrupt cache entry {}{}: {msg}",
                            node.id,
                            if rotated.is_ok() { " (rotated to .corrupt)" } else { "" }
                        );
                    } else {
                        crate::obs::metrics::counter("plan.cache.stale").incr();
                        if verbose {
                            crate::obs::log!(
                                crate::obs::Level::Warn,
                                "[plan] stale cache entry {}: {msg}",
                                node.id
                            );
                        }
                    }
                }
            }
        }
    }

    crate::obs::metrics::counter("plan.cache.miss").incr();
    if verbose {
        crate::obs::log!(
            crate::obs::Level::Info,
            "[plan] exec {} {}",
            node.id,
            node.stage.name()
        );
    }
    let mut attempt: u32 = 0;
    let (state, meas) = loop {
        match exec_node_once(runner, node, parent) {
            Ok(ok) => break ok,
            Err(e) if attempt < opts.retries => {
                attempt += 1;
                crate::obs::metrics::counter("plan.node.retries").incr();
                let backoff = opts.retry_backoff.saturating_mul(1u32 << (attempt - 1).min(6));
                crate::obs::log!(
                    crate::obs::Level::Warn,
                    "[plan] node {} attempt {attempt}/{} failed: {e:#} (retrying in {:?})",
                    node.id,
                    opts.retries,
                    backoff
                );
                std::thread::sleep(backoff);
            }
            Err(e) => return Err(e),
        }
    };

    if let Some((sp, mp)) = &paths {
        // Write-then-rename so an interrupted run can never leave a
        // half-written snapshot that later loads as a valid hit.  The tmp
        // name is per-process: concurrent `coc` runs over a shared cache
        // write identical bytes under distinct tmps and the second rename
        // atomically (and harmlessly) replaces the first.
        let tmp = sp.with_extension(format!("state.tmp.{}", std::process::id()));
        state.save_tagged(&tmp, Some(&tag))?;
        std::fs::rename(&tmp, sp)
            .with_context(|| format!("publishing snapshot {}", sp.display()))?;
        std::fs::write(mp, meas.to_json().to_string())
            .with_context(|| format!("writing {}", mp.display()))?;
        // Injected corruption (chaos tests): flip the first payload byte
        // of the just-published snapshot so the next probe exercises the
        // checksum-detect + rotate + recompute path.
        if crate::faults::fire(crate::faults::CACHE_CORRUPT) {
            if let Ok(mut b) = std::fs::read(sp) {
                let off = b.iter().position(|&x| x == b'\n').map(|p| p + 1).unwrap_or(0);
                if off < b.len() {
                    b[off] ^= 0xff;
                    let _ = std::fs::write(sp, &b);
                } else {
                    let _ = std::fs::write(sp, b"");
                }
            }
        }
    }
    Ok(NodeResult { state: Some(Arc::new(state)), meas, hit: false })
}

/// One attempt at a node: the [`faults::NODE_FAIL`](crate::faults) site,
/// the stage apply, and the measurement.
fn exec_node_once<R: NodeRunner>(
    runner: &R,
    node: &Node,
    parent: &ModelState,
) -> Result<(ModelState, Measurement)> {
    if crate::faults::fire(crate::faults::NODE_FAIL) {
        return Err(anyhow!(
            "injected fault: node_fail at {} ({})",
            node.id,
            node.stage.name()
        ));
    }
    let mut state = parent.clone();
    runner
        .apply(node.stage.as_ref(), &mut state)
        .with_context(|| format!("plan node {} ({})", node.id, node.stage.name()))?;
    state.history.push(node.stage.name());
    let meas = runner
        .measure(&state)
        .with_context(|| format!("measuring plan node {}", node.id))?;
    Ok((state, meas))
}

/// Threshold-sweep extras for one leaf state, replayed from
/// `<node_id>.extras.json` when cached under the same semantics
/// signature, computed (and snapshotted) once per distinct leaf
/// otherwise.
fn leaf_extras<R: NodeRunner>(
    id: NodeId,
    state: &ModelState,
    runner: &R,
    cache_dir: Option<&Path>,
    memo: &mut BTreeMap<NodeId, Vec<(String, Measurement)>>,
) -> Result<Vec<(String, Measurement)>> {
    if let Some(v) = memo.get(&id) {
        return Ok(v.clone());
    }
    let sig = runner.extras_signature();
    let path = cache_dir.map(|d| d.join(format!("{id}.extras.json")));
    if let Some(p) = &path {
        if p.exists() {
            if let Ok(v) = parse_extras(p, &sig) {
                memo.insert(id, v.clone());
                return Ok(v);
            }
        }
    }
    let v = runner.extra_measurements(state)?;
    if let Some(p) = &path {
        let json = crate::util::json::obj(vec![
            ("sig", crate::util::json::s(&sig)),
            (
                "extras",
                Json::Arr(
                    v.iter()
                        .map(|(suffix, m)| {
                            crate::util::json::obj(vec![
                                ("suffix", crate::util::json::s(suffix)),
                                ("m", m.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::write(p, json.to_string())
            .with_context(|| format!("writing {}", p.display()))?;
    }
    memo.insert(id, v.clone());
    Ok(v)
}

fn parse_extras(path: &Path, want_sig: &str) -> Result<Vec<(String, Measurement)>> {
    let j = Json::parse(&std::fs::read_to_string(path)?)?;
    let got_sig = j.req("sig")?.as_str().unwrap_or("");
    if got_sig != want_sig {
        return Err(anyhow!("extras signature `{got_sig}` != expected `{want_sig}`"));
    }
    j.req("extras")?
        .as_arr()
        .ok_or_else(|| anyhow!("extras field is not an array"))?
        .iter()
        .map(|e| {
            let suffix = e
                .req("suffix")?
                .as_str()
                .ok_or_else(|| anyhow!("extras suffix is not a string"))?
                .to_string();
            Ok((suffix, Measurement::from_json(e.req("m")?)?))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::{stages, Technique};
    use crate::order;
    use crate::sweep;

    fn key(seed: u64) -> PlanKey {
        PlanKey {
            arch: "mini_resnet".into(),
            dataset: "c10".into(),
            scale: "smoke".into(),
            base_steps: 40,
            seed,
        }
    }

    fn chain_for(seq: &[Technique], rung: usize, ladder: usize) -> Chain {
        let mut c = Chain::new();
        for &t in seq {
            c = c.push(sweep::stage_at(t, rung, ladder));
        }
        c
    }

    #[test]
    fn six_distill_orders_share_one_d_node() {
        let mut plan = Planner::new(key(42));
        for seq in order::distill_started_orders() {
            plan.submit(chain_for(&seq, 0, 2), &order::sequence_string(&seq), "rung0");
        }
        assert_eq!(plan.num_chains(), 6);
        assert_eq!(plan.total_stages(), 24);
        // D(1) + {P,Q,E}(3) + second-level pairs(6) + leaves(6).
        assert_eq!(plan.unique_nodes(), 16);
        assert_eq!(plan.root_children(), 1, "all six orders share exactly one D node");
    }

    #[test]
    fn resubmitting_a_chain_adds_no_nodes() {
        let mut plan = Planner::new(key(42));
        let seq = order::paper_law();
        let a = plan.submit(chain_for(&seq, 0, 2), "DPQE", "rung0");
        let b = plan.submit(chain_for(&seq, 0, 2), "DPQE", "again");
        assert_eq!(plan.unique_nodes(), 4);
        assert_eq!(plan.chain_node_ids(a), plan.chain_node_ids(b));
    }

    #[test]
    fn fingerprint_changes_move_the_node_id() {
        let mut plan = Planner::new(key(42));
        let mild = plan.submit(
            Chain::new().push(Box::new(stages::Prune { ratio: 0.4, ..Default::default() })),
            "P",
            "mild",
        );
        let aggressive = plan.submit(
            Chain::new().push(Box::new(stages::Prune { ratio: 0.7, ..Default::default() })),
            "P",
            "aggressive",
        );
        assert_eq!(plan.unique_nodes(), 2, "different rungs are different nodes");
        assert_ne!(plan.chain_node_ids(mild), plan.chain_node_ids(aggressive));

        // A hidden hyper-parameter (not in the display name) still splits.
        let ft = plan.submit(
            Chain::new().push(Box::new(stages::Prune {
                ratio: 0.4,
                finetune_frac: 0.9,
                ..Default::default()
            })),
            "P",
            "long-ft",
        );
        assert_eq!(plan.unique_nodes(), 3);
        assert_ne!(plan.chain_node_ids(mild), plan.chain_node_ids(ft));
    }

    #[test]
    fn plan_key_salts_every_node_id() {
        let chain = || Chain::new().push(Box::new(stages::Quantize::default()));
        let mut a = Planner::new(key(42));
        let mut b = Planner::new(key(43));
        let mut c = Planner::new(PlanKey { arch: "mini_vgg".into(), ..key(42) });
        let mut d = Planner::new(PlanKey { base_steps: 80, ..key(42) });
        let ia = a.submit(chain(), "Q", "x");
        let ib = b.submit(chain(), "Q", "x");
        let ic = c.submit(chain(), "Q", "x");
        let id = d.submit(chain(), "Q", "x");
        assert_ne!(a.chain_node_ids(ia), b.chain_node_ids(ib));
        assert_ne!(a.chain_node_ids(ia), c.chain_node_ids(ic));
        // A changed training budget must move the address even when the
        // scale tag (which usually implies it) stays the same.
        assert_ne!(a.chain_node_ids(ia), d.chain_node_ids(id));
    }

    #[test]
    fn node_ids_are_prefix_hash_chains() {
        let mut plan = Planner::new(key(1));
        let pq = plan.submit(
            Chain::new()
                .push(Box::new(stages::Prune::default()))
                .push(Box::new(stages::Quantize::default())),
            "PQ",
            "x",
        );
        let p = plan.submit(Chain::new().push(Box::new(stages::Prune::default())), "P", "x");
        let ids_pq = plan.chain_node_ids(pq);
        let ids_p = plan.chain_node_ids(p);
        // The P chain's single node IS the PQ chain's first node.
        assert_eq!(ids_p[0], ids_pq[0]);
        assert_ne!(ids_pq[0], ids_pq[1]);
        // Display form is 32 lowercase hex chars (cache file names).
        let s = ids_pq[1].to_string();
        assert_eq!(s.len(), 32);
        assert!(s.chars().all(|c| c.is_ascii_hexdigit()));
    }
}
