//! The Chain of Compression: composable compression stages applied in
//! sequence to a ModelState — the paper's central abstraction (Fig. 1).
//!
//! Each technique is a standard building block implementing
//! [`CompressionStage`]; a [`Chain`] is an ordered list of blocks.  The
//! coordinator measures (accuracy, BitOpsCR, CR) after every stage, which
//! is exactly the data behind the paper's figures and tables.

use anyhow::Result;

use crate::data::Dataset;
use crate::metrics::Measurement;
use crate::models::ModelState;
use crate::runtime::Engine;

pub mod plan;
pub mod stages;

pub use stages::{Distill, EarlyExit, HuffmanCoding, Prune, Quantize, WeightCluster};

/// Technique tags, used by the order-search machinery.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Technique {
    Distill,
    Prune,
    Quantize,
    EarlyExit,
}

impl Technique {
    pub fn letter(&self) -> char {
        match self {
            Technique::Distill => 'D',
            Technique::Prune => 'P',
            Technique::Quantize => 'Q',
            Technique::EarlyExit => 'E',
        }
    }

    pub fn from_letter(c: char) -> Option<Technique> {
        match c.to_ascii_uppercase() {
            'D' => Some(Technique::Distill),
            'P' => Some(Technique::Prune),
            'Q' => Some(Technique::Quantize),
            'E' => Some(Technique::EarlyExit),
            _ => None,
        }
    }

    /// Static (offline) vs dynamic (runtime) compression — one of the two
    /// ordering principles the paper extracts.
    pub fn is_dynamic(&self) -> bool {
        matches!(self, Technique::EarlyExit)
    }

    /// Granularity rank: architecture(0) > neuron(1) > sub-neuron(2);
    /// dynamic-architecture early exit ranks after all static stages.
    pub fn granularity_rank(&self) -> u8 {
        match self {
            Technique::Distill => 0,
            Technique::Prune => 1,
            Technique::Quantize => 2,
            Technique::EarlyExit => 3,
        }
    }
}

/// Everything a stage needs from the outside world.
pub struct StageCtx<'e> {
    pub engine: &'e Engine,
    pub train: &'e Dataset,
    pub test: &'e Dataset,
    /// Steps for a "full" training stage; fine-tunes get a fraction.
    pub base_steps: usize,
    pub seed: u64,
    pub verbose: bool,
}

/// Per-stage outcome, for logs and the fig15 waterfall.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReport {
    pub stage: String,
    pub technique: Technique,
    pub measurement: Measurement,
}

/// `Send + Sync` is part of the contract: stages are plain hyper-parameter
/// records, and the plan executor (`chain::plan`) shares them across
/// worker threads.
pub trait CompressionStage: Send + Sync {
    fn name(&self) -> String;
    fn technique(&self) -> Technique;
    /// Deterministic identity of this stage: technique tag plus **every**
    /// hyper-parameter, nothing else.  Two stages with equal fingerprints
    /// must produce bit-identical states from equal inputs — the planner
    /// hash-chains fingerprints into content addresses, so omitting a
    /// hyper-parameter here silently aliases distinct cache entries.
    fn fingerprint(&self) -> String;
    /// Apply the stage (including its fine-tuning) to `state` in place.
    fn apply(&self, state: &mut ModelState, ctx: &StageCtx) -> Result<()>;
}

/// An ordered chain of compression stages.
pub struct Chain {
    pub stages: Vec<Box<dyn CompressionStage>>,
}

impl Chain {
    pub fn new() -> Chain {
        Chain { stages: Vec::new() }
    }

    pub fn push(mut self, s: Box<dyn CompressionStage>) -> Chain {
        self.stages.push(s);
        self
    }

    pub fn sequence_letters(&self) -> String {
        self.stages.iter().map(|s| s.technique().letter()).collect()
    }

    /// Run every stage, measuring after each one.
    pub fn run(&self, state: &mut ModelState, ctx: &StageCtx) -> Result<Vec<StageReport>> {
        let mut reports = Vec::with_capacity(self.stages.len());
        for stage in &self.stages {
            let _span = crate::obs::trace::span_with(|| format!("chain.stage.{}", stage.name()));
            if ctx.verbose {
                crate::obs::log!(crate::obs::Level::Info, "[chain] applying {}", stage.name());
            }
            stage.apply(state, ctx)?;
            state.history.push(stage.name());
            let m = Measurement::take(ctx.engine, state, ctx.test)?;
            if ctx.verbose {
                crate::obs::log!(
                    crate::obs::Level::Info,
                    "[chain]   acc {:.4}  BitOpsCR {:.1}x  CR {:.1}x",
                    m.accuracy,
                    m.bitops_cr,
                    m.storage_cr
                );
            }
            reports.push(StageReport {
                stage: stage.name(),
                technique: stage.technique(),
                measurement: m,
            });
        }
        Ok(reports)
    }
}

impl Default for Chain {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn technique_letters_roundtrip() {
        for t in [Technique::Distill, Technique::Prune, Technique::Quantize, Technique::EarlyExit] {
            assert_eq!(Technique::from_letter(t.letter()), Some(t));
        }
        assert_eq!(Technique::from_letter('x'), None);
    }

    #[test]
    fn ordering_principles() {
        use Technique::*;
        assert!(!Distill.is_dynamic() && !Prune.is_dynamic() && !Quantize.is_dynamic());
        assert!(EarlyExit.is_dynamic());
        assert!(Distill.granularity_rank() < Prune.granularity_rank());
        assert!(Prune.granularity_rank() < Quantize.granularity_rank());
    }
}
