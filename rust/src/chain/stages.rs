//! The four compression building blocks (classic variants, per the paper's
//! §2 rule: no scenario-specific tricks, so the extracted ordering law is
//! general).
//!
//! * [`Distill`]  — classic Hinton KD into a width-scaled student.
//! * [`Prune`]    — uniform channel pruning by L2 importance + fine-tune.
//! * [`Quantize`] — fixed-point uniform QAT (DoReFa-style) at given bits.
//! * [`EarlyExit`]— train exit heads (+ joint fine-tune), set thresholds.

use anyhow::{ensure, Result};

use super::{CompressionStage, StageCtx, Technique};
use crate::models::{ModelState, QBits};
use crate::train::{self, TrainOpts};

fn base_opts(ctx: &StageCtx) -> TrainOpts {
    TrainOpts { steps: ctx.base_steps, seed: ctx.seed, ..Default::default() }
}

// ---------------------------------------------------------------------------
// Distillation
// ---------------------------------------------------------------------------

/// Knowledge distillation: the current state becomes the teacher; a fresh
/// student with `width` of the channels (uniform width scaling, as in the
/// paper's MobileNetV2 student) is trained on data + teacher logits.
///
/// If the teacher already carries compression state, the student inherits
/// it the way the paper's pipelines do: a pruned teacher (PD) hands the
/// student its *width budget* only (pruning decisions don't transfer
/// across re-initialization); a quantized teacher (QD) hands the student
/// its bit-widths so the student trains under the same arithmetic.
#[derive(Debug, Clone)]
pub struct Distill {
    /// Fraction of channels the student keeps (0 < width <= 1).
    pub width: f32,
    pub alpha: f32,
    pub tau: f32,
    /// Multiplier on ctx.base_steps for student training (distillation is
    /// a from-scratch training, not a fine-tune).
    pub steps_mult: f32,
}

impl Default for Distill {
    fn default() -> Self {
        Distill { width: 0.5, alpha: 0.7, tau: 4.0, steps_mult: 1.0 }
    }
}

impl CompressionStage for Distill {
    fn name(&self) -> String {
        format!("distill(width={:.2},alpha={:.1})", self.width, self.alpha)
    }

    fn technique(&self) -> Technique {
        Technique::Distill
    }

    fn fingerprint(&self) -> String {
        // `{}` on f32 is the shortest round-trippable form, so distinct
        // hyper-parameters can never collide in the fingerprint.
        format!(
            "distill|w={}|a={}|tau={}|sm={}",
            self.width, self.alpha, self.tau, self.steps_mult
        )
    }

    fn apply(&self, state: &mut ModelState, ctx: &StageCtx) -> Result<()> {
        ensure!(self.width > 0.0 && self.width <= 1.0, "bad student width {}", self.width);
        // 1. Teacher logits over the training set (teacher = current state).
        let teacher = train::teacher_logits(ctx.engine, state, ctx.train)?;

        // 2. Fresh student: same graph, uniformly narrower via masks.
        //    Student width composes with the teacher's existing pruning
        //    budget (a 0.5-width student of a 0.5-kept teacher keeps 0.25).
        let mut student = train::init_state(ctx.engine, state.arch.clone(), ctx.seed ^ 0x57d)?;
        for (slot, mask) in student.masks.iter_mut().enumerate() {
            let teacher_live = state.masks[slot].count_nonzero();
            let keep = ((teacher_live as f32 * self.width).round() as usize).max(2);
            for c in keep..mask.len() {
                mask.data[c] = 0.0;
            }
        }
        // Quantized teacher (QD): student trains under the same arithmetic.
        student.qbits = state.qbits;

        // 3. Train the student with KD.  If the teacher had trained exits
        //    the student keeps exit heads learning from *data* (the paper's
        //    finding: teacher exits make bad teachers for student exits).
        let had_exits = state.exits.trained;
        let mut opts = base_opts(ctx);
        opts.steps = ((ctx.base_steps as f32) * self.steps_mult) as usize;
        opts.kd_alpha = self.alpha;
        opts.kd_tau = self.tau;
        if had_exits {
            opts.exit_w = [0.3, 0.3];
        }
        train::train(ctx.engine, &mut student, ctx.train, Some(&teacher), &opts)?;

        // 4. The student replaces the teacher on the chain.
        student.exits = state.exits.clone();
        student.exits.trained = had_exits;
        student.history = state.history.clone();
        *state = student;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Pruning
// ---------------------------------------------------------------------------

/// Channel-importance criterion (L2 is the paper's classic choice; Random
/// exists for the ablation bench — see `coc exp ablation_prune`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Importance {
    L2,
    Random,
}

/// Uniform channel pruning: in every mask slot, remove `ratio` of the
/// currently-live channels with the smallest aggregate L2 weight norm,
/// then fine-tune at 1/10 LR (the paper's protocol).
#[derive(Debug, Clone)]
pub struct Prune {
    /// Fraction of live channels to remove per slot (0 <= ratio < 1).
    pub ratio: f32,
    /// Fine-tune budget as a fraction of ctx.base_steps.
    pub finetune_frac: f32,
    pub importance: Importance,
}

impl Default for Prune {
    fn default() -> Self {
        Prune { ratio: 0.5, finetune_frac: 0.5, importance: Importance::L2 }
    }
}

impl Prune {
    /// Live channels ordered lowest-importance first — the removal order.
    /// Total over every f32 bit pattern via `f32::total_cmp` (the
    /// `partial_cmp(..).unwrap()` it replaces aborted the stage on a NaN
    /// importance): a NaN importance — all-NaN weights — sorts above every
    /// finite value, so such a channel is pruned *last*, and exact ties
    /// keep ascending channel order (stable sort).
    fn removal_order(imp: &[f32], live: Vec<usize>) -> Vec<usize> {
        let mut order = live;
        order.sort_by(|&a, &b| imp[a].total_cmp(&imp[b]));
        order
    }

    /// Aggregate per-channel importance for one mask slot: the L2 norm of
    /// each channel's outgoing weights across every layer writing into the
    /// slot (residual stages have several writers).
    fn slot_importance(state: &ModelState, slot: usize) -> Vec<f32> {
        let channels = state.arch.mask_slots[slot].channels;
        let mut imp = vec![0.0f32; channels];
        for (li, l) in state.arch.layers.iter().enumerate() {
            if l.out_mask == slot as i64 {
                let w = &state.params[state.arch.weight_index(li)];
                for (c, n) in w.channel_l2().into_iter().enumerate() {
                    imp[c] += n * n;
                }
            }
        }
        imp.iter().map(|v| v.sqrt()).collect()
    }
}

impl CompressionStage for Prune {
    fn name(&self) -> String {
        format!("prune(ratio={:.2})", self.ratio)
    }

    fn technique(&self) -> Technique {
        Technique::Prune
    }

    fn fingerprint(&self) -> String {
        let imp = match self.importance {
            Importance::L2 => "l2",
            Importance::Random => "random",
        };
        format!("prune|r={}|ft={}|imp={imp}", self.ratio, self.finetune_frac)
    }

    fn apply(&self, state: &mut ModelState, ctx: &StageCtx) -> Result<()> {
        ensure!((0.0..1.0).contains(&self.ratio), "bad prune ratio {}", self.ratio);
        let mut rng = crate::util::rng::Rng::new(ctx.seed ^ 0x9121e);
        for slot in 0..state.arch.mask_slots.len() {
            let imp = match self.importance {
                Importance::L2 => Self::slot_importance(state, slot),
                Importance::Random => (0..state.arch.mask_slots[slot].channels)
                    .map(|_| rng.f32())
                    .collect(),
            };
            let live: Vec<usize> =
                (0..imp.len()).filter(|&c| state.masks[slot].data[c] != 0.0).collect();
            let remove = ((live.len() as f32) * self.ratio) as usize;
            let keep_min = 2;
            let remove = remove.min(live.len().saturating_sub(keep_min));
            // Lowest-importance live channels go first.
            let order = Self::removal_order(&imp, live);
            for &c in order.iter().take(remove) {
                state.masks[slot].data[c] = 0.0;
            }
        }
        // Fine-tune at 1/10 LR; momenta restart (masked channels froze).
        state.reset_momenta();
        let base = base_opts(ctx);
        let mut ft =
            TrainOpts::fine_tune_of(&base, ((ctx.base_steps as f32) * self.finetune_frac) as usize);
        if state.exits.trained {
            ft.exit_w = [0.3, 0.3];
        }
        train::train(ctx.engine, state, ctx.train, None, &ft)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Quantization
// ---------------------------------------------------------------------------

/// Fixed-point uniform QAT: switch the graph's fake-quant operands to the
/// target bit-widths and fine-tune (quantization-aware training at 1/10
/// LR).  `bits == 0` would mean fp32; both fields must be >= 1 here.
#[derive(Debug, Clone)]
pub struct Quantize {
    pub bits_w: f32,
    pub bits_a: f32,
    pub finetune_frac: f32,
}

impl Default for Quantize {
    fn default() -> Self {
        Quantize { bits_w: 1.0, bits_a: 8.0, finetune_frac: 0.5 }
    }
}

impl CompressionStage for Quantize {
    fn name(&self) -> String {
        format!("quantize({}w{}a)", self.bits_w, self.bits_a)
    }

    fn technique(&self) -> Technique {
        Technique::Quantize
    }

    fn fingerprint(&self) -> String {
        format!("quantize|bw={}|ba={}|ft={}", self.bits_w, self.bits_a, self.finetune_frac)
    }

    fn apply(&self, state: &mut ModelState, ctx: &StageCtx) -> Result<()> {
        ensure!(self.bits_w >= 1.0 && self.bits_a >= 1.0, "quantize needs bits >= 1");
        state.qbits = QBits { weight: self.bits_w, act: self.bits_a };
        state.reset_momenta();
        let base = base_opts(ctx);
        let mut ft =
            TrainOpts::fine_tune_of(&base, ((ctx.base_steps as f32) * self.finetune_frac) as usize);
        if state.exits.trained {
            // QE rule from the paper: exit layers accept quantized
            // activations and do QAT from the start.
            ft.exit_w = [0.3, 0.3];
        }
        train::train(ctx.engine, state, ctx.train, None, &ft)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Early exit
// ---------------------------------------------------------------------------

/// Train the two exit heads (joint fine-tune of body + exits with exit
/// losses enabled) and set confidence thresholds.  The threshold pair is a
/// *runtime* knob: sweeps vary it without retraining (each trained E model
/// yields several (accuracy, BitOpsCR) samples, as in the paper).
#[derive(Debug, Clone)]
pub struct EarlyExit {
    pub exit_w: [f32; 2],
    pub threshold: f32,
    /// Training budget as a fraction of ctx.base_steps.
    pub train_frac: f32,
}

impl Default for EarlyExit {
    fn default() -> Self {
        EarlyExit { exit_w: [0.4, 0.4], threshold: 0.8, train_frac: 0.5 }
    }
}

impl CompressionStage for EarlyExit {
    fn name(&self) -> String {
        format!("early_exit(t={:.2})", self.threshold)
    }

    fn technique(&self) -> Technique {
        Technique::EarlyExit
    }

    fn fingerprint(&self) -> String {
        format!(
            "early_exit|w1={}|w2={}|t={}|tf={}",
            self.exit_w[0], self.exit_w[1], self.threshold, self.train_frac
        )
    }

    fn apply(&self, state: &mut ModelState, ctx: &StageCtx) -> Result<()> {
        let base = base_opts(ctx);
        // Exit-head training is a fine-tune of the whole network with exit
        // losses on (EP/PE/QE semantics from the paper's captions).
        let mut ft =
            TrainOpts::fine_tune_of(&base, ((ctx.base_steps as f32) * self.train_frac) as usize);
        ft.exit_w = self.exit_w;
        state.reset_momenta();
        train::train(ctx.engine, state, ctx.train, None, &ft)?;
        state.exits.trained = true;
        state.exits.thresholds = Some((self.threshold, self.threshold));
        // Measure the exit distribution on the *training* set (calibration
        // data); Measurement::take refreshes it on test.
        let ev = crate::exits::evaluate(ctx.engine, state, ctx.train, self.threshold, self.threshold)?;
        state.exits.exit_probs = (ev.p_exit1, ev.p_exit2);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Deep-Compression baseline stages (Han et al. 2015): trained weight
// clustering + Huffman coding.  These are the "other combination methods"
// rows of Table 5 that can be rebuilt from first principles in this
// framework.
// ---------------------------------------------------------------------------

/// Weight clustering ("trained quantization" of Deep Compression): k-means
/// each weight tensor's values to `1 << index_bits` shared centroids,
/// fine-tune, then re-cluster so the deployed weights really are k-valued.
/// Storage: index_bits per weight + a per-layer fp32 codebook (accounted
/// in `Accountant::storage_bits`).  Compute (BitOps) is unchanged — the
/// centroids are still fp32 arithmetic, which is exactly why the paper's
/// fixed-point Q dominates on BitOpsCR while clustering shines on CR.
#[derive(Debug, Clone)]
pub struct WeightCluster {
    pub index_bits: u32,
    pub finetune_frac: f32,
}

impl Default for WeightCluster {
    fn default() -> Self {
        WeightCluster { index_bits: 4, finetune_frac: 0.4 }
    }
}

impl WeightCluster {
    fn cluster_params(state: &mut ModelState, k: usize) {
        for li in 0..state.arch.layers.len() {
            let wi = state.arch.weight_index(li);
            let w = &state.params[wi];
            let (q, _, _) = crate::util::kmeans::quantize_to_clusters(&w.data, k, 12);
            state.params[wi] = crate::tensor::Tensor::new(w.shape.clone(), q);
        }
    }
}

impl CompressionStage for WeightCluster {
    fn name(&self) -> String {
        format!("weight_cluster(k={})", 1u64 << self.index_bits)
    }

    fn technique(&self) -> Technique {
        Technique::Quantize // storage-side quantization family
    }

    fn fingerprint(&self) -> String {
        format!("weight_cluster|bits={}|ft={}", self.index_bits, self.finetune_frac)
    }

    fn apply(&self, state: &mut ModelState, ctx: &StageCtx) -> Result<()> {
        ensure!((1..=8).contains(&self.index_bits), "index_bits must be 1..=8");
        let k = 1usize << self.index_bits;
        Self::cluster_params(state, k);
        state.reset_momenta();
        let base = base_opts(ctx);
        let ft = TrainOpts::fine_tune_of(
            &base,
            ((ctx.base_steps as f32) * self.finetune_frac) as usize,
        );
        train::train(ctx.engine, state, ctx.train, None, &ft)?;
        // Re-cluster so deployment really has k distinct values per layer.
        Self::cluster_params(state, k);
        state.extras.cluster_bits = Some(self.index_bits as f32);
        Ok(())
    }
}

/// Huffman coding of the discrete weight symbols (cluster indices, or
/// fake-quant levels when the model is fixed-point quantized).  Pure
/// storage accounting — no retraining, no accuracy change.
#[derive(Debug, Clone, Default)]
pub struct HuffmanCoding;

impl CompressionStage for HuffmanCoding {
    fn name(&self) -> String {
        "huffman_coding".into()
    }

    fn technique(&self) -> Technique {
        Technique::Quantize
    }

    fn fingerprint(&self) -> String {
        "huffman_coding".into()
    }

    fn apply(&self, state: &mut ModelState, _ctx: &StageCtx) -> Result<()> {
        ensure!(
            state.extras.cluster_bits.is_some() || state.qbits.weight > 0.0,
            "huffman coding needs discrete weights: cluster or quantize first"
        );
        let mut total_bits = 0u64;
        for li in 0..state.arch.layers.len() {
            let wi = state.arch.weight_index(li);
            // Deployed (discrete) weight values.
            let deployed = if state.extras.cluster_bits.is_some() {
                state.params[wi].clone()
            } else {
                crate::models::host_weight_quant(&state.params[wi], state.qbits.weight)
            };
            // Symbolize by value (discrete by construction).  Ordering,
            // dedup, and lookup all use `total_cmp` so every bit pattern —
            // including a NaN that would have aborted the old
            // `partial_cmp(..).unwrap()` sort — maps to exactly one
            // symbol (NaN == NaN under total order, unlike PartialEq).
            let mut values: Vec<f32> = deployed.data.clone();
            values.sort_by(f32::total_cmp);
            values.dedup_by(|a, b| a.total_cmp(b).is_eq());
            let mut freqs = vec![0u64; values.len()];
            for v in &deployed.data {
                let idx =
                    values.partition_point(|x| x.total_cmp(v).is_lt()).min(values.len() - 1);
                freqs[idx] += 1;
            }
            let code = crate::util::huffman::HuffmanCode::from_freqs(&freqs);
            total_bits += code.coded_bits(&freqs) + code.table_bits();
        }
        state.extras.coded_weight_bits = Some(total_bits as f64);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prune_removal_order_is_nan_safe_and_tie_stable() {
        // NaN importance: never removed before any finite channel, and no
        // abort (the old partial_cmp unwrap panicked here).
        let imp = [0.5, f32::NAN, 0.1, 0.5];
        let order = Prune::removal_order(&imp, vec![0, 1, 2, 3]);
        assert_eq!(order, vec![2, 0, 3, 1], "NaN sorts above all finite importances");
        // Exact ties keep ascending channel order (stable sort), so the
        // pruning decision is deterministic across runs.
        let tied = [1.0, 1.0, 1.0];
        assert_eq!(Prune::removal_order(&tied, vec![2, 0, 1]), vec![2, 0, 1]);
        // -inf is the least important of all.
        let inf = [0.0, f32::NEG_INFINITY, f32::INFINITY];
        assert_eq!(Prune::removal_order(&inf, vec![0, 1, 2]), vec![1, 0, 2]);
    }

    #[test]
    fn defaults_are_sane() {
        assert!(Distill::default().width <= 1.0);
        assert!((0.0..1.0).contains(&Prune::default().ratio));
        assert!(Quantize::default().bits_w >= 1.0);
        assert!(EarlyExit::default().threshold > 0.0);
    }

    #[test]
    fn names_embed_hypers() {
        assert!(Distill { width: 0.25, ..Default::default() }.name().contains("0.25"));
        assert!(Quantize { bits_w: 2.0, bits_a: 8.0, ..Default::default() }
            .name()
            .contains("2w8a"));
    }

    #[test]
    fn fingerprints_cover_every_hyperparameter() {
        // Fields the short display name drops must still flip the
        // fingerprint — cache identity depends on it.
        let base = Prune::default();
        let ft = Prune { finetune_frac: 0.9, ..Default::default() };
        let imp = Prune { importance: Importance::Random, ..Default::default() };
        assert_eq!(base.name(), ft.name());
        assert_ne!(base.fingerprint(), ft.fingerprint());
        assert_ne!(base.fingerprint(), imp.fingerprint());

        let d = Distill::default();
        let tau = Distill { tau: 2.0, ..Default::default() };
        assert_ne!(d.fingerprint(), tau.fingerprint());

        let e = EarlyExit::default();
        let tf = EarlyExit { train_frac: 0.9, ..Default::default() };
        assert_ne!(e.fingerprint(), tf.fingerprint());

        let q = Quantize::default();
        assert_eq!(q.fingerprint(), Quantize::default().fingerprint());
    }
}
