//! PJRT backend: load AOT-compiled HLO text, compile once per engine,
//! execute through XLA.  The production [`super::Backend`] — see
//! python/compile/aot.py for why interchange is HLO *text*.
//!
//! Supports both transports: literal marshalling and device-resident
//! buffers (see DESIGN.md §Device residency).  Buffer-mode results rely
//! on the runtime untupling the output (one `PjRtBuffer` per tuple leaf);
//! when that (or buffer upload itself) is unavailable, callers see a
//! [`ResidencyUnsupported`] error and fall back to literal mode — same
//! graphs, same operand values, bit-identical outputs, different
//! transport.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::models::ArchManifest;
use crate::obs::trace;
use crate::tensor::Tensor;

use super::{
    foreign_buffer_error, Backend, DeviceBuf, DeviceBuffer, GraphExec, ResidencyUnsupported,
    StatsCell,
};

/// The PJRT backend: one CPU client over an artifacts directory.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    stats: Arc<StatsCell>,
}

impl PjrtBackend {
    pub(crate) fn new(artifacts_dir: PathBuf, stats: Arc<StatsCell>) -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client, artifacts_dir, stats })
    }

    fn compile(&self, path: &Path) -> Result<Box<dyn GraphExec>> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text `{}` (run `make artifacts`?)", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t0 = Instant::now();
        let exe = {
            let _s = trace::span("pjrt.compile");
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling `{}`", path.display()))?
        };
        let dt = t0.elapsed();
        if dt.as_millis() > 500 {
            crate::obs::log!(
                crate::obs::Level::Info,
                "[runtime] compiled {} in {:.1}s",
                path.display(),
                dt.as_secs_f64()
            );
        }
        Ok(Box::new(PjrtGraph {
            exe,
            name: path.display().to_string(),
            stats: self.stats.clone(),
        }))
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load_graph(&self, arch: &Arc<ArchManifest>, tag: &str) -> Result<Box<dyn GraphExec>> {
        let file = arch.graph(tag)?;
        self.compile(&self.artifacts_dir.join(file))
    }

    fn load_file(&self, path: &Path) -> Result<Box<dyn GraphExec>> {
        self.compile(path)
    }

    fn upload(&self, t: &Tensor) -> Result<DeviceBuffer> {
        let _s = trace::span("pjrt.upload");
        let t0 = Instant::now();
        let lit = tensor_to_literal(t)?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| ResidencyUnsupported(format!("buffer upload: {e}")))?;
        self.stats.upload_ns.add(t0.elapsed().as_nanos() as u64);
        self.stats.bytes_uploaded.add(4 * t.len() as u64);
        Ok(DeviceBuffer::new(Box::new(PjrtBuf { buf, stats: self.stats.clone() })))
    }
}

/// A compiled executable plus its engine's stats handle.
struct PjrtGraph {
    exe: xla::PjRtLoadedExecutable,
    name: String,
    stats: Arc<StatsCell>,
}

impl GraphExec for PjrtGraph {
    /// All our graphs are lowered with `return_tuple=True`, so PJRT hands
    /// back a single tuple buffer which we decompose into leaves.
    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let _s = trace::span("pjrt.run");
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let in_bytes: usize = inputs.iter().map(|t| 4 * t.len()).sum();
        let t1 = Instant::now();
        self.stats.upload_ns.add((t1 - t0).as_nanos() as u64);
        self.stats.bytes_uploaded.add(in_bytes as u64);

        let out = {
            let _s = trace::span("pjrt.execute");
            self.exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing `{}`", self.name))?
        };
        let t2 = Instant::now();
        self.stats.executions.incr();
        self.stats.execute_ns.add((t2 - t1).as_nanos() as u64);

        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of `{}`", self.name))?;
        let leaves = lit.to_tuple().context("decomposing result tuple")?;
        let tensors = leaves
            .into_iter()
            .map(|l| literal_to_tensor(&l))
            .collect::<Result<Vec<_>>>()?;
        let out_bytes: usize = tensors.iter().map(|t| 4 * t.len()).sum();
        self.stats.download_ns.add(t2.elapsed().as_nanos() as u64);
        self.stats.bytes_downloaded.add(out_bytes as u64);
        Ok(tensors)
    }

    fn run_buffers(&self, inputs: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>> {
        let bufs: Vec<&xla::PjRtBuffer> = inputs
            .iter()
            .map(|b| {
                b.inner()
                    .as_any()
                    .downcast_ref::<PjrtBuf>()
                    .map(|pb| &pb.buf)
                    .ok_or_else(|| foreign_buffer_error("pjrt"))
            })
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let mut out = {
            let _s = trace::span("pjrt.execute");
            self.exe
                .execute_b(&bufs)
                .with_context(|| format!("buffer-executing `{}`", self.name))?
        };
        self.stats.executions.incr();
        self.stats.execute_ns.add(t0.elapsed().as_nanos() as u64);
        anyhow::ensure!(!out.is_empty(), "`{}` returned no device results", self.name);
        Ok(out
            .swap_remove(0)
            .into_iter()
            .map(|buf| DeviceBuffer::new(Box::new(PjrtBuf { buf, stats: self.stats.clone() })))
            .collect())
    }
}

/// One device-resident array: a `PjRtBuffer` plus the stats handle of the
/// engine that allocated it.
struct PjrtBuf {
    buf: xla::PjRtBuffer,
    stats: Arc<StatsCell>,
}

impl DeviceBuf for PjrtBuf {
    fn to_tensor(&self) -> Result<Tensor> {
        let _s = trace::span("pjrt.download");
        let t0 = Instant::now();
        let lit = self.buf.to_literal_sync().context("downloading device buffer")?;
        let t = literal_to_tensor(&lit)?;
        self.stats.download_ns.add(t0.elapsed().as_nanos() as u64);
        self.stats.bytes_downloaded.add(4 * t.len() as u64);
        Ok(t)
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

// ----- literal <-> tensor ----------------------------------------------------

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // Scalar: reshape to rank 0.
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal has no array shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("literal is not f32")?;
    Ok(Tensor::new(dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let l = tensor_to_literal(&t).unwrap();
        let t2 = literal_to_tensor(&l).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar(3.5);
        let l = tensor_to_literal(&t).unwrap();
        let t2 = literal_to_tensor(&l).unwrap();
        assert_eq!(t2.shape, Vec::<usize>::new());
        assert_eq!(t2.data, vec![3.5]);
    }
}
