//! Execution runtime: pluggable backends behind one `Engine` facade.
//!
//! The coordinator's hot loops (train steps, eval batches, serve stages)
//! talk to an [`Engine`]; *how* a graph is compiled and executed is a
//! [`Backend`] implementation choice (see DESIGN.md §Backends):
//!
//! * [`pjrt::PjrtBackend`] — the production path: load AOT-compiled HLO
//!   text (emitted once by python/compile/aot.py), compile via the PJRT
//!   CPU client, execute through XLA.  Supports device residency.
//! * [`refback::RefBackend`] — a hermetic, deterministic pure-Rust
//!   interpreter of the manifest's graph contract (`train`, `eval`,
//!   `init`, staged serving graphs at their declared batch sizes),
//!   implemented directly against `tensor`/`models`.  No artifacts, no
//!   device, bit-identical results on every run *and at every kernel
//!   thread count* (`--ref-threads`, default: available parallelism) —
//!   this is what lets the end-to-end test suites run for real in CI.
//!
//! Selection is a constructor choice ([`Engine::new`] = PJRT,
//! [`Engine::new_ref`] = reference, [`Engine::with_backend`] = explicit,
//! [`Engine::with_backend_threads`] = explicit + kernel thread budget)
//! surfaced on the CLI as `--backend pjrt|ref` / `--ref-threads N`.
//!
//! # Device residency (see DESIGN.md §Device residency)
//!
//! Two transports exist for every graph:
//!
//! * **Literal mode** ([`Executable::run`]) — marshal host [`Tensor`]s per
//!   call and download the whole output tuple.  Always available on every
//!   backend.
//! * **Buffer mode** ([`Executable::run_buffers`]) — operands are
//!   [`DeviceBuffer`]s already resident on the device; outputs come back
//!   as device buffers that the next call can consume *without* any host
//!   round-trip.  PJRT-only: the reference backend has no device, so its
//!   [`Backend::upload`] reports [`ResidencyUnsupported`] and every caller
//!   degrades to the (exactly equivalent) literal transport through the
//!   same fallback machinery the PJRT path uses when buffer execution is
//!   unavailable.
//!
//! # Threading model (see DESIGN.md §Serving)
//!
//! PJRT client/executable handles are raw FFI handles and are *not*
//! `Send`: an [`Engine`] is therefore a **per-thread** object regardless
//! of backend, and [`DeviceBuffer`]s belong to the engine whose backend
//! allocated them.  All host-side state around it — [`RuntimeStats`]
//! snapshots, the executable cache, tensors, `ModelState`, the manifest —
//! is `Arc`-based and thread-safe, so multi-worker pools give each worker
//! thread its own `Engine` and move only `Send` data across threads.

pub mod pjrt;
pub mod refback;

pub use pjrt::{literal_to_tensor, tensor_to_literal};
pub use refback::{default_threads as default_ref_threads, threads_per_worker};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::models::{ArchManifest, ModelState};
use crate::obs::metrics::Counter;
use crate::tensor::Tensor;

/// Buffer-mode execution is unavailable (upload failed, the runtime
/// returned a packed tuple instead of untupled leaves, or the backend has
/// no device at all — the reference backend).  Callers with a
/// literal-mode fallback downcast to this to decide between "degrade
/// transport" and "real failure" — a diverged loss or a bad artifact must
/// never be retried on the other transport.
#[derive(Debug, thiserror::Error)]
#[error("device residency unsupported: {0}")]
pub struct ResidencyUnsupported(pub String);

/// Cumulative runtime counters (snapshot form).  Used by EXPERIMENTS.md
/// §Perf to split dispatch overhead from XLA execute time, and by the
/// residency benches to show transfer *volume*, not just time:
/// `bytes_uploaded`/`bytes_downloaded` count host->device and
/// device->host payload bytes across both transports.  The reference
/// backend counts executions and execute time but no transfer bytes —
/// nothing crosses a host/device boundary there.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub execute_ns: u64,
    pub upload_ns: u64,
    pub download_ns: u64,
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
}

/// Shared mutable counters: `obs::metrics::Counter` (relaxed atomics under
/// the hood) so executables can record from any thread that owns their
/// engine without locks on the hot path.  Engines are per-thread (PJRT
/// handles are not `Send`), so these stay per-engine rather than living in
/// the global metrics registry — `serve_bench.json` sums them per worker.
#[derive(Debug, Default)]
pub(crate) struct StatsCell {
    pub(crate) executions: Counter,
    pub(crate) execute_ns: Counter,
    pub(crate) upload_ns: Counter,
    pub(crate) download_ns: Counter,
    pub(crate) bytes_uploaded: Counter,
    pub(crate) bytes_downloaded: Counter,
}

impl StatsCell {
    pub(crate) fn snapshot(&self) -> RuntimeStats {
        RuntimeStats {
            executions: self.executions.get(),
            execute_ns: self.execute_ns.get(),
            upload_ns: self.upload_ns.get(),
            download_ns: self.download_ns.get(),
            bytes_uploaded: self.bytes_uploaded.get(),
            bytes_downloaded: self.bytes_downloaded.get(),
        }
    }

    fn reset(&self) {
        self.executions.reset();
        self.execute_ns.reset();
        self.upload_ns.reset();
        self.download_ns.reset();
        self.bytes_uploaded.reset();
        self.bytes_downloaded.reset();
    }
}

// ----- the backend trait -----------------------------------------------------

/// One compiled (or interpreted) graph.  Implementations record their own
/// execution/transfer counters into the engine's shared stats cell.
pub trait GraphExec {
    /// Execute with host tensors; returns the flattened output tuple.
    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>>;

    /// Execute with device-resident operands; outputs stay resident.
    /// Backends without residency return [`ResidencyUnsupported`].
    fn run_buffers(&self, inputs: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>>;
}

/// One backend-resident buffer payload.
pub trait DeviceBuf {
    /// Download to a host tensor (the only device->host path in buffer
    /// mode).  Shape is recovered backend-side, so callers never thread
    /// shape metadata through the hot loop.
    fn to_tensor(&self) -> Result<Tensor>;

    /// Downcast hook so a backend can recover its own concrete buffers
    /// from the type-erased operand list.
    fn as_any(&self) -> &dyn std::any::Any;
}

/// How graphs are resolved, compiled and executed.  Implementations are
/// per-engine (and therefore per-thread); they share the engine's stats
/// cell and record into it directly.
pub trait Backend {
    fn platform(&self) -> String;

    /// Resolve and prepare graph `tag` ("train", "eval", "init",
    /// "stage1", "stage2_b8", ...) of `arch`.  The PJRT backend maps the
    /// tag to an artifact file via the manifest and compiles it; the
    /// reference backend checks the manifest declares the tag and builds
    /// an interpreter over the arch descriptor.
    fn load_graph(&self, arch: &Arc<ArchManifest>, tag: &str) -> Result<Box<dyn GraphExec>>;

    /// Load a graph from an artifact file path directly (the kernel
    /// micro-bench graphs, which belong to no arch).  Errors on backends
    /// that have no artifact files.
    fn load_file(&self, path: &Path) -> Result<Box<dyn GraphExec>>;

    /// Upload one host tensor to a backend-resident buffer.  Errors are
    /// wrapped in [`ResidencyUnsupported`] so buffer-mode callers can
    /// distinguish "this transport is unavailable" from a real failure
    /// and degrade to literal mode.
    fn upload(&self, t: &Tensor) -> Result<DeviceBuffer>;

    /// Prepare inference graph `tag` over a lowered
    /// [`CompressedModel`](crate::models::compressed::CompressedModel):
    /// params, masks and qbits are baked into packed layers, so the
    /// returned graph takes the batch input as its only operand.
    /// Default: unsupported (only the reference backend executes packed
    /// forms today; the PJRT artifacts are dense by construction).
    fn load_compressed(
        &self,
        cm: &Arc<crate::models::compressed::CompressedModel>,
        tag: &str,
    ) -> Result<Box<dyn GraphExec>> {
        let _ = tag;
        bail!(
            "backend `{}` cannot execute compressed models (arch `{}`); \
             use --backend ref or the dense path",
            self.platform(),
            cm.arch.name
        )
    }
}

/// Backend selection, surfaced on the CLI as `--backend pjrt|ref`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    /// AOT HLO artifacts through the PJRT CPU client (production).
    Pjrt,
    /// Hermetic pure-Rust reference interpreter (CI / no artifacts).
    Ref,
}

impl BackendChoice {
    pub fn parse(s: &str) -> Option<BackendChoice> {
        match s {
            "pjrt" | "xla" => Some(BackendChoice::Pjrt),
            "ref" | "reference" | "host" => Some(BackendChoice::Ref),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Pjrt => "pjrt",
            BackendChoice::Ref => "ref",
        }
    }
}

// ----- executables and buffers ----------------------------------------------

/// A loaded graph plus IO bookkeeping — the object the hot loops hold.
/// Thin facade over the backend's [`GraphExec`].
pub struct Executable {
    pub name: String,
    imp: Box<dyn GraphExec>,
}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        self.imp.run(inputs)
    }

    /// Execute with device-resident operands; outputs stay resident.
    ///
    /// Nothing crosses the host boundary here.  Backends without
    /// residency (or a PJRT runtime that packs the output tuple) surface
    /// [`ResidencyUnsupported`], which callers answer by falling back to
    /// [`Executable::run`].
    ///
    /// No input donation/aliasing: inputs are borrowed, outputs are fresh
    /// buffers, and a consumed step-N state is freed when the caller drops
    /// its `DeviceBuffer`s after swapping in step N+1's outputs.
    pub fn run_buffers(&self, inputs: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>> {
        self.imp.run_buffers(inputs)
    }
}

/// One backend-resident array.  Belongs to the engine whose backend
/// allocated it and must not outlive it (the same per-thread discipline
/// as [`Executable`]s).
pub struct DeviceBuffer {
    imp: Box<dyn DeviceBuf>,
}

impl DeviceBuffer {
    pub(crate) fn new(imp: Box<dyn DeviceBuf>) -> DeviceBuffer {
        DeviceBuffer { imp }
    }

    pub(crate) fn inner(&self) -> &dyn DeviceBuf {
        self.imp.as_ref()
    }

    /// Download to a host tensor (the only device->host path in buffer
    /// mode).
    pub fn to_tensor(&self) -> Result<Tensor> {
        self.imp.to_tensor()
    }
}

// ----- device-resident state -------------------------------------------------

/// Device-side mirror of the pieces of `ModelState` the AOT graphs consume:
/// params, momenta, masks, and the qbits scalars.  The training loop swaps
/// `params`/`momenta` for each step's output buffers, so step N+1 consumes
/// step N's results without materializing a single host tensor; masks and
/// qbits are upload-once invariants (no graph writes them).
///
/// Host tensors are produced exactly once per stage, by
/// [`DeviceState::to_host`] at the stage boundary — the point where the
/// plan cache snapshots `ModelState` (see DESIGN.md §Device residency).
pub struct DeviceState {
    pub params: Vec<DeviceBuffer>,
    pub momenta: Vec<DeviceBuffer>,
    pub masks: Vec<DeviceBuffer>,
    pub qbw: DeviceBuffer,
    pub qba: DeviceBuffer,
}

impl DeviceState {
    /// Upload a full model state (the stage-entry cost, paid once — not
    /// per step).
    pub fn from_model(engine: &Engine, state: &ModelState) -> Result<DeviceState> {
        let up_all = |ts: &[Tensor]| -> Result<Vec<DeviceBuffer>> {
            ts.iter().map(|t| engine.upload(t)).collect()
        };
        Ok(DeviceState {
            params: up_all(&state.params)?,
            momenta: up_all(&state.momenta)?,
            masks: up_all(&state.masks)?,
            qbw: engine.upload(&Tensor::scalar(state.qbits.weight))?,
            qba: engine.upload(&Tensor::scalar(state.qbits.act))?,
        })
    }

    /// Materialize the trained params/momenta back into `state` — the
    /// single host-materialization point of a training stage.  Masks and
    /// qbits are never written by any graph, so the host copies are
    /// already current.  Literal round-trips are exact f32 bytes, so a
    /// state that went device-side and back is bit-identical to one that
    /// never left the host.
    pub fn to_host(&self, state: &mut ModelState) -> Result<()> {
        state.params = self.params.iter().map(|b| b.to_tensor()).collect::<Result<_>>()?;
        state.momenta = self.momenta.iter().map(|b| b.to_tensor()).collect::<Result<_>>()?;
        Ok(())
    }
}

// ----- the engine ------------------------------------------------------------

/// The execution engine: one backend + an executable cache.  One engine
/// per thread — see the module-level threading notes.
///
/// The cache is keyed by artifact file name (`load`) or `arch-name/tag`
/// (`load_graph`); like the artifact-file convention it assumes one
/// manifest per engine — callers that rebuild a same-named arch build a
/// fresh engine.
pub struct Engine {
    backend: Box<dyn Backend>,
    choice: BackendChoice,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    stats: Arc<StatsCell>,
}

impl Engine {
    /// Production engine: PJRT over an artifacts directory.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        Self::with_backend(BackendChoice::Pjrt, artifacts_dir)
    }

    /// Hermetic reference engine: no artifacts, no device, deterministic.
    /// Kernel threads resolve via `COC_REF_THREADS` / available
    /// parallelism ([`refback::default_threads`]).
    pub fn new_ref() -> Result<Self> {
        Self::with_backend(BackendChoice::Ref, "")
    }

    /// Reference engine with an explicit kernel thread budget (results
    /// are bit-identical at every setting — the budget is throughput
    /// only).
    pub fn new_ref_with_threads(threads: usize) -> Result<Self> {
        Self::with_backend_threads(BackendChoice::Ref, "", threads)
    }

    /// Explicit backend selection (the `--backend pjrt|ref` CLI path).
    pub fn with_backend<P: AsRef<Path>>(choice: BackendChoice, artifacts_dir: P) -> Result<Self> {
        Self::with_backend_threads(choice, artifacts_dir, refback::default_threads())
    }

    /// Explicit backend + kernel thread budget (`--ref-threads`).  The
    /// thread budget only applies to the reference backend's kernels;
    /// PJRT ignores it (XLA owns its own threading).  Worker pools pass
    /// [`threads_per_worker`] shares here so serve workers and plan
    /// `--jobs` workers compose with kernel threads without
    /// oversubscription.
    pub fn with_backend_threads<P: AsRef<Path>>(
        choice: BackendChoice,
        artifacts_dir: P,
        ref_threads: usize,
    ) -> Result<Self> {
        let stats = Arc::new(StatsCell::default());
        let artifacts_dir = artifacts_dir.as_ref().to_path_buf();
        let backend: Box<dyn Backend> = match choice {
            BackendChoice::Pjrt => {
                Box::new(pjrt::PjrtBackend::new(artifacts_dir.clone(), stats.clone())?)
            }
            BackendChoice::Ref => Box::new(refback::RefBackend::new(stats.clone(), ref_threads)),
        };
        Ok(Engine {
            backend,
            choice,
            artifacts_dir,
            cache: Mutex::new(HashMap::new()),
            stats,
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn backend(&self) -> BackendChoice {
        self.choice
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.snapshot()
    }

    pub fn reset_stats(&self) {
        self.stats.reset();
    }

    /// Upload one host tensor to a backend-resident buffer.  See
    /// [`Backend::upload`] for the error contract.
    pub fn upload(&self, t: &Tensor) -> Result<DeviceBuffer> {
        self.backend.upload(t)
    }

    /// Load graph `tag` of `arch` (cached per engine).  This is the
    /// backend-generic entry every arch-graph consumer uses; which bytes
    /// (if any) back the graph is the backend's business.
    pub fn load_graph(&self, arch: &Arc<ArchManifest>, tag: &str) -> Result<Arc<Executable>> {
        let key = format!("graph::{}::{tag}", arch.name);
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let imp = self
            .backend
            .load_graph(arch, tag)
            .with_context(|| format!("loading graph `{tag}` of arch `{}`", arch.name))?;
        let exec = Arc::new(Executable { name: format!("{}/{tag}", arch.name), imp });
        self.cache.lock().unwrap().insert(key, exec.clone());
        Ok(exec)
    }

    /// Load inference graph `tag` over a lowered compressed model.
    /// Uncached: compressed models are per-leaf values (not arch-keyed
    /// like dense graphs), and callers hold the returned executable for
    /// the lifetime they need.
    pub fn load_compressed_graph(
        &self,
        cm: &Arc<crate::models::compressed::CompressedModel>,
        tag: &str,
    ) -> Result<Arc<Executable>> {
        let imp = self
            .backend
            .load_compressed(cm, tag)
            .with_context(|| format!("loading compressed graph `{tag}` of `{}`", cm.arch.name))?;
        Ok(Arc::new(Executable { name: format!("compressed::{}::{tag}", cm.arch.name), imp }))
    }

    /// Load a graph from an artifact file (cached).  Kernel bench graphs
    /// only; arch graphs go through [`Engine::load_graph`].
    pub fn load(&self, file: &str) -> Result<Arc<Executable>> {
        let key = format!("file::{file}");
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(file);
        let imp = self.backend.load_file(&path)?;
        let exec = Arc::new(Executable { name: file.to_string(), imp });
        self.cache.lock().unwrap().insert(key, exec.clone());
        Ok(exec)
    }
}

/// Upload the invariant operand prefix shared by the eval and staged
/// serving graphs: `params* ++ masks* ++ qbw ++ qba`, in graph operand
/// order.  One definition so `train::eval_logits` and
/// `serve::StageRunner` can never drift apart.
pub fn upload_eval_prefix(engine: &Engine, state: &ModelState) -> Result<Vec<DeviceBuffer>> {
    let mut prefix = Vec::with_capacity(state.params.len() + state.masks.len() + 2);
    for t in state.params.iter().chain(state.masks.iter()) {
        prefix.push(engine.upload(t)?);
    }
    prefix.push(engine.upload(&Tensor::scalar(state.qbits.weight))?);
    prefix.push(engine.upload(&Tensor::scalar(state.qbits.act))?);
    Ok(prefix)
}

/// Log the first buffer-mode -> literal-mode fallback of the process (once:
/// when residency is unavailable it is unavailable for every subsequent
/// call, and the hot loops would otherwise print per stage/batch).
pub fn note_residency_fallback(what: &str, e: &anyhow::Error) {
    static WARNED: std::sync::Once = std::sync::Once::new();
    WARNED.call_once(|| {
        crate::obs::log!(
            crate::obs::Level::Warn,
            "[runtime] {what}: {e:#}; falling back to literal marshalling (logged once)"
        );
    });
}

/// Shared helper for backends: mixing buffers from another backend (or
/// engine) into an operand list is a caller bug, reported uniformly.
pub(crate) fn foreign_buffer_error(backend: &str) -> anyhow::Error {
    anyhow!("operand buffer was not allocated by this {backend} backend")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_snapshot_starts_zero() {
        let c = StatsCell::default();
        c.executions.add(3);
        assert_eq!(c.snapshot().executions, 3);
        c.reset();
        assert_eq!(c.snapshot().executions, 0);
    }

    #[test]
    fn stats_track_transfer_bytes() {
        let c = StatsCell::default();
        c.bytes_uploaded.add(1024);
        c.bytes_downloaded.add(8);
        let snap = c.snapshot();
        assert_eq!(snap.bytes_uploaded, 1024);
        assert_eq!(snap.bytes_downloaded, 8);
        c.reset();
        assert_eq!(c.snapshot().bytes_uploaded, 0);
        assert_eq!(c.snapshot().bytes_downloaded, 0);
    }

    #[test]
    fn residency_unsupported_is_downcastable() {
        // The train/eval/serve fallbacks rely on recovering this marker
        // from an anyhow chain to pick "degrade transport" over "fail".
        let e: anyhow::Error = ResidencyUnsupported("no buffer api".into()).into();
        assert!(e.downcast_ref::<ResidencyUnsupported>().is_some());
        assert!(e.to_string().contains("device residency unsupported"));
    }

    #[test]
    fn backend_choice_parses() {
        assert_eq!(BackendChoice::parse("pjrt"), Some(BackendChoice::Pjrt));
        assert_eq!(BackendChoice::parse("ref"), Some(BackendChoice::Ref));
        assert_eq!(BackendChoice::parse("reference"), Some(BackendChoice::Ref));
        assert_eq!(BackendChoice::parse("gpu"), None);
        assert_eq!(BackendChoice::Ref.name(), "ref");
        assert_eq!(BackendChoice::Pjrt.name(), "pjrt");
    }

    #[test]
    fn ref_engine_reports_backend_and_rejects_file_loads() {
        let e = Engine::new_ref().unwrap();
        assert_eq!(e.backend(), BackendChoice::Ref);
        assert!(e.platform().contains("ref"));
        assert!(e.load("kernel_qmatmul.hlo.txt").is_err(), "ref backend has no artifact files");
    }

    #[test]
    fn ref_engine_thread_budget_is_explicit_and_reported() {
        let e = Engine::new_ref_with_threads(3).unwrap();
        assert!(
            e.platform().contains("3 kernel threads"),
            "platform string should surface the kernel thread budget: {}",
            e.platform()
        );
        // Worker composition policy: each of 4 workers gets a 2-thread
        // share of an 8-thread budget, never less than 1.
        assert_eq!(threads_per_worker(8, 4), 2);
        assert_eq!(threads_per_worker(1, 4), 1);
    }

    #[test]
    fn ref_engine_upload_reports_residency_unsupported() {
        let e = Engine::new_ref().unwrap();
        let err = e.upload(&Tensor::scalar(1.0)).unwrap_err();
        assert!(
            err.downcast_ref::<ResidencyUnsupported>().is_some(),
            "ref upload must surface the fallback marker, got {err:#}"
        );
    }
}
