//! PJRT runtime: load AOT-compiled HLO text, compile once, execute from the
//! coordinator hot loop.
//!
//! Python/JAX only runs in the compile path (`make artifacts`); at
//! experiment time this module is the only bridge to XLA.  Interchange is
//! HLO *text* — see DESIGN.md and python/compile/aot.py for why.
//!
//! # Device residency (see DESIGN.md §Device residency)
//!
//! Two transports exist for every graph:
//!
//! * **Literal mode** ([`Executable::run`]) — marshal host [`Tensor`]s into
//!   `xla::Literal`s per call and download the whole output tuple.  Simple,
//!   always available, and the right shape for one-shot calls.
//! * **Buffer mode** ([`Executable::run_buffers`]) — operands are
//!   [`DeviceBuffer`]s already resident on the PJRT device; outputs come
//!   back as device buffers that the next call can consume *without* any
//!   host round-trip.  The training loop keeps its params/momenta resident
//!   across all steps ([`DeviceState`]) and only materializes host tensors
//!   at stage boundaries ([`DeviceState::to_host`]).
//!
//! Buffer-mode results rely on the runtime untupling the output (one
//! `PjRtBuffer` per tuple leaf).  When that (or buffer upload itself) is
//! unavailable, buffer-mode callers see a [`ResidencyUnsupported`] error
//! and fall back to literal mode — same graphs, same operand values,
//! bit-identical outputs, different transport.
//!
//! # Threading model (see DESIGN.md §Serving)
//!
//! The PJRT client and its loaded executables are raw FFI handles and are
//! *not* `Send`: an [`Engine`] is therefore a **per-thread** object, and
//! [`DeviceBuffer`]s belong to the engine whose client allocated them (and
//! must not outlive it, like executables).  All host-side state around it
//! — [`RuntimeStats`] snapshots, the executable cache, tensors,
//! `ModelState`, the manifest — is `Arc`-based and thread-safe, so the
//! multi-worker serving pool (`serve::worker`) gives each worker thread
//! its own `Engine` over the shared artifacts directory and moves only
//! `Send` data (jobs, tensors, model state) across threads.  Within one
//! engine, stats counters are atomics and the cache is behind a `Mutex`,
//! so nothing in this module assumes single-threaded use.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::models::ModelState;
use crate::tensor::Tensor;

/// Buffer-mode execution is unavailable (upload failed, or the runtime
/// returned a packed tuple instead of untupled leaves).  Callers with a
/// literal-mode fallback downcast to this to decide between "degrade
/// transport" and "real failure" — a diverged loss or a bad artifact must
/// never be retried on the other transport.
#[derive(Debug, thiserror::Error)]
#[error("device residency unsupported: {0}")]
pub struct ResidencyUnsupported(pub String);

/// Cumulative runtime counters (snapshot form).  Used by EXPERIMENTS.md
/// §Perf to split dispatch overhead from XLA execute time, and by the
/// residency benches to show transfer *volume*, not just time:
/// `bytes_uploaded`/`bytes_downloaded` count host->device and
/// device->host payload bytes across both transports.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub execute_ns: u64,
    pub upload_ns: u64,
    pub download_ns: u64,
    pub bytes_uploaded: u64,
    pub bytes_downloaded: u64,
}

/// Shared mutable counters: atomics so executables can record from any
/// thread that owns their engine without locks on the hot path.
#[derive(Debug, Default)]
struct StatsCell {
    executions: AtomicU64,
    execute_ns: AtomicU64,
    upload_ns: AtomicU64,
    download_ns: AtomicU64,
    bytes_uploaded: AtomicU64,
    bytes_downloaded: AtomicU64,
}

impl StatsCell {
    fn snapshot(&self) -> RuntimeStats {
        RuntimeStats {
            executions: self.executions.load(Ordering::Relaxed),
            execute_ns: self.execute_ns.load(Ordering::Relaxed),
            upload_ns: self.upload_ns.load(Ordering::Relaxed),
            download_ns: self.download_ns.load(Ordering::Relaxed),
            bytes_uploaded: self.bytes_uploaded.load(Ordering::Relaxed),
            bytes_downloaded: self.bytes_downloaded.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        self.executions.store(0, Ordering::Relaxed);
        self.execute_ns.store(0, Ordering::Relaxed);
        self.upload_ns.store(0, Ordering::Relaxed);
        self.download_ns.store(0, Ordering::Relaxed);
        self.bytes_uploaded.store(0, Ordering::Relaxed);
        self.bytes_downloaded.store(0, Ordering::Relaxed);
    }
}

/// A compiled executable plus IO bookkeeping.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    stats: Arc<StatsCell>,
}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    ///
    /// All our graphs are lowered with `return_tuple=True`, so PJRT hands
    /// back a single tuple buffer which we decompose into leaves.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let in_bytes: usize = inputs.iter().map(|t| 4 * t.len()).sum();
        let t1 = Instant::now();
        self.stats
            .upload_ns
            .fetch_add((t1 - t0).as_nanos() as u64, Ordering::Relaxed);
        self.stats.bytes_uploaded.fetch_add(in_bytes as u64, Ordering::Relaxed);

        let out = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing `{}`", self.name))?;
        let t2 = Instant::now();
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .execute_ns
            .fetch_add((t2 - t1).as_nanos() as u64, Ordering::Relaxed);

        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of `{}`", self.name))?;
        let leaves = lit.to_tuple().context("decomposing result tuple")?;
        let tensors = leaves
            .into_iter()
            .map(|l| literal_to_tensor(&l))
            .collect::<Result<Vec<_>>>()?;
        let out_bytes: usize = tensors.iter().map(|t| 4 * t.len()).sum();
        self.stats
            .download_ns
            .fetch_add(t2.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats.bytes_downloaded.fetch_add(out_bytes as u64, Ordering::Relaxed);
        Ok(tensors)
    }

    /// Execute with device-resident operands; outputs stay resident.
    ///
    /// Nothing crosses the host boundary here: no literal marshalling on
    /// the way in, no tuple download on the way out.  Results rely on the
    /// runtime untupling the output into one buffer per leaf; a packed
    /// single-buffer tuple for a multi-output graph surfaces at the call
    /// site as an output-count mismatch, which residency callers wrap in
    /// [`ResidencyUnsupported`] and answer by falling back to
    /// [`Executable::run`].
    ///
    /// No input donation/aliasing: inputs are borrowed, outputs are fresh
    /// buffers, and a consumed step-N state is freed when the caller drops
    /// its `DeviceBuffer`s after swapping in step N+1's outputs.
    pub fn run_buffers(&self, inputs: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>> {
        let bufs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|b| &b.buf).collect();
        let t0 = Instant::now();
        let mut out = self
            .exe
            .execute_b(&bufs)
            .with_context(|| format!("buffer-executing `{}`", self.name))?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .execute_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        anyhow::ensure!(!out.is_empty(), "`{}` returned no device results", self.name);
        Ok(out
            .swap_remove(0)
            .into_iter()
            .map(|buf| DeviceBuffer { buf, stats: self.stats.clone() })
            .collect())
    }
}

// ----- device-resident state -------------------------------------------------

/// One device-resident array: a `PjRtBuffer` plus the stats handle of the
/// engine that allocated it.  Belongs to that engine's client and must not
/// outlive it (the same per-thread discipline as [`Executable`]s).
pub struct DeviceBuffer {
    buf: xla::PjRtBuffer,
    stats: Arc<StatsCell>,
}

impl DeviceBuffer {
    /// Download to a host tensor (the only device->host path in buffer
    /// mode).  Shape is recovered from the on-device literal, so callers
    /// never thread shape metadata through the hot loop.
    pub fn to_tensor(&self) -> Result<Tensor> {
        let t0 = Instant::now();
        let lit = self.buf.to_literal_sync().context("downloading device buffer")?;
        let t = literal_to_tensor(&lit)?;
        self.stats
            .download_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats.bytes_downloaded.fetch_add(4 * t.len() as u64, Ordering::Relaxed);
        Ok(t)
    }
}

/// Device-side mirror of the pieces of `ModelState` the AOT graphs consume:
/// params, momenta, masks, and the qbits scalars.  The training loop swaps
/// `params`/`momenta` for each step's output buffers, so step N+1 consumes
/// step N's results without materializing a single host tensor; masks and
/// qbits are upload-once invariants (no graph writes them).
///
/// Host tensors are produced exactly once per stage, by
/// [`DeviceState::to_host`] at the stage boundary — the point where the
/// plan cache snapshots `ModelState` (see DESIGN.md §Device residency).
pub struct DeviceState {
    pub params: Vec<DeviceBuffer>,
    pub momenta: Vec<DeviceBuffer>,
    pub masks: Vec<DeviceBuffer>,
    pub qbw: DeviceBuffer,
    pub qba: DeviceBuffer,
}

impl DeviceState {
    /// Upload a full model state (the stage-entry cost, paid once — not
    /// per step).
    pub fn from_model(engine: &Engine, state: &ModelState) -> Result<DeviceState> {
        let up_all = |ts: &[Tensor]| -> Result<Vec<DeviceBuffer>> {
            ts.iter().map(|t| engine.upload(t)).collect()
        };
        Ok(DeviceState {
            params: up_all(&state.params)?,
            momenta: up_all(&state.momenta)?,
            masks: up_all(&state.masks)?,
            qbw: engine.upload(&Tensor::scalar(state.qbits.weight))?,
            qba: engine.upload(&Tensor::scalar(state.qbits.act))?,
        })
    }

    /// Materialize the trained params/momenta back into `state` — the
    /// single host-materialization point of a training stage.  Masks and
    /// qbits are never written by any graph, so the host copies are
    /// already current.  Literal round-trips are exact f32 bytes, so a
    /// state that went device-side and back is bit-identical to one that
    /// never left the host.
    pub fn to_host(&self, state: &mut ModelState) -> Result<()> {
        state.params = self.params.iter().map(|b| b.to_tensor()).collect::<Result<_>>()?;
        state.momenta = self.momenta.iter().map(|b| b.to_tensor()).collect::<Result<_>>()?;
        Ok(())
    }
}

/// The PJRT engine: one CPU client + an executable cache keyed by artifact
/// file name (compilation is seconds; every experiment reuses the cache).
///
/// One engine per thread — see the module-level threading notes.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    stats: Arc<StatsCell>,
}

impl Engine {
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            stats: Arc::new(StatsCell::default()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.snapshot()
    }

    pub fn reset_stats(&self) {
        self.stats.reset();
    }

    /// Upload one host tensor to a device-resident buffer.  Errors are
    /// wrapped in [`ResidencyUnsupported`] so buffer-mode callers can
    /// distinguish "this transport is unavailable" from a real failure
    /// and degrade to literal mode.
    pub fn upload(&self, t: &Tensor) -> Result<DeviceBuffer> {
        let t0 = Instant::now();
        let lit = tensor_to_literal(t)?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| ResidencyUnsupported(format!("buffer upload: {e}")))?;
        self.stats
            .upload_ns
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.stats.bytes_uploaded.fetch_add(4 * t.len() as u64, Ordering::Relaxed);
        Ok(DeviceBuffer { buf, stats: self.stats.clone() })
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, file: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(file) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text `{}` (run `make artifacts`?)", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t0 = Instant::now();
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling `{file}`"))?;
        let dt = t0.elapsed();
        if dt.as_millis() > 500 {
            eprintln!("[runtime] compiled {file} in {:.1}s", dt.as_secs_f64());
        }
        let exec = Arc::new(Executable {
            exe,
            name: file.to_string(),
            stats: self.stats.clone(),
        });
        self.cache.lock().unwrap().insert(file.to_string(), exec.clone());
        Ok(exec)
    }
}

/// Upload the invariant operand prefix shared by the eval and staged
/// serving graphs: `params* ++ masks* ++ qbw ++ qba`, in graph operand
/// order.  One definition so `train::eval_logits` and
/// `serve::StageRunner` can never drift apart.
pub fn upload_eval_prefix(engine: &Engine, state: &ModelState) -> Result<Vec<DeviceBuffer>> {
    let mut prefix = Vec::with_capacity(state.params.len() + state.masks.len() + 2);
    for t in state.params.iter().chain(state.masks.iter()) {
        prefix.push(engine.upload(t)?);
    }
    prefix.push(engine.upload(&Tensor::scalar(state.qbits.weight))?);
    prefix.push(engine.upload(&Tensor::scalar(state.qbits.act))?);
    Ok(prefix)
}

/// Log the first buffer-mode -> literal-mode fallback of the process (once:
/// when residency is unavailable it is unavailable for every subsequent
/// call, and the hot loops would otherwise print per stage/batch).
pub fn note_residency_fallback(what: &str, e: &anyhow::Error) {
    static WARNED: std::sync::Once = std::sync::Once::new();
    WARNED.call_once(|| {
        eprintln!("[runtime] {what}: {e:#}; falling back to literal marshalling (logged once)");
    });
}

// ----- literal <-> tensor ----------------------------------------------------

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // Scalar: reshape to rank 0.
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal has no array shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("literal is not f32")?;
    Ok(Tensor::new(dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let l = tensor_to_literal(&t).unwrap();
        let t2 = literal_to_tensor(&l).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar(3.5);
        let l = tensor_to_literal(&t).unwrap();
        let t2 = literal_to_tensor(&l).unwrap();
        assert_eq!(t2.shape, Vec::<usize>::new());
        assert_eq!(t2.data, vec![3.5]);
    }

    #[test]
    fn stats_snapshot_starts_zero() {
        let c = StatsCell::default();
        c.executions.fetch_add(3, Ordering::Relaxed);
        assert_eq!(c.snapshot().executions, 3);
        c.reset();
        assert_eq!(c.snapshot().executions, 0);
    }

    #[test]
    fn stats_track_transfer_bytes() {
        let c = StatsCell::default();
        c.bytes_uploaded.fetch_add(1024, Ordering::Relaxed);
        c.bytes_downloaded.fetch_add(8, Ordering::Relaxed);
        let snap = c.snapshot();
        assert_eq!(snap.bytes_uploaded, 1024);
        assert_eq!(snap.bytes_downloaded, 8);
        c.reset();
        assert_eq!(c.snapshot().bytes_uploaded, 0);
        assert_eq!(c.snapshot().bytes_downloaded, 0);
    }

    #[test]
    fn residency_unsupported_is_downcastable() {
        // The train/eval/serve fallbacks rely on recovering this marker
        // from an anyhow chain to pick "degrade transport" over "fail".
        let e: anyhow::Error = ResidencyUnsupported("no buffer api".into()).into();
        assert!(e.downcast_ref::<ResidencyUnsupported>().is_some());
        assert!(e.to_string().contains("device residency unsupported"));
    }
}
