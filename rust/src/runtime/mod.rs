//! PJRT runtime: load AOT-compiled HLO text, compile once, execute from the
//! coordinator hot loop.
//!
//! Python/JAX only runs in the compile path (`make artifacts`); at
//! experiment time this module is the only bridge to XLA.  Interchange is
//! HLO *text* — see DESIGN.md and python/compile/aot.py for why.
//!
//! # Threading model (see DESIGN.md §Serving)
//!
//! The PJRT client and its loaded executables are raw FFI handles and are
//! *not* `Send`: an [`Engine`] is therefore a **per-thread** object.  All
//! host-side state around it — [`RuntimeStats`] snapshots, the executable
//! cache, tensors, `ModelState`, the manifest — is `Arc`-based and
//! thread-safe, so the multi-worker serving pool (`serve::worker`) gives
//! each worker thread its own `Engine` over the shared artifacts directory
//! and moves only `Send` data (jobs, tensors, model state) across threads.
//! Within one engine, stats counters are atomics and the cache is behind a
//! `Mutex`, so nothing in this module assumes single-threaded use.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::tensor::Tensor;

/// Cumulative runtime counters (snapshot form).  Used by EXPERIMENTS.md
/// §Perf to split dispatch overhead from XLA execute time.
#[derive(Debug, Default, Clone)]
pub struct RuntimeStats {
    pub executions: u64,
    pub execute_ns: u64,
    pub upload_ns: u64,
    pub download_ns: u64,
}

/// Shared mutable counters: atomics so executables can record from any
/// thread that owns their engine without locks on the hot path.
#[derive(Debug, Default)]
struct StatsCell {
    executions: AtomicU64,
    execute_ns: AtomicU64,
    upload_ns: AtomicU64,
    download_ns: AtomicU64,
}

impl StatsCell {
    fn snapshot(&self) -> RuntimeStats {
        RuntimeStats {
            executions: self.executions.load(Ordering::Relaxed),
            execute_ns: self.execute_ns.load(Ordering::Relaxed),
            upload_ns: self.upload_ns.load(Ordering::Relaxed),
            download_ns: self.download_ns.load(Ordering::Relaxed),
        }
    }

    fn reset(&self) {
        self.executions.store(0, Ordering::Relaxed);
        self.execute_ns.store(0, Ordering::Relaxed);
        self.upload_ns.store(0, Ordering::Relaxed);
        self.download_ns.store(0, Ordering::Relaxed);
    }
}

/// A compiled executable plus IO bookkeeping.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    stats: Arc<StatsCell>,
}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    ///
    /// All our graphs are lowered with `return_tuple=True`, so PJRT hands
    /// back a single tuple buffer which we decompose into leaves.
    pub fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let t1 = Instant::now();
        self.stats
            .upload_ns
            .fetch_add((t1 - t0).as_nanos() as u64, Ordering::Relaxed);

        let out = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing `{}`", self.name))?;
        let t2 = Instant::now();
        self.stats.executions.fetch_add(1, Ordering::Relaxed);
        self.stats
            .execute_ns
            .fetch_add((t2 - t1).as_nanos() as u64, Ordering::Relaxed);

        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of `{}`", self.name))?;
        let leaves = lit.to_tuple().context("decomposing result tuple")?;
        let tensors = leaves
            .into_iter()
            .map(|l| literal_to_tensor(&l))
            .collect::<Result<Vec<_>>>()?;
        self.stats
            .download_ns
            .fetch_add(t2.elapsed().as_nanos() as u64, Ordering::Relaxed);
        Ok(tensors)
    }
}

/// The PJRT engine: one CPU client + an executable cache keyed by artifact
/// file name (compilation is seconds; every experiment reuses the cache).
///
/// One engine per thread — see the module-level threading notes.
pub struct Engine {
    client: xla::PjRtClient,
    artifacts_dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    stats: Arc<StatsCell>,
}

impl Engine {
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            stats: Arc::new(StatsCell::default()),
        })
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        self.stats.snapshot()
    }

    pub fn reset_stats(&self) {
        self.stats.reset();
    }

    /// Load + compile an HLO-text artifact (cached).
    pub fn load(&self, file: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(file) {
            return Ok(e.clone());
        }
        let path = self.artifacts_dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text `{}` (run `make artifacts`?)", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t0 = Instant::now();
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling `{file}`"))?;
        let dt = t0.elapsed();
        if dt.as_millis() > 500 {
            eprintln!("[runtime] compiled {file} in {:.1}s", dt.as_secs_f64());
        }
        let exec = Arc::new(Executable {
            exe,
            name: file.to_string(),
            stats: self.stats.clone(),
        });
        self.cache.lock().unwrap().insert(file.to_string(), exec.clone());
        Ok(exec)
    }
}

// ----- literal <-> tensor ----------------------------------------------------

pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    if t.shape.is_empty() {
        // Scalar: reshape to rank 0.
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

pub fn literal_to_tensor(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape().context("literal has no array shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>().context("literal is not f32")?;
    Ok(Tensor::new(dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let l = tensor_to_literal(&t).unwrap();
        let t2 = literal_to_tensor(&l).unwrap();
        assert_eq!(t, t2);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = Tensor::scalar(3.5);
        let l = tensor_to_literal(&t).unwrap();
        let t2 = literal_to_tensor(&l).unwrap();
        assert_eq!(t2.shape, Vec::<usize>::new());
        assert_eq!(t2.data, vec![3.5]);
    }

    #[test]
    fn stats_snapshot_starts_zero() {
        let c = StatsCell::default();
        c.executions.fetch_add(3, Ordering::Relaxed);
        assert_eq!(c.snapshot().executions, 3);
        c.reset();
        assert_eq!(c.snapshot().executions, 0);
    }
}
