//! Reusable scratch arena for the reference backend's hot loops.
//!
//! The naive interpreter allocated a fresh `Vec` for every op output,
//! every forward trace, every gradient and every per-step parameter
//! clone; over a training stage that is thousands of allocator
//! round-trips per step.  A [`Scratch`] keeps retired buffers on shelves
//! and hands them back out, so the steady state of a train/eval/serve
//! loop reuses the same allocations step after step.
//!
//! Ownership rules (DESIGN.md §Backends):
//!
//! * One arena per `RefGraph`, behind a `Mutex` the graph locks once per
//!   `run` — buffers never cross graphs or engines.
//! * `take(len)` returns a **zero-filled** buffer of exactly `len` — a
//!   recycled buffer is indistinguishable from a fresh allocation, so
//!   reuse can never perturb a value (determinism is the contract).
//!   `take_full(len)` skips that memset for outputs the caller provably
//!   writes in full (conv/matmul/norm outputs); accumulator buffers
//!   always go through `take`.
//! * Buffers that escape to the caller (returned output tensors) simply
//!   never come back — the arena only tracks what is explicitly
//!   [`Scratch::recycle`]d, and callers recycle exactly the intermediates
//!   they own (traces, activations, partials).
//! * Shelves are bounded ([`MAX_SHELF`]); overflow buffers drop and free.
//! * The im2col GEMM panel (`kernels::conv2d`) follows the same rules:
//!   taken via `take_full` *before* the parallel section, handed to the
//!   batch items as disjoint per-item chunks, recycled after the join —
//!   it never outlives the call and never crosses graphs.

use crate::tensor::Tensor;

/// Retired buffers kept per type; bounds arena growth if a caller
/// recycles more than it takes (it should not).
const MAX_SHELF: usize = 128;

#[derive(Default)]
pub struct Scratch {
    f32s: Vec<Vec<f32>>,
    u32s: Vec<Vec<u32>>,
}

impl Scratch {
    /// A zero-filled `f32` buffer of exactly `len`, reusing a retired
    /// allocation when one is big enough (best-fit by capacity).
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        match best_fit(&self.f32s, len) {
            Some(i) => {
                let mut v = self.f32s.swap_remove(i);
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => vec![0.0; len],
        }
    }

    /// Like [`Scratch::take`] but with **unspecified contents** (stale
    /// values from a previous use may remain) — skips the zero-fill
    /// memset, for outputs the caller provably writes in full (conv /
    /// matmul / norm outputs; the kernel property tests and the
    /// recycled-arena determinism test would catch any element left
    /// unwritten).  Accumulator buffers (`+=` targets) must use `take`.
    pub fn take_full(&mut self, len: usize) -> Vec<f32> {
        match best_fit(&self.f32s, len) {
            Some(i) => {
                let mut v = self.f32s.swap_remove(i);
                if v.len() > len {
                    v.truncate(len);
                } else {
                    // Only the appended region beyond the old length pays
                    // an initialization pass.
                    v.resize(len, 0.0);
                }
                v
            }
            None => vec![0.0; len],
        }
    }

    /// Like [`Scratch::take`] for the `u32` pool-route buffers.
    pub fn take_u32(&mut self, len: usize) -> Vec<u32> {
        match best_fit(&self.u32s, len) {
            Some(i) => {
                let mut v = self.u32s.swap_remove(i);
                v.clear();
                v.resize(len, 0);
                v
            }
            None => vec![0; len],
        }
    }

    pub fn recycle(&mut self, v: Vec<f32>) {
        if v.capacity() > 0 && self.f32s.len() < MAX_SHELF {
            self.f32s.push(v);
        }
    }

    pub fn recycle_u32(&mut self, v: Vec<u32>) {
        if v.capacity() > 0 && self.u32s.len() < MAX_SHELF {
            self.u32s.push(v);
        }
    }

    /// Retire a whole tensor's storage back to the arena.
    pub fn recycle_tensor(&mut self, t: Tensor) {
        self.recycle(t.data);
    }

    /// Buffers currently shelved (test/introspection hook).
    pub fn shelved(&self) -> usize {
        self.f32s.len() + self.u32s.len()
    }
}

/// Index of the smallest shelved buffer whose capacity covers `len`, so a
/// small request does not pin the largest buffer.
fn best_fit<T>(shelf: &[Vec<T>], len: usize) -> Option<usize> {
    let mut best: Option<(usize, usize)> = None;
    for (i, v) in shelf.iter().enumerate() {
        let cap = v.capacity();
        if cap >= len && best.map(|(_, c)| cap < c).unwrap_or(true) {
            best = Some((i, cap));
        }
    }
    // No buffer is big enough: grow the largest one rather than malloc
    // anew (steady-state sizes repeat, so this settles after warmup).
    if best.is_none() && !shelf.is_empty() {
        let mut imax = 0;
        for (i, v) in shelf.iter().enumerate() {
            if v.capacity() > shelf[imax].capacity() {
                imax = i;
            }
        }
        return Some(imax);
    }
    best.map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_after_recycle() {
        let mut s = Scratch::default();
        let mut v = s.take(4);
        v.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let cap = v.capacity();
        s.recycle(v);
        let v2 = s.take(3);
        assert_eq!(v2, vec![0.0; 3], "recycled buffer must be indistinguishable from fresh");
        assert!(v2.capacity() >= 3);
        assert_eq!(v2.capacity(), cap, "allocation was reused, not re-made");
    }

    #[test]
    fn take_full_skips_the_memset_but_sizes_exactly() {
        let mut s = Scratch::default();
        s.recycle(vec![7.0; 8]);
        let v = s.take_full(4);
        assert_eq!(v.len(), 4, "exact length, stale contents allowed");
        assert_eq!(v, vec![7.0; 4], "reused storage keeps prior values (callers overwrite)");
        s.recycle(v);
        let v = s.take_full(6);
        assert_eq!(v.len(), 6);
        assert_eq!(&v[4..], &[0.0, 0.0], "grown region is initialized");
        // Fresh allocations are zeroed either way.
        let mut empty = Scratch::default();
        assert_eq!(empty.take_full(3), vec![0.0; 3]);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut s = Scratch::default();
        s.recycle(Vec::with_capacity(100));
        s.recycle(Vec::with_capacity(10));
        let v = s.take(8);
        assert!(v.capacity() < 100, "small request must not pin the big buffer");
        assert_eq!(s.shelved(), 1);
    }

    #[test]
    fn grows_existing_buffer_when_none_fit() {
        let mut s = Scratch::default();
        s.recycle(vec![1.0; 4]);
        let v = s.take(16);
        assert_eq!(v, vec![0.0; 16]);
        assert_eq!(s.shelved(), 0, "the too-small buffer was taken and grown");
    }

    #[test]
    fn u32_shelf_independent() {
        let mut s = Scratch::default();
        s.recycle_u32(vec![7; 5]);
        assert_eq!(s.take_u32(5), vec![0; 5]);
        assert_eq!(s.take(2), vec![0.0; 2]);
    }

    #[test]
    fn tensor_recycling_roundtrip() {
        let mut s = Scratch::default();
        s.recycle_tensor(Tensor::ones(&[2, 3]));
        let v = s.take(6);
        assert_eq!(v, vec![0.0; 6]);
    }
}
