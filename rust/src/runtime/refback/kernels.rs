//! The reference backend's kernel layer: cache-blocked, batch-parallel,
//! allocation-free implementations of the ops the interpreter runs, plus
//! the retained naive reference kernels the property tests compare
//! against.
//!
//! # The canonical accumulation order
//!
//! Determinism here is stronger than "no data races": every output
//! element has **one** fixed accumulation order, independent of blocking,
//! batch size and thread count, so results are bit-identical at every
//! `--ref-threads` setting including 1.  The order (redefined once, in
//! this PR — see DESIGN.md §Backends):
//!
//! * **conv2d / dwconv2d forward** — each output element is a single f32
//!   chain from 0.0 over its in-bounds taps, `(ky, kx, ic)` ascending.
//!   No zero-skip: a 0.0 activation contributes its `±0.0` product like
//!   any other (the old `if xv != 0.0` branch is gone — it serialized the
//!   inner loop and made the chain data-dependent).
//! * **matmul** — per output element, the k-sum runs ascending, no
//!   zero-skip.
//! * **dot-shaped reductions** ([`lane_dot`]) — 8 fixed stripe lanes
//!   combined by a fixed tree.  Used where a backward pass reduces over
//!   channels (conv `dx`, dense `d act`, RMS-norm statistics).
//! * **cross-batch reductions** (`dw`, `db`) — per-item partials of fixed
//!   shape, reduced in item-index order (`pool::reduce_partials`).  This
//!   holds even single-threaded, so threading never re-associates a sum.
//!
//! Blocked kernels peel interior from border (no per-tap padding branch
//! in the interior), register-block the inner `cout` loops ([`MR`] x
//! [`NR`] accumulator tiles), parallelize over batch items (`pool`), and
//! draw every temporary from the caller's [`Scratch`] arena.  The hot
//! inner loops — the MR x NR tiles, the backward taps, the lane-order
//! reductions — run through [`super::simd`], which dispatches to
//! explicit AVX2/SSE2/NEON code producing the **same bits** as these
//! scalar loops (DESIGN.md §Backends, "SIMD tier"); big interior convs
//! additionally take an im2col+GEMM route through a packed scratch
//! panel, chosen by the shape-only heuristic [`im2col_pays`].  The
//! `naive_*` kernels implement the same canonical math in the plainest
//! textbook form (and stay scalar on purpose — they are the reference
//! the SIMD paths are pinned against); `cargo bench -- refback_kernels`
//! measures the gap and the property tests below pin bit-equality on
//! random shapes/strides.

use anyhow::{ensure, Result};

use super::pool;
use super::scratch::Scratch;
use super::simd;
use crate::tensor::Tensor;

/// Output pixels per register tile (conv) / rows per tile (matmul).
/// `models::compressed::BLOCK_R` must stay equal to this (pinned by a
/// test in `refback::compressed`): packed sparse blocks are sized to
/// the register tiles.
pub(crate) const MR: usize = 4;
/// Output channels per register tile (`models::compressed::BLOCK_C`).
pub(crate) const NR: usize = 8;

/// XLA SAME padding: total = max((out-1)·stride + k - in, 0), low = total/2.
pub fn same_pad_lo(inp: usize, out: usize, k: usize, stride: usize) -> usize {
    ((out - 1) * stride + k).saturating_sub(inp) / 2
}

/// Fixed-order striped dot product: lane `j` accumulates elements with
/// index ≡ j (mod 8); lanes combine by a fixed tree.  One canonical
/// order for every reduction over channels, the same whether the caller
/// is naive or blocked — and wide enough for the compiler to vectorize,
/// which a strict left-to-right f32 sum forbids.
#[inline]
pub fn lane_dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let main = n - n % 8;
    let mut l = [0.0f32; 8];
    let mut i = 0;
    while i < main {
        for j in 0..8 {
            l[j] += a[i + j] * b[i + j];
        }
        i += 8;
    }
    for (j, i) in (main..n).enumerate() {
        l[j] += a[i] * b[i];
    }
    ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
}

// ---------------------------------------------------------------------------
// Geometry
// ---------------------------------------------------------------------------

/// Shared conv geometry: SAME padding plus the interior output rectangle
/// `[oy0, oy1) x [ox0, ox1)` within which **every** tap of the k x k
/// window is in bounds — the peeled fast path needs no padding branches.
#[derive(Debug, Clone, Copy)]
pub struct ConvGeom {
    pub b: usize,
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub k: usize,
    pub cout: usize,
    pub stride: usize,
    pub ho: usize,
    pub wo: usize,
    pub ph: usize,
    pub pw: usize,
    pub oy0: usize,
    pub oy1: usize,
    pub ox0: usize,
    pub ox1: usize,
}

impl ConvGeom {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        b: usize,
        h: usize,
        w: usize,
        cin: usize,
        k: usize,
        cout: usize,
        stride: usize,
    ) -> ConvGeom {
        let stride = stride.max(1);
        let ho = h.div_ceil(stride);
        let wo = w.div_ceil(stride);
        let ph = same_pad_lo(h, ho, k, stride);
        let pw = same_pad_lo(w, wo, k, stride);
        // Interior along one axis: in*s >= pad (top tap in bounds) and
        // in*s + k - 1 - pad <= dim - 1 (bottom tap in bounds).
        let interior = |dim: usize, out: usize, pad: usize| -> (usize, usize) {
            let lo = pad.div_ceil(stride);
            let hi = if dim + pad >= k { ((dim + pad - k) / stride + 1).min(out) } else { 0 };
            (lo.min(hi), hi)
        };
        let (oy0, oy1) = interior(h, ho, ph);
        let (ox0, ox1) = interior(w, wo, pw);
        ConvGeom { b, h, w, cin, k, cout, stride, ho, wo, ph, pw, oy0, oy1, ox0, ox1 }
    }

    fn of_conv(x: &Tensor, w: &Tensor, stride: usize) -> Result<ConvGeom> {
        let (b, h, wd, cin) = dims4(x)?;
        ensure!(w.rank() == 4, "conv weight must be rank-4 HWIO, got {:?}", w.shape);
        let (k, cout) = (w.shape[0], w.shape[3]);
        ensure!(w.shape[1] == k, "conv weight must be square, got {:?}", w.shape);
        ensure!(w.shape[2] == cin, "conv weight cin {} != input channels {cin}", w.shape[2]);
        Ok(ConvGeom::new(b, h, wd, cin, k, cout, stride))
    }

    fn of_dwconv(x: &Tensor, w: &Tensor, stride: usize) -> Result<ConvGeom> {
        let (b, h, wd, c) = dims4(x)?;
        ensure!(w.rank() == 4, "dw weight must be rank-4, got {:?}", w.shape);
        let (k, cout) = (w.shape[0], w.shape[3]);
        ensure!(cout == c, "depthwise weight channels {cout} != input channels {c}");
        Ok(ConvGeom::new(b, h, wd, c, k, c, stride))
    }

    pub(crate) fn in_len(&self) -> usize {
        self.h * self.w * self.cin
    }

    pub(crate) fn out_len(&self) -> usize {
        self.ho * self.wo * self.cout
    }
}

pub fn dims4(t: &Tensor) -> Result<(usize, usize, usize, usize)> {
    ensure!(t.rank() == 4, "expected a rank-4 NHWC tensor, got shape {:?}", t.shape);
    Ok((t.shape[0], t.shape[1], t.shape[2], t.shape[3]))
}

// ---------------------------------------------------------------------------
// conv2d forward (blocked)
// ---------------------------------------------------------------------------

/// Blocked conv2d: NHWC x HWIO -> NHWC at SAME padding.  Batch-parallel;
/// `out` comes from (and temporaries return to) `scratch`.
pub fn conv2d(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    threads: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let _s = crate::obs::trace::span("refback.conv2d");
    let g = ConvGeom::of_conv(x, w, stride)?;
    let mut out = scratch.take_full(g.b * g.out_len());
    let flops = g.out_len() * g.k * g.k * g.cin;
    if im2col_pays(&g) {
        let kdim = g.k * g.k * g.cin;
        let plen = (g.oy1 - g.oy0) * (g.ox1 - g.ox0) * kdim;
        // One panel per batch item, all from the arena: zero steady-state
        // allocation once the shelf is warm.  The panel is recycled before
        // returning, so it never outlives the call (see scratch.rs).
        let mut panel = scratch.take_full(g.b * plen);
        pool::for_each_item2(
            threads,
            flops,
            g.b,
            (out.as_mut_slice(), g.out_len()),
            (panel.as_mut_slice(), plen),
            |bi, chunk, pnl| {
                let xi = &x.data[bi * g.in_len()..][..g.in_len()];
                conv2d_item_im2col(&g, xi, &w.data, chunk, pnl);
            },
        );
        scratch.recycle(panel);
    } else {
        pool::for_each_item(threads, flops, &mut out, g.out_len(), |bi, chunk| {
            conv2d_item(&g, &x.data[bi * g.in_len()..][..g.in_len()], &w.data, chunk);
        });
    }
    Ok(Tensor::new(vec![g.b, g.ho, g.wo, g.cout], out))
}

/// Shape-only heuristic for the im2col+GEMM route: pays when the
/// interior is big enough to amortize the pack and the GEMM runs full
/// tiles.  Deterministic in the geometry alone, so the route choice can
/// never depend on data — and both routes produce identical bits anyway
/// (pinned by `im2col_route_matches_direct_route_bitwise`).
fn im2col_pays(g: &ConvGeom) -> bool {
    let kdim = g.k * g.k * g.cin;
    let prows = g.oy1.saturating_sub(g.oy0) * g.ox1.saturating_sub(g.ox0);
    g.k > 1 && g.cout >= NR && prows >= 4 * MR && kdim >= 32
}

fn conv2d_item(g: &ConvGeom, x: &[f32], w: &[f32], out: &mut [f32]) {
    for oy in 0..g.ho {
        if oy >= g.oy0 && oy < g.oy1 && g.ox0 < g.ox1 {
            if g.ox0 > 0 {
                conv_edge_pixels(g, x, w, out, oy, 0, g.ox0);
            }
            conv_interior_row(g, x, w, out, oy);
            if g.ox1 < g.wo {
                conv_edge_pixels(g, x, w, out, oy, g.ox1, g.wo);
            }
        } else {
            conv_edge_pixels(g, x, w, out, oy, 0, g.wo);
        }
    }
}

/// im2col+GEMM route for one batch item: edges take the peeled edge
/// kernel; every interior pixel's receptive field is packed into one
/// `kdim`-long panel row in canonical `(ky, kx, ic)` tap order, then the
/// panel multiplies the HWIO weight matrix (`kdim x cout`) through the
/// shared 4x8 microkernel.  Packing reorders *reads* only — each output
/// element's accumulation chain is still the dense tap order, so the
/// bits match [`conv2d_item`] exactly.
fn conv2d_item_im2col(g: &ConvGeom, x: &[f32], w: &[f32], out: &mut [f32], panel: &mut [f32]) {
    for oy in 0..g.ho {
        if oy >= g.oy0 && oy < g.oy1 {
            if g.ox0 > 0 {
                conv_edge_pixels(g, x, w, out, oy, 0, g.ox0);
            }
            if g.ox1 < g.wo {
                conv_edge_pixels(g, x, w, out, oy, g.ox1, g.wo);
            }
        } else {
            conv_edge_pixels(g, x, w, out, oy, 0, g.wo);
        }
    }
    pack_interior(g, x, panel);
    gemm_interior(g, w, panel, out);
}

/// Fill panel row `p` (interior pixel `(oy0 + p/icols, ox0 + p%icols)`)
/// with its `k*k*cin` taps, `(ky, kx, ic)` ascending.  Stride 1 copies
/// each `ky` row as one contiguous `k*cin` run.
fn pack_interior(g: &ConvGeom, x: &[f32], panel: &mut [f32]) {
    let (s, k, cin) = (g.stride, g.k, g.cin);
    let kdim = k * k * cin;
    let icols = g.ox1 - g.ox0;
    for oy in g.oy0..g.oy1 {
        for ox in g.ox0..g.ox1 {
            let p = (oy - g.oy0) * icols + (ox - g.ox0);
            let prow = &mut panel[p * kdim..(p + 1) * kdim];
            let mut o = 0;
            for ky in 0..k {
                let iy = oy * s + ky - g.ph; // in bounds: interior invariant
                if s == 1 {
                    let start = (iy * g.w + ox - g.pw) * cin;
                    prow[o..o + k * cin].copy_from_slice(&x[start..start + k * cin]);
                    o += k * cin;
                } else {
                    for kx in 0..k {
                        let start = (iy * g.w + ox * s + kx - g.pw) * cin;
                        prow[o..o + cin].copy_from_slice(&x[start..start + cin]);
                        o += cin;
                    }
                }
            }
        }
    }
}

/// Panel `[prows x kdim]` times HWIO weights `[kdim x cout]` into the
/// interior rectangle of `out`.  Full MR x NR tiles go through
/// [`simd::gemm4x8`]; remainder rows/channels run the same ascending-k
/// scalar loop as `matmul_into`'s remainder branch.
fn gemm_interior(g: &ConvGeom, w: &[f32], panel: &[f32], out: &mut [f32]) {
    let cout = g.cout;
    let kdim = g.k * g.k * g.cin;
    let icols = g.ox1 - g.ox0;
    let prows = (g.oy1 - g.oy0) * icols;
    let out_off = |p: usize| {
        let oy = g.oy0 + p / icols;
        let ox = g.ox0 + p % icols;
        (oy * g.wo + ox) * cout
    };
    let mut p0 = 0;
    while p0 < prows {
        let mr = MR.min(prows - p0);
        let mut oc0 = 0;
        while oc0 < cout {
            let nc = NR.min(cout - oc0);
            if mr == MR && nc == NR {
                let mut acc = [[0.0f32; NR]; MR];
                let abase = [p0 * kdim, (p0 + 1) * kdim, (p0 + 2) * kdim, (p0 + 3) * kdim];
                simd::gemm4x8(&mut acc, panel, abase, kdim, &w[oc0..], cout);
                for (m, am) in acc.iter().enumerate() {
                    out[out_off(p0 + m) + oc0..][..NR].copy_from_slice(am);
                }
            } else {
                for p in p0..p0 + mr {
                    let prow = &panel[p * kdim..(p + 1) * kdim];
                    let off = out_off(p) + oc0;
                    out[off..off + nc].fill(0.0);
                    for (ki, &av) in prow.iter().enumerate() {
                        let wrow = &w[ki * cout + oc0..][..nc];
                        let orow = &mut out[off..off + nc];
                        for (o, &wv) in orow.iter_mut().zip(wrow) {
                            *o += av * wv;
                        }
                    }
                }
            }
            oc0 += nc;
        }
        p0 += mr;
    }
}

/// Border pixels: per-tap bounds checks, full-`cout` slice accumulator.
/// Per-element chain: in-bounds taps `(ky, kx, ic)` ascending.
fn conv_edge_pixels(
    g: &ConvGeom,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    oy: usize,
    x0: usize,
    x1: usize,
) {
    let (s, k, cin, cout) = (g.stride, g.k, g.cin, g.cout);
    for ox in x0..x1 {
        let off = (oy * g.wo + ox) * cout;
        out[off..off + cout].fill(0.0);
        for ky in 0..k {
            let iy = (oy * s + ky) as isize - g.ph as isize;
            if iy < 0 || iy >= g.h as isize {
                continue;
            }
            for kx in 0..k {
                let ix = (ox * s + kx) as isize - g.pw as isize;
                if ix < 0 || ix >= g.w as isize {
                    continue;
                }
                let xrow = &x[((iy as usize) * g.w + ix as usize) * cin..][..cin];
                let wbase = (ky * k + kx) * cin * cout;
                for (ic, &xv) in xrow.iter().enumerate() {
                    let wrow = &w[wbase + ic * cout..][..cout];
                    let acc = &mut out[off..off + cout];
                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                        *a += xv * wv;
                    }
                }
            }
        }
    }
}

/// Interior row: no padding branches anywhere; `MR x NR` register tiles
/// over (output pixel, output channel), remainders via
/// [`conv_interior_pixels`].  Same per-element chain as the edge path.
fn conv_interior_row(g: &ConvGeom, x: &[f32], w: &[f32], out: &mut [f32], oy: usize) {
    let cout = g.cout;
    let mut oc0 = 0;
    while oc0 < cout {
        let nc = NR.min(cout - oc0);
        if nc < NR {
            conv_interior_pixels(g, x, w, out, oy, g.ox0, g.ox1, oc0, nc);
            break;
        }
        let mut ox = g.ox0;
        while ox + MR <= g.ox1 {
            conv_tile(g, x, w, out, oy, ox, oc0);
            ox += MR;
        }
        if ox < g.ox1 {
            conv_interior_pixels(g, x, w, out, oy, ox, g.ox1, oc0, NR);
        }
        oc0 += NR;
    }
}

/// One full MR x NR register tile: accumulators live in registers across
/// the whole (ky, kx, ic) window, stored once at the end.
#[inline]
fn conv_tile(
    g: &ConvGeom,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    oy: usize,
    ox: usize,
    oc0: usize,
) {
    let (s, k, cin, cout) = (g.stride, g.k, g.cin, g.cout);
    let mut acc = [[0.0f32; NR]; MR];
    for ky in 0..k {
        let iy = oy * s + ky - g.ph; // in bounds: interior invariant
        let rowbase = iy * g.w * cin;
        for kx in 0..k {
            let mut xbase = [0usize; MR];
            for (m, xb) in xbase.iter_mut().enumerate() {
                *xb = rowbase + ((ox + m) * s + kx - g.pw) * cin;
            }
            let wbase = (ky * k + kx) * cin * cout + oc0;
            // The accumulators persist across taps, so chaining one
            // cin-deep microkernel call per (ky, kx) is the same single
            // per-element chain as the fused loop it replaces.
            simd::gemm4x8(&mut acc, x, xbase, cin, &w[wbase..], cout);
        }
    }
    for (m, am) in acc.iter().enumerate() {
        out[(oy * g.wo + ox + m) * g.cout + oc0..][..NR].copy_from_slice(am);
    }
}

/// Interior remainder pixels for one `[oc0, oc0+nc)` channel block: no
/// bounds checks, slice accumulator.
#[allow(clippy::too_many_arguments)]
fn conv_interior_pixels(
    g: &ConvGeom,
    x: &[f32],
    w: &[f32],
    out: &mut [f32],
    oy: usize,
    x0: usize,
    x1: usize,
    oc0: usize,
    nc: usize,
) {
    let (s, k, cin, cout) = (g.stride, g.k, g.cin, g.cout);
    for ox in x0..x1 {
        let off = (oy * g.wo + ox) * cout + oc0;
        out[off..off + nc].fill(0.0);
        for ky in 0..k {
            let iy = oy * s + ky - g.ph;
            for kx in 0..k {
                let ix = ox * s + kx - g.pw;
                let xrow = &x[(iy * g.w + ix) * cin..][..cin];
                let wbase = (ky * k + kx) * cin * cout + oc0;
                for (ic, &xv) in xrow.iter().enumerate() {
                    let wrow = &w[wbase + ic * cout..][..nc];
                    let acc = &mut out[off..off + nc];
                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                        *a += xv * wv;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// conv2d backward (blocked)
// ---------------------------------------------------------------------------

/// Gradient buffers of one conv; storage belongs to the caller's arena
/// (recycle after folding into the parameter gradients).
pub struct ConvGrads {
    pub dx: Vec<f32>,
    pub dw: Vec<f32>,
    pub db: Vec<f32>,
}

/// Blocked conv2d backward.  `dw`/`db` are cross-batch reductions:
/// per-item fixed-shape partials are materialized (from `scratch`) and
/// reduced in item-index order — bit-identical at every thread count.
pub fn conv2d_backward(
    x: &Tensor,
    w: &Tensor,
    gout: &Tensor,
    stride: usize,
    threads: usize,
    scratch: &mut Scratch,
) -> ConvGrads {
    let _s = crate::obs::trace::span("refback.conv2d_backward");
    let g = ConvGeom::new(
        x.shape[0],
        x.shape[1],
        x.shape[2],
        x.shape[3],
        w.shape[0],
        w.shape[3],
        stride,
    );
    debug_assert_eq!(gout.shape, [g.b, g.ho, g.wo, g.cout]);
    let wlen = w.len();
    let mut dx = scratch.take(x.len());
    let mut dwp = scratch.take(g.b * wlen);
    let mut dbp = scratch.take(g.b * g.cout);
    let flops = 2 * g.out_len() * g.k * g.k * g.cin;
    pool::for_each_item3(
        threads,
        flops,
        g.b,
        (dx.as_mut_slice(), g.in_len()),
        (dwp.as_mut_slice(), wlen),
        (dbp.as_mut_slice(), g.cout),
        |bi, dxi, dwi, dbi| {
            conv2d_bwd_item(
                &g,
                &x.data[bi * g.in_len()..][..g.in_len()],
                &w.data,
                &gout.data[bi * g.out_len()..][..g.out_len()],
                dxi,
                dwi,
                dbi,
            );
        },
    );
    let mut dw = scratch.take(wlen);
    let mut db = scratch.take(g.cout);
    pool::reduce_partials(&mut dw, &dwp);
    pool::reduce_partials(&mut db, &dbp);
    scratch.recycle(dwp);
    scratch.recycle(dbp);
    ConvGrads { dx, dw, db }
}

/// One conv-backward tap: `dw[tap] += xv·g` (vectorized over `cout`) and
/// `dx[tap] += <w[tap], g>` under the canonical lane order.
#[inline]
#[allow(clippy::too_many_arguments)]
fn conv_bwd_tap(
    cin: usize,
    cout: usize,
    x: &[f32],
    w: &[f32],
    grow: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    xbase: usize,
    wbase: usize,
) {
    simd::bwd_tap(
        &x[xbase..xbase + cin],
        &w[wbase..wbase + cin * cout],
        grow,
        &mut dx[xbase..xbase + cin],
        &mut dw[wbase..wbase + cin * cout],
    );
}

fn conv2d_bwd_item(
    g: &ConvGeom,
    x: &[f32],
    w: &[f32],
    gout: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    let (s, k, cin, cout) = (g.stride, g.k, g.cin, g.cout);
    for oy in 0..g.ho {
        let yin = oy >= g.oy0 && oy < g.oy1;
        for ox in 0..g.wo {
            let grow = &gout[(oy * g.wo + ox) * cout..][..cout];
            for (d, &gv) in db.iter_mut().zip(grow) {
                *d += gv;
            }
            if yin && ox >= g.ox0 && ox < g.ox1 {
                // Interior: every tap in bounds, no branches.
                for ky in 0..k {
                    let iy = oy * s + ky - g.ph;
                    for kx in 0..k {
                        let ix = ox * s + kx - g.pw;
                        let xbase = (iy * g.w + ix) * cin;
                        let wbase = (ky * k + kx) * cin * cout;
                        conv_bwd_tap(cin, cout, x, w, grow, dx, dw, xbase, wbase);
                    }
                }
            } else {
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - g.ph as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - g.pw as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let xbase = ((iy as usize) * g.w + ix as usize) * cin;
                        let wbase = (ky * k + kx) * cin * cout;
                        conv_bwd_tap(cin, cout, x, w, grow, dx, dw, xbase, wbase);
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// depthwise conv (blocked)
// ---------------------------------------------------------------------------

pub fn dwconv2d(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    threads: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let _s = crate::obs::trace::span("refback.dwconv2d");
    let g = ConvGeom::of_dwconv(x, w, stride)?;
    let mut out = scratch.take_full(g.b * g.out_len());
    let flops = g.ho * g.wo * g.cout * g.k * g.k;
    pool::for_each_item(threads, flops, &mut out, g.out_len(), |bi, chunk| {
        dwconv2d_item(&g, &x.data[bi * g.in_len()..][..g.in_len()], &w.data, chunk);
    });
    Ok(Tensor::new(vec![g.b, g.ho, g.wo, g.cout], out))
}

fn dwconv2d_item(g: &ConvGeom, x: &[f32], w: &[f32], out: &mut [f32]) {
    let (s, k, c) = (g.stride, g.k, g.cout);
    for oy in 0..g.ho {
        let yin = oy >= g.oy0 && oy < g.oy1;
        for ox in 0..g.wo {
            let off = (oy * g.wo + ox) * c;
            out[off..off + c].fill(0.0);
            if yin && ox >= g.ox0 && ox < g.ox1 {
                for ky in 0..k {
                    let iy = oy * s + ky - g.ph;
                    for kx in 0..k {
                        let ix = ox * s + kx - g.pw;
                        let xrow = &x[(iy * g.w + ix) * c..][..c];
                        let wrow = &w[(ky * k + kx) * c..][..c];
                        let acc = &mut out[off..off + c];
                        for ((a, &xv), &wv) in acc.iter_mut().zip(xrow).zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            } else {
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - g.ph as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - g.pw as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let xrow = &x[((iy as usize) * g.w + ix as usize) * c..][..c];
                        let wrow = &w[(ky * k + kx) * c..][..c];
                        let acc = &mut out[off..off + c];
                        for ((a, &xv), &wv) in acc.iter_mut().zip(xrow).zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
        }
    }
}

pub fn dwconv2d_backward(
    x: &Tensor,
    w: &Tensor,
    gout: &Tensor,
    stride: usize,
    threads: usize,
    scratch: &mut Scratch,
) -> ConvGrads {
    let _s = crate::obs::trace::span("refback.dwconv2d_backward");
    let c = x.shape[3];
    let g = ConvGeom::new(x.shape[0], x.shape[1], x.shape[2], c, w.shape[0], c, stride);
    let wlen = w.len();
    let mut dx = scratch.take(x.len());
    let mut dwp = scratch.take(g.b * wlen);
    let mut dbp = scratch.take(g.b * c);
    let flops = 2 * g.ho * g.wo * c * g.k * g.k;
    pool::for_each_item3(
        threads,
        flops,
        g.b,
        (dx.as_mut_slice(), g.in_len()),
        (dwp.as_mut_slice(), wlen),
        (dbp.as_mut_slice(), c),
        |bi, dxi, dwi, dbi| {
            dwconv2d_bwd_item(
                &g,
                &x.data[bi * g.in_len()..][..g.in_len()],
                &w.data,
                &gout.data[bi * g.out_len()..][..g.out_len()],
                dxi,
                dwi,
                dbi,
            );
        },
    );
    let mut dw = scratch.take(wlen);
    let mut db = scratch.take(c);
    pool::reduce_partials(&mut dw, &dwp);
    pool::reduce_partials(&mut db, &dbp);
    scratch.recycle(dwp);
    scratch.recycle(dbp);
    ConvGrads { dx, dw, db }
}

fn dwconv2d_bwd_item(
    g: &ConvGeom,
    x: &[f32],
    w: &[f32],
    gout: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    let (s, k, c) = (g.stride, g.k, g.cout);
    for oy in 0..g.ho {
        for ox in 0..g.wo {
            let grow = &gout[(oy * g.wo + ox) * c..][..c];
            for (d, &gv) in db.iter_mut().zip(grow) {
                *d += gv;
            }
            for ky in 0..k {
                let iy = (oy * s + ky) as isize - g.ph as isize;
                if iy < 0 || iy >= g.h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * s + kx) as isize - g.pw as isize;
                    if ix < 0 || ix >= g.w as isize {
                        continue;
                    }
                    let xbase = ((iy as usize) * g.w + ix as usize) * c;
                    let wbase = (ky * k + kx) * c;
                    for cc in 0..c {
                        let gv = grow[cc];
                        dw[wbase + cc] += x[xbase + cc] * gv;
                        dx[xbase + cc] += w[wbase + cc] * gv;
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// matmul (register-tiled)
// ---------------------------------------------------------------------------

/// `[m, k] @ [k, n] -> [m, n]`; per output element the k-sum runs
/// ascending from 0.0, no zero-skip.  MR x NR register tiles hold the
/// accumulators across the whole k loop.
pub fn matmul(a: &Tensor, w: &Tensor, scratch: &mut Scratch) -> Tensor {
    let _s = crate::obs::trace::span("refback.matmul");
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = w.shape[1];
    let mut out = scratch.take_full(m * n);
    matmul_into(m, k, n, &a.data, &w.data, &mut out);
    Tensor::new(vec![m, n], out)
}

pub fn matmul_into(m: usize, kdim: usize, n: usize, a: &[f32], w: &[f32], out: &mut [f32]) {
    let mut r0 = 0;
    while r0 < m {
        let mr = MR.min(m - r0);
        let mut c0 = 0;
        while c0 < n {
            let nc = NR.min(n - c0);
            if mr == MR && nc == NR {
                let mut acc = [[0.0f32; NR]; MR];
                let abase = [r0 * kdim, (r0 + 1) * kdim, (r0 + 2) * kdim, (r0 + 3) * kdim];
                simd::gemm4x8(&mut acc, a, abase, kdim, &w[c0..], n);
                for (mi, am) in acc.iter().enumerate() {
                    out[(r0 + mi) * n + c0..][..NR].copy_from_slice(am);
                }
            } else {
                for mi in r0..r0 + mr {
                    let arow = &a[mi * kdim..(mi + 1) * kdim];
                    out[mi * n + c0..][..nc].fill(0.0);
                    for (ki, &av) in arow.iter().enumerate() {
                        let wrow = &w[ki * n + c0..][..nc];
                        let orow = &mut out[mi * n + c0..][..nc];
                        for (o, &wv) in orow.iter_mut().zip(wrow) {
                            *o += av * wv;
                        }
                    }
                }
            }
            c0 += nc;
        }
        r0 += mr;
    }
}

// ---------------------------------------------------------------------------
// pooling / GAP / norms / pointwise
// ---------------------------------------------------------------------------

/// 2x2 stride-2 max-pool (VALID).  `record` additionally returns the
/// argmax route the pool backward pass consumes (empty otherwise).  Ties
/// keep the first window element (fixed scan order).
pub fn maxpool2(x: &Tensor, record: bool, scratch: &mut Scratch) -> Result<(Tensor, Vec<u32>)> {
    let (b, h, w, c) = dims4(x)?;
    ensure!(h >= 2 && w >= 2, "feature map {h}x{w} too small to pool");
    let ho = (h - 2) / 2 + 1;
    let wo = (w - 2) / 2 + 1;
    let mut out = scratch.take_full(b * ho * wo * c);
    let mut idx = if record { scratch.take_u32(b * ho * wo * c) } else { Vec::new() };
    for bi in 0..b {
        for oy in 0..ho {
            for ox in 0..wo {
                for cc in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut besti = usize::MAX;
                    for dy in 0..2 {
                        for dxp in 0..2 {
                            let fi = ((bi * h + oy * 2 + dy) * w + ox * 2 + dxp) * c + cc;
                            let v = x.data[fi];
                            if besti == usize::MAX || v > best {
                                best = v;
                                besti = fi;
                            }
                        }
                    }
                    let o = ((bi * ho + oy) * wo + ox) * c + cc;
                    out[o] = best;
                    if record {
                        idx[o] = besti as u32;
                    }
                }
            }
        }
    }
    Ok((Tensor::new(vec![b, ho, wo, c], out), idx))
}

/// Global average pool: [b, h, w, c] -> [b, c].
pub fn gap(x: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
    let (b, h, w, c) = dims4(x)?;
    let hw = (h * w) as f32;
    let mut out = scratch.take(b * c);
    for bi in 0..b {
        let orow = &mut out[bi * c..(bi + 1) * c];
        for p in 0..h * w {
            let xrow = &x.data[(bi * h * w + p) * c..][..c];
            for (o, &v) in orow.iter_mut().zip(xrow) {
                *o += v;
            }
        }
        for o in orow.iter_mut() {
            *o /= hw;
        }
    }
    Ok(Tensor::new(vec![b, c], out))
}

/// Per-sample RMS normalization over (H, W, C) with a live-channel
/// divisor (mirrors `archs.py::_rmsnorm`): y = x · rsqrt(Σx²/D + 1e-6),
/// D = H·W·live.  The Σx² statistic uses the canonical lane order.
/// Returns (y, per-sample rsqrt factors, D); `y` comes from `scratch`.
pub fn rmsnorm(x: &Tensor, live: f32, scratch: &mut Scratch) -> (Tensor, Vec<f32>, f32) {
    let (b, spl, d) = rms_dims(x, live);
    let mut out = scratch.take_full(x.len());
    let mut rs = Vec::with_capacity(b);
    for bi in 0..b {
        let row = &x.data[bi * spl..(bi + 1) * spl];
        let r = rms_factor(row, d);
        rs.push(r);
        for (o, &v) in out[bi * spl..(bi + 1) * spl].iter_mut().zip(row) {
            *o = v * r;
        }
    }
    (Tensor::new(x.shape.clone(), out), rs, d)
}

/// In-place [`rmsnorm`] for the trace-free inference path — identical
/// arithmetic (same statistic, same per-element multiply), so recording
/// never perturbs a value.
pub fn rmsnorm_inplace(x: &mut Tensor, live: f32) {
    let (b, spl, d) = rms_dims(x, live);
    for bi in 0..b {
        let row = &mut x.data[bi * spl..(bi + 1) * spl];
        let r = rms_factor(row, d);
        for v in row.iter_mut() {
            *v *= r;
        }
    }
}

fn rms_dims(x: &Tensor, live: f32) -> (usize, usize, f32) {
    let (b, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    (b, h * w * c, (h * w) as f32 * live)
}

#[inline]
fn rms_factor(row: &[f32], d: f32) -> f32 {
    let ms = simd::dot(row, row) / d;
    1.0 / (ms + 1e-6).sqrt()
}

/// d/dx of rmsnorm: dx = r·g − x·(Σ g·x)·r³/D, per sample; the Σ g·x
/// statistic uses the canonical lane order.
pub fn rmsnorm_backward(
    g: &Tensor,
    x_pre: &Tensor,
    rs: &[f32],
    d: f32,
    scratch: &mut Scratch,
) -> Tensor {
    let b = x_pre.shape[0];
    let spl = x_pre.len() / b.max(1);
    let mut out = scratch.take_full(g.len());
    for bi in 0..b {
        let grow = &g.data[bi * spl..(bi + 1) * spl];
        let xrow = &x_pre.data[bi * spl..(bi + 1) * spl];
        let r = rs[bi];
        let kf = simd::dot(grow, xrow) * r * r * r / d;
        for ((o, &gv), &xv) in out[bi * spl..(bi + 1) * spl].iter_mut().zip(grow).zip(xrow) {
            *o = r * gv - kf * xv;
        }
    }
    Tensor::new(g.shape.clone(), out)
}

pub fn relu_inplace(t: &mut Tensor) {
    for v in &mut t.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// DoReFa-style activation fake-quant with per-tensor dynamic scale
/// (mirrors `kernels/fake_quant.py::act_quant`); identity when bits <= 0.
/// The scale is a max-reduction — exact under any association, so it
/// needs no lane discipline.
pub fn act_quant_inplace(t: &mut Tensor, bits: f32) {
    if bits <= 0.0 {
        return;
    }
    let n = (bits.exp2() - 1.0).max(1.0);
    let mut s = 1e-8f32;
    for &v in &t.data {
        s = s.max(v.abs());
    }
    for v in &mut t.data {
        let an = (*v / s).clamp(0.0, 1.0);
        *v = (an * n).round() / n * s;
    }
}

pub fn add_channel_bias(t: &mut Tensor, bias: &[f32]) {
    let c = bias.len();
    for row in t.data.chunks_exact_mut(c) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

pub fn mul_channel_mask(t: &mut Tensor, mask: &[f32]) {
    let c = mask.len();
    for row in t.data.chunks_exact_mut(c) {
        for (v, &mv) in row.iter_mut().zip(mask) {
            *v *= mv;
        }
    }
}

pub fn add_row_bias(t: &mut Tensor, bias: &[f32]) {
    let n = bias.len();
    for row in t.data.chunks_exact_mut(n) {
        for (v, &bv) in row.iter_mut().zip(bias) {
            *v += bv;
        }
    }
}

pub fn add_assign(t: &mut Tensor, other: &Tensor) {
    debug_assert_eq!(t.len(), other.len());
    for (a, &b) in t.data.iter_mut().zip(&other.data) {
        *a += b;
    }
}

// ---------------------------------------------------------------------------
// Naive reference kernels
// ---------------------------------------------------------------------------
//
// The plainest possible implementations of the same canonical math:
// textbook per-element loops, per-tap bounds branches, memory
// accumulators, no blocking, no threads, fresh allocations.  They are the
// semantic ground truth the property tests compare the blocked kernels
// against, and the baseline the `refback_kernels` bench measures the
// speedup over.

pub fn naive_conv2d(x: &Tensor, w: &Tensor, stride: usize) -> Result<Tensor> {
    let g = ConvGeom::of_conv(x, w, stride)?;
    let (s, k, cin, cout) = (g.stride, g.k, g.cin, g.cout);
    let mut out = vec![0.0f32; g.b * g.out_len()];
    // The textbook 7-deep loop, sharing nothing with the blocked paths:
    // one scalar accumulator per output element, taps `(ky, kx, ic)`
    // ascending — the exact chain the blocked kernels must reproduce.
    for bi in 0..g.b {
        for oy in 0..g.ho {
            for ox in 0..g.wo {
                for oc in 0..cout {
                    let mut acc = 0.0f32;
                    for ky in 0..k {
                        let iy = (oy * s + ky) as isize - g.ph as isize;
                        if iy < 0 || iy >= g.h as isize {
                            continue;
                        }
                        for kx in 0..k {
                            let ix = (ox * s + kx) as isize - g.pw as isize;
                            if ix < 0 || ix >= g.w as isize {
                                continue;
                            }
                            for ic in 0..cin {
                                let xv = x.data
                                    [((bi * g.h + iy as usize) * g.w + ix as usize) * cin + ic];
                                let wv = w.data[((ky * k + kx) * cin + ic) * cout + oc];
                                acc += xv * wv;
                            }
                        }
                    }
                    out[((bi * g.ho + oy) * g.wo + ox) * cout + oc] = acc;
                }
            }
        }
    }
    Ok(Tensor::new(vec![g.b, g.ho, g.wo, g.cout], out))
}

pub fn naive_conv2d_backward(x: &Tensor, w: &Tensor, gout: &Tensor, stride: usize) -> ConvGrads {
    let g = ConvGeom::new(
        x.shape[0],
        x.shape[1],
        x.shape[2],
        x.shape[3],
        w.shape[0],
        w.shape[3],
        stride,
    );
    let wlen = w.len();
    let mut dx = vec![0.0f32; x.len()];
    let mut dwp = vec![0.0f32; g.b * wlen];
    let mut dbp = vec![0.0f32; g.b * g.cout];
    for bi in 0..g.b {
        naive_conv2d_bwd_item(
            &g,
            &x.data[bi * g.in_len()..][..g.in_len()],
            &w.data,
            &gout.data[bi * g.out_len()..][..g.out_len()],
            &mut dx[bi * g.in_len()..][..g.in_len()],
            &mut dwp[bi * wlen..][..wlen],
            &mut dbp[bi * g.cout..][..g.cout],
        );
    }
    let mut dw = vec![0.0f32; wlen];
    let mut db = vec![0.0f32; g.cout];
    pool::reduce_partials(&mut dw, &dwp);
    pool::reduce_partials(&mut db, &dbp);
    ConvGrads { dx, dw, db }
}

fn naive_conv2d_bwd_item(
    g: &ConvGeom,
    x: &[f32],
    w: &[f32],
    gout: &[f32],
    dx: &mut [f32],
    dw: &mut [f32],
    db: &mut [f32],
) {
    let (s, k, cin, cout) = (g.stride, g.k, g.cin, g.cout);
    for oy in 0..g.ho {
        for ox in 0..g.wo {
            let grow = &gout[(oy * g.wo + ox) * cout..][..cout];
            for (d, &gv) in db.iter_mut().zip(grow) {
                *d += gv;
            }
            for ky in 0..k {
                let iy = (oy * s + ky) as isize - g.ph as isize;
                if iy < 0 || iy >= g.h as isize {
                    continue;
                }
                for kx in 0..k {
                    let ix = (ox * s + kx) as isize - g.pw as isize;
                    if ix < 0 || ix >= g.w as isize {
                        continue;
                    }
                    let xbase = ((iy as usize) * g.w + ix as usize) * cin;
                    let wbase = (ky * k + kx) * cin * cout;
                    for ic in 0..cin {
                        let xv = x[xbase + ic];
                        let wrow = &w[wbase + ic * cout..][..cout];
                        for (oc, &gv) in grow.iter().enumerate() {
                            dw[wbase + ic * cout + oc] += xv * gv;
                        }
                        dx[xbase + ic] += lane_dot(wrow, grow);
                    }
                }
            }
        }
    }
}

pub fn naive_dwconv2d(x: &Tensor, w: &Tensor, stride: usize) -> Result<Tensor> {
    let g = ConvGeom::of_dwconv(x, w, stride)?;
    let c = g.cout;
    let mut out = vec![0.0f32; g.b * g.out_len()];
    for bi in 0..g.b {
        let xi = &x.data[bi * g.in_len()..][..g.in_len()];
        let oi = &mut out[bi * g.out_len()..][..g.out_len()];
        for oy in 0..g.ho {
            for ox in 0..g.wo {
                let off = (oy * g.wo + ox) * c;
                for ky in 0..g.k {
                    let iy = (oy * g.stride + ky) as isize - g.ph as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.k {
                        let ix = (ox * g.stride + kx) as isize - g.pw as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        for cc in 0..c {
                            oi[off + cc] += xi[((iy as usize) * g.w + ix as usize) * c + cc]
                                * w.data[(ky * g.k + kx) * c + cc];
                        }
                    }
                }
            }
        }
    }
    Ok(Tensor::new(vec![g.b, g.ho, g.wo, c], out))
}

pub fn naive_dwconv2d_backward(x: &Tensor, w: &Tensor, gout: &Tensor, stride: usize) -> ConvGrads {
    let c = x.shape[3];
    let g = ConvGeom::new(x.shape[0], x.shape[1], x.shape[2], c, w.shape[0], c, stride);
    let (s, k) = (g.stride, g.k);
    let wlen = w.len();
    let mut dx = vec![0.0f32; x.len()];
    let mut dwp = vec![0.0f32; g.b * wlen];
    let mut dbp = vec![0.0f32; g.b * c];
    // Independent transcription of the canonical order (per-item partials,
    // `(oy, ox)` ascending, in-bounds taps ascending) — deliberately NOT
    // the same code the blocked path runs, so a bug in one cannot hide in
    // the other.
    for bi in 0..g.b {
        for oy in 0..g.ho {
            for ox in 0..g.wo {
                for cc in 0..c {
                    let gv = gout.data[((bi * g.ho + oy) * g.wo + ox) * c + cc];
                    dbp[bi * c + cc] += gv;
                }
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - g.ph as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - g.pw as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        for cc in 0..c {
                            let gv = gout.data[((bi * g.ho + oy) * g.wo + ox) * c + cc];
                            let xi = ((bi * g.h + iy as usize) * g.w + ix as usize) * c + cc;
                            let wi = (ky * k + kx) * c + cc;
                            dwp[bi * wlen + wi] += x.data[xi] * gv;
                            dx[xi] += w.data[wi] * gv;
                        }
                    }
                }
            }
        }
    }
    let mut dw = vec![0.0f32; wlen];
    let mut db = vec![0.0f32; c];
    pool::reduce_partials(&mut dw, &dwp);
    pool::reduce_partials(&mut db, &dbp);
    ConvGrads { dx, dw, db }
}

pub fn naive_matmul(a: &Tensor, w: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let n = w.shape[1];
    let mut out = vec![0.0f32; m * n];
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0.0f32;
            for ki in 0..k {
                acc += a.data[mi * k + ki] * w.data[ki * n + ni];
            }
            out[mi * n + ni] = acc;
        }
    }
    Tensor::new(vec![m, n], out)
}

// ---------------------------------------------------------------------------
// Property tests: blocked == naive, bit for bit, at every thread count
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
        let data = (0..shape.iter().product::<usize>()).map(|_| rng.normal()).collect();
        Tensor::new(shape.to_vec(), data)
    }

    /// Decode a raw dim vector into a valid conv problem; shrinking the
    /// vector shrinks the problem.
    fn conv_case(v: &[usize]) -> Option<(usize, usize, usize, usize, usize, usize, usize, u64)> {
        if v.len() < 8 {
            return None;
        }
        let b = v[0] % 3 + 1;
        let h = v[1] % 7 + 3;
        let w = v[2] % 7 + 3;
        let cin = v[3] % 5 + 1;
        let cout = v[4] % 19 + 1; // crosses the NR=8 tile boundary
        let k = [1, 3, 5][v[5] % 3];
        let stride = v[6] % 2 + 1;
        Some((b, h, w, cin, cout, k, stride, v[7] as u64))
    }

    fn gen_dims(r: &mut Rng) -> Vec<usize> {
        (0..8).map(|_| r.below(1000)).collect()
    }

    #[test]
    fn prop_conv2d_blocked_equals_naive() {
        prop::check("conv2d blocked == naive", 60, gen_dims, |v| {
            let Some((b, h, w, cin, cout, k, s, seed)) = conv_case(v) else {
                return Ok(());
            };
            let mut rng = Rng::new(seed ^ 0xc0ffee);
            let x = rand_tensor(&[b, h, w, cin], &mut rng);
            let wt = rand_tensor(&[k, k, cin, cout], &mut rng);
            let want = naive_conv2d(&x, &wt, s).unwrap();
            for threads in [1usize, 2, 3] {
                let mut sc = Scratch::default();
                let got = conv2d(&x, &wt, s, threads, &mut sc).unwrap();
                if got.shape != want.shape || got.data != want.data {
                    return Err(format!(
                        "conv2d mismatch at {threads} threads (b={b} h={h} w={w} cin={cin} \
                         cout={cout} k={k} s={s})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_conv2d_backward_blocked_equals_naive() {
        prop::check("conv2d backward blocked == naive", 40, gen_dims, |v| {
            let Some((b, h, w, cin, cout, k, s, seed)) = conv_case(v) else {
                return Ok(());
            };
            let mut rng = Rng::new(seed ^ 0xdead);
            let x = rand_tensor(&[b, h, w, cin], &mut rng);
            let wt = rand_tensor(&[k, k, cin, cout], &mut rng);
            let ho = h.div_ceil(s);
            let wo = w.div_ceil(s);
            let gy = rand_tensor(&[b, ho, wo, cout], &mut rng);
            let want = naive_conv2d_backward(&x, &wt, &gy, s);
            for threads in [1usize, 2, 3] {
                let mut sc = Scratch::default();
                let got = conv2d_backward(&x, &wt, &gy, s, threads, &mut sc);
                if got.dx != want.dx || got.dw != want.dw || got.db != want.db {
                    return Err(format!(
                        "conv2d_backward mismatch at {threads} threads (b={b} h={h} w={w} \
                         cin={cin} cout={cout} k={k} s={s})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dwconv2d_blocked_equals_naive() {
        prop::check("dwconv2d blocked == naive", 40, gen_dims, |v| {
            let Some((b, h, w, c, _, k, s, seed)) = conv_case(v) else {
                return Ok(());
            };
            let mut rng = Rng::new(seed ^ 0xfeed);
            let x = rand_tensor(&[b, h, w, c], &mut rng);
            let wt = rand_tensor(&[k, k, 1, c], &mut rng);
            let want = naive_dwconv2d(&x, &wt, s).unwrap();
            let ho = h.div_ceil(s);
            let wo = w.div_ceil(s);
            let gy = rand_tensor(&[b, ho, wo, c], &mut rng);
            let wantb = naive_dwconv2d_backward(&x, &wt, &gy, s);
            for threads in [1usize, 2, 3] {
                let mut sc = Scratch::default();
                let got = dwconv2d(&x, &wt, s, threads, &mut sc).unwrap();
                if got.data != want.data || got.shape != want.shape {
                    return Err(format!("dwconv2d fwd mismatch at {threads} threads"));
                }
                let gotb = dwconv2d_backward(&x, &wt, &gy, s, threads, &mut sc);
                if gotb.dx != wantb.dx || gotb.dw != wantb.dw || gotb.db != wantb.db {
                    return Err(format!("dwconv2d bwd mismatch at {threads} threads"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_matmul_blocked_equals_naive() {
        prop::check("matmul blocked == naive", 80, gen_dims, |v| {
            if v.len() < 4 {
                return Ok(());
            }
            let m = v[0] % 9 + 1;
            let k = v[1] % 33 + 1;
            let n = v[2] % 21 + 1;
            let mut rng = Rng::new(v[3] as u64 ^ 0xabc);
            let a = rand_tensor(&[m, k], &mut rng);
            let w = rand_tensor(&[k, n], &mut rng);
            let want = naive_matmul(&a, &w);
            let mut sc = Scratch::default();
            let got = matmul(&a, &w, &mut sc);
            if got.data != want.data {
                return Err(format!("matmul mismatch (m={m} k={k} n={n})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_thread_count_invariance_on_threaded_sizes() {
        // Shapes big enough to clear the flops gate, so threads really
        // spawn: same bits at 1, 2 and 3 threads.
        prop::check("conv kernels thread-count invariant", 6, gen_dims, |v| {
            if v.len() < 2 {
                return Ok(());
            }
            let mut rng = Rng::new(v[0] as u64 ^ 0x717);
            let cout = 9 + v[1] % 12; // off-tile sizes included
            let x = rand_tensor(&[3, 14, 14, 8], &mut rng);
            let wt = rand_tensor(&[3, 3, 8, cout], &mut rng);
            let gy = rand_tensor(&[3, 14, 14, cout], &mut rng);
            let run = |threads: usize| {
                let mut sc = Scratch::default();
                let f = conv2d(&x, &wt, 1, threads, &mut sc).unwrap();
                let b = conv2d_backward(&x, &wt, &gy, 1, threads, &mut sc);
                (f.data, b.dx, b.dw, b.db)
            };
            let one = run(1);
            for t in [2usize, 3] {
                if run(t) != one {
                    return Err(format!("thread count {t} changed bits (cout={cout})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lane_dot_matches_f64_reference() {
        let mut rng = Rng::new(42);
        for n in [0usize, 1, 7, 8, 9, 16, 37] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let got = lane_dot(&a, &b) as f64;
            let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            assert!((got - want).abs() <= 1e-4 * (1.0 + want.abs()), "n={n}: {got} vs {want}");
        }
    }

    #[test]
    fn lane_dot_tail_matches_naive_stripe() {
        // The stripe remainder at every non-multiple-of-8 length 0..=17,
        // pinned bitwise against the plainest possible transcription of
        // the stripe rule: lane j sums elements with index ≡ j (mod 8).
        let mut rng = Rng::new(0x7a11);
        for n in 0..=17usize {
            let a: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut l = [0.0f32; 8];
            for i in 0..n {
                l[i % 8] += a[i] * b[i];
            }
            let want = ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]));
            assert_eq!(lane_dot(&a, &b).to_bits(), want.to_bits(), "n={n}");
        }
    }

    #[test]
    fn prop_kernels_bitwise_invariant_across_isa_paths() {
        // Every vectorized kernel, forced onto each ISA path the host
        // supports, must reproduce the scalar path's bits exactly.
        prop::check("kernels isa-invariant", 12, gen_dims, |v| {
            let Some((b, h, w, cin, cout, k, s, seed)) = conv_case(v) else {
                return Ok(());
            };
            let mut rng = Rng::new(seed ^ 0x51d);
            let x = rand_tensor(&[b, h, w, cin], &mut rng);
            let wt = rand_tensor(&[k, k, cin, cout], &mut rng);
            let ho = h.div_ceil(s);
            let wo = w.div_ceil(s);
            let gy = rand_tensor(&[b, ho, wo, cout], &mut rng);
            let gx = rand_tensor(&[b, h, w, cin], &mut rng);
            let a = rand_tensor(&[5, 37], &mut rng);
            let wm = rand_tensor(&[37, 13], &mut rng);
            let run = |isa: simd::Isa| {
                simd::with_forced(isa, || {
                    let mut sc = Scratch::default();
                    let f = conv2d(&x, &wt, s, 1, &mut sc).unwrap();
                    let bwd = conv2d_backward(&x, &wt, &gy, s, 1, &mut sc);
                    let mm = matmul(&a, &wm, &mut sc);
                    let (nrm, rs, d) = rmsnorm(&x, cin as f32, &mut sc);
                    let nb = rmsnorm_backward(&gx, &x, &rs, d, &mut sc);
                    (f.data, bwd.dx, bwd.dw, bwd.db, mm.data, nrm.data, nb.data)
                })
            };
            let want = run(simd::Isa::Scalar);
            for isa in simd::available() {
                if run(isa) != want {
                    return Err(format!(
                        "isa {} changed kernel bits (b={b} h={h} w={w} cin={cin} cout={cout} \
                         k={k} s={s})",
                        isa.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn im2col_route_matches_direct_route_bitwise() {
        // Shapes chosen to clear `im2col_pays` (big interior, kdim >= 32,
        // cout >= NR), compared against the direct per-item path.
        let mut rng = Rng::new(0x12c01);
        let cases = [
            (12usize, 12usize, 8usize, 16usize, 3usize, 1usize),
            (13, 11, 4, 9, 3, 1),
            (16, 16, 2, 8, 5, 2),
        ];
        for (h, w, cin, cout, k, s) in cases {
            let g = ConvGeom::new(2, h, w, cin, k, cout, s);
            assert!(im2col_pays(&g), "case h={h} w={w} must route through im2col");
            let x = rand_tensor(&[2, h, w, cin], &mut rng);
            let wt = rand_tensor(&[k, k, cin, cout], &mut rng);
            let mut sc = Scratch::default();
            let got = conv2d(&x, &wt, s, 2, &mut sc).unwrap();
            let mut direct = vec![0.0f32; 2 * g.out_len()];
            for bi in 0..2 {
                conv2d_item(
                    &g,
                    &x.data[bi * g.in_len()..][..g.in_len()],
                    &wt.data,
                    &mut direct[bi * g.out_len()..][..g.out_len()],
                );
            }
            assert_eq!(
                got.data, direct,
                "im2col route diverged (h={h} w={w} cin={cin} cout={cout} k={k} s={s})"
            );
        }
    }

    #[test]
    fn interior_bounds_are_actually_interior() {
        let cases = [(16usize, 16usize, 3usize, 1usize), (9, 7, 5, 2), (4, 4, 3, 2), (3, 3, 5, 1)];
        for (h, w, k, s) in cases {
            let g = ConvGeom::new(1, h, w, 1, k, 1, s);
            for oy in g.oy0..g.oy1 {
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - g.ph as isize;
                    assert!(iy >= 0 && (iy as usize) < h, "oy={oy} ky={ky} h={h} k={k} s={s}");
                }
            }
            for ox in g.ox0..g.ox1 {
                for kx in 0..k {
                    let ix = (ox * s + kx) as isize - g.pw as isize;
                    assert!(ix >= 0 && (ix as usize) < w, "ox={ox} kx={kx} w={w} k={k} s={s}");
                }
            }
            // And the first excluded rows/cols (if any) are genuinely not.
            if g.oy1 < g.ho {
                let oy = g.oy1;
                let any_oob = (0..k).any(|ky| {
                    let iy = (oy * s + ky) as isize - g.ph as isize;
                    iy < 0 || iy >= h as isize
                });
                assert!(any_oob, "row {oy} excluded from interior but fully in bounds");
            }
        }
    }

    #[test]
    fn same_padding_geometry() {
        assert_eq!(same_pad_lo(16, 16, 3, 1), 1);
        assert_eq!(same_pad_lo(16, 8, 3, 2), 0); // total 1, low 0
        assert_eq!(same_pad_lo(16, 16, 1, 1), 0);
    }

    #[test]
    fn maxpool_route_recording_does_not_perturb() {
        let mut sc = Scratch::default();
        let x = Tensor::ones(&[1, 5, 5, 1]);
        let (p, idx) = maxpool2(&x, true, &mut sc).unwrap();
        assert_eq!(p.shape, vec![1, 2, 2, 1]);
        assert_eq!(idx.len(), 4);
        let (p2, idx2) = maxpool2(&x, false, &mut sc).unwrap();
        assert_eq!(p2.data, p.data, "route recording must not perturb values");
        assert!(idx2.is_empty());
    }

    #[test]
    fn rmsnorm_inplace_matches_out_of_place() {
        let mut rng = Rng::new(7);
        let x = rand_tensor(&[2, 3, 3, 4], &mut rng);
        let mut sc = Scratch::default();
        let (y, rs, d) = rmsnorm(&x, 4.0, &mut sc);
        let mut x2 = x.clone();
        rmsnorm_inplace(&mut x2, 4.0);
        assert_eq!(y.data, x2.data, "in-place and out-of-place rmsnorm must agree bitwise");
        assert_eq!(rs.len(), 2);
        assert_eq!(d, 36.0);
    }
}
