//! Manifest topology for the reference backend: the DAG of conv/dense
//! layers and join nodes, topologically ordered and validated at load
//! time.
//!
//! The manifest declares edges two ways:
//!
//! * `LayerDesc::input` names the producer node a body layer consumes
//!   (`"@input"` = the raw graph input, legal only in seg1; `""` = the
//!   previous body layer in declaration order — the legacy feed-forward
//!   chain, kept bit-identical for pre-DAG manifests).
//! * `ArchManifest::joins` declares parameter-free join nodes:
//!   `b: Some` is the residual add `relu(a + b)` -> act_quant -> mask
//!   (`archs.py::finish_block`), `b: None` the unary linear-bottleneck
//!   terminal (act_quant -> mask, no relu).
//!
//! [`Dag::build`] resolves every edge, Kahn-sorts the nodes with a
//! deterministic (segment, declaration-index) priority — so there is
//! exactly **one** canonical execution order per manifest — and
//! validates:
//!
//! * acyclicity (a cycle names a concrete unsatisfiable edge),
//! * channel agreement along every edge and across join operands,
//! * spatial agreement across join operands,
//! * mask-slot width at every masked join,
//! * segment structure: edges never point backward, each non-empty
//!   segment has exactly one terminal node, and only that terminal may
//!   feed a later segment (it becomes the h1/h2 stage cut — references
//!   to it from later segments are rewritten to [`NodeRef::Input`] so
//!   each segment executes self-contained against its stage input),
//! * the body holds exactly one dense classifier and it is the seg3
//!   terminal.
//!
//! Execution (forward in `order`, backward in exact reverse, gradient
//! fan-in accumulated in reverse-topological consumer order) lives in
//! the parent module; this file is pure topology.

use anyhow::{anyhow, bail, ensure, Result};

use crate::models::{ArchManifest, LayerKind};

/// Reference to a node's producer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeRef {
    /// The executed segment's external input: the raw image for seg1,
    /// the previous stage's carried feature map (h1/h2) for seg2/seg3.
    Input,
    /// Another node, by id (index into [`Dag::nodes`]).
    Node(usize),
}

/// What a node computes; geometry lives on the [`Node`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeOp {
    /// The conv/dwconv pipeline of `arch.layers[li]`.
    Conv { li: usize },
    /// The dense classifier pipeline of `arch.layers[li]`.
    Dense { li: usize },
    /// `relu(a + b)` -> act_quant -> mask (residual join).
    Join { out_mask: i64 },
    /// act_quant -> mask (unary terminal, no relu — linear bottleneck).
    Output { out_mask: i64 },
}

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub op: NodeOp,
    /// Producer refs: one for conv/dense/output, two for a binary join.
    pub inputs: Vec<NodeRef>,
    /// Segment rank 1..=3.
    pub seg: u8,
    /// Output channels (for joins: the agreed operand width).
    pub cout: usize,
    pub hout: usize,
    pub wout: usize,
}

/// The validated topology (see module docs for the invariants).
pub struct Dag {
    pub nodes: Vec<Node>,
    /// The canonical topological order over all nodes — segment-
    /// contiguous (all seg1 nodes, then seg2, then seg3), declaration
    /// index breaking ties.
    pub order: Vec<usize>,
    /// `order[..seg_end[0]]` is seg1, `order[seg_end[0]..seg_end[1]]`
    /// seg2, the rest seg3.
    pub seg_end: [usize; 3],
    /// Node id of each non-empty segment's terminal (the h1 / h2 /
    /// logits producer); `None` for an empty segment.
    pub terminal: [Option<usize>; 3],
    /// Same-segment consumers of each node, in topological order — the
    /// forward refcount source, and (reversed) the canonical gradient
    /// fan-in accumulation order.
    pub consumers: Vec<Vec<usize>>,
}

fn seg_rank(s: &str) -> Option<u8> {
    match s {
        "seg1" => Some(1),
        "seg2" => Some(2),
        "seg3" => Some(3),
        _ => None,
    }
}

impl Dag {
    /// Build and validate the topology for `arch`'s body layers
    /// (`body` holds layer indices in declaration order, exit heads
    /// excluded — those hang off the stage cuts, not the DAG).
    pub fn build(arch: &ArchManifest, body: &[usize]) -> Result<Dag> {
        ensure!(!body.is_empty(), "arch `{}` has no body layers", arch.name);
        let nb = body.len();
        let n = nb + arch.joins.len();

        // ---- nodes (body layers first, joins after, declaration order) ----
        let mut nodes: Vec<Node> = Vec::with_capacity(n);
        let mut by_name = std::collections::BTreeMap::<&str, usize>::new();
        for (i, &li) in body.iter().enumerate() {
            let l = &arch.layers[li];
            ensure!(l.name != "@input", "layer name `@input` is reserved");
            ensure!(
                by_name.insert(l.name.as_str(), i).is_none(),
                "duplicate node name `{}`",
                l.name
            );
            let op = match l.kind {
                LayerKind::Dense => NodeOp::Dense { li },
                _ => NodeOp::Conv { li },
            };
            nodes.push(Node {
                name: l.name.clone(),
                op,
                inputs: Vec::new(),
                seg: seg_rank(&l.segment)
                    .ok_or_else(|| anyhow!("layer `{}`: unknown segment `{}`", l.name, l.segment))?,
                cout: l.cout,
                hout: l.hout,
                wout: l.wout,
            });
        }
        for (ji, j) in arch.joins.iter().enumerate() {
            ensure!(j.name != "@input", "join name `@input` is reserved");
            ensure!(
                by_name.insert(j.name.as_str(), nb + ji).is_none(),
                "duplicate node name `{}`",
                j.name
            );
            let op = match j.b {
                Some(_) => NodeOp::Join { out_mask: j.out_mask },
                None => NodeOp::Output { out_mask: j.out_mask },
            };
            nodes.push(Node {
                name: j.name.clone(),
                op,
                inputs: Vec::new(),
                seg: seg_rank(&j.segment)
                    .ok_or_else(|| anyhow!("join `{}`: unknown segment `{}`", j.name, j.segment))?,
                // Filled from the operands once the order is known.
                cout: 0,
                hout: 0,
                wout: 0,
            });
        }

        // ---- edge resolution ----
        // Legacy chain mode (pre-DAG manifests): no joins, no explicit
        // inputs — compile the declaration-order chain, bit-identical to
        // the former feed-forward walker.
        let legacy =
            arch.joins.is_empty() && body.iter().all(|&li| arch.layers[li].input.is_empty());
        if legacy {
            for (i, node) in nodes.iter_mut().enumerate() {
                node.inputs = if i == 0 { vec![NodeRef::Input] } else { vec![NodeRef::Node(i - 1)] };
            }
        } else {
            for (i, &li) in body.iter().enumerate() {
                let l = &arch.layers[li];
                ensure!(
                    !l.input.is_empty(),
                    "layer `{}`: missing `input` edge (a manifest with joins or explicit \
                     edges must declare every producer)",
                    l.name
                );
                let r = if l.input == "@input" {
                    ensure!(
                        nodes[i].seg == 1,
                        "layer `{}` (seg{}) cannot consume `@input` (only seg1 reads the raw \
                         input)",
                        l.name,
                        nodes[i].seg
                    );
                    NodeRef::Input
                } else {
                    match by_name.get(l.input.as_str()) {
                        Some(&p) => NodeRef::Node(p),
                        None => bail!("layer `{}`: unknown input node `{}`", l.name, l.input),
                    }
                };
                nodes[i].inputs = vec![r];
            }
            for (ji, j) in arch.joins.iter().enumerate() {
                let mut ins = Vec::new();
                for opn in std::iter::once(&j.a).chain(j.b.as_ref()) {
                    ensure!(
                        opn != "@input",
                        "join `{}`: operand `@input` is not a node (join operands must be \
                         declared layers or joins)",
                        j.name
                    );
                    match by_name.get(opn.as_str()) {
                        Some(&p) => ins.push(NodeRef::Node(p)),
                        None => bail!("join `{}`: unknown operand node `{}`", j.name, opn),
                    }
                }
                nodes[nb + ji].inputs = ins;
            }
        }

        // ---- edges never point backward across segments ----
        for c in 0..n {
            for ii in 0..nodes[c].inputs.len() {
                if let NodeRef::Node(p) = nodes[c].inputs[ii] {
                    ensure!(
                        nodes[p].seg <= nodes[c].seg,
                        "edge `{} -> {}`: producer in seg{} follows consumer in seg{}",
                        nodes[p].name,
                        nodes[c].name,
                        nodes[p].seg,
                        nodes[c].seg
                    );
                }
            }
        }

        // ---- Kahn topological sort, (segment, declaration-index) priority ----
        let mut indeg = vec![0usize; n];
        for (c, node) in nodes.iter().enumerate() {
            indeg[c] = node.inputs.iter().filter(|r| matches!(r, NodeRef::Node(_))).count();
        }
        let mut emitted = vec![false; n];
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            let mut pick: Option<usize> = None;
            for i in 0..n {
                if !emitted[i] && indeg[i] == 0 {
                    let better = match pick {
                        None => true,
                        Some(b) => (nodes[i].seg, i) < (nodes[b].seg, b),
                    };
                    if better {
                        pick = Some(i);
                    }
                }
            }
            let Some(i) = pick else { break };
            emitted[i] = true;
            order.push(i);
            for c in 0..n {
                if !emitted[c] {
                    let hits = nodes[c]
                        .inputs
                        .iter()
                        .filter(|r| matches!(r, NodeRef::Node(p) if *p == i))
                        .count();
                    indeg[c] -= hits;
                }
            }
        }
        if order.len() < n {
            // Deterministic diagnostic: the first stuck node (declaration
            // order) and its first unsatisfied producer name the cycle.
            let c = (0..n).find(|&i| !emitted[i]).unwrap();
            let p = nodes[c]
                .inputs
                .iter()
                .find_map(|r| match r {
                    NodeRef::Node(p) if !emitted[*p] => Some(*p),
                    _ => None,
                })
                .unwrap_or(c);
            bail!(
                "arch `{}`: dependency cycle: edge `{} -> {}` can never be satisfied",
                arch.name,
                nodes[p].name,
                nodes[c].name
            );
        }

        // ---- per-edge shape validation; join geometry from operands ----
        for &i in &order {
            match nodes[i].op {
                NodeOp::Conv { li } | NodeOp::Dense { li } => {
                    let l = &arch.layers[li];
                    if let NodeRef::Node(p) = nodes[i].inputs[0] {
                        ensure!(
                            nodes[p].cout == l.cin,
                            "edge `{} -> {}`: `{}` expects cin {}, `{}` produces cout {}",
                            nodes[p].name,
                            nodes[i].name,
                            nodes[i].name,
                            l.cin,
                            nodes[p].name,
                            nodes[p].cout
                        );
                    }
                }
                NodeOp::Join { out_mask } | NodeOp::Output { out_mask } => {
                    let a = match nodes[i].inputs[0] {
                        NodeRef::Node(p) => p,
                        NodeRef::Input => unreachable!("join operands resolve to nodes"),
                    };
                    let (cout, hout, wout) = (nodes[a].cout, nodes[a].hout, nodes[a].wout);
                    if let Some(NodeRef::Node(b)) = nodes[i].inputs.get(1).copied() {
                        ensure!(
                            nodes[b].cout == cout,
                            "join `{}`: operands `{}` (cout {}) and `{}` (cout {}) disagree",
                            nodes[i].name,
                            nodes[a].name,
                            cout,
                            nodes[b].name,
                            nodes[b].cout
                        );
                        ensure!(
                            nodes[b].hout == hout && nodes[b].wout == wout,
                            "join `{}`: operands `{}` ({}x{}) and `{}` ({}x{}) differ spatially",
                            nodes[i].name,
                            nodes[a].name,
                            hout,
                            wout,
                            nodes[b].name,
                            nodes[b].hout,
                            nodes[b].wout
                        );
                    }
                    if out_mask >= 0 {
                        let slot = arch.mask_slots.get(out_mask as usize).ok_or_else(|| {
                            anyhow!("join `{}`: mask slot {} undeclared", nodes[i].name, out_mask)
                        })?;
                        ensure!(
                            slot.channels == cout,
                            "join `{}`: mask slot {} covers {} channels, join has {}",
                            nodes[i].name,
                            out_mask,
                            slot.channels,
                            cout
                        );
                    }
                    nodes[i].cout = cout;
                    nodes[i].hout = hout;
                    nodes[i].wout = wout;
                }
            }
        }

        // ---- segment structure: consumers, terminals, stage cuts ----
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut cross: Vec<(usize, usize)> = Vec::new();
        for &c in &order {
            for r in &nodes[c].inputs {
                if let NodeRef::Node(p) = *r {
                    if nodes[p].seg == nodes[c].seg {
                        consumers[p].push(c);
                    } else {
                        cross.push((p, c));
                    }
                }
            }
        }
        let mut seg_n = [0usize; 3];
        for node in &nodes {
            seg_n[(node.seg - 1) as usize] += 1;
        }
        let mut terminal: [Option<usize>; 3] = [None; 3];
        for &i in &order {
            if consumers[i].is_empty() {
                let s = (nodes[i].seg - 1) as usize;
                if let Some(t) = terminal[s] {
                    bail!(
                        "seg{}: multiple terminal nodes (`{}`, `{}`): exactly one node may \
                         produce the stage output",
                        nodes[i].seg,
                        nodes[t].name,
                        nodes[i].name
                    );
                }
                terminal[s] = Some(i);
            }
        }
        for &(p, c) in &cross {
            let ps = (nodes[p].seg - 1) as usize;
            ensure!(
                terminal[ps] == Some(p),
                "edge `{} -> {}`: only the seg{} terminal may feed a later segment",
                nodes[p].name,
                nodes[c].name,
                nodes[p].seg
            );
            for s in ps + 1..(nodes[c].seg - 1) as usize {
                ensure!(
                    seg_n[s] == 0,
                    "edge `{} -> {}` skips non-empty seg{}",
                    nodes[p].name,
                    nodes[c].name,
                    s + 1
                );
            }
        }
        // The classifier: exactly one dense node, and it is the seg3
        // terminal (so `stage3` always produces logits).
        let dense: Vec<usize> = (0..n)
            .filter(|&i| matches!(nodes[i].op, NodeOp::Dense { .. }))
            .collect();
        ensure!(
            dense.len() == 1,
            "arch `{}`: the body must contain exactly one dense classifier (found {})",
            arch.name,
            dense.len()
        );
        ensure!(
            terminal[2] == Some(dense[0]),
            "arch `{}`: the dense classifier `{}` must be the seg3 terminal",
            arch.name,
            nodes[dense[0]].name
        );

        // ---- rewrite cross-segment refs to the stage input ----
        // Each segment now executes self-contained: the previous stage's
        // carried feature map arrives as `NodeRef::Input`.
        for c in 0..n {
            let cs = nodes[c].seg;
            let mut new_inputs = std::mem::take(&mut nodes[c].inputs);
            for r in &mut new_inputs {
                if let NodeRef::Node(p) = *r {
                    if nodes[p].seg < cs {
                        *r = NodeRef::Input;
                    }
                }
            }
            nodes[c].inputs = new_inputs;
        }

        let seg_end = [
            order.iter().take_while(|&&i| nodes[i].seg == 1).count(),
            order.iter().take_while(|&&i| nodes[i].seg <= 2).count(),
            n,
        ];
        Ok(Dag { nodes, order, seg_end, terminal, consumers })
    }

    /// Topologically ordered node ids of one segment (0-based: 0 = seg1).
    pub fn seg_range(&self, seg: usize) -> &[usize] {
        let start = if seg == 0 { 0 } else { self.seg_end[seg - 1] };
        &self.order[start..self.seg_end[seg]]
    }

    /// Terminal of `seg` or, when that segment is empty, of the nearest
    /// earlier non-empty segment (the value a stage cut carries forward).
    pub fn effective_terminal(&self, seg: usize) -> Option<usize> {
        (0..=seg).rev().find_map(|s| self.terminal[s])
    }

    /// Layer indices of nodes reading the *raw* graph input (seg1
    /// `@input` consumers — the stem).  Rewritten stage inputs in later
    /// segments do not count: those carry quantized activations, while
    /// the raw image is never quantized (int8 packing exclusion).
    pub fn input_layers(&self) -> Vec<usize> {
        self.nodes
            .iter()
            .filter(|nd| nd.seg == 1 && nd.inputs.contains(&NodeRef::Input))
            .filter_map(|nd| match nd.op {
                NodeOp::Conv { li } | NodeOp::Dense { li } => Some(li),
                _ => None,
            })
            .collect()
    }
}
