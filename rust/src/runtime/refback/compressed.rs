//! Compressed execution for the reference backend: inference kernels
//! over a `models::compressed::CompressedModel` — channel-compacted
//! feature maps, blocked-CSR sparse conv/matmul, and integer int8
//! paths — wired into the same scratch arena, batch pool and graph
//! contract as the dense interpreter.
//!
//! # Parity contract
//!
//! The pruned-fp32 pipeline is **bit-identical** to the dense
//! interpreter's eval/stage logits: compaction only removes channels
//! whose dense activations are `±0.0`, stored blocks are walked in the
//! dense path's canonical ascending reduction order, and skipping a
//! `±0.0` product never changes an f32 accumulator that starts at
//! `+0.0` (see the `models::compressed` module docs).  The RMS-norm
//! statistic assigns lanes by *original* channel index
//! ([`rmsnorm_live_inplace`]), so compaction cannot re-associate the
//! `Σx²` chain.
//!
//! The int8 path is tolerance-level against dense fake-quant (integer
//! codes are exact; one f32 rescale per output element replaces the
//! f32 product chain) but exactly deterministic at every thread count:
//! i32 accumulation is associative, so there is nothing threading can
//! re-order.
//!
//! Block walks dispatch through the shared [`super::simd`] lane ops:
//! the f32 paths keep one accumulation chain per output row in
//! ascending stored-column order on every ISA (bit-identical to the
//! scalar walk), and the int8 path uses widening vector sums freely
//! because i32 math is order-free.
//!
//! Activation codes are *recovered*, not re-derived: lowering admits a
//! layer to int8 only when its runtime input is an exact `act_quant`
//! image — post-relu, so the quant scale equals the tensor max and
//! survives max-pooling — which makes `code = round(v / s_a · na)`
//! exact ([`act_codes`]).
//!
//! Compressed graphs are inference-only (`eval` / `stageN`): training
//! updates raw weights that lowering has already folded away.

use std::borrow::Cow;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::models::compressed::{Bcsr, CompressedModel, PackedForm, BLOCK_C, BLOCK_LEN, BLOCK_R};
use crate::models::LayerKind;
use crate::tensor::Tensor;

use super::kernels::{self, ConvGeom};
use super::pool;
use super::scratch::Scratch;
use super::simd;
use super::{dag, peek_value, recycle_cow, release_value, take_value, GraphKind, RefNet};
use crate::runtime::{DeviceBuffer, GraphExec, ResidencyUnsupported, StatsCell};

/// Load one compressed graph (`eval` or `stageN[_bB]`), mirroring
/// `RefBackend::load_graph` validation.
pub(super) fn load(
    cm: &Arc<CompressedModel>,
    tag: &str,
    stats: Arc<StatsCell>,
    threads: usize,
) -> Result<Box<dyn GraphExec>> {
    let kind = GraphKind::parse(tag)
        .ok_or_else(|| anyhow!("unknown graph tag `{tag}` (init|train|eval|stageN[_bB])"))?;
    ensure!(
        matches!(kind, GraphKind::Eval | GraphKind::Stage { .. }),
        "compressed execution is inference-only; graph `{tag}` needs the dense path"
    );
    ensure!(
        cm.arch.graphs.contains_key(tag),
        "arch `{}` does not declare graph `{tag}`",
        cm.arch.name
    );
    let net = CompressedNet::compile(cm.clone(), threads)?;
    Ok(Box::new(CompressedGraph {
        net,
        kind,
        name: format!("ref+cmp://{}/{tag}", cm.arch.name),
        stats,
        scratch: Mutex::new(Scratch::default()),
    }))
}

struct CompressedGraph {
    net: CompressedNet,
    kind: GraphKind,
    name: String,
    stats: Arc<StatsCell>,
    scratch: Mutex<Scratch>,
}

impl GraphExec for CompressedGraph {
    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let _s = crate::obs::trace::span("refback.compressed.run");
        let t0 = Instant::now();
        let out = self
            .dispatch(inputs)
            .with_context(|| format!("executing `{}`", self.name))?;
        self.stats.executions.incr();
        self.stats.execute_ns.add(t0.elapsed().as_nanos() as u64);
        Ok(out)
    }

    fn run_buffers(&self, _inputs: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>> {
        Err(ResidencyUnsupported("ref backend has no device buffers".into()).into())
    }
}

impl CompressedGraph {
    /// Compressed graphs take **one** operand — the batch input —
    /// because params, masks and qbits are all baked at lowering.
    fn dispatch(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let scratch = &mut *self.scratch.lock().unwrap();
        ensure!(inputs.len() == 1, "compressed graphs take 1 operand, got {}", inputs.len());
        let x = inputs[0];
        let net = &self.net;
        match self.kind {
            GraphKind::Eval => {
                ensure!(
                    x.shape.first() == Some(&net.cm.arch.eval_batch),
                    "eval graph lowered at batch {}, got input batch {:?}",
                    net.cm.arch.eval_batch,
                    x.shape.first()
                );
                let (h1, e1) = net.stage1(x, scratch)?;
                let (h2, e2) = net.stage2(&h1, scratch)?;
                scratch.recycle_tensor(h1);
                let logits = net.stage3(&h2, scratch)?;
                scratch.recycle_tensor(h2);
                Ok(vec![logits, e1, e2])
            }
            GraphKind::Stage { stage, batch } => {
                ensure!(
                    x.shape.first() == Some(&batch),
                    "stage{stage} graph lowered at batch {batch}, got input batch {:?}",
                    x.shape.first()
                );
                match stage {
                    1 => {
                        let (h1, e1) = net.stage1(x, scratch)?;
                        Ok(vec![e1, h1])
                    }
                    2 => {
                        let (h2, e2) = net.stage2(x, scratch)?;
                        Ok(vec![e2, h2])
                    }
                    _ => Ok(vec![net.stage3(x, scratch)?]),
                }
            }
            GraphKind::Init | GraphKind::Train => {
                bail!("compressed graphs are inference-only")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The compressed network
// ---------------------------------------------------------------------------

/// The dense interpreter's validated topology (`RefNet`) plus the packed
/// layers; stage composition and segment bookkeeping are shared so the
/// two paths cannot drift.
struct CompressedNet {
    cm: Arc<CompressedModel>,
    base: RefNet,
}

impl CompressedNet {
    fn compile(cm: Arc<CompressedModel>, threads: usize) -> Result<CompressedNet> {
        let base = RefNet::compile(cm.arch.clone(), threads)?;
        let arch = &cm.arch;
        ensure!(
            cm.layers.len() == arch.layers.len(),
            "compressed model has {} layers, arch `{}` declares {}",
            cm.layers.len(),
            arch.name,
            arch.layers.len()
        );
        for (l, pl) in arch.layers.iter().zip(&cm.layers) {
            ensure!(
                pl.bias.len() == pl.out_live.len(),
                "layer `{}`: bias covers {} channels, {} live",
                l.name,
                pl.bias.len(),
                pl.out_live.len()
            );
            let kdim = match l.kind {
                LayerKind::Dense => pl.in_live.len(),
                _ => l.k * l.k * pl.in_live.len(),
            };
            let ok = match &pl.form {
                PackedForm::Dense { w } => {
                    let full = match l.kind {
                        LayerKind::Dense => vec![l.cin, l.cout],
                        LayerKind::DwConv => vec![l.k, l.k, 1, l.cout],
                        LayerKind::Conv => vec![l.k, l.k, l.cin, l.cout],
                    };
                    pl.in_live.len() == l.cin && pl.out_live.len() == l.cout && w.shape == full
                }
                PackedForm::DwMapped { w, in_pos } => {
                    l.kind == LayerKind::DwConv
                        && in_pos.len() == pl.out_live.len()
                        && w.shape == vec![l.k, l.k, 1, pl.out_live.len()]
                }
                PackedForm::SparseF32 { csr, values } => {
                    csr.rows == pl.out_live.len()
                        && csr.cols == kdim
                        && values.len() == csr.nblocks() * BLOCK_LEN
                }
                PackedForm::Int8 { csr, codes, .. } => {
                    l.kind != LayerKind::DwConv
                        && csr.rows == pl.out_live.len()
                        && csr.cols == kdim
                        && codes.len() == csr.nblocks() * BLOCK_LEN
                }
            };
            ensure!(ok, "layer `{}`: inconsistent packed form `{}`", l.name, pl.form.tag());
        }
        // Compaction must agree along every declared edge: a consumer's
        // live input set is its producer's live output set, and both
        // operands of a join carry the same live set (the dense path's
        // mask-slot agreement, restated structurally).  `node_src[ni]`
        // names the layer whose `out_live` defines node `ni`'s channels
        // (joins propagate their operand's source).
        let d = &base.dag;
        let mut node_src: Vec<Option<usize>> = vec![None; d.nodes.len()];
        for seg in 0..3 {
            // Live set flowing in with the stage input: the previous
            // effective terminal's (None for the raw seg1 image).
            let seg_src: Option<usize> = if seg == 0 {
                None
            } else {
                d.effective_terminal(seg - 1).and_then(|t| node_src[t])
            };
            let src_of = |r: dag::NodeRef, node_src: &[Option<usize>]| match r {
                dag::NodeRef::Input => seg_src,
                dag::NodeRef::Node(p) => node_src[p],
            };
            for &ni in d.seg_range(seg) {
                let node = &d.nodes[ni];
                match node.op {
                    dag::NodeOp::Conv { li } | dag::NodeOp::Dense { li } => {
                        if let Some(p) = src_of(node.inputs[0], &node_src) {
                            ensure!(
                                cm.layers[li].in_live == cm.layers[p].out_live,
                                "layer `{}` live inputs disagree with `{}` live outputs",
                                arch.layers[li].name,
                                arch.layers[p].name
                            );
                        }
                        node_src[ni] = Some(li);
                    }
                    dag::NodeOp::Join { .. } => {
                        let a = src_of(node.inputs[0], &node_src);
                        let b = src_of(node.inputs[1], &node_src);
                        if let (Some(pa), Some(pb)) = (a, b) {
                            ensure!(
                                cm.layers[pa].out_live == cm.layers[pb].out_live,
                                "join `{}`: operands `{}` and `{}` disagree on live channels",
                                node.name,
                                arch.layers[pa].name,
                                arch.layers[pb].name
                            );
                        }
                        node_src[ni] = a.or(b);
                    }
                    dag::NodeOp::Output { .. } => {
                        node_src[ni] = src_of(node.inputs[0], &node_src);
                    }
                }
            }
        }
        for (head, seg) in [(base.exit1, 0usize), (base.exit2, 1)] {
            if let Some(li) = head {
                let cut = d.effective_terminal(seg).and_then(|t| node_src[t]).ok_or_else(|| {
                    anyhow!(
                        "exit head `{}` cuts a segment with no live-set producer",
                        arch.layers[li].name
                    )
                })?;
                ensure!(
                    cm.layers[li].in_live == cm.layers[cut].out_live,
                    "exit head `{}` live inputs disagree with cut layer `{}`",
                    arch.layers[li].name,
                    arch.layers[cut].name
                );
            }
        }
        Ok(CompressedNet { cm, base })
    }

    // ----- forward ----------------------------------------------------------

    /// Pools (lazy, geometry-driven) + packed conv -> bias -> live-RMS
    /// norm -> relu -> act_quant.  Same op order as the dense
    /// `conv_forward` minus weight quant (baked at lowering) and the mask
    /// multiply (structural: dead channels no longer exist).
    fn conv_forward(&self, li: usize, mut xin: Cow<'_, Tensor>, scratch: &mut Scratch) -> Result<Tensor> {
        let l = &self.cm.arch.layers[li];
        let pl = &self.cm.layers[li];
        let threads = self.base.threads;
        let s = l.stride.max(1);
        loop {
            let (_, h, w, _) = kernels::dims4(&xin)?;
            if h.div_ceil(s) <= l.hout && w.div_ceil(s) <= l.wout {
                break;
            }
            let (pooled, _) = kernels::maxpool2(&xin, false, scratch)?;
            recycle_cow(xin, scratch);
            xin = Cow::Owned(pooled);
        }
        let (_, h, w, c) = kernels::dims4(&xin)?;
        ensure!(
            h.div_ceil(s) == l.hout && w.div_ceil(s) == l.wout,
            "layer `{}`: no pooling schedule maps {h}x{w} input to declared {}x{} output at \
             stride {s}",
            l.name,
            l.hout,
            l.wout
        );
        ensure!(
            c == pl.in_live.len(),
            "layer `{}`: input has {c} channels, packed form expects {} live",
            l.name,
            pl.in_live.len()
        );
        let mut y = match &pl.form {
            PackedForm::Dense { w } => match l.kind {
                LayerKind::DwConv => kernels::dwconv2d(&xin, w, s, threads, scratch)?,
                _ => kernels::conv2d(&xin, w, s, threads, scratch)?,
            },
            PackedForm::DwMapped { w, in_pos } => {
                dwconv_mapped(&xin, w, in_pos, s, threads, scratch)?
            }
            PackedForm::SparseF32 { csr, values } => {
                sparse_conv2d(&xin, csr, values, l.k, s, threads, scratch)?
            }
            PackedForm::Int8 { csr, codes, scale_w } => {
                qconv2d(&xin, csr, codes, *scale_w, l.k, s, self.cm.qbits.act, threads, scratch)?
            }
        };
        recycle_cow(xin, scratch);
        kernels::add_channel_bias(&mut y, &pl.bias);
        if pl.out_live.len() == l.cout {
            // Uncompacted: flat lanes already equal original-index lanes.
            kernels::rmsnorm_inplace(&mut y, pl.live_divisor);
        } else {
            rmsnorm_live_inplace(&mut y, &pl.out_live, l.cout, pl.live_divisor);
        }
        if l.act {
            // `act: false` layers (pre-join convs / projections) stop at
            // the norm; their join applies relu + act_quant.
            kernels::relu_inplace(&mut y);
            kernels::act_quant_inplace(&mut y, self.cm.qbits.act);
        }
        Ok(y)
    }

    /// GAP -> act_quant -> packed matmul -> bias, mirroring the dense
    /// `dense_forward`.
    fn dense_forward(&self, li: usize, feat: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let l = &self.cm.arch.layers[li];
        let pl = &self.cm.layers[li];
        let (_, _, _, c) = kernels::dims4(feat)?;
        ensure!(
            c == pl.in_live.len(),
            "dense `{}`: fan-in {} live != feature channels {c}",
            l.name,
            pl.in_live.len()
        );
        let mut aq = kernels::gap(feat, scratch)?;
        kernels::act_quant_inplace(&mut aq, self.cm.qbits.act);
        let mut out = match &pl.form {
            PackedForm::Dense { w } => kernels::matmul(&aq, w, scratch),
            PackedForm::SparseF32 { csr, values } => sparse_matmul(&aq, csr, values, scratch),
            PackedForm::Int8 { csr, codes, scale_w } => {
                qmatmul(&aq, csr, codes, *scale_w, self.cm.qbits.act, scratch)
            }
            PackedForm::DwMapped { .. } => {
                bail!("dense `{}` cannot execute a depthwise packed form", l.name)
            }
        };
        kernels::add_row_bias(&mut out, &pl.bias);
        scratch.recycle_tensor(aq);
        Ok(out)
    }

    fn exit_forward(
        &self,
        head: Option<usize>,
        feat: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        match head {
            Some(li) => self.dense_forward(li, feat, scratch),
            None => {
                let b = *feat.shape.first().unwrap_or(&0);
                let nc = self.cm.arch.num_classes;
                Ok(Tensor::new(vec![b, nc], scratch.take(b * nc)))
            }
        }
    }

    /// Own a (possibly borrowed) operand value so a join can accumulate
    /// into it in place.
    fn own(a: Cow<'_, Tensor>, scratch: &mut Scratch) -> Tensor {
        match a {
            Cow::Owned(t) => t,
            Cow::Borrowed(t) => {
                let mut buf = Tensor::new(t.shape.clone(), scratch.take_full(t.len()));
                buf.data.copy_from_slice(&t.data);
                buf
            }
        }
    }

    /// Execute one segment of the DAG over compacted feature maps: the
    /// dense `forward_segment` minus traces (inference-only) and mask
    /// multiplies (structural — dead channels no longer exist).  Same
    /// canonical node order, same refcounted buffer hand-off.
    fn forward_segment(&self, seg: usize, input: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        let d = &self.base.dag;
        let range = d.seg_range(seg);
        if range.is_empty() {
            // Empty segment: the stage carries its input through unchanged.
            return Ok(input.clone());
        }
        let term = d.terminal[seg].expect("non-empty segment has a terminal");
        let n = d.nodes.len();
        let mut values: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        let mut refs: Vec<usize> = vec![0; n];
        for &ni in range {
            refs[ni] = d.consumers[ni].len();
        }
        refs[term] += 1;
        for &ni in range {
            let node = &d.nodes[ni];
            let out = match node.op {
                dag::NodeOp::Conv { li } => {
                    let xin = take_value(&mut values, &mut refs, node.inputs[0], input);
                    self.conv_forward(li, xin, scratch)?
                }
                dag::NodeOp::Dense { li } => {
                    let out = {
                        let feat = peek_value(&values, node.inputs[0], input);
                        self.dense_forward(li, feat, scratch)?
                    };
                    release_value(&mut values, &mut refs, node.inputs[0], scratch);
                    out
                }
                dag::NodeOp::Join { .. } => {
                    let a = take_value(&mut values, &mut refs, node.inputs[0], input);
                    let mut z = Self::own(a, scratch);
                    {
                        let bt = peek_value(&values, node.inputs[1], input);
                        ensure!(
                            z.len() == bt.len(),
                            "join `{}`: operand sizes {} vs {} (batch mismatch)",
                            node.name,
                            z.len(),
                            bt.len()
                        );
                        kernels::add_assign(&mut z, bt);
                    }
                    release_value(&mut values, &mut refs, node.inputs[1], scratch);
                    kernels::relu_inplace(&mut z);
                    kernels::act_quant_inplace(&mut z, self.cm.qbits.act);
                    z
                }
                dag::NodeOp::Output { .. } => {
                    let a = take_value(&mut values, &mut refs, node.inputs[0], input);
                    let mut z = Self::own(a, scratch);
                    kernels::act_quant_inplace(&mut z, self.cm.qbits.act);
                    z
                }
            };
            values[ni] = Some(out);
        }
        let out = values[term].take().expect("terminal value computed");
        for v in values.iter_mut() {
            if let Some(t) = v.take() {
                scratch.recycle_tensor(t);
            }
        }
        Ok(out)
    }

    fn stage1(&self, x: &Tensor, scratch: &mut Scratch) -> Result<(Tensor, Tensor)> {
        let h1 = self.forward_segment(0, x, scratch)?;
        let e1 = self.exit_forward(self.base.exit1, &h1, scratch)?;
        Ok((h1, e1))
    }

    fn stage2(&self, h1: &Tensor, scratch: &mut Scratch) -> Result<(Tensor, Tensor)> {
        let h2 = self.forward_segment(1, h1, scratch)?;
        let e2 = self.exit_forward(self.base.exit2, &h2, scratch)?;
        Ok((h2, e2))
    }

    fn stage3(&self, h2: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        self.forward_segment(2, h2, scratch)
    }
}

// ---------------------------------------------------------------------------
// Kernels
// ---------------------------------------------------------------------------

/// Per-sample RMS normalization over a channel-compacted map, assigning
/// the `Σx²` statistic lanes by **original** flat index — `(p · cout_full
/// + out_live[cl]) % 8`, the lane `kernels::lane_dot` gives that element
/// in the dense path — so the surviving squares land in the same lanes,
/// in the same ascending order, as before compaction.  Dropped channels
/// contributed exactly `(±0.0)² = +0.0` to a lane chain that can never
/// go negative, so omitting them is bit-exact.
fn rmsnorm_live_inplace(t: &mut Tensor, out_live: &[u32], cout_full: usize, live: f32) {
    let (b, h, w, c) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
    debug_assert_eq!(c, out_live.len());
    let spl = h * w * c;
    let d = (h * w) as f32 * live;
    for bi in 0..b {
        let row = &mut t.data[bi * spl..(bi + 1) * spl];
        let mut l = [0.0f32; 8];
        for (p, px) in row.chunks_exact(c).enumerate() {
            let base = p * cout_full;
            for (&v, &oc) in px.iter().zip(out_live) {
                l[(base + oc as usize) % 8] += v * v;
            }
        }
        let ms = ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]));
        let r = 1.0 / (ms / d + 1e-6).sqrt();
        for v in row.iter_mut() {
            *v *= r;
        }
    }
}

/// Reduction-index decode table for the blocked-CSR conv kernels:
/// `rtab[3r..3r+3] = (ky, kx, live input channel)` for matrix column
/// `r`, hoisting the div/mod chain out of the pixel loop.
fn conv_rtab(cols: usize, k: usize, cin: usize, scratch: &mut Scratch) -> Vec<u32> {
    let mut rtab = scratch.take_u32(3 * cols);
    for r in 0..cols {
        let (tap, ic) = (r / cin, r % cin);
        rtab[3 * r] = (tap / k) as u32;
        rtab[3 * r + 1] = (tap % k) as u32;
        rtab[3 * r + 2] = ic as u32;
    }
    rtab
}

/// Decode matrix column `r` of a [`conv_rtab`] table back to
/// `(ky, kx, live input channel)`.
fn rtab_at(rtab: &[u32], r: usize) -> (usize, usize, usize) {
    (rtab[3 * r] as usize, rtab[3 * r + 1] as usize, rtab[3 * r + 2] as usize)
}

/// Blocked-CSR sparse conv2d over a channel-compacted NHWC input.  Each
/// live output channel's accumulator runs over the stored entries of its
/// block-row in ascending column order — the dense canonical `(ky, kx,
/// ic)` chain restricted to stored entries, which only ever drops `±0.0`
/// products — so the result is bit-identical to masked-dense execution.
fn sparse_conv2d(
    x: &Tensor,
    csr: &Bcsr,
    values: &[f32],
    k: usize,
    stride: usize,
    threads: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let _s = crate::obs::trace::span("refback.sparse_conv2d");
    let (b, h, w, cin) = kernels::dims4(x)?;
    ensure!(
        csr.cols == k * k * cin,
        "sparse conv: csr has {} columns, geometry needs {}",
        csr.cols,
        k * k * cin
    );
    let g = ConvGeom::new(b, h, w, cin, k, csr.rows, stride);
    let rtab = conv_rtab(csr.cols, k, cin, scratch);
    let mut out = scratch.take_full(g.b * g.out_len());
    let flops = g.ho * g.wo * csr.nblocks() * BLOCK_LEN;
    pool::for_each_item(threads, flops, &mut out, g.out_len(), |bi, chunk| {
        sparse_conv2d_item(&g, csr, values, &rtab, &x.data[bi * g.in_len()..][..g.in_len()], chunk);
    });
    scratch.recycle_u32(rtab);
    Ok(Tensor::new(vec![g.b, g.ho, g.wo, g.cout], out))
}

fn sparse_conv2d_item(
    g: &ConvGeom,
    csr: &Bcsr,
    values: &[f32],
    rtab: &[u32],
    x: &[f32],
    out: &mut [f32],
) {
    let (s, cin) = (g.stride, g.cin);
    for oy in 0..g.ho {
        let yin = oy >= g.oy0 && oy < g.oy1;
        for ox in 0..g.wo {
            let interior = yin && ox >= g.ox0 && ox < g.ox1;
            let off = (oy * g.wo + ox) * g.cout;
            for br in 0..csr.block_rows() {
                let mut acc = [0.0f32; BLOCK_R];
                for bi in csr.row_blocks(br) {
                    let r0 = csr.col_idx[bi] as usize * BLOCK_C;
                    let blk = &values[bi * BLOCK_LEN..][..BLOCK_LEN];
                    let ncc = BLOCK_C.min(csr.cols - r0);
                    if interior {
                        // Every tap is in bounds: gather the window
                        // values for this block's columns and run the
                        // shared 4-row lane op.  Each output row's chain
                        // is still ascending stored columns, so the bits
                        // cannot move (see simd.rs).
                        let mut xv = [0.0f32; BLOCK_C];
                        for (cc, v) in xv[..ncc].iter_mut().enumerate() {
                            let r = r0 + cc;
                            let (ky, kx, ic) = rtab_at(rtab, r);
                            *v = x[((oy * s + ky - g.ph) * g.w + (ox * s + kx - g.pw)) * cin + ic];
                        }
                        simd::sparse_block(&mut acc, blk, &xv[..ncc]);
                        continue;
                    }
                    for cc in 0..ncc {
                        let (ky, kx, ic) = rtab_at(rtab, r0 + cc);
                        let iy = (oy * s + ky) as isize - g.ph as isize;
                        let ix = (ox * s + kx) as isize - g.pw as isize;
                        if iy < 0 || iy >= g.h as isize || ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        let xv = x[((iy as usize) * g.w + ix as usize) * cin + ic];
                        for (rr, a) in acc.iter_mut().enumerate() {
                            *a += blk[rr * BLOCK_C + cc] * xv;
                        }
                    }
                }
                let oc0 = br * BLOCK_R;
                let nr = BLOCK_R.min(g.cout - oc0);
                out[off + oc0..][..nr].copy_from_slice(&acc[..nr]);
            }
        }
    }
}

/// Blocked-CSR sparse matmul (`[m, cols] @ packed -> [m, rows]`), the
/// dense head counterpart of [`sparse_conv2d`]: per output element the
/// chain is ascending stored columns, bit-identical to masked-dense.
fn sparse_matmul(a: &Tensor, csr: &Bcsr, values: &[f32], scratch: &mut Scratch) -> Tensor {
    let _s = crate::obs::trace::span("refback.sparse_matmul");
    let (m, kdim) = (a.shape[0], a.shape[1]);
    debug_assert_eq!(kdim, csr.cols);
    let n = csr.rows;
    let mut out = scratch.take_full(m * n);
    for mi in 0..m {
        let arow = &a.data[mi * kdim..][..kdim];
        let orow = &mut out[mi * n..][..n];
        for br in 0..csr.block_rows() {
            let mut acc = [0.0f32; BLOCK_R];
            for bi in csr.row_blocks(br) {
                let r0 = csr.col_idx[bi] as usize * BLOCK_C;
                let blk = &values[bi * BLOCK_LEN..][..BLOCK_LEN];
                let ncc = BLOCK_C.min(kdim - r0);
                simd::sparse_block(&mut acc, blk, &arow[r0..r0 + ncc]);
            }
            let c0 = br * BLOCK_R;
            let nr = BLOCK_R.min(n - c0);
            orow[c0..c0 + nr].copy_from_slice(&acc[..nr]);
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Recover the integer activation codes of an exact `act_quant` image:
/// the quant scale is the tensor max (the max element quantizes to
/// itself), so `round(v / s_a · na)` reproduces each element's code
/// exactly.  Returns `(codes, s_a)`; an all-zero tensor recovers scale
/// 0 and all-zero codes.
fn act_codes(x: &[f32], bits_a: f32, scratch: &mut Scratch) -> (Vec<u32>, f32) {
    let na = (bits_a.exp2() - 1.0).max(1.0);
    let mut s = 0.0f32;
    for &v in x {
        s = s.max(v.abs());
    }
    let mut codes = scratch.take_u32(x.len());
    if s > 0.0 {
        for (c, &v) in codes.iter_mut().zip(x) {
            *c = ((v / s).clamp(0.0, 1.0) * na).round() as u32;
        }
    }
    (codes, s)
}

/// int8 conv: integer weight codes x recovered activation codes, i32
/// accumulation in the same ascending stored-entry order, one f32
/// rescale (`acc · scale_w · s_a / na`) per output element.
#[allow(clippy::too_many_arguments)]
fn qconv2d(
    x: &Tensor,
    csr: &Bcsr,
    codes_w: &[i8],
    scale_w: f32,
    k: usize,
    stride: usize,
    bits_a: f32,
    threads: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let _s = crate::obs::trace::span("refback.qconv2d");
    let (b, h, w, cin) = kernels::dims4(x)?;
    ensure!(
        csr.cols == k * k * cin,
        "int8 conv: csr has {} columns, geometry needs {}",
        csr.cols,
        k * k * cin
    );
    let g = ConvGeom::new(b, h, w, cin, k, csr.rows, stride);
    let na = (bits_a.exp2() - 1.0).max(1.0);
    let (ac, s_a) = act_codes(&x.data, bits_a, scratch);
    let f = scale_w * (s_a / na);
    let rtab = conv_rtab(csr.cols, k, cin, scratch);
    let mut out = scratch.take_full(g.b * g.out_len());
    let flops = g.ho * g.wo * csr.nblocks() * BLOCK_LEN;
    pool::for_each_item(threads, flops, &mut out, g.out_len(), |bi, chunk| {
        qconv2d_item(&g, csr, codes_w, f, &rtab, &ac[bi * g.in_len()..][..g.in_len()], chunk);
    });
    scratch.recycle_u32(ac);
    scratch.recycle_u32(rtab);
    Ok(Tensor::new(vec![g.b, g.ho, g.wo, g.cout], out))
}

fn qconv2d_item(
    g: &ConvGeom,
    csr: &Bcsr,
    codes_w: &[i8],
    f: f32,
    rtab: &[u32],
    ac: &[u32],
    out: &mut [f32],
) {
    let (s, cin) = (g.stride, g.cin);
    for oy in 0..g.ho {
        let yin = oy >= g.oy0 && oy < g.oy1;
        for ox in 0..g.wo {
            let interior = yin && ox >= g.ox0 && ox < g.ox1;
            let off = (oy * g.wo + ox) * g.cout;
            for br in 0..csr.block_rows() {
                let mut acc = [0i32; BLOCK_R];
                for bi in csr.row_blocks(br) {
                    let r0 = csr.col_idx[bi] as usize * BLOCK_C;
                    let blk = &codes_w[bi * BLOCK_LEN..][..BLOCK_LEN];
                    let ncc = BLOCK_C.min(csr.cols - r0);
                    // Zero-padded code gather: out-of-bounds taps and
                    // tail lanes contribute exact 0 products, and i32
                    // accumulation is order-free, so one widening lane
                    // op covers interior, border and tail alike.
                    let mut av = [0i32; BLOCK_C];
                    for (cc, a) in av[..ncc].iter_mut().enumerate() {
                        let (ky, kx, ic) = rtab_at(rtab, r0 + cc);
                        if interior {
                            let iy = oy * s + ky - g.ph;
                            let ix = ox * s + kx - g.pw;
                            *a = ac[(iy * g.w + ix) * cin + ic] as i32;
                            continue;
                        }
                        let iy = (oy * s + ky) as isize - g.ph as isize;
                        let ix = (ox * s + kx) as isize - g.pw as isize;
                        if iy >= 0 && iy < g.h as isize && ix >= 0 && ix < g.w as isize {
                            *a = ac[((iy as usize) * g.w + ix as usize) * cin + ic] as i32;
                        }
                    }
                    simd::qblock(&mut acc, blk, &av);
                }
                let oc0 = br * BLOCK_R;
                let nr = BLOCK_R.min(g.cout - oc0);
                for (a, &v) in out[off + oc0..][..nr].iter_mut().zip(&acc[..nr]) {
                    *a = v as f32 * f;
                }
            }
        }
    }
}

/// int8 matmul for the dense heads: same code recovery and rescale as
/// [`qconv2d`], serial (head matrices are tiny).
fn qmatmul(
    a: &Tensor,
    csr: &Bcsr,
    codes_w: &[i8],
    scale_w: f32,
    bits_a: f32,
    scratch: &mut Scratch,
) -> Tensor {
    let _s = crate::obs::trace::span("refback.qmatmul");
    let (m, kdim) = (a.shape[0], a.shape[1]);
    debug_assert_eq!(kdim, csr.cols);
    let n = csr.rows;
    let na = (bits_a.exp2() - 1.0).max(1.0);
    let (ac, s_a) = act_codes(&a.data, bits_a, scratch);
    let f = scale_w * (s_a / na);
    let mut out = scratch.take_full(m * n);
    for mi in 0..m {
        let arow = &ac[mi * kdim..][..kdim];
        let orow = &mut out[mi * n..][..n];
        for br in 0..csr.block_rows() {
            let mut acc = [0i32; BLOCK_R];
            for bi in csr.row_blocks(br) {
                let r0 = csr.col_idx[bi] as usize * BLOCK_C;
                let blk = &codes_w[bi * BLOCK_LEN..][..BLOCK_LEN];
                let ncc = BLOCK_C.min(kdim - r0);
                let mut av = [0i32; BLOCK_C];
                for (a, &c) in av[..ncc].iter_mut().zip(&arow[r0..r0 + ncc]) {
                    *a = c as i32;
                }
                simd::qblock(&mut acc, blk, &av);
            }
            let c0 = br * BLOCK_R;
            let nr = BLOCK_R.min(n - c0);
            for (o, &v) in orow[c0..c0 + nr].iter_mut().zip(&acc[..nr]) {
                *o = v as f32 * f;
            }
        }
    }
    scratch.recycle_u32(ac);
    Tensor::new(vec![m, n], out)
}

/// Depthwise conv over compacted channels: each live output channel
/// reads its mapped live input position (`in_pos`, -1 = the input
/// channel is dead and the output is `+0.0` pre-bias), taps ascending —
/// the dense per-channel chain restricted to the live pair.
fn dwconv_mapped(
    x: &Tensor,
    w: &Tensor,
    in_pos: &[i32],
    stride: usize,
    threads: usize,
    scratch: &mut Scratch,
) -> Result<Tensor> {
    let _s = crate::obs::trace::span("refback.dwconv_mapped");
    let (b, h, wd, cin) = kernels::dims4(x)?;
    let (k, cout) = (w.shape[0], w.shape[3]);
    ensure!(in_pos.len() == cout, "dw map covers {} channels, weight has {cout}", in_pos.len());
    let g = ConvGeom::new(b, h, wd, cin, k, cout, stride);
    let mut out = scratch.take_full(g.b * g.out_len());
    let flops = g.ho * g.wo * cout * k * k;
    pool::for_each_item(threads, flops, &mut out, g.out_len(), |bi, chunk| {
        dwconv_mapped_item(&g, &w.data, in_pos, &x.data[bi * g.in_len()..][..g.in_len()], chunk);
    });
    Ok(Tensor::new(vec![g.b, g.ho, g.wo, g.cout], out))
}

fn dwconv_mapped_item(g: &ConvGeom, w: &[f32], in_pos: &[i32], x: &[f32], out: &mut [f32]) {
    let (s, k, cin, cout) = (g.stride, g.k, g.cin, g.cout);
    for oy in 0..g.ho {
        for ox in 0..g.wo {
            let off = (oy * g.wo + ox) * cout;
            for (ocl, &p) in in_pos.iter().enumerate() {
                if p < 0 {
                    out[off + ocl] = 0.0;
                    continue;
                }
                let ic = p as usize;
                let mut acc = 0.0f32;
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - g.ph as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - g.pw as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        acc += w[(ky * k + kx) * cout + ocl]
                            * x[((iy as usize) * g.w + ix as usize) * cin + ic];
                    }
                }
                out[off + ocl] = acc;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Property tests: packed kernels == masked-dense, bit for bit (f32) or
// within tolerance (int8), at every thread count
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn cmp_block_geometry_matches_kernel_tiles() {
        // The packed block shape IS the register tile shape; if either
        // side changes, packing must change with it.
        assert_eq!(BLOCK_R, kernels::MR);
        assert_eq!(BLOCK_C, kernels::NR);
    }

    fn rand_tensor(shape: &[usize], rng: &mut Rng) -> Tensor {
        let data = (0..shape.iter().product::<usize>()).map(|_| rng.normal()).collect();
        Tensor::new(shape.to_vec(), data)
    }

    /// Random live subset of `0..full` (never empty — mirrors the
    /// lowering fallback).
    fn rand_live(full: usize, rng: &mut Rng) -> Vec<u32> {
        let mut v: Vec<u32> = (0..full as u32).filter(|_| rng.below(2) == 0).collect();
        if v.is_empty() {
            v.push(rng.below(full) as u32);
        }
        v
    }

    /// Fold a full conv weight to its masked-dense form: entries on a
    /// dead input or output channel become literal +0.0.
    fn fold_conv_weight(w: &Tensor, in_live: &[u32], out_live: &[u32]) -> Tensor {
        let (k, cin, cout) = (w.shape[0], w.shape[2], w.shape[3]);
        let in_dead: Vec<bool> = (0..cin).map(|c| !in_live.contains(&(c as u32))).collect();
        let out_dead: Vec<bool> = (0..cout).map(|c| !out_live.contains(&(c as u32))).collect();
        let mut folded = w.clone();
        for tap in 0..k * k {
            for ic in 0..cin {
                for oc in 0..cout {
                    if in_dead[ic] || out_dead[oc] {
                        folded.data[(tap * cin + ic) * cout + oc] = 0.0;
                    }
                }
            }
        }
        folded
    }

    /// Pack the compacted live x live matrix of a folded conv weight.
    fn pack_conv(
        w_folded: &Tensor,
        in_live: &[u32],
        out_live: &[u32],
    ) -> (Bcsr, Vec<f32>) {
        let (k, cin, cout) = (w_folded.shape[0], w_folded.shape[2], w_folded.shape[3]);
        let (nin, nout) = (in_live.len(), out_live.len());
        let mut vals = Vec::new();
        let csr = Bcsr::build(
            nout,
            k * k * nin,
            |ocl, r| {
                let (tap, icl) = (r / nin, r % nin);
                w_folded.data[(tap * cin + in_live[icl] as usize) * cout
                    + out_live[ocl] as usize]
            },
            |v: f32| v != 0.0,
            &mut vals,
        );
        (csr, vals)
    }

    /// Embed a compacted NHWC map into full channels, +0.0 at dead ones.
    fn embed(x_live: &Tensor, in_live: &[u32], cin_full: usize) -> Tensor {
        let (b, h, w, c) = (x_live.shape[0], x_live.shape[1], x_live.shape[2], x_live.shape[3]);
        let mut full = Tensor::zeros(&[b, h, w, cin_full]);
        for p in 0..b * h * w {
            for (cl, &ic) in in_live.iter().enumerate() {
                full.data[p * cin_full + ic as usize] = x_live.data[p * c + cl];
            }
        }
        full
    }

    /// Restrict a full NHWC map to its live channels.
    fn restrict(x_full: &Tensor, out_live: &[u32]) -> Tensor {
        let (b, h, w, c) = (x_full.shape[0], x_full.shape[1], x_full.shape[2], x_full.shape[3]);
        let mut data = Vec::with_capacity(b * h * w * out_live.len());
        for p in 0..b * h * w {
            for &oc in out_live {
                data.push(x_full.data[p * c + oc as usize]);
            }
        }
        Tensor::new(vec![b, h, w, out_live.len()], data)
    }

    fn conv_case(v: &[usize]) -> Option<(usize, usize, usize, usize, usize, usize, usize, u64)> {
        if v.len() < 8 {
            return None;
        }
        let b = v[0] % 2 + 1;
        let h = v[1] % 6 + 3;
        let w = v[2] % 6 + 3;
        let cin = v[3] % 7 + 2;
        let cout = v[4] % 19 + 2; // crosses the BLOCK_R=4 boundary
        let k = [1, 3, 5][v[5] % 3];
        let stride = v[6] % 2 + 1;
        Some((b, h, w, cin, cout, k, stride, v[7] as u64))
    }

    fn gen_dims(r: &mut Rng) -> Vec<usize> {
        (0..8).map(|_| r.below(1000)).collect()
    }

    #[test]
    fn prop_sparse_conv2d_matches_masked_dense_bitwise() {
        prop::check("sparse conv2d == masked dense", 50, gen_dims, |v| {
            let Some((b, h, w, cin, cout, k, s, seed)) = conv_case(v) else {
                return Ok(());
            };
            let mut rng = Rng::new(seed ^ 0x5bc5);
            let in_live = rand_live(cin, &mut rng);
            let out_live = rand_live(cout, &mut rng);
            let x_live = rand_tensor(&[b, h, w, in_live.len()], &mut rng);
            let wt = rand_tensor(&[k, k, cin, cout], &mut rng);
            let folded = fold_conv_weight(&wt, &in_live, &out_live);
            let (csr, vals) = pack_conv(&folded, &in_live, &out_live);
            let x_full = embed(&x_live, &in_live, cin);
            let want = restrict(&kernels::naive_conv2d(&x_full, &folded, s).unwrap(), &out_live);
            for threads in [1usize, 2, 3] {
                let mut sc = Scratch::default();
                let got = sparse_conv2d(&x_live, &csr, &vals, k, s, threads, &mut sc).unwrap();
                if got.shape != want.shape || got.data != want.data {
                    return Err(format!(
                        "sparse conv mismatch at {threads} threads (b={b} h={h} w={w} cin={cin} \
                         cout={cout} k={k} s={s} live {}x{})",
                        in_live.len(),
                        out_live.len()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sparse_matmul_matches_masked_dense_bitwise() {
        prop::check("sparse matmul == masked dense", 60, gen_dims, |v| {
            if v.len() < 5 {
                return Ok(());
            }
            let m = v[0] % 9 + 1;
            let kdim = v[1] % 33 + 2;
            let n = v[2] % 21 + 2;
            let mut rng = Rng::new(v[3] as u64 ^ 0x9a7);
            let in_live = rand_live(kdim, &mut rng);
            let out_live = rand_live(n, &mut rng);
            let a_live = rand_tensor(&[m, in_live.len()], &mut rng);
            let wt = rand_tensor(&[kdim, n], &mut rng);
            let in_dead: Vec<bool> = (0..kdim).map(|c| !in_live.contains(&(c as u32))).collect();
            let out_dead: Vec<bool> = (0..n).map(|c| !out_live.contains(&(c as u32))).collect();
            let mut folded = wt.clone();
            for ki in 0..kdim {
                for ni in 0..n {
                    if in_dead[ki] || out_dead[ni] {
                        folded.data[ki * n + ni] = 0.0;
                    }
                }
            }
            let mut vals = Vec::new();
            let csr = Bcsr::build(
                out_live.len(),
                in_live.len(),
                |ocl, r| folded.data[in_live[r] as usize * n + out_live[ocl] as usize],
                |x: f32| x != 0.0,
                &mut vals,
            );
            // Embed a into full kdim (dead inputs +0.0), run dense, restrict.
            let mut a_full = Tensor::zeros(&[m, kdim]);
            for mi in 0..m {
                for (cl, &ic) in in_live.iter().enumerate() {
                    a_full.data[mi * kdim + ic as usize] = a_live.data[mi * in_live.len() + cl];
                }
            }
            let dense = kernels::naive_matmul(&a_full, &folded);
            let mut want = Vec::with_capacity(m * out_live.len());
            for mi in 0..m {
                for &oc in &out_live {
                    want.push(dense.data[mi * n + oc as usize]);
                }
            }
            let mut sc = Scratch::default();
            let got = sparse_matmul(&a_live, &csr, &vals, &mut sc);
            if got.data != want {
                return Err(format!("sparse matmul mismatch (m={m} k={kdim} n={n})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dwconv_mapped_matches_masked_dense_bitwise() {
        prop::check("dw mapped == masked dense", 40, gen_dims, |v| {
            let Some((b, h, w, c, _, k, s, seed)) = conv_case(v) else {
                return Ok(());
            };
            let mut rng = Rng::new(seed ^ 0xd3ad);
            let in_live = rand_live(c, &mut rng);
            let out_live = rand_live(c, &mut rng);
            let x_live = rand_tensor(&[b, h, w, in_live.len()], &mut rng);
            let wt = rand_tensor(&[k, k, 1, c], &mut rng);
            // Compact to live outputs; dead-out channels don't exist here.
            let mut wdata = Vec::with_capacity(k * k * out_live.len());
            for tap in 0..k * k {
                for &oc in &out_live {
                    wdata.push(wt.data[tap * c + oc as usize]);
                }
            }
            let w_cmp = Tensor::new(vec![k, k, 1, out_live.len()], wdata);
            let in_pos: Vec<i32> = out_live
                .iter()
                .map(|&oc| in_live.iter().position(|&ic| ic == oc).map_or(-1, |p| p as i32))
                .collect();
            // Dense reference: embed input (dead channels +0.0), dwconv
            // with the full weight, restrict outputs.
            let x_full = embed(&x_live, &in_live, c);
            let full = kernels::naive_dwconv2d(&x_full, &wt, s).unwrap();
            let want = restrict(&full, &out_live);
            for threads in [1usize, 2] {
                let mut sc = Scratch::default();
                let got = dwconv_mapped(&x_live, &w_cmp, &in_pos, s, threads, &mut sc).unwrap();
                if got.shape != want.shape || got.data != want.data {
                    return Err(format!(
                        "dw mapped mismatch at {threads} threads (b={b} h={h} w={w} c={c} k={k} \
                         s={s})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_qmatmul_tracks_fake_quant_within_tolerance() {
        prop::check("qmatmul ~= fake-quant dense", 40, gen_dims, |v| {
            if v.len() < 5 {
                return Ok(());
            }
            let m = v[0] % 6 + 1;
            let kdim = v[1] % 40 + 2;
            let n = v[2] % 21 + 2;
            let bits_w = (v[3] % 7 + 1) as f32;
            let bits_a = (v[4] % 8 + 1) as f32;
            let mut rng = Rng::new(v[3] as u64 ^ 0x111);
            // An exact act_quant image (nonnegative pre-image, as produced
            // by relu/gap upstream).
            let mut a = rand_tensor(&[m, kdim], &mut rng);
            for x in &mut a.data {
                *x = x.abs();
            }
            kernels::act_quant_inplace(&mut a, bits_a);
            let raw = rand_tensor(&[kdim, n], &mut rng);
            let wq = crate::models::host_weight_quant(&raw, bits_w);
            let nw = (2f32.powf(bits_w) - 1.0).max(1.0);
            let (tmax, wmax) = crate::models::weight_quant_scales(&raw.data);
            let mut codes = Vec::new();
            let csr = Bcsr::build(
                n,
                kdim,
                |oc, r| {
                    let tn = raw.data[r * n + oc].tanh() / (2.0 * tmax) + 0.5;
                    (2.0 * (tn * nw).round() - nw) as i8
                },
                |c| c != 0,
                &mut codes,
            );
            let mut sc = Scratch::default();
            let got = qmatmul(&a, &csr, &codes, wmax / nw, bits_a, &mut sc);
            let want = kernels::naive_matmul(&a, &wq);
            let s_a = a.data.iter().fold(0.0f32, |s, &x| s.max(x.abs()));
            let tol = 1e-5 + kdim as f32 * wmax * s_a * 1e-5;
            for (oc, (&g, &d)) in got.data.iter().zip(&want.data).enumerate() {
                if (g - d).abs() > tol {
                    return Err(format!(
                        "qmatmul off at {oc}: {g} vs {d} (tol {tol}, m={m} k={kdim} n={n} \
                         bw={bits_w} ba={bits_a})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_qconv2d_tracks_fake_quant_and_is_thread_invariant() {
        prop::check("qconv2d ~= fake-quant dense, thread-invariant", 25, gen_dims, |v| {
            let Some((b, h, w, cin, cout, k, s, seed)) = conv_case(v) else {
                return Ok(());
            };
            let bits_w = (v[0] % 7 + 1) as f32;
            let bits_a = (v[1] % 8 + 1) as f32;
            let mut rng = Rng::new(seed ^ 0x4b1d);
            let mut x = rand_tensor(&[b, h, w, cin], &mut rng);
            for xv in &mut x.data {
                *xv = xv.abs();
            }
            kernels::act_quant_inplace(&mut x, bits_a);
            let raw = rand_tensor(&[k, k, cin, cout], &mut rng);
            let wq = crate::models::host_weight_quant(&raw, bits_w);
            let nw = (2f32.powf(bits_w) - 1.0).max(1.0);
            let (tmax, wmax) = crate::models::weight_quant_scales(&raw.data);
            let mut codes = Vec::new();
            let csr = Bcsr::build(
                cout,
                k * k * cin,
                |oc, r| {
                    let tn = raw.data[r * cout + oc].tanh() / (2.0 * tmax) + 0.5;
                    (2.0 * (tn * nw).round() - nw) as i8
                },
                |c| c != 0,
                &mut codes,
            );
            let want = kernels::naive_conv2d(&x, &wq, s).unwrap();
            let s_a = x.data.iter().fold(0.0f32, |m, &xv| m.max(xv.abs()));
            let tol = 1e-5 + (k * k * cin) as f32 * wmax * s_a * 1e-5;
            let mut sc = Scratch::default();
            let one = qconv2d(&x, &csr, &codes, wmax / nw, k, s, bits_a, 1, &mut sc).unwrap();
            for (i, (&g, &d)) in one.data.iter().zip(&want.data).enumerate() {
                if (g - d).abs() > tol {
                    return Err(format!(
                        "qconv2d off at {i}: {g} vs {d} (tol {tol}, cin={cin} cout={cout} k={k})"
                    ));
                }
            }
            for threads in [2usize, 3] {
                let mut sc = Scratch::default();
                let got =
                    qconv2d(&x, &csr, &codes, wmax / nw, k, s, bits_a, threads, &mut sc).unwrap();
                if got.data != one.data {
                    return Err(format!("qconv2d changed bits at {threads} threads"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn compressed_kernels_bitwise_invariant_across_isa_paths() {
        // Every compressed kernel, forced onto each ISA path the host
        // supports, must reproduce the scalar path's bits exactly — f32
        // by the stripe argument, int8 because i32 sums are exact on
        // every path.
        let (b, h, w, cin, cout, k, s) = (2usize, 6, 7, 5, 11, 3, 1);
        let mut rng = Rng::new(0xc0de);
        let in_live = rand_live(cin, &mut rng);
        let out_live = rand_live(cout, &mut rng);
        let x_live = rand_tensor(&[b, h, w, in_live.len()], &mut rng);
        let wt = rand_tensor(&[k, k, cin, cout], &mut rng);
        let folded = fold_conv_weight(&wt, &in_live, &out_live);
        let (csr, vals) = pack_conv(&folded, &in_live, &out_live);
        let am = rand_tensor(&[3, csr.cols], &mut rng);
        let mut xq = rand_tensor(&[b, h, w, cin], &mut rng);
        for v in &mut xq.data {
            *v = v.abs();
        }
        kernels::act_quant_inplace(&mut xq, 8.0);
        let mut aq = rand_tensor(&[3, k * k * cin], &mut rng);
        for v in &mut aq.data {
            *v = v.abs();
        }
        kernels::act_quant_inplace(&mut aq, 8.0);
        let mut codes = Vec::new();
        let qcsr = Bcsr::build(
            cout,
            k * k * cin,
            |oc, r| (((oc * 37 + r * 11) % 17) as i32 - 8) as i8,
            |c| c != 0,
            &mut codes,
        );
        let run = |isa: simd::Isa| {
            simd::with_forced(isa, || {
                let mut sc = Scratch::default();
                let sp = sparse_conv2d(&x_live, &csr, &vals, k, s, 2, &mut sc).unwrap();
                let sm = sparse_matmul(&am, &csr, &vals, &mut sc);
                let qc = qconv2d(&xq, &qcsr, &codes, 0.01, k, s, 8.0, 2, &mut sc).unwrap();
                let qm = qmatmul(&aq, &qcsr, &codes, 0.01, 8.0, &mut sc);
                (sp.data, sm.data, qc.data, qm.data)
            })
        };
        let want = run(simd::Isa::Scalar);
        for isa in simd::available() {
            assert_eq!(run(isa), want, "isa {} changed compressed kernel bits", isa.name());
        }
    }

    #[test]
    fn rmsnorm_live_matches_dense_lanes_on_embedded_map() {
        let mut rng = Rng::new(0x60d);
        for (cfull, h, w) in [(8usize, 3usize, 3usize), (11, 4, 2), (5, 2, 5)] {
            let out_live = rand_live(cfull, &mut rng);
            let live = out_live.len() as f32;
            let x_live = rand_tensor(&[2, h, w, out_live.len()], &mut rng);
            // Dense path: embedded map (dead channels +0.0), flat lanes.
            let mut full = embed(&x_live, &out_live, cfull);
            kernels::rmsnorm_inplace(&mut full, live);
            let want = restrict(&full, &out_live);
            let mut got = x_live.clone();
            rmsnorm_live_inplace(&mut got, &out_live, cfull, live);
            assert_eq!(got.data, want.data, "cfull={cfull} live={}", out_live.len());
        }
    }

    #[test]
    fn act_codes_recover_exactly() {
        let mut rng = Rng::new(77);
        for bits in [1.0f32, 2.0, 4.0, 8.0] {
            let na = (bits.exp2() - 1.0).max(1.0);
            let mut t = rand_tensor(&[4, 9], &mut rng);
            for v in &mut t.data {
                *v = v.abs();
            }
            kernels::act_quant_inplace(&mut t, bits);
            let mut sc = Scratch::default();
            let (codes, s_a) = act_codes(&t.data, bits, &mut sc);
            // Rebuild every element from its code: must be bit-exact.
            for (&c, &v) in codes.iter().zip(&t.data) {
                assert!(c as f32 <= na);
                let rebuilt = c as f32 / na * s_a;
                assert_eq!(rebuilt.to_bits(), v.to_bits(), "bits={bits} code={c}");
            }
        }
    }
}
