//! Batch-parallel execution helpers for the reference-backend kernels.
//!
//! The determinism contract (DESIGN.md §Backends) is **thread-count
//! invariance**: every value a kernel produces must be bit-identical for
//! every thread count, including 1.  The helpers here make that easy to
//! uphold by construction:
//!
//! * work is split over *items* (batch samples), and every item's output
//!   lives in its own disjoint chunk of the output buffer(s) — no shared
//!   accumulator is ever written from two threads;
//! * each item is computed by a pure function of its inputs, so *which*
//!   thread runs it cannot change its bits;
//! * cross-item reductions never happen here: kernels materialize
//!   fixed-shape per-item partials (also disjoint chunks) and reduce them
//!   afterwards in item-index order on the calling thread
//!   ([`reduce_partials`]).
//!
//! Threads are plain `std::thread::scope` spawns over contiguous item
//! ranges (the offline crate set has no rayon); spawning costs a few tens
//! of microseconds, so callers gate on [`worth_threading`] and serve-style
//! batch-1 calls never pay it.

use std::ops::Range;

/// Resolve the kernel thread count for a new reference engine:
/// an explicit request wins, then the `COC_REF_THREADS` environment
/// variable, then `std::thread::available_parallelism()`.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("COC_REF_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Kernel threads for one worker of an `n`-worker pool, so serve workers
/// and plan `--jobs` workers compose with kernel threads without
/// oversubscribing the machine: each worker gets its share of the total,
/// never less than 1.
pub fn threads_per_worker(total: usize, workers: usize) -> usize {
    (total / workers.max(1)).max(1)
}

/// Below this many flops per item, scoped-thread spawn overhead dominates
/// any win (measured: a spawn+join round is ~30-80µs; 64k f32 MACs are
/// ~15µs single-threaded).  Serve-time batch-1 stage calls and the tiny
/// unit-test archs all fall under it and stay serial.
const MIN_FLOPS_PER_ITEM: usize = 64 * 1024;

/// Should this kernel call actually spawn?  Never affects results — only
/// whether the (bit-identical) per-item work runs on one thread or many.
pub fn worth_threading(threads: usize, items: usize, flops_per_item: usize) -> bool {
    threads > 1 && items > 1 && flops_per_item >= MIN_FLOPS_PER_ITEM
}

/// Contiguous near-equal split of `0..items` into at most `parts` ranges.
fn ranges(items: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, items.max(1));
    let base = items / parts;
    let extra = items % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run `f(item, chunk)` for every item, where `chunk` is the item's
/// disjoint `item_len` slice of `out`.  Parallel over contiguous item
/// ranges when it pays; bit-identical at every thread count.
pub fn for_each_item<F>(
    threads: usize,
    flops_per_item: usize,
    out: &mut [f32],
    item_len: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    if item_len == 0 || out.is_empty() {
        return;
    }
    let items = out.len() / item_len;
    debug_assert_eq!(out.len(), items * item_len);
    if !worth_threading(threads, items, flops_per_item) {
        for (i, chunk) in out.chunks_exact_mut(item_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let rs = ranges(items, threads);
    std::thread::scope(|s| {
        let mut rest = out;
        for r in rs {
            let (head, tail) = rest.split_at_mut(r.len() * item_len);
            rest = tail;
            let f = &f;
            s.spawn(move || {
                for (j, chunk) in head.chunks_exact_mut(item_len).enumerate() {
                    f(r.start + j, chunk);
                }
            });
        }
    });
}

/// Two-buffer variant: each item owns a disjoint chunk of `a` and `b`
/// (e.g. the im2col conv route: output slice + per-item packed panel).
pub fn for_each_item2<F>(
    threads: usize,
    flops_per_item: usize,
    items: usize,
    a: (&mut [f32], usize),
    b: (&mut [f32], usize),
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    let (a, alen) = a;
    let (b, blen) = b;
    if items == 0 {
        return;
    }
    debug_assert_eq!(a.len(), items * alen);
    debug_assert_eq!(b.len(), items * blen);
    if !worth_threading(threads, items, flops_per_item) {
        for i in 0..items {
            f(i, &mut a[i * alen..(i + 1) * alen], &mut b[i * blen..(i + 1) * blen]);
        }
        return;
    }
    let rs = ranges(items, threads);
    std::thread::scope(|s| {
        let (mut ra, mut rb) = (a, b);
        for r in rs {
            let (ha, ta) = ra.split_at_mut(r.len() * alen);
            ra = ta;
            let (hb, tb) = rb.split_at_mut(r.len() * blen);
            rb = tb;
            let f = &f;
            s.spawn(move || {
                for j in 0..r.len() {
                    f(
                        r.start + j,
                        &mut ha[j * alen..(j + 1) * alen],
                        &mut hb[j * blen..(j + 1) * blen],
                    );
                }
            });
        }
    });
}

/// Three-output variant: each item owns disjoint chunks of `a`, `b` and
/// `c` (e.g. conv backward: `dx` slice + per-item `dw` and `db` partials).
pub fn for_each_item3<F>(
    threads: usize,
    flops_per_item: usize,
    items: usize,
    a: (&mut [f32], usize),
    b: (&mut [f32], usize),
    c: (&mut [f32], usize),
    f: F,
) where
    F: Fn(usize, &mut [f32], &mut [f32], &mut [f32]) + Sync,
{
    let (a, alen) = a;
    let (b, blen) = b;
    let (c, clen) = c;
    if items == 0 {
        return;
    }
    debug_assert_eq!(a.len(), items * alen);
    debug_assert_eq!(b.len(), items * blen);
    debug_assert_eq!(c.len(), items * clen);
    if !worth_threading(threads, items, flops_per_item) {
        for i in 0..items {
            f(
                i,
                &mut a[i * alen..(i + 1) * alen],
                &mut b[i * blen..(i + 1) * blen],
                &mut c[i * clen..(i + 1) * clen],
            );
        }
        return;
    }
    let rs = ranges(items, threads);
    std::thread::scope(|s| {
        let (mut ra, mut rb, mut rc) = (a, b, c);
        for r in rs {
            let (ha, ta) = ra.split_at_mut(r.len() * alen);
            ra = ta;
            let (hb, tb) = rb.split_at_mut(r.len() * blen);
            rb = tb;
            let (hc, tc) = rc.split_at_mut(r.len() * clen);
            rc = tc;
            let f = &f;
            s.spawn(move || {
                for j in 0..r.len() {
                    f(
                        r.start + j,
                        &mut ha[j * alen..(j + 1) * alen],
                        &mut hb[j * blen..(j + 1) * blen],
                        &mut hc[j * clen..(j + 1) * clen],
                    );
                }
            });
        }
    });
}

/// Reduce per-item partials into `acc` in **item-index order** — the one
/// canonical cross-item accumulation order, independent of how the
/// partials were computed.  `partials` is `items * acc.len()` long.
pub fn reduce_partials(acc: &mut [f32], partials: &[f32]) {
    let n = acc.len();
    if n == 0 {
        return;
    }
    for item in partials.chunks_exact(n) {
        for (a, &p) in acc.iter_mut().zip(item) {
            *a += p;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_exactly() {
        for (items, parts) in [(10usize, 3usize), (3, 8), (1, 1), (16, 4), (7, 7)] {
            let rs = ranges(items, parts);
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, items);
            let (min, max) = rs
                .iter()
                .fold((usize::MAX, 0), |(lo, hi), r| (lo.min(r.len()), hi.max(r.len())));
            assert!(max - min <= 1, "near-equal split: {rs:?}");
        }
    }

    #[test]
    fn for_each_item_same_bits_any_thread_count() {
        let items = 13;
        let len = 7;
        let run = |threads: usize| {
            let mut out = vec![0.0f32; items * len];
            // Force threading past the flops gate with a big fake cost.
            for_each_item(threads, usize::MAX, &mut out, len, |i, chunk| {
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = ((i * 31 + j) as f32).sin();
                }
            });
            out
        };
        let a = run(1);
        for t in [2, 3, 5, 16] {
            assert_eq!(a, run(t), "thread count {t} changed bits");
        }
    }

    #[test]
    fn for_each_item2_disjoint_chunks() {
        let items = 6;
        let run = |threads: usize| {
            let mut a = vec![0.0f32; items * 2];
            let mut b = vec![0.0f32; items * 3];
            for_each_item2(threads, usize::MAX, items, (&mut a, 2), (&mut b, 3), |i, ca, cb| {
                ca.fill(i as f32);
                cb.fill(i as f32 * 10.0);
            });
            (a, b)
        };
        let one = run(1);
        assert_eq!(one, run(4));
        assert_eq!(one.0[..4], [0.0, 0.0, 1.0, 1.0]);
        assert_eq!(one.1[..6], [0.0, 0.0, 0.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn for_each_item3_disjoint_chunks() {
        let items = 5;
        let run = |threads: usize| {
            let mut a = vec![0.0f32; items * 2];
            let mut b = vec![0.0f32; items * 3];
            let mut c = vec![0.0f32; items];
            for_each_item3(
                threads,
                usize::MAX,
                items,
                (&mut a, 2),
                (&mut b, 3),
                (&mut c, 1),
                |i, ca, cb, cc| {
                    ca.fill(i as f32);
                    cb.fill(i as f32 * 10.0);
                    cc[0] = i as f32 * 100.0;
                },
            );
            (a, b, c)
        };
        let one = run(1);
        assert_eq!(one, run(3));
        assert_eq!(one.2, vec![0.0, 100.0, 200.0, 300.0, 400.0]);
    }

    #[test]
    fn reduce_partials_index_order() {
        let mut acc = vec![1.0f32, 2.0];
        reduce_partials(&mut acc, &[10.0, 20.0, 100.0, 200.0]);
        assert_eq!(acc, vec![111.0, 222.0]);
    }

    #[test]
    fn small_work_stays_serial() {
        assert!(!worth_threading(8, 1, usize::MAX), "single item never threads");
        assert!(!worth_threading(1, 64, usize::MAX), "one thread never spawns");
        assert!(!worth_threading(8, 64, 100), "tiny items never thread");
        assert!(worth_threading(2, 2, MIN_FLOPS_PER_ITEM));
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn threads_per_worker_shares_without_oversubscription() {
        assert_eq!(threads_per_worker(8, 2), 4);
        assert_eq!(threads_per_worker(8, 3), 2);
        assert_eq!(threads_per_worker(2, 4), 1, "never below 1");
        assert_eq!(threads_per_worker(4, 0), 4, "0 workers treated as 1");
    }
}
