//! Explicit 8-lane SIMD tier for the reference-backend kernels.
//!
//! Every f32 entry point here computes the **same bits** as the scalar
//! canonical-order kernels in [`super::kernels`] — SIMD is a throughput
//! choice, never a semantics choice (DESIGN.md §Backends, "SIMD tier").
//! The contract that makes this possible:
//!
//! * **Stripe-shaped reductions** ([`dot`]) keep `lane_dot`'s exact
//!   semantics: lane `j` accumulates elements with index ≡ j (mod 8), the
//!   tail tops up lanes `0..n%8`, and the lanes combine by the fixed tree
//!   `((l0+l4)+(l1+l5)) + ((l2+l6)+(l3+l7))`.  An 8-wide vector
//!   accumulator *is* the eight stripe lanes, so a vector `add` per step
//!   reproduces the per-lane chains verbatim; narrower ISAs split the
//!   stripe into two 4-lane halves, which changes nothing — each lane is
//!   still its own sequential chain.
//! * **Independent-chain kernels** ([`gemm4x8`], [`axpy`] inside
//!   [`bwd_tap`], [`sparse_block`]) vectorize across *outputs*: each
//!   output element keeps its own sequential accumulation chain in its
//!   own lane, in the canonical order, so there is no horizontal f32 sum
//!   at all.  Multiply-then-add only — never FMA: a fused op rounds once
//!   where the scalar kernels round twice, and `#[target_feature]` never
//!   enables contraction on its own.
//! * **Integer kernels** ([`qblock`]) accumulate in i32, which is
//!   associative — any order (including true horizontal vector sums) is
//!   exact, so the int8 path is exempt from the stripe rule.
//!
//! ISA selection is runtime feature detection (`auto`), overridable with
//! `--simd` / `COC_REF_SIMD` (`scalar|sse2|avx2|neon`); the chosen path
//! is logged once per process so bench JSONs record which path ran.  The
//! scalar fallback compiles on every architecture and is itself pinned
//! bitwise against `lane_dot` and the blocked kernels by the property
//! tests below and in `kernels`/`compressed`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

use anyhow::{bail, Result};

/// One instruction-set path.  All variants exist on every architecture
/// (so CLI parsing and tests are portable); [`available`] reports which
/// ones the host can actually run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    /// Portable scalar loops — the canonical-order reference, compiled
    /// everywhere.
    Scalar,
    /// x86-64 baseline 4-wide f32 / `pmaddwd` int8 (always available on
    /// x86-64; forcing it on an AVX2 host exercises the narrow path).
    Sse2,
    /// x86-64 8-wide f32 and widening int8 (runtime-detected).
    Avx2,
    /// aarch64 baseline 4-wide NEON (always available on aarch64).
    Neon,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    fn code(self) -> u8 {
        match self {
            Isa::Scalar => 1,
            Isa::Sse2 => 2,
            Isa::Avx2 => 3,
            Isa::Neon => 4,
        }
    }

    fn from_code(v: u8) -> Isa {
        match v {
            2 => Isa::Sse2,
            3 => Isa::Avx2,
            4 => Isa::Neon,
            _ => Isa::Scalar,
        }
    }
}

/// A parsed `--simd` / `COC_REF_SIMD` value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Pick the widest ISA the host supports (the default).
    Auto,
    /// Force one path (errors at [`set_policy`] if the host lacks it).
    Fixed(Isa),
}

pub fn parse_policy(s: &str) -> Option<Policy> {
    match s.to_ascii_lowercase().as_str() {
        "auto" => Some(Policy::Auto),
        "scalar" => Some(Policy::Fixed(Isa::Scalar)),
        "sse2" => Some(Policy::Fixed(Isa::Sse2)),
        "avx2" => Some(Policy::Fixed(Isa::Avx2)),
        "neon" => Some(Policy::Fixed(Isa::Neon)),
        _ => None,
    }
}

/// Can the host execute this path?  `Scalar` always; baseline ISAs by
/// target architecture; AVX2 by runtime detection (cached by std).
pub fn detect(isa: Isa) -> bool {
    match isa {
        Isa::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => true,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => true,
        _ => false,
    }
}

/// Every path the host can run, scalar first — the ISA matrix the
/// property and digest tests sweep.
pub fn available() -> Vec<Isa> {
    [Isa::Scalar, Isa::Sse2, Isa::Avx2, Isa::Neon]
        .into_iter()
        .filter(|&isa| detect(isa))
        .collect()
}

fn detect_best() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            Isa::Avx2
        } else {
            Isa::Sse2
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Isa::Neon
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        Isa::Scalar
    }
}

/// Process default, resolved once from `COC_REF_SIMD` (else auto-detect)
/// and logged — so every run records which path produced its numbers.
static DEFAULT: OnceLock<Isa> = OnceLock::new();
/// CLI / test override: 0 = none, else `Isa::code`.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

fn announce(isa: Isa, why: &str) -> Isa {
    crate::obs::log!(crate::obs::Level::Info, "[refback] simd path: {} ({why})", isa.name());
    isa
}

fn default_isa() -> Isa {
    *DEFAULT.get_or_init(|| match std::env::var("COC_REF_SIMD") {
        Ok(raw) => match parse_policy(raw.trim()) {
            Some(Policy::Auto) => announce(detect_best(), "auto"),
            Some(Policy::Fixed(isa)) if detect(isa) => announce(isa, "COC_REF_SIMD"),
            Some(Policy::Fixed(isa)) => {
                crate::obs::log!(
                    crate::obs::Level::Warn,
                    "[refback] COC_REF_SIMD={} is unavailable on this host; using auto",
                    isa.name()
                );
                announce(detect_best(), "auto")
            }
            None => {
                crate::obs::log!(
                    crate::obs::Level::Warn,
                    "[refback] COC_REF_SIMD=`{}` unrecognized (auto|scalar|sse2|avx2|neon); \
                     using auto",
                    raw.trim()
                );
                announce(detect_best(), "auto")
            }
        },
        Err(_) => announce(detect_best(), "auto"),
    })
}

/// The ISA every dispatching entry point uses right now.
pub fn active() -> Isa {
    match OVERRIDE.load(Ordering::Relaxed) {
        0 => default_isa(),
        v => Isa::from_code(v),
    }
}

/// Apply a `--simd` flag value: `auto` clears any override, a fixed ISA
/// must be available on this host.  Threaded from the CLI exactly like
/// `--ref-threads` — results are bit-identical at every setting.
pub fn set_policy(s: &str) -> Result<()> {
    match parse_policy(s) {
        Some(Policy::Auto) => {
            OVERRIDE.store(0, Ordering::SeqCst);
            Ok(())
        }
        Some(Policy::Fixed(isa)) => {
            if !detect(isa) {
                let have: Vec<&str> = available().iter().map(|i| i.name()).collect();
                bail!(
                    "--simd {}: not available on this host (available: {})",
                    isa.name(),
                    have.join("|")
                );
            }
            OVERRIDE.store(isa.code(), Ordering::SeqCst);
            crate::obs::log!(
                crate::obs::Level::Info,
                "[refback] simd path forced: {}",
                isa.name()
            );
            Ok(())
        }
        None => bail!("--simd must be auto|scalar|sse2|avx2|neon, got `{s}`"),
    }
}

/// Run `f` with the active ISA forced to `isa`, restoring the previous
/// override afterwards (panic-safe).  Serialized by a lock: the override
/// is process-global, so path-comparing tests and bench tiers must not
/// interleave flips.  Concurrent *unguarded* work is unaffected in
/// results — every path is bit-identical — it just momentarily runs on
/// the forced path.
pub fn with_forced<R>(isa: Isa, f: impl FnOnce() -> R) -> R {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(u8);
    impl Drop for Restore {
        fn drop(&mut self) {
            OVERRIDE.store(self.0, Ordering::SeqCst);
        }
    }
    let _restore = Restore(OVERRIDE.swap(isa.code(), Ordering::SeqCst));
    f()
}

// ---------------------------------------------------------------------------
// Dispatching entry points
//
// Every op has a `<name>_with(isa, ...)` form (the property tests sweep
// it over `available()`) and a `<name>(...)` form reading `active()`.
// An ISA the host cannot run falls back to scalar — identical bits, so
// degradation is invisible except in speed.
// ---------------------------------------------------------------------------

/// Striped dot product — bitwise equal to [`super::kernels::lane_dot`]
/// on every path.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with(active(), a, b)
}

pub fn dot_with(isa: Isa, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: `detect` verified the feature at policy time; the guard
        // re-checks so a stale Isa value can never reach an unsupported
        // instruction.
        Isa::Avx2 if detect(Isa::Avx2) => unsafe { x86::dot_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::dot_sse2(a, b) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::dot_neon(a, b) },
        _ => scalar::dot(a, b),
    }
}

/// The 4x8 register-tile microkernel shared by `conv_tile`,
/// `matmul_into` and the im2col GEMM: for `kk` ascending,
/// `acc[m] += a[abase[m] + kk] * b[kk*ldb .. kk*ldb+8]`.
/// Each `acc[m][n]` keeps its own sequential chain — identical bits to
/// the scalar tile loop.
#[inline]
pub fn gemm4x8(
    acc: &mut [[f32; 8]; 4],
    a: &[f32],
    abase: [usize; 4],
    kc: usize,
    b: &[f32],
    ldb: usize,
) {
    gemm4x8_with(active(), acc, a, abase, kc, b, ldb)
}

pub fn gemm4x8_with(
    isa: Isa,
    acc: &mut [[f32; 8]; 4],
    a: &[f32],
    abase: [usize; 4],
    kc: usize,
    b: &[f32],
    ldb: usize,
) {
    debug_assert!(kc == 0 || (kc - 1) * ldb + 8 <= b.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability re-checked; see `dot_with`.
        Isa::Avx2 if detect(Isa::Avx2) => unsafe { x86::gemm4x8_avx2(acc, a, abase, kc, b, ldb) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::gemm4x8_sse2(acc, a, abase, kc, b, ldb) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::gemm4x8_neon(acc, a, abase, kc, b, ldb) },
        _ => scalar::gemm4x8(acc, a, abase, kc, b, ldb),
    }
}

/// One conv-backward tap over all its input channels: for each `ic`,
/// `dwtap[ic*cout..][..cout] += xrow[ic] * grow` (independent per-element
/// chains) and `dxrow[ic] += dot(wtap[ic*cout..][..cout], grow)` (stripe
/// order).  Fused so the per-call dispatch cost is paid once per tap,
/// not once per channel.
#[inline]
pub fn bwd_tap(xrow: &[f32], wtap: &[f32], grow: &[f32], dxrow: &mut [f32], dwtap: &mut [f32]) {
    bwd_tap_with(active(), xrow, wtap, grow, dxrow, dwtap)
}

pub fn bwd_tap_with(
    isa: Isa,
    xrow: &[f32],
    wtap: &[f32],
    grow: &[f32],
    dxrow: &mut [f32],
    dwtap: &mut [f32],
) {
    debug_assert_eq!(xrow.len(), dxrow.len());
    debug_assert_eq!(wtap.len(), dwtap.len());
    debug_assert_eq!(wtap.len(), xrow.len() * grow.len());
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability re-checked; see `dot_with`.
        Isa::Avx2 if detect(Isa::Avx2) => unsafe {
            x86::bwd_tap_avx2(xrow, wtap, grow, dxrow, dwtap)
        },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::bwd_tap_sse2(xrow, wtap, grow, dxrow, dwtap) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::bwd_tap_neon(xrow, wtap, grow, dxrow, dwtap) },
        _ => scalar::bwd_tap(xrow, wtap, grow, dxrow, dwtap),
    }
}

/// One 4x8 BCSR block: for `cc` ascending over `xv`,
/// `acc[rr] += blk[rr*8 + cc] * xv[cc]` — the per-row chains stay
/// sequential in `cc` (the canonical block walk), vectorized across the
/// four rows.  `blk` holds at least 32 values (row-major 4x8).
#[inline]
pub fn sparse_block(acc: &mut [f32; 4], blk: &[f32], xv: &[f32]) {
    sparse_block_with(active(), acc, blk, xv)
}

pub fn sparse_block_with(isa: Isa, acc: &mut [f32; 4], blk: &[f32], xv: &[f32]) {
    debug_assert!(blk.len() >= 32 && xv.len() <= 8);
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: SSE2 is x86-64 baseline (AVX2 implies it).
        Isa::Sse2 | Isa::Avx2 => unsafe { x86::sparse_block_sse2(acc, blk, xv) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::sparse_block_neon(acc, blk, xv) },
        _ => scalar::sparse_block(acc, blk, xv),
    }
}

/// One 4x8 int8 BCSR block: `acc[rr] += Σ_cc blk[rr*8+cc] as i32 *
/// av[cc]`.  i32 sums are associative, so this path may use widening
/// i8→i16→i32 vector math and true horizontal sums — exact in any
/// order.  Callers zero-pad `av` past the block's live columns (a 0
/// product is exact) and guarantee every entry fits in i16 (activation
/// codes are ≤ 255).
#[inline]
pub fn qblock(acc: &mut [i32; 4], blk: &[i8], av: &[i32; 8]) {
    qblock_with(active(), acc, blk, av)
}

pub fn qblock_with(isa: Isa, acc: &mut [i32; 4], blk: &[i8], av: &[i32; 8]) {
    debug_assert!(blk.len() >= 32);
    debug_assert!(av.iter().all(|&v| (-32768..=32767).contains(&v)));
    match isa {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: availability re-checked; see `dot_with`.
        Isa::Avx2 if detect(Isa::Avx2) => unsafe { x86::qblock_avx2(acc, blk, av) },
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => unsafe { x86::qblock_sse2(acc, blk, av) },
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => unsafe { neon::qblock_neon(acc, blk, av) },
        _ => scalar::qblock(acc, blk, av),
    }
}

// ---------------------------------------------------------------------------
// Scalar reference path (compiled everywhere)
// ---------------------------------------------------------------------------

mod scalar {
    /// Verbatim `lane_dot` semantics (kernels.rs is the canonical copy;
    /// `prop_dot_matches_lane_dot` pins the two together).
    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % 8;
        let mut l = [0.0f32; 8];
        let mut i = 0;
        while i < main {
            for j in 0..8 {
                l[j] += a[i + j] * b[i + j];
            }
            i += 8;
        }
        for (j, i) in (main..n).enumerate() {
            l[j] += a[i] * b[i];
        }
        ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
    }

    pub(super) fn gemm4x8(
        acc: &mut [[f32; 8]; 4],
        a: &[f32],
        abase: [usize; 4],
        kc: usize,
        b: &[f32],
        ldb: usize,
    ) {
        for kk in 0..kc {
            let brow = &b[kk * ldb..kk * ldb + 8];
            let av = [a[abase[0] + kk], a[abase[1] + kk], a[abase[2] + kk], a[abase[3] + kk]];
            for (m, am) in acc.iter_mut().enumerate() {
                let xv = av[m];
                for (c, &wv) in am.iter_mut().zip(brow) {
                    *c += xv * wv;
                }
            }
        }
    }

    pub(super) fn bwd_tap(
        xrow: &[f32],
        wtap: &[f32],
        grow: &[f32],
        dxrow: &mut [f32],
        dwtap: &mut [f32],
    ) {
        let cout = grow.len();
        for (ic, (&xv, dx)) in xrow.iter().zip(dxrow.iter_mut()).enumerate() {
            let wrow = &wtap[ic * cout..(ic + 1) * cout];
            let dwrow = &mut dwtap[ic * cout..(ic + 1) * cout];
            for (dv, &gv) in dwrow.iter_mut().zip(grow) {
                *dv += xv * gv;
            }
            *dx += dot(wrow, grow);
        }
    }

    pub(super) fn sparse_block(acc: &mut [f32; 4], blk: &[f32], xv: &[f32]) {
        for (cc, &v) in xv.iter().enumerate() {
            for (rr, a) in acc.iter_mut().enumerate() {
                *a += blk[rr * 8 + cc] * v;
            }
        }
    }

    pub(super) fn qblock(acc: &mut [i32; 4], blk: &[i8], av: &[i32; 8]) {
        for (rr, a) in acc.iter_mut().enumerate() {
            let row = &blk[rr * 8..rr * 8 + 8];
            let mut s = 0i32;
            for (&wv, &v) in row.iter().zip(av) {
                s += wv as i32 * v;
            }
            *a += s;
        }
    }
}

// ---------------------------------------------------------------------------
// x86-64: SSE2 (baseline) and AVX2 (runtime-detected)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        __m128i, _mm256_add_ps, _mm256_castsi256_si128, _mm256_cvtepi8_epi32,
        _mm256_extracti128_si256, _mm256_loadu_ps, _mm256_loadu_si256, _mm256_mul_ps,
        _mm256_mullo_epi32, _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps, _mm_add_epi32,
        _mm_add_ps, _mm_cvtsi128_si32, _mm_loadl_epi64, _mm_loadu_ps, _mm_loadu_si128,
        _mm_madd_epi16, _mm_mul_ps, _mm_packs_epi32, _mm_set1_ps, _mm_set_ps, _mm_setzero_ps,
        _mm_shuffle_epi32, _mm_srai_epi16, _mm_storeu_ps, _mm_unpacklo_epi8,
    };

    // ----- dot: the stripe lanes live in the vector accumulator(s) -----

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % 8;
        let mut v = _mm256_setzero_ps();
        let mut i = 0;
        while i < main {
            let av = _mm256_loadu_ps(a[i..i + 8].as_ptr());
            let bv = _mm256_loadu_ps(b[i..i + 8].as_ptr());
            v = _mm256_add_ps(v, _mm256_mul_ps(av, bv));
            i += 8;
        }
        let mut l = [0.0f32; 8];
        _mm256_storeu_ps(l.as_mut_ptr(), v);
        for (j, i) in (main..n).enumerate() {
            l[j] += a[i] * b[i];
        }
        ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn dot_sse2(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % 8;
        let mut v0 = _mm_setzero_ps();
        let mut v1 = _mm_setzero_ps();
        let mut i = 0;
        while i < main {
            let a0 = _mm_loadu_ps(a[i..i + 4].as_ptr());
            let b0 = _mm_loadu_ps(b[i..i + 4].as_ptr());
            let a1 = _mm_loadu_ps(a[i + 4..i + 8].as_ptr());
            let b1 = _mm_loadu_ps(b[i + 4..i + 8].as_ptr());
            v0 = _mm_add_ps(v0, _mm_mul_ps(a0, b0));
            v1 = _mm_add_ps(v1, _mm_mul_ps(a1, b1));
            i += 8;
        }
        let mut l = [0.0f32; 8];
        _mm_storeu_ps(l.as_mut_ptr(), v0);
        _mm_storeu_ps(l[4..].as_mut_ptr(), v1);
        for (j, i) in (main..n).enumerate() {
            l[j] += a[i] * b[i];
        }
        ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
    }

    // ----- gemm4x8: one 8-wide accumulator per tile row -----

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn gemm4x8_avx2(
        acc: &mut [[f32; 8]; 4],
        a: &[f32],
        abase: [usize; 4],
        kc: usize,
        b: &[f32],
        ldb: usize,
    ) {
        let mut c0 = _mm256_loadu_ps(acc[0].as_ptr());
        let mut c1 = _mm256_loadu_ps(acc[1].as_ptr());
        let mut c2 = _mm256_loadu_ps(acc[2].as_ptr());
        let mut c3 = _mm256_loadu_ps(acc[3].as_ptr());
        for kk in 0..kc {
            let bv = _mm256_loadu_ps(b[kk * ldb..kk * ldb + 8].as_ptr());
            // mul then add — no FMA, matching the scalar two-rounding chain.
            c0 = _mm256_add_ps(c0, _mm256_mul_ps(_mm256_set1_ps(a[abase[0] + kk]), bv));
            c1 = _mm256_add_ps(c1, _mm256_mul_ps(_mm256_set1_ps(a[abase[1] + kk]), bv));
            c2 = _mm256_add_ps(c2, _mm256_mul_ps(_mm256_set1_ps(a[abase[2] + kk]), bv));
            c3 = _mm256_add_ps(c3, _mm256_mul_ps(_mm256_set1_ps(a[abase[3] + kk]), bv));
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn gemm4x8_sse2(
        acc: &mut [[f32; 8]; 4],
        a: &[f32],
        abase: [usize; 4],
        kc: usize,
        b: &[f32],
        ldb: usize,
    ) {
        let mut lo = [_mm_setzero_ps(); 4];
        let mut hi = [_mm_setzero_ps(); 4];
        for m in 0..4 {
            lo[m] = _mm_loadu_ps(acc[m][..4].as_ptr());
            hi[m] = _mm_loadu_ps(acc[m][4..].as_ptr());
        }
        for kk in 0..kc {
            let b0 = _mm_loadu_ps(b[kk * ldb..kk * ldb + 4].as_ptr());
            let b1 = _mm_loadu_ps(b[kk * ldb + 4..kk * ldb + 8].as_ptr());
            for m in 0..4 {
                let xs = _mm_set1_ps(a[abase[m] + kk]);
                lo[m] = _mm_add_ps(lo[m], _mm_mul_ps(xs, b0));
                hi[m] = _mm_add_ps(hi[m], _mm_mul_ps(xs, b1));
            }
        }
        for m in 0..4 {
            _mm_storeu_ps(acc[m][..4].as_mut_ptr(), lo[m]);
            _mm_storeu_ps(acc[m][4..].as_mut_ptr(), hi[m]);
        }
    }

    // ----- bwd_tap: vector axpy over cout + striped dot per channel -----

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn bwd_tap_avx2(
        xrow: &[f32],
        wtap: &[f32],
        grow: &[f32],
        dxrow: &mut [f32],
        dwtap: &mut [f32],
    ) {
        let cout = grow.len();
        let main = cout - cout % 8;
        for (ic, &xv) in xrow.iter().enumerate() {
            let wrow = &wtap[ic * cout..(ic + 1) * cout];
            let dwrow = &mut dwtap[ic * cout..(ic + 1) * cout];
            let xs = _mm256_set1_ps(xv);
            let mut c = 0;
            while c < main {
                let dv = _mm256_loadu_ps(dwrow[c..c + 8].as_ptr());
                let gv = _mm256_loadu_ps(grow[c..c + 8].as_ptr());
                _mm256_storeu_ps(
                    dwrow[c..c + 8].as_mut_ptr(),
                    _mm256_add_ps(dv, _mm256_mul_ps(xs, gv)),
                );
                c += 8;
            }
            for (dv, &gv) in dwrow[main..].iter_mut().zip(&grow[main..]) {
                *dv += xv * gv;
            }
            dxrow[ic] += dot_avx2(wrow, grow);
        }
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn bwd_tap_sse2(
        xrow: &[f32],
        wtap: &[f32],
        grow: &[f32],
        dxrow: &mut [f32],
        dwtap: &mut [f32],
    ) {
        let cout = grow.len();
        let main = cout - cout % 4;
        for (ic, &xv) in xrow.iter().enumerate() {
            let wrow = &wtap[ic * cout..(ic + 1) * cout];
            let dwrow = &mut dwtap[ic * cout..(ic + 1) * cout];
            let xs = _mm_set1_ps(xv);
            let mut c = 0;
            while c < main {
                let dv = _mm_loadu_ps(dwrow[c..c + 4].as_ptr());
                let gv = _mm_loadu_ps(grow[c..c + 4].as_ptr());
                _mm_storeu_ps(dwrow[c..c + 4].as_mut_ptr(), _mm_add_ps(dv, _mm_mul_ps(xs, gv)));
                c += 4;
            }
            for (dv, &gv) in dwrow[main..].iter_mut().zip(&grow[main..]) {
                *dv += xv * gv;
            }
            dxrow[ic] += dot_sse2(wrow, grow);
        }
    }

    // ----- sparse 4x8 block: vectorized over the 4 block rows -----

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn sparse_block_sse2(acc: &mut [f32; 4], blk: &[f32], xv: &[f32]) {
        let mut c = _mm_loadu_ps(acc.as_ptr());
        for (cc, &v) in xv.iter().enumerate() {
            // Column cc of the row-major 4x8 block, one element per lane.
            let col = _mm_set_ps(blk[24 + cc], blk[16 + cc], blk[8 + cc], blk[cc]);
            c = _mm_add_ps(c, _mm_mul_ps(col, _mm_set1_ps(v)));
        }
        _mm_storeu_ps(acc.as_mut_ptr(), c);
    }

    // ----- int8 4x8 block: widening multiplies, horizontal i32 sums -----

    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn qblock_avx2(acc: &mut [i32; 4], blk: &[i8], av: &[i32; 8]) {
        let avv = _mm256_loadu_si256(av.as_ptr().cast());
        for (rr, a) in acc.iter_mut().enumerate() {
            let row: *const __m128i = blk[rr * 8..rr * 8 + 8].as_ptr().cast();
            let wide = _mm256_cvtepi8_epi32(_mm_loadl_epi64(row));
            let prod = _mm256_mullo_epi32(wide, avv);
            // i32 addition is associative: any horizontal order is exact.
            let lo = _mm256_castsi256_si128(prod);
            let s4 = _mm_add_epi32(lo, _mm256_extracti128_si256::<1>(prod));
            let s2 = _mm_add_epi32(s4, _mm_shuffle_epi32::<0b0100_1110>(s4));
            let s1 = _mm_add_epi32(s2, _mm_shuffle_epi32::<0b1011_0001>(s2));
            *a += _mm_cvtsi128_si32(s1);
        }
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn qblock_sse2(acc: &mut [i32; 4], blk: &[i8], av: &[i32; 8]) {
        // Activation codes fit i16 (≤ 255), so pack them down and use
        // pmaddwd: 8 widening i16 multiplies + pairwise i32 adds per row.
        let a16 = _mm_packs_epi32(
            _mm_loadu_si128(av[..4].as_ptr().cast()),
            _mm_loadu_si128(av[4..].as_ptr().cast()),
        );
        for (rr, a) in acc.iter_mut().enumerate() {
            let row: *const __m128i = blk[rr * 8..rr * 8 + 8].as_ptr().cast();
            // Sign-extend 8 x i8 -> 8 x i16: interleave with self, then
            // arithmetic shift each 16-bit lane down by 8.
            let w16 = {
                let raw = _mm_loadl_epi64(row);
                _mm_srai_epi16::<8>(_mm_unpacklo_epi8(raw, raw))
            };
            let pr = _mm_madd_epi16(w16, a16);
            let s2 = _mm_add_epi32(pr, _mm_shuffle_epi32::<0b0100_1110>(pr));
            let s1 = _mm_add_epi32(s2, _mm_shuffle_epi32::<0b1011_0001>(s2));
            *a += _mm_cvtsi128_si32(s1);
        }
    }
}

// ---------------------------------------------------------------------------
// aarch64: NEON (baseline)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::{
        vaddq_f32, vaddq_s32, vaddvq_s32, vdupq_n_f32, vget_high_s16, vget_low_s16, vld1_s8,
        vld1q_f32, vld1q_s32, vmovl_s16, vmovl_s8, vmulq_f32, vmulq_s32, vst1q_f32,
    };

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot_neon(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let main = n - n % 8;
        let mut v0 = vdupq_n_f32(0.0);
        let mut v1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i < main {
            let a0 = vld1q_f32(a[i..i + 4].as_ptr());
            let b0 = vld1q_f32(b[i..i + 4].as_ptr());
            let a1 = vld1q_f32(a[i + 4..i + 8].as_ptr());
            let b1 = vld1q_f32(b[i + 4..i + 8].as_ptr());
            v0 = vaddq_f32(v0, vmulq_f32(a0, b0));
            v1 = vaddq_f32(v1, vmulq_f32(a1, b1));
            i += 8;
        }
        let mut l = [0.0f32; 8];
        vst1q_f32(l.as_mut_ptr(), v0);
        vst1q_f32(l[4..].as_mut_ptr(), v1);
        for (j, i) in (main..n).enumerate() {
            l[j] += a[i] * b[i];
        }
        ((l[0] + l[4]) + (l[1] + l[5])) + ((l[2] + l[6]) + (l[3] + l[7]))
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn gemm4x8_neon(
        acc: &mut [[f32; 8]; 4],
        a: &[f32],
        abase: [usize; 4],
        kc: usize,
        b: &[f32],
        ldb: usize,
    ) {
        let mut lo = [vdupq_n_f32(0.0); 4];
        let mut hi = [vdupq_n_f32(0.0); 4];
        for m in 0..4 {
            lo[m] = vld1q_f32(acc[m][..4].as_ptr());
            hi[m] = vld1q_f32(acc[m][4..].as_ptr());
        }
        for kk in 0..kc {
            let b0 = vld1q_f32(b[kk * ldb..kk * ldb + 4].as_ptr());
            let b1 = vld1q_f32(b[kk * ldb + 4..kk * ldb + 8].as_ptr());
            for m in 0..4 {
                let xs = vdupq_n_f32(a[abase[m] + kk]);
                // mul then add — no vmlaq/FMA, matching scalar rounding.
                lo[m] = vaddq_f32(lo[m], vmulq_f32(xs, b0));
                hi[m] = vaddq_f32(hi[m], vmulq_f32(xs, b1));
            }
        }
        for m in 0..4 {
            vst1q_f32(acc[m][..4].as_mut_ptr(), lo[m]);
            vst1q_f32(acc[m][4..].as_mut_ptr(), hi[m]);
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn bwd_tap_neon(
        xrow: &[f32],
        wtap: &[f32],
        grow: &[f32],
        dxrow: &mut [f32],
        dwtap: &mut [f32],
    ) {
        let cout = grow.len();
        let main = cout - cout % 4;
        for (ic, &xv) in xrow.iter().enumerate() {
            let wrow = &wtap[ic * cout..(ic + 1) * cout];
            let dwrow = &mut dwtap[ic * cout..(ic + 1) * cout];
            let xs = vdupq_n_f32(xv);
            let mut c = 0;
            while c < main {
                let dv = vld1q_f32(dwrow[c..c + 4].as_ptr());
                let gv = vld1q_f32(grow[c..c + 4].as_ptr());
                vst1q_f32(dwrow[c..c + 4].as_mut_ptr(), vaddq_f32(dv, vmulq_f32(xs, gv)));
                c += 4;
            }
            for (dv, &gv) in dwrow[main..].iter_mut().zip(&grow[main..]) {
                *dv += xv * gv;
            }
            dxrow[ic] += dot_neon(wrow, grow);
        }
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn sparse_block_neon(acc: &mut [f32; 4], blk: &[f32], xv: &[f32]) {
        let mut c = vld1q_f32(acc.as_ptr());
        for (cc, &v) in xv.iter().enumerate() {
            let colv = [blk[cc], blk[8 + cc], blk[16 + cc], blk[24 + cc]];
            c = vaddq_f32(c, vmulq_f32(vld1q_f32(colv.as_ptr()), vdupq_n_f32(v)));
        }
        vst1q_f32(acc.as_mut_ptr(), c);
    }

    #[target_feature(enable = "neon")]
    pub(super) unsafe fn qblock_neon(acc: &mut [i32; 4], blk: &[i8], av: &[i32; 8]) {
        let a_lo = vld1q_s32(av[..4].as_ptr());
        let a_hi = vld1q_s32(av[4..].as_ptr());
        for (rr, a) in acc.iter_mut().enumerate() {
            let w16 = vmovl_s8(vld1_s8(blk[rr * 8..rr * 8 + 8].as_ptr()));
            let w_lo = vmovl_s16(vget_low_s16(w16));
            let w_hi = vmovl_s16(vget_high_s16(w16));
            let s = vaddq_s32(vmulq_s32(w_lo, a_lo), vmulq_s32(w_hi, a_hi));
            *a += vaddvq_s32(s);
        }
    }
}

// ---------------------------------------------------------------------------
// Property tests: every available ISA == scalar, bit for bit
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_vec(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal()).collect()
    }

    fn gen_len_seed(r: &mut Rng) -> (usize, usize) {
        (r.below(70), r.below(1 << 20))
    }

    fn gen_cols_seed(r: &mut Rng) -> (usize, usize) {
        (r.below(9), r.below(1 << 20))
    }

    fn gen_kc_ldb_seed(r: &mut Rng) -> (usize, usize, usize) {
        (r.below(40), r.below(9), r.below(1 << 20))
    }

    fn gen_cin_cout_seed(r: &mut Rng) -> (usize, usize, usize) {
        (r.below(9), r.below(40), r.below(1 << 20))
    }

    #[test]
    fn available_includes_scalar_and_detected_paths() {
        let have = available();
        assert_eq!(have[0], Isa::Scalar);
        for isa in &have {
            assert!(detect(*isa), "{} listed but not detected", isa.name());
        }
        assert!(detect(active()), "active isa must be runnable");
    }

    #[test]
    fn policy_parses_and_rejects() {
        assert_eq!(parse_policy("auto"), Some(Policy::Auto));
        assert_eq!(parse_policy("scalar"), Some(Policy::Fixed(Isa::Scalar)));
        assert_eq!(parse_policy("AVX2"), Some(Policy::Fixed(Isa::Avx2)));
        assert_eq!(parse_policy("sse2"), Some(Policy::Fixed(Isa::Sse2)));
        assert_eq!(parse_policy("neon"), Some(Policy::Fixed(Isa::Neon)));
        assert_eq!(parse_policy("avx512"), None);
        assert!(set_policy("definitely-not-an-isa").is_err());
    }

    #[test]
    fn with_forced_restores_previous_path() {
        // `active()` outside a forced section races other tests' forced
        // windows (the override is process-global), so only lock-held
        // facts are asserted: the forced path inside the section, and —
        // nested via the raw cell, because the lock is not reentrant —
        // that a swap/restore pair brings the forced path back exactly
        // the way `with_forced`'s own `Restore` does on exit.
        with_forced(Isa::Scalar, || {
            assert_eq!(active(), Isa::Scalar);
            let prev = OVERRIDE.swap(0, Ordering::SeqCst);
            assert_eq!(Isa::from_code(prev), Isa::Scalar);
            assert_eq!(active(), default_isa(), "cleared override reads the default");
            OVERRIDE.store(prev, Ordering::SeqCst);
            assert_eq!(active(), Isa::Scalar, "restore brings the forced path back");
        });
    }

    #[test]
    fn prop_dot_matches_scalar_on_every_isa() {
        prop::check("simd dot == scalar", 120, gen_len_seed, |&(n, seed)| {
            let mut rng = Rng::new(seed as u64 ^ 0x51);
            let a = rand_vec(n, &mut rng);
            let b = rand_vec(n, &mut rng);
            let want = dot_with(Isa::Scalar, &a, &b);
            for isa in available() {
                let got = dot_with(isa, &a, &b);
                if got.to_bits() != want.to_bits() {
                    return Err(format!(
                        "dot diverged on isa {} at n={n}: {got} vs {want}",
                        isa.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn dot_matches_lane_dot_at_all_tail_lengths() {
        let mut rng = Rng::new(0xd07);
        for n in 0..=33usize {
            let a = rand_vec(n, &mut rng);
            let b = rand_vec(n, &mut rng);
            let want = super::super::kernels::lane_dot(&a, &b);
            for isa in available() {
                let got = dot_with(isa, &a, &b);
                assert_eq!(got.to_bits(), want.to_bits(), "n={n} isa={}", isa.name());
            }
        }
    }

    #[test]
    fn prop_gemm4x8_matches_scalar_on_every_isa() {
        prop::check("simd gemm4x8 == scalar", 80, gen_kc_ldb_seed, |&(kcr, extra, seed)| {
            let kc = kcr + 1;
            let ldb = 8 + extra;
            let mut rng = Rng::new(seed as u64 ^ 0x93);
            let a = rand_vec(4 * kc, &mut rng);
            let abase = [0, kc, 2 * kc, 3 * kc];
            let b = rand_vec(kc * ldb, &mut rng);
            let acc0: Vec<f32> = rand_vec(32, &mut rng);
            let mut want = [[0.0f32; 8]; 4];
            for (m, am) in want.iter_mut().enumerate() {
                am.copy_from_slice(&acc0[m * 8..(m + 1) * 8]);
            }
            let mut got0 = want;
            gemm4x8_with(Isa::Scalar, &mut got0, &a, abase, kc, &b, ldb);
            for isa in available() {
                let mut got = want;
                gemm4x8_with(isa, &mut got, &a, abase, kc, &b, ldb);
                for m in 0..4 {
                    for n in 0..8 {
                        if got[m][n].to_bits() != got0[m][n].to_bits() {
                            return Err(format!(
                                "gemm4x8 diverged on {} at kc={kc} ldb={ldb} [{m}][{n}]",
                                isa.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_bwd_tap_matches_scalar_on_every_isa() {
        prop::check("simd bwd_tap == scalar", 60, gen_cin_cout_seed, |&(cinr, coutr, seed)| {
            let (cin, cout) = (cinr + 1, coutr + 1);
            let mut rng = Rng::new(seed as u64 ^ 0xb4d);
            let xrow = rand_vec(cin, &mut rng);
            let wtap = rand_vec(cin * cout, &mut rng);
            let grow = rand_vec(cout, &mut rng);
            let dx0 = rand_vec(cin, &mut rng);
            let dw0 = rand_vec(cin * cout, &mut rng);
            let (mut dxw, mut dww) = (dx0.clone(), dw0.clone());
            bwd_tap_with(Isa::Scalar, &xrow, &wtap, &grow, &mut dxw, &mut dww);
            for isa in available() {
                let (mut dx, mut dw) = (dx0.clone(), dw0.clone());
                bwd_tap_with(isa, &xrow, &wtap, &grow, &mut dx, &mut dw);
                if dx != dxw || dw != dww {
                    return Err(format!(
                        "bwd_tap diverged on {} at cin={cin} cout={cout}",
                        isa.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_sparse_block_matches_scalar_on_every_isa() {
        prop::check("simd sparse_block == scalar", 80, gen_cols_seed, |&(ncc, seed)| {
            let mut rng = Rng::new(seed as u64 ^ 0x5b);
            let blk = rand_vec(32, &mut rng);
            let xv = rand_vec(ncc, &mut rng);
            let acc0 = [rng.normal(), rng.normal(), rng.normal(), rng.normal()];
            let mut want = acc0;
            sparse_block_with(Isa::Scalar, &mut want, &blk, &xv);
            for isa in available() {
                let mut got = acc0;
                sparse_block_with(isa, &mut got, &blk, &xv);
                if got.map(f32::to_bits) != want.map(f32::to_bits) {
                    return Err(format!("sparse_block diverged on {} at ncc={ncc}", isa.name()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_qblock_matches_scalar_on_every_isa() {
        prop::check("simd qblock == scalar", 80, gen_cols_seed, |&(ncc, seed)| {
            let mut rng = Rng::new(seed as u64 ^ 0x18);
            let blk: Vec<i8> = (0..32).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
            let mut av = [0i32; 8];
            for v in av.iter_mut().take(ncc) {
                *v = rng.below(256) as i32; // activation codes are 0..=255
            }
            let acc0 = [
                rng.below(1000) as i32,
                rng.below(1000) as i32,
                rng.below(1000) as i32,
                rng.below(1000) as i32,
            ];
            let mut want = acc0;
            qblock_with(Isa::Scalar, &mut want, &blk, &av);
            for isa in available() {
                let mut got = acc0;
                qblock_with(isa, &mut got, &blk, &av);
                if got != want {
                    return Err(format!("qblock diverged on {} at ncc={ncc}", isa.name()));
                }
            }
            Ok(())
        });
    }
}
