//! Hermetic reference backend: a deterministic pure-Rust interpreter of
//! the manifest's graph contract, implemented directly against `tensor`
//! and `models` — no artifacts, no PJRT, no Python.
//!
//! It serves the same graphs the AOT path lowers (same operand orders,
//! same output leaf counts, same mask/qbit semantics):
//!
//! ```text
//! init    : seed                                  -> params ++ momenta
//! train   : params ++ momenta ++ batch ++ knobs   -> params' ++ momenta' ++ [loss, acc]
//! eval    : params ++ masks ++ qbw ++ qba ++ x    -> (logits, exit1, exit2)
//! stageN  : params ++ masks ++ qbw ++ qba ++ h    -> (exit logits, h') | logits
//! ```
//!
//! The module is layered (this PR's split):
//!
//! * [`kernels`] — cache-blocked, batch-parallel implementations of the
//!   ops (interior/border peeling, register-tiled inner loops, no
//!   zero-skip branches), plus the retained naive reference kernels the
//!   property tests compare against bit-for-bit.
//! * [`scratch`] — the per-graph arena that reuses forward-trace,
//!   gradient and activation buffers across steps, so the steady state
//!   of a train/eval/serve loop is allocation-free.
//! * [`pool`] — `std::thread::scope`-based batch parallelism helpers and
//!   the `--ref-threads` resolution/composition policy.
//! * this file — the interpreter: manifest validation, operand plumbing,
//!   the forward/backward passes and the fused loss/update.
//!
//! # Contract (see DESIGN.md §Backends)
//!
//! * **Determinism: thread-count-invariant canonical accumulation
//!   order** — every output element has one fixed f32 accumulation order
//!   (see the [`kernels`] module docs), cross-batch reductions go
//!   through fixed-shape per-item partials reduced in index order, and
//!   buffer reuse hands out zero-filled storage — so two runs over the
//!   same operands are bit-identical at *every* `--ref-threads` setting
//!   including 1.  This is what the hermetic CI suites (and the golden
//!   digest diff) pin.
//! * **DAG interpretation** — the network is rebuilt from the manifest's
//!   `LayerDesc` list plus its declared `joins`: every edge (layer
//!   `input` fields, join operands) is resolved and validated at load
//!   time — cycles and shape mismatches are rejected with a diagnostic
//!   naming the offending edge (see [`dag`]) — and execution follows the
//!   one canonical topological order (segment-contiguous, declaration
//!   index breaking ties).  Forward hands intermediates between nodes
//!   through reference-counted scratch-arena buffers; backward runs in
//!   exact reverse, with gradient fan-in accumulated in reverse-
//!   topological consumer order — a fixed mul+add chain per element, so
//!   the determinism contract above is untouched by fan-out.  Manifests
//!   with no joins and no explicit `input` edges compile as the legacy
//!   feed-forward chain (declaration order), bit-identical to the
//!   pre-DAG interpreter.  2x2 max-pools are still inserted lazily
//!   whenever a conv's declared output geometry requires a smaller
//!   input (`ceil(h/stride) > hout`).
//! * **Stage composition** — `eval` is *implemented as* stage1 ∘ stage2 ∘
//!   stage3, so staged execution reproduces an eval of the same batch
//!   composition bit-identically by construction.  Across *different*
//!   batch groupings this holds at fp32 (per-row ops only); with
//!   activation quantization on (`qba > 0`) the per-tensor dynamic
//!   scale spans the batch, so regrouping can shift quantized values —
//!   exactly as on the AOT graphs (`fake_quant.py::act_quant`).
//! * **Same compression semantics** — channel masks multiply activations
//!   before a live-channel RMS norm (mirroring `archs.py::apply_conv`),
//!   and the fake quantizers reproduce the L1 kernels' arithmetic
//!   (`models::host_weight_quant`, DoReFa-style activation quant);
//!   backward passes through the quantizers straight-through.
//! * **No device residency** — [`Backend::upload`] reports
//!   [`ResidencyUnsupported`], so every hot loop degrades to its literal
//!   transport through the same fallback machinery the PJRT path uses.
//!
//! The train graph computes a real backward pass (conv/dwconv, live-RMS
//! norm, relu, straight-through quantizers, max-pool, GAP, dense) for the
//! fused loss `(1-α)·CE + α·KD + Σ wᵢ·CEᵢ(exit) + wd·‖W‖²` and the fused
//! SGD-with-momentum update, matching `python/compile/model.py`.  The
//! gradient-check unit test pins the derivation against finite
//! differences.

mod compressed;
pub mod dag;
pub mod kernels;
pub mod pool;
pub mod scratch;
pub mod simd;

pub use pool::{default_threads, threads_per_worker};
pub use scratch::Scratch;

use std::borrow::Cow;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::models::{ArchManifest, LayerKind, ModelState};
use crate::tensor::Tensor;

use super::{Backend, DeviceBuffer, GraphExec, ResidencyUnsupported, StatsCell};

/// The reference backend: the engine's stats handle plus the kernel
/// thread budget every graph it loads will use.
pub struct RefBackend {
    stats: Arc<StatsCell>,
    threads: usize,
}

impl RefBackend {
    pub(crate) fn new(stats: Arc<StatsCell>, threads: usize) -> RefBackend {
        RefBackend { stats, threads: threads.max(1) }
    }
}

impl Backend for RefBackend {
    fn platform(&self) -> String {
        format!("ref-cpu (deterministic host interpreter, {} kernel threads)", self.threads)
    }

    fn load_graph(&self, arch: &Arc<ArchManifest>, tag: &str) -> Result<Box<dyn GraphExec>> {
        let kind = GraphKind::parse(tag)
            .ok_or_else(|| anyhow!("unknown graph tag `{tag}` (init|train|eval|stageN[_bB])"))?;
        // The manifest remains the single source of truth for which
        // graphs exist (mirrors artifact presence on the PJRT path, and
        // lets the serving batch ladder degrade identically).
        ensure!(
            arch.graphs.contains_key(tag),
            "arch `{}` does not declare graph `{tag}`",
            arch.name
        );
        let net = RefNet::compile(arch.clone(), self.threads)?;
        Ok(Box::new(RefGraph {
            net,
            kind,
            name: format!("ref://{}/{tag}", arch.name),
            stats: self.stats.clone(),
            scratch: Mutex::new(Scratch::default()),
        }))
    }

    fn load_file(&self, path: &std::path::Path) -> Result<Box<dyn GraphExec>> {
        bail!(
            "ref backend has no artifact files (tag-addressed graphs only): {}",
            path.display()
        )
    }

    fn upload(&self, _t: &Tensor) -> Result<DeviceBuffer> {
        Err(ResidencyUnsupported("ref backend keeps all state host-side (no device)".into()).into())
    }

    fn load_compressed(
        &self,
        cm: &Arc<crate::models::compressed::CompressedModel>,
        tag: &str,
    ) -> Result<Box<dyn GraphExec>> {
        compressed::load(cm, tag, self.stats.clone(), self.threads)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum GraphKind {
    Init,
    Train,
    Eval,
    Stage { stage: u8, batch: usize },
}

impl GraphKind {
    fn parse(tag: &str) -> Option<GraphKind> {
        match tag {
            "init" => Some(GraphKind::Init),
            "train" => Some(GraphKind::Train),
            "eval" => Some(GraphKind::Eval),
            _ => {
                let rest = tag.strip_prefix("stage")?;
                let (s, b) = match rest.split_once("_b") {
                    Some((s, b)) => (s, b.parse::<usize>().ok()?),
                    None => (rest, 1),
                };
                let stage: u8 = s.parse().ok()?;
                ((1..=3).contains(&stage) && b >= 1).then_some(GraphKind::Stage { stage, batch: b })
            }
        }
    }
}

struct RefGraph {
    net: RefNet,
    kind: GraphKind,
    name: String,
    stats: Arc<StatsCell>,
    /// Per-graph buffer arena: locked once per `run`, never shared
    /// across graphs or engines (see `scratch` module docs).
    scratch: Mutex<Scratch>,
}

impl GraphExec for RefGraph {
    fn run(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let _s = crate::obs::trace::span("refback.run");
        let t0 = Instant::now();
        let out = self
            .dispatch(inputs)
            .with_context(|| format!("executing `{}`", self.name))?;
        self.stats.executions.incr();
        self.stats.execute_ns.add(t0.elapsed().as_nanos() as u64);
        Ok(out)
    }

    fn run_buffers(&self, _inputs: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>> {
        Err(ResidencyUnsupported("ref backend has no device buffers".into()).into())
    }
}

impl RefGraph {
    fn dispatch(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let scratch = &mut *self.scratch.lock().unwrap();
        self.dispatch_with(inputs, scratch)
    }

    fn dispatch_with(&self, inputs: &[&Tensor], scratch: &mut Scratch) -> Result<Vec<Tensor>> {
        let net = &self.net;
        match self.kind {
            GraphKind::Init => {
                ensure!(inputs.len() == 1, "init takes 1 operand, got {}", inputs.len());
                let seed = scalar(inputs[0], "seed")?;
                ensure!(seed.is_finite() && seed >= 0.0, "bad init seed {seed}");
                // Same He-normal init as `ModelState::init_host`, so rust-
                // and graph-initialized states are identical by definition.
                let st = ModelState::init_host(net.arch.clone(), seed as u64);
                let mut out = st.params;
                out.extend(st.momenta);
                Ok(out)
            }
            GraphKind::Train => net.train_step(inputs, scratch),
            GraphKind::Eval => {
                let (params, masks, qbw, qba, x) = net.split_eval_operands(inputs)?;
                ensure!(
                    x.shape.first() == Some(&net.arch.eval_batch),
                    "eval graph lowered at batch {}, got input batch {:?}",
                    net.arch.eval_batch,
                    x.shape.first()
                );
                let (h1, e1) = net.stage1(params, masks, qbw, qba, x, scratch)?;
                let (h2, e2) = net.stage2(params, masks, qbw, qba, &h1, scratch)?;
                scratch.recycle_tensor(h1);
                let logits = net.stage3(params, masks, qbw, qba, &h2, scratch)?;
                scratch.recycle_tensor(h2);
                Ok(vec![logits, e1, e2])
            }
            GraphKind::Stage { stage, batch } => {
                let (params, masks, qbw, qba, x) = net.split_eval_operands(inputs)?;
                ensure!(
                    x.shape.first() == Some(&batch),
                    "stage{stage} graph lowered at batch {batch}, got input batch {:?}",
                    x.shape.first()
                );
                match stage {
                    1 => {
                        let (h1, e1) = net.stage1(params, masks, qbw, qba, x, scratch)?;
                        Ok(vec![e1, h1])
                    }
                    2 => {
                        let (h2, e2) = net.stage2(params, masks, qbw, qba, x, scratch)?;
                        Ok(vec![e2, h2])
                    }
                    _ => Ok(vec![net.stage3(params, masks, qbw, qba, x, scratch)?]),
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The interpreted network
// ---------------------------------------------------------------------------

/// Retire a layer input the forward pass owned; borrowed inputs are the
/// caller's operands and stay untouched.  (Layer inputs travel as
/// `Cow<Tensor>` so the forward pass recycles every intermediate it owns
/// without cloning the operands it does not — the one clone left is a
/// trace of an unpooled borrowed input, via `Cow::into_owned`.)
fn recycle_cow(xin: Cow<'_, Tensor>, scratch: &mut Scratch) {
    if let Cow::Owned(t) = xin {
        scratch.recycle_tensor(t);
    }
}

// ---------------------------------------------------------------------------
// DAG value plumbing (forward refcounts, backward fan-in)
// ---------------------------------------------------------------------------

/// Hand a producer's value to a consumer, decrementing its refcount: the
/// last consumer takes ownership (`Cow::Owned` — the buffer is recycled
/// or kept as a trace downstream), every earlier one borrows.  The stage
/// input is always borrowed (it belongs to the caller).
fn take_value<'a>(
    values: &'a mut [Option<Tensor>],
    refs: &mut [usize],
    r: dag::NodeRef,
    input: &'a Tensor,
) -> Cow<'a, Tensor> {
    match r {
        dag::NodeRef::Input => Cow::Borrowed(input),
        dag::NodeRef::Node(p) => {
            refs[p] -= 1;
            if refs[p] == 0 {
                Cow::Owned(values[p].take().expect("producer value live"))
            } else {
                Cow::Borrowed(values[p].as_ref().expect("producer value live"))
            }
        }
    }
}

/// Borrow a producer's value without consuming a reference (pair with
/// [`release_value`] once the consumer is done with it).
fn peek_value<'a>(values: &'a [Option<Tensor>], r: dag::NodeRef, input: &'a Tensor) -> &'a Tensor {
    match r {
        dag::NodeRef::Input => input,
        dag::NodeRef::Node(p) => values[p].as_ref().expect("producer value live"),
    }
}

/// Drop one reference to a producer's value; the last release recycles
/// the buffer into the arena.
fn release_value(
    values: &mut [Option<Tensor>],
    refs: &mut [usize],
    r: dag::NodeRef,
    scratch: &mut Scratch,
) {
    if let dag::NodeRef::Node(p) = r {
        refs[p] -= 1;
        if refs[p] == 0 {
            if let Some(t) = values[p].take() {
                scratch.recycle_tensor(t);
            }
        }
    }
}

/// Route a gradient contribution to its producer during the backward
/// pass: the first contribution becomes the accumulator, later ones are
/// added element-wise.  Called in reverse-topological consumer order, so
/// the fan-in accumulation order is canonical (thread-count invariant
/// and bit-identical across runs).
fn route_grad(
    node_g: &mut [Option<Tensor>],
    g_in: &mut Option<Tensor>,
    r: dag::NodeRef,
    g: Tensor,
    scratch: &mut Scratch,
) {
    let slot = match r {
        dag::NodeRef::Input => g_in,
        dag::NodeRef::Node(p) => &mut node_g[p],
    };
    match slot {
        None => *slot = Some(g),
        Some(acc) => {
            kernels::add_assign(acc, &g);
            scratch.recycle_tensor(g);
        }
    }
}

/// The DAG interpretation of one `ArchManifest` (validated at load
/// time — see the module docs and [`dag`] for the contract).
struct RefNet {
    arch: Arc<ArchManifest>,
    /// The validated topology: canonical execution order, stage cuts,
    /// per-node consumer lists (forward refcounts / backward fan-in).
    dag: dag::Dag,
    /// Layer indices of the exit heads, when declared.
    exit1: Option<usize>,
    exit2: Option<usize>,
    /// Kernel thread budget (results are identical at every setting).
    threads: usize,
}

impl RefNet {
    fn compile(arch: Arc<ArchManifest>, threads: usize) -> Result<RefNet> {
        ensure!(
            arch.param_shapes.len() == 2 * arch.layers.len(),
            "arch `{}`: {} param shapes for {} layers (want (w, b) pairs)",
            arch.name,
            arch.param_shapes.len(),
            arch.layers.len()
        );
        let mut body = Vec::new();
        let (mut exit1, mut exit2) = (None, None);
        let mut last_rank = 0u8;
        for (li, l) in arch.layers.iter().enumerate() {
            let want_w: Vec<usize> = match l.kind {
                LayerKind::Dense => vec![l.cin, l.cout],
                LayerKind::DwConv => vec![l.k, l.k, 1, l.cout],
                LayerKind::Conv => vec![l.k, l.k, l.cin, l.cout],
            };
            ensure!(
                arch.param_shapes[2 * li] == want_w,
                "layer `{}`: declared weight shape {:?} != expected {:?}",
                l.name,
                arch.param_shapes[2 * li],
                want_w
            );
            ensure!(
                arch.param_shapes[2 * li + 1] == vec![l.cout],
                "layer `{}`: declared bias shape {:?} != [{}]",
                l.name,
                arch.param_shapes[2 * li + 1],
                l.cout
            );
            if l.out_mask >= 0 {
                let slot = arch.mask_slots.get(l.out_mask as usize).ok_or_else(|| {
                    anyhow!("layer `{}`: mask slot {} undeclared", l.name, l.out_mask)
                })?;
                ensure!(
                    slot.channels == l.cout,
                    "layer `{}`: mask slot {} covers {} channels, layer has {}",
                    l.name,
                    l.out_mask,
                    slot.channels,
                    l.cout
                );
            }
            match l.segment.as_str() {
                "seg1" | "seg2" | "seg3" => {
                    let rank = match l.segment.as_str() {
                        "seg1" => 1,
                        "seg2" => 2,
                        _ => 3,
                    };
                    ensure!(
                        rank >= last_rank,
                        "layer `{}`: body segments must appear in seg1..seg3 order",
                        l.name
                    );
                    last_rank = rank;
                    body.push(li);
                }
                "exit1" | "exit2" => {
                    ensure!(l.kind == LayerKind::Dense, "exit head `{}` must be dense", l.name);
                    ensure!(
                        l.cout == arch.num_classes,
                        "exit head `{}` emits {} classes, arch has {}",
                        l.name,
                        l.cout,
                        arch.num_classes
                    );
                    let slot = if l.segment == "exit1" { &mut exit1 } else { &mut exit2 };
                    ensure!(slot.is_none(), "duplicate {} head `{}`", l.segment, l.name);
                    *slot = Some(li);
                }
                other => bail!("layer `{}`: unknown segment `{other}`", l.name),
            }
        }
        ensure!(!body.is_empty(), "arch `{}` has no body layers", arch.name);
        // Topology: resolve and validate every edge, order the nodes.
        // (Cycles / shape mismatches are rejected here, naming the edge.)
        let net_dag = dag::Dag::build(&arch, &body)?;
        let fc = net_dag.terminal[2].expect("dag guarantees a seg3 terminal");
        let fc_li = match net_dag.nodes[fc].op {
            dag::NodeOp::Dense { li } => li,
            _ => unreachable!("dag guarantees the seg3 terminal is the dense classifier"),
        };
        ensure!(
            arch.layers[fc_li].cout == arch.num_classes,
            "arch `{}`: classifier emits {} classes, arch declares {}",
            arch.name,
            arch.layers[fc_li].cout,
            arch.num_classes
        );
        if let Some(x1) = exit1 {
            let t = net_dag
                .terminal[0]
                .ok_or_else(|| anyhow!("exit1 head declared but seg1 has no layers"))?;
            let feed = net_dag.nodes[t].cout;
            ensure!(
                arch.layers[x1].cin == feed,
                "exit1 head fan-in {} != seg1 output channels {feed}",
                arch.layers[x1].cin
            );
        }
        if let Some(x2) = exit2 {
            let t = net_dag
                .effective_terminal(1)
                .ok_or_else(|| anyhow!("exit2 head declared but seg1/seg2 have no layers"))?;
            let feed = net_dag.nodes[t].cout;
            ensure!(
                arch.layers[x2].cin == feed,
                "exit2 head fan-in {} != seg2 output channels {feed}",
                arch.layers[x2].cin
            );
        }
        Ok(RefNet { arch, dag: net_dag, exit1, exit2, threads: threads.max(1) })
    }

    // ----- operand plumbing -------------------------------------------------

    /// Split the `params* ++ masks* ++ qbw ++ qba ++ x` operand list the
    /// eval and stage graphs share, validating shapes.  Returns operand
    /// sub-slices directly — no per-call `Vec` of references.
    #[allow(clippy::type_complexity)]
    fn split_eval_operands<'a>(
        &self,
        inputs: &'a [&'a Tensor],
    ) -> Result<(&'a [&'a Tensor], &'a [&'a Tensor], f32, f32, &'a Tensor)> {
        let np = self.arch.num_params();
        let nm = self.arch.mask_slots.len();
        ensure!(
            inputs.len() == np + nm + 3,
            "eval/stage graphs take {} operands, got {}",
            np + nm + 3,
            inputs.len()
        );
        let params = &inputs[..np];
        self.check_params(params)?;
        let masks = &inputs[np..np + nm];
        self.check_masks(masks)?;
        let qbw = scalar(inputs[np + nm], "qbw")?;
        let qba = scalar(inputs[np + nm + 1], "qba")?;
        Ok((params, masks, qbw, qba, inputs[np + nm + 2]))
    }

    fn check_params(&self, params: &[&Tensor]) -> Result<()> {
        for (i, p) in params.iter().enumerate() {
            ensure!(
                p.shape == self.arch.param_shapes[i],
                "param {i} has shape {:?}, manifest declares {:?}",
                p.shape,
                self.arch.param_shapes[i]
            );
        }
        Ok(())
    }

    fn check_masks(&self, masks: &[&Tensor]) -> Result<()> {
        for (i, m) in masks.iter().enumerate() {
            ensure!(
                m.shape == vec![self.arch.mask_slots[i].channels],
                "mask {i} has shape {:?}, slot declares [{}]",
                m.shape,
                self.arch.mask_slots[i].channels
            );
        }
        Ok(())
    }

    /// Quantized weight view into an arena buffer (no per-layer alloc;
    /// `take_full` — the quant pass writes every element).
    fn weight_quant(&self, w: &Tensor, bits: f32, scratch: &mut Scratch) -> Tensor {
        let mut out = scratch.take_full(w.len());
        crate::models::host_weight_quant_into(&w.data, bits, &mut out);
        Tensor::new(w.shape.clone(), out)
    }

    // ----- forward ----------------------------------------------------------

    /// Execute one segment (0-based) of the DAG in the canonical
    /// topological order from its stage input.  Intermediates are
    /// reference-counted: a producer's buffer is borrowed by every
    /// consumer but the last, which takes ownership (so it is either
    /// recycled into the arena or kept as a trace — never cloned).
    /// `record` keeps the per-node traces the train backward pass
    /// consumes; eval/stage/serve callers pass `false`.  Both modes run
    /// the same ops in the same order, so recording never perturbs a
    /// value.  Returns the segment terminal's value plus the traces in
    /// execution order.
    #[allow(clippy::too_many_arguments)]
    fn forward_segment(
        &self,
        seg: usize,
        params: &[&Tensor],
        masks: &[&Tensor],
        qbw: f32,
        qba: f32,
        input: &Tensor,
        record: bool,
        scratch: &mut Scratch,
    ) -> Result<(Tensor, Vec<(usize, NodeTrace)>)> {
        let d = &self.dag;
        let range = d.seg_range(seg);
        if range.is_empty() {
            // Empty segment: the stage carries its input through unchanged.
            return Ok((input.clone(), Vec::new()));
        }
        let term = d.terminal[seg].expect("non-empty segment has a terminal");
        let n = d.nodes.len();
        let mut values: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        // Consumer refcounts; the terminal escapes to the caller (+1) so
        // it is never moved into (or recycled by) a same-segment consumer.
        let mut refs: Vec<usize> = (0..n).map(|i| d.consumers[i].len()).collect();
        refs[term] += 1;
        let mut traces: Vec<(usize, NodeTrace)> = Vec::new();
        for &ni in range {
            let node = &d.nodes[ni];
            let (out, tr) = match node.op {
                dag::NodeOp::Conv { li } => {
                    let xin = take_value(&mut values, &mut refs, node.inputs[0], input);
                    let (out, tr) =
                        self.conv_forward(li, xin, params, masks, qbw, qba, record, scratch)?;
                    (out, tr.map(NodeTrace::Conv))
                }
                dag::NodeOp::Dense { li } => {
                    let (out, tr) = {
                        let xr = peek_value(&values, node.inputs[0], input);
                        self.dense_forward(li, xr, params, qbw, qba, record, scratch)?
                    };
                    release_value(&mut values, &mut refs, node.inputs[0], scratch);
                    (out, tr.map(NodeTrace::Dense))
                }
                dag::NodeOp::Join { out_mask } => {
                    // z = relu(a + b) -> act_quant -> mask (finish_block).
                    let (ra, rb) = (node.inputs[0], node.inputs[1]);
                    let mut z = match take_value(&mut values, &mut refs, ra, input) {
                        Cow::Owned(t) => t,
                        Cow::Borrowed(t) => {
                            let mut zb = scratch.take_full(t.len());
                            zb.copy_from_slice(&t.data);
                            Tensor::new(t.shape.clone(), zb)
                        }
                    };
                    {
                        let bt = peek_value(&values, rb, input);
                        ensure!(
                            bt.len() == z.len(),
                            "join `{}`: operand sizes {} vs {} (batch mismatch)",
                            node.name,
                            z.len(),
                            bt.len()
                        );
                        kernels::add_assign(&mut z, bt);
                    }
                    release_value(&mut values, &mut refs, rb, scratch);
                    kernels::relu_inplace(&mut z);
                    let tr = record.then(|| {
                        let mut nr = scratch.take_full(z.len());
                        nr.copy_from_slice(&z.data);
                        NodeTrace::Join {
                            relu_out: Tensor::new(z.shape.clone(), nr),
                            out_mask,
                        }
                    });
                    kernels::act_quant_inplace(&mut z, qba);
                    if out_mask >= 0 {
                        kernels::mul_channel_mask(&mut z, &masks[out_mask as usize].data);
                    }
                    (z, tr)
                }
                dag::NodeOp::Output { out_mask } => {
                    // Unary terminal: act_quant -> mask (linear bottleneck —
                    // no relu, the non-linearity lives in the block).
                    let mut z = match take_value(&mut values, &mut refs, node.inputs[0], input) {
                        Cow::Owned(t) => t,
                        Cow::Borrowed(t) => {
                            let mut zb = scratch.take_full(t.len());
                            zb.copy_from_slice(&t.data);
                            Tensor::new(t.shape.clone(), zb)
                        }
                    };
                    let tr = record.then(|| NodeTrace::Output { out_mask });
                    kernels::act_quant_inplace(&mut z, qba);
                    if out_mask >= 0 {
                        kernels::mul_channel_mask(&mut z, &masks[out_mask as usize].data);
                    }
                    (z, tr)
                }
            };
            values[ni] = Some(out);
            if let Some(tr) = tr {
                traces.push((ni, tr));
            }
        }
        let out = values[term].take().expect("terminal value live");
        // Defensive: every non-terminal value was moved or recycled when
        // its refcount hit zero (dead nodes are rejected at load).
        for v in values.into_iter().flatten() {
            scratch.recycle_tensor(v);
        }
        Ok((out, traces))
    }

    /// Pools (lazy, geometry-driven) + conv -> bias -> mask -> live-RMS
    /// norm -> relu -> act_quant, mirroring `archs.py::apply_conv`.
    #[allow(clippy::too_many_arguments)]
    fn conv_forward(
        &self,
        li: usize,
        mut xin: Cow<'_, Tensor>,
        params: &[&Tensor],
        masks: &[&Tensor],
        qbw: f32,
        qba: f32,
        record: bool,
        scratch: &mut Scratch,
    ) -> Result<(Tensor, Option<ConvTrace>)> {
        let l = &self.arch.layers[li];
        let s = l.stride.max(1);
        let mut pools = Vec::new();
        loop {
            let (_, h, w, _) = kernels::dims4(&xin)?;
            if h.div_ceil(s) <= l.hout && w.div_ceil(s) <= l.wout {
                break;
            }
            let (pooled, idx) = kernels::maxpool2(&xin, record, scratch)?;
            if record {
                pools.push(PoolTrace { idx, in_shape: xin.shape.clone() });
            }
            // Pre-pool values are never consumed again (the backward
            // route is the recorded argmax indices).
            recycle_cow(xin, scratch);
            xin = Cow::Owned(pooled);
        }
        let (_, h, w, _) = kernels::dims4(&xin)?;
        ensure!(
            h.div_ceil(s) == l.hout && w.div_ceil(s) == l.wout,
            "layer `{}`: no pooling schedule maps {h}x{w} input to declared {}x{} output at \
             stride {s}",
            l.name,
            l.hout,
            l.wout
        );
        let wq = self.weight_quant(params[2 * li], qbw, scratch);
        let mut y = match l.kind {
            LayerKind::Conv => kernels::conv2d(&xin, &wq, s, self.threads, scratch)?,
            LayerKind::DwConv => kernels::dwconv2d(&xin, &wq, s, self.threads, scratch)?,
            LayerKind::Dense => unreachable!("dense handled by dense_forward"),
        };
        kernels::add_channel_bias(&mut y, &params[2 * li + 1].data);
        let mvec = (l.out_mask >= 0).then(|| masks[l.out_mask as usize]);
        if let Some(m) = mvec {
            kernels::mul_channel_mask(&mut y, &m.data);
        }
        let live = match mvec {
            Some(m) => m.data.iter().sum::<f32>().max(1.0),
            None => l.cout as f32,
        };
        if !record {
            recycle_cow(xin, scratch);
            scratch.recycle_tensor(wq);
            // In-place norm: identical arithmetic to the recorded path.
            kernels::rmsnorm_inplace(&mut y, live);
            // `act: false` stops after the norm (pre-join convs and 1x1
            // projections — the relu and act_quant live in the join).
            if l.act {
                kernels::relu_inplace(&mut y);
                kernels::act_quant_inplace(&mut y, qba);
            }
            return Ok((y, None));
        }
        let x = xin.into_owned();
        let masked = y;
        let (mut normed, rs, d) = kernels::rmsnorm(&masked, live, scratch);
        let normed_relu = if l.act {
            kernels::relu_inplace(&mut normed);
            let mut nr = scratch.take_full(normed.len());
            nr.copy_from_slice(&normed.data);
            Some(Tensor::new(normed.shape.clone(), nr))
        } else {
            None
        };
        if l.act {
            kernels::act_quant_inplace(&mut normed, qba);
        }
        Ok((normed, Some(ConvTrace { li, pools, x, wq, masked, rs, d, normed_relu })))
    }

    /// GAP -> act_quant -> quantized matmul -> bias (the `qmatmul` head).
    #[allow(clippy::too_many_arguments)]
    fn dense_forward(
        &self,
        li: usize,
        feat: &Tensor,
        params: &[&Tensor],
        qbw: f32,
        qba: f32,
        record: bool,
        scratch: &mut Scratch,
    ) -> Result<(Tensor, Option<DenseTrace>)> {
        let l = &self.arch.layers[li];
        let (_, h, w, c) = kernels::dims4(feat)?;
        ensure!(
            c == l.cin,
            "dense `{}`: fan-in {} != feature channels {c}",
            l.name,
            l.cin
        );
        let mut aq = kernels::gap(feat, scratch)?;
        kernels::act_quant_inplace(&mut aq, qba);
        let wq = self.weight_quant(params[2 * li], qbw, scratch);
        let mut out = kernels::matmul(&aq, &wq, scratch);
        kernels::add_row_bias(&mut out, &params[2 * li + 1].data);
        if !record {
            scratch.recycle_tensor(aq);
            scratch.recycle_tensor(wq);
            return Ok((out, None));
        }
        let tr = DenseTrace { li, feat_shape: feat.shape.clone(), hw: (h, w), aq, wq };
        Ok((out, Some(tr)))
    }

    /// Exit head logits over a segment output (zero logits when the arch
    /// declares no head — "never confident", deterministically).
    #[allow(clippy::too_many_arguments)]
    fn exit_forward(
        &self,
        head: Option<usize>,
        feat: &Tensor,
        params: &[&Tensor],
        qbw: f32,
        qba: f32,
        record: bool,
        scratch: &mut Scratch,
    ) -> Result<(Tensor, Option<DenseTrace>)> {
        match head {
            Some(li) => self.dense_forward(li, feat, params, qbw, qba, record, scratch),
            None => {
                let b = *feat.shape.first().unwrap_or(&0);
                let nc = self.arch.num_classes;
                Ok((Tensor::new(vec![b, nc], scratch.take(b * nc)), None))
            }
        }
    }

    fn stage1(
        &self,
        params: &[&Tensor],
        masks: &[&Tensor],
        qbw: f32,
        qba: f32,
        x: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<(Tensor, Tensor)> {
        let (h1, _) = self.forward_segment(0, params, masks, qbw, qba, x, false, scratch)?;
        let (e1, _) = self.exit_forward(self.exit1, &h1, params, qbw, qba, false, scratch)?;
        Ok((h1, e1))
    }

    fn stage2(
        &self,
        params: &[&Tensor],
        masks: &[&Tensor],
        qbw: f32,
        qba: f32,
        h1: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<(Tensor, Tensor)> {
        let (h2, _) = self.forward_segment(1, params, masks, qbw, qba, h1, false, scratch)?;
        let (e2, _) = self.exit_forward(self.exit2, &h2, params, qbw, qba, false, scratch)?;
        Ok((h2, e2))
    }

    fn stage3(
        &self,
        params: &[&Tensor],
        masks: &[&Tensor],
        qbw: f32,
        qba: f32,
        h2: &Tensor,
        scratch: &mut Scratch,
    ) -> Result<Tensor> {
        // `RefNet::compile` guarantees the seg3 terminal is the dense
        // classifier, so this segment always produces logits.
        let (logits, _) = self.forward_segment(2, params, masks, qbw, qba, h2, false, scratch)?;
        Ok(logits)
    }

    // ----- the train graph --------------------------------------------------

    fn train_step(&self, inputs: &[&Tensor], scratch: &mut Scratch) -> Result<Vec<Tensor>> {
        let np = self.arch.num_params();
        let nm = self.arch.mask_slots.len();
        // params(np) ++ momenta(np) ++ x ++ y ++ masks(nm) ++ qbw ++ qba ++
        // tlogits ++ kd_alpha ++ kd_tau ++ exit_w ++ hp.
        ensure!(
            inputs.len() == 2 * np + nm + 9,
            "train graph takes {} operands, got {}",
            2 * np + nm + 9,
            inputs.len()
        );
        let params = &inputs[..np];
        self.check_params(params)?;
        let momenta = &inputs[np..2 * np];
        let x = inputs[2 * np];
        let y = inputs[2 * np + 1];
        let masks = &inputs[2 * np + 2..2 * np + 2 + nm];
        self.check_masks(masks)?;
        let rest = &inputs[2 * np + 2 + nm..];
        let qbw = scalar(rest[0], "qbw")?;
        let qba = scalar(rest[1], "qba")?;
        let tlogits = rest[2];
        let kd_alpha = scalar(rest[3], "kd_alpha")?;
        let kd_tau = scalar(rest[4], "kd_tau")?;
        let exit_w = rest[5];
        let hp = rest[6];
        ensure!(exit_w.len() == 2, "exit_w must have 2 entries");
        ensure!(hp.len() == 3, "hp must be [lr, momentum, weight_decay]");
        let (lr, mu, wd) = (hp.data[0], hp.data[1], hp.data[2]);
        let b = *x.shape.first().unwrap_or(&0);
        ensure!(
            b == self.arch.train_batch,
            "train graph lowered at batch {}, got {b}",
            self.arch.train_batch
        );
        ensure!(y.shape.first() == Some(&b), "label batch mismatch");

        let (loss, acc, mut grads) = self.loss_and_grads(
            params,
            masks,
            qbw,
            qba,
            x,
            y,
            tlogits,
            kd_alpha,
            kd_tau,
            [exit_w.data[0], exit_w.data[1]],
            wd,
            scratch,
        )?;

        // Fused SGD-with-momentum update: m' = mu*m + g; p' = p - lr*m'.
        // m' is written into the gradient buffers (which become the new
        // momenta outputs) and p' straight into an arena buffer — the old
        // per-step `(*params[i]).clone()` is gone, and the arithmetic
        // (p - lr*m' element-wise) is unchanged, so results are
        // bit-identical.
        let mut out = Vec::with_capacity(2 * np + 2);
        let mut new_momenta = Vec::with_capacity(np);
        for i in 0..np {
            let g = &mut grads[i];
            for (gv, &mv) in g.data.iter_mut().zip(&momenta[i].data) {
                *gv += mu * mv;
            }
            let mut p = scratch.take_full(params[i].len());
            for ((po, &pv), &mv) in p.iter_mut().zip(&params[i].data).zip(&g.data) {
                *po = pv - lr * mv;
            }
            out.push(Tensor::new(params[i].shape.clone(), p));
            new_momenta.push(std::mem::replace(g, Tensor::zeros(&[0])));
        }
        out.extend(new_momenta);
        out.push(Tensor::scalar(loss));
        out.push(Tensor::scalar(acc));
        Ok(out)
    }

    /// Forward + loss + full backward.  Returns (loss, acc, d loss/d param)
    /// with the weight-decay term already folded in.  Factored out of
    /// [`RefNet::train_step`] so the gradient-check test can compare the
    /// analytic gradients against finite differences of the loss.
    #[allow(clippy::too_many_arguments)]
    fn loss_and_grads(
        &self,
        params: &[&Tensor],
        masks: &[&Tensor],
        qbw: f32,
        qba: f32,
        x: &Tensor,
        y: &Tensor,
        tlogits: &Tensor,
        kd_alpha: f32,
        kd_tau: f32,
        exit_w: [f32; 2],
        wd: f32,
        scratch: &mut Scratch,
    ) -> Result<(f32, f32, Vec<Tensor>)> {
        let nc = self.arch.num_classes;
        let b = *x.shape.first().unwrap_or(&0);
        ensure!(
            y.rank() == 2 && y.shape[1] >= nc,
            "one-hot labels need >= {nc} columns, got {:?}",
            y.shape
        );
        ensure!(
            tlogits.shape == vec![b, nc],
            "teacher logits shape {:?}, want [{b}, {nc}]",
            tlogits.shape
        );

        // ---- forward (with traces) ----
        let (h1, tr1) = self.forward_segment(0, params, masks, qbw, qba, x, true, scratch)?;
        let (e1, tr_e1) = self.exit_forward(self.exit1, &h1, params, qbw, qba, true, scratch)?;
        let (h2, tr2) = self.forward_segment(1, params, masks, qbw, qba, &h1, true, scratch)?;
        let (e2, tr_e2) = self.exit_forward(self.exit2, &h2, params, qbw, qba, true, scratch)?;
        let (logits, tr3) = self.forward_segment(2, params, masks, qbw, qba, &h2, true, scratch)?;

        // ---- loss + logit cotangents ----
        let (ce, d_ce) = cross_entropy(&logits, y, nc, 1.0 - kd_alpha);
        let (kd, d_kd) = kd_loss(&logits, tlogits, kd_tau, kd_alpha);
        let (ce1, d_e1) = cross_entropy(&e1, y, nc, exit_w[0]);
        let (ce2, d_e2) = cross_entropy(&e2, y, nc, exit_w[1]);
        let l2: f32 = params
            .iter()
            .step_by(2)
            .map(|p| p.data.iter().map(|v| v * v).sum::<f32>())
            .sum();
        let loss = (1.0 - kd_alpha) * ce + kd_alpha * kd
            + exit_w[0] * ce1
            + exit_w[1] * ce2
            + wd * l2;
        let acc = accuracy(&logits, y, nc);

        // ---- backward (consumes the traces; buffers return to the arena) ----
        let mut grads: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::new(p.shape.clone(), scratch.take(p.len())))
            .collect();
        let mut d_logits = Tensor::new(vec![b, nc], scratch.take(b * nc));
        if let Some(d) = &d_ce {
            kernels::add_assign(&mut d_logits, d);
        }
        if let Some(d) = &d_kd {
            kernels::add_assign(&mut d_logits, d);
        }
        // seg3: reverse-topo walk from the classifier back to h2
        // (backward_segment consumes the terminal cotangent).
        let mut g = self.backward_segment(2, tr3, d_logits, masks, &mut grads, scratch);
        // exit2 contributes at h2.
        if let (Some(tr), Some(d)) = (tr_e2, &d_e2) {
            let ge = self.dense_backward(tr, d, &mut grads, scratch);
            kernels::add_assign(&mut g, &ge);
            scratch.recycle_tensor(ge);
        }
        let mut g = self.backward_segment(1, tr2, g, masks, &mut grads, scratch);
        // exit1 contributes at h1.
        if let (Some(tr), Some(d)) = (tr_e1, &d_e1) {
            let ge = self.dense_backward(tr, d, &mut grads, scratch);
            kernels::add_assign(&mut g, &ge);
            scratch.recycle_tensor(ge);
        }
        let g = self.backward_segment(0, tr1, g, masks, &mut grads, scratch);
        // g is now d loss / d x — discarded into the arena.
        scratch.recycle_tensor(g);

        // Weight decay: d(wd * Σ‖W‖²)/dW = 2·wd·W, weights only.
        if wd != 0.0 {
            for i in (0..grads.len()).step_by(2) {
                for (gv, &pv) in grads[i].data.iter_mut().zip(&params[i].data) {
                    *gv += 2.0 * wd * pv;
                }
            }
        }

        // Retire the forward/cotangent intermediates.
        for t in [h1, h2, logits, e1, e2] {
            scratch.recycle_tensor(t);
        }
        for d in [d_ce, d_kd, d_e1, d_e2].into_iter().flatten() {
            scratch.recycle_tensor(d);
        }
        Ok((loss, acc, grads))
    }

    /// Backward through one segment: reverse canonical order over the
    /// recorded traces, each node's cotangent fully fan-in-accumulated
    /// (in reverse-topological consumer order — fixed, deterministic)
    /// before the node itself runs.  Consumes `g_out` (the cotangent at
    /// the segment terminal) and returns the cotangent at the segment's
    /// stage input.
    fn backward_segment(
        &self,
        seg: usize,
        traces: Vec<(usize, NodeTrace)>,
        g_out: Tensor,
        masks: &[&Tensor],
        grads: &mut [Tensor],
        scratch: &mut Scratch,
    ) -> Tensor {
        let d = &self.dag;
        if d.seg_range(seg).is_empty() {
            // Empty segment forwarded its input unchanged — identity VJP.
            return g_out;
        }
        let term = d.terminal[seg].expect("non-empty segment has a terminal");
        let n = d.nodes.len();
        let mut node_g: Vec<Option<Tensor>> = (0..n).map(|_| None).collect();
        node_g[term] = Some(g_out);
        let mut g_in: Option<Tensor> = None;
        for (ni, tr) in traces.into_iter().rev() {
            let g = node_g[ni].take().expect("consumer cotangents accumulated");
            match tr {
                NodeTrace::Conv(tr) => {
                    let r = d.nodes[ni].inputs[0];
                    let gx = self.conv_backward(tr, g, masks, grads, scratch);
                    route_grad(&mut node_g, &mut g_in, r, gx, scratch);
                }
                NodeTrace::Dense(tr) => {
                    let r = d.nodes[ni].inputs[0];
                    let gx = self.dense_backward(tr, &g, grads, scratch);
                    scratch.recycle_tensor(g);
                    route_grad(&mut node_g, &mut g_in, r, gx, scratch);
                }
                NodeTrace::Join { relu_out, out_mask } => {
                    // mask -> act_quant (STE) -> relu gate; then d(a+b)
                    // hands the same gated cotangent to both operands.
                    let mut g = g;
                    if out_mask >= 0 {
                        kernels::mul_channel_mask(&mut g, &masks[out_mask as usize].data);
                    }
                    for (gv, &ov) in g.data.iter_mut().zip(&relu_out.data) {
                        if ov <= 0.0 {
                            *gv = 0.0;
                        }
                    }
                    scratch.recycle_tensor(relu_out);
                    let (ra, rb) = (d.nodes[ni].inputs[0], d.nodes[ni].inputs[1]);
                    let ga = {
                        let mut buf = scratch.take_full(g.len());
                        buf.copy_from_slice(&g.data);
                        Tensor::new(g.shape.clone(), buf)
                    };
                    route_grad(&mut node_g, &mut g_in, ra, ga, scratch);
                    route_grad(&mut node_g, &mut g_in, rb, g, scratch);
                }
                NodeTrace::Output { out_mask } => {
                    // mask -> act_quant (STE); no relu in the unary path.
                    let mut g = g;
                    if out_mask >= 0 {
                        kernels::mul_channel_mask(&mut g, &masks[out_mask as usize].data);
                    }
                    route_grad(&mut node_g, &mut g_in, d.nodes[ni].inputs[0], g, scratch);
                }
            }
        }
        g_in.expect("segment consumes its stage input")
    }

    /// Backward through one dense head (straight-through quantizers, the
    /// `qmatmul` VJP: cotangents against the *quantized* operands).
    /// Accumulates dW/db, retires the trace, and returns the gradient at
    /// the 4-D input feature.
    fn dense_backward(
        &self,
        tr: DenseTrace,
        g: &Tensor,
        grads: &mut [Tensor],
        scratch: &mut Scratch,
    ) -> Tensor {
        let li = tr.li;
        let (m, n) = (g.shape[0], g.shape[1]);
        let k = tr.aq.shape[1];
        // db = column sums of g.
        for row in g.data.chunks_exact(n) {
            for (dbv, &gv) in grads[2 * li + 1].data.iter_mut().zip(row) {
                *dbv += gv;
            }
        }
        // dW[k, n] += aqᵀ g — rows ascending, no zero-skip (canonical).
        let dw = &mut grads[2 * li].data;
        for mi in 0..m {
            let arow = &tr.aq.data[mi * k..(mi + 1) * k];
            let grow = &g.data[mi * n..(mi + 1) * n];
            for (ki, &av) in arow.iter().enumerate() {
                let dwrow = &mut dw[ki * n..(ki + 1) * n];
                for (dwv, &gv) in dwrow.iter_mut().zip(grow) {
                    *dwv += av * gv;
                }
            }
        }
        // da = g wqᵀ (canonical lane order per dot), then GAP backward
        // (uniform 1/(h·w) broadcast).
        let (h, w) = tr.hw;
        let scale = 1.0 / (h * w) as f32;
        let hw = h * w;
        let mut dfeat = scratch.take(tr.feat_shape.iter().product());
        for mi in 0..m {
            let grow = &g.data[mi * n..(mi + 1) * n];
            for ki in 0..k {
                let wrow = &tr.wq.data[ki * n..(ki + 1) * n];
                let dv = simd::dot(wrow, grow) * scale;
                // Broadcast to every spatial position of channel ki.
                for p in 0..hw {
                    dfeat[(mi * hw + p) * k + ki] += dv;
                }
            }
        }
        let out = Tensor::new(tr.feat_shape, dfeat);
        scratch.recycle_tensor(tr.aq);
        scratch.recycle_tensor(tr.wq);
        out
    }

    /// Backward through one conv pipeline: act_quant (STE) -> relu ->
    /// live-RMS norm -> mask -> conv -> pools.  Accumulates dW/db,
    /// retires the trace, and returns the gradient at the layer's
    /// (pre-pool) input.
    fn conv_backward(
        &self,
        tr: ConvTrace,
        g_out: Tensor,
        masks: &[&Tensor],
        grads: &mut [Tensor],
        scratch: &mut Scratch,
    ) -> Tensor {
        let l = &self.arch.layers[tr.li];
        // act_quant: straight-through.
        let mut g = g_out;
        // relu: pass where the (pre-quant) activation was positive.
        // `act: false` layers recorded no gate — their pipeline stopped
        // at the norm, so the cotangent passes through untouched.
        if let Some(nr) = &tr.normed_relu {
            for (gv, &ov) in g.data.iter_mut().zip(&nr.data) {
                if ov <= 0.0 {
                    *gv = 0.0;
                }
            }
        }
        // live-RMS norm backward.
        let g2 = kernels::rmsnorm_backward(&g, &tr.masked, &tr.rs, tr.d, scratch);
        scratch.recycle_tensor(g);
        let mut g = g2;
        // mask: dead channels carry no gradient.
        if l.out_mask >= 0 {
            kernels::mul_channel_mask(&mut g, &masks[l.out_mask as usize].data);
        }
        // conv backward (w.r.t. the quantized weights; straight-through to
        // the raw weights, matching the L1 kernels' STE).
        let s = l.stride.max(1);
        let cg = match l.kind {
            LayerKind::Conv => {
                kernels::conv2d_backward(&tr.x, &tr.wq, &g, s, self.threads, scratch)
            }
            LayerKind::DwConv => {
                kernels::dwconv2d_backward(&tr.x, &tr.wq, &g, s, self.threads, scratch)
            }
            LayerKind::Dense => unreachable!(),
        };
        scratch.recycle_tensor(g);
        for (dwv, &gv) in grads[2 * tr.li].data.iter_mut().zip(&cg.dw) {
            *dwv += gv;
        }
        for (dbv, &gv) in grads[2 * tr.li + 1].data.iter_mut().zip(&cg.db) {
            *dbv += gv;
        }
        scratch.recycle(cg.dw);
        scratch.recycle(cg.db);
        // pools backward, innermost first.
        let mut dx = cg.dx;
        let mut shape = tr.x.shape.clone();
        for p in tr.pools.into_iter().rev() {
            let mut up = scratch.take(p.in_shape.iter().product());
            for (gi, &v) in dx.iter().enumerate() {
                up[p.idx[gi] as usize] += v;
            }
            scratch.recycle(dx);
            scratch.recycle_u32(p.idx);
            dx = up;
            shape = p.in_shape;
        }
        scratch.recycle_tensor(tr.x);
        scratch.recycle_tensor(tr.wq);
        scratch.recycle_tensor(tr.masked);
        if let Some(nr) = tr.normed_relu {
            scratch.recycle_tensor(nr);
        }
        Tensor::new(shape, dx)
    }
}

// ---------------------------------------------------------------------------
// Traces
// ---------------------------------------------------------------------------

struct PoolTrace {
    /// Flat input index each output element drew from (gradient route).
    idx: Vec<u32>,
    in_shape: Vec<usize>,
}

struct ConvTrace {
    li: usize,
    pools: Vec<PoolTrace>,
    /// Conv input (post pools).
    x: Tensor,
    wq: Tensor,
    /// Post bias+mask — the RMS-norm input.
    masked: Tensor,
    /// Per-sample rsqrt factors and the live-channel divisor.
    rs: Vec<f32>,
    d: f32,
    /// Post-relu, pre-quant (the relu gradient gate); `None` for
    /// `act: false` layers, whose pipeline stops at the norm.
    normed_relu: Option<Tensor>,
}

struct DenseTrace {
    li: usize,
    feat_shape: Vec<usize>,
    hw: (usize, usize),
    /// act_quant(GAP(feat)) — the quantized matmul LHS.
    aq: Tensor,
    wq: Tensor,
}

/// One recorded forward step of the DAG walk, keyed by node id in
/// [`RefNet::forward_segment`]'s trace list (execution order; the
/// backward pass walks it in exact reverse).
enum NodeTrace {
    Conv(ConvTrace),
    Dense(DenseTrace),
    /// Residual join: the post-relu pre-quant values gate the relu VJP.
    Join { relu_out: Tensor, out_mask: i64 },
    /// Unary terminal: mask/STE only — no relu, nothing to record.
    Output { out_mask: i64 },
}

// ---------------------------------------------------------------------------
// Scalars & losses (fixed-order f32 loops; cheap relative to the kernels)
// ---------------------------------------------------------------------------

fn scalar(t: &Tensor, what: &str) -> Result<f32> {
    ensure!(t.len() == 1, "{what} must be a scalar, got shape {:?}", t.shape);
    Ok(t.data[0])
}

fn log_softmax_row(row: &[f32], out: &mut [f32]) {
    let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut lse = 0.0f32;
    for &v in row {
        lse += (v - m).exp();
    }
    let lse = lse.ln();
    for (o, &v) in out.iter_mut().zip(row) {
        *o = v - m - lse;
    }
}

/// Mean CE of logits [B, nc] against one-hot labels (first `nc` columns
/// of `y`).  Returns (ce, coeff·dce/dlogits); the gradient is skipped
/// when `coeff == 0` (the loss term still contributes its value).
fn cross_entropy(logits: &Tensor, y: &Tensor, nc: usize, coeff: f32) -> (f32, Option<Tensor>) {
    let b = logits.shape[0];
    let ycols = y.shape[1];
    let mut ls = vec![0.0f32; nc];
    let mut ce = 0.0f32;
    let mut grad = (coeff != 0.0).then(|| vec![0.0f32; b * nc]);
    for bi in 0..b {
        let row = &logits.data[bi * nc..(bi + 1) * nc];
        let yrow = &y.data[bi * ycols..bi * ycols + nc];
        log_softmax_row(row, &mut ls);
        for (l, &yv) in ls.iter().zip(yrow) {
            ce -= yv * l;
        }
        if let Some(g) = &mut grad {
            let grow = &mut g[bi * nc..(bi + 1) * nc];
            for ((gv, &l), &yv) in grow.iter_mut().zip(&ls).zip(yrow) {
                *gv = coeff * (l.exp() - yv) / b as f32;
            }
        }
    }
    (ce / b as f32, grad.map(|g| Tensor::new(vec![b, nc], g)))
}

/// Hinton KD: tau² · mean_b Σ_c softmax(t/τ)·(lsm(t/τ) − lsm(s/τ)).
/// Returns (kd, coeff·dkd/ds) with dkd/ds = τ·(softmax(s/τ) − softmax(t/τ))/B.
fn kd_loss(logits: &Tensor, tlog: &Tensor, tau: f32, coeff: f32) -> (f32, Option<Tensor>) {
    let (b, nc) = (logits.shape[0], logits.shape[1]);
    let tau = if tau > 0.0 { tau } else { 1.0 };
    let mut ls_s = vec![0.0f32; nc];
    let mut ls_t = vec![0.0f32; nc];
    let mut srow = vec![0.0f32; nc];
    let mut trow = vec![0.0f32; nc];
    let mut kd = 0.0f32;
    let mut grad = (coeff != 0.0).then(|| vec![0.0f32; b * nc]);
    for bi in 0..b {
        for c in 0..nc {
            srow[c] = logits.data[bi * nc + c] / tau;
            trow[c] = tlog.data[bi * nc + c] / tau;
        }
        log_softmax_row(&srow, &mut ls_s);
        log_softmax_row(&trow, &mut ls_t);
        for c in 0..nc {
            let t = ls_t[c].exp();
            kd += t * (ls_t[c] - ls_s[c]);
        }
        if let Some(g) = &mut grad {
            let grow = &mut g[bi * nc..(bi + 1) * nc];
            for (c, gv) in grow.iter_mut().enumerate() {
                let p = ls_s[c].exp();
                let t = ls_t[c].exp();
                *gv = coeff * tau * (p - t) / b as f32;
            }
        }
    }
    (tau * tau * kd / b as f32, grad.map(|g| Tensor::new(vec![b, nc], g)))
}

/// Mean top-1 agreement between logits and one-hot labels (first `nc`
/// columns), under the repo's one shared argmax rule
/// (`tensor::argmax_slice`: total over every f32 bit pattern, last
/// maximum on ties — NaN-safe like every other accuracy in the crate).
fn accuracy(logits: &Tensor, y: &Tensor, nc: usize) -> f32 {
    let b = logits.shape[0];
    let ycols = y.shape[1];
    let mut correct = 0usize;
    for r in 0..b {
        let pr = crate::tensor::argmax_slice(&logits.data[r * nc..(r + 1) * nc]);
        let yr = crate::tensor::argmax_slice(&y.data[r * ycols..r * ycols + nc]);
        correct += (pr == yr) as usize;
    }
    correct as f32 / b.max(1) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{JoinDesc, LayerDesc, MaskSlot};
    use std::collections::BTreeMap;

    fn layer(
        name: &str,
        kind: LayerKind,
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        hout: usize,
        out_mask: i64,
        segment: &str,
    ) -> LayerDesc {
        LayerDesc {
            name: name.into(),
            kind,
            k,
            cin,
            cout,
            stride,
            hout,
            wout: hout,
            in_mask: -1,
            out_mask,
            segment: segment.into(),
            input: String::new(),
            act: true,
        }
    }

    /// `layer` with an explicit producer edge and activation flag (the
    /// DAG-manifest spelling).
    #[allow(clippy::too_many_arguments)]
    fn dlayer(
        name: &str,
        kind: LayerKind,
        k: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        hout: usize,
        out_mask: i64,
        segment: &str,
        input: &str,
        act: bool,
    ) -> LayerDesc {
        let mut l = layer(name, kind, k, cin, cout, stride, hout, out_mask, segment);
        l.input = input.into();
        l.act = act;
        l
    }

    /// Tiny feed-forward arch: conv(2->3) @4x4 -> dense(3->4), one exit
    /// head after seg1.  All graph tags declared.
    fn tiny_arch() -> Arc<ArchManifest> {
        let layers = vec![
            layer("c1", LayerKind::Conv, 3, 2, 3, 1, 4, 0, "seg1"),
            layer("fc", LayerKind::Dense, 1, 3, 4, 1, 1, -1, "seg3"),
            layer("x1", LayerKind::Dense, 1, 3, 4, 1, 1, -1, "exit1"),
        ];
        let mut graphs = BTreeMap::new();
        for tag in ["init", "train", "eval", "stage1", "stage2", "stage3"] {
            graphs.insert(tag.to_string(), format!("ref://tiny/{tag}"));
        }
        Arc::new(ArchManifest {
            name: "tiny".into(),
            num_classes: 4,
            layers,
            mask_slots: vec![MaskSlot { name: "m0".into(), channels: 3 }],
            param_shapes: vec![
                vec![3, 3, 2, 3],
                vec![3],
                vec![3, 4],
                vec![4],
                vec![3, 4],
                vec![4],
            ],
            graphs,
            train_batch: 2,
            eval_batch: 2,
            stage_batch: 1,
            stage_batches: vec![1],
            stage_h1_shape: vec![1, 4, 4, 3],
            stage_h2_shape: vec![1, 4, 4, 3],
            joins: Vec::new(),
        })
    }

    fn det_tensor(shape: &[usize], salt: u64) -> Tensor {
        let mut rng = crate::util::rng::Rng::new(0x5eed ^ salt);
        let data = (0..shape.iter().product::<usize>()).map(|_| rng.normal() * 0.5).collect();
        Tensor::new(shape.to_vec(), data)
    }

    fn train_graph(threads: usize) -> RefGraph {
        RefGraph {
            net: RefNet::compile(tiny_arch(), threads).unwrap(),
            kind: GraphKind::Train,
            name: "t".into(),
            stats: Arc::new(StatsCell::default()),
            scratch: Mutex::new(Scratch::default()),
        }
    }

    #[test]
    fn ref_graph_tags_parse() {
        assert_eq!(GraphKind::parse("init"), Some(GraphKind::Init));
        assert_eq!(GraphKind::parse("train"), Some(GraphKind::Train));
        assert_eq!(GraphKind::parse("eval"), Some(GraphKind::Eval));
        assert_eq!(GraphKind::parse("stage1"), Some(GraphKind::Stage { stage: 1, batch: 1 }));
        assert_eq!(GraphKind::parse("stage3_b8"), Some(GraphKind::Stage { stage: 3, batch: 8 }));
        assert_eq!(GraphKind::parse("stage4"), None);
        assert_eq!(GraphKind::parse("stage1_b0"), None);
        assert_eq!(GraphKind::parse("bogus"), None);
    }

    /// Boilerplate around a layer list: consistent param shapes, no
    /// graphs — enough to compile a `RefNet` directly.
    fn arch_of(
        name: &str,
        layers: Vec<LayerDesc>,
        joins: Vec<JoinDesc>,
        mask_slots: Vec<MaskSlot>,
    ) -> Arc<ArchManifest> {
        let param_shapes = layers
            .iter()
            .flat_map(|l| {
                let w = match l.kind {
                    LayerKind::Dense => vec![l.cin, l.cout],
                    LayerKind::DwConv => vec![l.k, l.k, 1, l.cout],
                    LayerKind::Conv => vec![l.k, l.k, l.cin, l.cout],
                };
                [w, vec![l.cout]]
            })
            .collect();
        Arc::new(ArchManifest {
            name: name.into(),
            num_classes: 4,
            layers,
            mask_slots,
            param_shapes,
            graphs: BTreeMap::new(),
            train_batch: 2,
            eval_batch: 2,
            stage_batch: 1,
            stage_batches: vec![1],
            stage_h1_shape: vec![],
            stage_h2_shape: vec![],
            joins,
        })
    }

    /// Small residual block: stem -> a1 -> a2 (act=false), joined with a
    /// skip (identity when the widths agree, 1x1 projection otherwise),
    /// then a dense head — fan-out 2 at the stem, one skip join: the
    /// minimal topology the old chain walker could not express.
    fn residual_arch(c1: usize, c2: usize, masked: bool) -> Arc<ArchManifest> {
        let mut layers = vec![
            dlayer("stem", LayerKind::Conv, 3, 3, c1, 1, 8, -1, "seg1", "@input", true),
            dlayer("a1", LayerKind::Conv, 3, c1, c2, 1, 8, -1, "seg1", "stem", true),
            dlayer("a2", LayerKind::Conv, 3, c2, c2, 1, 8, -1, "seg1", "a1", false),
        ];
        let skip = if c1 == c2 {
            "stem".to_string()
        } else {
            layers
                .push(dlayer("proj", LayerKind::Conv, 1, c1, c2, 1, 8, -1, "seg1", "stem", false));
            "proj".to_string()
        };
        layers.push(dlayer("fc", LayerKind::Dense, 1, c2, 4, 1, 1, -1, "seg3", "j", true));
        let joins = vec![JoinDesc {
            name: "j".into(),
            a: "a2".into(),
            b: Some(skip),
            out_mask: if masked { 0 } else { -1 },
            segment: "seg1".into(),
        }];
        let mask_slots =
            if masked { vec![MaskSlot { name: "mj".into(), channels: c2 }] } else { vec![] };
        arch_of("resblock", layers, joins, mask_slots)
    }

    /// Recompute-everything reference walker: every producer is
    /// recomputed for every consumer — no sharing, no refcounts, no
    /// buffer hand-off (exponential in fan-out; fine at this size).
    /// Bitwise agreement with `forward_segment` pins that the executor's
    /// buffer machinery never perturbs a value.
    #[allow(clippy::too_many_arguments)]
    fn naive_value(
        net: &RefNet,
        ni: usize,
        params: &[&Tensor],
        masks: &[&Tensor],
        qbw: f32,
        qba: f32,
        input: &Tensor,
        scratch: &mut Scratch,
    ) -> Tensor {
        let op = net.dag.nodes[ni].op;
        let ins: Vec<dag::NodeRef> = net.dag.nodes[ni].inputs.clone();
        let arg = |r: dag::NodeRef, scratch: &mut Scratch| match r {
            dag::NodeRef::Input => input.clone(),
            dag::NodeRef::Node(p) => {
                naive_value(net, p, params, masks, qbw, qba, input, scratch)
            }
        };
        match op {
            dag::NodeOp::Conv { li } => {
                let xin = arg(ins[0], scratch);
                net.conv_forward(li, Cow::Owned(xin), params, masks, qbw, qba, false, scratch)
                    .unwrap()
                    .0
            }
            dag::NodeOp::Dense { li } => {
                let xin = arg(ins[0], scratch);
                let (out, _) =
                    net.dense_forward(li, &xin, params, qbw, qba, false, scratch).unwrap();
                out
            }
            dag::NodeOp::Join { out_mask } => {
                let a = arg(ins[0], scratch);
                let b = arg(ins[1], scratch);
                let z: Vec<f32> = a.data.iter().zip(&b.data).map(|(&av, &bv)| av + bv).collect();
                let mut t = Tensor::new(a.shape.clone(), z);
                kernels::relu_inplace(&mut t);
                kernels::act_quant_inplace(&mut t, qba);
                if out_mask >= 0 {
                    kernels::mul_channel_mask(&mut t, &masks[out_mask as usize].data);
                }
                t
            }
            dag::NodeOp::Output { out_mask } => {
                let mut t = arg(ins[0], scratch);
                kernels::act_quant_inplace(&mut t, qba);
                if out_mask >= 0 {
                    kernels::mul_channel_mask(&mut t, &masks[out_mask as usize].data);
                }
                t
            }
        }
    }

    #[test]
    fn ref_load_error_names_shape_mismatched_edge() {
        // A layer whose cin does not match its producer's cout must be
        // rejected at load time with a diagnostic naming the edge —
        // both in legacy chain mode and with explicit edges.
        let layers = vec![
            layer("c1", LayerKind::Conv, 3, 3, 8, 1, 8, -1, "seg1"),
            layer("proj", LayerKind::Conv, 1, 3, 8, 1, 8, -1, "seg2"),
            layer("fc", LayerKind::Dense, 1, 8, 4, 1, 1, -1, "seg3"),
        ];
        let err = RefNet::compile(arch_of("resnetish", layers, vec![], vec![]), 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("edge `c1 -> proj`"), "{msg}");
        assert!(msg.contains("cin 3") && msg.contains("cout 8"), "{msg}");

        let layers = vec![
            dlayer("c1", LayerKind::Conv, 3, 3, 8, 1, 8, -1, "seg1", "@input", true),
            dlayer("c2", LayerKind::Conv, 3, 6, 8, 1, 8, -1, "seg1", "c1", true),
            dlayer("fc", LayerKind::Dense, 1, 8, 4, 1, 1, -1, "seg3", "c2", true),
        ];
        let err = RefNet::compile(arch_of("edgy", layers, vec![], vec![]), 1).unwrap_err();
        assert!(format!("{err:#}").contains("edge `c1 -> c2`"), "{err:#}");
    }

    #[test]
    fn ref_load_error_names_cyclic_edge() {
        // Two convs consuming each other can never be scheduled; the
        // diagnostic must name a concrete unsatisfiable edge.
        let layers = vec![
            dlayer("a", LayerKind::Conv, 3, 4, 4, 1, 8, -1, "seg1", "b", true),
            dlayer("b", LayerKind::Conv, 3, 4, 4, 1, 8, -1, "seg1", "a", true),
            dlayer("fc", LayerKind::Dense, 1, 4, 4, 1, 1, -1, "seg3", "b", true),
        ];
        let err = RefNet::compile(arch_of("cyclic", layers, vec![], vec![]), 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("cycle"), "{msg}");
        assert!(msg.contains("edge `b -> a`"), "{msg}");
    }

    #[test]
    fn ref_load_error_names_disagreeing_join_operands() {
        // Join operands with different widths name both offenders.
        let layers = vec![
            dlayer("stem", LayerKind::Conv, 3, 3, 4, 1, 8, -1, "seg1", "@input", true),
            dlayer("a1", LayerKind::Conv, 3, 4, 6, 1, 8, -1, "seg1", "stem", false),
            dlayer("fc", LayerKind::Dense, 1, 6, 4, 1, 1, -1, "seg3", "j1", true),
        ];
        let joins = vec![JoinDesc {
            name: "j1".into(),
            a: "a1".into(),
            b: Some("stem".into()),
            out_mask: -1,
            segment: "seg1".into(),
        }];
        let err = RefNet::compile(arch_of("mismatch", layers, joins, vec![]), 1).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("join `j1`"), "{msg}");
        assert!(msg.contains("`a1` (cout 6)") && msg.contains("`stem` (cout 4)"), "{msg}");
    }

    /// Deterministic channel mask for the join slot: roughly one in
    /// three channels pruned, never all of them.
    fn join_mask(c: usize, salt: u64) -> Tensor {
        let data = (0..c)
            .map(|i| if (i as u64 + salt) % 3 == 0 && c > 1 { 0.0 } else { 1.0 })
            .collect();
        Tensor::new(vec![c], data)
    }

    #[test]
    fn ref_dag_forward_matches_naive_walker() {
        // Random small residual DAGs (fan-out 2 at the stem, one skip
        // join, identity or 1x1 projection): the refcounted executor
        // must agree bitwise with the recompute-everything walker, at
        // fp32 and under weight+activation fake-quant.
        crate::util::prop::check(
            "ref_dag_forward_matches_naive_walker",
            8,
            |rng| (rng.below(3), rng.below(3), rng.next_u64()),
            |&(w1, w2, salt)| {
                // Map shrink-safe offsets to valid widths: w1 == w2
                // exercises the identity skip, otherwise a projection.
                let (c1, c2) = (3 + w1, 3 + w2);
                let masked = salt % 2 == 1;
                let arch = residual_arch(c1, c2, masked);
                let net = RefNet::compile(arch.clone(), 1)
                    .map_err(|e| format!("compile: {e:#}"))?;
                let params: Vec<Tensor> = arch
                    .param_shapes
                    .iter()
                    .enumerate()
                    .map(|(i, s)| det_tensor(s, salt ^ (i as u64)))
                    .collect();
                let pref: Vec<&Tensor> = params.iter().collect();
                let masks = if masked { vec![join_mask(c2, salt)] } else { vec![] };
                let mref: Vec<&Tensor> = masks.iter().collect();
                let x = det_tensor(&[2, 8, 8, 3], salt.wrapping_add(17));
                let mut sc = Scratch::default();
                for (qbw, qba) in [(0.0f32, 0.0f32), (4.0, 8.0)] {
                    let (h1, _) = net
                        .forward_segment(0, &pref, &mref, qbw, qba, &x, false, &mut sc)
                        .map_err(|e| format!("seg1 forward: {e:#}"))?;
                    let t0 = net.dag.terminal[0].expect("seg1 terminal");
                    let n1 = naive_value(&net, t0, &pref, &mref, qbw, qba, &x, &mut sc);
                    if h1.data != n1.data {
                        return Err(format!("seg1 diverged from naive walker (qb {qbw}/{qba})"));
                    }
                    let (logits, _) = net
                        .forward_segment(2, &pref, &mref, qbw, qba, &h1, false, &mut sc)
                        .map_err(|e| format!("seg3 forward: {e:#}"))?;
                    let t2 = net.dag.terminal[2].expect("seg3 terminal");
                    let n3 = naive_value(&net, t2, &pref, &mref, qbw, qba, &h1, &mut sc);
                    if logits.data != n3.data {
                        return Err(format!("seg3 diverged from naive walker (qb {qbw}/{qba})"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ref_dag_gradients_match_finite_differences() {
        // The backward fan-in through a skip join: one cotangent routed
        // to both operands, accumulated in canonical order.  Checked
        // against central differences for the identity-skip (masked)
        // and 1x1-projection (unmasked) shapes.
        for (c1, c2, masked) in [(4usize, 4usize, true), (3, 5, false)] {
            let arch = residual_arch(c1, c2, masked);
            let net = RefNet::compile(arch.clone(), 1).unwrap();
            let params: Vec<Tensor> = arch
                .param_shapes
                .iter()
                .enumerate()
                .map(|(i, s)| det_tensor(s, 60 + i as u64))
                .collect();
            let masks = if masked { vec![join_mask(c2, 1)] } else { vec![] };
            let mref: Vec<&Tensor> = masks.iter().collect();
            let x = det_tensor(&[2, 8, 8, 3], 200);
            let mut y = Tensor::zeros(&[2, 4]);
            y.data[1] = 1.0;
            y.data[4 + 2] = 1.0;
            let tlog = Tensor::zeros(&[2, 4]);
            let loss_of = |ps: &[Tensor]| -> f32 {
                let pref: Vec<&Tensor> = ps.iter().collect();
                let mut sc = Scratch::default();
                net.loss_and_grads(
                    &pref, &mref, 0.0, 0.0, &x, &y, &tlog, 0.0, 4.0, [0.0, 0.0], 0.0, &mut sc,
                )
                .unwrap()
                .0
            };
            let pref: Vec<&Tensor> = params.iter().collect();
            let mut sc = Scratch::default();
            let (_, _, grads) = net
                .loss_and_grads(
                    &pref, &mref, 0.0, 0.0, &x, &y, &tlog, 0.0, 4.0, [0.0, 0.0], 0.0, &mut sc,
                )
                .unwrap();
            for (pi, p) in params.iter().enumerate() {
                for probe in 0..3.min(p.len()) {
                    let ci = (probe * 13 + pi * 5) % p.len();
                    let eps = 5e-3f32;
                    let mut plus = params.clone();
                    plus[pi].data[ci] += eps;
                    let mut minus = params.clone();
                    minus[pi].data[ci] -= eps;
                    let numeric = (loss_of(&plus) - loss_of(&minus)) / (2.0 * eps);
                    let analytic = grads[pi].data[ci];
                    let tol = 2e-2f32.max(0.05 * numeric.abs());
                    assert!(
                        (numeric - analytic).abs() <= tol,
                        "dag grad mismatch at param {pi}[{ci}] (c1={c1}, c2={c2}, \
                         masked={masked}): analytic {analytic} vs numeric {numeric}"
                    );
                }
            }
        }
    }

    #[test]
    fn ref_dag_train_thread_count_invariance() {
        // Same loss and gradients, bit for bit, at 1/2/3 kernel threads
        // — the PR 5 contract carried over to residual topologies.
        let arch = residual_arch(3, 5, true);
        let params: Vec<Tensor> = arch
            .param_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| det_tensor(s, 80 + i as u64))
            .collect();
        let pref: Vec<&Tensor> = params.iter().collect();
        let masks = [join_mask(5, 2)];
        let mref: Vec<&Tensor> = masks.iter().collect();
        let x = det_tensor(&[3, 8, 8, 3], 300);
        let mut y = Tensor::zeros(&[3, 4]);
        y.data[0] = 1.0;
        y.data[4 + 1] = 1.0;
        y.data[8 + 3] = 1.0;
        let tlog = det_tensor(&[3, 4], 301);
        let mut base: Option<(f32, Vec<Tensor>, Tensor)> = None;
        for threads in [1usize, 2, 3] {
            let net = RefNet::compile(arch.clone(), threads).unwrap();
            let mut sc = Scratch::default();
            let (loss, _, grads) = net
                .loss_and_grads(
                    &pref, &mref, 0.0, 0.0, &x, &y, &tlog, 0.3, 2.0, [0.0, 0.0], 1e-4, &mut sc,
                )
                .unwrap();
            let (h1, _) = net.stage1(&pref, &mref, 0.0, 0.0, &x, &mut sc).unwrap();
            match &base {
                None => base = Some((loss, grads, h1)),
                Some((l0, g0, h0)) => {
                    assert_eq!(loss.to_bits(), l0.to_bits(), "loss differs at {threads} threads");
                    for (ga, gb) in grads.iter().zip(g0) {
                        assert_eq!(ga.data, gb.data, "grads differ at {threads} threads");
                    }
                    assert_eq!(h1.data, h0.data, "stage1 differs at {threads} threads");
                }
            }
        }
    }

    #[test]
    fn ref_eval_equals_stage_composition_bitwise() {
        let arch = tiny_arch();
        let net = RefNet::compile(arch.clone(), 1).unwrap();
        let params: Vec<Tensor> = arch
            .param_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| det_tensor(s, i as u64))
            .collect();
        let pref: Vec<&Tensor> = params.iter().collect();
        let masks = [Tensor::new(vec![3], vec![1.0, 0.0, 1.0])];
        let mref: Vec<&Tensor> = masks.iter().collect();
        let x = det_tensor(&[2, 8, 8, 2], 99);
        let mut sc = Scratch::default();
        for (qbw, qba) in [(0.0f32, 0.0f32), (4.0, 8.0)] {
            let (h1, e1) = net.stage1(&pref, &mref, qbw, qba, &x, &mut sc).unwrap();
            let (h2, e2) = net.stage2(&pref, &mref, qbw, qba, &h1, &mut sc).unwrap();
            let logits = net.stage3(&pref, &mref, qbw, qba, &h2, &mut sc).unwrap();
            // Masked channel never influences downstream values.
            assert!(h1.data.chunks_exact(3).all(|c| c[1] == 0.0));
            // eval is the same composition — bit-identical by construction.
            let graph = RefGraph {
                net: RefNet::compile(arch.clone(), 1).unwrap(),
                kind: GraphKind::Eval,
                name: "t".into(),
                stats: Arc::new(StatsCell::default()),
                scratch: Mutex::new(Scratch::default()),
            };
            let mut inputs: Vec<&Tensor> = pref.clone();
            inputs.extend(mref.iter().copied());
            let qbw_t = Tensor::scalar(qbw);
            let qba_t = Tensor::scalar(qba);
            inputs.push(&qbw_t);
            inputs.push(&qba_t);
            inputs.push(&x);
            let outs = graph.dispatch(&inputs).unwrap();
            assert_eq!(outs.len(), 3);
            assert_eq!(outs[0].data, logits.data);
            assert_eq!(outs[1].data, e1.data);
            assert_eq!(outs[2].data, e2.data);
        }
    }

    #[test]
    fn ref_train_gradients_match_finite_differences() {
        // The load-bearing test of the whole backward pass: analytic
        // gradients vs central differences of the loss, at fp32 (smooth
        // except relu/max kinks, which the fixed seed avoids measurably).
        let arch = tiny_arch();
        let net = RefNet::compile(arch.clone(), 1).unwrap();
        let params: Vec<Tensor> = arch
            .param_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| det_tensor(s, 7 + i as u64))
            .collect();
        let masks = [Tensor::new(vec![3], vec![1.0, 1.0, 0.0])];
        let mref: Vec<&Tensor> = masks.iter().collect();
        let x = det_tensor(&[2, 8, 8, 2], 123);
        let mut y = Tensor::zeros(&[2, 4]);
        y.data[1] = 1.0; // sample 0 -> class 1
        y.data[4 + 3] = 1.0; // sample 1 -> class 3
        let tlog = det_tensor(&[2, 4], 321);

        // Three loss configurations: plain CE, CE+exits+wd, CE+KD.
        let configs = [
            (0.0f32, 4.0f32, [0.0f32, 0.0f32], 0.0f32),
            (0.0, 4.0, [0.4, 0.0], 1e-3),
            (0.5, 2.0, [0.0, 0.0], 0.0),
        ];
        for (ka, tau, ew, wd) in configs {
            let loss_of = |ps: &[Tensor]| -> f32 {
                let pref: Vec<&Tensor> = ps.iter().collect();
                let mut sc = Scratch::default();
                net.loss_and_grads(&pref, &mref, 0.0, 0.0, &x, &y, &tlog, ka, tau, ew, wd, &mut sc)
                    .unwrap()
                    .0
            };
            let pref: Vec<&Tensor> = params.iter().collect();
            let mut sc = Scratch::default();
            let (_, _, grads) = net
                .loss_and_grads(&pref, &mref, 0.0, 0.0, &x, &y, &tlog, ka, tau, ew, wd, &mut sc)
                .unwrap();
            // Probe a spread of coordinates in every parameter tensor.
            for (pi, p) in params.iter().enumerate() {
                for probe in 0..3.min(p.len()) {
                    let ci = (probe * 13 + pi * 5) % p.len();
                    let eps = 5e-3f32;
                    let mut plus = params.clone();
                    plus[pi].data[ci] += eps;
                    let mut minus = params.clone();
                    minus[pi].data[ci] -= eps;
                    let numeric = (loss_of(&plus) - loss_of(&minus)) / (2.0 * eps);
                    let analytic = grads[pi].data[ci];
                    let tol = 2e-2f32.max(0.05 * numeric.abs());
                    assert!(
                        (numeric - analytic).abs() <= tol,
                        "grad mismatch at param {pi}[{ci}] (ka={ka}, ew={ew:?}, wd={wd}): \
                         analytic {analytic} vs numeric {numeric}"
                    );
                }
            }
        }
    }

    #[test]
    fn ref_train_step_is_deterministic_and_updates() {
        // Two dispatches on ONE graph: the second run draws every buffer
        // from the recycled arena, so this also pins "scratch reuse never
        // perturbs a value".
        let arch = tiny_arch();
        let graph = train_graph(1);
        let params: Vec<Tensor> = arch
            .param_shapes
            .iter()
            .enumerate()
            .map(|(i, s)| det_tensor(s, 40 + i as u64))
            .collect();
        let momenta: Vec<Tensor> =
            arch.param_shapes.iter().map(|s| Tensor::zeros(s)).collect();
        let x = det_tensor(&[2, 8, 8, 2], 55);
        let mut y = Tensor::zeros(&[2, 4]);
        y.data[0] = 1.0;
        y.data[4 + 2] = 1.0;
        let masks = [Tensor::ones(&[3])];
        let qbw = Tensor::scalar(0.0);
        let qba = Tensor::scalar(0.0);
        let tlog = Tensor::zeros(&[2, 4]);
        let ka = Tensor::scalar(0.0);
        let kt = Tensor::scalar(4.0);
        let ew = Tensor::from_vec(vec![0.0, 0.0]);
        let hp = Tensor::from_vec(vec![0.05, 0.9, 1e-4]);
        let mut inputs: Vec<&Tensor> = Vec::new();
        inputs.extend(params.iter());
        inputs.extend(momenta.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(masks.iter());
        inputs.push(&qbw);
        inputs.push(&qba);
        inputs.push(&tlog);
        inputs.push(&ka);
        inputs.push(&kt);
        inputs.push(&ew);
        inputs.push(&hp);

        let a = graph.dispatch(&inputs).unwrap();
        assert!(graph.scratch.lock().unwrap().shelved() > 0, "arena retired step buffers");
        let b = graph.dispatch(&inputs).unwrap();
        assert_eq!(a.len(), 2 * arch.num_params() + 2);
        for (ta, tb) in a.iter().zip(&b) {
            assert_eq!(ta.data, tb.data, "train step must be bit-deterministic");
        }
        let loss = a[a.len() - 2].data[0];
        assert!(loss.is_finite() && loss > 0.0);
        // Parameters moved (there is a gradient).
        assert_ne!(a[0].data, params[0].data);

        // Thread-count invariance at graph level: a fresh graph compiled
        // at a different kernel-thread budget produces the same bits.
        for threads in [2usize, 3] {
            let gt = train_graph(threads);
            let c = gt.dispatch(&inputs).unwrap();
            for (ta, tc) in a.iter().zip(&c) {
                assert_eq!(ta.data, tc.data, "thread count {threads} changed train results");
            }
        }
    }
}
