//! `coc` — Chain of Compression CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   info                         — manifest + platform summary
//!   train   --arch A --dataset D — train a base model, report accuracy
//!   chain   --seq DPQE ...       — run a compression chain end-to-end
//!   exp     <id>                 — regenerate a paper table/figure
//!   serve   --arch A ...         — early-exit serving loop demo
//!   toposort                     — measure pairwise orders, derive the law
//!
//! Common flags: --artifacts DIR (default artifacts), --out DIR (default
//! results), --scale smoke|default|paper, --seed N, --verbose.

use anyhow::{anyhow, Result};

use coc::chain::{stages, Chain};
use coc::data::DatasetKind;
use coc::exp::{self, ExpCtx};
use coc::metrics::Measurement;
use coc::order;
use coc::serve::Server;
use coc::sweep::Scale;
use coc::train::{self, TrainOpts};
use coc::util::cli::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from(args: &Args) -> Result<ExpCtx> {
    let scale = Scale::parse(args.get_or("scale", "default"))
        .ok_or_else(|| anyhow!("--scale must be smoke|default|paper"))?;
    ExpCtx::new(
        args.get_or("artifacts", coc::DEFAULT_ARTIFACTS),
        args.get_or("out", coc::DEFAULT_RESULTS),
        scale,
        args.get_u64("seed", 42)?,
        args.flag("verbose"),
    )
}

fn real_main() -> Result<()> {
    let args = Args::parse_env();
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("chain") => cmd_chain(&args),
        Some("exp") => {
            let ctx = ctx_from(&args)?;
            let id = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: coc exp <id> (fig6..fig15, table1..table5, toposort, all)"))?;
            exp::run(&ctx, id)
        }
        Some("toposort") => {
            let ctx = ctx_from(&args)?;
            exp::run(&ctx, "toposort")
        }
        Some("serve") => cmd_serve(&args),
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand `{o}`\n");
            }
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!("coc {} — Chain of Compression coordinator", coc::version());
    println!("usage: coc <info|train|chain|exp|serve|toposort> [flags]");
    println!("  coc exp all --scale default     # regenerate every table/figure");
    println!("  coc chain --seq DPQE --arch mini_resnet --dataset c10");
    println!("  coc serve --arch mini_resnet --requests 200 --threshold 0.8");
}

fn cmd_info(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    println!("platform: {}", ctx.engine.platform());
    println!("artifacts: {}", ctx.engine.artifacts_dir().display());
    for (name, arch) in &ctx.manifest.archs {
        let base = coc::models::Accountant::baseline_bitops(arch);
        let params: usize = arch.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        println!(
            "arch {name}: {} layers, {} mask slots, {params} params, baseline {base:.3e} BitOps",
            arch.layers.len(),
            arch.mask_slots.len()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let arch = args.get_or("arch", "mini_resnet");
    let kind = DatasetKind::parse(args.get_or("dataset", "c10"))
        .ok_or_else(|| anyhow!("--dataset must be c10|c100|svhn|cinic"))?;
    let (train_ds, test_ds) = ctx.datasets(kind);
    let arch_m = ctx.manifest.arch(arch)?;
    let mut st = train::init_state(&ctx.engine, arch_m, ctx.seed)?;
    let opts = TrainOpts {
        steps: args.get_usize("steps", ctx.scale.base_steps())?,
        lr: args.get_f32("lr", 0.05)?,
        seed: ctx.seed,
        log_every: if args.flag("verbose") { 20 } else { 0 },
        ..Default::default()
    };
    let log = train::train(&ctx.engine, &mut st, &train_ds, None, &opts)?;
    let acc = train::eval_accuracy(&ctx.engine, &st, &test_ds)?;
    println!(
        "trained {arch} on {} for {} steps: final loss {:.4}, test acc {:.2}%",
        kind.name(),
        opts.steps,
        log.final_loss(),
        acc * 100.0
    );
    Ok(())
}

fn cmd_chain(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let arch = args.get_or("arch", "mini_resnet");
    let kind = DatasetKind::parse(args.get_or("dataset", "c10"))
        .ok_or_else(|| anyhow!("--dataset must be c10|c100|svhn|cinic"))?;
    let seq = order::parse_sequence(args.get_or("seq", "DPQE"))?;
    let rung = args.get_usize("rung", 1)?;
    let ladder = ctx.scale.ladder();

    let (train_ds, test_ds) = ctx.datasets(kind);
    let base = ctx.base_model(arch, kind, &train_ds)?;
    let orig = train::eval_accuracy(&ctx.engine, &base, &test_ds)?;
    println!("base {arch}/{}: acc {:.2}%", kind.name(), orig * 100.0);

    let sctx = ctx.stage_ctx(&train_ds, &test_ds);
    let mut state = base.clone();
    let chain = exp::chain_for_sequence(&seq, rung.min(ladder - 1), ladder);
    let reports = chain.run(&mut state, &sctx)?;
    for r in &reports {
        println!(
            "  after {:<24} acc {:.2}%  BitOpsCR {:>8.1}x  CR {:>7.1}x",
            r.stage,
            r.measurement.accuracy * 100.0,
            r.measurement.bitops_cr,
            r.measurement.storage_cr
        );
    }
    let m = Measurement::take(&ctx.engine, &state, &test_ds)?;
    println!(
        "chain {}: acc {:.2}% ({:+.2}%)  BitOpsCR {:.1}x  CR {:.1}x",
        order::sequence_string(&seq),
        m.accuracy * 100.0,
        (m.accuracy - orig) * 100.0,
        m.bitops_cr,
        m.storage_cr
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let arch = args.get_or("arch", "mini_resnet");
    let kind = DatasetKind::parse(args.get_or("dataset", "c10"))
        .ok_or_else(|| anyhow!("--dataset must be c10|c100|svhn|cinic"))?;
    let threshold = args.get_f32("threshold", 0.8)?;
    let requests = args.get_usize("requests", 200)?;

    let (train_ds, test_ds) = ctx.datasets(kind);
    let mut state = ctx.base_model(arch, kind, &train_ds)?;
    // Ensure exits are trained before serving.
    let sctx = ctx.stage_ctx(&train_ds, &test_ds);
    let chain = Chain::new().push(Box::new(stages::EarlyExit {
        threshold,
        ..Default::default()
    }));
    chain.run(&mut state, &sctx)?;

    let server = Server::new(&ctx.engine, state)?;
    let rep = server.serve_dataset(&test_ds, requests, threshold, threshold)?;
    println!(
        "served {} requests: acc {:.2}%  exit1 {:.0}%  exit2 {:.0}%  p50 {:.0}µs  p95 {:.0}µs  {:.0} rps",
        rep.requests,
        rep.accuracy * 100.0,
        rep.p_exit1 * 100.0,
        rep.p_exit2 * 100.0,
        rep.latency_us.p50(),
        rep.latency_us.p95(),
        rep.throughput_rps
    );
    Ok(())
}
