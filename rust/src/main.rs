//! `coc` — Chain of Compression CLI (L3 leader entrypoint).
//!
//! Subcommands:
//!   info                         — manifest + platform summary
//!   train   --arch A --dataset D — train a base model, report accuracy
//!   chain   --seq DPQE ...       — run a compression chain end-to-end
//!   exp     <id>                 — regenerate a paper table/figure
//!   serve   --arch A ...         — early-exit serving loop demo
//!   serve-bench --workers N ...  — concurrent serving benchmark (queue +
//!                                  micro-batching + worker pool + loadgen)
//!   toposort                     — measure pairwise orders, derive the law
//!
//! Common flags: --artifacts DIR (default artifacts), --out DIR (default
//! results), --scale smoke|default|paper, --seed N, --verbose,
//! --backend pjrt|ref (ref = hermetic pure-rust interpreter, no
//! artifacts needed — falls back to the built-in mini_vgg manifest),
//! --ref-threads N (ref kernel thread budget; default available
//! parallelism, bit-identical results at every N),
//! --simd auto|scalar|sse2|avx2|neon (ref kernel ISA path; env
//! `COC_REF_SIMD`; every path produces identical bits).
//! Plan-executor flags (chain/exp/toposort): --jobs N runs independent
//! chain branches on N worker engines; --no-cache disables the
//! content-addressed stage cache under results/cache/; --lower packs
//! every plan leaf into its serve-ready CompressedModel (published as
//! `<node_id>.cmp` when caching).

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use coc::chain::{stages, Chain};
use coc::data::DatasetKind;
use coc::exp::{self, ExpCtx};
use coc::metrics::Measurement;
use coc::models::compressed::CompressedModel;
use coc::order;
use coc::runtime::BackendChoice;
use coc::serve::batcher::BatchPolicy;
use coc::serve::loadgen::{self, LoadMode, LoadOpts};
use coc::serve::slo::Slo;
use coc::serve::worker::{PoolOpts, WorkerPool};
use coc::serve::Server;
use coc::sweep::Scale;
use coc::train::{self, TrainOpts};
use coc::util::cli::Args;
use coc::util::json::{num, obj, s, Json};

fn main() {
    if let Err(e) = real_main() {
        coc::obs::log!(coc::obs::Level::Error, "error: {e:#}");
        std::process::exit(1);
    }
}

fn ctx_from(args: &Args) -> Result<ExpCtx> {
    let scale = Scale::parse(args.get_or("scale", "default"))
        .ok_or_else(|| anyhow!("--scale must be smoke|default|paper"))?;
    let backend = BackendChoice::parse(args.get_or("backend", "pjrt"))
        .ok_or_else(|| anyhow!("--backend must be pjrt|ref"))?;
    // --ref-threads: total kernel-thread budget for the ref backend
    // (default: COC_REF_THREADS or available parallelism).  Results are
    // bit-identical at every setting; worker pools (serve, plan --jobs)
    // split the budget so thread layers compose without oversubscription.
    let ref_threads =
        args.get_usize_min("ref-threads", coc::runtime::default_ref_threads(), 1)?;
    let mut ctx = ExpCtx::with_backend_threads(
        backend,
        args.get_or("artifacts", coc::DEFAULT_ARTIFACTS),
        args.get_or("out", coc::DEFAULT_RESULTS),
        scale,
        args.get_u64("seed", 42)?,
        args.flag("verbose"),
        ref_threads,
    )?;
    ctx.jobs = args.get_usize_min("jobs", 1, 1)?;
    ctx.cache = !args.flag("no-cache");
    // --lower: after a plan run, pack every leaf into its CompressedModel
    // (serve-ready sparse/int8 form) and publish `<node_id>.cmp` when
    // caching; also reports packed-vs-dense bytes per leaf.
    ctx.lower = args.flag("lower");
    Ok(ctx)
}

fn real_main() -> Result<()> {
    let args = Args::parse_env();
    // --trace-out PATH (any subcommand): record spans for the whole run
    // and export on the way out — `.jsonl` gets line-delimited events,
    // anything else the Chrome `trace_event` format (load it in
    // chrome://tracing or Perfetto).  Tracing never touches numerics:
    // results are bit-identical with and without it (pinned by
    // `ref_golden_digest_is_thread_count_invariant`).
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        coc::obs::trace::enable();
    }
    // --simd (any subcommand): pin the ref-backend kernel ISA path,
    // overriding COC_REF_SIMD.  Purely a performance knob — every path
    // produces identical bits (pinned by the digest suite).
    if let Some(v) = args.get("simd") {
        coc::runtime::refback::simd::set_policy(v)?;
    }
    // --faults SPEC / --fault-seed N (any subcommand): arm the
    // deterministic fault-injection layer, overriding COC_FAULTS /
    // COC_FAULT_SEED.  `coc serve-bench --faults "worker_panic@p=0.01"`
    // is the chaos-soak entrypoint; see `coc::faults` for the spec forms.
    match args.get("faults") {
        Some(spec) => coc::faults::configure(spec, args.get_u64("fault-seed", 0)?)?,
        None => coc::faults::configure_from_env()?,
    }
    let result = dispatch(&args);
    if let Some(path) = &trace_out {
        coc::obs::trace::disable();
        match coc::obs::trace::export(path) {
            Ok(()) => {
                coc::obs::log!(coc::obs::Level::Info, "wrote trace {}", path.display());
            }
            Err(e) => {
                // Never mask the command's own result with an export error.
                coc::obs::log!(
                    coc::obs::Level::Error,
                    "failed to write trace {}: {e:#}",
                    path.display()
                );
            }
        }
    }
    result
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("info") => cmd_info(args),
        Some("train") => cmd_train(args),
        Some("chain") => cmd_chain(args),
        Some("exp") => {
            let ctx = ctx_from(args)?;
            let id = args
                .positional
                .first()
                .ok_or_else(|| anyhow!("usage: coc exp <id> (fig6..fig15, table1..table5, toposort, all)"))?;
            exp::run(&ctx, id)
        }
        Some("toposort") => {
            let ctx = ctx_from(args)?;
            exp::run(&ctx, "toposort")
        }
        Some("serve") => cmd_serve(args),
        Some("serve-bench") => cmd_serve_bench(args),
        Some("bench-diff") => cmd_bench_diff(args),
        other => {
            if let Some(o) = other {
                coc::obs::log!(coc::obs::Level::Error, "unknown subcommand `{o}`\n");
            }
            print_usage();
            Ok(())
        }
    }
}

/// `coc bench-diff`: distill the current `results/*.json` into per-area
/// metric sets and compare them against the committed `BENCH_<area>.json`
/// ledgers at the repo root.  Exits nonzero when any metric regresses past
/// its tolerance — the CI regression gate.  `--update` re-blesses the
/// ledger from the current results instead of comparing.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    use coc::obs::ledger;
    let root = std::path::PathBuf::from(args.get_or("root", "."));
    let results = std::path::PathBuf::from(args.get_or("results", coc::DEFAULT_RESULTS));
    let threshold = match args.get("threshold") {
        Some(t) => Some(
            t.parse::<f64>()
                .map_err(|_| anyhow!("--threshold must be a number (tolerance in %)"))?,
        ),
        None => None,
    };
    let update = args.flag("update");
    let wanted = args.get_or("area", "all");
    if wanted != "all" && !ledger::areas().contains(&wanted) {
        return Err(anyhow!(
            "--area must be all|{}, got `{wanted}`",
            ledger::areas().join("|")
        ));
    }
    let mut regressions = Vec::new();
    let mut compared = 0usize;
    for &area in ledger::areas() {
        if wanted != "all" && wanted != area {
            continue;
        }
        let path = ledger::ledger_path(&root, area);
        let current = match ledger::extract(area, &results) {
            Ok(c) => c,
            Err(e) => {
                if wanted == area {
                    return Err(e);
                }
                // `all` sweeps every area but only judges the ones whose
                // results files exist — a serve-only run must not fail on
                // missing refback results.
                coc::obs::log!(coc::obs::Level::Warn, "bench-diff [{area}]: skipped ({e:#})");
                continue;
            }
        };
        if update {
            current.save(&path)?;
            println!(
                "bench-diff [{area}]: blessed {} metrics into {}",
                current.metrics.len(),
                path.display()
            );
            continue;
        }
        let baseline = ledger::BenchArea::load(&path)?;
        let lines = ledger::diff(&baseline, &current, threshold);
        print!("{}", ledger::format_table(area, &lines));
        compared += 1;
        for l in lines.into_iter().filter(|l| l.regressed) {
            regressions.push(format!(
                "{area}.{}: {:.4} -> {:.4} ({:+.1}% past {:.0}% tolerance)",
                l.name, l.baseline, l.current, l.regression_pct, l.tol_pct
            ));
        }
    }
    if !update && compared == 0 {
        return Err(anyhow!(
            "bench-diff compared nothing: no results for `{wanted}` under {}",
            results.display()
        ));
    }
    if regressions.is_empty() {
        Ok(())
    } else {
        Err(anyhow!("bench regressions:\n  {}", regressions.join("\n  ")))
    }
}

fn print_usage() {
    println!("coc {} — Chain of Compression coordinator", coc::version());
    println!("usage: coc <info|train|chain|exp|serve|serve-bench|bench-diff|toposort> [flags]");
    println!("  coc bench-diff                  # gate results/ against BENCH_*.json ledgers");
    println!("  coc bench-diff --update         # re-bless the ledgers from current results");
    println!("  coc serve-bench --backend ref --trace-out trace.json   # Chrome trace of a run");
    println!("  (any subcommand accepts --trace-out PATH; COC_LOG=error|warn|info|debug)");
    println!("  coc exp all --scale default     # regenerate every table/figure");
    println!("  coc exp table1 --scale smoke --jobs 2   # plan-parallel, cached");
    println!("  coc exp table1 --no-cache       # force from-scratch execution");
    println!("  coc chain --seq DPQE --arch mini_resnet --dataset c10");
    println!("  coc serve --arch mini_resnet --requests 200 --threshold 0.8");
    println!("  coc serve-bench --workers 4 --mode closed --concurrency 16 --requests 2000");
    println!("  coc serve-bench --workers 4 --mode open --rate 500 --slo-ms 50 --baseline");
    println!("  coc serve-bench --backend ref --compressed   # dense vs packed sparse/int8 serve");
    println!("    (--compressed runs a P->Q->E leaf twice — dense kernels, then the lowered");
    println!("     CompressedModel — and reports the speedup + model-bytes ratio;");
    println!("     --prune-ratio/--bits-w/--bits-a tune the leaf, ref backend only)");
    println!("  coc chain --seq PQE --arch mini_vgg --backend ref --lower   # pack leaves");
    println!("  coc chain --seq PQE --arch mini_vgg --backend ref   # hermetic, no artifacts");
    println!("    (--backend ref interprets feed-forward manifests; builtin arch: mini_vgg.");
    println!("     mini_resnet/mini_mobilenet drivers need the pjrt backend + artifacts.");
    println!("     --ref-threads N caps its kernel threads — results are bit-identical");
    println!("     at every N; serve/plan workers split the budget automatically.");
    println!("     --simd auto|scalar|sse2|avx2|neon pins the kernel ISA path, env");
    println!("     COC_REF_SIMD — every path produces identical bits.)");
}

fn cmd_info(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    println!("platform: {}", ctx.engine.platform());
    println!("artifacts: {}", ctx.engine.artifacts_dir().display());
    for (name, arch) in &ctx.manifest.archs {
        let base = coc::models::Accountant::baseline_bitops(arch);
        let params: usize = arch.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum();
        println!(
            "arch {name}: {} layers, {} mask slots, {params} params, baseline {base:.3e} BitOps",
            arch.layers.len(),
            arch.mask_slots.len()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let arch = args.get_or("arch", "mini_resnet");
    let kind = DatasetKind::parse(args.get_or("dataset", "c10"))
        .ok_or_else(|| anyhow!("--dataset must be c10|c100|svhn|cinic"))?;
    let (train_ds, test_ds) = ctx.datasets(kind);
    let arch_m = ctx.manifest.arch(arch)?;
    let mut st = train::init_state(&ctx.engine, arch_m, ctx.seed)?;
    let opts = TrainOpts {
        steps: args.get_usize("steps", ctx.scale.base_steps())?,
        lr: args.get_f32("lr", 0.05)?,
        seed: ctx.seed,
        log_every: if args.flag("verbose") { 20 } else { 0 },
        ..Default::default()
    };
    let log = train::train(&ctx.engine, &mut st, &train_ds, None, &opts)?;
    let acc = train::eval_accuracy(&ctx.engine, &st, &test_ds)?;
    println!(
        "trained {arch} on {} for {} steps: final loss {:.4}, test acc {:.2}%",
        kind.name(),
        opts.steps,
        log.final_loss(),
        acc * 100.0
    );
    Ok(())
}

fn cmd_chain(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let arch = args.get_or("arch", "mini_resnet");
    let kind = DatasetKind::parse(args.get_or("dataset", "c10"))
        .ok_or_else(|| anyhow!("--dataset must be c10|c100|svhn|cinic"))?;
    let seq = order::parse_sequence(args.get_or("seq", "DPQE"))?;
    let rung = args.get_usize("rung", 1)?;
    let ladder = ctx.scale.ladder();

    let (train_ds, test_ds) = ctx.datasets(kind);
    let base = ctx.base_model(arch, kind, &train_ds)?;
    let orig = train::eval_accuracy(&ctx.engine, &base, &test_ds)?;
    println!("base {arch}/{}: acc {:.2}%", kind.name(), orig * 100.0);

    // Through the planner: a repeated `coc chain` (or one sharing a prefix
    // with a previous experiment) replays cached stages.
    let rung = rung.min(ladder - 1);
    let mut plan = ctx.planner(arch, kind);
    plan.submit(
        exp::chain_for_sequence(&seq, rung, ladder),
        &order::sequence_string(&seq),
        &format!("rung{rung}"),
    );
    let run = ctx.run_plan_reports("chain", &plan, &base, &train_ds, &test_ds)?;
    let outcome = &run.outcomes[0];
    for r in &outcome.reports {
        println!(
            "  after {:<24} acc {:.2}%  BitOpsCR {:>8.1}x  CR {:>7.1}x",
            r.stage,
            r.measurement.accuracy * 100.0,
            r.measurement.bitops_cr,
            r.measurement.storage_cr
        );
    }
    let m = Measurement::take(&ctx.engine, &outcome.final_state, &test_ds)?;
    println!(
        "chain {}: acc {:.2}% ({:+.2}%)  BitOpsCR {:.1}x  CR {:.1}x",
        order::sequence_string(&seq),
        m.accuracy * 100.0,
        (m.accuracy - orig) * 100.0,
        m.bitops_cr,
        m.storage_cr
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let arch = args.get_or("arch", "mini_resnet");
    let kind = DatasetKind::parse(args.get_or("dataset", "c10"))
        .ok_or_else(|| anyhow!("--dataset must be c10|c100|svhn|cinic"))?;
    let threshold = args.get_f32("threshold", 0.8)?;
    let requests = args.get_usize("requests", 200)?;

    let (train_ds, test_ds) = ctx.datasets(kind);
    let mut state = ctx.base_model(arch, kind, &train_ds)?;
    // Ensure exits are trained before serving.
    let sctx = ctx.stage_ctx(&train_ds, &test_ds);
    let chain = Chain::new().push(Box::new(stages::EarlyExit {
        threshold,
        ..Default::default()
    }));
    chain.run(&mut state, &sctx)?;

    let server = Server::new(&ctx.engine, state)?;
    let rep = server.serve_dataset(&test_ds, requests, threshold, threshold)?;
    println!(
        "served {} requests: acc {:.2}%  exit1 {:.0}%  exit2 {:.0}%  p50 {:.0}µs  p95 {:.0}µs  {:.0} rps",
        rep.requests,
        rep.accuracy * 100.0,
        rep.p_exit1 * 100.0,
        rep.p_exit2 * 100.0,
        rep.latency_us.p50(),
        rep.latency_us.p95(),
        rep.throughput_rps
    );
    Ok(())
}

/// `coc serve-bench`: the concurrent serving benchmark — request queue +
/// dynamic micro-batching + a pool of workers with per-worker PJRT
/// engines, driven by an open- or closed-loop load generator.  Writes a
/// JSON report (latency percentiles, exit distribution, goodput under
/// SLO, queue depth) under `--out`.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    let ctx = ctx_from(args)?;
    let arch = args.get_or("arch", "mini_resnet");
    let kind = DatasetKind::parse(args.get_or("dataset", "c10"))
        .ok_or_else(|| anyhow!("--dataset must be c10|c100|svhn|cinic"))?;
    let threshold = args.get_f32("threshold", 0.8)?;
    let requests = args.get_usize("requests", 2000)?;
    let workers = args.get_usize("workers", 4)?.max(1);
    let queue_capacity = args.get_usize("queue", 256)?.max(1);
    let max_batch = args.get_usize("batch", 8)?.max(1);
    let batch_wait_us = args.get_u64("batch-wait-us", 2000)?;
    let slo_ms = args.get_f64("slo-ms", 50.0)?;
    let mode = match args.get_or("mode", "closed") {
        "open" => LoadMode::Open { rate_rps: args.get_f64("rate", 500.0)? },
        "closed" => LoadMode::Closed {
            concurrency: args.get_usize("concurrency", 4 * workers)?,
        },
        other => return Err(anyhow!("--mode must be open|closed, got `{other}`")),
    };
    // --compressed: run the same load twice — dense kernels, then the
    // packed (sparse/int8) kernels over the lowered model — and report
    // the serve-time speedup and model-bytes ratio.  The leaf is a real
    // P -> Q -> E chain so both pruning and quantization have something
    // to cash in (ref backend; PJRT artifacts are dense by construction).
    let compressed_mode = args.flag("compressed");

    // Same model preparation as `coc serve`, so the two are comparable.
    let (train_ds, test_ds) = ctx.datasets(kind);
    let mut state = ctx.base_model(arch, kind, &train_ds)?;
    let sctx = ctx.stage_ctx(&train_ds, &test_ds);
    let mut chain = Chain::new();
    if compressed_mode {
        chain = chain
            .push(Box::new(stages::Prune {
                ratio: args.get_f32("prune-ratio", 0.5)?,
                ..Default::default()
            }))
            .push(Box::new(stages::Quantize {
                bits_w: args.get_f32("bits-w", 2.0)?,
                bits_a: args.get_f32("bits-a", 8.0)?,
                ..Default::default()
            }));
    }
    chain
        .push(Box::new(stages::EarlyExit { threshold, ..Default::default() }))
        .run(&mut state, &sctx)?;

    // Optional synchronous single-stream baseline (the `coc serve` path)
    // for an apples-to-apples speedup figure in the same report.
    let baseline = if args.flag("baseline") {
        let server = Server::new(&ctx.engine, state.clone())?;
        let n = requests.min(512).max(1);
        let rep = server.serve_dataset(&test_ds, n, threshold, threshold)?;
        println!(
            "baseline (1 stream): {:.0} rps  acc {:.2}%  exit1 {:.0}% exit2 {:.0}%  p50 {:.0}µs",
            rep.throughput_rps,
            rep.accuracy * 100.0,
            rep.p_exit1 * 100.0,
            rep.p_exit2 * 100.0,
            rep.latency_us.p50()
        );
        Some(rep)
    } else {
        None
    };

    let mut pool_opts = PoolOpts::new(ctx.engine.artifacts_dir(), workers, (threshold, threshold));
    pool_opts.backend = ctx.backend;
    pool_opts.ref_threads = ctx.ref_threads;
    pool_opts.queue_capacity = queue_capacity;
    pool_opts.batch =
        BatchPolicy { max_batch, max_wait: Duration::from_micros(batch_wait_us) };
    // --deadline-ms: per-request latency budget; expired work is shed
    // with a terminal Timeout outcome instead of executed (0 = off).
    let deadline_ms = args.get_f64("deadline-ms", 0.0)?;
    if deadline_ms > 0.0 {
        pool_opts.deadline = Some(Duration::from_secs_f64(deadline_ms / 1000.0));
    }
    let load_opts = LoadOpts {
        mode,
        requests,
        seed: ctx.seed,
        slo: Slo { latency_ms: slo_ms },
        ..Default::default()
    };
    let state = Arc::new(state);
    let (report, outcome) = run_pool_bench(&state, &test_ds, &pool_opts, &load_opts)?;

    println!("{}", report.summary_line());
    if let Some(base) = &baseline {
        println!(
            "speedup vs single stream: {:.2}x ({:.0} rps vs {:.0} rps)",
            report.throughput_rps / base.throughput_rps.max(1e-9),
            report.throughput_rps,
            base.throughput_rps
        );
    }

    let worker_stats = Json::Arr(
        outcome
            .stats
            .iter()
            .map(|w| {
                obj(vec![
                    ("worker", num(w.worker as f64)),
                    ("processed", num(w.processed as f64)),
                    ("drains", num(w.drains as f64)),
                    ("max_chunk", num(w.max_chunk as f64)),
                    ("stage_batch", num(w.stage_batch as f64)),
                    ("padding_waste", num(w.padding_waste())),
                    ("bytes_uploaded", num(w.bytes_uploaded as f64)),
                    ("bytes_downloaded", num(w.bytes_downloaded as f64)),
                ])
            })
            .collect(),
    );
    // Pool-wide transfer volume (includes each worker's one-time
    // resident-prefix upload): with the device-resident operand prefix the
    // steady-state upload share is just the request input rows.
    let bytes_up: u64 = outcome.stats.iter().map(|w| w.bytes_uploaded).sum();
    let bytes_down: u64 = outcome.stats.iter().map(|w| w.bytes_downloaded).sum();
    let mut fields = vec![
        ("model", s(arch)),
        ("backend", s(ctx.backend.name())),
        ("dataset", s(kind.name())),
        ("threshold", num(threshold as f64)),
        ("queue_capacity", num(queue_capacity as f64)),
        ("max_batch", num(max_batch as f64)),
        ("batch_wait_us", num(batch_wait_us as f64)),
        ("bytes_uploaded", num(bytes_up as f64)),
        ("bytes_downloaded", num(bytes_down as f64)),
        ("bench", report.to_json()),
        ("worker_stats", worker_stats),
    ];
    if let Some(base) = &baseline {
        fields.push((
            "baseline",
            obj(vec![
                ("requests", num(base.requests as f64)),
                ("accuracy", num(base.accuracy)),
                ("p_exit1", num(base.p_exit1)),
                ("p_exit2", num(base.p_exit2)),
                ("p50_us", num(base.latency_us.p50())),
                ("p95_us", num(base.latency_us.p95())),
                ("p99_us", num(base.latency_us.p99())),
                ("throughput_rps", num(base.throughput_rps)),
            ]),
        ));
        fields.push((
            "speedup_vs_single_stream",
            num(report.throughput_rps / base.throughput_rps.max(1e-9)),
        ));
    }

    if compressed_mode {
        // Second pass: identical pool and load, compressed kernels.  The
        // lowering below is the same one every worker performs; done here
        // once more for the bytes accounting.
        let cm = CompressedModel::lower(&state)?;
        let bytes_dense = CompressedModel::dense_bytes(&state.arch) as f64;
        let bytes_packed = cm.packed_bytes() as f64;
        let mut cmp_opts = pool_opts.clone();
        cmp_opts.compressed = true;
        let (creport, _coutcome) = run_pool_bench(&state, &test_ds, &cmp_opts, &load_opts)?;
        let speedup = creport.throughput_rps / report.throughput_rps.max(1e-9);
        println!("compressed: {}", creport.summary_line());
        println!(
            "compressed vs dense: {speedup:.2}x rps, {:.0} -> {:.0} model bytes ({:.2}x smaller)",
            bytes_dense,
            bytes_packed,
            bytes_dense / bytes_packed.max(1.0)
        );
        fields.push(("compressed_bench", creport.to_json()));
        fields.push(("compressed_speedup", num(speedup)));
        fields.push(("bytes_model_dense", num(bytes_dense)));
        fields.push(("bytes_model_compressed", num(bytes_packed)));
        // The focused dense-vs-compressed comparison, fed to the
        // `serve_compressed` BENCH ledger area.
        let cmp_fields = vec![
            ("model", s(arch)),
            ("backend", s(ctx.backend.name())),
            ("dataset", s(kind.name())),
            ("dense", report.to_json()),
            ("compressed", creport.to_json()),
            ("speedup", num(speedup)),
            ("bytes_model_dense", num(bytes_dense)),
            ("bytes_model_compressed", num(bytes_packed)),
            ("bytes_ratio", num(bytes_packed / bytes_dense.max(1.0))),
        ];
        ctx.reporter
            .write("serve_bench_compressed.json", &obj(cmp_fields).to_string())?;
    }
    ctx.reporter.write("serve_bench.json", &obj(fields).to_string())?;
    Ok(())
}

/// Start one worker pool over `state`, drive `load_opts` through it, and
/// return the bench report plus per-worker stats.  Shared by the dense
/// and compressed passes of `coc serve-bench` so the two measurements
/// differ only in kernels.
fn run_pool_bench(
    state: &Arc<coc::models::ModelState>,
    test_ds: &coc::data::Dataset,
    pool_opts: &PoolOpts,
    load_opts: &LoadOpts,
) -> Result<(loadgen::BenchReport, coc::serve::worker::PoolOutcome)> {
    let pool = WorkerPool::start(state.clone(), pool_opts.clone());
    let up = pool.wait_ready(Duration::from_secs(600))?;
    if !up.all_up() {
        coc::obs::log!(
            coc::obs::Level::Warn,
            "warning: partial pool start — {}",
            up.describe()
        );
    }
    let report = loadgen::run(&pool, test_ds, load_opts)?;
    let outcome = pool.shutdown();
    for e in &outcome.errors {
        coc::obs::log!(coc::obs::Level::Error, "worker error: {e}");
    }
    // The terminal-outcome invariant is a hard contract: an accepted
    // request that never reached done/timeout/failed means the pool
    // dropped it, and no bench number from such a run can be trusted.
    if report.lost > 0 {
        anyhow::bail!(
            "{} accepted request(s) reached no terminal outcome — serve accounting broken",
            report.lost
        );
    }
    Ok((report, outcome))
}
