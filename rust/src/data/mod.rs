//! Synthetic dataset generators — the CIFAR10 / CIFAR100 / SVHN / CINIC10
//! analogs (see DESIGN.md §Substitutions: no dataset downloads in this
//! environment, and the paper's claims ride on task *difficulty ordering*,
//! which these generators preserve).
//!
//! Each class is a deterministic texture program: an oriented sinusoidal
//! grating + a class-colored blob + a polarity pattern, perturbed per
//! sample by random phase, shift, amplitude and pixel noise.  Difficulty
//! knobs: number of classes, noise level, intra-class jitter.
//!
//! Generators are seeded and pure: the same (dataset, seed, index) always
//! yields the same sample, so experiments replay exactly.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub const IMG_HW: usize = 16;
pub const IMG_C: usize = 3;
pub const NUM_CLASSES_MAX: usize = 20;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DatasetKind {
    /// CIFAR10 analog: 10 classes, moderate noise.
    SynthC10,
    /// CIFAR100 analog: 20 classes, higher intra-class variation — the
    /// "hard" task on which compression ratios shrink (paper Tables 2-4).
    SynthC100,
    /// SVHN analog: 10 digit-glyph classes, low noise (easiest).
    SynthSVHN,
    /// CINIC10 analog: C10 textures under distribution shift (brightness /
    /// contrast jitter + extra noise).
    SynthCINIC,
}

impl DatasetKind {
    pub fn parse(s: &str) -> Option<DatasetKind> {
        match s {
            "synth_c10" | "c10" | "cifar10" => Some(DatasetKind::SynthC10),
            "synth_c100" | "c100" | "cifar100" => Some(DatasetKind::SynthC100),
            "synth_svhn" | "svhn" => Some(DatasetKind::SynthSVHN),
            "synth_cinic" | "cinic" | "cinic10" => Some(DatasetKind::SynthCINIC),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::SynthC10 => "synth_c10",
            DatasetKind::SynthC100 => "synth_c100",
            DatasetKind::SynthSVHN => "synth_svhn",
            DatasetKind::SynthCINIC => "synth_cinic",
        }
    }

    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::SynthC100 => 20,
            _ => 10,
        }
    }

    fn noise(&self) -> f32 {
        match self {
            DatasetKind::SynthSVHN => 0.10,
            DatasetKind::SynthC10 => 0.22,
            DatasetKind::SynthC100 => 0.30,
            DatasetKind::SynthCINIC => 0.30,
        }
    }

    fn jitter(&self) -> f32 {
        match self {
            DatasetKind::SynthSVHN => 0.3,
            DatasetKind::SynthC10 => 0.6,
            DatasetKind::SynthC100 => 1.0,
            DatasetKind::SynthCINIC => 0.8,
        }
    }

    fn distribution_shift(&self) -> bool {
        matches!(self, DatasetKind::SynthCINIC)
    }
}

/// 5x7 bitmap digit glyphs for the SVHN analog.
const DIGITS: [u64; 10] = [
    0b01110_10001_10011_10101_11001_10001_01110, // 0
    0b00100_01100_00100_00100_00100_00100_01110, // 1
    0b01110_10001_00001_00010_00100_01000_11111, // 2
    0b01110_10001_00001_00110_00001_10001_01110, // 3
    0b00010_00110_01010_10010_11111_00010_00010, // 4
    0b11111_10000_11110_00001_00001_10001_01110, // 5
    0b00110_01000_10000_11110_10001_10001_01110, // 6
    0b11111_00001_00010_00100_01000_01000_01000, // 7
    0b01110_10001_10001_01110_10001_10001_01110, // 8
    0b01110_10001_10001_01111_00001_00010_01100, // 9
];

/// One dataset split held in memory as a single batch-major tensor pair.
pub struct Dataset {
    pub kind: DatasetKind,
    pub images: Tensor, // [n, 16, 16, 3]
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

impl Dataset {
    /// Generate `n` samples.  `split_salt` decouples train/test streams.
    pub fn generate(kind: DatasetKind, n: usize, seed: u64, split_salt: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ split_salt.wrapping_mul(0x9e3779b97f4a7c15));
        let mut images = Vec::with_capacity(n * IMG_HW * IMG_HW * IMG_C);
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            let label = rng.below(kind.num_classes());
            let img = gen_image(kind, label, &mut rng);
            images.extend_from_slice(&img);
            labels.push(label);
        }
        Dataset {
            kind,
            images: Tensor::new(vec![n, IMG_HW, IMG_HW, IMG_C], images),
            labels,
            num_classes: kind.num_classes(),
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Copy a batch by indices into (x, one-hot y with NUM_CLASSES_MAX cols).
    pub fn batch(&self, idx: &[usize]) -> (Tensor, Tensor) {
        let spl = IMG_HW * IMG_HW * IMG_C;
        let mut x = Vec::with_capacity(idx.len() * spl);
        let mut y = vec![0.0f32; idx.len() * NUM_CLASSES_MAX];
        for (bi, &i) in idx.iter().enumerate() {
            x.extend_from_slice(&self.images.data[i * spl..(i + 1) * spl]);
            y[bi * NUM_CLASSES_MAX + self.labels[i]] = 1.0;
        }
        (
            Tensor::new(vec![idx.len(), IMG_HW, IMG_HW, IMG_C], x),
            Tensor::new(vec![idx.len(), NUM_CLASSES_MAX], y),
        )
    }
}

/// Deterministic per-class texture parameters.
fn class_program(label: usize) -> (f32, f32, [f32; 3], f32) {
    // Golden-angle spacing decorrelates neighbouring classes.
    let g = label as f32 * 2.39996;
    let freq = 1.2 + (label % 5) as f32 * 0.55;
    let theta = g;
    let color = [
        0.5 + 0.5 * (g * 1.3).sin(),
        0.5 + 0.5 * (g * 2.1 + 1.0).sin(),
        0.5 + 0.5 * (g * 3.7 + 2.0).sin(),
    ];
    let polarity = if label % 2 == 0 { 1.0 } else { -1.0 };
    (freq, theta, color, polarity)
}

fn gen_image(kind: DatasetKind, label: usize, rng: &mut Rng) -> Vec<f32> {
    let (freq, theta, color, polarity) = class_program(label);
    let jit = kind.jitter();
    let phase = rng.range_f32(0.0, std::f32::consts::TAU) * jit;
    let dth = rng.range_f32(-0.25, 0.25) * jit;
    let amp = 1.0 + rng.range_f32(-0.3, 0.3) * jit;
    let cx = 8.0 + rng.range_f32(-3.0, 3.0) * jit;
    let cy = 8.0 + rng.range_f32(-3.0, 3.0) * jit;
    let noise = kind.noise();
    let (gain, bias) = if kind.distribution_shift() {
        (rng.range_f32(0.6, 1.4), rng.range_f32(-0.3, 0.3))
    } else {
        (1.0, 0.0)
    };

    let ct = (theta + dth).cos();
    let st_ = (theta + dth).sin();
    let mut out = Vec::with_capacity(IMG_HW * IMG_HW * IMG_C);
    let glyph = if kind == DatasetKind::SynthSVHN { Some(DIGITS[label % 10]) } else { None };
    for y in 0..IMG_HW {
        for x in 0..IMG_HW {
            let xf = x as f32;
            let yf = y as f32;
            // Oriented grating.
            let u = (xf * ct + yf * st_) * freq * 0.5 + phase;
            let grating = u.sin() * amp * polarity;
            // Class-colored radial blob.
            let d2 = ((xf - cx) * (xf - cx) + (yf - cy) * (yf - cy)) / 18.0;
            let blob = (-d2).exp();
            // Digit glyph overlay for SVHN (5x7 centered, 2x scale).
            let mut glyph_v = 0.0;
            if let Some(bits) = glyph {
                let gx = (x as i32 - 3) / 2;
                let gy = (y as i32 - 1) / 2;
                if (0..5).contains(&gx) && (0..7).contains(&gy) {
                    let bit = 34 - (gy * 5 + gx); // bit 34 = top-left
                    if bits >> bit & 1 == 1 {
                        glyph_v = 1.6;
                    }
                }
            }
            for c in 0..IMG_C {
                let v = 0.45 * grating + 1.1 * blob * (color[c] - 0.5) + glyph_v
                    + noise * rng.normal();
                out.push((v * gain + bias).clamp(-3.0, 3.0));
            }
        }
    }
    out
}

/// Epoch iterator: reshuffles indices each epoch, yields fixed-size batches
/// (drops the ragged tail — batch shape is baked into the AOT graph).
pub struct Batcher {
    n: usize,
    batch: usize,
    order: Vec<usize>,
    pos: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, seed: u64) -> Batcher {
        assert!(batch <= n, "batch {batch} > dataset {n}");
        let mut b = Batcher { n, batch, order: (0..n).collect(), pos: 0, rng: Rng::new(seed) };
        b.rng.shuffle(&mut b.order);
        b
    }

    /// Next batch of indices, reshuffling at epoch boundaries.
    pub fn next_indices(&mut self) -> &[usize] {
        if self.pos + self.batch > self.n {
            self.rng.shuffle(&mut self.order);
            self.pos = 0;
        }
        let s = &self.order[self.pos..self.pos + self.batch];
        self.pos += self.batch;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Dataset::generate(DatasetKind::SynthC10, 32, 7, 0);
        let b = Dataset::generate(DatasetKind::SynthC10, 32, 7, 0);
        assert_eq!(a.images.data, b.images.data);
        assert_eq!(a.labels, b.labels);
        let c = Dataset::generate(DatasetKind::SynthC10, 32, 8, 0);
        assert_ne!(a.images.data, c.images.data);
    }

    #[test]
    fn split_salt_decouples() {
        let tr = Dataset::generate(DatasetKind::SynthC10, 16, 7, 0);
        let te = Dataset::generate(DatasetKind::SynthC10, 16, 7, 1);
        assert_ne!(tr.images.data, te.images.data);
    }

    #[test]
    fn shapes_and_labels() {
        for kind in [
            DatasetKind::SynthC10,
            DatasetKind::SynthC100,
            DatasetKind::SynthSVHN,
            DatasetKind::SynthCINIC,
        ] {
            let d = Dataset::generate(kind, 64, 3, 0);
            assert_eq!(d.images.shape, vec![64, IMG_HW, IMG_HW, IMG_C]);
            assert!(d.labels.iter().all(|&l| l < kind.num_classes()));
            // All classes should appear in 64 draws with high probability
            // for the 10-class sets.
            if kind.num_classes() == 10 {
                let mut seen = [false; 10];
                for &l in &d.labels {
                    seen[l] = true;
                }
                assert!(seen.iter().filter(|&&s| s).count() >= 8);
            }
        }
    }

    #[test]
    fn images_bounded_and_varied() {
        let d = Dataset::generate(DatasetKind::SynthC10, 16, 5, 0);
        assert!(d.images.data.iter().all(|v| v.is_finite() && v.abs() <= 3.0));
        let mean: f32 = d.images.data.iter().sum::<f32>() / d.images.len() as f32;
        let var: f32 =
            d.images.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d.images.len() as f32;
        assert!(var > 0.05, "images look constant, var={var}");
    }

    #[test]
    fn classes_are_separable_by_template_distance() {
        // Nearest-class-mean classification on clean-ish samples should be
        // far above chance — the datasets must be learnable.
        let kind = DatasetKind::SynthC10;
        let train = Dataset::generate(kind, 400, 11, 0);
        let test = Dataset::generate(kind, 100, 11, 1);
        let spl = IMG_HW * IMG_HW * IMG_C;
        let mut means = vec![vec![0.0f32; spl]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..train.len() {
            let l = train.labels[i];
            counts[l] += 1;
            for (m, v) in means[l].iter_mut().zip(train.images.row(i)) {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f32;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let row = test.images.row(i);
            let pred = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(row).map(|(m, v)| (m - v) * (m - v)).sum();
                    let db: f32 = means[b].iter().zip(row).map(|(m, v)| (m - v) * (m - v)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == test.labels[i] {
                correct += 1;
            }
        }
        assert!(correct >= 40, "nearest-mean accuracy {correct}/100 — dataset not learnable");
    }

    #[test]
    fn svhn_easier_than_c100() {
        // Confirm the difficulty ordering the evaluation relies on, via
        // within-class variance relative to between-class distance.
        fn spread(kind: DatasetKind) -> f32 {
            let d = Dataset::generate(kind, 200, 13, 0);
            let spl = IMG_HW * IMG_HW * IMG_C;
            let k = kind.num_classes();
            let mut means = vec![vec![0.0f32; spl]; k];
            let mut counts = vec![0usize; k];
            for i in 0..d.len() {
                counts[d.labels[i]] += 1;
                for (m, v) in means[d.labels[i]].iter_mut().zip(d.images.row(i)) {
                    *m += v;
                }
            }
            for (m, &c) in means.iter_mut().zip(&counts) {
                for v in m.iter_mut() {
                    *v /= c.max(1) as f32;
                }
            }
            let mut within = 0.0f32;
            for i in 0..d.len() {
                let m = &means[d.labels[i]];
                within += m
                    .iter()
                    .zip(d.images.row(i))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum::<f32>();
            }
            within / d.len() as f32
        }
        assert!(spread(DatasetKind::SynthSVHN) < spread(DatasetKind::SynthC100));
    }

    #[test]
    fn batcher_covers_epoch() {
        let mut b = Batcher::new(10, 3, 1);
        let mut seen = vec![0usize; 10];
        for _ in 0..3 {
            for &i in b.next_indices() {
                seen[i] += 1;
            }
        }
        // 9 of 10 indices per epoch (tail dropped); over one epoch no
        // index repeats more than once.
        assert!(seen.iter().all(|&c| c <= 1 || c <= 2));
        assert_eq!(seen.iter().sum::<usize>(), 9);
    }

    #[test]
    fn batch_one_hot() {
        let d = Dataset::generate(DatasetKind::SynthC10, 8, 3, 0);
        let (x, y) = d.batch(&[0, 1, 2]);
        assert_eq!(x.shape, vec![3, IMG_HW, IMG_HW, IMG_C]);
        assert_eq!(y.shape, vec![3, NUM_CLASSES_MAX]);
        for (bi, row) in y.data.chunks_exact(NUM_CLASSES_MAX).enumerate() {
            assert_eq!(row.iter().sum::<f32>(), 1.0);
            assert_eq!(row[d.labels[bi]], 1.0);
        }
    }
}
