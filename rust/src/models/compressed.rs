//! Post-chain lowering: pack a trained `ModelState` into the form the
//! serve-time kernels actually execute, so pruning and quantization pay
//! at inference instead of only in the analytic accounting.
//!
//! Three mechanisms, chosen per layer (see `PackedForm`):
//!
//! * **Channel compaction** — binary channel masks are removed
//!   structurally: dead input/output channels are dropped from the weight
//!   matrix and the feature maps shrink network-wide (the consumer's
//!   `in_mask` slot is validated to equal the producer's `out_mask`, so
//!   the live sets agree along the chain).
//! * **Blocked-CSR** — the compacted `cout_live x K_live` matrix is tiled
//!   into `BLOCK_R x BLOCK_C` dense blocks (the kernel register-tile
//!   geometry); incidentally all-zero tiles are dropped.  Stored entries
//!   keep the exact fake-quant f32 values the dense path computes, and
//!   the kernels walk them in the dense path's canonical reduction order,
//!   so the pruned-fp32 pipeline stays bit-identical.
//! * **int8** — layers whose DoReFa grid fits i8 (integer `bits_w` in
//!   1..=7, integer `bits_a` in 1..=8, quantized input available, i32
//!   accumulator can't overflow) store integer weight codes plus one
//!   per-layer f32 scale; the kernels accumulate in i32 and rescale once
//!   per output element.  This path is tolerance-level (not bitwise)
//!   equal to dense fake-quant, but exactly deterministic.
//!
//! Zero-skip safety: an f32 accumulator chain that starts at +0.0 never
//! produces -0.0 (`+0 + ±0 = +0`, `x + (-x) = +0`), so omitting `±0.0`
//! product terms from a single-accumulator ascending-order chain never
//! changes the accumulator's bits.  Dead-channel folding always writes
//! literal `+0.0` (branch, never multiply: `w * 0.0` preserves sign).

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, ensure, Context, Result};

use super::{
    host_weight_quant, weight_quant_scales, ArchManifest, ExitState, LayerDesc, LayerKind,
    ModelState, QBits,
};
use crate::tensor::Tensor;
use crate::util::json::{num, obj, s, Json};

/// On-disk format version for `.cmp` files; newer files are rejected.
pub const COMPRESSED_FORMAT_VERSION: u32 = 1;

/// Block geometry = the refback kernel register tile (MR x NR).  Equality
/// with `runtime::refback::kernels::{MR, NR}` is pinned by a test there so
/// packed blocks always feed the kernel tiles directly.
pub const BLOCK_R: usize = 4;
pub const BLOCK_C: usize = 8;
pub const BLOCK_LEN: usize = BLOCK_R * BLOCK_C;

/// Block-level CSR over a `rows x cols` weight matrix with rows = live
/// output channels and cols = live reduction indices (`(ky, kx, ic)` for
/// conv, `ic` for dense), tiled into `BLOCK_R x BLOCK_C` dense blocks.
///
/// `row_ptr[br]..row_ptr[br+1]` indexes the stored blocks of block-row
/// `br`; `col_idx[bi]` is the block-column of stored block `bi`.  Block
/// payloads (f32 values or i8 codes) live beside the structure in
/// `PackedForm`, `BLOCK_LEN` entries per stored block in row-major tile
/// order, zero-padded outside the matrix bounds.
#[derive(Debug, Clone, PartialEq)]
pub struct Bcsr {
    pub rows: usize,
    pub cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
}

impl Bcsr {
    pub fn block_rows(&self) -> usize {
        self.rows.div_ceil(BLOCK_R)
    }

    pub fn nblocks(&self) -> usize {
        self.col_idx.len()
    }

    /// Stored-block index range of block-row `br` (ascending block-column).
    #[inline]
    pub fn row_blocks(&self, br: usize) -> std::ops::Range<usize> {
        self.row_ptr[br] as usize..self.row_ptr[br + 1] as usize
    }

    /// Build from per-entry values: every tile containing at least one
    /// in-bounds entry for which `keep` is true is stored (its `BLOCK_LEN`
    /// payload appended to `out`); all-skippable tiles are dropped.
    pub fn build<T: Copy + Default>(
        rows: usize,
        cols: usize,
        mut value: impl FnMut(usize, usize) -> T,
        keep: impl Fn(T) -> bool,
        out: &mut Vec<T>,
    ) -> Bcsr {
        let (nbr, nbc) = (rows.div_ceil(BLOCK_R), cols.div_ceil(BLOCK_C));
        let mut row_ptr = Vec::with_capacity(nbr + 1);
        row_ptr.push(0u32);
        let mut col_idx = Vec::new();
        for br in 0..nbr {
            for bc in 0..nbc {
                let mut buf = [T::default(); BLOCK_LEN];
                let mut any = false;
                for rr in 0..BLOCK_R {
                    let r = br * BLOCK_R + rr;
                    if r >= rows {
                        break;
                    }
                    for cc in 0..BLOCK_C {
                        let c = bc * BLOCK_C + cc;
                        if c >= cols {
                            break;
                        }
                        let v = value(r, c);
                        if keep(v) {
                            any = true;
                        }
                        buf[rr * BLOCK_C + cc] = v;
                    }
                }
                if any {
                    col_idx.push(bc as u32);
                    out.extend_from_slice(&buf);
                }
            }
            row_ptr.push(col_idx.len() as u32);
        }
        Bcsr { rows, cols, row_ptr, col_idx }
    }
}

/// Per-layer packed representation.
#[derive(Debug, Clone, PartialEq)]
pub enum PackedForm {
    /// Uncompacted fallback: the quant-baked full-geometry weight tensor,
    /// executed by the existing dense kernels (still saves the per-forward
    /// `host_weight_quant` tanh pass over the raw weights).
    Dense { w: Tensor },
    /// Depthwise conv: weights compacted to live output channels plus a
    /// per-output map into the live input channels (-1 = dead input, the
    /// output is bias-only).
    DwMapped { w: Tensor, in_pos: Vec<i32> },
    /// Blocked-CSR over the compacted matrix, fake-quant f32 values.
    SparseF32 { csr: Bcsr, values: Vec<f32> },
    /// Blocked-CSR of DoReFa integer codes (`2q - n`, odd, never 0 for a
    /// live entry) with one per-layer scale; value = code * scale_w.
    Int8 { csr: Bcsr, codes: Vec<i8>, scale_w: f32 },
}

impl PackedForm {
    pub fn tag(&self) -> &'static str {
        match self {
            PackedForm::Dense { .. } => "dense",
            PackedForm::DwMapped { .. } => "dw",
            PackedForm::SparseF32 { .. } => "sparse",
            PackedForm::Int8 { .. } => "int8",
        }
    }

    fn payload_bytes(&self) -> usize {
        match self {
            PackedForm::Dense { w } => 4 * w.len(),
            PackedForm::DwMapped { w, in_pos } => 4 * w.len() + 4 * in_pos.len(),
            PackedForm::SparseF32 { csr, values } => {
                4 * (csr.row_ptr.len() + csr.col_idx.len() + values.len())
            }
            PackedForm::Int8 { csr, codes, .. } => {
                4 * (csr.row_ptr.len() + csr.col_idx.len()) + codes.len() + 4
            }
        }
    }
}

/// One lowered layer, index-aligned with `arch.layers` (kind / geometry
/// are read from the manifest, not duplicated here).
#[derive(Debug, Clone, PartialEq)]
pub struct PackedLayer {
    /// Original channel index of each live input channel, ascending.
    pub in_live: Vec<u32>,
    /// Original channel index of each live output channel, ascending.
    pub out_live: Vec<u32>,
    /// rmsnorm divisor the dense path uses: mask-sum clamped to >= 1, or
    /// full `cout` when the layer is unmasked.
    pub live_divisor: f32,
    /// Bias over live output channels (dead fallback channels fold to +0).
    pub bias: Vec<f32>,
    pub form: PackedForm,
}

impl PackedLayer {
    pub fn packed_bytes(&self) -> usize {
        4 * (self.in_live.len() + self.out_live.len() + self.bias.len() + 1)
            + self.form.payload_bytes()
    }
}

/// A `ModelState` lowered for compressed execution.
#[derive(Debug, Clone)]
pub struct CompressedModel {
    pub arch: Arc<ArchManifest>,
    pub qbits: QBits,
    pub exits: ExitState,
    /// Index-aligned with `arch.layers`.
    pub layers: Vec<PackedLayer>,
    pub history: Vec<String>,
}

fn live_set(st: &ModelState, slot: i64, full: usize) -> Vec<u32> {
    if slot < 0 {
        return (0..full as u32).collect();
    }
    let m = &st.masks[slot as usize];
    let mut v: Vec<u32> = m
        .data
        .iter()
        .enumerate()
        .filter_map(|(i, &x)| (x != 0.0).then_some(i as u32))
        .collect();
    if v.is_empty() {
        // Fully-dead slot: keep channel 0 with weights and bias folded to
        // +0 so downstream shapes stay non-empty (mirrors the dense
        // path's `live >= 1` rmsnorm divisor clamp).
        v.push(0);
    }
    v
}

/// Flat index into the original `[k,k,cin,cout]` / `[cin,cout]` weight for
/// matrix entry (live output row `ocl`, live reduction column `r`).
fn orig_index(l: &LayerDesc, in_live: &[u32], out_live: &[u32], ocl: usize, r: usize) -> usize {
    let oc = out_live[ocl] as usize;
    match l.kind {
        LayerKind::Dense => in_live[r] as usize * l.cout + oc,
        _ => {
            let (tap, icl) = (r / in_live.len(), r % in_live.len());
            (tap * l.cin + in_live[icl] as usize) * l.cout + oc
        }
    }
}

fn int8_ok(l: &LayerDesc, qb: &QBits, first_body: bool, kdim: usize) -> bool {
    let int_bits = |b: f32, lo: f32, hi: f32| b >= lo && b <= hi && b.fract() == 0.0;
    // n = 2^bits_w - 1 must fit i8 (codes span [-n, n]), so bits_w <= 7;
    // activation codes span [0, 2^bits_a - 1], recovered into u32.
    if !int_bits(qb.weight, 1.0, 7.0) || !int_bits(qb.act, 1.0, 8.0) {
        return false;
    }
    // Depthwise stays f32 (cheap, mapped kernel); a conv stem's input is
    // the raw image, never an act_quant grid, so codes can't be recovered.
    // Dense heads quantize their own gap input, so they always qualify.
    if l.kind == LayerKind::DwConv || (l.kind != LayerKind::Dense && first_body) {
        return false;
    }
    let nw = 2f64.powf(qb.weight as f64) - 1.0;
    let na = 2f64.powf(qb.act as f64) - 1.0;
    kdim as f64 * nw * na < i32::MAX as f64
}

impl CompressedModel {
    /// Lower a trained state.  Fails (caller falls back to dense
    /// execution) when a structural invariant doesn't hold: non-binary
    /// masks, a masked stem input, or producer/consumer mask-slot
    /// disagreement along the body chain or at an exit cut.
    pub fn lower(st: &ModelState) -> Result<CompressedModel> {
        let arch = st.arch.clone();
        // Masks must be exactly binary: `mul_channel_mask` scales by the
        // mask value, and only *1.0 (bitwise identity) / *0.0 (dead
        // channel) can be replaced by structural channel selection.
        for (si, m) in st.masks.iter().enumerate() {
            for &v in &m.data {
                ensure!(
                    v == 0.0 || v == 1.0,
                    "mask slot {si} is not binary (found {v}); cannot lower"
                );
            }
        }
        let body: Vec<usize> = arch
            .layers
            .iter()
            .enumerate()
            .filter_map(|(i, l)| (!l.segment.starts_with("exit")).then_some(i))
            .collect();
        ensure!(!body.is_empty(), "arch `{}` has no body layers", arch.name);
        // Legacy chain manifests (no joins, no declared edges) consume in
        // declaration order; DAG manifests name every producer edge.
        let legacy =
            arch.joins.is_empty() && body.iter().all(|&i| arch.layers[i].input.is_empty());
        // Effective out-mask per producer name: body layers and joins
        // (a join's output carries its own `out_mask` slot).
        let out_mask_of = |name: &str| -> Option<i64> {
            if let Some(&i) = body.iter().find(|&&i| arch.layers[i].name == name) {
                return Some(arch.layers[i].out_mask);
            }
            arch.joins.iter().find(|j| j.name == name).map(|j| j.out_mask)
        };
        // Compaction drops dead channels from the feature map, so every
        // consumer must agree with its producer on the mask slot, and a
        // join's operands must both carry the join's own slot (the add
        // only makes sense over one shared live set).
        if legacy {
            ensure!(
                arch.layers[body[0]].in_mask < 0,
                "stem layer `{}` has a masked input; cannot lower",
                arch.layers[body[0]].name
            );
            for w in body.windows(2) {
                let (p, l) = (&arch.layers[w[0]], &arch.layers[w[1]]);
                ensure!(
                    l.in_mask == p.out_mask,
                    "layer `{}` in_mask {} != producer `{}` out_mask {}; cannot lower",
                    l.name,
                    l.in_mask,
                    p.name,
                    p.out_mask
                );
            }
        } else {
            for &i in &body {
                let l = &arch.layers[i];
                if l.input == "@input" {
                    ensure!(
                        l.in_mask < 0,
                        "stem layer `{}` has a masked input; cannot lower",
                        l.name
                    );
                    continue;
                }
                let pm = out_mask_of(&l.input).ok_or_else(|| {
                    anyhow!("layer `{}`: unknown producer `{}`; cannot lower", l.name, l.input)
                })?;
                ensure!(
                    l.in_mask == pm,
                    "layer `{}` in_mask {} != producer `{}` out_mask {}; cannot lower",
                    l.name,
                    l.in_mask,
                    l.input,
                    pm
                );
            }
            for j in &arch.joins {
                let am = out_mask_of(&j.a).ok_or_else(|| {
                    anyhow!("join `{}`: unknown operand `{}`; cannot lower", j.name, j.a)
                })?;
                ensure!(
                    am == j.out_mask,
                    "join `{}`: operand `{}` out_mask {} != join out_mask {}; cannot lower",
                    j.name,
                    j.a,
                    am,
                    j.out_mask
                );
                if let Some(b) = &j.b {
                    let bm = out_mask_of(b).ok_or_else(|| {
                        anyhow!("join `{}`: unknown operand `{}`; cannot lower", j.name, b)
                    })?;
                    ensure!(
                        bm == j.out_mask,
                        "join `{}`: operands `{}` (out_mask {am}) and `{b}` (out_mask {bm}) \
                         disagree at the skip join; cannot lower",
                        j.name,
                        j.a
                    );
                }
            }
        }
        for l in &arch.layers {
            if let Some(seg) = l.segment.strip_prefix("exit") {
                ensure!(l.kind == LayerKind::Dense, "exit head `{}` is not dense", l.name);
                // The stage output's mask slot: for legacy chains the last
                // body layer of the segment; for DAG manifests the segment
                // terminal — the one node (layer or join) in the segment
                // nothing else in the segment consumes.
                let segname = format!("seg{seg}");
                let cut: Option<(String, i64)> = if legacy {
                    body.iter()
                        .rev()
                        .find(|&&i| arch.layers[i].segment == segname)
                        .map(|&i| (arch.layers[i].name.clone(), arch.layers[i].out_mask))
                } else {
                    let mut nodes: Vec<(&str, i64)> = body
                        .iter()
                        .filter(|&&i| arch.layers[i].segment == segname)
                        .map(|&i| (arch.layers[i].name.as_str(), arch.layers[i].out_mask))
                        .collect();
                    nodes.extend(
                        arch.joins
                            .iter()
                            .filter(|j| j.segment == segname)
                            .map(|j| (j.name.as_str(), j.out_mask)),
                    );
                    let consumed: Vec<&str> = body
                        .iter()
                        .filter(|&&i| arch.layers[i].segment == segname)
                        .map(|&i| arch.layers[i].input.as_str())
                        .chain(arch.joins.iter().filter(|j| j.segment == segname).flat_map(
                            |j| {
                                std::iter::once(j.a.as_str())
                                    .chain(j.b.as_deref().into_iter())
                            },
                        ))
                        .collect();
                    nodes
                        .iter()
                        .find(|(n, _)| !consumed.contains(n))
                        .map(|&(n, m)| (n.to_string(), m))
                };
                let (cut_name, cut_mask) =
                    cut.ok_or_else(|| anyhow!("exit head `{}` cuts a missing segment", l.name))?;
                ensure!(
                    l.in_mask == cut_mask,
                    "exit head `{}` in_mask {} != cut `{}` out_mask {}; cannot lower",
                    l.name,
                    l.in_mask,
                    cut_name,
                    cut_mask
                );
            }
        }

        let qb = st.qbits;
        let mut layers = Vec::with_capacity(arch.layers.len());
        for (li, l) in arch.layers.iter().enumerate() {
            let in_live = live_set(st, l.in_mask, l.cin);
            let out_live = live_set(st, l.out_mask, l.cout);
            let live_divisor = if l.out_mask >= 0 {
                st.masks[l.out_mask as usize].data.iter().sum::<f32>().max(1.0)
            } else {
                l.cout as f32
            };
            let out_dead =
                |oc: usize| l.out_mask >= 0 && st.masks[l.out_mask as usize].data[oc] == 0.0;
            let in_dead =
                |ic: usize| l.in_mask >= 0 && st.masks[l.in_mask as usize].data[ic] == 0.0;
            let raw_w = &st.params[2 * li];
            let bias_full = &st.params[2 * li + 1];
            let bias: Vec<f32> = out_live
                .iter()
                .map(|&oc| if out_dead(oc as usize) { 0.0 } else { bias_full.data[oc as usize] })
                .collect();
            let form = match l.kind {
                LayerKind::DwConv => {
                    let wq = host_weight_quant(raw_w, qb.weight);
                    let mut data = Vec::with_capacity(l.k * l.k * out_live.len());
                    for tap in 0..l.k * l.k {
                        for &oc in &out_live {
                            let v = wq.data[tap * l.cout + oc as usize];
                            data.push(if out_dead(oc as usize) { 0.0 } else { v });
                        }
                    }
                    let in_pos = out_live
                        .iter()
                        .map(|&oc| {
                            in_live.iter().position(|&ic| ic == oc).map_or(-1, |p| p as i32)
                        })
                        .collect();
                    PackedForm::DwMapped {
                        w: Tensor::new(vec![l.k, l.k, 1, out_live.len()], data),
                        in_pos,
                    }
                }
                LayerKind::Conv | LayerKind::Dense => {
                    let kdim = match l.kind {
                        LayerKind::Dense => in_live.len(),
                        _ => l.k * l.k * in_live.len(),
                    };
                    // "First body" = consumes the raw image (no act_quant
                    // grid to recover codes from): the declared `@input`
                    // consumers in a DAG manifest, the chain head in a
                    // legacy one.
                    let raw_input =
                        if legacy { li == body[0] } else { l.input == "@input" };
                    if int8_ok(l, &qb, raw_input, kdim) {
                        // Integer codes from the *raw* weights with the
                        // same (tmax, wmax) scan host_weight_quant uses,
                        // so fake-quant value = code * scale_w up to one
                        // f32 rounding.
                        let n = (2f32.powf(qb.weight) - 1.0).max(1.0);
                        let (tmax, wmax) = weight_quant_scales(&raw_w.data);
                        let mut codes = Vec::new();
                        let csr = Bcsr::build(
                            out_live.len(),
                            kdim,
                            |ocl, r| {
                                let oi = orig_index(l, &in_live, &out_live, ocl, r);
                                let fold = out_dead(out_live[ocl] as usize)
                                    || in_dead(in_live[r % in_live.len()] as usize);
                                if fold {
                                    0
                                } else {
                                    let tn = raw_w.data[oi].tanh() / (2.0 * tmax) + 0.5;
                                    (2.0 * (tn * n).round() - n) as i8
                                }
                            },
                            |c| c != 0,
                            &mut codes,
                        );
                        PackedForm::Int8 { csr, codes, scale_w: wmax / n }
                    } else {
                        let wq = host_weight_quant(raw_w, qb.weight);
                        if in_live.len() == l.cin && out_live.len() == l.cout {
                            PackedForm::Dense { w: wq }
                        } else {
                            let mut values = Vec::new();
                            let csr = Bcsr::build(
                                out_live.len(),
                                kdim,
                                |ocl, r| {
                                    let oi = orig_index(l, &in_live, &out_live, ocl, r);
                                    let fold = out_dead(out_live[ocl] as usize)
                                        || in_dead(in_live[r % in_live.len()] as usize);
                                    if fold {
                                        0.0
                                    } else {
                                        wq.data[oi]
                                    }
                                },
                                |v| v != 0.0,
                                &mut values,
                            );
                            PackedForm::SparseF32 { csr, values }
                        }
                    }
                }
            };
            layers.push(PackedLayer { in_live, out_live, live_divisor, bias, form });
        }
        Ok(CompressedModel {
            arch,
            qbits: qb,
            exits: st.exits.clone(),
            layers,
            history: st.history.clone(),
        })
    }

    /// Total packed parameter bytes (structure + payload + bias + maps).
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|pl| pl.packed_bytes()).sum()
    }

    /// Dense f32 parameter bytes for the same arch (weights + biases) —
    /// the baseline the serve path ships today.
    pub fn dense_bytes(arch: &ArchManifest) -> usize {
        arch.param_shapes.iter().map(|sh| 4 * sh.iter().product::<usize>()).sum()
    }
}

// ---------------------------------------------------------------------------
// Persistence: one JSON header line (version + per-layer structure), then
// raw little-endian payload per layer:
//   bias f32 ++ in_live u32 ++ out_live u32 ++ form payload
// where the form payload is w f32 (dense) / w f32 ++ in_pos i32 (dw) /
// row_ptr u32 ++ col_idx u32 ++ values f32 (sparse) / row_ptr ++ col_idx
// ++ codes i8 (int8).  Mirrors `ModelState::save_tagged`.
// ---------------------------------------------------------------------------

fn take<'a>(b: &'a [u8], off: &mut usize, n: usize) -> Result<&'a [u8]> {
    let end = *off + n;
    ensure!(end <= b.len(), "corrupt compressed model: truncated payload");
    let out = &b[*off..end];
    *off = end;
    Ok(out)
}

fn read_f32(b: &[u8], off: &mut usize, n: usize) -> Result<Vec<f32>> {
    Ok(take(b, off, 4 * n)?
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_u32(b: &[u8], off: &mut usize, n: usize) -> Result<Vec<u32>> {
    Ok(take(b, off, 4 * n)?
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_i32(b: &[u8], off: &mut usize, n: usize) -> Result<Vec<i32>> {
    Ok(take(b, off, 4 * n)?
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn read_i8(b: &[u8], off: &mut usize, n: usize) -> Result<Vec<i8>> {
    Ok(take(b, off, n)?.iter().map(|&x| x as i8).collect())
}

fn usz(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?.as_usize().ok_or_else(|| anyhow!("bad `{key}` in compressed header"))
}

impl CompressedModel {
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let shape_json =
            |w: &Tensor| Json::Arr(w.shape.iter().map(|&d| num(d as f64)).collect());
        let layer_hdr = |pl: &PackedLayer| -> Json {
            let mut f = vec![
                ("form", s(pl.form.tag())),
                ("nin", num(pl.in_live.len() as f64)),
                ("nout", num(pl.out_live.len() as f64)),
                ("live_div", num(pl.live_divisor as f64)),
            ];
            match &pl.form {
                PackedForm::Dense { w } => f.push(("wshape", shape_json(w))),
                PackedForm::DwMapped { w, in_pos } => {
                    f.push(("wshape", shape_json(w)));
                    f.push(("nmap", num(in_pos.len() as f64)));
                }
                PackedForm::SparseF32 { csr, values } => {
                    f.push(("rows", num(csr.rows as f64)));
                    f.push(("cols", num(csr.cols as f64)));
                    f.push(("nrp", num(csr.row_ptr.len() as f64)));
                    f.push(("nci", num(csr.col_idx.len() as f64)));
                    f.push(("nval", num(values.len() as f64)));
                }
                PackedForm::Int8 { csr, codes, scale_w } => {
                    f.push(("rows", num(csr.rows as f64)));
                    f.push(("cols", num(csr.cols as f64)));
                    f.push(("nrp", num(csr.row_ptr.len() as f64)));
                    f.push(("nci", num(csr.col_idx.len() as f64)));
                    f.push(("nval", num(codes.len() as f64)));
                    f.push(("scale_w", num(*scale_w as f64)));
                }
            }
            obj(f)
        };
        let header = obj(vec![
            ("version", num(COMPRESSED_FORMAT_VERSION as f64)),
            ("arch", s(&self.arch.name)),
            ("qbits_w", num(self.qbits.weight as f64)),
            ("qbits_a", num(self.qbits.act as f64)),
            ("exits_trained", Json::Bool(self.exits.trained)),
            ("exit_t1", num(self.exits.thresholds.map(|t| t.0).unwrap_or(-1.0) as f64)),
            ("exit_t2", num(self.exits.thresholds.map(|t| t.1).unwrap_or(-1.0) as f64)),
            ("exit_p1", num(self.exits.exit_probs.0)),
            ("exit_p2", num(self.exits.exit_probs.1)),
            ("history", Json::Arr(self.history.iter().map(|h| s(h)).collect())),
            ("layers", Json::Arr(self.layers.iter().map(layer_hdr).collect())),
        ]);
        let mut bytes = header.to_string().into_bytes();
        bytes.push(b'\n');
        let put_f32 = |bytes: &mut Vec<u8>, vs: &[f32]| {
            for v in vs {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        };
        let put_u32 = |bytes: &mut Vec<u8>, vs: &[u32]| {
            for v in vs {
                bytes.extend_from_slice(&v.to_le_bytes());
            }
        };
        for pl in &self.layers {
            put_f32(&mut bytes, &pl.bias);
            put_u32(&mut bytes, &pl.in_live);
            put_u32(&mut bytes, &pl.out_live);
            match &pl.form {
                PackedForm::Dense { w } => put_f32(&mut bytes, &w.data),
                PackedForm::DwMapped { w, in_pos } => {
                    put_f32(&mut bytes, &w.data);
                    for v in in_pos {
                        bytes.extend_from_slice(&v.to_le_bytes());
                    }
                }
                PackedForm::SparseF32 { csr, values } => {
                    put_u32(&mut bytes, &csr.row_ptr);
                    put_u32(&mut bytes, &csr.col_idx);
                    put_f32(&mut bytes, values);
                }
                PackedForm::Int8 { csr, codes, .. } => {
                    put_u32(&mut bytes, &csr.row_ptr);
                    put_u32(&mut bytes, &csr.col_idx);
                    bytes.extend(codes.iter().map(|&c| c as u8));
                }
            }
        }
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path.as_ref(), bytes)
            .with_context(|| format!("saving compressed model to {}", path.as_ref().display()))
    }

    pub fn load<P: AsRef<Path>>(path: P, arch: Arc<ArchManifest>) -> Result<CompressedModel> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("loading compressed model from {}", path.as_ref().display()))?;
        let nl = bytes
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| anyhow!("corrupt compressed model: no header"))?;
        let header = Json::parse(std::str::from_utf8(&bytes[..nl])?)
            .map_err(|e| anyhow!("corrupt compressed header: {e}"))?;
        let version = header.get("version").and_then(|v| v.as_f64()).unwrap_or(0.0);
        if version > COMPRESSED_FORMAT_VERSION as f64 {
            return Err(anyhow!(
                "compressed model is format v{version}, newer than supported \
                 v{COMPRESSED_FORMAT_VERSION}"
            ));
        }
        let got_arch = header.req("arch")?.as_str().unwrap_or("");
        ensure!(
            got_arch == arch.name,
            "compressed model is for arch `{got_arch}`, expected `{}`",
            arch.name
        );
        let lhdrs = header
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow!("compressed header: layers not an array"))?;
        ensure!(
            lhdrs.len() == arch.layers.len(),
            "compressed model has {} layers, arch `{}` has {}",
            lhdrs.len(),
            arch.name,
            arch.layers.len()
        );
        let mut off = nl + 1;
        let mut layers = Vec::with_capacity(lhdrs.len());
        for lh in lhdrs {
            let (nin, nout) = (usz(lh, "nin")?, usz(lh, "nout")?);
            let live_divisor = lh.req("live_div")?.as_f64().unwrap_or(1.0) as f32;
            let bias = read_f32(&bytes, &mut off, nout)?;
            let in_live = read_u32(&bytes, &mut off, nin)?;
            let out_live = read_u32(&bytes, &mut off, nout)?;
            let wshape = |lh: &Json| -> Result<Vec<usize>> {
                Ok(lh
                    .req("wshape")?
                    .as_arr()
                    .ok_or_else(|| anyhow!("bad wshape"))?
                    .iter()
                    .filter_map(|d| d.as_usize())
                    .collect())
            };
            let csr_of = |lh: &Json, b: &[u8], off: &mut usize| -> Result<(Bcsr, usize)> {
                let (rows, cols) = (usz(lh, "rows")?, usz(lh, "cols")?);
                let (nrp, nci, nval) = (usz(lh, "nrp")?, usz(lh, "nci")?, usz(lh, "nval")?);
                let row_ptr = read_u32(b, off, nrp)?;
                let col_idx = read_u32(b, off, nci)?;
                ensure!(
                    nrp == rows.div_ceil(BLOCK_R) + 1
                        && row_ptr.last().copied() == Some(nci as u32)
                        && nval == nci * BLOCK_LEN,
                    "corrupt compressed model: inconsistent blocked-CSR structure"
                );
                Ok((Bcsr { rows, cols, row_ptr, col_idx }, nval))
            };
            let form = match lh.req("form")?.as_str().unwrap_or("") {
                "dense" => {
                    let sh = wshape(lh)?;
                    let n = sh.iter().product::<usize>();
                    PackedForm::Dense { w: Tensor::new(sh, read_f32(&bytes, &mut off, n)?) }
                }
                "dw" => {
                    let sh = wshape(lh)?;
                    let n = sh.iter().product::<usize>();
                    let w = Tensor::new(sh, read_f32(&bytes, &mut off, n)?);
                    let in_pos = read_i32(&bytes, &mut off, usz(lh, "nmap")?)?;
                    PackedForm::DwMapped { w, in_pos }
                }
                "sparse" => {
                    let (csr, nval) = csr_of(lh, &bytes, &mut off)?;
                    PackedForm::SparseF32 { csr, values: read_f32(&bytes, &mut off, nval)? }
                }
                "int8" => {
                    let (csr, nval) = csr_of(lh, &bytes, &mut off)?;
                    let codes = read_i8(&bytes, &mut off, nval)?;
                    let scale_w = lh.req("scale_w")?.as_f64().unwrap_or(0.0) as f32;
                    PackedForm::Int8 { csr, codes, scale_w }
                }
                other => return Err(anyhow!("unknown packed form `{other}`")),
            };
            layers.push(PackedLayer { in_live, out_live, live_divisor, bias, form });
        }
        let t1 = header.req("exit_t1")?.as_f64().unwrap_or(-1.0) as f32;
        let t2 = header.req("exit_t2")?.as_f64().unwrap_or(-1.0) as f32;
        Ok(CompressedModel {
            arch,
            qbits: QBits {
                weight: header.req("qbits_w")?.as_f64().unwrap_or(0.0) as f32,
                act: header.req("qbits_a")?.as_f64().unwrap_or(0.0) as f32,
            },
            exits: ExitState {
                trained: header.req("exits_trained")?.as_bool().unwrap_or(false),
                thresholds: if t1 >= 0.0 { Some((t1, t2)) } else { None },
                exit_probs: (
                    header.req("exit_p1")?.as_f64().unwrap_or(0.0),
                    header.req("exit_p2")?.as_f64().unwrap_or(0.0),
                ),
            },
            layers,
            history: header
                .get("history")
                .and_then(|h| h.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builtin_ref_manifest;

    fn pruned_state(seed: u64, qbits: QBits) -> ModelState {
        let arch = builtin_ref_manifest().arch("mini_vgg").unwrap();
        let mut st = ModelState::init_host(arch, seed);
        // Deterministically kill every other channel in every slot.
        for m in &mut st.masks {
            for (i, v) in m.data.iter_mut().enumerate() {
                if i % 2 == 1 {
                    *v = 0.0;
                }
            }
        }
        st.qbits = qbits;
        st
    }

    #[test]
    fn bcsr_build_skips_dead_blocks_and_pads_edges() {
        // 6x10 matrix, nonzero only in (row 5, col 9): block-rows 0 has no
        // stored block, block-row 1 stores exactly block-col 1.
        let mut vals = Vec::new();
        let csr = Bcsr::build(
            6,
            10,
            |r, c| if r == 5 && c == 9 { 7.0 } else { 0.0 },
            |v: f32| v != 0.0,
            &mut vals,
        );
        assert_eq!(csr.row_ptr, vec![0, 0, 1]);
        assert_eq!(csr.col_idx, vec![1]);
        assert_eq!(vals.len(), BLOCK_LEN);
        // Row 5 = tile row 1, col 9 = tile col 1; everything else padded 0.
        for (i, &v) in vals.iter().enumerate() {
            let want = if i == BLOCK_C + 1 { 7.0 } else { 0.0 };
            assert_eq!(v, want, "tile entry {i}");
        }
        assert_eq!(csr.nblocks(), 1);
        assert_eq!(csr.block_rows(), 2);
    }

    #[test]
    fn lower_pruned_fp32_compacts_and_shrinks() {
        let st = pruned_state(11, QBits::FP32);
        let cm = CompressedModel::lower(&st).unwrap();
        assert_eq!(cm.layers.len(), st.arch.layers.len());
        // c1 (in unmasked, out slot 0 half-dead): 3 live inputs, 8 live outs.
        let c1 = &cm.layers[0];
        assert_eq!(c1.in_live.len(), 3);
        assert_eq!(c1.out_live, (0..16).step_by(2).collect::<Vec<u32>>());
        assert_eq!(c1.live_divisor, 8.0);
        assert!(matches!(c1.form, PackedForm::SparseF32 { .. }));
        // All body + exit layers are masked on at least one side -> sparse.
        for pl in &cm.layers {
            assert!(matches!(pl.form, PackedForm::SparseF32 { .. }), "{:?}", pl.form.tag());
        }
        let dense = CompressedModel::dense_bytes(&cm.arch);
        let packed = cm.packed_bytes();
        assert!(
            packed * 2 < dense,
            "half-pruned model should pack to well under half: {packed} vs {dense}"
        );
    }

    #[test]
    fn lower_unpruned_fp32_is_dense_fallback() {
        let arch = builtin_ref_manifest().arch("mini_vgg").unwrap();
        let st = ModelState::init_host(arch, 5);
        let cm = CompressedModel::lower(&st).unwrap();
        for (pl, l) in cm.layers.iter().zip(&st.arch.layers) {
            assert!(matches!(pl.form, PackedForm::Dense { .. }), "layer {}", l.name);
            assert_eq!(pl.in_live.len(), l.cin);
            assert_eq!(pl.out_live.len(), l.cout);
        }
        // fp32 dense fallback carries the identical weight values.
        if let PackedForm::Dense { w } = &cm.layers[0].form {
            assert_eq!(w.data, st.params[0].data);
        }
    }

    #[test]
    fn lower_int8_selects_and_codes_match_fake_quant() {
        let st = pruned_state(13, QBits { weight: 2.0, act: 8.0 });
        let cm = CompressedModel::lower(&st).unwrap();
        // Stem conv can't take integer input -> sparse f32; everything
        // downstream qualifies for int8.
        assert!(matches!(cm.layers[0].form, PackedForm::SparseF32 { .. }));
        for pl in &cm.layers[1..] {
            assert!(matches!(pl.form, PackedForm::Int8 { .. }), "{}", pl.form.tag());
        }
        // code * scale_w reproduces host_weight_quant up to one rounding.
        for (li, pl) in cm.layers.iter().enumerate() {
            let PackedForm::Int8 { csr, codes, scale_w } = &pl.form else { continue };
            let l = &st.arch.layers[li];
            let wq = host_weight_quant(&st.params[2 * li], st.qbits.weight);
            let wmax = st.params[2 * li].data.iter().fold(1e-8f32, |m, v| m.max(v.abs()));
            for br in 0..csr.block_rows() {
                for bi in csr.row_blocks(br) {
                    let bc = csr.col_idx[bi] as usize;
                    for rr in 0..BLOCK_R {
                        let ocl = br * BLOCK_R + rr;
                        if ocl >= csr.rows {
                            break;
                        }
                        for cc in 0..BLOCK_C {
                            let r = bc * BLOCK_C + cc;
                            if r >= csr.cols {
                                break;
                            }
                            let code = codes[bi * BLOCK_LEN + rr * BLOCK_C + cc];
                            // DoReFa codes are odd: never zero for a live entry.
                            assert_eq!(code.rem_euclid(2), 1_i8.rem_euclid(2));
                            let got = code as f32 * scale_w;
                            let want = wq.data[orig_index(l, &pl.in_live, &pl.out_live, ocl, r)];
                            assert!(
                                (got - want).abs() <= 1e-6 * wmax,
                                "layer {li} code {code} -> {got} vs {want}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn lower_rejects_non_binary_masks() {
        let mut st = pruned_state(3, QBits::FP32);
        st.masks[2].data[0] = 0.5;
        let err = CompressedModel::lower(&st).unwrap_err();
        assert!(err.to_string().contains("not binary"), "{err}");
    }

    #[test]
    fn fully_dead_slot_falls_back_to_one_folded_channel() {
        let mut st = pruned_state(7, QBits::FP32);
        for v in &mut st.masks[1].data {
            *v = 0.0;
        }
        let cm = CompressedModel::lower(&st).unwrap();
        // c2 writes slot 1: single fallback output channel, bias folded.
        let c2 = &cm.layers[1];
        assert_eq!(c2.out_live, vec![0]);
        assert_eq!(c2.bias, vec![0.0]);
        assert_eq!(c2.live_divisor, 1.0);
        // and every weight entry folded to +0 -> zero stored blocks.
        if let PackedForm::SparseF32 { csr, values } = &c2.form {
            assert_eq!(csr.nblocks(), 0);
            assert!(values.is_empty());
        } else {
            panic!("expected sparse form");
        }
        // c3 reads slot 1: single live input channel.
        assert_eq!(cm.layers[2].in_live, vec![0]);
    }

    #[test]
    fn save_load_roundtrip_and_stale_version_rejected() {
        let mut st = pruned_state(17, QBits { weight: 2.0, act: 8.0 });
        st.exits = ExitState {
            trained: true,
            thresholds: Some((0.8, 0.7)),
            exit_probs: (0.4, 0.3),
        };
        st.history.push("prune(0.5)".into());
        st.history.push("quantize(2w8a)".into());
        let cm = CompressedModel::lower(&st).unwrap();
        let path =
            std::env::temp_dir().join(format!("coc_cmp_{}.cmp", std::process::id()));
        cm.save(&path).unwrap();
        let cm2 = CompressedModel::load(&path, st.arch.clone()).unwrap();
        assert_eq!(cm.layers, cm2.layers);
        assert_eq!(cm.qbits, cm2.qbits);
        assert_eq!(cm.history, cm2.history);
        assert_eq!(cm2.exits.thresholds, Some((0.8, 0.7)));
        assert!(cm2.exits.trained);
        assert_eq!(cm.packed_bytes(), cm2.packed_bytes());

        // A header claiming a future format version is rejected outright.
        let bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = String::from_utf8(bytes[..nl].to_vec()).unwrap().replace(
            &format!("\"version\":{COMPRESSED_FORMAT_VERSION}"),
            "\"version\":99",
        );
        let mut patched = header.into_bytes();
        patched.extend_from_slice(&bytes[nl..]);
        std::fs::write(&path, &patched).unwrap();
        let err = CompressedModel::load(&path, st.arch.clone()).unwrap_err();
        assert!(err.to_string().contains("newer"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
