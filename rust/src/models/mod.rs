//! Model manifest (emitted by python/compile/aot.py) + host-side model
//! state + BitOps / storage accounting.
//!
//! The manifest is the single source of truth the coordinator shares with
//! the L2 graphs: parameter order/shapes, mask slots, per-layer geometry.
//! All compression metrics (BitOpsCR, CR) are computed here from layer
//! descriptors + the current masks/bit-widths — the same *analytic*
//! accounting the paper uses (BitOps are counted, not measured).

pub mod compressed;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

pub const FP_BITS: f64 = 32.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    DwConv,
    Dense,
}

#[derive(Debug, Clone)]
pub struct LayerDesc {
    pub name: String,
    pub kind: LayerKind,
    pub k: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub hout: usize,
    pub wout: usize,
    /// Mask slot feeding this layer's input channels (-1 = unmasked).
    pub in_mask: i64,
    /// Mask slot over this layer's output channels (-1 = unmasked).
    pub out_mask: i64,
    /// "seg1" | "seg2" | "seg3" | "exit1" | "exit2".
    pub segment: String,
    /// Producer node this layer consumes: `""` means the previous body
    /// layer in declaration order (the legacy feed-forward chain),
    /// `"@input"` the raw graph input, otherwise a layer or join name.
    pub input: String,
    /// Conv activation flag: `false` stops the op pipeline after the
    /// norm (no relu, no activation quantization) — used by pre-join
    /// convs and 1x1 projections whose non-linearity lives in the join.
    pub act: bool,
}

/// A DAG join node: `b: Some` computes `relu(a + b)` then activation
/// quantization then the `out_mask` multiply (the residual add of
/// `archs.py::finish_block`); `b: None` is a unary terminal (act-quant +
/// mask only — the MobileNet linear-bottleneck block output).  Joins own
/// no parameters and appear only in `ArchManifest::joins`, so the
/// params-are-(w,b)-pairs-in-layer-order contract is untouched.
#[derive(Debug, Clone)]
pub struct JoinDesc {
    pub name: String,
    /// Primary operand (the block body's last conv), by node name.
    pub a: String,
    /// Skip operand (identity or 1x1 projection output), by node name.
    pub b: Option<String>,
    /// Mask slot applied after the join's activation quantization
    /// (-1 = unmasked).
    pub out_mask: i64,
    /// "seg1" | "seg2" | "seg3" — joins never live in exit heads.
    pub segment: String,
}

#[derive(Debug, Clone)]
pub struct MaskSlot {
    pub name: String,
    pub channels: usize,
}

#[derive(Debug, Clone)]
pub struct ArchManifest {
    pub name: String,
    pub num_classes: usize,
    pub layers: Vec<LayerDesc>,
    pub mask_slots: Vec<MaskSlot>,
    pub param_shapes: Vec<Vec<usize>>,
    /// graph tag ("train", "eval", "init", "stage1"...) -> artifact file.
    pub graphs: BTreeMap<String, String>,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub stage_batch: usize,
    /// All batch sizes the staged serving graphs were lowered at (always
    /// contains 1; larger entries are the micro-batched variants with
    /// graph tags like `stage1_b8`).
    pub stage_batches: Vec<usize>,
    pub stage_h1_shape: Vec<usize>,
    pub stage_h2_shape: Vec<usize>,
    /// Skip/terminal join nodes (empty = pure feed-forward chain).
    pub joins: Vec<JoinDesc>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub num_classes: usize,
    pub input_hw: usize,
    pub input_c: usize,
    pub archs: BTreeMap<String, Arc<ArchManifest>>,
    /// kernel bench name -> artifact file.
    pub kernels: BTreeMap<String, String>,
}

impl Manifest {
    pub fn load<P: AsRef<Path>>(artifacts_dir: P) -> Result<Manifest> {
        let path = artifacts_dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let input = j.req("input")?;
        let mut archs = BTreeMap::new();
        for (name, aj) in j.req("archs")?.as_obj().ok_or_else(|| anyhow!("archs not an object"))? {
            archs.insert(name.clone(), Arc::new(parse_arch(aj)?));
        }
        let mut kernels = BTreeMap::new();
        if let Some(kj) = j.get("kernels").and_then(|k| k.as_obj()) {
            for (name, v) in kj {
                if let Some(f) = v.get("file").and_then(|f| f.as_str()) {
                    kernels.insert(name.clone(), f.to_string());
                }
            }
        }
        Ok(Manifest {
            num_classes: j.req("num_classes")?.as_usize().unwrap_or(20),
            input_hw: input.req("h")?.as_usize().unwrap_or(16),
            input_c: input.req("c")?.as_usize().unwrap_or(3),
            archs,
            kernels,
        })
    }

    pub fn arch(&self, name: &str) -> Result<Arc<ArchManifest>> {
        self.archs
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown arch `{name}` (have: {:?})", self.archs.keys()))
    }
}

fn parse_arch(j: &Json) -> Result<ArchManifest> {
    let layers = j
        .req("layers")?
        .as_arr()
        .ok_or_else(|| anyhow!("layers not an array"))?
        .iter()
        .map(parse_layer)
        .collect::<Result<Vec<_>>>()?;
    let mask_slots = j
        .req("mask_slots")?
        .as_arr()
        .ok_or_else(|| anyhow!("mask_slots not an array"))?
        .iter()
        .map(|m| {
            Ok(MaskSlot {
                name: m.req("name")?.as_str().unwrap_or("").to_string(),
                channels: m.req("channels")?.as_usize().unwrap_or(0),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let param_shapes = j
        .req("param_shapes")?
        .as_arr()
        .ok_or_else(|| anyhow!("param_shapes not an array"))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| anyhow!("param shape not an array"))
                .map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect())
        })
        .collect::<Result<Vec<_>>>()?;
    let mut graphs = BTreeMap::new();
    for (tag, g) in j.req("graphs")?.as_obj().ok_or_else(|| anyhow!("graphs not an object"))? {
        graphs.insert(
            tag.clone(),
            g.req("file")?.as_str().unwrap_or("").to_string(),
        );
    }
    let usz_arr = |key: &str| -> Result<Vec<usize>> {
        Ok(j.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow!("{key} not an array"))?
            .iter()
            .filter_map(|d| d.as_usize())
            .collect())
    };
    // Absent in pre-DAG manifests: feed-forward chain.
    let joins = match j.get("joins").and_then(|a| a.as_arr()) {
        Some(arr) => arr
            .iter()
            .map(|jj| {
                Ok(JoinDesc {
                    name: jj.req("name")?.as_str().unwrap_or("").to_string(),
                    a: jj.req("a")?.as_str().unwrap_or("").to_string(),
                    b: jj.get("b").and_then(|s| s.as_str()).map(String::from),
                    out_mask: jj.get("out_mask").and_then(|v| v.as_i64()).unwrap_or(-1),
                    segment: jj.req("segment")?.as_str().unwrap_or("seg1").to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?,
        None => Vec::new(),
    };
    Ok(ArchManifest {
        name: j.req("name")?.as_str().unwrap_or("").to_string(),
        num_classes: j.req("num_classes")?.as_usize().unwrap_or(20),
        layers,
        mask_slots,
        param_shapes,
        graphs,
        train_batch: j.req("train_batch")?.as_usize().unwrap_or(32),
        eval_batch: j.req("eval_batch")?.as_usize().unwrap_or(64),
        stage_batch: j.req("stage_batch")?.as_usize().unwrap_or(1),
        // Absent in pre-micro-batching manifests: batch-1 only.
        stage_batches: j
            .get("stage_batches")
            .and_then(|a| a.as_arr())
            .map(|a| a.iter().filter_map(|d| d.as_usize()).collect())
            .unwrap_or_else(|| vec![1]),
        stage_h1_shape: usz_arr("stage_h1_shape")?,
        stage_h2_shape: usz_arr("stage_h2_shape")?,
        joins,
    })
}

fn parse_layer(j: &Json) -> Result<LayerDesc> {
    let kind = match j.req("kind")?.as_str() {
        Some("conv") => LayerKind::Conv,
        Some("dwconv") => LayerKind::DwConv,
        Some("dense") => LayerKind::Dense,
        other => return Err(anyhow!("unknown layer kind {other:?}")),
    };
    Ok(LayerDesc {
        name: j.req("name")?.as_str().unwrap_or("").to_string(),
        kind,
        k: j.req("k")?.as_usize().unwrap_or(1),
        cin: j.req("cin")?.as_usize().unwrap_or(0),
        cout: j.req("cout")?.as_usize().unwrap_or(0),
        stride: j.req("stride")?.as_usize().unwrap_or(1),
        hout: j.req("hout")?.as_usize().unwrap_or(1),
        wout: j.req("wout")?.as_usize().unwrap_or(1),
        in_mask: j.req("in_mask")?.as_i64().unwrap_or(-1),
        out_mask: j.req("out_mask")?.as_i64().unwrap_or(-1),
        segment: j.req("segment")?.as_str().unwrap_or("seg1").to_string(),
        // Absent in pre-DAG manifests: chain from the previous layer,
        // full activation pipeline.
        input: j.get("input").and_then(|s| s.as_str()).unwrap_or("").to_string(),
        act: j.get("act").and_then(|b| b.as_bool()).unwrap_or(true),
    })
}

impl ArchManifest {
    pub fn graph(&self, tag: &str) -> Result<&str> {
        self.graphs
            .get(tag)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("arch `{}` has no graph `{tag}`", self.name))
    }

    /// Tag of a staged serving graph at the given batch size (`stage1` at
    /// batch 1, `stage1_b8` at batch 8, ...).
    pub fn stage_graph_tag(stage: u8, batch: usize) -> String {
        if batch <= 1 {
            format!("stage{stage}")
        } else {
            format!("stage{stage}_b{batch}")
        }
    }

    /// Largest lowered stage batch size that is <= `cap` (1 when only the
    /// batch-1 graphs exist).
    pub fn best_stage_batch(&self, cap: usize) -> usize {
        self.stage_batches
            .iter()
            .copied()
            .filter(|&b| b <= cap && self.graphs.contains_key(&Self::stage_graph_tag(1, b)))
            .max()
            .unwrap_or(1)
            .max(1)
    }

    pub fn num_params(&self) -> usize {
        self.param_shapes.len()
    }

    /// Index of the (weight) param for layer `li`: params are (w, b) pairs
    /// in layer order.
    pub fn weight_index(&self, li: usize) -> usize {
        2 * li
    }

    pub fn layer_index(&self, name: &str) -> Option<usize> {
        self.layers.iter().position(|l| l.name == name)
    }
}

// ---------------------------------------------------------------------------
// Built-in reference manifest.
// ---------------------------------------------------------------------------

/// Per-layer weight+bias shapes in layer order (the (w, b) pair contract).
fn ref_param_shapes(layers: &[LayerDesc]) -> Vec<Vec<usize>> {
    layers
        .iter()
        .flat_map(|l| {
            let w = match l.kind {
                LayerKind::Dense => vec![l.cin, l.cout],
                LayerKind::DwConv => vec![l.k, l.k, 1, l.cout],
                LayerKind::Conv => vec![l.k, l.k, l.cin, l.cout],
            };
            [w, vec![l.cout]]
        })
        .collect()
}

/// Every graph tag the AOT path would lower for `arch` (batch 1 and 8
/// staged variants); the `ref://` values are never opened.
fn ref_graph_map(arch: &str) -> BTreeMap<String, String> {
    let mut graphs = BTreeMap::new();
    for tag in ["init", "train", "eval"] {
        graphs.insert(tag.to_string(), format!("ref://{arch}/{tag}"));
    }
    for stage in 1..=3u8 {
        for batch in [1usize, 8] {
            let tag = ArchManifest::stage_graph_tag(stage, batch);
            graphs.insert(tag.clone(), format!("ref://{arch}/{tag}"));
        }
    }
    graphs
}

fn mini_vgg_arch() -> ArchManifest {
    let conv = |name: &str,
                cin: usize,
                cout: usize,
                hout: usize,
                in_mask: i64,
                out_mask: i64,
                segment: &str| LayerDesc {
        name: name.into(),
        kind: LayerKind::Conv,
        k: 3,
        cin,
        cout,
        stride: 1,
        hout,
        wout: hout,
        in_mask,
        out_mask,
        segment: segment.into(),
        input: String::new(),
        act: true,
    };
    let dense = |name: &str, cin: usize, in_mask: i64, segment: &str| LayerDesc {
        name: name.into(),
        kind: LayerKind::Dense,
        k: 1,
        cin,
        cout: 20,
        stride: 1,
        hout: 1,
        wout: 1,
        in_mask,
        out_mask: -1,
        segment: segment.into(),
        input: String::new(),
        act: true,
    };
    let layers = vec![
        conv("c1", 3, 16, 16, -1, 0, "seg1"),
        conv("c2", 16, 16, 16, 0, 1, "seg1"),
        conv("c3", 16, 32, 8, 1, 2, "seg2"),
        conv("c4", 32, 32, 8, 2, 3, "seg2"),
        conv("c5", 32, 64, 4, 3, 4, "seg3"),
        conv("c6", 64, 64, 4, 4, 5, "seg3"),
        dense("fc", 64, 5, "seg3"),
        dense("exit1_fc", 16, 1, "exit1"),
        dense("exit2_fc", 32, 3, "exit2"),
    ];
    let mask_slots = ["c1", "c2", "c3", "c4", "c5", "c6"]
        .iter()
        .zip([16usize, 16, 32, 32, 64, 64])
        .map(|(name, channels)| MaskSlot { name: (*name).into(), channels })
        .collect();
    let param_shapes = ref_param_shapes(&layers);
    ArchManifest {
        name: "mini_vgg".into(),
        num_classes: 20,
        layers,
        mask_slots,
        param_shapes,
        graphs: ref_graph_map("mini_vgg"),
        train_batch: 32,
        eval_batch: 64,
        stage_batch: 1,
        stage_batches: vec![1, 8],
        stage_h1_shape: vec![1, 16, 16, 16],
        stage_h2_shape: vec![1, 8, 8, 32],
        joins: Vec::new(),
    }
}

/// Host-side MiniResNet (`archs.py::MiniResNet`): three stages of two
/// basic blocks on a 16x16x3 input.  Residual joins carry the *stage*
/// mask slot and every pre-join conv (`*b`) and 1x1 projection (`*p`)
/// writes into that same slot (`act: false` — their non-linearity lives
/// in the join), so both operands of every skip add share one live set —
/// the coupled-channel constraint residual pruning always imposes.
/// Interior `*a` convs own independent block mask slots.
fn mini_resnet_arch() -> ArchManifest {
    let conv = |name: &str,
                k: usize,
                cin: usize,
                cout: usize,
                stride: usize,
                hout: usize,
                in_mask: i64,
                out_mask: i64,
                segment: &str,
                input: &str,
                act: bool| LayerDesc {
        name: name.into(),
        kind: LayerKind::Conv,
        k,
        cin,
        cout,
        stride,
        hout,
        wout: hout,
        in_mask,
        out_mask,
        segment: segment.into(),
        input: input.into(),
        act,
    };
    let dense = |name: &str, cin: usize, in_mask: i64, segment: &str, input: &str| LayerDesc {
        name: name.into(),
        kind: LayerKind::Dense,
        k: 1,
        cin,
        cout: 20,
        stride: 1,
        hout: 1,
        wout: 1,
        in_mask,
        out_mask: -1,
        segment: segment.into(),
        input: input.into(),
        act: true,
    };
    let join = |name: &str, a: &str, b: &str, out_mask: i64, segment: &str| JoinDesc {
        name: name.into(),
        a: a.into(),
        b: Some(b.into()),
        out_mask,
        segment: segment.into(),
    };
    // Mask slots: 0=s1 1=b11 2=b12 3=s2 4=b21 5=b22 6=s3 7=b31 8=b32.
    let layers = vec![
        conv("stem", 3, 3, 16, 1, 16, -1, 0, "seg1", "@input", true),
        conv("b11a", 3, 16, 16, 1, 16, 0, 1, "seg1", "stem", true),
        conv("b11b", 3, 16, 16, 1, 16, 1, 0, "seg1", "b11a", false),
        conv("b12a", 3, 16, 16, 1, 16, 0, 2, "seg1", "j11", true),
        conv("b12b", 3, 16, 16, 1, 16, 2, 0, "seg1", "b12a", false),
        conv("b21a", 3, 16, 32, 2, 8, 0, 4, "seg2", "j12", true),
        conv("b21b", 3, 32, 32, 1, 8, 4, 3, "seg2", "b21a", false),
        conv("b21p", 1, 16, 32, 2, 8, 0, 3, "seg2", "j12", false),
        conv("b22a", 3, 32, 32, 1, 8, 3, 5, "seg2", "j21", true),
        conv("b22b", 3, 32, 32, 1, 8, 5, 3, "seg2", "b22a", false),
        conv("b31a", 3, 32, 64, 2, 4, 3, 7, "seg3", "j22", true),
        conv("b31b", 3, 64, 64, 1, 4, 7, 6, "seg3", "b31a", false),
        conv("b31p", 1, 32, 64, 2, 4, 3, 6, "seg3", "j22", false),
        conv("b32a", 3, 64, 64, 1, 4, 6, 8, "seg3", "j31", true),
        conv("b32b", 3, 64, 64, 1, 4, 8, 6, "seg3", "b32a", false),
        dense("fc", 64, 6, "seg3", "j32"),
        dense("exit1_fc", 16, 0, "exit1", ""),
        dense("exit2_fc", 32, 3, "exit2", ""),
    ];
    let joins = vec![
        join("j11", "b11b", "stem", 0, "seg1"),
        join("j12", "b12b", "j11", 0, "seg1"),
        join("j21", "b21b", "b21p", 3, "seg2"),
        join("j22", "b22b", "j21", 3, "seg2"),
        join("j31", "b31b", "b31p", 6, "seg3"),
        join("j32", "b32b", "j31", 6, "seg3"),
    ];
    let mask_slots = ["s1", "b11", "b12", "s2", "b21", "b22", "s3", "b31", "b32"]
        .iter()
        .zip([16usize, 16, 16, 32, 32, 32, 64, 64, 64])
        .map(|(name, channels)| MaskSlot { name: (*name).into(), channels })
        .collect();
    let param_shapes = ref_param_shapes(&layers);
    ArchManifest {
        name: "mini_resnet".into(),
        num_classes: 20,
        layers,
        mask_slots,
        param_shapes,
        graphs: ref_graph_map("mini_resnet"),
        train_batch: 32,
        eval_batch: 64,
        stage_batch: 1,
        stage_batches: vec![1, 8],
        stage_h1_shape: vec![1, 16, 16, 16],
        stage_h2_shape: vec![1, 8, 8, 32],
        joins,
    }
}

/// Host-side MiniMobileNet (`archs.py::MiniMobileNet`): inverted
/// residual bottlenecks — 1x1 expand, 3x3 depthwise, 1x1 linear project
/// (`act: false`).  Blocks 1-4 change channel counts so their outputs
/// are *unary* terminals (`b: None` — act-quant + mask, no relu, no
/// add); block 5 projects back to block 4's width and is the one true
/// residual join.  Depthwise convs share their expand slot's mask
/// (depthwise channels are structurally coupled to their inputs).
fn mini_mobilenet_arch() -> ArchManifest {
    let conv = |name: &str,
                kind: LayerKind,
                k: usize,
                cin: usize,
                cout: usize,
                stride: usize,
                hout: usize,
                in_mask: i64,
                out_mask: i64,
                segment: &str,
                input: &str,
                act: bool| LayerDesc {
        name: name.into(),
        kind,
        k,
        cin,
        cout,
        stride,
        hout,
        wout: hout,
        in_mask,
        out_mask,
        segment: segment.into(),
        input: input.into(),
        act,
    };
    let unary = |name: &str, a: &str, out_mask: i64, segment: &str| JoinDesc {
        name: name.into(),
        a: a.into(),
        b: None,
        out_mask,
        segment: segment.into(),
    };
    use LayerKind::{Conv, DwConv};
    // Mask slots: 0=stem 1=e1 2=o1 3=e2 4=o2 5=e3 6=o3 7=e4 8=o4 9=e5.
    let layers = vec![
        conv("stem", Conv, 3, 3, 16, 1, 16, -1, 0, "seg1", "@input", true),
        conv("b1e", Conv, 1, 16, 32, 1, 16, 0, 1, "seg1", "stem", true),
        conv("b1d", DwConv, 3, 32, 32, 1, 16, 1, 1, "seg1", "b1e", true),
        conv("b1p", Conv, 1, 32, 24, 1, 16, 1, 2, "seg1", "b1d", false),
        conv("b2e", Conv, 1, 24, 48, 1, 16, 2, 3, "seg1", "t1", true),
        conv("b2d", DwConv, 3, 48, 48, 2, 8, 3, 3, "seg1", "b2e", true),
        conv("b2p", Conv, 1, 48, 32, 1, 8, 3, 4, "seg1", "b2d", false),
        conv("b3e", Conv, 1, 32, 64, 1, 8, 4, 5, "seg2", "t2", true),
        conv("b3d", DwConv, 3, 64, 64, 2, 4, 5, 5, "seg2", "b3e", true),
        conv("b3p", Conv, 1, 64, 64, 1, 4, 5, 6, "seg2", "b3d", false),
        conv("b4e", Conv, 1, 64, 128, 1, 4, 6, 7, "seg3", "t3", true),
        conv("b4d", DwConv, 3, 128, 128, 1, 4, 7, 7, "seg3", "b4e", true),
        conv("b4p", Conv, 1, 128, 96, 1, 4, 7, 8, "seg3", "b4d", false),
        conv("b5e", Conv, 1, 96, 192, 1, 4, 8, 9, "seg3", "t4", true),
        conv("b5d", DwConv, 3, 192, 192, 1, 4, 9, 9, "seg3", "b5e", true),
        conv("b5p", Conv, 1, 192, 96, 1, 4, 9, 8, "seg3", "b5d", false),
        LayerDesc {
            name: "fc".into(),
            kind: LayerKind::Dense,
            k: 1,
            cin: 96,
            cout: 20,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: 8,
            out_mask: -1,
            segment: "seg3".into(),
            input: "j5".into(),
            act: true,
        },
        LayerDesc {
            name: "exit1_fc".into(),
            kind: LayerKind::Dense,
            k: 1,
            cin: 32,
            cout: 20,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: 4,
            out_mask: -1,
            segment: "exit1".into(),
            input: String::new(),
            act: true,
        },
        LayerDesc {
            name: "exit2_fc".into(),
            kind: LayerKind::Dense,
            k: 1,
            cin: 64,
            cout: 20,
            stride: 1,
            hout: 1,
            wout: 1,
            in_mask: 6,
            out_mask: -1,
            segment: "exit2".into(),
            input: String::new(),
            act: true,
        },
    ];
    let joins = vec![
        unary("t1", "b1p", 2, "seg1"),
        unary("t2", "b2p", 4, "seg1"),
        unary("t3", "b3p", 6, "seg2"),
        unary("t4", "b4p", 8, "seg3"),
        JoinDesc {
            name: "j5".into(),
            a: "b5p".into(),
            b: Some("t4".into()),
            out_mask: 8,
            segment: "seg3".into(),
        },
    ];
    let mask_slots = ["stem", "e1", "o1", "e2", "o2", "e3", "o3", "e4", "o4", "e5"]
        .iter()
        .zip([16usize, 32, 24, 48, 32, 64, 64, 128, 96, 192])
        .map(|(name, channels)| MaskSlot { name: (*name).into(), channels })
        .collect();
    let param_shapes = ref_param_shapes(&layers);
    ArchManifest {
        name: "mini_mobilenet".into(),
        num_classes: 20,
        layers,
        mask_slots,
        param_shapes,
        graphs: ref_graph_map("mini_mobilenet"),
        train_batch: 32,
        eval_batch: 64,
        stage_batch: 1,
        stage_batches: vec![1, 8],
        stage_h1_shape: vec![1, 8, 8, 32],
        stage_h2_shape: vec![1, 4, 4, 64],
        joins,
    }
}

/// Arch names served by [`builtin_ref_manifest`] — the hermetic test
/// matrix iterates exactly this list.
pub const BUILTIN_REF_ARCHS: [&str; 3] = ["mini_vgg", "mini_resnet", "mini_mobilenet"];

/// Host-side replica of the MiniVGG / MiniResNet / MiniMobileNet
/// manifests (`python/compile/archs.py` + the aot.py manifest fields),
/// so the reference backend can drive the whole CLI with no
/// `make artifacts` step.  The graph maps declare every tag the AOT path
/// would lower (the ref backend resolves tags against these maps; the
/// `ref://` values are never opened).
///
/// One geometry difference from the AOT lowering is deliberate: the ref
/// backend pools lazily *before* the conv that needs a smaller input, so
/// its exit-cut features are the pre-pool segment outputs
/// (`stage_h1_shape` [1, 16, 16, 16] instead of the JAX cut's
/// [1, 8, 8, 16] for mini_vgg).  Stage graphs and eval share the cut by
/// construction, so the serving contract is unaffected.
pub fn builtin_ref_manifest() -> Manifest {
    let mut archs = BTreeMap::new();
    archs.insert("mini_vgg".to_string(), Arc::new(mini_vgg_arch()));
    archs.insert("mini_resnet".to_string(), Arc::new(mini_resnet_arch()));
    archs.insert("mini_mobilenet".to_string(), Arc::new(mini_mobilenet_arch()));
    Manifest {
        num_classes: 20,
        input_hw: 16,
        input_c: 3,
        archs,
        kernels: BTreeMap::new(),
    }
}

// ---------------------------------------------------------------------------
// Model state: everything that evolves along the compression chain.
// ---------------------------------------------------------------------------

/// Quantization setting: 0 bits = fp32 path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QBits {
    pub weight: f32,
    pub act: f32,
}

impl QBits {
    pub const FP32: QBits = QBits { weight: 0.0, act: 0.0 };

    pub fn effective_w(&self) -> f64 {
        if self.weight <= 0.0 {
            FP_BITS
        } else {
            self.weight as f64
        }
    }

    pub fn effective_a(&self) -> f64 {
        if self.act <= 0.0 {
            FP_BITS
        } else {
            self.act as f64
        }
    }
}

/// Early-exit deployment state: thresholds on max-softmax confidence plus
/// the measured exit distribution (filled in by exits::calibrate).
#[derive(Debug, Clone, Default)]
pub struct ExitState {
    pub trained: bool,
    pub thresholds: Option<(f32, f32)>,
    /// Measured P(exit at 1), P(exit at 2) on the calibration set.
    pub exit_probs: (f64, f64),
}

/// Storage-side compression applied host-side (Deep-Compression baseline
/// stages): weight clustering (codebook) and entropy coding.  These change
/// the *storage* accounting; compute (BitOps) is governed by qbits/masks.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StorageExtras {
    /// log2(#centroids) bits/weight after clustering (None = unclustered).
    pub cluster_bits: Option<f32>,
    /// Measured entropy-coded total weight bits (None = uncoded).  Set by
    /// the HuffmanCoding stage; includes code-table side information.
    pub coded_weight_bits: Option<f64>,
}

#[derive(Clone)]
pub struct ModelState {
    pub arch: Arc<ArchManifest>,
    pub params: Vec<Tensor>,
    pub momenta: Vec<Tensor>,
    pub masks: Vec<Tensor>,
    pub qbits: QBits,
    pub exits: ExitState,
    pub extras: StorageExtras,
    /// Human-readable provenance: compression stages applied so far.
    pub history: Vec<String>,
}

impl ModelState {
    /// Host-side init (unit tests / no-artifact paths): He-normal weights,
    /// zero biases — mirrors `Net.init_params` in archs.py.
    pub fn init_host(arch: Arc<ArchManifest>, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed);
        let mut params = Vec::with_capacity(arch.param_shapes.len());
        for (li, l) in arch.layers.iter().enumerate() {
            let wshape = &arch.param_shapes[2 * li];
            let fan_in = match l.kind {
                LayerKind::Dense => l.cin,
                LayerKind::DwConv => l.k * l.k,
                LayerKind::Conv => l.k * l.k * l.cin,
            };
            params.push(Tensor::he_normal(wshape, fan_in, &mut rng));
            params.push(Tensor::zeros(&arch.param_shapes[2 * li + 1]));
        }
        let momenta = params.iter().map(|p| Tensor::zeros(&p.shape)).collect();
        let masks = arch
            .mask_slots
            .iter()
            .map(|m| Tensor::ones(&[m.channels]))
            .collect();
        ModelState {
            arch,
            params,
            momenta,
            masks,
            qbits: QBits::FP32,
            exits: ExitState::default(),
            extras: StorageExtras::default(),
            history: Vec::new(),
        }
    }

    pub fn reset_momenta(&mut self) {
        for m in &mut self.momenta {
            m.data.iter_mut().for_each(|v| *v = 0.0);
        }
    }

    /// Active (unmasked) channel count for a mask slot id; `-1` = `full`.
    pub fn active_channels(&self, slot: i64, full: usize) -> usize {
        if slot < 0 {
            full
        } else {
            self.masks[slot as usize].count_nonzero()
        }
    }

    /// Fraction of channels kept across all mask slots (1.0 = unpruned).
    pub fn keep_fraction(&self) -> f64 {
        let total: usize = self.arch.mask_slots.iter().map(|m| m.channels).sum();
        let live: usize = self.masks.iter().map(|m| m.count_nonzero()).sum();
        live as f64 / total.max(1) as f64
    }
}

// ---------------------------------------------------------------------------
// Persistence: cache trained states (base teachers, plan-cache snapshots)
// across experiments.  Format: one JSON header line (version + shapes +
// metadata + optional content-address tag), then raw little-endian f32
// for params ++ momenta ++ masks.
// ---------------------------------------------------------------------------

/// On-disk state format version.  v1 files (no `version` field) still
/// load; files newer than this are rejected instead of misparsed.
pub const STATE_FORMAT_VERSION: u32 = 2;

/// FNV-1a 64 over the raw f32 payload — the integrity checksum written
/// into the state header.  Stored as a hex string because a u64 does not
/// survive a JSON f64 round-trip.  Headers without the field (files
/// written before the checksum existed) load unverified.
fn payload_fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl ModelState {
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        self.save_tagged(path, None)
    }

    /// Save with an optional content-address tag (the plan-cache node id):
    /// the tag is written into the header, and `load_tagged` with an
    /// expected tag refuses snapshots produced by a different recipe.
    pub fn save_tagged<P: AsRef<Path>>(&self, path: P, node: Option<&str>) -> Result<()> {
        use crate::util::json::{num, obj, s, Json};
        let shapes = |ts: &[Tensor]| {
            Json::Arr(
                ts.iter()
                    .map(|t| Json::Arr(t.shape.iter().map(|&d| num(d as f64)).collect()))
                    .collect(),
            )
        };
        let mut fields = vec![
            ("version", num(STATE_FORMAT_VERSION as f64)),
            ("arch", s(&self.arch.name)),
            ("params", shapes(&self.params)),
            ("momenta", shapes(&self.momenta)),
            ("masks", shapes(&self.masks)),
            ("qbits_w", num(self.qbits.weight as f64)),
            ("qbits_a", num(self.qbits.act as f64)),
            ("exits_trained", Json::Bool(self.exits.trained)),
            ("exit_t1", num(self.exits.thresholds.map(|t| t.0).unwrap_or(-1.0) as f64)),
            ("exit_t2", num(self.exits.thresholds.map(|t| t.1).unwrap_or(-1.0) as f64)),
            ("exit_p1", num(self.exits.exit_probs.0)),
            ("exit_p2", num(self.exits.exit_probs.1)),
            ("cluster_bits", num(self.extras.cluster_bits.unwrap_or(-1.0) as f64)),
            ("coded_weight_bits", num(self.extras.coded_weight_bits.unwrap_or(-1.0))),
            (
                "history",
                Json::Arr(self.history.iter().map(|h| s(h)).collect()),
            ),
        ];
        if let Some(tag) = node {
            fields.push(("node", s(tag)));
        }
        let mut payload = Vec::new();
        for t in self.params.iter().chain(&self.momenta).chain(&self.masks) {
            for v in &t.data {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        fields.push(("checksum", s(&format!("{:016x}", payload_fnv64(&payload)))));
        let header = obj(fields);
        let mut bytes = header.to_string().into_bytes();
        bytes.push(b'\n');
        bytes.extend_from_slice(&payload);
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path.as_ref(), bytes)
            .with_context(|| format!("saving state to {}", path.as_ref().display()))
    }

    pub fn load<P: AsRef<Path>>(path: P, arch: Arc<ArchManifest>) -> Result<ModelState> {
        Self::load_tagged(path, arch, None)
    }

    /// Load, additionally verifying the header's format version and —
    /// when `node` is given — its content-address tag.  A missing or
    /// mismatched tag is an error, which plan-cache callers treat as a
    /// cache miss.
    pub fn load_tagged<P: AsRef<Path>>(
        path: P,
        arch: Arc<ArchManifest>,
        node: Option<&str>,
    ) -> Result<ModelState> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("loading state from {}", path.as_ref().display()))?;
        let nl = bytes
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| anyhow!("corrupt state file: no header"))?;
        let header = Json::parse(std::str::from_utf8(&bytes[..nl])?)
            .map_err(|e| anyhow!("corrupt state header: {e}"))?;
        // v1 files predate the version field.
        let version = header.get("version").and_then(|v| v.as_f64()).unwrap_or(1.0);
        if version > STATE_FORMAT_VERSION as f64 {
            return Err(anyhow!(
                "state file is format v{version}, newer than supported v{STATE_FORMAT_VERSION}"
            ));
        }
        // Payload integrity: headers written with a checksum must match
        // the bytes that follow — a truncated or bit-flipped snapshot is
        // an error here, not a garbage model later.  Checksum-less
        // headers (older files) still load.
        if let Some(want) = header.get("checksum").and_then(|v| v.as_str()) {
            let got = format!("{:016x}", payload_fnv64(&bytes[nl + 1..]));
            if got != want {
                return Err(anyhow!(
                    "corrupt state file: payload checksum {got} != header {want}"
                ));
            }
        }
        if let Some(want) = node {
            let got = header.get("node").and_then(|v| v.as_str()).unwrap_or("");
            if got != want {
                return Err(anyhow!(
                    "state file node tag `{got}` does not match expected `{want}`"
                ));
            }
        }
        let got_arch = header.req("arch")?.as_str().unwrap_or("");
        if got_arch != arch.name {
            return Err(anyhow!("state file is for arch `{got_arch}`, expected `{}`", arch.name));
        }
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
            Ok(header
                .req(key)?
                .as_arr()
                .ok_or_else(|| anyhow!("bad shapes"))?
                .iter()
                .map(|s| s.as_arr().unwrap_or(&[]).iter().filter_map(|d| d.as_usize()).collect())
                .collect())
        };
        let mut off = nl + 1;
        let mut read_group = |shapes: Vec<Vec<usize>>| -> Result<Vec<Tensor>> {
            let mut out = Vec::with_capacity(shapes.len());
            for shape in shapes {
                let n: usize = shape.iter().product();
                let end = off + n * 4;
                if end > bytes.len() {
                    return Err(anyhow!("corrupt state file: truncated data"));
                }
                let data = bytes[off..end]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                off = end;
                out.push(Tensor::new(shape, data));
            }
            Ok(out)
        };
        let params = read_group(shapes("params")?)?;
        let momenta = read_group(shapes("momenta")?)?;
        let masks = read_group(shapes("masks")?)?;
        let t1 = header.req("exit_t1")?.as_f64().unwrap_or(-1.0) as f32;
        let t2 = header.req("exit_t2")?.as_f64().unwrap_or(-1.0) as f32;
        Ok(ModelState {
            arch,
            params,
            momenta,
            masks,
            qbits: QBits {
                weight: header.req("qbits_w")?.as_f64().unwrap_or(0.0) as f32,
                act: header.req("qbits_a")?.as_f64().unwrap_or(0.0) as f32,
            },
            exits: ExitState {
                trained: header.req("exits_trained")?.as_bool().unwrap_or(false),
                thresholds: if t1 >= 0.0 { Some((t1, t2)) } else { None },
                exit_probs: (
                    header.req("exit_p1")?.as_f64().unwrap_or(0.0),
                    header.req("exit_p2")?.as_f64().unwrap_or(0.0),
                ),
            },
            extras: StorageExtras {
                cluster_bits: header
                    .get("cluster_bits")
                    .and_then(|v| v.as_f64())
                    .filter(|&v| v >= 0.0)
                    .map(|v| v as f32),
                coded_weight_bits: header
                    .get("coded_weight_bits")
                    .and_then(|v| v.as_f64())
                    .filter(|&v| v >= 0.0),
            },
            history: header
                .get("history")
                .and_then(|h| h.as_arr())
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default(),
        })
    }
}

/// Host-side replica of the L1 `weight_quant` (DoReFa + max|w| rescale) —
/// used to materialize *deployed* weight values for entropy-coding
/// analysis.  Must match python/compile/kernels/fake_quant.py.
pub fn host_weight_quant(w: &Tensor, bits: f32) -> Tensor {
    let mut data = vec![0.0f32; w.len()];
    host_weight_quant_into(&w.data, bits, &mut data);
    Tensor::new(w.shape.clone(), data)
}

/// DoReFa scale pair `(tmax, wmax)` shared by `host_weight_quant_into`
/// and the int8 packer in `models::compressed` — one pass over the raw
/// weights instead of the former two (the per-element `tanh` scan was
/// half the quantization cost on the refback per-forward path).
///
/// Relies on `|tanh v| = tanh |v|` (odd symmetry) and f32 `tanh` being
/// monotonic, so `max_i |tanh v_i| = tanh(max_i |v_i|)` — pinned
/// bit-identical against the two-pass reference by a regression test.
pub fn weight_quant_scales(w: &[f32]) -> (f32, f32) {
    let mut amax = 0.0f32;
    for &v in w {
        amax = amax.max(v.abs());
    }
    (amax.tanh().max(1e-8), amax.max(1e-8))
}

/// `host_weight_quant` into a caller-provided buffer, so the reference
/// backend's per-layer/per-step quantization writes into reused scratch
/// storage instead of allocating.  Identity copy when `bits <= 0`.
pub fn host_weight_quant_into(w: &[f32], bits: f32, out: &mut [f32]) {
    debug_assert_eq!(w.len(), out.len());
    if bits <= 0.0 {
        out.copy_from_slice(w);
        return;
    }
    let n = (2f32.powf(bits) - 1.0).max(1.0);
    let (tmax, wmax) = weight_quant_scales(w);
    for (o, &v) in out.iter_mut().zip(w) {
        let tn = v.tanh() / (2.0 * tmax) + 0.5;
        *o = (2.0 * ((tn * n).round() / n) - 1.0) * wmax;
    }
}

// ---------------------------------------------------------------------------
// BitOps / storage accounting.
// ---------------------------------------------------------------------------

/// MACs for one layer given active channel counts.
pub fn layer_macs(l: &LayerDesc, cin_active: usize, cout_active: usize) -> f64 {
    let spatial = (l.hout * l.wout) as f64;
    match l.kind {
        LayerKind::Conv => spatial * (l.k * l.k) as f64 * cin_active as f64 * cout_active as f64,
        // Depthwise: one filter per channel.
        LayerKind::DwConv => spatial * (l.k * l.k) as f64 * cout_active as f64,
        LayerKind::Dense => cin_active as f64 * cout_active as f64,
    }
}

/// Weight-parameter count for one layer given active channels (bias excluded).
pub fn layer_weight_count(l: &LayerDesc, cin_active: usize, cout_active: usize) -> f64 {
    match l.kind {
        LayerKind::Conv => (l.k * l.k) as f64 * cin_active as f64 * cout_active as f64,
        LayerKind::DwConv => (l.k * l.k) as f64 * cout_active as f64,
        LayerKind::Dense => cin_active as f64 * cout_active as f64,
    }
}

pub struct Accountant<'a> {
    pub state: &'a ModelState,
}

impl<'a> Accountant<'a> {
    pub fn new(state: &'a ModelState) -> Self {
        Accountant { state }
    }

    fn active(&self, slot: i64, full: usize) -> usize {
        self.state.active_channels(slot, full)
    }

    /// BitOps for one layer under the current masks/bits.  The stem layer
    /// (raw image input) always pays fp32 activation bits — the first
    /// layer's input is never quantized (standard QAT practice and the
    /// paper's setup).
    pub fn layer_bitops(&self, l: &LayerDesc) -> f64 {
        let cin = self.active(l.in_mask, l.cin);
        let cout = self.active(l.out_mask, l.cout);
        let q = &self.state.qbits;
        let ba = if l.in_mask < 0 && l.cin <= 4 { FP_BITS } else { q.effective_a() };
        layer_macs(l, cin, cout) * q.effective_w() * ba
    }

    fn segment_bitops(&self, segment: &str) -> f64 {
        self.state
            .arch
            .layers
            .iter()
            .filter(|l| l.segment == segment)
            .map(|l| self.layer_bitops(l))
            .sum()
    }

    /// Expected BitOps per inference under the current exit policy.
    ///
    /// Without exits: seg1+seg2+seg3.  With exits enabled, exit heads are
    /// always evaluated on the path that reaches them and the expectation
    /// is taken over the measured exit distribution.
    pub fn expected_bitops(&self) -> f64 {
        let s1 = self.segment_bitops("seg1");
        let s2 = self.segment_bitops("seg2");
        let s3 = self.segment_bitops("seg3");
        let e1 = self.segment_bitops("exit1");
        let e2 = self.segment_bitops("exit2");
        if !self.state.exits.trained || self.state.exits.thresholds.is_none() {
            return s1 + s2 + s3;
        }
        let (p1, p2) = self.state.exits.exit_probs;
        let p3 = (1.0 - p1 - p2).max(0.0);
        p1 * (s1 + e1) + p2 * (s1 + e1 + s2 + e2) + p3 * (s1 + e1 + s2 + e2 + s3)
    }

    /// Total storage bits for deployable parameters: weights at the weight
    /// bit-width (active channels only), biases at fp32.  Exit-head
    /// parameters count only when exits are deployed.
    ///
    /// Deep-Compression-style extras override the per-weight cost:
    /// clustering stores log2(k) bits/weight + a k-entry fp32 codebook per
    /// layer; Huffman coding replaces the whole weight payload with the
    /// measured coded size.
    pub fn storage_bits(&self) -> f64 {
        if let Some(coded) = self.state.extras.coded_weight_bits {
            // Coded payload covers all weights; biases stay fp32.
            let bias_bits: f64 = self
                .deployable_layers()
                .map(|l| self.active(l.out_mask, l.cout) as f64 * FP_BITS)
                .sum();
            return coded + bias_bits;
        }
        let q = &self.state.qbits;
        let per_weight = self.state.extras.cluster_bits.map(|b| b as f64);
        let mut bits = 0.0;
        for l in self.deployable_layers() {
            let cin = self.active(l.in_mask, l.cin);
            let cout = self.active(l.out_mask, l.cout);
            let w = layer_weight_count(l, cin, cout);
            match per_weight {
                Some(cb) => {
                    // index bits + per-layer codebook (2^cb centroids).
                    bits += w * cb + (2f64.powf(cb)) * FP_BITS;
                }
                None => bits += w * q.effective_w(),
            }
            bits += cout as f64 * FP_BITS; // bias
        }
        bits
    }

    fn deployable_layers(&self) -> impl Iterator<Item = &LayerDesc> {
        let exits_deployed = self.state.exits.trained;
        self.state
            .arch
            .layers
            .iter()
            .filter(move |l| exits_deployed || !l.segment.starts_with("exit"))
    }

    /// fp32, unpruned, exit-free single-pass cost — the paper's baseline.
    pub fn baseline_bitops(arch: &ArchManifest) -> f64 {
        arch.layers
            .iter()
            .filter(|l| !l.segment.starts_with("exit"))
            .map(|l| {
                let ba = if l.in_mask < 0 && l.cin <= 4 { FP_BITS } else { FP_BITS };
                layer_macs(l, l.cin, l.cout) * FP_BITS * ba
            })
            .sum()
    }

    pub fn baseline_storage(arch: &ArchManifest) -> f64 {
        arch.layers
            .iter()
            .filter(|l| !l.segment.starts_with("exit"))
            .map(|l| {
                layer_weight_count(l, l.cin, l.cout) * FP_BITS + l.cout as f64 * FP_BITS
            })
            .sum()
    }

    /// The paper's headline metrics.
    pub fn bitops_cr(&self) -> f64 {
        Self::baseline_bitops(&self.state.arch) / self.expected_bitops().max(1.0)
    }

    pub fn storage_cr(&self) -> f64 {
        Self::baseline_storage(&self.state.arch) / self.storage_bits().max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_arch() -> Arc<ArchManifest> {
        let layers = vec![
            LayerDesc {
                name: "c1".into(),
                kind: LayerKind::Conv,
                k: 3,
                cin: 3,
                cout: 8,
                stride: 1,
                hout: 8,
                wout: 8,
                in_mask: -1,
                out_mask: 0,
                segment: "seg1".into(),
                input: String::new(),
                act: true,
            },
            LayerDesc {
                name: "fc".into(),
                kind: LayerKind::Dense,
                k: 1,
                cin: 8,
                cout: 4,
                stride: 1,
                hout: 1,
                wout: 1,
                in_mask: 0,
                out_mask: -1,
                segment: "seg3".into(),
                input: String::new(),
                act: true,
            },
            LayerDesc {
                name: "exit1_fc".into(),
                kind: LayerKind::Dense,
                k: 1,
                cin: 8,
                cout: 4,
                stride: 1,
                hout: 1,
                wout: 1,
                in_mask: 0,
                out_mask: -1,
                segment: "exit1".into(),
                input: String::new(),
                act: true,
            },
        ];
        Arc::new(ArchManifest {
            name: "toy".into(),
            num_classes: 4,
            param_shapes: vec![
                vec![3, 3, 3, 8],
                vec![8],
                vec![8, 4],
                vec![4],
                vec![8, 4],
                vec![4],
            ],
            mask_slots: vec![MaskSlot { name: "m0".into(), channels: 8 }],
            layers,
            graphs: BTreeMap::new(),
            train_batch: 2,
            eval_batch: 2,
            stage_batch: 1,
            stage_batches: vec![1],
            stage_h1_shape: vec![1, 8, 8, 8],
            stage_h2_shape: vec![1, 8, 8, 8],
            joins: Vec::new(),
        })
    }

    #[test]
    fn baseline_macs() {
        let arch = toy_arch();
        // c1: 8*8 * 9 * 3 * 8 = 13824 MACs; fc: 8*4 = 32.
        let want = (13824.0 + 32.0) * 32.0 * 32.0;
        assert_eq!(Accountant::baseline_bitops(&arch), want);
    }

    #[test]
    fn quantization_reduces_bitops() {
        let arch = toy_arch();
        let mut st = ModelState::init_host(arch, 0);
        let base = Accountant::new(&st).expected_bitops();
        st.qbits = QBits { weight: 1.0, act: 8.0 };
        let q = Accountant::new(&st).expected_bitops();
        // conv input is the image (fp32 acts); fc gets 1x8.
        assert!(q < base / 30.0, "q={q} base={base}");
        assert!(Accountant::new(&st).bitops_cr() > 30.0);
    }

    #[test]
    fn pruning_reduces_bitops_linearly() {
        let arch = toy_arch();
        let mut st = ModelState::init_host(arch, 0);
        let full = Accountant::new(&st).expected_bitops();
        // Kill half the channels in slot 0.
        for i in 0..4 {
            st.masks[0].data[i] = 0.0;
        }
        let half = Accountant::new(&st).expected_bitops();
        assert!((half / full - 0.5).abs() < 0.01, "{half} vs {full}");
    }

    #[test]
    fn exits_reduce_expected_bitops() {
        let arch = toy_arch();
        let mut st = ModelState::init_host(arch, 0);
        let no_exit = Accountant::new(&st).expected_bitops();
        st.exits = ExitState {
            trained: true,
            thresholds: Some((0.8, 0.8)),
            exit_probs: (0.9, 0.05),
        };
        let with_exit = Accountant::new(&st).expected_bitops();
        // 90% of traffic stops after seg1+exit head; fc (seg3) is tiny here
        // compared to c1, so expectation barely exceeds seg1 cost.
        assert!(with_exit < no_exit * 1.01);
        // and the exit head itself is accounted:
        assert!(with_exit > 0.0);
    }

    #[test]
    fn storage_counts_exits_only_when_deployed() {
        let arch = toy_arch();
        let mut st = ModelState::init_host(arch, 0);
        let without = Accountant::new(&st).storage_bits();
        st.exits.trained = true;
        let with = Accountant::new(&st).storage_bits();
        assert!(with > without);
    }

    #[test]
    fn save_load_roundtrip() {
        let arch = toy_arch();
        let mut st = ModelState::init_host(arch.clone(), 3);
        st.qbits = QBits { weight: 2.0, act: 8.0 };
        st.masks[0].data[1] = 0.0;
        st.exits = ExitState {
            trained: true,
            thresholds: Some((0.8, 0.7)),
            exit_probs: (0.4, 0.3),
        };
        st.history.push("quantize(2w8a)".into());
        let path = std::env::temp_dir().join(format!("coc_state_{}.bin", std::process::id()));
        st.save(&path).unwrap();
        let st2 = ModelState::load(&path, arch).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(st.params, st2.params);
        assert_eq!(st.momenta, st2.momenta);
        assert_eq!(st.masks, st2.masks);
        assert_eq!(st.qbits, st2.qbits);
        assert_eq!(st2.exits.thresholds, Some((0.8, 0.7)));
        assert!(st2.exits.trained);
        assert_eq!(st2.history, vec!["quantize(2w8a)".to_string()]);
    }

    #[test]
    fn tagged_save_load_verifies_node_and_version() {
        let arch = toy_arch();
        let st = ModelState::init_host(arch.clone(), 7);
        let path = std::env::temp_dir().join(format!("coc_state_tag_{}.bin", std::process::id()));
        st.save_tagged(&path, Some("deadbeef")).unwrap();

        // Matching tag loads; wrong tag is refused; untagged load ignores.
        assert!(ModelState::load_tagged(&path, arch.clone(), Some("deadbeef")).is_ok());
        assert!(ModelState::load_tagged(&path, arch.clone(), Some("cafebabe")).is_err());
        assert!(ModelState::load(&path, arch.clone()).is_ok());

        // An untagged file never satisfies an expected tag.
        st.save(&path).unwrap();
        assert!(ModelState::load_tagged(&path, arch.clone(), Some("deadbeef")).is_err());

        // A header claiming a future format version is rejected outright.
        let mut bytes = std::fs::read(&path).unwrap();
        let nl = bytes.iter().position(|&b| b == b'\n').unwrap();
        let header = String::from_utf8(bytes[..nl].to_vec())
            .unwrap()
            .replace(&format!("\"version\":{STATE_FORMAT_VERSION}"), "\"version\":99");
        let mut patched = header.into_bytes();
        patched.extend_from_slice(&bytes[nl..]);
        bytes = patched;
        std::fs::write(&path, &bytes).unwrap();
        let err = ModelState::load(&path, arch).unwrap_err();
        assert!(err.to_string().contains("newer"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupt_snapshots_fail_cleanly() {
        let arch = toy_arch();
        let st = ModelState::init_host(arch.clone(), 5);
        let path =
            std::env::temp_dir().join(format!("coc_state_corrupt_{}.bin", std::process::id()));
        st.save_tagged(&path, Some("feedc0de")).unwrap();
        let full = std::fs::read(&path).unwrap();
        let nl = full.iter().position(|&b| b == b'\n').unwrap();

        // Truncated payload: the checksum reports corruption before shape
        // parsing can walk off the end.
        std::fs::write(&path, &full[..full.len() - 7]).unwrap();
        let err = ModelState::load_tagged(&path, arch.clone(), Some("feedc0de")).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");

        // A single flipped bit deep in the payload — valid lengths, valid
        // header, silently different weights without the checksum.
        let mut flipped = full.clone();
        let mid = nl + 1 + (full.len() - nl - 1) / 2;
        flipped[mid] ^= 0x40;
        std::fs::write(&path, &flipped).unwrap();
        let err = ModelState::load_tagged(&path, arch.clone(), Some("feedc0de")).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");

        // Zero-length file: a clean error, never a panic.
        std::fs::write(&path, b"").unwrap();
        assert!(ModelState::load_tagged(&path, arch.clone(), Some("feedc0de")).is_err());

        // A checksum-less header (written before the field existed) still
        // loads — old caches stay valid.
        let header = String::from_utf8(full[..nl].to_vec()).unwrap();
        let pos = header.rfind(",\"checksum\"").unwrap();
        let mut legacy = format!("{}}}", &header[..pos]).into_bytes();
        legacy.extend_from_slice(&full[nl..]);
        std::fs::write(&path, &legacy).unwrap();
        let st2 = ModelState::load_tagged(&path, arch.clone(), Some("feedc0de")).unwrap();
        assert_eq!(st.params, st2.params);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn model_state_is_send_and_sync() {
        // Compile-enforced: worker threads in serve::worker move ModelState
        // (and everything it holds) across threads.
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<ModelState>();
        assert_send_sync::<ArchManifest>();
        assert_send_sync::<Manifest>();
    }

    #[test]
    fn stage_graph_tags_and_best_batch() {
        assert_eq!(ArchManifest::stage_graph_tag(1, 1), "stage1");
        assert_eq!(ArchManifest::stage_graph_tag(2, 8), "stage2_b8");
        let mut arch = (*toy_arch()).clone();
        arch.stage_batches = vec![1, 4, 8];
        arch.graphs.insert("stage1_b4".into(), "f4".into());
        arch.graphs.insert("stage1_b8".into(), "f8".into());
        assert_eq!(arch.best_stage_batch(16), 8);
        assert_eq!(arch.best_stage_batch(7), 4);
        assert_eq!(arch.best_stage_batch(1), 1);
        // A declared batch without a lowered graph is ignored.
        arch.graphs.remove("stage1_b8");
        assert_eq!(arch.best_stage_batch(16), 4);
    }

    #[test]
    fn ref_builtin_manifest_is_consistent() {
        let m = builtin_ref_manifest();
        for name in BUILTIN_REF_ARCHS {
            let arch = m.arch(name).unwrap();
            assert_eq!(arch.name, name);
            assert_eq!(arch.param_shapes.len(), 2 * arch.layers.len());
            for l in &arch.layers {
                if l.out_mask >= 0 {
                    assert_eq!(
                        arch.mask_slots[l.out_mask as usize].channels,
                        l.cout,
                        "{name}/{}",
                        l.name
                    );
                }
                if l.in_mask >= 0 {
                    assert_eq!(
                        arch.mask_slots[l.in_mask as usize].channels,
                        l.cin,
                        "{name}/{}",
                        l.name
                    );
                }
            }
            for j in &arch.joins {
                assert!(j.out_mask >= 0, "{name}/{}: builtin joins are masked", j.name);
                // Join operands must resolve to a declared node.
                for op in std::iter::once(&j.a).chain(j.b.as_ref()) {
                    assert!(
                        arch.layers.iter().any(|l| &l.name == op)
                            || arch.joins.iter().any(|jj| &jj.name == op),
                        "{name}/{}: unknown operand {op}",
                        j.name
                    );
                }
            }
            for tag in [
                "init", "train", "eval", "stage1", "stage2", "stage3", "stage1_b8", "stage2_b8",
                "stage3_b8",
            ] {
                assert!(arch.graphs.contains_key(tag), "{name}: missing graph tag {tag}");
            }
            assert_eq!(arch.best_stage_batch(8), 8);
            assert_eq!(arch.best_stage_batch(7), 1);
            let st = ModelState::init_host(arch.clone(), 1);
            assert_eq!(st.params.len(), arch.num_params());
            assert_eq!(st.masks.len(), arch.mask_slots.len());
        }
        assert_eq!(
            m.arch("mini_resnet").unwrap().joins.len(),
            6,
            "mini_resnet has one join per basic block"
        );
        assert_eq!(m.arch("mini_vgg").unwrap().joins.len(), 0);
        assert_eq!(m.arch("mini_mobilenet").unwrap().mask_slots.len(), 10);
    }

    #[test]
    fn single_pass_weight_quant_is_bit_identical_to_two_pass() {
        // The retired two-pass scan (per-element tanh for tmax): the
        // single-pass rewrite must reproduce it bit-for-bit, including on
        // adversarial inputs (all below the 1e-8 seed floor, exact ties,
        // negatives — where |tanh v| = tanh |v| symmetry is load-bearing).
        fn two_pass(w: &[f32], bits: f32, out: &mut [f32]) {
            if bits <= 0.0 {
                out.copy_from_slice(w);
                return;
            }
            let n = (2f32.powf(bits) - 1.0).max(1.0);
            let mut tmax = 1e-8f32;
            let mut wmax = 1e-8f32;
            for &v in w {
                tmax = tmax.max(v.tanh().abs());
                wmax = wmax.max(v.abs());
            }
            for (o, &v) in out.iter_mut().zip(w) {
                let tn = v.tanh() / (2.0 * tmax) + 0.5;
                *o = (2.0 * ((tn * n).round() / n) - 1.0) * wmax;
            }
        }
        let mut rng = Rng::new(0xfeed);
        let mut cases: Vec<Vec<f32>> = (0..50)
            .map(|i| (0..(1 + i * 7) % 97).map(|_| rng.normal()).collect())
            .collect();
        cases.push(vec![1e-12, -1e-12, 0.0]); // under the seed floor
        cases.push(vec![2.5, 2.5, -2.5]); // exact ties, sign symmetry
        cases.push(vec![-7.0]); // extremum is negative
        cases.push(vec![]);
        for w in &cases {
            for bits in [0.0f32, 1.0, 2.0, 4.0, 8.0] {
                let mut want = vec![0.0f32; w.len()];
                let mut got = vec![0.0f32; w.len()];
                two_pass(w, bits, &mut want);
                host_weight_quant_into(w, bits, &mut got);
                let (wb, gb): (Vec<u32>, Vec<u32>) = (
                    want.iter().map(|v| v.to_bits()).collect(),
                    got.iter().map(|v| v.to_bits()).collect(),
                );
                assert_eq!(wb, gb, "bits={bits} w={w:?}");
            }
        }
    }

    #[test]
    fn keep_fraction() {
        let arch = toy_arch();
        let mut st = ModelState::init_host(arch, 0);
        assert_eq!(st.keep_fraction(), 1.0);
        st.masks[0].data[0] = 0.0;
        assert!((st.keep_fraction() - 7.0 / 8.0).abs() < 1e-9);
    }
}
