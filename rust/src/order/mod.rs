//! The Combinational Sequence Law machinery (paper §3–§5).
//!
//! Pairwise preferences between techniques form a directed graph; the
//! paper's claim is that the graph is a DAG with a *unique* topological
//! order — D → P → Q → E — matching two principles: static before dynamic,
//! coarse granularity before fine.  This module turns measured pairwise
//! preferences into that order and exposes enumeration helpers for the
//! order-comparison experiments (Table 1).

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::chain::Technique;

/// A measured pairwise preference: applying `first` then `second` beat the
/// reverse order with the given score margin.
#[derive(Debug, Clone)]
pub struct Preference {
    pub first: Technique,
    pub second: Technique,
    /// frontier_score(first,second) - frontier_score(second,first); > 0
    /// means the (first, second) order wins.
    pub margin: f64,
}

/// Preference graph over the four techniques.
#[derive(Debug, Default, Clone)]
pub struct PreferenceGraph {
    /// edge (a -> b) = "apply a before b", with margin.
    pub edges: BTreeMap<(Technique, Technique), f64>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SortOutcome {
    /// A unique topological order exists (the paper's combinational law).
    Unique(Vec<Technique>),
    /// A valid order exists but is not unique (missing comparisons).
    Ambiguous(Vec<Technique>),
    /// The preferences contain a cycle — no consistent order.
    Cycle(Vec<Technique>),
}

impl PreferenceGraph {
    pub fn add(&mut self, p: Preference) {
        if p.margin >= 0.0 {
            self.edges.insert((p.first, p.second), p.margin);
        } else {
            self.edges.insert((p.second, p.first), -p.margin);
        }
    }

    pub fn nodes(&self) -> Vec<Technique> {
        let mut ns: Vec<Technique> = self
            .edges
            .keys()
            .flat_map(|&(a, b)| [a, b])
            .collect();
        ns.sort();
        ns.dedup();
        ns
    }

    /// Kahn's algorithm with uniqueness detection: the order is unique iff
    /// at every step exactly one node has zero in-degree.
    pub fn toposort(&self) -> SortOutcome {
        let nodes = self.nodes();
        let mut indeg: BTreeMap<Technique, usize> =
            nodes.iter().map(|&n| (n, 0)).collect();
        for (_, b) in self.edges.keys() {
            *indeg.get_mut(b).unwrap() += 1;
        }
        let mut order = Vec::new();
        let mut unique = true;
        let mut remaining = indeg.clone();
        while !remaining.is_empty() {
            let zero: Vec<Technique> = remaining
                .iter()
                .filter(|(_, &d)| d == 0)
                .map(|(&n, _)| n)
                .collect();
            if zero.is_empty() {
                return SortOutcome::Cycle(order);
            }
            if zero.len() > 1 {
                unique = false;
            }
            let n = zero[0];
            order.push(n);
            remaining.remove(&n);
            for (&(a, b), _) in &self.edges {
                if a == n {
                    if let Some(d) = remaining.get_mut(&b) {
                        *d = d.saturating_sub(1);
                    }
                }
            }
        }
        if unique {
            SortOutcome::Unique(order)
        } else {
            SortOutcome::Ambiguous(order)
        }
    }
}

/// The paper's derived law, for assertions and defaults.
pub fn paper_law() -> Vec<Technique> {
    vec![Technique::Distill, Technique::Prune, Technique::Quantize, Technique::EarlyExit]
}

/// All orderings of the four techniques that start with Distillation —
/// the Table 1 comparison set (DPQE, DQPE, DPEQ, DQEP, DEPQ, DEQP).
pub fn distill_started_orders() -> Vec<Vec<Technique>> {
    use Technique::*;
    let rest = [Prune, Quantize, EarlyExit];
    let mut out = Vec::new();
    // All permutations of the remaining three.
    let idx = [[0, 1, 2], [0, 2, 1], [1, 0, 2], [1, 2, 0], [2, 0, 1], [2, 1, 0]];
    for p in idx {
        let mut o = vec![Distill];
        o.extend(p.iter().map(|&i| rest[i]));
        out.push(o);
    }
    out
}

pub fn sequence_string(seq: &[Technique]) -> String {
    seq.iter().map(|t| t.letter()).collect()
}

pub fn parse_sequence(s: &str) -> Result<Vec<Technique>> {
    s.chars()
        .map(|c| Technique::from_letter(c).ok_or_else(|| anyhow!("bad technique letter `{c}`")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chain::Technique::*;
    use crate::util::prop;

    fn pref(a: Technique, b: Technique) -> Preference {
        Preference { first: a, second: b, margin: 1.0 }
    }

    #[test]
    fn paper_preferences_give_unique_dpqe() {
        // The six measured pairwise orders from §3.
        let mut g = PreferenceGraph::default();
        for (a, b) in [
            (Distill, Prune),
            (Distill, Quantize),
            (Distill, EarlyExit),
            (Prune, Quantize),
            (Prune, EarlyExit),
            (Quantize, EarlyExit),
        ] {
            g.add(pref(a, b));
        }
        assert_eq!(g.toposort(), SortOutcome::Unique(paper_law()));
    }

    #[test]
    fn negative_margin_flips_edge() {
        let mut g = PreferenceGraph::default();
        g.add(Preference { first: Prune, second: Distill, margin: -2.0 });
        assert!(g.edges.contains_key(&(Distill, Prune)));
    }

    #[test]
    fn missing_edges_ambiguous() {
        let mut g = PreferenceGraph::default();
        g.add(pref(Distill, Prune));
        g.add(pref(Quantize, EarlyExit));
        match g.toposort() {
            SortOutcome::Ambiguous(o) => assert_eq!(o.len(), 4),
            other => panic!("want ambiguous, got {other:?}"),
        }
    }

    #[test]
    fn cycle_detected() {
        let mut g = PreferenceGraph::default();
        g.add(pref(Distill, Prune));
        g.add(pref(Prune, Quantize));
        g.add(pref(Quantize, Distill));
        assert!(matches!(g.toposort(), SortOutcome::Cycle(_)));
    }

    #[test]
    fn distill_orders_enumeration() {
        let orders = distill_started_orders();
        assert_eq!(orders.len(), 6);
        let strings: Vec<String> = orders.iter().map(|o| sequence_string(o)).collect();
        for want in ["DPQE", "DQPE", "DPEQ", "DQEP", "DEPQ", "DEQP"] {
            assert!(strings.contains(&want.to_string()), "missing {want}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        let seq = parse_sequence("DPQE").unwrap();
        assert_eq!(sequence_string(&seq), "DPQE");
        assert!(parse_sequence("DPX").is_err());
    }

    /// Property: any complete, acyclic preference set over the 4 techniques
    /// yields a unique topological order consistent with every edge.
    #[test]
    fn prop_complete_acyclic_is_unique_and_consistent() {
        prop::check(
            "toposort complete acyclic",
            200,
            |rng| {
                // Random linear order of the 4 techniques; derive all 6 edges.
                let mut ts = [Distill, Prune, Quantize, EarlyExit];
                for i in (1..4).rev() {
                    ts.swap(i, rng.below(i + 1));
                }
                ts.to_vec()
            },
            |ts| {
                let mut g = PreferenceGraph::default();
                for i in 0..4 {
                    for j in (i + 1)..4 {
                        g.add(Preference { first: ts[i], second: ts[j], margin: 1.0 });
                    }
                }
                match g.toposort() {
                    SortOutcome::Unique(o) if o == *ts => Ok(()),
                    other => Err(format!("want Unique({ts:?}), got {other:?}")),
                }
            },
        );
    }
}

// `Technique` has no Shrink impl needed beyond default.
impl crate::util::prop::Shrink for Technique {}
