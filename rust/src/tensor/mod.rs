//! Host-side f32 tensor: the coordinator's working representation of model
//! parameters, masks and batches.  Conversion to/from `xla::Literal`
//! happens in `runtime`; everything else (init, norms, reductions used by
//! pruning importance) lives here.

use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} does not match data length {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![1.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(data: Vec<f32>) -> Self {
        Tensor { shape: vec![data.len()], data }
    }

    /// He/Kaiming-normal init for a conv/dense weight with given fan-in.
    pub fn he_normal(shape: &[usize], fan_in: usize, rng: &mut Rng) -> Self {
        let std = (2.0 / fan_in as f32).sqrt();
        let data = (0..shape.iter().product::<usize>())
            .map(|_| rng.normal() * std)
            .collect();
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// L2 norm of the whole tensor.
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Per-output-channel L2 norms for a weight tensor whose LAST axis is
    /// the output-channel axis (HWIO conv weights and [in, out] dense
    /// weights both satisfy this) — the channel-importance signal used by
    /// the pruning stage.
    ///
    /// Row-wise `chunks_exact` accumulation instead of per-element
    /// `i % c` modulo indexing: each channel still sums its contributions
    /// in ascending row order (bit-identical results), without a hardware
    /// divide per element on the pruning path.
    pub fn channel_l2(&self) -> Vec<f32> {
        let c = *self.shape.last().expect("channel_l2 on rank-0 tensor");
        let mut out = vec![0.0f32; c];
        if c == 0 {
            return out;
        }
        debug_assert_eq!(self.data.len() % c, 0, "tensor length is a multiple of its last axis");
        for row in self.data.chunks_exact(c) {
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v * v;
            }
        }
        for v in &mut out {
            *v = v.sqrt();
        }
        out
    }

    /// Number of non-zero entries (mask occupancy).
    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    pub fn argmax(&self) -> usize {
        argmax_slice(&self.data)
    }

    /// Row-wise argmax for a [n, c] tensor.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        self.data.chunks_exact(c).map(argmax_slice).collect()
    }

    /// Row-wise softmax for a [n, c] tensor (used for exit confidences).
    ///
    /// Allocation-free per row: exponentials are written straight into the
    /// output buffer and normalized in place (this sits on the per-request
    /// exit-confidence path, where a per-row scratch `Vec` was measurable
    /// allocator traffic).  Identical arithmetic order to the per-row-
    /// buffer version: exp left-to-right, sum left-to-right, then divide —
    /// so results are bit-identical.
    pub fn softmax_rows(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let c = self.shape[1];
        let mut out = Vec::with_capacity(self.data.len());
        for row in self.data.chunks_exact(c) {
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let start = out.len();
            out.extend(row.iter().map(|x| (x - m).exp()));
            let sum: f32 = out[start..].iter().sum();
            for v in &mut out[start..] {
                *v /= sum;
            }
        }
        Tensor::new(self.shape.clone(), out)
    }

    /// Flattened view of one row of a [n, ...] batch tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        let stride: usize = self.shape[1..].iter().product();
        &self.data[i * stride..(i + 1) * stride]
    }
}

/// Argmax of a logits row — the one tie-breaking rule shared by eval,
/// exits and serving: 0 for empty input, the *highest* index among exact
/// ties (`Iterator::max_by` keeps the last maximum).  Total over all f32
/// bit patterns via `f32::total_cmp`: a NaN logit row returns its NaN
/// index (positive NaN orders above +inf) deterministically instead of
/// aborting the whole serve batch, as the `partial_cmp(..).unwrap()` it
/// replaces did.
pub fn argmax_slice(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape_check() {
        let t = Tensor::new(vec![2, 3], vec![1.0; 6]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.rank(), 2);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![1.0; 5]);
    }

    #[test]
    fn he_normal_scale() {
        let mut rng = Rng::new(3);
        let t = Tensor::he_normal(&[3, 3, 64, 64], 3 * 3 * 64, &mut rng);
        let var = t.data.iter().map(|x| x * x).sum::<f32>() / t.len() as f32;
        let want = 2.0 / (3.0 * 3.0 * 64.0);
        assert!((var - want).abs() < want * 0.2, "var {var} want {want}");
    }

    #[test]
    fn channel_l2_last_axis() {
        // shape [2, 3]: columns are channels.
        let t = Tensor::new(vec![2, 3], vec![1.0, 0.0, 2.0, 1.0, 0.0, 2.0]);
        let n = t.channel_l2();
        assert!((n[0] - (2.0f32).sqrt()).abs() < 1e-6);
        assert_eq!(n[1], 0.0);
        assert!((n[2] - (8.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn channel_l2_chunked_matches_modulo_reference_bitwise() {
        // The chunks_exact rewrite must keep the exact per-channel
        // summation order of the old `i % c` walk — including on shapes
        // whose row count is odd / not a multiple of any unroll width,
        // where a blocked or reordered accumulation would diverge.
        let mut rng = Rng::new(11);
        for shape in [vec![7, 5], vec![3, 3, 4], vec![1, 9], vec![13], vec![5, 1]] {
            let t = Tensor::new(
                shape.clone(),
                (0..shape.iter().product::<usize>()).map(|_| rng.normal()).collect(),
            );
            let c = *t.shape.last().unwrap();
            let mut want = vec![0.0f32; c];
            for (i, &v) in t.data.iter().enumerate() {
                want[i % c] += v * v;
            }
            for v in &mut want {
                *v = v.sqrt();
            }
            assert_eq!(t.channel_l2(), want, "shape {shape:?}");
        }
        // Degenerate 0-channel tensor stays total (and must not panic in
        // chunks_exact).
        assert!(Tensor::new(vec![2, 0], vec![]).channel_l2().is_empty());
    }

    #[test]
    fn softmax_rows_normalized() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 1.0, 2.0, 5.0, 5.0, 5.0]);
        let s = t.softmax_rows();
        for row in s.data.chunks_exact(3) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
        assert!((s.data[3] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.2, 3.0, 1.0, 2.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn argmax_is_total_over_nan_and_ties() {
        // NaN must not abort (the old partial_cmp unwrap did) and must be
        // deterministic: positive NaN orders above +inf under total_cmp,
        // so a NaN row picks its NaN index.
        assert_eq!(argmax_slice(&[f32::NAN, 1.0]), 0);
        assert_eq!(argmax_slice(&[1.0, f32::NAN]), 1);
        assert_eq!(argmax_slice(&[f32::NAN, f32::NAN]), 1, "ties keep the last maximum");
        // Negative NaN orders below -inf: it never wins against a finite.
        assert_eq!(argmax_slice(&[-f32::NAN, -1.0]), 1);
        // Exact ties resolve to the highest index (Iterator::max_by keeps
        // the last maximum) — the rule eval, exits and serving all share.
        assert_eq!(argmax_slice(&[2.0, 2.0, 1.0]), 1);
        assert_eq!(argmax_slice(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 1);
        // Degenerate inputs stay total.
        assert_eq!(argmax_slice(&[]), 0);
        assert_eq!(argmax_slice(&[-0.0, 0.0]), 1, "+0 > -0 under total_cmp");
    }

    #[test]
    fn softmax_rows_handles_many_rows_without_row_state_leaking() {
        // In-place normalization must be per-row: a uniform row after a
        // peaked row comes out uniform.
        let t = Tensor::new(vec![3, 2], vec![10.0, -10.0, 3.0, 3.0, -1.0, 1.0]);
        let s = t.softmax_rows();
        assert!(s.data[0] > 0.999);
        assert!((s.data[2] - 0.5).abs() < 1e-6 && (s.data[3] - 0.5).abs() < 1e-6);
        for row in s.data.chunks_exact(2) {
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
    }
}
