//! Observability: tracing, metrics, leveled logging, and the bench ledger.
//!
//! Self-contained (no external crates — see DESIGN.md "Substrates built
//! from scratch") and deliberately boring on the hot path: every
//! instrumentation site costs one relaxed atomic load when its subsystem
//! is disabled, and none of it touches numerics, so instrumented runs are
//! bit-identical to bare ones (`ref_golden_digest_is_thread_count_invariant`
//! pins this with a traced re-run).
//!
//! * [`trace`]   — `span()`-scoped timers with hierarchical parent ids,
//!   per-thread buffers, and Chrome `trace_event` / JSONL export
//!   (`--trace-out PATH` on the CLI).
//! * [`metrics`] — typed counters/gauges/log2-bucket histograms plus the
//!   process-wide named registry; histogram merges are associative and
//!   deterministic.
//! * [`ledger`]  — the committed `BENCH_<area>.json` trajectory and the
//!   `coc bench-diff` regression gate.
//! * `obs::log!` — leveled logging to stderr (level from `COC_LOG`:
//!   `error|warn|info|debug`, default `info`), sharing the capture sink
//!   with traces so tests can assert on emitted events.

pub mod ledger;
pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

/// Log severity, most severe first.  A configured level admits itself and
/// everything more severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" | "e" | "0" => Some(Level::Error),
            "warn" | "warning" | "w" | "1" => Some(Level::Warn),
            "info" | "i" | "2" => Some(Level::Info),
            "debug" | "d" | "3" => Some(Level::Debug),
            _ => None,
        }
    }
}

const LEVEL_UNSET: u8 = u8::MAX;
static MAX_LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != LEVEL_UNSET {
        return v;
    }
    // First call parses COC_LOG once; unparseable values fall back to the
    // default rather than erroring (logging must never fail a run).
    let lvl = std::env::var("COC_LOG")
        .ok()
        .as_deref()
        .and_then(Level::parse)
        .unwrap_or(Level::Info) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the env-configured level (tests; `--verbose`-style flags).
pub fn set_log_level(l: Level) {
    MAX_LEVEL.store(l as u8, Ordering::Relaxed);
}

/// Whether a `log!` at `l` would emit — callers can gate expensive
/// formatting on this (the macro already does).
#[inline]
pub fn log_enabled(l: Level) -> bool {
    (l as u8) <= max_level()
}

static CAPTURE: AtomicBool = AtomicBool::new(false);

fn capture_buf() -> &'static Mutex<Vec<(Level, String)>> {
    static BUF: OnceLock<Mutex<Vec<(Level, String)>>> = OnceLock::new();
    BUF.get_or_init(|| Mutex::new(Vec::new()))
}

/// Emit one formatted record: stderr always, plus the in-memory capture
/// buffer when a [`LogCapture`] is live.  Not meant to be called directly
/// — use `obs::log!`.
#[doc(hidden)]
pub fn log_emit(level: Level, msg: String) {
    eprintln!("{msg}");
    if CAPTURE.load(Ordering::Relaxed) {
        capture_buf().lock().unwrap_or_else(|e| e.into_inner()).push((level, msg));
    }
}

/// Test hook: while a `LogCapture` is alive, every `obs::log!` record is
/// also appended to a shared in-memory buffer.  The capture state is
/// process-global, so records from concurrently running tests interleave —
/// assert with `contains`, not equality.
pub struct LogCapture(());

impl LogCapture {
    pub fn start() -> LogCapture {
        capture_buf().lock().unwrap_or_else(|e| e.into_inner()).clear();
        CAPTURE.store(true, Ordering::SeqCst);
        LogCapture(())
    }

    /// Stop capturing and return everything recorded since `start`.
    pub fn take(self) -> Vec<(Level, String)> {
        CAPTURE.store(false, Ordering::SeqCst);
        std::mem::take(&mut *capture_buf().lock().unwrap_or_else(|e| e.into_inner()))
    }
}

impl Drop for LogCapture {
    fn drop(&mut self) {
        CAPTURE.store(false, Ordering::Relaxed);
    }
}

/// Leveled log macro: `obs::log!(Level::Warn, "queue full: {n}")`.
/// Arguments are not even formatted when the level is filtered out.
#[macro_export]
macro_rules! coc_log {
    ($lvl:expr, $($arg:tt)*) => {{
        let lvl = $lvl;
        if $crate::obs::log_enabled(lvl) {
            $crate::obs::log_emit(lvl, format!($($arg)*));
        }
    }};
}

pub use crate::coc_log as log;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_ordering() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("DEBUG"), Some(Level::Debug));
        assert_eq!(Level::parse("bogus"), None);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Debug);
        assert_eq!(Level::Warn.name(), "warn");
    }

    #[test]
    fn log_capture_sees_emitted_events() {
        let cap = LogCapture::start();
        // Error always passes the filter regardless of COC_LOG.
        crate::obs::log!(Level::Error, "obs-test-marker {}", 42);
        let records = cap.take();
        assert!(
            records.iter().any(|(l, m)| *l == Level::Error && m == "obs-test-marker 42"),
            "{records:?}"
        );
    }

    #[test]
    fn filtered_levels_do_not_format() {
        // A Debug record under the default Info level must not evaluate
        // its arguments (the macro short-circuits before format!).
        if std::env::var("COC_LOG").is_ok() {
            return; // the environment overrides the default; skip
        }
        set_log_level(Level::Info);
        let evaluated = std::cell::Cell::new(false);
        crate::obs::log!(Level::Debug, "never: {}", {
            evaluated.set(true);
            "x"
        });
        assert!(!evaluated.get(), "filtered log! must not format its arguments");
    }
}
