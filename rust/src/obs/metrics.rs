//! Typed metrics: counters, gauges and fixed-log2-bucket histograms, plus
//! a process-wide named registry.
//!
//! Design constraints (from the determinism contract in DESIGN.md):
//!
//! * **Recording is lock-free on the hot path** — `Counter`/`Gauge` are a
//!   single relaxed atomic op; call sites cache the `Arc` handle so the
//!   registry's map lock is paid once at setup, never per event.
//! * **Histogram merges are associative, commutative and deterministic** —
//!   a histogram is a fixed vector of integer bucket counts, and merging
//!   is bucket-wise `u64` addition.  Raw sample vectors (the old
//!   `Summary` representation) concatenate, which is order-dependent for
//!   every derived quantile under re-sorting ties and unbounded in
//!   memory; bucket counts are neither.  `util::prop` pins the algebra.
//! * Bucket boundaries are powers of two and the bucket index is computed
//!   from the f64 exponent bits — no `log2` call, bit-exact on every
//!   platform.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

// ----- primitives -----------------------------------------------------------

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-write-wins instantaneous value (stored as f64 bits).  Also tracks
/// the maximum ever set, which is what queue-depth reporting wants.
#[derive(Debug)]
pub struct Gauge {
    bits: AtomicU64,
    max_bits: AtomicU64,
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

impl Gauge {
    pub const fn new() -> Gauge {
        // 0.0f64 is all-zero bits, so const-init works without to_bits().
        Gauge { bits: AtomicU64::new(0), max_bits: AtomicU64::new(0) }
    }

    #[inline]
    pub fn set(&self, v: f64) {
        let b = v.to_bits();
        self.bits.store(b, Ordering::Relaxed);
        // Monotonic max over non-negative values: for IEEE-754 doubles
        // >= 0, the bit pattern orders like the value.
        if v >= 0.0 {
            self.max_bits.fetch_max(b, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    pub fn reset(&self) {
        self.bits.store(0, Ordering::Relaxed);
        self.max_bits.store(0, Ordering::Relaxed);
    }
}

/// Number of log2 buckets.  Bucket 0 holds everything `< 2^-15` (and
/// non-positive values); bucket `i` (1..62) holds `[2^(i-16), 2^(i-15))`;
/// bucket 63 holds `>= 2^47`.  For microsecond-scale latencies that spans
/// sub-nanosecond to ~1.6 days.
pub const HIST_BUCKETS: usize = 64;
const HIST_EXP_BIAS: i64 = 16;

/// Bucket index of a value — pure bit arithmetic on the f64 exponent, so
/// identical on every platform and under every optimization level.
#[inline]
pub fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v <= 0.0 {
        return 0; // zero, negatives, NaN
    }
    let e = ((v.to_bits() >> 52) & 0x7ff) as i64 - 1023; // floor(log2 v) for normals
    (e + HIST_EXP_BIAS).clamp(0, HIST_BUCKETS as i64 - 1) as usize
}

/// Lower edge of bucket `i` (0.0 for bucket 0).
fn bucket_lo(i: usize) -> f64 {
    if i == 0 {
        0.0
    } else {
        f64::from_bits((((i as i64 - HIST_EXP_BIAS + 1023) as u64) & 0x7ff) << 52)
    }
}

/// Upper edge of bucket `i`.
fn bucket_hi(i: usize) -> f64 {
    bucket_lo(i + 1)
}

/// Fixed-size log2-bucket histogram.  The whole state is the bucket-count
/// vector: `merge` is bucket-wise addition, hence associative, commutative
/// and deterministic, and memory is O(1) regardless of sample count.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; HIST_BUCKETS] }
    }
}

impl Histogram {
    pub fn record(&mut self, v: f64) {
        self.buckets[bucket_of(v)] += 1;
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.iter().all(|&b| b == 0)
    }

    /// Quantile estimate by linear interpolation inside the owning bucket
    /// (same rank convention as `util::stats::percentile`: rank spans
    /// `0..count-1`).  Empty histograms return 0.0.  Resolution is the
    /// bucket width — callers with exact min/max should clamp.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 100.0) / 100.0) * (n - 1) as f64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let last_in_bucket = (seen + c - 1) as f64;
            if rank <= last_in_bucket {
                // Position within this bucket's occupants, mapped linearly
                // across the bucket span.
                let frac = if c == 1 {
                    0.5
                } else {
                    (rank - seen as f64) / (c - 1) as f64
                };
                return bucket_lo(i) + frac * (bucket_hi(i) - bucket_lo(i));
            }
            seen += c;
        }
        bucket_hi(HIST_BUCKETS - 1)
    }

    /// How many recorded values are `<= x`, to bucket resolution: full
    /// buckets below `x`'s bucket count exactly; `x`'s own bucket
    /// contributes a linearly interpolated share.
    pub fn count_le(&self, x: f64) -> u64 {
        if x.is_nan() || x <= 0.0 {
            return 0;
        }
        let bx = bucket_of(x);
        let mut n = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if i < bx {
                n += c;
            } else if i == bx {
                let lo = bucket_lo(i);
                let hi = bucket_hi(i);
                let frac = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
                n += (c as f64 * frac).round() as u64;
            }
        }
        n
    }
}

// ----- registry -------------------------------------------------------------

/// Process-wide named metrics.  Naming scheme (see DESIGN.md
/// "Observability"): dotted `area.object.event` paths, e.g.
/// `serve.queue.accepted`, `plan.cache.hit`, `refback.kernel.conv2d`.
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<Mutex<Histogram>>>>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(Registry::default)
}

/// Get-or-create the named counter.  Call once and cache the handle —
/// the map lock is for setup, not the hot path.
pub fn counter(name: &str) -> Arc<Counter> {
    let mut m = registry().counters.lock().unwrap();
    m.entry(name.to_string()).or_default().clone()
}

pub fn gauge(name: &str) -> Arc<Gauge> {
    let mut m = registry().gauges.lock().unwrap();
    m.entry(name.to_string()).or_default().clone()
}

pub fn histogram(name: &str) -> Arc<Mutex<Histogram>> {
    let mut m = registry().histograms.lock().unwrap();
    m.entry(name.to_string()).or_default().clone()
}

/// Flat snapshot of every registered metric: `(name, value)` sorted by
/// name.  Gauges contribute `name` and `name.max`; histograms contribute
/// count/p50/p95/p99.
pub fn snapshot() -> Vec<(String, f64)> {
    let reg = registry();
    let mut out = Vec::new();
    for (k, c) in reg.counters.lock().unwrap().iter() {
        out.push((k.clone(), c.get() as f64));
    }
    for (k, g) in reg.gauges.lock().unwrap().iter() {
        out.push((k.clone(), g.get()));
        out.push((format!("{k}.max"), g.max()));
    }
    for (k, h) in reg.histograms.lock().unwrap().iter() {
        let h = h.lock().unwrap();
        out.push((format!("{k}.count"), h.count() as f64));
        out.push((format!("{k}.p50"), h.quantile(50.0)));
        out.push((format!("{k}.p95"), h.quantile(95.0)));
        out.push((format!("{k}.p99"), h.quantile(99.0)));
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    out
}

/// Zero every registered metric (tests; `bench-diff --update` workflows).
pub fn reset_all() {
    let reg = registry();
    for c in reg.counters.lock().unwrap().values() {
        c.reset();
    }
    for g in reg.gauges.lock().unwrap().values() {
        g.reset();
    }
    for h in reg.histograms.lock().unwrap().values() {
        *h.lock().unwrap() = Histogram::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let c = Counter::new();
        c.add(3);
        c.incr();
        assert_eq!(c.get(), 4);
        c.reset();
        assert_eq!(c.get(), 0);

        let g = Gauge::new();
        g.set(2.5);
        g.set(1.0);
        assert_eq!(g.get(), 1.0);
        assert_eq!(g.max(), 2.5);
    }

    #[test]
    fn registry_hands_out_shared_handles() {
        let a = counter("test.registry.shared");
        let b = counter("test.registry.shared");
        a.add(2);
        b.add(3);
        assert_eq!(a.get(), 5);
        let snap = snapshot();
        assert!(snap.iter().any(|(k, v)| k == "test.registry.shared" && *v >= 5.0));
    }

    #[test]
    fn bucket_edges_partition_the_line() {
        // Every value lands in exactly the bucket whose [lo, hi) contains
        // it, including at the edges.
        for i in 1..HIST_BUCKETS - 1 {
            let lo = bucket_lo(i);
            let hi = bucket_hi(i);
            assert_eq!(bucket_of(lo), i, "lower edge of bucket {i}");
            assert_eq!(bucket_of(hi * 0.999_999), i, "interior of bucket {i}");
            assert_eq!(bucket_of(hi), i + 1, "upper edge belongs to bucket {}", i + 1);
            assert!(hi > lo);
        }
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(f64::NAN), 0);
        assert_eq!(bucket_of(1e300), HIST_BUCKETS - 1);
    }

    #[test]
    fn histogram_quantiles_track_exact_to_bucket_resolution() {
        let mut h = Histogram::default();
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        for &x in &xs {
            h.record(x);
        }
        assert_eq!(h.count(), 1000);
        for q in [50.0, 95.0, 99.0] {
            let exact = crate::util::stats::percentile(&xs, q);
            let est = h.quantile(q);
            // log2 buckets: the estimate lands within the true value's
            // bucket, i.e. within a factor of 2.
            assert!(est >= exact / 2.0 && est <= exact * 2.0, "q{q}: est {est} vs exact {exact}");
        }
        assert_eq!(h.count_le(0.0), 0);
        assert_eq!(h.count_le(1e9), 1000);
        let le = h.count_le(500.0) as f64;
        assert!((le - 500.0).abs() <= 260.0, "count_le(500) ~ 500 to bucket resolution, got {le}");
    }

    #[test]
    fn prop_histogram_merge_is_associative_and_commutative() {
        crate::util::prop::check(
            "histogram merge algebra",
            120,
            |r| {
                let mut sets = Vec::new();
                for _ in 0..3 {
                    let n = r.below(40);
                    sets.push((0..n).map(|_| r.f32() as f64 * 1e5).collect::<Vec<f64>>());
                }
                (sets[0].clone(), sets[1].clone(), sets[2].clone())
            },
            |(xa, xb, xc)| {
                let build = |xs: &[f64]| {
                    let mut h = Histogram::default();
                    for &x in xs {
                        h.record(x);
                    }
                    h
                };
                let (a, b, c) = (build(xa), build(xb), build(xc));
                // (a + b) + c == a + (b + c)
                let mut l = a.clone();
                l.merge(&b);
                l.merge(&c);
                let mut bc = b.clone();
                bc.merge(&c);
                let mut r1 = a.clone();
                r1.merge(&bc);
                if l != r1 {
                    return Err("merge is not associative".into());
                }
                // a + b == b + a
                let mut ab = a.clone();
                ab.merge(&b);
                let mut ba = b.clone();
                ba.merge(&a);
                if ab != ba {
                    return Err("merge is not commutative".into());
                }
                // Merging equals recording the concatenation.
                let mut all: Vec<f64> = xa.clone();
                all.extend_from_slice(xb);
                all.extend_from_slice(xc);
                if l != build(&all) {
                    return Err("merge differs from recording the union".into());
                }
                Ok(())
            },
        );
    }
}
